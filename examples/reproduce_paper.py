"""Full reproduction of the paper's Section 6 experiments.

    PYTHONPATH=src python examples/reproduce_paper.py [--n-jobs 10000]
        [--seeds 3] [--sweep umed|load|flex|all]

10^4 jobs per point with multi-seed 95% confidence intervals, as in the
paper ("For each experiment, 10^4 jobs were submitted ... and we have
obtained 95% confidence intervals").  Budget ~30-60 min for --sweep all
at full size on one core; reduced sizes preserve the orderings.
"""
from __future__ import annotations

import argparse
import time
from collections import defaultdict

from repro.core.types import ALL_POLICIES
from repro.sim import WorkloadParams, generate, mean_ci95, run_policies

SWEEPS = {
    "umed": [("u_med", float(v)) for v in (5, 6, 7, 8, 9)],
    "load": [("arrival_factor", v) for v in (0.5, 0.75, 1.0, 1.25, 1.5)],
    "flex": [("flex", float(v)) for v in (1, 2, 3, 4, 5)],
}


def run_sweep(name: str, n_jobs: int, seeds: int) -> None:
    print(f"\n=== sweep: {name} (n_jobs={n_jobs}, {seeds} seeds) ===")
    print(f"{'point':>8s} {'policy':8s} {'accept':>8s} {'±':>7s} "
          f"{'slowdown':>9s} {'±':>7s}")
    for key, value in SWEEPS[name]:
        acc = defaultdict(list)
        slow = defaultdict(list)
        for seed in range(seeds):
            kw = ({"artime_factor": value, "deadline_factor": value}
                  if key == "flex" else {key: value})
            jobs = generate(WorkloadParams(n_jobs=n_jobs, seed=seed,
                                           **kw))
            for r in run_policies(jobs, 1024, ALL_POLICIES):
                acc[r.policy].append(r.acceptance_rate)
                slow[r.policy].append(r.avg_slowdown)
        for pol in ALL_POLICIES:
            a, a_ci = mean_ci95(acc[pol.value])
            s, s_ci = mean_ci95(slow[pol.value])
            print(f"{value:>8} {pol.value:8s} {a:8.4f} {a_ci:7.4f} "
                  f"{s:9.4f} {s_ci:7.4f}", flush=True)
        best = max(acc, key=lambda p: sum(acc[p]) / len(acc[p]))
        fastest = min(slow, key=lambda p: sum(slow[p]) / len(slow[p]))
        print(f"         -> best acceptance: {best}, "
              f"lowest slowdown: {fastest}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-jobs", type=int, default=10_000)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--sweep", default="all",
                    choices=["umed", "load", "flex", "all"])
    args = ap.parse_args()
    t0 = time.time()
    names = list(SWEEPS) if args.sweep == "all" else [args.sweep]
    for name in names:
        run_sweep(name, args.n_jobs, args.seeds)
    print(f"\ntotal {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
