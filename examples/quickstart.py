"""Quickstart: schedule deadline-constrained AR jobs on a cluster.

Reproduces the paper's Figure 1 walkthrough through the service API
(`repro.api.ReservationService`), then compares the seven policies on
the same request — on all three engines (literal list oracle, numpy
host, JAX device) to show they agree bit-for-bit.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import ReservationService, ServiceConfig
from repro.core import ALL_POLICIES, ARRequest

N_PE = 100


def build_session(engine: str):
    svc = ReservationService(ServiceConfig(n_pe=N_PE, engine=engine))
    s = svc.session()
    s.add_allocation(0, 300, range(0, 20))        # job1: running
    s.add_allocation(0, 100, range(20, 50))       # job2: running
    s.add_allocation(800, 1000, range(0, 25))     # job3: reserved
    return s


def main() -> None:
    print("cluster with 2 running jobs + 1 reservation (paper Fig. 1)")
    req = ARRequest(t_a=0, t_r=200, t_du=200, t_dl=900, n_pe=40)
    print(f"new AR request: ready={req.t_r} duration={req.t_du} "
          f"deadline={req.t_dl} n_pe={req.n_pe}\n")
    header = f"{'policy':8s} | " + " | ".join(
        f"{e:>22s}" for e in ("list", "host", "device"))
    print(header)
    print("-" * len(header))
    for pol in ALL_POLICIES:
        cells = []
        for engine in ("list", "host", "device"):
            s = build_session(engine)
            a = s.find_allocation(req, pol)
            r = a.rectangle
            cells.append(f"t_s={a.t_s} rect({r.t_begin},"
                         f"{r.t_end if r.t_end < 2**31-1 else 'inf'},"
                         f"{r.n_free})")
        agree = "OK" if len(set(cells)) == 1 else "MISMATCH"
        print(f"{pol.value:8s} | " + " | ".join(
            f"{c:>22s}" for c in cells) + f"  [{agree}]")
    print("\nFF starts earliest (t=200); PE_W/Du_B wait for the bigger"
          " all-free rectangle at t=300 — the paper's Section 5 example.")
    print("\nFor streaming admission (offer/tick/cancel) see "
          "examples/service_demo.py.")


if __name__ == "__main__":
    main()
