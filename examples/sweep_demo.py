"""Sweep demo: the paper's Section-6 experiment matrix in one dispatch.

Runs policies × loads × seeds as lanes of a single vmapped on-device
scan (``simulate_grid``, DESIGN.md §4) and prints the stacked metrics:
the paper's headline orderings — PE Worst Fit accepts the most jobs,
First Fit gives the lowest slowdown — drop out of one ``GridResult``.

``--backfill`` adds the deferral-queue scenario axis (DESIGN.md §6):
the same policies run under {none, easy, conservative} backfilling in
the *same* dispatch (the mode is traced per lane), showing EASY's
acceptance gain over strict arrival-order admission.

``--sharded`` shards the grid's lane axis over every local device
(``ServiceConfig.placement="auto"``, DESIGN.md §8) — same single
dispatch, bit-identical decisions, lanes spread across the mesh.
Force a multi-device host on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

    PYTHONPATH=src python examples/sweep_demo.py [--n-jobs 150]
    PYTHONPATH=src python examples/sweep_demo.py --backfill
    PYTHONPATH=src python examples/sweep_demo.py --sharded
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.sim import GridSpec, WorkloadParams, simulate_grid


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-jobs", type=int, default=150,
                    help="jobs per grid cell")
    ap.add_argument("--n-pe", type=int, default=64)
    ap.add_argument("--backfill", action="store_true",
                    help="add the {none, easy, conservative} "
                         "backfilling axis (small fragmented machine)")
    ap.add_argument("--sharded", action="store_true",
                    help="shard the lane axis over every local device "
                         "(placement='auto', DESIGN.md §8)")
    args = ap.parse_args()

    if args.backfill:
        # a small machine with relatively wide jobs: fragmentation
        # gives the EASY displacement real holes to fill
        spec = GridSpec(
            arrival_factors=(2.5,),
            seeds=(3, 5),
            flex_factors=(3.0,),
            backfill_modes=("none", "easy", "conservative"),
            base=WorkloadParams(u_low=2.0, u_med=3.0, u_hi=4.0),
            n_pe=16,
            n_jobs=args.n_jobs,
            park_capacity=8,
        )
    else:
        spec = GridSpec(
            arrival_factors=(1.0, 1.5, 2.0),
            seeds=(0, 1, 2),
            flex_factors=(3.0,),
            base=WorkloadParams(u_low=2.0, u_med=4.0, u_hi=6.0),
            n_pe=args.n_pe,
            n_jobs=args.n_jobs,
        )
    print(f"grid: {len(spec.policies)} policies x "
          f"{len(spec.backfill_modes)} backfill modes x "
          f"{len(spec.arrival_factors)} loads x {len(spec.seeds)} "
          f"seeds = {spec.n_cells} cells, one vmapped dispatch")
    placement = "auto" if args.sharded else "single"
    if args.sharded:
        import jax

        from repro.launch.mesh import data_shards, make_lane_mesh
        mesh = make_lane_mesh(spec.n_cells)
        print(f"placement=auto: {spec.n_cells} lanes sharded "
              f"{data_shards(mesh)}-way over {jax.device_count()} "
              "local device(s), decisions identical to single-device")
    print()
    r = simulate_grid(spec, capacity=64 if args.backfill else 128,
                      placement=placement)
    print(r.summary())

    acc, sd = r.policy_acceptance(), r.policy_slowdown()
    print(f"\nhighest acceptance: "
          f"{max(acc, key=acc.get)} (paper: PE_W)")
    print(f"lowest slowdown:    {min(sd, key=sd.get)} (paper: FF)")

    if args.backfill:
        by_mode = r.mode_policy_acceptance()
        print("\nacceptance by backfill mode (grid mean per policy):")
        for mode in r.backfill_modes:
            mean = float(np.mean(list(by_mode[mode].values())))
            print(f"  {mode:12s} {mean:.3f}  "
                  + " ".join(f"{p}={by_mode[mode][p]:.3f}"
                             for p in ("PE_W", "FF")))
        gain = np.mean(list(by_mode["easy"].values())) - \
            np.mean(list(by_mode["none"].values()))
        print(f"\nEASY accepts {gain:+.3f} over strict arrival-order "
              f"admission; conservative is decision-identical to it")
    else:
        pe_w = list(r.policies).index("PE_W")
        by_load = np.nanmean(r.acceptance[pe_w, 0], axis=(1, 2))
        print("\nPE_W acceptance vs load "
              f"{list(spec.arrival_factors)}: "
              f"{[round(float(x), 3) for x in by_load]} "
              "(paper Fig. 4 expects a decreasing trend)")


if __name__ == "__main__":
    main()
