"""Service demo: one long-lived session admitting a live request feed.

The paper's scheduler is a *service*: AR requests arrive continuously
and each must be answered immediately.  This demo drives a
`repro.api.ReservationService` session the way an RPC frontend would —
arrivals trickle in small irregular groups, every `offer` answers with
concrete reservations, `tick` releases finished jobs, and one customer
cancels.  Because arrivals stage through the fixed-shape ring buffer,
the device never re-pads and never recompiles after the first chunk,
no matter how the groups are sized.

    PYTHONPATH=src python examples/service_demo.py [--n-jobs 400]
        [--index-tile T]

``--index-tile`` attaches the hierarchical availability index
(DESIGN.md §12) to the session's timeline: admission decisions are
bit-identical either way (the index only prunes provably hopeless
work), which the CI smoke verifies by diffing this demo's output
between an indexed and an index-free run.
"""
from __future__ import annotations

import argparse
import random

from repro.api import ReservationService, ServiceConfig
from repro.core import batch as batch_lib
from repro.core.types import ARRequest, Policy
from repro.sim import WorkloadParams, generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-jobs", type=int, default=400)
    ap.add_argument("--n-pe", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--index-tile", type=int, default=None,
                    help="tile size for the hierarchical availability "
                         "index (None = index off; decisions are "
                         "identical either way)")
    args = ap.parse_args()
    random.seed(args.seed)

    jobs = [j for j in generate(WorkloadParams(
        n_jobs=args.n_jobs, n_pe=args.n_pe, seed=args.seed,
        u_low=2.0, u_med=4.0, u_hi=6.0)) if j.n_pe <= args.n_pe]
    jobs.sort(key=lambda j: j.t_a)

    svc = ReservationService(ServiceConfig(
        n_pe=args.n_pe, policy=Policy.PE_W, chunk_size=args.chunk,
        ring_capacity=4 * args.chunk, index_tile=args.index_tile))
    session = svc.session()
    print(f"service up: n_pe={args.n_pe}, policy=PE_W, "
          f"chunk={args.chunk} (fixed admission shape), "
          f"index_tile={args.index_tile}\n")

    # -- arrivals in irregular groups, decisions per group -------------
    compiles_after_warmup = None
    i, group = 0, 0
    while i < len(jobs):
        take = random.randint(1, 3 * args.chunk // 2)
        batch = jobs[i:i + take]
        res = session.offer(batch)
        if group == 0:
            compiles_after_warmup = batch_lib.admit_stream._cache_size()
        if group < 4 or i + take >= len(jobs):
            print(f"  group {group:3d}: offered {len(batch):3d} "
                  f"accepted {res.n_accepted:3d}")
        elif group == 4:
            print("  ...")
        i += take
        group += 1
    assert compiles_after_warmup == batch_lib.admit_stream._cache_size(), \
        "streaming admission recompiled after warmup"

    m = session.metrics()
    print(f"\n{m['offered']} requests over {group} offers -> "
          f"{m['chunks']} fixed-shape chunks, {m['growths']} capacity "
          f"growths, ring wrapped={m['ring_wrapped']}")
    print(f"accepted {m['accepted']} "
          f"({m['accepted'] / max(m['offered'], 1):.0%}); zero "
          f"recompilation after warmup (jit cache stable)")

    # -- the other verbs ----------------------------------------------
    horizon = max(j.t_dl for j in jobs) + 1
    print(f"\ntick({horizon}) released {session.tick(horizon)} "
          f"finished reservations; timeline records left: "
          f"{len(session.records())}")
    future = ARRequest(t_a=horizon, t_r=horizon, t_du=600,
                       t_dl=horizon + 1800, n_pe=args.n_pe // 2)
    alloc = session.offer([future]).allocations()[0]
    print(f"reserve [{alloc.t_s}, {alloc.t_e}) x "
          f"{len(alloc.pe_ids)} PEs, then cancel -> "
          f"{session.cancel(alloc)} (cancel again -> "
          f"{session.cancel(alloc)}: idempotent)")


if __name__ == "__main__":
    main()
