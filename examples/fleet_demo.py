"""Fleet demo: the paper's AR scheduler running a 512-chip TPU fleet.

Submits a mixed stream of training/serving jobs over the assigned
architectures, then injects the failure modes the runtime must absorb:
chip failures (checkpoint-granular migration), stragglers (deadline-
slack stretching), and elastic rescaling.

    PYTHONPATH=src python examples/fleet_demo.py [--policy PE_W]
"""
from __future__ import annotations

import argparse
import random

from repro.core import Policy
from repro.runtime import FleetScheduler, JobState

WORKLOAD = [
    # (arch, shape, chips, steps)
    ("kimi-k2-1t-a32b", "train_4k", 512, 200),
    ("qwen3-4b", "train_4k", 256, 1500),
    ("minitron-8b", "train_4k", 256, 800),
    ("granite-moe-1b-a400m", "train_4k", 128, 3000),
    ("stablelm-1.6b", "train_4k", 64, 2000),
    ("starcoder2-7b", "prefill_32k", 128, 20_000),
    ("llama-3.2-vision-11b", "decode_32k", 128, 50_000),
    ("zamba2-7b", "long_500k", 64, 100_000),
    ("xlstm-1.3b", "decode_32k", 32, 80_000),
    ("seamless-m4t-medium", "decode_32k", 32, 60_000),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="PE_W",
                    choices=[p.value for p in Policy])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    random.seed(args.seed)
    fleet = FleetScheduler(n_chips=512, policy=Policy(args.policy))

    print(f"=== submitting {len(WORKLOAD)} jobs "
          f"(policy={args.policy}) ===")
    jobs = []
    for i, (arch, shape, chips, steps) in enumerate(WORKLOAD):
        fleet.advance(fleet.now + random.randint(0, 300))
        j = fleet.submit(arch, shape, chips, steps,
                         deadline_slack=2.5)
        jobs.append(j)
        dur = j.t_end - j.t_start if j.t_start >= 0 else 0
        print(f"  [{j.state.value:8s}] {arch:22s} {shape:12s} "
              f"{chips:4d} chips  start={j.t_start:>7} "
              f"dur={dur:>7}s")

    running = [j for j in jobs if j.state != JobState.REJECTED]
    print(f"\naccepted {len(running)}/{len(jobs)}; fleet utilisation "
          f"(next 24h): {fleet.utilisation(86_400):.2f}")

    print("\n=== fault injection ===")
    victim = next(j for j in running if j.chips)
    fleet.advance(max(fleet.now, victim.t_start) + 600)
    chip = victim.chips[3]
    migrated = fleet.fail_chip(chip)
    print(f"chip {chip} failed at t={fleet.now}: migrated jobs "
          f"{migrated} (victim preemptions={victim.preemptions})")

    stragglers = [j for j in running
                  if j.state in (JobState.RUNNING, JobState.RESERVED)]
    if stragglers:
        s = stragglers[-1]
        ok = fleet.report_straggler(s.job_id, slowdown=1.4)
        print(f"straggler {s.arch}: re-reserved within deadline "
              f"slack -> {ok}")

    big = [j for j in running if j.n_chips >= 256
           and j.state in (JobState.RUNNING, JobState.RESERVED)]
    if big:
        b = big[-1]
        ok = fleet.rescale(b.job_id, b.n_chips // 2)
        print(f"elastic rescale {b.arch}: {b.n_chips * 2 if ok else b.n_chips}"
              f" -> {b.n_chips} chips -> {ok}")

    print(f"\nfinal states: {fleet.summary()}")
    print(f"event log ({len(fleet.events)} events), last 8:")
    for e in fleet.events[-8:]:
        print(f"  t={e[0]:>7} {e[1]:14s} id={e[2]}")

    partitioned_demo(args)


def partitioned_demo(args) -> None:
    """Partitioned fleet (DESIGN.md §4): 4 x 128-chip partitions
    behind one vmapped state, bulk traffic routed in one dispatch."""
    print("\n=== partitioned fleet: 4 x 128 chips, one vmapped state "
          "===")
    fleet = FleetScheduler(n_chips=512, n_partitions=4,
                           policy=Policy(args.policy),
                           routing="least_loaded")
    small = [(a, s, min(c, 128), n) for a, s, c, n in WORKLOAD
             if c <= 128] * 2
    specs = [dict(arch=a, shape=s, n_chips=c, n_steps=n)
             for a, s, c, n in small]
    jobs = fleet.submit_batch(specs)
    spread = {}
    for j in jobs:
        key = j.partition if j.partition >= 0 else "rejected"
        spread[key] = spread.get(key, 0) + 1
    print(f"submitted {len(jobs)} jobs in one routed dispatch; "
          f"partition spread: {dict(sorted(spread.items(), key=str))}")
    probe = fleet.submit_batch(
        [dict(arch="qwen3-4b", shape="train_4k", n_chips=64,
              n_steps=100)], routing="best_acceptance")[0]
    print(f"best-acceptance probe placed job on partition "
          f"{probe.partition} (state={probe.state.value})")


if __name__ == "__main__":
    main()
