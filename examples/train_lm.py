"""End-to-end driver: train a small LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py            # ~10M params
    PYTHONPATH=src python examples/train_lm.py --big      # ~100M params

Exercises the full production stack on the local device: config system,
deterministic sharded data pipeline, remat+microbatch train step, AdamW,
async atomic checkpointing, and restart (rerun the same command after a
kill and it resumes).  On real accelerators, launch/train.py runs the
same loop on the production mesh.
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--big", action="store_true",
                    help="~100M-parameter config (slow on CPU)")
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt/train_lm")
    args = ap.parse_args()

    # qwen3-style family, sized for the demo
    if args.big:
        base = get_config("qwen3-4b")
        # ~100M params: 12L x 512 wide, 32k vocab
        cfg = dataclasses.replace(
            base, name="qwen3-100m", n_layers=12, d_model=512,
            n_heads=8, n_kv_heads=4, head_dim=64, d_ff=1536,
            vocab=32_768)
        out = run("qwen3-100m", steps=args.steps, smoke=True,
                  batch=8, seq=256, ckpt_dir=args.ckpt_dir + "-big",
                  ckpt_every=50, microbatches=2, config=cfg)
    else:
        out = run("stablelm-1.6b", steps=args.steps, smoke=True,
                  batch=8, seq=128, ckpt_dir=args.ckpt_dir,
                  ckpt_every=50, microbatches=2)
    print(f"\nfinal: {out}")
    assert out["last_loss"] is None or out["first_loss"] is None or \
        out["last_loss"] < out["first_loss"], "loss should decrease"


if __name__ == "__main__":
    main()
