"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp

from repro.core import timeline as tl_lib
from repro.core.hostsched import (
    HostScheduler,
    ids_from_mask,
    lowest_bits,
    mask_from_ids,
    popcount,
)
from repro.core.listsched import ListScheduler
from repro.core.types import T_INF

# ---------------------------------------------------------------------------
# bitmask helpers
# ---------------------------------------------------------------------------


@given(st.sets(st.integers(0, 199), max_size=64))
def test_mask_roundtrip(ids):
    mask = mask_from_ids(ids, 200)
    assert set(ids_from_mask(mask)) == ids
    assert int(popcount(mask)) == len(ids)


@given(st.sets(st.integers(0, 99), min_size=1, max_size=60),
       st.data())
def test_lowest_bits_picks_smallest(ids, data):
    k = data.draw(st.integers(1, len(ids)))
    mask = mask_from_ids(ids, 100)
    sel = lowest_bits(mask, k)
    chosen = set(ids_from_mask(sel))
    assert len(chosen) == k
    assert chosen == set(sorted(ids)[:k])


@given(st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=8))
@settings(deadline=None)
def test_pack_unpack_roundtrip(words):
    w = np.array(words, dtype=np.uint32)[None, :]
    n_pe = w.shape[1] * 32
    bits = tl_lib.unpack_bits(jnp.asarray(w), n_pe)
    repacked = tl_lib.pack_bits(np.asarray(bits))
    assert np.array_equal(np.asarray(repacked), w)


# ---------------------------------------------------------------------------
# timeline semantics vs the literal paper oracle
# ---------------------------------------------------------------------------

op_strategy = st.lists(
    st.tuples(
        st.integers(0, 80),        # t_s
        st.integers(1, 20),        # duration
        st.sets(st.integers(0, 30), min_size=1, max_size=12),
    ),
    min_size=1, max_size=12,
)


@given(op_strategy)
@settings(max_examples=40, deadline=None)
def test_host_matches_oracle_under_random_ops(ops):
    n_pe = 31
    oracle = ListScheduler(n_pe)
    host = HostScheduler(n_pe)
    added = []
    for (t_s, du, pes) in ops:
        busy = oracle.window_busy(t_s, t_s + du)
        pes = pes - busy
        if not pes:
            continue
        oracle.add_allocation(t_s, t_s + du, set(pes))
        host.add_allocation(t_s, t_s + du, sorted(pes))
        added.append((t_s, t_s + du, pes))
        assert host.records() == oracle.records()
    # interleaved deletions restore agreement at every step
    for (t_s, t_e, pes) in added:
        oracle.delete_allocation(t_s, t_e, set(pes))
        host.delete_allocation(t_s, t_e, sorted(pes))
        assert host.records() == oracle.records()
    assert host.records() == []   # everything released -> empty


@given(op_strategy)
@settings(max_examples=30, deadline=None)
def test_timeline_invariants(ops):
    """Device timeline: sorted validity, merged neighbours, empty tail."""
    n_pe = 31
    oracle = ListScheduler(n_pe)
    tl = tl_lib.empty(64, n_pe)
    for (t_s, du, pes) in ops:
        busy = oracle.window_busy(t_s, t_s + du)
        pes = pes - busy
        if not pes:
            continue
        oracle.add_allocation(t_s, t_s + du, set(pes))
        mask_bits = np.zeros(tl.words * 32, np.uint32)
        for i in pes:
            mask_bits[i] = 1
        mask = tl_lib.pack_bits(mask_bits[None, :])[0]
        tl, overflow = tl_lib.update(tl, t_s, t_s + du, mask,
                                     is_add=True)
        assert not bool(overflow)
        times = np.asarray(tl.times)
        occ = np.asarray(tl.occ)
        valid = times < T_INF
        n_valid = int(valid.sum())
        # 1. valid entries sorted strictly ascending, prefix-packed
        assert np.all(valid[:n_valid])
        assert np.all(np.diff(times[:n_valid]) > 0)
        # 2. consecutive valid rows differ (paper's merge invariant)
        if n_valid > 1:
            assert np.all(
                np.any(occ[1:n_valid] != occ[:n_valid - 1], axis=1))
        # 3. last valid row empty (all free after the final boundary)
        if n_valid:
            assert not occ[n_valid - 1].any()
        # 4. padding rows are zeroed
        assert not occ[n_valid:].any()


# ---------------------------------------------------------------------------
# packed-word tail widths (n_pe % 32 != 0)
# ---------------------------------------------------------------------------


@given(st.integers(1, 160).filter(lambda n: n % 32), st.data())
@settings(max_examples=40, deadline=None)
def test_tail_width_pack_unpack_roundtrip(n_pe, data):
    """Every non-word-aligned machine size round-trips bit-exactly."""
    W = tl_lib.n_words(n_pe)
    on = data.draw(st.sets(st.integers(0, n_pe - 1)))
    bits = np.zeros(W * 32, np.uint32)
    for i in on:
        bits[i] = 1
    words = tl_lib.pack_bits(bits[None, :])
    back = np.asarray(tl_lib.unpack_bits(jnp.asarray(words), n_pe))[0]
    assert set(np.flatnonzero(back).tolist()) == on
    # the packed words carry nothing beyond bit n_pe - 1
    full = np.asarray(
        tl_lib.unpack_bits(jnp.asarray(words), W * 32))[0]
    assert not full[n_pe:].any()


@given(st.integers(1, 160).filter(lambda n: n % 32))
@settings(max_examples=40, deadline=None)
def test_tail_width_pe_valid_mask(n_pe):
    """pe_valid_mask sets exactly the first n_pe bits, tail zero."""
    vm = tl_lib.pe_valid_mask(n_pe)
    W = tl_lib.n_words(n_pe)
    assert vm.shape == (W,)
    assert int(popcount(vm)) == n_pe
    bits = np.asarray(tl_lib.unpack_bits(jnp.asarray(vm)[None, :],
                                         W * 32))[0]
    assert bits[:n_pe].all() and not bits[n_pe:].any()


@given(st.integers(1, 130).filter(lambda n: n % 32))
@settings(max_examples=15, deadline=None)
def test_tail_bits_never_leak_into_free_count(n_pe):
    """The padding bits of the last word are never counted free.

    On an all-free timeline the search must report exactly ``n_pe``
    free units — and a request for ``n_pe + 1`` must be infeasible —
    for every tail width.  A leak of the word-padding bits into the
    popcount contraction would break both.
    """
    from repro.core import search as search_lib

    tl = tl_lib.empty(16, n_pe)
    res = search_lib.search(
        tl, jnp.int32(0), jnp.int32(5), jnp.int32(1000),
        jnp.int32(n_pe), jnp.int32(0), jnp.int32(0), n_pe=n_pe)
    assert bool(res.found)
    assert int(res.n_free) == n_pe
    over = search_lib.search(
        tl, jnp.int32(0), jnp.int32(5), jnp.int32(1000),
        jnp.int32(n_pe + 1), jnp.int32(0), jnp.int32(0), n_pe=n_pe)
    assert not bool(over.found)
