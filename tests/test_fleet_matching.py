"""Batched fleet ingress vs the sequential probe-commit oracle.

PR 7 rebuilt :meth:`PartitionedCore.admit_stream_allocations` so an
N-request batch costs a bounded number of device dispatches (probe →
match → grouped-commit rounds plus a fused device-sequential tail)
instead of O(N) probe/commit round-trips.  The contract is *bit-exact
decision identity* with the sequential host loop it replaced, locked
here PR 4-style against :class:`repro.core.hostsched.FleetRoutingOracle`
for every routing:

* fast gate: 300 jobs, contended traffic, all three routings;
* slow gate: 1000 jobs × all 7 policies × all 3 routings;
* mid-batch growth (tiny capacity) must not perturb decisions;
* dispatch counts are bounded by the round limit, never by N;
* an 8-device subprocess runs the sharded matcher;
* partitioned sessions now thread backfill/auto-release through the
  core (parked requests promote on tick; cancel clears the pending
  slot).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import ReservationService, ServiceConfig
from repro.core import ARRequest, Policy
from repro.core.hostsched import FleetRoutingOracle
from repro.core.types import ALL_POLICIES
from repro.runtime.fleet import PartitionedCore

ROUTINGS3 = ("round_robin", "least_loaded", "best_acceptance")


def _gen(n, seed, spacing=20, dmin=50, dmax=600, slack=1.0, wmax=30,
         pemax=17):
    rng = np.random.default_rng(seed)
    reqs, t = [], 0
    for _ in range(n):
        t += int(rng.integers(0, spacing))
        dur = int(rng.integers(dmin, dmax))
        r = t + int(rng.integers(0, wmax))
        t_dl = r + int(dur * (1.0 + slack * rng.random()))
        reqs.append(ARRequest(t_a=t, t_r=r, t_du=dur, t_dl=t_dl,
                              n_pe=int(rng.integers(1, pemax))))
    return reqs


def _key(a):
    return None if a is None else (a.t_s, a.t_e, tuple(a.pe_ids))


def _assert_matches_oracle(n_chips, n_parts, reqs, policy, routing,
                           capacity=64, match_rounds=8):
    # match_rounds=8 forces the probe/match/commit rounds protocol on
    # single-device hosts (where auto mode goes straight to the fused
    # scan); match_rounds=None covers the auto path
    core = PartitionedCore(n_chips, n_parts, capacity=capacity,
                           match_rounds=match_rounds)
    got = core.admit_stream_allocations(reqs, policy, routing=routing)
    oracle = FleetRoutingOracle(n_chips, n_parts)
    exp = oracle.admit_batch(reqs, policy, routing)
    mism = [i for i in range(len(reqs)) if _key(got[i]) != _key(exp[i])]
    assert not mism, (
        f"{routing}/{policy}: request {mism[0]} got "
        f"{got[mism[0]]} want {exp[mism[0]]}")
    assert core.records() == oracle.records()
    return core


# ---------------------------------------------------------------------------
# decision identity vs the sequential oracle
# ---------------------------------------------------------------------------


def test_fast_gate_300_jobs_all_routings():
    reqs = _gen(300, seed=3, slack=0.6)
    for routing in ROUTINGS3:
        for policy in (Policy.FF, Policy.PEDU_W):
            _assert_matches_oracle(64, 4, reqs, policy, routing)


def test_fast_gate_auto_rounds_mode():
    """The auto heuristic (fused-only on a single device) must make
    the same decisions as the forced rounds protocol and the oracle."""
    reqs = _gen(300, seed=3, slack=0.6)
    for policy in (Policy.FF, Policy.PEDU_W):
        core = _assert_matches_oracle(64, 4, reqs, policy,
                                      "best_acceptance",
                                      match_rounds=None)
        if core.mesh is None or core.mesh.devices.size == 1:
            assert core.match_max_rounds == 0
            assert core.last_match_rounds == 0


@pytest.mark.slow
def test_slow_gate_1000_jobs_all_policies_all_routings():
    reqs = _gen(1000, seed=17, spacing=10, slack=0.8)
    for routing in ROUTINGS3:
        for policy in ALL_POLICIES:
            _assert_matches_oracle(128, 8, reqs, policy, routing,
                                   capacity=128)


def test_mid_batch_growth_is_decision_invariant():
    """capacity=8 forces the ensemble to grow mid-batch; the grown
    replay must reproduce the big-capacity decision sequence."""
    reqs = _gen(120, seed=11, spacing=8, slack=0.8)
    for routing in ROUTINGS3:
        core = _assert_matches_oracle(64, 4, reqs, Policy.FF, routing,
                                      capacity=8)
        assert core.states.tl.times.shape[-1] > 8    # actually grew


def test_tight_slack_exercises_rejections():
    reqs = _gen(200, seed=5, slack=0.1, spacing=6)
    core = _assert_matches_oracle(64, 4, reqs, Policy.PE_B,
                                  "best_acceptance")
    # the point of the scenario: a healthy mix of accept and reject
    assert 0 < core.last_match_rounds <= core.match_max_rounds


# ---------------------------------------------------------------------------
# dispatch complexity: bounded by rounds, never by N
# ---------------------------------------------------------------------------


def test_dispatch_count_constant_in_batch_size():
    counts = {}
    for n in (32, 128):
        core = PartitionedCore(64, 4, capacity=256, match_rounds=8)
        core.admit_stream_allocations(
            _gen(n, seed=7), Policy.FF, routing="best_acceptance")
        # per round: probe + match + grouped commit; plus one fused
        # tail dispatch.  (No growth at capacity=256.)
        assert core.dispatches <= 3 * core.match_max_rounds + 1, n
        counts[n] = core.dispatches
    # 4x the requests may take MORE rounds, never O(N) dispatches
    assert counts[128] <= 3 * PartitionedCore.match_max_rounds + 1

    # auto mode on a single device: the whole batch is one fused
    # matcher dispatch (plus staging), still constant in N
    core = PartitionedCore(64, 4, capacity=256)
    core.admit_stream_allocations(_gen(128, seed=7), Policy.FF,
                                  routing="best_acceptance")
    assert core.dispatches <= 3 * PartitionedCore.match_max_rounds + 1

    for routing in ("round_robin", "least_loaded"):
        core = PartitionedCore(64, 4, capacity=256)
        core.admit_stream_allocations(_gen(128, seed=7), Policy.FF,
                                      routing=routing)
        assert core.dispatches <= 2, routing   # route scan + commit


def test_route_preview_and_legacy_shim():
    core = PartitionedCore(64, 4, capacity=64)
    reqs = _gen(16, seed=2)
    lanes = core.route(reqs, "best_acceptance")
    assert len(lanes) == 16 and all(-1 <= l < 4 for l in lanes)
    # an impossible request previews as unroutable
    wide = ARRequest(t_a=0, t_r=0, t_du=10, t_dl=20, n_pe=17)
    assert core.route([wide], "best_acceptance") == [-1]
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError):
            core.route(reqs, "best_acceptance", legacy_raise=True)


def test_least_loaded_device_vector_tracks_commits():
    core = PartitionedCore(64, 4, capacity=64)
    oracle = FleetRoutingOracle(64, 4)
    reqs = _gen(60, seed=9)
    core.admit_stream_allocations(reqs, Policy.FF,
                                  routing="least_loaded")
    oracle.admit_batch(reqs, Policy.FF, "least_loaded")
    np.testing.assert_allclose(core.load, oracle.load)
    # the device copy used by the routing scan agrees with the ledger
    np.testing.assert_allclose(np.asarray(core._load_dev), core.load)


# ---------------------------------------------------------------------------
# partitioned sessions: backfill + auto-release threading (PR 7)
# ---------------------------------------------------------------------------


def test_partition_session_auto_release_ticks_all_lanes():
    sess = ReservationService(ServiceConfig(
        n_pe=32, n_partitions=2, chunk_size=None)).session()
    reqs = [ARRequest(t_a=0, t_r=0, t_du=100, t_dl=400, n_pe=8)
            for _ in range(4)]
    res = sess.offer(reqs, routing="round_robin")
    assert res.n_accepted == 4
    assert sess.tick(50) == 0
    assert sess.tick(500) == 4          # both lanes, one dispatch
    assert sess.records() == []
    assert sess.metrics()["released"] == 4


def test_partition_session_cancel_clears_pending_slot():
    sess = ReservationService(ServiceConfig(
        n_pe=32, n_partitions=2, chunk_size=None)).session()
    res = sess.offer([ARRequest(t_a=0, t_r=0, t_du=100, t_dl=400,
                                n_pe=8)], routing="round_robin")
    (alloc,) = res.allocations()
    assert sess.cancel(alloc) is True
    assert sess.cancel(alloc) is False   # slot already cleared
    assert sess.tick(10_000) == 0        # nothing left to release
    assert sess.records() == []


def test_partition_session_backfills_per_lane():
    sess = ReservationService(ServiceConfig(
        n_pe=32, n_partitions=2, chunk_size=None, backfill="easy",
        backfill_queue=8)).session()
    # saturate both partitions until t=1000
    blockers = [ARRequest(t_a=0, t_r=0, t_du=1000, t_dl=1000, n_pe=16)
                for _ in range(2)]
    assert sess.offer(blockers, routing="round_robin").n_accepted == 2
    # infeasible before the blockers release, feasible after: parks
    late = ARRequest(t_a=1, t_r=1, t_du=50, t_dl=2000, n_pe=16)
    res = sess.offer([late], routing="best_acceptance")
    assert res.n_accepted == 1           # parked counts as accepted
    m = sess.metrics()
    assert m["n_parked_now"] >= 1 and m["park_capacity"] == 8
    assert any(sess.pending(lane) for lane in (0, 1))
    sess.tick(1500)
    m = sess.metrics()
    assert m["n_parked_now"] == 0 and m["n_promoted"] >= 1
    assert m["dispatches"] > 0


def test_partition_session_best_acceptance_metrics():
    sess = ReservationService(ServiceConfig(
        n_pe=64, n_partitions=4, auto_release=False,
        chunk_size=None)).session()
    res = sess.offer(_gen(48, seed=4), routing="best_acceptance")
    assert res.n_offered == 48
    m = sess.metrics()
    # single-device auto mode matches in 0 rounds (pure fused scan);
    # either way the dispatch count is bounded by rounds, never by N
    assert m["match_rounds"] >= 0
    assert m["dispatches"] <= 3 * PartitionedCore.match_max_rounds + 1


# ---------------------------------------------------------------------------
# 8-device sharded matcher
# ---------------------------------------------------------------------------


def test_eight_way_sharded_matcher_subprocess():
    """Force 8 host devices and check the sharded [N, E] probe/match
    pipeline reproduces the host oracle bit-exactly."""
    code = """
import numpy as np
import jax
assert jax.device_count() == 8, jax.devices()
from repro.core import ARRequest, Policy
from repro.core.hostsched import FleetRoutingOracle
from repro.runtime.fleet import PartitionedCore
rng = np.random.default_rng(13)
reqs, t = [], 0
for _ in range(96):
    t += int(rng.integers(0, 12))
    dur = int(rng.integers(50, 400))
    r = t + int(rng.integers(0, 30))
    reqs.append(ARRequest(t_a=t, t_r=r, t_du=dur,
                          t_dl=r + int(dur * (1 + rng.random())),
                          n_pe=int(rng.integers(1, 17))))
core = PartitionedCore(128, 8, capacity=64)
got = core.admit_stream_allocations(reqs, Policy.FF,
                                    routing="best_acceptance")
exp = FleetRoutingOracle(128, 8).admit_batch(reqs, Policy.FF)
def key(a):
    return None if a is None else (a.t_s, a.t_e, tuple(a.pe_ids))
assert [key(a) for a in got] == [key(a) for a in exp]
assert core.mesh is not None
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
