"""Multi-resource timelines and heterogeneous lanes (DESIGN.md §11).

Three invariants anchor this suite:

1. **R=1 bit-identity** — an ``rspec=(n_pe,)`` state produces the
   exact same compiled decisions as a legacy ``rspec=None`` state on
   every field, policy and backfill mode (the layout is byte-identical
   so this is a code-path regression gate).
2. **Host-mirror differential** — device decisions on R >= 2 layouts
   match :class:`repro.core.hostsched.MultiResourceOracle` bit-exactly
   on both the jnp and kernel search paths.
3. **Plane confinement** — chosen unit ids always live inside their
   resource's bit range and never exceed the per-plane demand.

Plus the PR's edge-case regression sweep: the T_INF horizon guard,
``ids_to_mask32`` validation, and the zero-span utilization NaN.
"""
import dataclasses
import random

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import batch as batch_lib
from repro.core import timeline as tl_lib
from repro.core.hostsched import MultiResourceOracle
from repro.core.resources import ResourceSpec
from repro.core.types import ALL_POLICIES, ARRequest, Policy, T_INF


def _random_jobs(n, rspec, seed=0, horizon=2000):
    rng = random.Random(seed)
    jobs, t = [], 0
    for _ in range(n):
        t += rng.randint(0, 6)
        n_pe = rng.randint(1, rspec.n_pe)
        du = rng.randint(1, 40)
        slack = rng.randint(0, 60)
        tail = tuple(rng.randint(0, u) for u in rspec.units[1:])
        tr = t + rng.randint(0, 5)
        jobs.append(ARRequest(
            t_a=t, t_r=tr, t_du=du, t_dl=tr + du + slack, n_pe=n_pe,
            demand=(n_pe,) + tail))
    return jobs


def _run_device(jobs, rspec, policy, mode, use_kernel, n_pe):
    xd = rspec.R - 1 if rspec is not None else 0
    state = tl_lib.init_state(256, n_pe, 256, park_capacity=8,
                              rspec=rspec)
    batch = batch_lib.requests_to_batch(jobs, extra_demand=xd)
    state, dec = batch_lib.admit_stream_grow(
        state, batch, policy, backfill=batch_lib.as_backfill_id(mode),
        n_pe=n_pe, use_kernel=use_kernel)
    acc = np.asarray(dec.accepted)
    ts = np.asarray(dec.t_s)
    return [(bool(a), int(t)) for a, t in zip(acc, ts)], dec


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------


def test_resource_spec_layout():
    spec = ResourceSpec((33, 4, 64))
    assert spec.R == 3 and spec.n_pe == 33
    assert spec.words_per == (2, 1, 2)
    assert spec.word_offsets == (0, 2, 3)
    assert spec.total_words == 5 and spec.total_bits == 160
    assert spec.plane_slice(1) == slice(2, 3)
    assert spec.bit_offset(2) == 96
    bits = spec.valid_bits_np()
    # per-plane valid bits: exactly units[r] set, padding zero
    assert bits[:33].all() and not bits[33:64].any()
    assert bits[64:68].all() and not bits[68:96].any()
    assert bits[96:160].all()
    # heterogeneous shrink
    hv = spec.valid_bits_np((16, 2, 64))
    assert hv[:16].all() and not hv[16:64].any()
    assert hv[64:66].all() and not hv[66:96].any()


def test_resource_spec_r1_layout_is_legacy():
    spec = ResourceSpec((64,))
    assert spec.total_words == tl_lib.n_words(64)
    assert np.array_equal(spec.valid_mask_np(),
                          tl_lib.pe_valid_mask(64))


def test_demand_tail_validation():
    spec = ResourceSpec((8, 4))
    assert spec.demand_tail(None, 3) == (0,)
    assert spec.demand_tail((3, 2), 3) == (2,)
    with pytest.raises(ValueError):
        spec.demand_tail((4, 2), 3)       # plane 0 != n_pe
    with pytest.raises(ValueError):
        spec.demand_tail((3,), 3)         # wrong length
    with pytest.raises(ValueError):
        spec.demand_tail((3, 5), 3)       # over plane size


def test_arrequest_demand_validation():
    with pytest.raises(ValueError):
        ARRequest(t_a=0, t_r=0, t_du=1, t_dl=2, n_pe=2, demand=(3, 1))
    with pytest.raises(ValueError):
        ARRequest(t_a=0, t_r=0, t_du=1, t_dl=2, n_pe=2, demand=(2, -1))
    r = ARRequest(t_a=0, t_r=0, t_du=1, t_dl=2, n_pe=2,
                  demand=[2, 1])
    assert r.demand == (2, 1)


# ---------------------------------------------------------------------------
# R=1 bit-identity with the legacy path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernel", [False, True])
def test_r1_decisions_bit_identical(use_kernel):
    n_pe = 48
    rspec = ResourceSpec((n_pe,))
    rng = random.Random(7)
    jobs, t = [], 0
    for _ in range(120):
        t += rng.randint(0, 4)
        du = rng.randint(1, 30)
        jobs.append(ARRequest(
            t_a=t, t_r=t, t_du=du, t_dl=t + du + rng.randint(0, 50),
            n_pe=rng.randint(1, n_pe)))
    for policy in (Policy.FF, Policy.PE_W, Policy.PEDU_B):
        for mode in ("none", "easy", "conservative"):
            _, legacy = _run_device(jobs, None, policy, mode,
                                    use_kernel, n_pe)
            _, mr = _run_device(jobs, rspec, policy, mode,
                                use_kernel, n_pe)
            for f in legacy._fields:
                assert np.array_equal(
                    np.asarray(getattr(legacy, f)),
                    np.asarray(getattr(mr, f))), (policy, mode, f)


@pytest.mark.slow
def test_r1_full_policy_matrix_bit_identical():
    """1000 jobs x 7 policies x 3 backfill modes, legacy == R=1."""
    n_pe = 64
    rspec = ResourceSpec((n_pe,))
    rng = random.Random(3)
    jobs, t = [], 0
    for _ in range(1000):
        t += rng.randint(0, 3)
        du = rng.randint(1, 25)
        tr = t + rng.randint(0, 4)
        jobs.append(ARRequest(
            t_a=t, t_r=tr, t_du=du, t_dl=tr + du + rng.randint(0, 80),
            n_pe=rng.randint(1, n_pe)))
    for policy in ALL_POLICIES:
        for mode in ("none", "easy", "conservative"):
            ref, _ = _run_device(jobs, None, policy, mode, False, n_pe)
            got, _ = _run_device(jobs, rspec, policy, mode, False,
                                 n_pe)
            assert ref == got, (policy, mode)


# ---------------------------------------------------------------------------
# R>=2 differential vs the host mirror
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("mode", ["none", "easy", "conservative"])
def test_multires_matches_host_oracle(mode, use_kernel):
    rspec = ResourceSpec((32, 4, 8))
    jobs = _random_jobs(150, rspec, seed=11)
    for policy in (Policy.FF, Policy.PE_B, Policy.PEDU_W):
        ref = MultiResourceOracle(rspec, policy, mode,
                                  park_capacity=8).run(jobs)
        got, _ = _run_device(jobs, rspec, policy, mode, use_kernel,
                             rspec.n_pe)
        diff = [i for i, (x, y) in enumerate(zip(ref, got)) if x != y]
        assert not diff, (policy, mode, diff[:5])


@pytest.mark.slow
@pytest.mark.parametrize("use_kernel", [False, True])
def test_multires_oracle_differential_slow(use_kernel):
    rspec = ResourceSpec((64, 6, 3, 40))
    jobs = _random_jobs(500, rspec, seed=29)
    for policy in ALL_POLICIES:
        for mode in ("none", "easy", "conservative"):
            ref = MultiResourceOracle(rspec, policy, mode,
                                      park_capacity=8).run(jobs)
            got, _ = _run_device(jobs, rspec, policy, mode,
                                 use_kernel, rspec.n_pe)
            assert ref == got, (policy, mode)


def test_chosen_units_confined_to_planes():
    rspec = ResourceSpec((16, 4))
    jobs = _random_jobs(60, rspec, seed=5)
    _, dec = _run_device(jobs, rspec, Policy.FF, "none", False, 16)
    acc = np.asarray(dec.accepted)
    masks = np.asarray(dec.pe_mask)
    gpu0 = rspec.bit_offset(1)
    for i, j in enumerate(jobs):
        if not acc[i]:
            continue
        ids = batch_lib.mask32_to_ids(masks[i])
        pes = [b for b in ids if b < 16]
        gpus = [b for b in ids if gpu0 <= b < gpu0 + 4]
        assert len(pes) == j.demand[0]
        assert len(gpus) == j.demand[1]
        assert len(ids) == len(pes) + len(gpus)  # nothing in padding


# ---------------------------------------------------------------------------
# heterogeneous machine lanes
# ---------------------------------------------------------------------------


def test_heterogeneous_lane_valid_mask_blocks_dead_pes():
    from repro.api import ReservationService, ServiceConfig
    cfg = ServiceConfig(n_pe=32, lanes=3, machine_sizes=(32, 20, 8),
                        engine="device", chunk_size=None)
    s = ReservationService(cfg).session()
    req = [ARRequest(t_a=0, t_r=0, t_du=5, t_dl=50, n_pe=16)]
    res = s.offer([req, req, req])
    acc = np.asarray(res.decision.accepted)[:, 0]
    assert acc.tolist() == [True, True, False]
    # chosen PEs stay below each lane's live size
    masks = np.asarray(res.decision.pe_mask)
    for lane, size in ((0, 32), (1, 20)):
        ids = batch_lib.mask32_to_ids(masks[lane, 0])
        assert max(ids) < size and len(ids) == 16


def test_heterogeneous_lanes_with_resources():
    from repro.api import ReservationService, ServiceConfig
    cfg = ServiceConfig(n_pe=16, lanes=2, machine_sizes=(16, 4),
                        resources=(16, 2), engine="device",
                        chunk_size=None)
    s = ReservationService(cfg).session()
    req = [ARRequest(t_a=0, t_r=0, t_du=5, t_dl=50, n_pe=8,
                     demand=(8, 1))]
    res = s.offer([req, req])
    acc = np.asarray(res.decision.accepted)[:, 0]
    assert acc.tolist() == [True, False]   # lane 1: only 4 live PEs


def test_machine_units_requires_rspec():
    from repro.core import ensemble as ens_lib
    with pytest.raises(ValueError, match="rspec"):
        ens_lib.init_ensemble(2, 32, 16, machine_units=((16,), (8,)))
    with pytest.raises(ValueError, match="lanes"):
        ens_lib.init_ensemble(2, 32, 16, rspec=ResourceSpec((16,)),
                              machine_units=((16,),))


# ---------------------------------------------------------------------------
# service-level validation and staging
# ---------------------------------------------------------------------------


def test_service_demand_validation():
    from repro.api import ReservationService, ServiceConfig
    s = ReservationService(ServiceConfig(
        n_pe=8, resources=(8, 2), engine="device")).session()
    with pytest.raises(ValueError, match="demand"):
        s.offer([ARRequest(t_a=0, t_r=0, t_du=1, t_dl=10, n_pe=1,
                           demand=(1, 3))])
    plain = ReservationService(ServiceConfig(n_pe=8)).session()
    with pytest.raises(ValueError, match="single-resource"):
        plain.offer([ARRequest(t_a=0, t_r=0, t_du=1, t_dl=10, n_pe=1,
                               demand=(1, 1))])


def test_config_validation():
    from repro.api import ServiceConfig
    with pytest.raises(ValueError, match="resources"):
        ServiceConfig(n_pe=8, resources=(4, 2))
    with pytest.raises(ValueError, match="device"):
        ServiceConfig(n_pe=8, engine="host", resources=(8, 2))
    with pytest.raises(ValueError, match="machine_sizes"):
        ServiceConfig(n_pe=8, lanes=2, machine_sizes=(8,))
    with pytest.raises(ValueError):
        ServiceConfig(n_pe=8, lanes=2, machine_sizes=(8, 9))
    cfg = ServiceConfig(n_pe=8, resources=(8, 2, 2))
    assert cfg.rspec.R == 3 and cfg.extra_demand == 2
    hom = ServiceConfig(n_pe=8)
    assert hom.rspec is None and hom.extra_demand == 0
    het = ServiceConfig(n_pe=8, lanes=2, machine_sizes=(8, 4))
    assert het.rspec.units == (8,)          # implicit R=1 spec
    assert het.machine_units == ((8,), (4,))


def test_ring_demand_staging_roundtrip():
    """Chunked ring staging must carry demand columns bit-exactly."""
    from repro.api import ReservationService, ServiceConfig
    rspec = ResourceSpec((16, 4))
    jobs = _random_jobs(40, rspec, seed=17)
    chunked = ReservationService(ServiceConfig(
        n_pe=16, resources=(16, 4), engine="device",
        chunk_size=8, ring_capacity=32)).session()
    oneshot = ReservationService(ServiceConfig(
        n_pe=16, resources=(16, 4), engine="device",
        chunk_size=None)).session()
    d1 = chunked.offer(jobs).decision
    d2 = oneshot.offer(jobs).decision
    n = len(jobs)
    assert np.array_equal(np.asarray(d1.accepted)[:n],
                          np.asarray(d2.accepted))
    assert np.array_equal(np.asarray(d1.t_s)[:n],
                          np.asarray(d2.t_s))


# ---------------------------------------------------------------------------
# edge-case regression sweep (satellites)
# ---------------------------------------------------------------------------


def test_update_clamps_horizon_interval():
    """An interval touching T_INF must not corrupt the timeline."""
    tl = tl_lib.empty(16, 8)
    mask = tl_lib.ids_to_mask32([0, 1], tl.words)
    for t_s, t_e in ((T_INF - 5, T_INF), (T_INF, T_INF + 0),
                     (5, 5), (7, 3)):
        new_tl, ovf = tl_lib.update(tl, t_s, t_e, mask, is_add=True)
        assert not bool(ovf)
        assert np.array_equal(np.asarray(new_tl.times),
                              np.asarray(tl.times)), (t_s, t_e)
        assert np.array_equal(np.asarray(new_tl.occ),
                              np.asarray(tl.occ)), (t_s, t_e)


def test_admit_rejects_horizon_window():
    """A request whose window ends at T_INF is rejected, not half-
    committed (the admit-step guard of the T_INF clamp)."""
    n_pe = 8
    state = tl_lib.init_state(32, n_pe, 16)
    req = ARRequest(t_a=0, t_r=T_INF - 10, t_du=10, t_dl=T_INF,
                    n_pe=2)
    state, alloc = batch_lib.admit_one(state, req, Policy.FF,
                                       n_pe=n_pe)
    assert alloc is None
    times = np.asarray(state.tl.times)
    assert (times >= T_INF).all()      # nothing committed


def test_ids_to_mask32_validation():
    with pytest.raises(ValueError, match="out of range"):
        tl_lib.ids_to_mask32([8], 1, n_pe=8)
    with pytest.raises(ValueError, match="out of range"):
        tl_lib.ids_to_mask32([32], 1)          # beyond word width
    with pytest.raises(ValueError, match="duplicate"):
        tl_lib.ids_to_mask32([3, 3], 1)
    with pytest.raises(ValueError, match="out of range"):
        tl_lib.ids_to_mask32([-1], 1)
    with pytest.raises(TypeError, match="not an integer"):
        tl_lib.ids_to_mask32([1.5], 1)

    def traced(ids):
        return tl_lib.ids_to_mask32([ids], 1)

    with pytest.raises(TypeError, match="host-side"):
        jax.jit(traced)(jnp.int32(1))
    # valid call still packs correctly
    m = np.asarray(tl_lib.ids_to_mask32([0, 31], 1, n_pe=32))
    assert m[0] == np.uint32(0x80000001)


def test_every_tail_width_roundtrip_and_no_leak():
    """Exhaustive mirror of the hypothesis tail-width properties.

    Runs even without hypothesis installed: every ``n_pe % 32 != 0``
    tail width (1..31, across one- and two-word sizes) must pack /
    unpack bit-exactly, keep ``pe_valid_mask`` confined to the first
    ``n_pe`` bits, and report exactly ``n_pe`` free units on an empty
    timeline (``n_pe + 1`` infeasible) — i.e. word-padding bits never
    leak into the popcount contractions.
    """
    from repro.core import search as search_lib

    rng = np.random.default_rng(42)
    for n_pe in list(range(1, 32)) + [33, 47, 63]:
        W = tl_lib.n_words(n_pe)
        bits = np.zeros(W * 32, np.uint32)
        on = rng.choice(n_pe, size=rng.integers(0, n_pe + 1),
                        replace=False)
        bits[on] = 1
        words = tl_lib.pack_bits(bits[None, :])
        back = np.asarray(tl_lib.unpack_bits(jnp.asarray(words),
                                             W * 32))[0]
        assert np.array_equal(back.astype(np.uint32), bits), n_pe
        vm = tl_lib.pe_valid_mask(n_pe)
        vb = np.asarray(tl_lib.unpack_bits(jnp.asarray(vm)[None, :],
                                           W * 32))[0]
        assert vb[:n_pe].all() and not vb[n_pe:].any(), n_pe
        tl = tl_lib.empty(4, n_pe)
        res = search_lib.search(
            tl, jnp.int32(0), jnp.int32(5), jnp.int32(1000),
            jnp.int32(n_pe), jnp.int32(0), jnp.int32(0), n_pe=n_pe)
        assert bool(res.found) and int(res.n_free) == n_pe, n_pe
        over = search_lib.search(
            tl, jnp.int32(0), jnp.int32(5), jnp.int32(1000),
            jnp.int32(n_pe + 1), jnp.int32(0), jnp.int32(0),
            n_pe=n_pe)
        assert not bool(over.found), n_pe


def test_zero_span_utilization_is_nan():
    from repro.sim.metrics import SimResult, nanmean_safe
    r = SimResult(policy="FF", n_jobs=0, n_accepted=0, busy_area=5.0,
                  span=0.0, n_pe=8)
    assert np.isnan(r.utilization)
    no_pe = SimResult(policy="FF", n_jobs=0, n_accepted=0,
                      busy_area=0.0, span=10.0, n_pe=0)
    assert np.isnan(no_pe.utilization)
    # aggregations mask, not propagate
    assert nanmean_safe([r.utilization, 0.5]) == 0.5
    ok = SimResult(policy="FF", n_jobs=1, n_accepted=1,
                   busy_area=40.0, span=10.0, n_pe=8)
    assert ok.utilization == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# grid integration
# ---------------------------------------------------------------------------


def test_grid_resource_mix_axis_cross_checked():
    from repro.sim.sweep import GridSpec, simulate_grid
    spec = GridSpec(policies=(Policy.FF, Policy.PE_W),
                    backfill_modes=("none", "easy"),
                    arrival_factors=(1.0,), seeds=(0,),
                    n_pe=32, n_jobs=40,
                    resources=(32, 4),
                    resource_mixes=(None, (1.0,)))
    res = simulate_grid(spec, cross_check=True)
    assert res.acceptance.shape == spec.shape == (2, 2, 1, 1, 1, 2)
    # saturating the GPU plane can only reduce acceptance
    assert (res.n_accepted[..., 1] <= res.n_accepted[..., 0]).all()


def test_grid_resource_mix_requires_resources():
    from repro.sim.sweep import GridSpec, simulate_grid
    with pytest.raises(ValueError, match="resources"):
        simulate_grid(GridSpec(policies=(Policy.FF,),
                               arrival_factors=(1.0,), seeds=(0,),
                               n_jobs=5, resource_mixes=((0.5,),)))
