"""Sliding-window decode: the ring-buffer cache must match windowed
full-sequence attention — including after the buffer wraps around.

This is the mechanism behind the hybrid family's 524k-context cells
(zamba2's shared attention at long_500k), so the wraparound path needs
direct evidence, not just shape checks.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import attention as attn_lib
from repro.models.common import KeyGen


def _windowed_reference(p, xs, cfg, rope, window):
    """Full-sequence attention with an explicit window mask."""
    out = attn_lib.self_attention(p, xs, cfg, rope, window=window)
    return out


def test_ring_buffer_matches_windowed_attention_past_wraparound():
    cfg = dataclasses.replace(
        get_config("zamba2-7b").reduced(),
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        long_attention="window", window=8)
    key = jax.random.PRNGKey(0)
    p = attn_lib.init_attention(KeyGen(key), cfg, jnp.float32)
    B, T, W = 1, 24, cfg.window          # T = 3x window: two wraps
    xs = jax.random.normal(key, (B, T, cfg.d_model), jnp.float32) * 0.3
    rope = attn_lib.make_rope(cfg, T + 1)

    # reference: full-sequence windowed attention, last token's output
    ref_full = _windowed_reference(p, xs, cfg, rope, W)

    # decode path: feed tokens one by one through the ring buffer
    cache = attn_lib.init_cache(cfg, B, W, jnp.float32)
    outs = []
    for t in range(T):
        o, cache = attn_lib.decode_attention(
            p, xs[:, t:t + 1], cache, jnp.int32(t), cfg, rope,
            window=W)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)

    # before the first wrap the paths must agree; after wraps the ring
    # holds exactly the last W keys, so they must *still* agree.
    np.testing.assert_allclose(np.asarray(dec[:, :W]),
                               np.asarray(ref_full[:, :W]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dec[:, -1]),
                               np.asarray(ref_full[:, -1]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref_full),
                               rtol=2e-4, atol=2e-4)
