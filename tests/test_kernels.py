"""Pallas availscan kernel: shape/dtype sweeps vs the pure-jnp oracle.

The kernel is integer/boolean-exact, so assertions are equality, not
allclose (n_free counts are exact small-int f32 sums).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import search as search_lib
from repro.core import timeline as tl_lib
from repro.core.types import T_INF
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref


def _random_timeline(rng, n_pe, capacity, n_jobs):
    tl = tl_lib.empty(capacity, n_pe)
    t = 0
    for _ in range(n_jobs):
        t_s = t + int(rng.integers(0, 10))
        t_e = t_s + int(rng.integers(1, 30))
        ids = rng.choice(n_pe, size=int(rng.integers(1, n_pe // 2 + 1)),
                         replace=False)
        bits = np.zeros(tl.words * 32, np.uint32)
        bits[ids] = 1
        mask = tl_lib.pack_bits(bits[None, :])[0]
        tl, overflow = tl_lib.update(tl, t_s, t_e, mask, is_add=True)
        assert not bool(overflow)
        t = t_s
    return tl


@pytest.mark.parametrize("n_pe", [8, 40, 100, 128, 200])
@pytest.mark.parametrize("capacity", [32, 64])
def test_kernel_matches_ref_sweep(n_pe, capacity):
    rng = np.random.default_rng(n_pe * 1000 + capacity)
    tl = _random_timeline(rng, n_pe, capacity, n_jobs=10)
    t_du = jnp.int32(7)
    t_now = jnp.int32(0)
    starts = search_lib.candidate_starts(
        tl, jnp.int32(2), t_du, jnp.int32(90))
    ref = kernel_ref.availability_rectangles(tl, starts, t_du, t_now,
                                             n_pe)
    got = kernel_ops.availability_rectangles(tl, starts, t_du, t_now,
                                             n_pe)
    np.testing.assert_array_equal(np.asarray(got.n_free),
                                  np.asarray(ref.n_free))
    np.testing.assert_array_equal(np.asarray(got.t_begin),
                                  np.asarray(ref.t_begin))
    np.testing.assert_array_equal(np.asarray(got.t_end),
                                  np.asarray(ref.t_end))
    np.testing.assert_array_equal(np.asarray(got.valid),
                                  np.asarray(ref.valid))


@pytest.mark.parametrize("duration", [1, 13, 64])
def test_kernel_durations(duration):
    rng = np.random.default_rng(duration)
    n_pe = 64
    tl = _random_timeline(rng, n_pe, 32, n_jobs=8)
    t_du = jnp.int32(duration)
    starts = search_lib.candidate_starts(
        tl, jnp.int32(0), t_du, jnp.int32(200))
    ref = kernel_ref.availability_rectangles(
        tl, starts, t_du, jnp.int32(0), n_pe)
    got = kernel_ops.availability_rectangles(
        tl, starts, t_du, jnp.int32(0), n_pe)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kernel_empty_timeline():
    n_pe = 32
    tl = tl_lib.empty(16, n_pe)
    starts = jnp.array([0, 5, T_INF], jnp.int32)
    got = kernel_ops.availability_rectangles(
        tl, starts, jnp.int32(4), jnp.int32(0), n_pe)
    assert int(got.n_free[0]) == n_pe
    assert int(got.t_end[0]) == T_INF
    assert not bool(got.valid[2])


def test_kernel_fallback_on_large_shapes(monkeypatch):
    """Beyond the VMEM budget the wrapper must fall back to the ref."""
    monkeypatch.setattr(kernel_ops, "_MAX_OCC_ELEMS", 16)
    rng = np.random.default_rng(0)
    tl = _random_timeline(rng, 64, 32, n_jobs=4)
    starts = search_lib.candidate_starts(
        tl, jnp.int32(0), jnp.int32(5), jnp.int32(60))
    got = kernel_ops.availability_rectangles(
        tl, starts, jnp.int32(5), jnp.int32(0), 64)
    ref = kernel_ref.availability_rectangles(
        tl, starts, jnp.int32(5), jnp.int32(0), 64)
    np.testing.assert_array_equal(np.asarray(got.n_free),
                                  np.asarray(ref.n_free))


def test_full_find_allocation_with_kernel():
    """End-to-end jitted find_allocation, kernel vs jnp paths."""
    from repro.core.scheduler import DeviceScheduler
    from repro.core.types import ALL_POLICIES, ARRequest
    import random
    random.seed(3)
    a = DeviceScheduler(48, capacity=32, use_kernel=False)
    b = DeviceScheduler(48, capacity=32, use_kernel=True)
    t = 0
    for step in range(60):
        t += random.randint(0, 3)
        du = random.randint(1, 15)
        req = ARRequest(t_a=t, t_r=t + random.randint(0, 5), t_du=du,
                        t_dl=t + du + random.randint(5, 30),
                        n_pe=random.randint(1, 48))
        pol = random.choice(list(ALL_POLICIES))
        ra = a.find_allocation(req, pol, t_now=t)
        rb = b.find_allocation(req, pol, t_now=t)
        assert (ra is None) == (rb is None)
        if ra:
            assert (ra.t_s, ra.pe_ids, ra.rectangle) == \
                (rb.t_s, rb.pe_ids, rb.rectangle)
            a.add_allocation(ra.t_s, ra.t_e, list(ra.pe_ids))
            b.add_allocation(ra.t_s, ra.t_e, list(ra.pe_ids))
