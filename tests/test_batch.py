"""Batched device admission: fused step, scan, overflow growth.

Covers the functional core of DESIGN.md §3: the fused ``admit`` step
against the classic find+add path, capacity overflow -> grow -> retry in
both the three-op wrapper and the scanned stream, and end-to-end
decision/metric identity of ``simulate_batched`` with the host loop.
"""
import numpy as np
import pytest

from repro.core import batch as batch_lib
from repro.core.listsched import ListScheduler
from repro.core.scheduler import DeviceScheduler
from repro.core.types import ALL_POLICIES, ARRequest, Policy
from repro.sim import WorkloadParams, generate, simulate, simulate_batched

SMALL_SIZES = dict(u_low=2.0, u_med=4.0, u_hi=6.0)


def _paper_example(s):
    s.add_allocation(0, 300, list(range(0, 20)))
    s.add_allocation(0, 100, list(range(20, 50)))
    s.add_allocation(800, 1000, list(range(0, 25)))


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_admit_matches_find_then_add(policy):
    """One fused step == find_allocation + add_allocation."""
    a = DeviceScheduler(100, capacity=64)
    b = DeviceScheduler(100, capacity=64)
    _paper_example(a)
    _paper_example(b)
    req = ARRequest(t_a=0, t_r=200, t_du=200, t_dl=900, n_pe=40)
    alloc_a = a.find_allocation(req, policy)
    a.add_allocation(alloc_a.t_s, alloc_a.t_e, list(alloc_a.pe_ids))
    alloc_b = b.admit(req, policy)
    assert (alloc_a.t_s, alloc_a.t_e, alloc_a.pe_ids) == \
        (alloc_b.t_s, alloc_b.t_e, alloc_b.pe_ids)
    assert alloc_a.rectangle == alloc_b.rectangle
    assert a.records() == b.records()


def test_admit_rejects_infeasible():
    s = DeviceScheduler(100, capacity=64)
    _paper_example(s)
    req = ARRequest(t_a=0, t_r=0, t_du=250, t_dl=260, n_pe=90)
    assert s.admit(req, Policy.FF) is None
    assert int(s.state.n_accepted) == 0


def test_admit_releases_due_completions():
    """The pending buffer mirrors the simulator's completion heap."""
    s = DeviceScheduler(8, capacity=32)
    r1 = ARRequest(t_a=0, t_r=0, t_du=10, t_dl=10, n_pe=8)
    assert s.admit(r1, Policy.FF) is not None
    # all 8 PEs busy in [0, 10): a request arriving at t=20 releases
    # the finished job first, so the full machine is free again
    r2 = ARRequest(t_a=20, t_r=20, t_du=5, t_dl=25, n_pe=8)
    alloc = s.admit(r2, Policy.FF)
    assert alloc is not None and alloc.t_s == 20
    assert int(s.state.n_released) == 1
    # the released record is gone from the timeline
    assert all(t >= 20 for t, _ in s.records())


# ---------------------------------------------------------------------------
# overflow -> grow -> retry
# ---------------------------------------------------------------------------


def test_update_overflow_grows_and_retries():
    """`DeviceScheduler._update` doubles capacity when records overflow."""
    dev = DeviceScheduler(8, capacity=4)
    oracle = ListScheduler(8)
    # disjoint windows: each allocation contributes two records
    for i in range(4):
        t0, t1 = 100 * i, 100 * i + 50
        dev.add_allocation(t0, t1, [i])
        oracle.add_allocation(t0, t1, {i})
    assert dev.tl.capacity > 4          # grew (4 allocs -> 8 records)
    assert dev.records() == oracle.records()
    # deletions on the grown state stay exact
    for i in range(4):
        dev.delete_allocation(100 * i, 100 * i + 50, [i])
        oracle.delete_allocation(100 * i, 100 * i + 50, {i})
    assert dev.records() == oracle.records() == []


def _piling_stream(n_jobs):
    """Arrivals that pile up: every reservation is live at once."""
    return [ARRequest(t_a=i, t_r=i, t_du=5000, t_dl=i + 5000, n_pe=1)
            for i in range(n_jobs)]


def test_admit_stream_overflow_mid_scan_retries_deterministically():
    """Overflow inside the scan surfaces to the host wrapper, which
    grows the state and re-runs; decisions match a big-capacity run."""
    jobs = _piling_stream(12)           # 12 concurrent reservations
    small = DeviceScheduler(16, capacity=8, pending_capacity=2)
    big = DeviceScheduler(16, capacity=128, pending_capacity=64)
    dec_small = small.admit_stream(jobs, Policy.FF)
    dec_big = big.admit_stream(jobs, Policy.FF)
    assert small.tl.capacity > 8        # timeline grew
    assert small.state.pending_capacity > 2   # pending buffer grew
    np.testing.assert_array_equal(np.asarray(dec_small.accepted),
                                  np.asarray(dec_big.accepted))
    np.testing.assert_array_equal(np.asarray(dec_small.t_s),
                                  np.asarray(dec_big.t_s))
    np.testing.assert_array_equal(np.asarray(dec_small.pe_mask),
                                  np.asarray(dec_big.pe_mask))
    assert small.records() == big.records()
    # the retry is deterministic: running again from scratch agrees
    again = DeviceScheduler(16, capacity=8, pending_capacity=2)
    dec_again = again.admit_stream(jobs, Policy.FF)
    np.testing.assert_array_equal(np.asarray(dec_small.t_s),
                                  np.asarray(dec_again.t_s))


def test_single_admit_overflow_grows():
    """`admit_one` growth: tiny capacity, many live reservations."""
    s = DeviceScheduler(16, capacity=4, pending_capacity=1)
    for req in _piling_stream(6):
        assert s.admit(req, Policy.FF) is not None
    assert s.tl.capacity > 4
    assert int(s.state.n_accepted) == 6


# ---------------------------------------------------------------------------
# end-to-end equivalence with the host event loop
# ---------------------------------------------------------------------------


def test_simulate_batched_matches_host_loop_quick():
    jobs = generate(WorkloadParams(n_jobs=250, n_pe=64, seed=3,
                                   **SMALL_SIZES))
    jobs = [j for j in jobs if j.n_pe <= 64]
    r = simulate_batched(jobs, 64, Policy.PE_W, capacity=64,
                         cross_check=True)   # raises on any divergence
    assert 0.0 < r.acceptance_rate < 1.0


def test_simulate_batched_matches_host_loop_all_policies_1k():
    """Acceptance gate: identical decisions/metrics on >=1000 jobs for
    all seven policies (cross_check raises on the first divergence)."""
    jobs = generate(WorkloadParams(n_jobs=1000, n_pe=64, seed=7,
                                   **SMALL_SIZES))
    jobs = [j for j in jobs if j.n_pe <= 64]
    assert len(jobs) >= 1000
    for policy in ALL_POLICIES:
        r = simulate_batched(jobs, 64, policy, capacity=64,
                             cross_check=True)
        assert r.n_jobs == len(jobs)


def test_admit_stream_kernel_matches_dense():
    """use_kernel=True threads the Pallas scan into the fused step."""
    jobs = [ARRequest(t_a=5 * i, t_r=5 * i, t_du=20, t_dl=5 * i + 80,
                      n_pe=1 + i % 8) for i in range(20)]
    dense = DeviceScheduler(48, capacity=32, use_kernel=False)
    kern = DeviceScheduler(48, capacity=32, use_kernel=True)
    d1 = dense.admit_stream(jobs, Policy.PE_W)
    d2 = kern.admit_stream(jobs, Policy.PE_W)
    np.testing.assert_array_equal(np.asarray(d1.accepted),
                                  np.asarray(d2.accepted))
    np.testing.assert_array_equal(np.asarray(d1.t_s),
                                  np.asarray(d2.t_s))
    assert dense.records() == kern.records()


def test_requests_roundtrip_and_decision_unpack():
    jobs = _piling_stream(3)
    batch = batch_lib.requests_to_batch(jobs)
    assert [int(x) for x in batch.t_a] == [0, 1, 2]
    s = DeviceScheduler(16, capacity=32)
    dec = s.admit_stream(jobs, Policy.FF)
    allocs = batch_lib.decisions_to_allocations(dec)
    assert all(a is not None for a in allocs)
    assert sorted(sum((a.pe_ids for a in allocs), ())) == [0, 1, 2]


# ---------------------------------------------------------------------------
# fleet bulk submission
# ---------------------------------------------------------------------------


def test_fleet_submit_batch_matches_sequential():
    from repro.runtime import FleetScheduler

    specs = [dict(arch="qwen3-4b", shape="train_4k", n_chips=64,
                  n_steps=200) for _ in range(3)]
    fa = FleetScheduler(n_chips=128, engine="device")
    fb = FleetScheduler(n_chips=128, engine="device")
    batch_jobs = fa.submit_batch(specs)
    seq_jobs = [fb.submit(**s) for s in specs]
    for x, y in zip(batch_jobs, seq_jobs):
        assert (x.state, x.t_start, x.t_end, x.chips) == \
            (y.state, y.t_start, y.t_end, y.chips)
    assert fa.core.records() == fb.core.records()
    # completions release through advance() (auto_release=False path)
    fa.advance(max(j.t_end for j in batch_jobs) + 1)
    assert fa.core.records() == []
