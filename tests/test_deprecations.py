"""The deprecation-shim sweep: every remaining legacy entry point
must still warn, warn exactly once per call site pattern, and keep
behaving — so downstream users get the migration message without a
behaviour cliff.  Individual equivalence gates live next to their
subsystems (``test_service.py``, ``test_fleet_partition.py``); this
sweep is the single checklist of what is still deprecated.
"""
import warnings

import pytest

from repro.core import batch as batch_lib
from repro.core import timeline as tl_lib
from repro.core.scheduler import DeviceScheduler, make_scheduler
from repro.core.types import ARRequest, Policy


def test_make_scheduler_warns_for_every_engine():
    for engine in ("host", "list", "device"):
        with pytest.warns(DeprecationWarning,
                          match="make_scheduler is deprecated"):
            eng = make_scheduler(8, engine)
        assert eng is not None


def test_device_scheduler_class_warns_once_per_construction():
    with pytest.warns(DeprecationWarning,
                      match="DeviceScheduler is deprecated"):
        sched = DeviceScheduler(capacity=16, n_pe=8)
    # the shim still schedules
    req = ARRequest(t_a=0, t_r=0, t_du=5, t_dl=20, n_pe=2)
    assert sched.find_allocation(req, Policy.FF) is not None


def test_admit_stream_auto_warns_and_forwards():
    state = tl_lib.init_state(16, 8, 16)
    batch = batch_lib.requests_to_batch(
        [ARRequest(t_a=0, t_r=0, t_du=5, t_dl=20, n_pe=2)])
    with pytest.warns(DeprecationWarning,
                      match="admit_stream_auto is deprecated"):
        _, dec = batch_lib.admit_stream_auto(
            state, batch, Policy.FF, n_pe=8)
    assert bool(dec.accepted[0])


def test_route_legacy_raise_warns_then_raises():
    from repro.api import ReservationService, ServiceConfig

    sess = ReservationService(ServiceConfig(
        n_pe=8, n_partitions=2, auto_release=False,
        chunk_size=None)).session()
    core = sess.engine
    reqs = [ARRequest(t_a=0, t_r=0, t_du=5, t_dl=20, n_pe=2)]
    # the modern contract: a commit-free lane preview, no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        lanes = core.route(reqs, "best_acceptance")
    assert len(lanes) == len(reqs)
    with pytest.warns(DeprecationWarning,
                      match="legacy_raise=True.*deprecated"):
        with pytest.raises(ValueError, match="best_acceptance"):
            core.route(reqs, "best_acceptance", legacy_raise=True)


def test_no_other_entry_point_warns_by_default():
    """The supported surface is warning-free: building a service,
    offering, polling metrics and ticking must not emit
    DeprecationWarning."""
    from repro.api import ReservationService, ServiceConfig
    from repro.tenancy import TenantSpec

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        sess = ReservationService(ServiceConfig(
            n_pe=8, capacity=32, chunk_size=4, ring_capacity=8,
            tenants=TenantSpec(weights=(1.0, 1.0)))).session()
        sess.offer([ARRequest(t_a=0, t_r=0, t_du=5, t_dl=20, n_pe=2,
                              tenant=1)])
        sess.metrics()
        sess.metrics(tenant=1)
        sess.tick(3)
