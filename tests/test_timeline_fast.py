"""Sort-free timeline updates, locked down against the lexsort oracle.

PR 5 (DESIGN.md §7) replaced the lexsort-based ``timeline.update`` with
a ``searchsorted`` + shift-gather insertion and added ``update_many``
(K same-direction intervals in one boundary-union + merge pass).  The
original implementation is retained as ``timeline.update_lexsort`` and
these suites assert the new paths are **bit-identical** to it — times,
occupancy words, the overflow flag and the ``n_keep`` high-water count
— across fuzzed add/delete/mixed sequences, duplicate-boundary cases
and overflow.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import batch as batch_lib
from repro.core import timeline as tl_lib
from repro.core.types import T_INF


def _rand_mask(rng, n_pe, words):
    ids = rng.choice(n_pe, size=int(rng.integers(1, n_pe + 1)),
                     replace=False)
    return tl_lib.ids_to_mask32(ids, words)


def _assert_tl_equal(a, b, ctx=None):
    np.testing.assert_array_equal(
        np.asarray(a.times), np.asarray(b.times), err_msg=str(ctx))
    np.testing.assert_array_equal(
        np.asarray(a.occ), np.asarray(b.occ), err_msg=str(ctx))


def _step_both(tl_pair, t_s, t_e, mask, is_add, ctx):
    """Apply one interval through both implementations and compare."""
    new, old = tl_pair
    a, ova, nka = tl_lib.update(new, t_s, t_e, mask, is_add=is_add,
                                with_count=True)
    b, ovb, nkb = tl_lib.update_lexsort(old, t_s, t_e, mask,
                                        is_add=is_add, with_count=True)
    assert bool(ova) == bool(ovb), ctx
    assert int(nka) == int(nkb), ctx
    _assert_tl_equal(a, b, ctx)
    return (a, b), bool(ova)


# ---------------------------------------------------------------------------
# seeded fuzz: update == update_lexsort, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_update_matches_lexsort_fuzzed(seed):
    rng = np.random.default_rng(seed)
    S = int(rng.choice([4, 8, 16, 32]))
    n_pe = int(rng.choice([8, 33, 64]))
    pair = (tl_lib.empty(S, n_pe), tl_lib.empty(S, n_pe))
    for step in range(40):
        t_s = int(rng.integers(0, 120))
        t_e = t_s + int(rng.integers(0, 40))   # includes empty windows
        mask = _rand_mask(rng, n_pe, pair[0].words)
        pair, overflowed = _step_both(
            pair, t_s, t_e, mask, bool(rng.integers(0, 2)),
            (seed, step))
        if overflowed:
            break


def test_update_duplicate_boundaries_and_degenerate_windows():
    """t_s / t_e coinciding with existing records, zero-length and
    inverted windows, adjacent and nested intervals."""
    n_pe = 8
    pair = (tl_lib.empty(16, n_pe), tl_lib.empty(16, n_pe))
    m = tl_lib.ids_to_mask32([0, 1], pair[0].words)
    m2 = tl_lib.ids_to_mask32([2, 3], pair[0].words)
    cases = [
        (10, 20, m, True), (10, 20, m2, True),   # duplicate boundaries
        (20, 30, m, True),                       # adjacent (merges)
        (12, 18, m2, True),                      # nested
        (15, 15, m, True),                       # empty window: no-op
        (18, 12, m, True),                       # inverted: no-op
        (10, 20, m2, False),                     # delete splits
        (0, 100, m, False),                      # superset delete
        (20, 30, m, False), (12, 18, m2, False),
        (10, 20, m, False),                      # back to empty
    ]
    for i, (t_s, t_e, mask, is_add) in enumerate(cases):
        pair, _ = _step_both(pair, t_s, t_e, mask, is_add, i)
    assert [t for t in np.asarray(pair[0].times) if t < T_INF] == []


def test_update_overflow_flag_and_count_match():
    """Overflow latches identically (n_keep may exceed capacity)."""
    n_pe = 4
    pair = (tl_lib.empty(4, n_pe), tl_lib.empty(4, n_pe))
    m = tl_lib.ids_to_mask32([0], pair[0].words)
    for i in range(2):            # 2 disjoint intervals -> 4 records
        pair, ov = _step_both(pair, 100 * i, 100 * i + 10, m, True, i)
        assert not ov
    # the third disjoint interval needs 6 records on capacity 4
    new, ova, nka = tl_lib.update(pair[0], 500, 510, m, is_add=True,
                                  with_count=True)
    old, ovb, nkb = tl_lib.update_lexsort(pair[1], 500, 510, m,
                                          is_add=True, with_count=True)
    assert bool(ova) and bool(ovb)
    assert int(nka) == int(nkb) == 6
    _assert_tl_equal(new, old)


# ---------------------------------------------------------------------------
# update_many == sequential lexsort chain
# ---------------------------------------------------------------------------


def _preloaded(rng, S, n_pe, n=5):
    tl = tl_lib.empty(S, n_pe)
    for _ in range(n):
        t_s = int(rng.integers(0, 80))
        t_e = t_s + int(rng.integers(1, 25))
        tl2, ov = tl_lib.update_lexsort(
            tl, t_s, t_e, _rand_mask(rng, n_pe, tl.words), is_add=True)
        if bool(ov):
            break
        tl = tl2
    return tl


@pytest.mark.parametrize("seed", range(10))
@pytest.mark.parametrize("is_add", [True, False])
def test_update_many_matches_sequential_chain(seed, is_add):
    rng = np.random.default_rng(1000 * seed + is_add)
    S, n_pe = int(rng.choice([8, 16, 32])), 16
    tl0 = _preloaded(rng, S, n_pe)
    K = int(rng.integers(1, 7))
    ts = rng.integers(0, 90, size=K).astype(np.int32)
    te = ts + rng.integers(0, 30, size=K).astype(np.int32)
    masks = jnp.stack([_rand_mask(rng, n_pe, tl0.words)
                       for _ in range(K)])
    active = rng.integers(0, 4, size=K) > 0     # some inactive slots
    got, ovg, nkg = tl_lib.update_many(
        tl0, jnp.asarray(ts), jnp.asarray(te), masks,
        jnp.asarray(active), is_add=is_add, with_count=True)
    ref, ovr = tl0, False
    for k in range(K):
        if not active[k]:
            continue
        ref, ov = tl_lib.update_lexsort(
            ref, int(ts[k]), int(te[k]), masks[k], is_add=is_add)
        ovr = ovr or bool(ov)
    # a sequential-only overflow is legal (transient spike past S);
    # a batched-only overflow never is — the batch's n_keep is the
    # final sequential record count, <= the sequential maximum
    if bool(ovg):
        assert ovr, (seed, is_add)
    if not ovr and not bool(ovg):
        _assert_tl_equal(got, ref, (seed, is_add))
        assert int(nkg) == int(jnp.sum(ref.times < T_INF))


def test_update_many_single_interval_equals_update():
    """K=1 update_many is exactly update, overflow flag included."""
    rng = np.random.default_rng(7)
    tl = _preloaded(rng, 8, 8)
    for trial in range(20):
        t_s = int(rng.integers(0, 90))
        t_e = t_s + int(rng.integers(0, 30))
        mask = _rand_mask(rng, 8, tl.words)
        is_add = bool(rng.integers(0, 2))
        a, ova, nka = tl_lib.update_many(
            tl, jnp.asarray([t_s], jnp.int32),
            jnp.asarray([t_e], jnp.int32), mask[None, :],
            jnp.asarray([True]), is_add=is_add, with_count=True)
        b, ovb, nkb = tl_lib.update(tl, t_s, t_e, mask, is_add=is_add,
                                    with_count=True)
        assert bool(ova) == bool(ovb) and int(nka) == int(nkb)
        _assert_tl_equal(a, b, trial)
        if not bool(ovb):
            tl = b


def test_update_many_all_inactive_is_identity():
    rng = np.random.default_rng(3)
    tl = _preloaded(rng, 16, 8)
    got, ov, nk = tl_lib.update_many(
        tl, jnp.zeros((4,), jnp.int32), jnp.ones((4,), jnp.int32),
        jnp.zeros((4, tl.words), jnp.uint32), jnp.zeros((4,), bool),
        is_add=False, with_count=True)
    assert not bool(ov)
    _assert_tl_equal(got, tl)
    assert int(nk) == int(jnp.sum(tl.times < T_INF))


def test_update_many_no_transient_overflow():
    """A batch whose end state fits never overflows, even when some
    sequential order would spike past capacity transiently."""
    n_pe = 2
    tl = tl_lib.empty(4, n_pe)
    m = tl_lib.ids_to_mask32([0], tl.words)
    tl, ov = tl_lib.update(tl, 0, 100, m, is_add=True)
    assert not bool(ov)
    # deleting [20,40) then [40,60) sequentially splits to 3 then
    # merges back to 2+2 records; the batch sees only the end state
    ts = jnp.asarray([20, 40], jnp.int32)
    te = jnp.asarray([40, 60], jnp.int32)
    got, ov2, nk = tl_lib.update_many(
        tl, ts, te, jnp.stack([m, m]), jnp.asarray([True, True]),
        is_add=False, with_count=True)
    assert not bool(ov2)
    ref = tl
    for k in range(2):
        ref, _ = tl_lib.update_lexsort(
            ref, int(ts[k]), int(te[k]), m, is_add=False)
    _assert_tl_equal(got, ref)


# ---------------------------------------------------------------------------
# the batched verbs built on update_many
# ---------------------------------------------------------------------------


def test_release_due_chunked_matches_sequential_deletes():
    """More due completions than one RELEASE_CHUNK: the fused
    multi-release lands on the identical canonical timeline."""
    n_pe = 16
    n = batch_lib.RELEASE_CHUNK + 4
    state = tl_lib.init_state(64, n_pe, 32)
    ref_tl = tl_lib.empty(64, n_pe)
    for i in range(n):
        mask = tl_lib.ids_to_mask32([i % n_pe], state.tl.words)
        t_s, t_e = 5 * i, 5 * i + 50
        new_tl, ov = tl_lib.update(state.tl, t_s, t_e, mask,
                                   is_add=True)
        assert not bool(ov)
        state = state._replace(
            tl=new_tl,
            pend_ts=state.pend_ts.at[i].set(t_s),
            pend_te=state.pend_te.at[i].set(t_e),
            pend_mask=state.pend_mask.at[i].set(mask))
        ref_tl, _ = tl_lib.update_lexsort(ref_tl, t_s, t_e, mask,
                                          is_add=True)
    out = batch_lib.release_due_step(state, jnp.int32(10_000))
    assert not bool(out.overflow)
    assert int(out.n_released) == n
    for i in range(n):
        mask = tl_lib.ids_to_mask32([i % n_pe], state.tl.words)
        ref_tl, _ = tl_lib.update_lexsort(ref_tl, 5 * i, 5 * i + 50,
                                          mask, is_add=False)
    _assert_tl_equal(out.tl, ref_tl)
    assert bool(jnp.all(out.pend_te == T_INF))


def test_cancel_many_matches_sequential_cancel():
    """Batched cancel == sequential cancel_one, duplicates included."""
    from repro.core.types import ARRequest, Policy

    n_pe = 8
    state = tl_lib.init_state(32, n_pe, 16)
    allocs = []
    for i in range(4):
        req = ARRequest(t_a=0, t_r=10 * i, t_du=8, t_dl=10 * i + 8,
                        n_pe=2)
        state, alloc = batch_lib.admit_one(state, req, Policy.FF,
                                           n_pe=n_pe)
        assert alloc is not None
        allocs.append(alloc)
    W = state.tl.words
    entries = [(a.t_s, a.t_e, tl_lib.ids_to_mask32(a.pe_ids, W))
               for a in allocs[:3]]
    entries.append(entries[0])            # duplicate -> False
    entries.append((999, 1000,
                    tl_lib.ids_to_mask32([0], W)))   # unknown -> False
    got_state, got = batch_lib.cancel_many(state, entries)
    ref_state = state
    ref = []
    for ts, te, mk in entries:
        ref_state, done = batch_lib.cancel_one(ref_state, ts, te, mk)
        ref.append(done)
    assert got == ref == [True, True, True, False, False]
    _assert_tl_equal(got_state.tl, ref_state.tl)
    np.testing.assert_array_equal(np.asarray(got_state.pend_te),
                                  np.asarray(ref_state.pend_te))


# ---------------------------------------------------------------------------
# Hypothesis fuzz (runs where hypothesis is installed)
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                           # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def test_hypothesis_update_matches_lexsort(data):
        n_pe = data.draw(st.sampled_from([4, 8, 33]))
        S = data.draw(st.sampled_from([4, 8, 16]))
        pair = (tl_lib.empty(S, n_pe), tl_lib.empty(S, n_pe))
        n_steps = data.draw(st.integers(1, 12))
        for step in range(n_steps):
            t_s = data.draw(st.integers(0, 60))
            t_e = t_s + data.draw(st.integers(0, 25))
            ids = data.draw(
                st.sets(st.integers(0, n_pe - 1), min_size=1))
            mask = tl_lib.ids_to_mask32(sorted(ids), pair[0].words)
            pair, overflowed = _step_both(
                pair, t_s, t_e, mask, data.draw(st.booleans()), step)
            if overflowed:
                break

    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def test_hypothesis_update_many_matches_chain(data):
        n_pe, S = 8, 16
        rng = np.random.default_rng(data.draw(st.integers(0, 2**16)))
        tl0 = _preloaded(rng, S, n_pe, n=3)
        K = data.draw(st.integers(1, 5))
        is_add = data.draw(st.booleans())
        ts, te, masks, active = [], [], [], []
        for _ in range(K):
            s = data.draw(st.integers(0, 70))
            ts.append(s)
            te.append(s + data.draw(st.integers(0, 20)))
            ids = data.draw(
                st.sets(st.integers(0, n_pe - 1), min_size=1))
            masks.append(tl_lib.ids_to_mask32(sorted(ids), tl0.words))
            active.append(data.draw(st.booleans()))
        got, ovg, _ = tl_lib.update_many(
            tl0, jnp.asarray(ts, jnp.int32),
            jnp.asarray(te, jnp.int32), jnp.stack(masks),
            jnp.asarray(active), is_add=is_add, with_count=True)
        ref, ovr = tl0, False
        for k in range(K):
            if not active[k]:
                continue
            ref, ov = tl_lib.update_lexsort(
                ref, ts[k], te[k], masks[k], is_add=is_add)
            ovr = ovr or bool(ov)
        # batched-only overflow is always a bug (see the seeded test)
        if bool(ovg):
            assert ovr
        if not ovr and not bool(ovg):
            _assert_tl_equal(got, ref)
