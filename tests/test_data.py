"""Data pipeline: determinism, restart purity, sharding arithmetic."""
import numpy as np

from repro.data import TokenPipeline


def test_batch_is_pure_function_of_step():
    p1 = TokenPipeline(1024, 64, 8, microbatches=2, seed=5)
    p2 = TokenPipeline(1024, 64, 8, microbatches=2, seed=5)
    for s in (0, 3, 17):
        b1, b2 = p1.batch_at(s), p2.batch_at(s)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_labels_are_shifted_tokens():
    p = TokenPipeline(512, 32, 4, seed=0)
    b = p.batch_at(0)
    np.testing.assert_array_equal(
        b["tokens"].reshape(-1, 32)[:, 1:],
        b["labels"].reshape(-1, 32)[:, :-1])


def test_dp_ranks_get_distinct_data():
    a = TokenPipeline(512, 32, 8, dp_rank=0, dp_size=2, seed=0)
    b = TokenPipeline(512, 32, 8, dp_rank=1, dp_size=2, seed=0)
    assert a.local_batch == 4
    assert not np.array_equal(a.batch_at(0)["tokens"],
                              b.batch_at(0)["tokens"])


def test_tokens_in_vocab_range():
    p = TokenPipeline(100, 64, 4, seed=1)
    b = p.batch_at(0)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < 100


def test_prefetch_thread_delivers_in_order():
    p = TokenPipeline(256, 16, 4, seed=2, prefetch=2)
    p.start(from_step=0)
    try:
        got0 = p.next_prefetched()
        got1 = p.next_prefetched()
        np.testing.assert_array_equal(got0["tokens"],
                                      p.batch_at(0)["tokens"])
        np.testing.assert_array_equal(got1["tokens"],
                                      p.batch_at(1)["tokens"])
    finally:
        p.stop()
