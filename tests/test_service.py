"""The ReservationService session API (DESIGN.md §5).

Acceptance gates for the streaming redesign:

* chunked ``Session.offer`` over a 1000-job stream is decision- and
  metric-identical to the one-shot scan for all seven policies — with
  the jit cache provably stable after the first chunk (zero
  recompilation) and the staging ring wrapping around;
* mid-stream capacity growth inside a chunk reproduces the
  big-capacity decisions exactly (grow-once high-water protocol);
* the deprecated entry points (``make_scheduler``, ``DeviceScheduler``,
  ``admit_stream_auto``) warn and behave identically;
* the remaining verbs — ``tick``, ``cancel``, ``snapshot``/``restore``,
  ``metrics`` — and the ensemble / host / partition backends.
"""
import numpy as np
import pytest

from repro.api import OfferResult, ReservationService, ServiceConfig
from repro.core import batch as batch_lib
from repro.core import timeline as tl_lib
from repro.core.types import ALL_POLICIES, ARRequest, Policy
from repro.sim import WorkloadParams, generate

SMALL_SIZES = dict(u_low=2.0, u_med=4.0, u_hi=6.0)


def _workload(n_jobs, n_pe, seed=7):
    jobs = [j for j in generate(WorkloadParams(
        n_jobs=n_jobs, n_pe=n_pe, seed=seed, **SMALL_SIZES))
        if j.n_pe <= n_pe]
    return sorted(jobs, key=lambda j: j.t_a)


def _one_shot(jobs, n_pe, policy, capacity, pending_capacity):
    state = tl_lib.init_state(capacity, n_pe, pending_capacity)
    _, dec = batch_lib.admit_stream_grow(
        state, batch_lib.requests_to_batch(jobs), policy, n_pe=n_pe)
    return (np.asarray(dec.accepted), np.asarray(dec.t_s),
            np.asarray(dec.pe_mask))


def _offered_decisions(results):
    """Valid-only (accepted, t_s, pe_mask) across OfferResults."""
    acc, ts, masks = [], [], []
    for res in results:
        v = np.asarray(res.valid)
        acc.append(np.asarray(res.decision.accepted)[v])
        ts.append(np.asarray(res.decision.t_s)[v])
        masks.append(np.asarray(res.decision.pe_mask)[v])
    return (np.concatenate(acc), np.concatenate(ts),
            np.concatenate(masks))


# ---------------------------------------------------------------------------
# the acceptance gate: 1000 jobs, 7 policies, zero recompilation
# ---------------------------------------------------------------------------


def test_offer_1k_stream_identical_to_one_shot_all_policies():
    """Chunked streaming == one-shot scan, with a stable jit cache
    after the first chunk and a wrapped staging ring."""
    n_pe = 64
    jobs = _workload(1000, n_pe)
    assert len(jobs) >= 1000
    # one-shot references first (their own 1000-long scan shape gets
    # its cache entry out of the way of the chunked-path assertion)
    refs = {policy: _one_shot(jobs, n_pe, policy, 128, 256)
            for policy in ALL_POLICIES}
    rng = np.random.RandomState(0)
    warm_cache = None
    for policy in ALL_POLICIES:
        sess = ReservationService(ServiceConfig(
            n_pe=n_pe, policy=policy, capacity=128,
            pending_capacity=256, chunk_size=64,
            ring_capacity=128)).session()
        results, i = [], 0
        while i < len(jobs):
            take = int(rng.randint(1, 160))
            results.append(sess.offer(jobs[i:i + take]))
            i += take
            if warm_cache is None:
                # first chunk of the first policy compiled the scan;
                # nothing after it may compile again
                warm_cache = batch_lib.admit_stream._cache_size()
        acc, ts, masks = _offered_decisions(results)
        ref_acc, ref_ts, ref_masks = refs[policy]
        np.testing.assert_array_equal(acc, ref_acc)
        np.testing.assert_array_equal(ts, ref_ts)
        np.testing.assert_array_equal(masks, ref_masks)
        m = sess.metrics()
        # metric-identity with the one-shot run
        assert m["accepted"] == int(ref_acc.sum())
        assert m["offered"] == len(jobs)
        assert m["growths"] == 0
        assert m["ring_wrapped"]          # 1000 jobs through 128 slots
        assert m["chunks"] >= len(jobs) // 64
    assert warm_cache == batch_lib.admit_stream._cache_size(), \
        "chunked offer recompiled after warmup"


def test_offer_with_backfilling_compiles_once_per_chunk_shape():
    """Backfilling extension of the cache gate: the deferral mode is
    *traced*, so chunked offers compile once per chunk shape and an
    easy session, a conservative session and every policy share the
    same cache entry."""
    n_pe = 32
    jobs = _workload(260, n_pe, seed=13)
    warm = None
    for mode in ("easy", "conservative"):
        for policy in (Policy.PE_W, Policy.FF):
            sess = ReservationService(ServiceConfig(
                n_pe=n_pe, policy=policy, capacity=128,
                backfill=mode, backfill_queue=8, chunk_size=32,
                ring_capacity=64)).session()
            i = 0
            while i < len(jobs):
                sess.offer(jobs[i:i + 50])
                i += 50
                if warm is None:
                    # the first chunk of the first session compiled
                    # the Q=8 scan; nothing after it may compile
                    warm = batch_lib.admit_stream._cache_size()
    assert warm == batch_lib.admit_stream._cache_size(), \
        "backfilling offer recompiled after warmup"


def test_offer_mid_stream_growth_identical_to_big_capacity():
    """A chunk that overflows grows once (high-water) and re-runs;
    decisions match a session that started with ample capacity."""
    n_pe = 16
    # arrivals that pile up: every reservation is live at once
    jobs = [ARRequest(t_a=i, t_r=i, t_du=5000, t_dl=i + 5000, n_pe=1)
            for i in range(40)]
    small = ReservationService(ServiceConfig(
        n_pe=n_pe, capacity=8, pending_capacity=4, chunk_size=8,
        ring_capacity=16)).session()
    big = ReservationService(ServiceConfig(
        n_pe=n_pe, capacity=256, pending_capacity=256, chunk_size=8,
        ring_capacity=16)).session()
    res_s = [small.offer(jobs[:25]), small.offer(jobs[25:])]
    res_b = [big.offer(jobs[:25]), big.offer(jobs[25:])]
    acc_s, ts_s, masks_s = _offered_decisions(res_s)
    acc_b, ts_b, masks_b = _offered_decisions(res_b)
    np.testing.assert_array_equal(acc_s, acc_b)
    np.testing.assert_array_equal(ts_s, ts_b)
    np.testing.assert_array_equal(masks_s, masks_b)
    m = small.metrics()
    assert m["growths"] >= 1
    assert m["capacity"] > 8 and m["pending_capacity"] > 4
    assert big.metrics()["growths"] == 0


def test_offer_flush_false_stages_remainder():
    n_pe = 32
    jobs = _workload(90, n_pe, seed=3)
    sess = ReservationService(ServiceConfig(
        n_pe=n_pe, capacity=64, chunk_size=32,
        ring_capacity=64)).session()
    partial = sess.offer(jobs, flush=False)
    staged = sess.metrics()["ring_staged"]
    assert staged == len(jobs) % 32
    assert partial.n_offered == len(jobs) - staged
    rest = sess.flush()
    assert rest.n_offered == staged
    acc, ts, _ = _offered_decisions([partial, rest])
    ref_acc, ref_ts, _ = _one_shot(jobs, n_pe, Policy.PE_W, 64, 256)
    np.testing.assert_array_equal(acc, ref_acc)
    np.testing.assert_array_equal(ts, ref_ts)


# ---------------------------------------------------------------------------
# the other verbs
# ---------------------------------------------------------------------------


def test_tick_releases_and_cancel_is_idempotent():
    sess = ReservationService(ServiceConfig(
        n_pe=8, capacity=32, chunk_size=4, ring_capacity=8)).session()
    r1 = sess.offer([ARRequest(t_a=0, t_r=0, t_du=10, t_dl=20,
                               n_pe=8)])
    assert r1.n_accepted == 1
    assert sess.tick(5) == 0              # nothing due yet
    assert sess.tick(15) == 1             # released
    assert sess.records() == []
    r2 = sess.offer([ARRequest(t_a=20, t_r=20, t_du=10, t_dl=40,
                               n_pe=8)])
    alloc = r2.allocations()[0]
    assert sess.cancel(alloc) is True
    assert sess.cancel(alloc) is False    # already withdrawn: no-op
    assert sess.records() == []
    # the capacity freed by cancel is immediately reusable
    r3 = sess.offer([ARRequest(t_a=20, t_r=20, t_du=10, t_dl=40,
                               n_pe=8)])
    assert r3.allocations()[0].t_s == alloc.t_s
    m = sess.metrics()
    assert (m["released"], m["cancelled"]) == (1, 1)


def test_snapshot_restore_roundtrip():
    n_pe = 32
    jobs = _workload(60, n_pe, seed=5)
    sess = ReservationService(ServiceConfig(
        n_pe=n_pe, capacity=64, chunk_size=8,
        ring_capacity=16)).session()
    sess.offer(jobs[:30])
    snap = sess.snapshot()
    records = sess.records()
    metrics = sess.metrics()
    sess.offer(jobs[30:])
    assert sess.metrics()["offered"] == len(jobs)
    sess.restore(snap)
    assert sess.records() == records
    assert sess.metrics() == metrics
    # the restored session continues identically
    again = sess.offer(jobs[30:])
    assert again.n_offered == len(jobs) - 30


# ---------------------------------------------------------------------------
# ensemble and host backends through the same verb set
# ---------------------------------------------------------------------------


def test_ensemble_session_matches_single_lane_sessions():
    n_pe = 32
    jobs = _workload(120, n_pe, seed=2)
    policies = [Policy.FF, Policy.PE_W, Policy.DU_B]
    streams = [jobs, jobs[:70], jobs[:45]]
    esess = ReservationService(ServiceConfig(
        n_pe=n_pe, lanes=3, capacity=64, chunk_size=16,
        ring_capacity=32)).session()
    eres = esess.offer(streams, policy=policies)
    acc = np.asarray(eres.decision.accepted)
    ts = np.asarray(eres.decision.t_s)
    for lane, (pol, stream) in enumerate(zip(policies, streams)):
        ssess = ReservationService(ServiceConfig(
            n_pe=n_pe, policy=pol, capacity=64, chunk_size=16,
            ring_capacity=32)).session()
        sres = ssess.offer(stream)
        v = eres.valid[lane]
        np.testing.assert_array_equal(
            acc[lane][v],
            np.asarray(sres.decision.accepted)[sres.valid])
        np.testing.assert_array_equal(
            ts[lane][v],
            np.asarray(sres.decision.t_s)[sres.valid])
    # ensemble tick releases the still-pending tail on every lane;
    # afterwards every accepted reservation has been released
    horizon = max(j.t_dl for j in jobs) + 1
    assert esess.tick(horizon) > 0
    states = esess._backend.states
    assert int(np.asarray(states.n_released).sum()) == \
        int(np.asarray(states.n_accepted).sum())
    for lane in range(3):
        assert esess._backend.records(lane) == []


def test_ensemble_filler_never_releases_ahead_of_staged_requests():
    """A lane contributing filler (flush=False) while it still holds
    staged requests must not advance that lane's release clock past
    them — filler is stamped with the last *popped* arrival."""
    n_pe = 4
    a = ARRequest(t_a=0, t_r=0, t_du=5, t_dl=5, n_pe=4)
    d = ARRequest(t_a=3, t_r=3, t_du=2, t_dl=5, n_pe=4)  # blocked by a
    e = ARRequest(t_a=7, t_r=7, t_du=2, t_dl=10, n_pe=4)
    lane0 = [ARRequest(t_a=t, t_r=t, t_du=1, t_dl=t + 3, n_pe=1)
             for t in range(8)]
    sess = ReservationService(ServiceConfig(
        n_pe=n_pe, lanes=2, capacity=32, chunk_size=4,
        ring_capacity=8)).session()
    r1 = sess.offer([[], [a]])                 # admit a on lane 1
    # lane 0 drives full-chunk drains while lane 1 stages d, e; the
    # filler chunks lane 1 contributes must not release a early
    r2 = sess.offer([lane0, [d, e]], flush=False)
    r3 = sess.flush()
    lane1 = np.concatenate(
        [np.asarray(r.decision.accepted)[1][np.asarray(r.valid)[1]]
         for r in (r1, r2, r3)])
    ref = ReservationService(ServiceConfig(
        n_pe=n_pe, capacity=32, chunk_size=4,
        ring_capacity=8)).session()
    ref_acc = np.concatenate([
        np.asarray(r.decision.accepted)[r.valid]
        for r in (ref.offer([a]), ref.offer([d, e]))])
    np.testing.assert_array_equal(lane1, ref_acc)
    assert list(ref_acc) == [True, False, True]


def test_host_and_device_sessions_agree():
    n_pe = 32
    jobs = _workload(80, n_pe, seed=11)
    dev = ReservationService(ServiceConfig(
        n_pe=n_pe, capacity=64, chunk_size=16,
        ring_capacity=32)).session()
    host = ReservationService(ServiceConfig(
        n_pe=n_pe, engine="host")).session()
    dres = dev.offer(jobs)
    hres = host.offer(jobs)
    np.testing.assert_array_equal(
        np.asarray(dres.decision.accepted)[dres.valid],
        np.asarray(hres.decision.accepted))
    np.testing.assert_array_equal(
        np.asarray(dres.decision.t_s)[dres.valid],
        np.asarray(hres.decision.t_s))
    assert dev.records() == host.records()
    horizon = max(j.t_dl for j in jobs) + 1
    assert dev.tick(horizon) == host.tick(horizon)
    assert host.records() == []


def test_partition_session_routes_bulk_offers():
    reqs = [ARRequest(t_a=0, t_r=0, t_du=100, t_dl=1000, n_pe=8)
            for _ in range(6)]
    sess = ReservationService(ServiceConfig(
        n_pe=32, n_partitions=2, auto_release=False,
        chunk_size=None)).session()
    res = sess.offer(reqs, routing="round_robin")
    allocs = res.allocations()
    assert sum(a is not None for a in allocs) == 6
    lanes = {a.pe_ids[0] // 16 for a in allocs}
    assert lanes == {0, 1}                 # spread across partitions
    assert sess.cancel(allocs[0]) is True
    assert sess.metrics()["chips_per_partition"] == 16


# ---------------------------------------------------------------------------
# config validation and the deprecation shims
# ---------------------------------------------------------------------------


def test_config_validation():
    with pytest.raises(ValueError, match="unknown engine"):
        ServiceConfig(n_pe=8, engine="gpu")
    with pytest.raises(ValueError, match="exclusive"):
        ServiceConfig(n_pe=8, lanes=2, n_partitions=2)
    with pytest.raises(ValueError, match="device"):
        ServiceConfig(n_pe=8, engine="host", lanes=2)
    with pytest.raises(ValueError, match="divisible"):
        ServiceConfig(n_pe=10, n_partitions=3)
    with pytest.raises(ValueError, match="routing"):
        ServiceConfig(n_pe=8, routing="nearest")
    with pytest.raises(ValueError, match="ring_capacity"):
        ServiceConfig(n_pe=8, chunk_size=64, ring_capacity=8)
    with pytest.raises(TypeError, match="unknown device engine"):
        ServiceConfig.from_engine_kwargs(8, "device", buckets=True)
    # partitioned sessions handle completions either way now (lanes
    # auto-release via tick, or the caller deletes); growth stays
    # internal to the core
    assert ServiceConfig(n_pe=8, n_partitions=2).auto_release
    assert not ServiceConfig(n_pe=8, n_partitions=2,
                             auto_release=False).auto_release
    with pytest.raises(ValueError, match="auto_grow"):
        ServiceConfig(n_pe=8, n_partitions=2, auto_release=False,
                      auto_grow=False)
    with pytest.raises(ValueError, match="first-class"):
        ServiceConfig(n_pe=8, engine="device",
                      engine_kwargs={"capacity": 4})


def test_auto_grow_false_raises_before_any_growth():
    jobs = [ARRequest(t_a=i, t_r=i, t_du=5000, t_dl=i + 5000, n_pe=1)
            for i in range(30)]
    sess = ReservationService(ServiceConfig(
        n_pe=16, capacity=8, pending_capacity=4, auto_grow=False,
        chunk_size=8, ring_capacity=16)).session()
    with pytest.raises(RuntimeError, match="overflowing"):
        sess.offer(jobs)
    m = sess.metrics()
    assert m["growths"] == 0
    assert m["capacity"] == 8 and m["pending_capacity"] == 4
    # the overflowing chunk's requests went back to the ring, so a
    # manual recovery (e.g. a grown session restore) loses nothing
    assert m["ring_staged"] > 0


def test_ensemble_cancel_targets_the_named_lane():
    r = ARRequest(t_a=0, t_r=0, t_du=100, t_dl=200, n_pe=4)
    sess = ReservationService(ServiceConfig(
        n_pe=8, lanes=2, capacity=32, chunk_size=4,
        ring_capacity=8)).session()
    res = sess.offer([[r], [r]])
    allocs = [
        batch_lib.decisions_to_allocations(
            batch_lib.Decision(*[np.asarray(f)[lane]
                                 for f in res.decision]))[0]
        for lane in range(2)]
    # cancelling on lane 1 must not touch lane 0's timeline
    assert sess.cancel(allocs[1], lane=1) is True
    assert sess._backend.records(0) != []
    assert sess._backend.records(1) == []
    assert sess.cancel(allocs[1], lane=1) is False   # idempotent
    with pytest.raises(ValueError, match="out of range"):
        sess.cancel(allocs[0], lane=5)
    # non-ensemble sessions reject a lane
    flat = ReservationService(ServiceConfig(
        n_pe=8, chunk_size=4, ring_capacity=8)).session()
    a = flat.offer([r]).allocations()[0]
    with pytest.raises(ValueError, match="ensemble"):
        flat.cancel(a, lane=1)


def test_flush_false_rejected_without_a_ring():
    r = ARRequest(t_a=0, t_r=0, t_du=10, t_dl=100, n_pe=2)
    for cfg in (ServiceConfig(n_pe=8, chunk_size=None),
                ServiceConfig(n_pe=8, engine="host"),
                ServiceConfig(n_pe=8, n_partitions=2,
                              auto_release=False, chunk_size=None)):
        sess = ReservationService(cfg).session()
        with pytest.raises(ValueError, match="flush=False"):
            sess.offer([r], flush=False)


def test_make_scheduler_shim_forwards_host_engine_kwargs():
    import warnings

    from repro.core.scheduler import make_scheduler

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        host = make_scheduler(16, engine="host", candidate_chunk=32)
        assert host._chunk == 32
        with pytest.raises(TypeError):
            make_scheduler(16, engine="host", capacity=64)
        with pytest.raises(TypeError):
            make_scheduler(16, engine="list", candidate_chunk=32)


def test_offer_requires_arrival_order_and_rejects_atomically():
    late = ARRequest(t_a=100, t_r=100, t_du=5, t_dl=110, n_pe=1)
    early = ARRequest(t_a=50, t_r=50, t_du=5, t_dl=60, n_pe=1)
    for cfg in (ServiceConfig(n_pe=8, chunk_size=4, ring_capacity=8),
                ServiceConfig(n_pe=8, engine="host")):
        sess = ReservationService(cfg).session()
        sess.offer([late])
        with pytest.raises(ValueError, match="arrival-ordered"):
            sess.offer([late, early])
        m = sess.metrics()
        # the rejected offer left nothing behind: no staging, no
        # counter drift (the in-order prefix was not half-admitted)
        assert m["offered"] == 1
        assert m.get("ring_staged", 0) == 0


def test_ensemble_flush_false_keeps_partial_lanes_staged():
    r = [ARRequest(t_a=i, t_r=i, t_du=10, t_dl=i + 50, n_pe=1)
         for i in range(8)]
    sess = ReservationService(ServiceConfig(
        n_pe=8, lanes=2, capacity=32, chunk_size=4,
        ring_capacity=8)).session()
    res = sess.offer([r, r[:1]], flush=False)
    # lane 0 drained its two full chunks; lane 1's single request
    # stays staged (the flush=False contract)
    assert res.n_offered == 8
    assert [ring.count for ring in sess._backend.rings] == [0, 1]
    rest = sess.flush()
    assert rest.n_offered == 1
    assert sum(ring.count for ring in sess._backend.rings) == 0


def _paper_example(s, pes=list):
    s.add_allocation(0, 300, pes(range(0, 20)))
    s.add_allocation(0, 100, pes(range(20, 50)))
    s.add_allocation(800, 1000, pes(range(0, 25)))


def test_make_scheduler_shim_warns_and_matches_service():
    req = ARRequest(t_a=0, t_r=200, t_du=200, t_dl=900, n_pe=40)
    for engine in ("list", "host", "device"):
        with pytest.warns(DeprecationWarning,
                          match="make_scheduler is deprecated"):
            from repro.core.scheduler import make_scheduler
            old = make_scheduler(100, engine=engine)
        _paper_example(old, set if engine == "list" else list)
        sess = ReservationService(ServiceConfig(
            n_pe=100, engine=engine)).session()
        _paper_example(sess)
        for pol in ALL_POLICIES:
            a = old.find_allocation(req, pol)
            b = sess.find_allocation(req, pol)
            assert (a.t_s, a.t_e, a.pe_ids, a.rectangle) == \
                (b.t_s, b.t_e, b.pe_ids, b.rectangle)


def test_device_scheduler_shim_warns_and_matches_engine():
    from repro.core.scheduler import DeviceEngine, DeviceScheduler

    with pytest.warns(DeprecationWarning,
                      match="DeviceScheduler is deprecated"):
        old = DeviceScheduler(100, capacity=64)
    assert isinstance(old, DeviceEngine)
    new = ReservationService(ServiceConfig(
        n_pe=100, engine="device", capacity=64)).session().engine
    assert isinstance(new, DeviceEngine)
    _paper_example(old)
    _paper_example(new)
    req = ARRequest(t_a=0, t_r=200, t_du=200, t_dl=900, n_pe=40)
    a = old.admit(req, Policy.PE_W)
    b = new.admit(req, Policy.PE_W)
    assert (a.t_s, a.t_e, a.pe_ids) == (b.t_s, b.t_e, b.pe_ids)
    assert old.records() == new.records()


def test_admit_stream_auto_shim_warns_and_matches_grow():
    n_pe = 16
    jobs = _workload(50, n_pe, seed=9)
    batch = batch_lib.requests_to_batch(jobs)
    state = tl_lib.init_state(64, n_pe, 64)
    with pytest.warns(DeprecationWarning,
                      match="admit_stream_auto is deprecated"):
        out_a, dec_a = batch_lib.admit_stream_auto(
            state, batch, Policy.PE_W, n_pe=n_pe)
    out_b, dec_b = batch_lib.admit_stream_grow(
        state, batch, Policy.PE_W, n_pe=n_pe)
    np.testing.assert_array_equal(np.asarray(dec_a.accepted),
                                  np.asarray(dec_b.accepted))
    np.testing.assert_array_equal(np.asarray(dec_a.t_s),
                                  np.asarray(dec_b.t_s))
    np.testing.assert_array_equal(np.asarray(out_a.tl.times),
                                  np.asarray(out_b.tl.times))


def test_offer_result_empty_and_prepacked_guard():
    sess = ReservationService(ServiceConfig(
        n_pe=8, chunk_size=4, ring_capacity=8)).session()
    empty = sess.offer([])
    assert isinstance(empty, OfferResult)
    assert empty.n_offered == 0 and empty.allocations() == []
    with pytest.raises(ValueError, match="bypasses the ring"):
        sess.offer(batch_lib.requests_to_batch(
            [ARRequest(t_a=0, t_r=0, t_du=5, t_dl=10, n_pe=1)]))


def test_push_front_recovers_arrival_order_across_repeated_latches():
    """Three consecutive latched offers restage to the ring *front*:
    contents stay in arrival order through physical wraparound, and
    ``last_popped_t_a`` stays rewound to the newest decided arrival
    so later partial chunks cannot release undecided predecessors."""
    import warnings

    sess = ReservationService(ServiceConfig(
        n_pe=16, capacity=8, pending_capacity=4, auto_grow=False,
        chunk_size=8, ring_capacity=16)).session()
    ring = sess._backend.ring
    # feasible warm-up advances the ring head and the filler stamp
    warm = [ARRequest(t_a=i, t_r=i, t_du=1, t_dl=i + 4, n_pe=1)
            for i in range(10)]
    res = sess.offer(warm)
    assert int(np.asarray(res.decision.accepted).sum()) == 10
    assert ring._head == 10 and ring.last_popped_t_a == 9
    # three overflowing waves, each fully restaged (no drops)
    over = [ARRequest(t_a=100 + i, t_r=100 + i, t_du=5000,
                      t_dl=100 + i + 5000, n_pe=1)
            for i in range(16)]
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        for lo, hi in ((0, 6), (6, 11), (11, 16)):
            with pytest.raises(RuntimeError, match="overflowing"):
                sess.offer(over[lo:hi])
            assert ring.count == hi           # everything restaged...
            assert ring._head == 10           # ...at the front
            assert ring.last_popped_t_a == 9  # stamp stays rewound
    assert sess.metrics()["growths"] == 0
    # count 16 at head 10 means the ring physically wrapped; popping
    # must replay the undecided requests in exact arrival order
    batch, valid = ring.pop_chunk(ring.count, 16)
    assert np.asarray(batch.t_a)[np.asarray(valid)].tolist() \
        == [100 + i for i in range(16)]
