"""Backfilling admission (DESIGN.md §6), locked down differentially.

Every backfill path ships with a host reference: the
:class:`repro.core.hostsched.BackfillOracle` re-states the device
pipeline — promote due parked reservations, release due completions,
EASY retry-on-release sweep, search, commit-or-park, EASY displacement
transaction — as a literal Python event loop, and these suites assert
the device ``admit_stream`` is **bit-identical** to it: decisions,
parked flags, timeline records, deferral-queue contents and counters.

On top of the differential gates, property tests pin the two safety
invariants:

* conservative backfilling never moves any reservation — it is
  decision-identical to ``none`` with an observable queue;
* EASY never delays the head-of-queue reservation or a committed
  start (the retry sweep moves strictly earlier; displacement touches
  non-head entries only, transactionally).
"""
import numpy as np
import pytest

from repro.api import ReservationService, ServiceConfig
from repro.core import batch as batch_lib
from repro.core import ensemble as ens_lib
from repro.core import timeline as tl_lib
from repro.core.hostsched import BackfillOracle
from repro.core.types import ALL_POLICIES, ARRequest, Policy, T_INF
from repro.sim import WorkloadParams, generate_filtered

N_PE = 16
SIZES = dict(u_low=2.0, u_med=3.0, u_hi=4.0)
MODES = ("easy", "conservative")


def _workload(n_jobs, seed, load=2.0, n_pe=N_PE):
    jobs = generate_filtered(WorkloadParams(
        n_jobs=n_jobs, n_pe=n_pe, seed=seed, arrival_factor=load,
        **SIZES), max_pe=n_pe)
    return sorted(jobs, key=lambda j: j.t_a)


def _device_run(jobs, policy, mode, *, Q=8, n_pe=N_PE, capacity=64,
                pending=128, use_kernel=False):
    state = tl_lib.init_state(capacity, n_pe, pending,
                              park_capacity=Q)
    out, dec = batch_lib.admit_stream_grow(
        state, batch_lib.requests_to_batch(jobs), policy, n_pe=n_pe,
        backfill=mode, use_kernel=use_kernel)
    acc = np.asarray(dec.accepted)
    trace = [(bool(a), int(t))
             for a, t in zip(acc, np.asarray(dec.t_s))]
    parked = [bool(p) for p in np.asarray(dec.parked)]
    return trace, parked, out


def _records(state):
    times = np.asarray(state.tl.times)
    occ = np.asarray(state.tl.occ)
    return [(int(t), frozenset(batch_lib.mask32_to_ids(o)))
            for t, o in zip(times, occ) if t < T_INF]


def _assert_matches_oracle(jobs, policy, mode, **kw):
    trace, parked, out = _device_run(jobs, policy, mode, **kw)
    orc = BackfillOracle(N_PE, policy, mode,
                         park_capacity=kw.get("Q", 8))
    ref = [orc.admit(r) for r in jobs]
    assert trace == [r[:2] for r in ref], (policy, mode)
    assert parked == [r[2] for r in ref], (policy, mode)
    # end state: timeline records, queue contents, counters
    assert _records(out) == orc.records()
    assert batch_lib.parked_entries(out) == orc.pending()
    assert int(out.n_parked) == orc.n_parked
    assert int(out.n_promoted) == orc.n_promoted
    assert int(out.n_moved) == orc.n_moved
    return trace, out


# ---------------------------------------------------------------------------
# differential gates: device == host oracle, bit for bit
# ---------------------------------------------------------------------------


def test_stream_differential_all_policies_both_modes():
    """300-job stream × 7 policies × {easy, conservative}: decisions,
    parked flags, records, queue and counters all match the oracle."""
    jobs = _workload(300, seed=3)
    for policy in ALL_POLICIES:
        for mode in MODES:
            _assert_matches_oracle(jobs, policy, mode)


def test_conservative_is_decision_identical_to_none():
    """The paper's admission *is* conservative backfilling: freezing
    parked reservations reproduces the ``none`` trace exactly."""
    jobs = _workload(250, seed=11)
    for policy in ALL_POLICIES:
        none_trace, _, _ = _device_run(jobs, policy, "none", Q=0)
        cons_trace, parked, out = _device_run(jobs, policy,
                                              "conservative")
        assert cons_trace == none_trace, policy
        # ...but the queue is real: delayed accepts are marked parked
        delayed = [a and t > j.t_r
                   for (a, t), j in zip(none_trace, jobs)]
        # graceful degradation aside (full queue commits instead),
        # every parked flag corresponds to a delayed accept
        assert all(d for p, d in zip(parked, delayed) if p)
        assert int(out.n_parked) > 0
        assert int(out.n_moved) == 0


def test_easy_displacement_deterministic_scenario():
    """Hand-built displacement: the head keeps its reservation, the
    non-head parked job moves inside its window, the otherwise-
    rejected arrival is admitted."""
    n_pe = 4
    a = ARRequest(t_a=0, t_r=0, t_du=10, t_dl=30, n_pe=4)   # [0,10)
    b = ARRequest(t_a=1, t_r=1, t_du=5, t_dl=40, n_pe=4)    # ->[10,15)
    c = ARRequest(t_a=2, t_r=2, t_du=5, t_dl=60, n_pe=4)    # ->[15,20)
    d = ARRequest(t_a=3, t_r=3, t_du=5, t_dl=20, n_pe=4)    # window!
    jobs = [a, b, c, d]

    none_trace, _, _ = _device_run(jobs, Policy.FF, "none", Q=0,
                                   n_pe=n_pe)
    assert none_trace == [(True, 0), (True, 10), (True, 15),
                          (False, -1)]
    easy_trace, parked, out = _device_run(jobs, Policy.FF, "easy",
                                          n_pe=n_pe)
    assert easy_trace == [(True, 0), (True, 10), (True, 15),
                          (True, 15)]
    assert parked == [False, True, True, True]
    entries = batch_lib.parked_entries(out)
    by_seq = {e["seq"]: e for e in entries}
    assert by_seq[0]["t_s"] == 10          # head b: untouched
    assert by_seq[1]["t_s"] == 20          # c: displaced 15 -> 20
    assert by_seq[2]["t_s"] == 15          # d: admitted into c's slot
    assert int(out.n_moved) == 1
    # the oracle agrees on everything
    orc = BackfillOracle(n_pe, Policy.FF, "easy")
    assert orc.run(jobs) == easy_trace
    assert orc.pending() == entries
    assert orc.moves == [(1, 15, 20, False, "displace")]


def test_cancel_arms_retry_sweep_and_matches_oracle():
    """A cancel frees future capacity and arms the EASY retry-on-
    release sweep: parked reservations are pulled strictly earlier on
    the next admit step, matching the oracle move for move."""
    n_pe = 4
    a = ARRequest(t_a=0, t_r=0, t_du=10, t_dl=30, n_pe=4)
    b = ARRequest(t_a=1, t_r=1, t_du=5, t_dl=40, n_pe=4)  # parks @10
    e = ARRequest(t_a=2, t_r=2, t_du=1, t_dl=12, n_pe=4)
    sess = ReservationService(ServiceConfig(
        n_pe=n_pe, policy=Policy.FF, capacity=64, backfill="easy",
        backfill_queue=4, chunk_size=None)).session()
    orc = BackfillOracle(n_pe, Policy.FF, "easy", park_capacity=4)
    r1 = sess.offer([a, b])
    for req in (a, b):
        orc.admit(req)
    assert sess.pending()[0]["t_s"] == 10
    alloc_a = r1.allocations()[0]
    assert sess.cancel(alloc_a) is True
    assert orc.cancel(alloc_a.t_s, alloc_a.t_e, alloc_a.pe_ids)
    r2 = sess.offer([e])
    acc_e, ts_e, parked_e = orc.admit(e)
    # the sweep ran first: b moved 10 -> 2, then e fit at 7
    assert sess.pending() == orc.pending()
    assert sess.pending()[0]["t_s"] == 2
    dec = r2.decision
    assert (bool(np.asarray(dec.accepted)[0]),
            int(np.asarray(dec.t_s)[0])) == (acc_e, ts_e)
    m = sess.metrics()
    assert m["n_moved"] == orc.n_moved == 1
    assert orc.moves[-1] == (0, 10, 2, True, "retry")


def test_mid_stream_growth_reproduces_big_capacity_decisions():
    """The grow-once overflow protocol stays deterministic through
    parking, promotion and displacement."""
    jobs = _workload(150, seed=5, load=2.5)
    for mode in MODES:
        small = _device_run(jobs, Policy.PE_W, mode, capacity=8,
                            pending=2)
        big = _device_run(jobs, Policy.PE_W, mode, capacity=256,
                          pending=256)
        assert small[0] == big[0], mode
        assert small[1] == big[1], mode
        assert _records(small[2]) == _records(big[2])
        assert int(small[2].tl.capacity) > 8    # it really grew


def test_queue_full_degrades_gracefully():
    """With a 1-slot queue, delayed accepts beyond the first commit
    immovably (as under ``none``) — decisions still match the oracle
    with the same capacity."""
    jobs = _workload(200, seed=9, load=2.5)
    trace, out = _assert_matches_oracle(jobs, Policy.PE_W, "easy",
                                        Q=1)
    delayed_accepts = sum(
        1 for (a, t), j in zip(trace, jobs) if a and t > j.t_r)
    assert delayed_accepts > int(out.n_parked) > 0


def test_session_chunked_offer_identical_to_one_shot():
    """Ring-staged `Session.offer` arrivals admit bit-identically to
    the one-shot scan under backfilling, with a wrapped ring."""
    jobs = _workload(300, seed=7)
    rng = np.random.RandomState(0)
    for mode in MODES:
        ref_trace, ref_parked, ref_out = _device_run(
            jobs, Policy.PE_W, mode, capacity=128, pending=256)
        sess = ReservationService(ServiceConfig(
            n_pe=N_PE, policy=Policy.PE_W, capacity=128,
            backfill=mode, backfill_queue=8, chunk_size=32,
            ring_capacity=64)).session()
        accs, tss, parks = [], [], []
        i = 0
        while i < len(jobs):
            take = int(rng.randint(1, 80))
            res = sess.offer(jobs[i:i + take])
            i += take
            if res.decision is not None:
                v = np.asarray(res.valid)
                accs.append(np.asarray(res.decision.accepted)[v])
                tss.append(np.asarray(res.decision.t_s)[v])
                parks.append(np.asarray(res.decision.parked)[v])
        trace = [(bool(a), int(t)) for a, t in
                 zip(np.concatenate(accs), np.concatenate(tss))]
        assert trace == ref_trace, mode
        assert [bool(p) for p in np.concatenate(parks)] == ref_parked
        assert sess.metrics()["ring_wrapped"]
        assert sess.pending() == batch_lib.parked_entries(ref_out)


def test_ensemble_mixed_mode_lanes_match_single_lane_sessions():
    """One vmapped dispatch with per-lane traced modes equals three
    independent single-mode runs."""
    jobs = _workload(120, seed=2)
    batch, valid = batch_lib.pad_streams([jobs] * 3, N_PE)
    states = ens_lib.init_ensemble(3, 64, N_PE, 128, park_capacity=8)
    out, dec = ens_lib.admit_stream_ensemble_auto(
        states, batch, [Policy.PE_W] * 3,
        backfills=("none", "easy", "conservative"), n_pe=N_PE)
    for lane, mode in enumerate(("none", "easy", "conservative")):
        ref_trace, ref_parked, _ = _device_run(
            jobs, Policy.PE_W, mode, Q=8)
        acc = np.asarray(dec.accepted)[lane][:len(jobs)]
        ts = np.asarray(dec.t_s)[lane][:len(jobs)]
        assert [(bool(a), int(t))
                for a, t in zip(acc, ts)] == ref_trace, mode
    # ... note lane 0 ran mode none on a Q=8 state: identical to Q=0
    # ensemble sessions report the same backfill counters as
    # single-lane ones (summed across lanes)
    esess = ReservationService(ServiceConfig(
        n_pe=N_PE, lanes=3, capacity=64, chunk_size=None,
        backfill=("none", "easy", "conservative"),
        backfill_queue=8)).session()
    esess.offer([jobs, jobs, jobs], policy=[Policy.PE_W] * 3)
    m = esess.metrics()
    assert m["park_capacity"] == 8
    assert m["n_parked"] > 0 and "n_moved" in m and "n_promoted" in m
    assert len(esess.pending(lane=2)) == m["n_parked_now"] - \
        len(esess.pending(lane=1))


def test_kernel_path_matches_dense_under_backfill():
    """The Pallas search kernel threads through the retry sweep and
    the displacement transaction; decisions must stay identical to
    the dense path."""
    jobs = _workload(60, seed=6, load=2.5)
    for mode in MODES:
        dense = _device_run(jobs, Policy.PE_W, mode, Q=4)
        kern = _device_run(jobs, Policy.PE_W, mode, Q=4,
                           use_kernel=True)
        assert dense[0] == kern[0], mode
        assert dense[1] == kern[1], mode
        assert _records(dense[2]) == _records(kern[2])


def test_backfill_config_validation_and_pending_surface():
    with pytest.raises(ValueError, match="unknown backfill"):
        ServiceConfig(n_pe=8, backfill="aggressive")
    with pytest.raises(ValueError, match="device"):
        ServiceConfig(n_pe=8, engine="host", backfill="easy")
    with pytest.raises(ValueError, match="auto_release"):
        ServiceConfig(n_pe=8, backfill="easy", auto_release=False)
    with pytest.raises(ValueError, match="auto_release"):
        ServiceConfig(n_pe=8, n_partitions=2, auto_release=False,
                      chunk_size=None, backfill="easy")
    with pytest.raises(ValueError, match="single name"):
        ServiceConfig(n_pe=8, n_partitions=2, chunk_size=None,
                      backfill=("easy", "none"))
    # partition lanes backfill with one shared mode
    assert ServiceConfig(n_pe=8, n_partitions=2, chunk_size=None,
                         backfill="easy").backfilling
    with pytest.raises(ValueError, match="modes for"):
        ServiceConfig(n_pe=8, backfill=("easy", "none"))
    with pytest.raises(ValueError, match="backfill_queue"):
        ServiceConfig(n_pe=8, backfill="easy", backfill_queue=0)
    cfg = ServiceConfig(n_pe=8, lanes=2, backfill=("easy", "none"))
    assert cfg.backfilling and cfg.park_capacity == 8
    assert ServiceConfig(n_pe=8).park_capacity == 0
    # a 1-tuple is the single-lane spelling of the per-lane form
    one = ReservationService(ServiceConfig(
        n_pe=8, backfill=("easy",), chunk_size=None)).session()
    r = one.offer([ARRequest(t_a=0, t_r=0, t_du=5, t_dl=20, n_pe=8)])
    assert r.n_accepted == 1
    # integer mode ids are range-checked, not silently ignored
    with pytest.raises(ValueError, match="out of range"):
        batch_lib.as_backfill_id(5)
    with pytest.raises(ValueError, match="single lane"):
        batch_lib.as_backfill_id(("easy", "none"))
    # non-backfilling sessions expose an empty queue
    sess = ReservationService(ServiceConfig(
        n_pe=8, chunk_size=None)).session()
    assert sess.pending() == []
    host = ReservationService(ServiceConfig(
        n_pe=8, engine="host")).session()
    assert host.pending() == []


def test_cancel_reaches_parked_reservations():
    """cancel() withdraws a parked reservation (not only committed
    ones) and frees its queue slot."""
    n_pe = 4
    a = ARRequest(t_a=0, t_r=0, t_du=10, t_dl=30, n_pe=4)
    b = ARRequest(t_a=1, t_r=1, t_du=5, t_dl=40, n_pe=4)
    sess = ReservationService(ServiceConfig(
        n_pe=n_pe, policy=Policy.FF, capacity=64, backfill="easy",
        backfill_queue=4, chunk_size=None)).session()
    res = sess.offer([a, b])
    alloc_b = res.allocations()[1]
    assert alloc_b.t_s == 10
    assert len(sess.pending()) == 1
    assert sess.cancel(alloc_b) is True
    assert sess.pending() == []
    assert sess.cancel(alloc_b) is False       # idempotent


# ---------------------------------------------------------------------------
# safety invariants (seeded property checks; Hypothesis below)
# ---------------------------------------------------------------------------


def test_invariants_on_seeded_workloads():
    """Conservative never moves a reservation; EASY moves are either
    strictly-earlier retries or non-head displacements."""
    for seed, load in ((3, 2.0), (5, 3.0), (9, 2.5)):
        jobs = _workload(150, seed=seed, load=load)
        for policy in (Policy.PE_W, Policy.DU_W):
            orc = BackfillOracle(N_PE, policy, "conservative")
            orc.run(jobs)
            assert orc.moves == []
            orc = BackfillOracle(N_PE, policy, "easy")
            orc.run(jobs)
            for seq, old, new, was_head, event in orc.moves:
                if event == "retry":
                    assert new < old           # never delays anybody
                else:
                    assert event == "displace"
                    assert not was_head        # head is protected


def test_device_committed_starts_and_head_never_delayed():
    """Step the device `admit` one request at a time and watch the
    state: committed reservations never change, and while a given
    entry is head of queue its start never increases."""
    jobs = _workload(80, seed=4, load=2.5)
    state = tl_lib.init_state(64, N_PE, 128, park_capacity=8)
    committed = {}          # (t_s, t_e, mask_bytes) -> first seen
    prev_head = None        # (seq, t_s)
    from repro.core.policies import policy_index

    for req in jobs:
        state, dec = batch_lib.admit(
            state, batch_lib.request_struct(req),
            np.int32(policy_index(Policy.PE_W)),
            np.int32(batch_lib.BF_EASY), n_pe=N_PE)
        assert not bool(state.overflow)
        # committed (pending-release) entries are immutable: every
        # triple either persists or was released because t_e <= now
        pend = {(int(ts), int(te), bytes(np.asarray(m)))
                for ts, te, m in zip(
                    np.asarray(state.pend_ts),
                    np.asarray(state.pend_te),
                    np.asarray(state.pend_mask))
                if te < T_INF}
        gone = set(committed) - pend
        for ts, te, _ in gone:
            assert te <= req.t_a
            committed.pop((ts, te, _))
        for trip in pend:
            committed[trip] = True
        entries = batch_lib.parked_entries(state)
        if entries:
            head = (entries[0]["seq"], entries[0]["t_s"])
            if prev_head is not None and head[0] == prev_head[0]:
                assert head[1] <= prev_head[1], \
                    "EASY delayed the head-of-queue reservation"
            prev_head = head
        else:
            prev_head = None


# ---------------------------------------------------------------------------
# Hypothesis property tests (run where hypothesis is installed)
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                           # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    def _requests(draw):
        n = draw(st.integers(8, 24))
        jobs = []
        t = 0
        for _ in range(n):
            t += draw(st.integers(0, 6))
            du = draw(st.integers(1, 12))
            slack = draw(st.integers(0, 20))
            ar = draw(st.integers(0, 8))
            jobs.append(ARRequest(
                t_a=t, t_r=t + ar, t_du=du,
                t_dl=t + ar + du + slack,
                n_pe=draw(st.integers(1, 8))))
        return jobs

    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def test_hypothesis_conservative_never_moves(data):
        jobs = _requests(data.draw)
        orc = BackfillOracle(8, Policy.PE_W, "conservative",
                             park_capacity=6)
        none = BackfillOracle(8, Policy.PE_W, "none",
                              park_capacity=6)
        assert orc.run(jobs) == none.run(jobs)
        assert orc.moves == []

    @settings(max_examples=40, deadline=None)
    @given(st.data())
    def test_hypothesis_easy_never_delays_head(data):
        jobs = _requests(data.draw)
        orc = BackfillOracle(8, Policy.PE_W, "easy", park_capacity=6)
        orc.run(jobs)
        for seq, old, new, was_head, event in orc.moves:
            assert event != "retry" or new < old
            assert event != "displace" or not was_head

    @settings(max_examples=15, deadline=None)
    @given(st.data())
    def test_hypothesis_device_matches_oracle(data):
        jobs = _requests(data.draw)
        mode = data.draw(st.sampled_from(MODES))
        state = tl_lib.init_state(64, 8, 64, park_capacity=6)
        _, dec = batch_lib.admit_stream_grow(
            state, batch_lib.requests_to_batch(jobs), Policy.PE_W,
            n_pe=8, backfill=mode)
        acc = np.asarray(dec.accepted)
        trace = [(bool(a), int(t))
                 for a, t in zip(acc, np.asarray(dec.t_s))]
        orc = BackfillOracle(8, Policy.PE_W, mode, park_capacity=6)
        assert trace == orc.run(jobs)


# ---------------------------------------------------------------------------
# the 1000-job acceptance gate (slow lane)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_differential_1k_jobs_all_policies_both_modes():
    """ISSUE acceptance criterion: 1000-job streams × 7 policies ×
    {easy, conservative} decide bit-identically to the host oracle,
    including mid-stream capacity growth and ring-staged arrivals."""
    jobs = _workload(1100, seed=1, load=1.5, n_pe=32)[:1000]
    assert len(jobs) == 1000
    rng = np.random.RandomState(1)
    for policy in ALL_POLICIES:
        for mode in MODES:
            state = tl_lib.init_state(
                32, 32, 16, park_capacity=8)   # forces growth
            out, dec = batch_lib.admit_stream_grow(
                state, batch_lib.requests_to_batch(jobs), policy,
                n_pe=32, backfill=mode)
            acc = np.asarray(dec.accepted)
            trace = [(bool(a), int(t))
                     for a, t in zip(acc, np.asarray(dec.t_s))]
            orc = BackfillOracle(32, policy, mode, park_capacity=8)
            ref = orc.run(jobs)
            assert trace == ref, (policy, mode)
            assert batch_lib.parked_entries(out) == orc.pending()
            # ring-staged session arrivals reproduce the same stream
            sess = ReservationService(ServiceConfig(
                n_pe=32, policy=policy, capacity=128, backfill=mode,
                backfill_queue=8, chunk_size=64,
                ring_capacity=128)).session()
            accs, tss = [], []
            i = 0
            while i < len(jobs):
                take = int(rng.randint(1, 160))
                res = sess.offer(jobs[i:i + take])
                i += take
                v = np.asarray(res.valid)
                accs.append(np.asarray(res.decision.accepted)[v])
                tss.append(np.asarray(res.decision.t_s)[v])
            strace = [(bool(a), int(t)) for a, t in
                      zip(np.concatenate(accs), np.concatenate(tss))]
            assert strace == ref, (policy, mode, "session")
