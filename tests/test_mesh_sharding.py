"""Mesh-sharded ensemble dispatch + donated state buffers (DESIGN.md §8).

Acceptance gates for the scale-out PR:

* the ``launch.mesh`` runtime seam (``make_lane_mesh`` /
  ``resolve_placement``) builds divisor meshes on whatever device
  count the host exposes, and ``ServiceConfig.placement`` validates;
* sharded sessions (``placement="auto"``/``"host"``) are decision-
  **bit-identical** to unsharded (``"single"``) sessions — chunked
  streaming, mid-stream growth, every backfill mode, and the whole
  ``simulate_grid`` matrix;
* donation: the steady-state chunk dispatch consumes its input
  buffers, never recompiles after warmup, and the grow-once /
  snapshot-restore / ``auto_grow=False`` contracts all survive it.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
CI ``test-mesh`` lane) these tests exercise real 8-way sharding; on a
single device the placement degrades to the host mesh with the same
code paths.  ``test_eight_way_subprocess`` forces the 8-device case
from any environment.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import ReservationService, ServiceConfig
from repro.core import batch as batch_lib
from repro.core import ensemble as ens_lib
from repro.core import timeline as tl_lib
from repro.core.types import ALL_POLICIES, Policy
from repro.launch import mesh as mesh_lib
from repro.sharding import rules as shard_rules
from repro.sim import WorkloadParams, generate
from repro.sim.sweep import GridSpec, simulate_grid

SMALL_SIZES = dict(u_low=2.0, u_med=4.0, u_hi=6.0)


def _workload(n_jobs, n_pe, seed=7):
    jobs = [j for j in generate(WorkloadParams(
        n_jobs=n_jobs, n_pe=n_pe, seed=seed, **SMALL_SIZES))
        if j.n_pe <= n_pe]
    return sorted(jobs, key=lambda j: j.t_a)


def _lane_streams(n_lanes, n_jobs, n_pe):
    return [_workload(n_jobs, n_pe, seed=11 + e)
            for e in range(n_lanes)]


def _decision_tuple(res):
    return (np.asarray(res.decision.accepted),
            np.asarray(res.decision.t_s),
            np.asarray(res.decision.pe_mask),
            np.asarray(res.valid))


def _assert_same_decisions(a, b):
    for x, y in zip(_decision_tuple(a), _decision_tuple(b)):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# the mesh seam: helpers + config validation
# ---------------------------------------------------------------------------


def test_mesh_helpers():
    host = mesh_lib.make_host_mesh()
    assert mesh_lib.data_shards(host) == 1
    assert host.shape["model"] == 1

    n_dev = len(jax.devices())
    for lanes in (1, 6, 7, 63, 504):
        mesh = mesh_lib.make_lane_mesh(lanes)
        d = mesh_lib.data_shards(mesh)
        assert lanes % d == 0, (lanes, d)
        assert d <= n_dev
        # largest divisor: no k in (d, n_dev] divides lanes
        assert all(lanes % k for k in range(d + 1, n_dev + 1))
    capped = mesh_lib.make_lane_mesh(504, max_shards=2)
    assert mesh_lib.data_shards(capped) == 2 if n_dev >= 2 else 1
    with pytest.raises(ValueError):
        mesh_lib.make_lane_mesh(0)


def test_resolve_placement():
    assert mesh_lib.resolve_placement(None, 8) is None
    assert mesh_lib.resolve_placement("single", 8) is None
    host = mesh_lib.resolve_placement("host", 8)
    assert mesh_lib.data_shards(host) == 1
    auto = mesh_lib.resolve_placement("auto", 8)
    assert 8 % mesh_lib.data_shards(auto) == 0
    one = mesh_lib.resolve_placement(1, 8)
    assert mesh_lib.data_shards(one) == 1
    with pytest.raises(ValueError):
        mesh_lib.resolve_placement("cluster", 8)


def test_production_mesh_helpers_still_build():
    # the dry-run seam must not regress while the runtime reuses it
    if len(jax.devices()) < 256:
        with pytest.raises(ValueError):
            mesh_lib.make_production_mesh()
        return
    mesh = mesh_lib.make_production_mesh()
    assert dict(mesh.shape) == {"data": 16, "model": 16}
    assert mesh_lib.data_shards(mesh) == 16


def test_placement_config_validation():
    ServiceConfig(n_pe=8, placement="auto")
    ServiceConfig(n_pe=8, placement=None, donate=False)
    ServiceConfig(n_pe=8, placement=4)
    for bad in ("cluster", 0, -2, True, 1.5):
        with pytest.raises((ValueError, TypeError)):
            ServiceConfig(n_pe=8, placement=bad)


def test_lane_spec_and_shard_ensemble():
    mesh = mesh_lib.make_lane_mesh(len(jax.devices()))
    states = ens_lib.init_ensemble(len(jax.devices()) or 1, 16, 8, 16)
    sharded = shard_rules.shard_ensemble(mesh, states)
    # lane axis sharded over data, payload axes replicated
    sh = sharded.tl.times.sharding
    assert sh.spec[0] in (("data",), ("pod", "data"), None)
    assert all(ax is None for ax in sh.spec[1:])
    np.testing.assert_array_equal(np.asarray(sharded.tl.times),
                                  np.asarray(states.tl.times))
    # mesh=None is the identity
    assert shard_rules.shard_ensemble(None, states) is states


# ---------------------------------------------------------------------------
# sharded == unsharded, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backfill", ["none", "easy", "conservative"])
def test_sharded_ensemble_identical_to_single(backfill):
    """Chunked ensemble streaming under placement="auto" reproduces
    the unsharded session bit-for-bit, including a mid-stream
    collective growth (tiny initial capacity)."""
    n_pe, lanes = 16, 6
    streams = _lane_streams(lanes, 120, n_pe)
    policies = [ALL_POLICIES[e % len(ALL_POLICIES)]
                for e in range(lanes)]
    results = {}
    for placement in ("single", "auto"):
        sess = ReservationService(ServiceConfig(
            n_pe=n_pe, lanes=lanes, capacity=4, pending_capacity=4,
            chunk_size=16, ring_capacity=64, backfill=backfill,
            placement=placement)).session()
        res = sess.offer(streams, policy=policies)
        results[placement] = (res, sess.metrics())
    _assert_same_decisions(results["single"][0], results["auto"][0])
    m_single, m_auto = results["single"][1], results["auto"][1]
    assert m_auto["growths"] >= 1          # capacity=8 must grow
    for key in ("offered", "accepted", "chunks", "growths"):
        assert m_single[key] == m_auto[key], key
    assert m_auto["placement_shards"] == \
        max(k for k in range(1, len(jax.devices()) + 1)
            if lanes % k == 0)


def test_sharded_donation_off_identical():
    """placement and donation are independent axes: all four
    combinations decide identically."""
    n_pe, lanes = 16, 4
    streams = _lane_streams(lanes, 80, n_pe)
    ref = None
    for placement in ("single", "auto"):
        for donate in (False, True):
            sess = ReservationService(ServiceConfig(
                n_pe=n_pe, lanes=lanes, capacity=32,
                pending_capacity=32, chunk_size=16, ring_capacity=64,
                placement=placement, donate=donate)).session()
            res = sess.offer(streams)
            if ref is None:
                ref = res
            else:
                _assert_same_decisions(ref, res)


def test_simulate_grid_sharded_equals_single():
    spec = GridSpec(n_jobs=60, n_pe=16, seeds=(0, 1),
                    arrival_factors=(1.0,), flex_factors=(0.5,),
                    policies=(Policy.FF, Policy.DU_B),
                    backfill_modes=("none", "easy"))
    single = simulate_grid(spec, capacity=32, placement="single",
                           donate=False, record_decisions=True)
    sharded = simulate_grid(spec, capacity=32, placement="auto",
                            record_decisions=True)
    np.testing.assert_array_equal(single.acceptance, sharded.acceptance)
    np.testing.assert_array_equal(single.n_accepted, sharded.n_accepted)
    assert single.decisions == sharded.decisions


# ---------------------------------------------------------------------------
# donation: allocation-free steady state, contracts preserved
# ---------------------------------------------------------------------------


def test_donated_stream_consumes_input_and_matches():
    n_pe = 16
    jobs = _workload(64, n_pe)
    batch = batch_lib.requests_to_batch(jobs)
    state_a = tl_lib.init_state(64, n_pe, 64)
    state_b = tl_lib.init_state(64, n_pe, 64)
    out_a, dec_a = batch_lib.admit_stream(
        state_a, batch, jnp.int32(0), n_pe=n_pe)
    out_b, dec_b = batch_lib.admit_stream_donated(
        state_b, batch, jnp.int32(0), n_pe=n_pe)
    np.testing.assert_array_equal(np.asarray(dec_a.accepted),
                                  np.asarray(dec_b.accepted))
    np.testing.assert_array_equal(np.asarray(dec_a.t_s),
                                  np.asarray(dec_b.t_s))
    np.testing.assert_array_equal(np.asarray(out_a.tl.times),
                                  np.asarray(out_b.tl.times))
    assert state_b.tl.times.is_deleted()      # donated away
    assert not state_a.tl.times.is_deleted()  # non-donated untouched


def test_donated_chunk_cache_stable_after_warmup():
    """Steady-state streaming through the donated dispatch: zero
    recompiles after the first chunk."""
    n_pe = 16
    jobs = _workload(400, n_pe)
    sess = ReservationService(ServiceConfig(
        n_pe=n_pe, capacity=64, pending_capacity=64, chunk_size=32,
        ring_capacity=64)).session()
    warm = None
    i = 0
    while i < len(jobs):
        sess.offer(jobs[i:i + 50])
        i += 50
        if warm is None:
            warm = batch_lib.admit_stream_donated._cache_size()
    assert warm == batch_lib.admit_stream_donated._cache_size(), \
        "donated chunk dispatch recompiled after warmup"
    assert sess.metrics()["growths"] == 0


def test_donated_grow_rollback_equivalence():
    """Overflow under donation: grow_rollback re-materializes and the
    retry reproduces the never-overflowed decisions exactly."""
    n_pe = 16
    jobs = _workload(200, n_pe)
    batch = batch_lib.requests_to_batch(jobs)
    big, dec_big = batch_lib.admit_stream_grow(
        tl_lib.init_state(256, n_pe, 256), batch, Policy.FF,
        n_pe=n_pe)
    small, dec_small = batch_lib.admit_stream_grow(
        tl_lib.init_state(4, n_pe, 4), batch, Policy.FF,
        n_pe=n_pe, donate=True)
    np.testing.assert_array_equal(np.asarray(dec_big.accepted),
                                  np.asarray(dec_small.accepted))
    np.testing.assert_array_equal(np.asarray(dec_big.t_s),
                                  np.asarray(dec_small.t_s))
    assert int(small.n_accepted) == int(big.n_accepted)


def test_growth_mid_stream_donated_session():
    """A chunked session starting at capacity 4 equals a session that
    started big — the pipelined deferred-overflow replay path."""
    n_pe = 32
    jobs = _workload(300, n_pe, seed=3)
    res, metrics = {}, {}
    for cap in (4, 256):
        sess = ReservationService(ServiceConfig(
            n_pe=n_pe, capacity=cap, pending_capacity=max(cap, 8),
            chunk_size=32, ring_capacity=64)).session()
        out = []
        for i in range(0, len(jobs), 70):
            out.append(sess.offer(jobs[i:i + 70]))
        acc = np.concatenate(
            [np.asarray(r.decision.accepted)[np.asarray(r.valid)]
             for r in out])
        ts = np.concatenate(
            [np.asarray(r.decision.t_s)[np.asarray(r.valid)]
             for r in out])
        res[cap] = (acc, ts)
        metrics[cap] = sess.metrics()
    np.testing.assert_array_equal(res[4][0], res[256][0])
    np.testing.assert_array_equal(res[4][1], res[256][1])
    assert metrics[4]["growths"] >= 1
    assert metrics[4]["accepted"] == metrics[256]["accepted"]


def test_snapshot_restore_with_donation():
    """A snapshot pins the buffers (donation pauses), restore rewinds,
    and the replayed traffic decides identically."""
    n_pe = 16
    jobs = _workload(200, n_pe, seed=5)
    sess = ReservationService(ServiceConfig(
        n_pe=n_pe, capacity=64, pending_capacity=64, chunk_size=16,
        ring_capacity=64)).session()
    sess.offer(jobs[:100])
    snap = sess.snapshot()
    res_1 = sess.offer(jobs[100:])
    m_1 = sess.metrics()
    sess.restore(snap)
    res_2 = sess.offer(jobs[100:])
    _assert_same_decisions(res_1, res_2)
    assert sess.metrics() == m_1
    # the snapshot's state arrays must have survived both replays
    state, _ = snap[0]
    assert not state.tl.times.is_deleted()


def test_auto_grow_false_with_donation_stays_usable():
    """auto_grow=False: the first overflow raises, the session state
    is rolled back (donation reinstalls it) and admission continues."""
    n_pe = 16
    jobs = _workload(300, n_pe, seed=9)
    sess = ReservationService(ServiceConfig(
        n_pe=n_pe, capacity=4, pending_capacity=4, chunk_size=16,
        ring_capacity=512, auto_grow=False)).session()
    with pytest.raises(batch_lib.GrowthError):
        sess.offer(jobs)
    m = sess.metrics()
    assert m["growths"] == 0
    assert m["capacity"] == 4                 # rolled back, not grown
    # the overflowing chunk's requests went back to the staging ring
    assert m["ring_staged"] > 0


def test_one_shot_donated_offer_result_usable():
    """The one-shot (chunk_size=None) path donates too; the returned
    decision arrays must be fresh buffers, not aliases of the state."""
    n_pe = 16
    jobs = _workload(50, n_pe)
    sess = ReservationService(ServiceConfig(
        n_pe=n_pe, capacity=64, chunk_size=None)).session()
    r1 = sess.offer(jobs[:25])
    r2 = sess.offer(jobs[25:])
    assert int(np.asarray(r1.decision.accepted).sum()) > 0
    assert int(np.asarray(r2.decision.accepted).sum()) > 0
    assert sess.metrics()["accepted"] == r1.n_accepted + r2.n_accepted


# ---------------------------------------------------------------------------
# the big differential + the forced-8-device run
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("backfill", ["none", "easy", "conservative"])
def test_sharded_differential_500_jobs_all_policies(backfill):
    """>= 500 jobs x 7 policies x backfill mode: sharded chunked
    streaming == unsharded one-shot, bit for bit (the ISSUE gate)."""
    n_pe = 32
    lanes = len(ALL_POLICIES)
    stream = _workload(560, n_pe, seed=21)
    assert len(stream) >= 500
    stream = stream[:500]
    streams = [list(stream) for _ in range(lanes)]
    sharded = ReservationService(ServiceConfig(
        n_pe=n_pe, lanes=lanes, capacity=64, pending_capacity=64,
        chunk_size=64, ring_capacity=128, backfill=backfill,
        placement="auto")).session()
    res = sharded.offer(streams, policy=list(ALL_POLICIES))
    acc = np.asarray(res.decision.accepted)
    ts = np.asarray(res.decision.t_s)
    valid = np.asarray(res.valid)
    for lane, policy in enumerate(ALL_POLICIES):
        single = ReservationService(ServiceConfig(
            n_pe=n_pe, policy=policy, capacity=64,
            pending_capacity=64, chunk_size=None, backfill=backfill,
            placement="single", donate=False)).session()
        ref = single.offer(stream)
        v = valid[lane]
        np.testing.assert_array_equal(
            acc[lane][v], np.asarray(ref.decision.accepted))
        np.testing.assert_array_equal(
            ts[lane][v], np.asarray(ref.decision.t_s))


@pytest.mark.slow
def test_eight_way_subprocess():
    """Force 8 host devices in a subprocess and check a sharded grid
    both shards 8 ways and matches the unsharded decisions."""
    code = """
import os
import numpy as np
from repro.api import ReservationService, ServiceConfig
from repro.sim.sweep import GridSpec, simulate_grid
from repro.core.types import Policy
import jax
assert jax.device_count() == 8, jax.devices()
spec = GridSpec(n_jobs=40, n_pe=16, seeds=(0, 1, 2, 3),
                arrival_factors=(1.0,), flex_factors=(0.5,),
                policies=(Policy.FF, Policy.DU_B),
                backfill_modes=("none",))
single = simulate_grid(spec, capacity=32, placement="single",
                       donate=False, record_decisions=True)
sharded = simulate_grid(spec, capacity=32, placement="auto",
                        record_decisions=True)
np.testing.assert_array_equal(single.acceptance, sharded.acceptance)
assert single.decisions == sharded.decisions
sess = ReservationService(ServiceConfig(
    n_pe=16, lanes=8, capacity=32, chunk_size=8,
    ring_capacity=32)).session()
assert sess.metrics()["placement_shards"] == 8
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
