"""Prefill+decode must agree with the full-sequence forward pass.

For every family: run ``forward`` on T+1 tokens; separately prefill the
first T and decode one step; the decode logits must match the forward
logits at the last position (bf16 tolerance).  This pins the cache
layouts (roped K/V, ring buffers, recurrent states) to the training
path's semantics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tf_lib

FAMS = ["stablelm-1.6b", "granite-moe-1b-a400m", "zamba2-7b",
        "xlstm-1.3b", "llama-3.2-vision-11b", "seamless-m4t-medium"]


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.family == "moe":
        # capacity dropping legitimately differs between a 32-token
        # prefill and a 2-token decode batch; disable drops so the two
        # paths compute identical expert mixtures.
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    key = jax.random.PRNGKey(1)
    params = tf_lib.init_params(cfg, key)
    B, T = 2, 16
    tokens = jax.random.randint(key, (B, T + 1), 0, cfg.vocab)
    extra = {}
    if cfg.family == "encdec":
        extra["enc_frames"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        extra["image_embeds"] = jax.random.normal(
            key, (B, cfg.vision_tokens, cfg.vision_dim)) * 0.1

    # reference: full forward over T+1 tokens -> logits at position T
    out = tf_lib.forward(params, cfg, tokens, extra)
    head = params.get("lm_head")
    head = params["tok_embed"].T if head is None else head
    ref = jnp.einsum("bd,dv->bv", out.hidden[:, -1], head)
    ref = np.asarray(ref, np.float32)

    # serving path: prefill T tokens, decode token T
    _, cache = tf_lib.prefill(params, cfg, tokens[:, :T], extra,
                              max_len=T + 1)
    got, _ = tf_lib.decode_step(params, cfg, cache, tokens[:, T:T + 1],
                                extra)
    got = np.asarray(got, np.float32)

    # compare top-1 and logit values (bf16 path -> loose atol)
    assert np.argmax(ref, -1).tolist() == np.argmax(got, -1).tolist()
    np.testing.assert_allclose(got, ref, rtol=0.12, atol=0.12)


def test_multi_step_decode_stays_consistent():
    """Decode 4 steps; each must match a fresh forward of the prefix."""
    cfg = get_config("stablelm-1.6b").reduced()
    key = jax.random.PRNGKey(2)
    params = tf_lib.init_params(cfg, key)
    B, T, N = 1, 8, 4
    tokens = jax.random.randint(key, (B, T + N), 0, cfg.vocab)
    _, cache = tf_lib.prefill(params, cfg, tokens[:, :T],
                              max_len=T + N)
    head = params["lm_head"]
    for i in range(N):
        got, cache = tf_lib.decode_step(
            params, cfg, cache, tokens[:, T + i:T + i + 1])
        out = tf_lib.forward(params, cfg, tokens[:, :T + i + 1])
        ref = jnp.einsum("bd,dv->bv", out.hidden[:, -1], head)
        assert np.argmax(np.asarray(ref), -1).tolist() == \
            np.argmax(np.asarray(got), -1).tolist(), f"step {i}"


def test_generate_greedy_runs():
    from repro.serve.engine import generate
    cfg = get_config("qwen3-4b").reduced()
    params = tf_lib.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.full((2, 8), 5, jnp.int32)
    out = generate(params, cfg, prompt, n_tokens=6, jit=True)
    assert out.shape == (2, 6)
    assert np.all(np.asarray(out) >= 0)
    assert np.all(np.asarray(out) < cfg.vocab)
