"""Multi-tenant admission (DESIGN.md §10), locked down differentially.

The tenancy subsystem threads a :class:`repro.tenancy.TenantTable`
through the fused admit step: a quota gate before the search, a
weighted fair-share ranking in the deferral-queue sweeps, overdue
reaping in ``Session.tick`` and per-tenant telemetry folded into the
device-resident accumulators.  The gates here:

* **zero-tenant default**: ``tenants=None`` contributes no pytree
  leaves — state, decisions and metrics are exactly the PR 7 ones;
* **equal-weight / unlimited-quota neutrality**: a tenant table whose
  weights are all equal and whose quotas/caps are unlimited is
  bit-identical to no table at all — decisions, records, queue state
  and counters — across the 1000-job × 7-policy × 3-backfill matrix
  (the FCFS-equivalence invariant of the fair-share key);
* **host oracle**: :class:`repro.core.hostsched.TenantOracle` matches
  the device path bit-for-bit on quota rejections, fair-share
  promotion order, reaping, and every per-tenant counter including
  the float32 EWMAs;
* **poll-cheap telemetry**: an idle ``Session.metrics()`` performs
  zero device fetches (satellite: the ``_device_fetch`` choke point).
"""
import dataclasses

import numpy as np
import pytest

from repro.api import ReservationService, ServiceConfig
from repro.core import batch as batch_lib
from repro.core import ensemble as ens_lib
from repro.core import timeline as tl_lib
from repro.core.hostsched import TenantOracle
from repro.core.policies import policy_index
from repro.core.types import ALL_POLICIES, ARRequest, Policy, T_INF
from repro.sim import WorkloadParams, generate_filtered
from repro.tenancy import (TenantSpec, init_table, stack_tables,
                           tenant_view)

N_PE = 16
SIZES = dict(u_low=2.0, u_med=3.0, u_hi=4.0)
MODES = ("none", "easy", "conservative")


def _workload(n_jobs, seed, load=2.0, n_pe=N_PE, n_tenants=0):
    jobs = generate_filtered(WorkloadParams(
        n_jobs=n_jobs, n_pe=n_pe, seed=seed, arrival_factor=load,
        **SIZES), max_pe=n_pe)
    jobs = sorted(jobs, key=lambda j: j.t_a)
    if n_tenants:
        rng = np.random.default_rng(seed + 1)
        jobs = [dataclasses.replace(
            j, tenant=int(rng.integers(0, n_tenants))) for j in jobs]
    return jobs


def _records(state):
    times = np.asarray(state.tl.times)
    occ = np.asarray(state.tl.occ)
    return [(int(t), frozenset(batch_lib.mask32_to_ids(o)))
            for t, o in zip(times, occ) if t < T_INF]


def _queue(state):
    """Parked entries with the tenancy-only keys stripped."""
    drop = ("tenant", "t_a")
    return [{k: v for k, v in e.items() if k not in drop}
            for e in batch_lib.parked_entries(state)]


def _run_device(jobs, policy, mode, spec, *, Q=8, capacity=64,
                pending=128, n_pe=N_PE):
    table = (init_table(spec, pending, Q)
             if spec is not None else None)
    state = tl_lib.init_state(capacity, n_pe, pending,
                              park_capacity=Q, tenants=table)
    out, dec = batch_lib.admit_stream_grow(
        state,
        batch_lib.requests_to_batch(jobs,
                                    with_tenant=spec is not None),
        policy, n_pe=n_pe, backfill=mode)
    trace = [(bool(a), int(t), bool(p)) for a, t, p in
             zip(np.asarray(dec.accepted), np.asarray(dec.t_s),
                 np.asarray(dec.parked))]
    return trace, out


# ---------------------------------------------------------------------------
# the neutrality gate: equal weights + unlimited quotas == no tenants
# ---------------------------------------------------------------------------


def test_equal_weight_unlimited_is_bit_identical_to_no_tenants():
    """1000 jobs × 7 policies × 3 backfill modes, one vmapped
    ensemble dispatch per variant: an all-equal tenant table must not
    change a single decision, record, queue entry or counter."""
    n_pe = 64
    jobs = generate_filtered(WorkloadParams(
        n_jobs=1000, n_pe=n_pe, seed=3, arrival_factor=1.0),
        max_pe=n_pe)
    jobs = sorted(jobs, key=lambda j: j.t_a)
    assert len(jobs) >= 500
    jobs = [dataclasses.replace(j, tenant=i % 3)
            for i, j in enumerate(jobs)]
    cells = [(p, m) for p in ALL_POLICIES for m in MODES]
    spec = TenantSpec(weights=(1.0, 1.0, 1.0))   # unlimited quotas

    def run(tenants):
        sess = ReservationService(ServiceConfig(
            n_pe=n_pe, lanes=len(cells), capacity=128,
            pending_capacity=256, chunk_size=None,
            backfill=tuple(m for _, m in cells),
            backfill_queue=8, tenants=tenants)).session()
        batch, valid = batch_lib.pad_streams(
            [jobs] * len(cells), n_pe,
            with_tenant=tenants is not None)
        pids = np.asarray([policy_index(p) for p, _ in cells],
                          np.int32)
        res = sess.offer((batch, valid), policy=pids)
        return sess, res

    sess0, res0 = run(None)
    sess1, res1 = run((spec,) * len(cells))
    for f in ("accepted", "t_s", "parked"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res0.decision, f)),
            np.asarray(getattr(res1.decision, f)))
    for lane in range(len(cells)):
        m0 = ens_lib.member(sess0._backend.states, lane)
        m1 = ens_lib.member(sess1._backend.states, lane)
        assert _records(m0) == _records(m1), cells[lane]
        assert _queue(m0) == _queue(m1), cells[lane]
        for c in ("n_parked", "n_promoted", "n_moved", "n_released"):
            assert int(getattr(m0, c)) == int(getattr(m1, c)), \
                (cells[lane], c)
    assert ens_lib.member(sess0._backend.states, 0).tenants is None
    assert "tenants" not in sess0.metrics()
    assert "tenants" in sess1.metrics()


def test_fair_key_reduces_to_fcfs_under_equal_weights():
    """Host statement of the same invariant: the weighted key with
    equal weights sorts exactly like the FCFS seq order."""
    spec = TenantSpec(weights=(2.5, 2.5, 2.5))
    orc = TenantOracle(N_PE, Policy.FF, "easy", spec)
    entries = [dict(seq=s, tenant=s % 3, t_a=t)
               for s, t in enumerate([0, 0, 3, 3, 7])]
    for t_now in (7, 10, 100):
        order = sorted(entries,
                       key=lambda p: orc._order_key(p, t_now))
        assert [p["seq"] for p in order] == [0, 1, 2, 3, 4]


# ---------------------------------------------------------------------------
# device == TenantOracle: gate, fair share, counters, EWMAs
# ---------------------------------------------------------------------------


SPEC = TenantSpec(weights=(1.0, 4.0, 2.0),
                  quotas=(500.0, None, 800.0),
                  max_live=(None, 6, None))


def test_device_matches_tenant_oracle_bit_for_bit():
    jobs = _workload(300, seed=3, n_tenants=3)
    for mode in MODES:
        for policy in (Policy.FF, Policy.PE_B, Policy.PEDU_W):
            trace, out = _run_device(jobs, policy, mode, SPEC)
            orc = TenantOracle(N_PE, policy, mode, SPEC,
                               park_capacity=8)
            assert trace == [orc.admit(r) for r in jobs], \
                (mode, policy)
            assert _records(out) == orc.records(), (mode, policy)
            t, a = out.tenants, orc.accounts
            for f in ("used", "live", "n_accepted", "n_rejected",
                      "n_quota_rejected", "n_parked", "acc_ewma",
                      "slow_ewma"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(t, f)), getattr(a, f),
                    err_msg=f"{mode}/{policy}/{f}")
            assert np.asarray(t.occ_ewma) == a.occ_ewma
            assert int(np.asarray(t.n_quota_rejected).sum()) > 0


def test_fair_share_changes_promotion_order_and_matches_oracle():
    """A heavy tenant's parked reservation outranks an earlier light
    one in the EASY retry sweep — and the device still matches the
    oracle bit for bit under the skewed weights."""
    spec = TenantSpec(weights=(1.0, 16.0))
    jobs = _workload(300, seed=9, n_tenants=2)
    base = TenantSpec(weights=(1.0, 1.0))
    for policy in (Policy.FF, Policy.PE_B):
        skew, out_s = _run_device(jobs, policy, "easy", spec)
        flat, out_f = _run_device(jobs, policy, "easy", base)
        orc = TenantOracle(N_PE, policy, "easy", spec,
                           park_capacity=8)
        assert skew == [orc.admit(r) for r in jobs], policy
        assert _records(out_s) == orc.records(), policy
    # the weights must be observable somewhere across seeds/policies
    diffs = 0
    for seed in (9, 10, 11):
        jb = _workload(300, seed=seed, n_tenants=2)
        for policy in (Policy.FF, Policy.PE_B):
            s, _ = _run_device(jb, policy, "easy", spec)
            f, _ = _run_device(jb, policy, "easy", base)
            diffs += s != f
    assert diffs > 0, "weight skew never changed any decision"


def test_reaping_matches_oracle_and_charges_owner():
    spec = TenantSpec(weights=(1.0, 1.0), grace=3)
    jobs = _workload(200, seed=5, n_tenants=2)
    trace, out = _run_device(jobs, Policy.FF, "easy", spec)
    orc = TenantOracle(N_PE, Policy.FF, "easy", spec,
                       park_capacity=8)
    ref = [orc.admit(r) for r in jobs]
    assert trace == ref
    horizon = max(j.t_a for j in jobs) + 6000
    out = batch_lib.reap_until(out, horizon, 3)
    n = orc.reap(horizon)
    assert n > 0
    assert _records(out) == orc.records()
    t, a = out.tenants, orc.accounts
    np.testing.assert_array_equal(np.asarray(t.n_reaped), a.n_reaped)
    np.testing.assert_array_equal(np.asarray(t.live), a.live)
    assert int(np.asarray(t.n_reaped).sum()) == n


def test_session_tick_reaps_overdue_reservations():
    spec = TenantSpec(weights=(1.0,), grace=5)
    sess = ReservationService(ServiceConfig(
        n_pe=8, capacity=32, chunk_size=4, ring_capacity=8,
        auto_release=False, tenants=spec)).session()
    r = ARRequest(t_a=0, t_r=0, t_du=10, t_dl=20, n_pe=4, tenant=0)
    assert bool(np.asarray(sess.offer([r]).decision.accepted)[0])
    assert sess.metrics(tenant=0)["live"] == 1
    assert sess.tick(14) == 0          # t_e + grace = 15 not yet due
    assert sess.tick(15) == 1
    m = sess.metrics(tenant=0)
    assert m["live"] == 0 and m["n_reaped"] == 1
    assert sess.metrics()["reaped"] == 1


def test_ensemble_lane_tables_and_reaping():
    spec0 = TenantSpec(weights=(1.0, 1.0), grace=4)
    spec1 = TenantSpec(weights=(1.0,))          # no grace: never reaps
    sess = ReservationService(ServiceConfig(
        n_pe=8, lanes=2, capacity=32, chunk_size=4, ring_capacity=8,
        auto_release=False, tenants=(spec0, spec1))).session()
    r0 = ARRequest(t_a=0, t_r=0, t_du=6, t_dl=20, n_pe=4, tenant=1)
    r1 = ARRequest(t_a=0, t_r=0, t_du=6, t_dl=20, n_pe=4, tenant=0)
    sess.offer([[r0], [r1]])
    m = sess.metrics()
    assert m["tenants"]["live"].tolist() == [[0, 1], [1, 0]]
    assert sess.tick(9) == 0
    assert sess.tick(10) == 1          # lane 0 reaps at t_e+4
    m = sess.metrics()
    assert m["tenants"]["live"].tolist() == [[0, 0], [1, 0]]
    assert m["tenants"]["n_reaped"].tolist() == [[0, 1], [0, 0]]


# ---------------------------------------------------------------------------
# telemetry: tenant views and the idle-poll fast path
# ---------------------------------------------------------------------------


def test_metrics_tenant_view_and_errors():
    spec = TenantSpec(weights=(1.0, 2.0), quotas=(100.0, None))
    sess = ReservationService(ServiceConfig(
        n_pe=8, capacity=32, chunk_size=4, ring_capacity=8,
        tenants=spec)).session()
    reqs = [ARRequest(t_a=i, t_r=i, t_du=20, t_dl=i + 40, n_pe=2,
                      tenant=i % 2) for i in range(6)]
    sess.offer(reqs)
    v0 = sess.metrics(tenant=0)
    assert v0["tenant"] == 0 and v0["weight"] == 1.0
    assert v0["live"] + sess.metrics(tenant=1)["live"] \
        == int(sess.metrics()["tenants"]["live"].sum())
    with pytest.raises(ValueError, match="out of range"):
        sess.metrics(tenant=2)
    plain = ReservationService(ServiceConfig(
        n_pe=8, chunk_size=4, ring_capacity=8)).session()
    with pytest.raises(ValueError, match="multi-tenant"):
        plain.metrics(tenant=0)
    with pytest.raises(ValueError, match="out of range"):
        sess.offer([ARRequest(t_a=9, t_r=9, t_du=5, t_dl=30, n_pe=1,
                              tenant=7)])


def test_idle_metrics_performs_zero_device_fetches(monkeypatch):
    """Satellite gate: polling an idle session costs no device sync.
    Every device->host metric transfer goes through the
    ``service._device_fetch`` choke point; count its calls."""
    from repro.api import service as service_mod

    calls = {"n": 0}
    real = service_mod._device_fetch

    def counting(tree):
        calls["n"] += 1
        return real(tree)

    monkeypatch.setattr(service_mod, "_device_fetch", counting)
    for cfg in (ServiceConfig(n_pe=8, capacity=32, chunk_size=4,
                              ring_capacity=8,
                              tenants=TenantSpec(weights=(1.0, 1.0))),
                ServiceConfig(n_pe=8, capacity=32, chunk_size=4,
                              ring_capacity=8),
                ServiceConfig(n_pe=8, lanes=2, capacity=32,
                              chunk_size=4, ring_capacity=8)):
        sess = ReservationService(cfg).session()
        reqs = [ARRequest(t_a=0, t_r=0, t_du=10, t_dl=30, n_pe=2)]
        sess.offer(reqs if cfg.lanes == 1 else [reqs] * cfg.lanes)
        sess.metrics()                 # warms the snapshot cache
        calls["n"] = 0
        for _ in range(5):
            sess.metrics()             # idle polls
            if cfg.tenants is not None:
                sess.metrics(tenant=0)
        assert calls["n"] == 0, cfg
        # a new offer invalidates the cache: exactly one refresh fetch
        # (plus the pipelined drain's latch read)
        sess.offer(
            [ARRequest(t_a=5, t_r=5, t_du=10, t_dl=40, n_pe=2)]
            if cfg.lanes == 1 else
            [[ARRequest(t_a=5, t_r=5, t_du=10, t_dl=40, n_pe=2)]] * 2)
        calls["n"] = 0
        sess.metrics()
        after_offer = calls["n"]
        assert after_offer >= 1
        calls["n"] = 0
        sess.metrics()
        assert calls["n"] == 0, cfg


# ---------------------------------------------------------------------------
# state plumbing: growth, grids, partitions, config validation
# ---------------------------------------------------------------------------


def test_growth_preserves_tenant_accounting():
    spec = TenantSpec(weights=(1.0, 1.0), quotas=(None, None))
    jobs = _workload(400, seed=2, n_tenants=2)
    # tiny capacities force the grow-once protocol mid-stream
    trace_small, out_small = _run_device(jobs, Policy.FF, "easy",
                                         spec, capacity=8, pending=8)
    trace_big, out_big = _run_device(jobs, Policy.FF, "easy", spec,
                                     capacity=512, pending=512)
    assert trace_small == trace_big
    t0, t1 = out_small.tenants, out_big.tenants
    for f in ("used", "live", "n_accepted", "n_rejected", "acc_ewma",
              "slow_ewma"):
        np.testing.assert_array_equal(np.asarray(getattr(t0, f)),
                                      np.asarray(getattr(t1, f)), f)
    pend = np.asarray(out_small.tenants.pend_tenant)
    assert pend.shape[0] == int(out_small.pend_te.shape[0])
    assert ((pend >= -1) & (pend < 2)).all()


def test_simulate_grid_tenant_mix_axis():
    from repro.sim.sweep import GridSpec, simulate_grid

    spec = GridSpec(
        policies=(Policy.FF, Policy.PE_B),
        arrival_factors=(1.0,), seeds=(0,), flex_factors=(3.0,),
        backfill_modes=("none", "easy"),
        tenant_mixes=(None, TenantSpec(weights=(1.0, 3.0),
                                       quotas=(4000.0, None))),
        n_pe=64, n_jobs=100)
    res = simulate_grid(spec, cross_check=True)
    assert res.acceptance.shape == (2, 2, 1, 1, 1, 2)
    assert (res.n_jobs > 0).all()
    legacy = simulate_grid(dataclasses.replace(
        spec, tenant_mixes=(None,)), cross_check=True)
    assert legacy.acceptance.shape == (2, 2, 1, 1, 1)
    np.testing.assert_array_equal(res.acceptance[..., 0],
                                  legacy.acceptance)
    # the quota-bound mix must actually bite somewhere
    assert (res.acceptance[..., 1] < res.acceptance[..., 0]).any()


def test_partition_sessions_gate_route_and_reap():
    spec = TenantSpec(weights=(1.0, 1.0), quotas=(40.0, None),
                      max_live=(None, 2), grace=2)
    sess = ReservationService(ServiceConfig(
        n_pe=8, n_partitions=2, auto_release=False, chunk_size=None,
        tenants=spec)).session()
    reqs = [ARRequest(t_a=i, t_r=i, t_du=10, t_dl=i + 30, n_pe=2,
                      tenant=i % 2) for i in range(8)]
    res = sess.offer(reqs)
    m = sess.metrics()
    snap = m["tenants"]
    assert snap["n_quota_rejected"].sum() > 0
    assert (snap["live"] <= np.asarray([100, 2])).all()
    assert m["ledger_depth"] == int(snap["live"].sum())
    live_before = int(snap["live"].sum())
    reaped = sess.tick(200)
    assert reaped == live_before
    snap = sess.metrics()["tenants"]
    assert int(snap["live"].sum()) == 0
    assert int(snap["n_reaped"].sum()) == reaped
    with pytest.raises(ValueError, match="out of range"):
        sess.offer([ARRequest(t_a=99, t_r=99, t_du=5, t_dl=200,
                              n_pe=1, tenant=5)])


def test_tenant_config_validation_errors():
    spec = TenantSpec(weights=(1.0, 1.0))
    with pytest.raises(ValueError, match="share one tenant spec"):
        ServiceConfig(n_pe=8, n_partitions=2, auto_release=False,
                      chunk_size=None, tenants=(spec, spec))
    with pytest.raises(ValueError, match="tenant specs for"):
        ServiceConfig(n_pe=8, lanes=3, chunk_size=4, ring_capacity=8,
                      tenants=(spec, spec))
    with pytest.raises(ValueError, match="TenantSpec or None"):
        ServiceConfig(n_pe=8, lanes=2, chunk_size=4, ring_capacity=8,
                      tenants=(spec, "notaspec"))
    with pytest.raises(ValueError, match="must be a TenantSpec"):
        ServiceConfig(n_pe=8, chunk_size=4, ring_capacity=8,
                      tenants="gold")
    with pytest.raises(ValueError, match="engine='device'"):
        ServiceConfig(n_pe=8, engine="host", tenants=spec)
    with pytest.raises(ValueError, match="pending-queue size"):
        ServiceConfig(n_pe=8, pending_capacity=4, chunk_size=4,
                      ring_capacity=8,
                      tenants=TenantSpec(weights=(1.0,) * 8))
    with pytest.raises(ValueError, match="over_quota"):
        TenantSpec(weights=(1.0,), over_quota="park")
    with pytest.raises(ValueError, match="weights"):
        TenantSpec(weights=())
    with pytest.raises(ValueError, match="quotas"):
        TenantSpec(weights=(1.0,), quotas=(1.0, 2.0))


def test_tenant_view_helper():
    spec = TenantSpec(weights=(1.0, 2.0))
    table = init_table(spec, 16, 4)
    snap = {f: np.asarray(getattr(table, f))
            for f in ("weight", "quota", "max_live", "used", "live",
                      "n_accepted", "n_rejected", "n_quota_rejected",
                      "n_parked", "n_reaped", "acc_ewma",
                      "slow_ewma")}
    snap["occ_ewma"] = np.float32(0.0)
    v = tenant_view(snap, 1)
    assert v["tenant"] == 1 and v["weight"] == 2.0
    with pytest.raises(ValueError, match="out of range"):
        tenant_view(snap, 2)
    stacked = stack_tables((spec, None), 16, 4)
    assert np.asarray(stacked.weight).shape == (2, 2)
