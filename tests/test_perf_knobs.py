"""Numerical sanity of the beyond-paper perf knobs (§Perf B/C).

The optimizations must not change semantics beyond quantisation noise:
* int8 KV cache: decode still matches the full forward's top-1;
* int8 MoE dispatch: loss within quantisation tolerance of baseline;
* sequence parallelism: a sharding constraint only — bitwise no-op on
  a single device.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tf_lib


def test_int8_kv_cache_decode_matches_forward():
    cfg = dataclasses.replace(get_config("stablelm-1.6b").reduced(),
                              kv_cache_dtype="int8")
    key = jax.random.PRNGKey(3)
    params = tf_lib.init_params(cfg, key)
    B, T = 2, 16
    tokens = jax.random.randint(key, (B, T + 1), 0, cfg.vocab)
    out = tf_lib.forward(params, cfg, tokens)
    ref = jnp.einsum("bd,dv->bv", out.hidden[:, -1], params["lm_head"])
    _, cache = tf_lib.prefill(params, cfg, tokens[:, :T],
                              max_len=T + 1)
    assert cache["attn"]["k"].dtype == jnp.int8
    got, _ = tf_lib.decode_step(params, cfg, cache, tokens[:, T:T + 1])
    assert np.argmax(np.asarray(ref), -1).tolist() == \
        np.argmax(np.asarray(got), -1).tolist()
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref,
                               np.float32), rtol=0.25, atol=0.25)


def test_int8_moe_dispatch_close_to_baseline():
    base = dataclasses.replace(
        get_config("granite-moe-1b-a400m").reduced(),
        capacity_factor=100.0)
    quant = dataclasses.replace(base, moe_quant_dispatch=True)
    key = jax.random.PRNGKey(0)
    params = tf_lib.init_params(base, key)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, base.vocab),
             "labels": jax.random.randint(key, (2, 32), 0, base.vocab)}
    l0, _ = tf_lib.loss_fn(params, base, batch)
    l1, _ = tf_lib.loss_fn(params, quant, batch)
    assert abs(float(l0) - float(l1)) < 0.05 * float(l0)


def test_seq_parallel_is_noop_on_single_device():
    base = get_config("qwen3-4b").reduced()
    sp = dataclasses.replace(base, seq_parallel=True)
    key = jax.random.PRNGKey(1)
    params = tf_lib.init_params(base, key)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, base.vocab),
             "labels": jax.random.randint(key, (2, 32), 0, base.vocab)}
    l0, _ = tf_lib.loss_fn(params, base, batch)
    l1, _ = tf_lib.loss_fn(params, sp, batch)
    assert float(l0) == float(l1)


def test_int8_cache_struct_halves_bytes():
    cfg = get_config("qwen3-4b")
    c16 = jax.eval_shape(lambda: tf_lib.init_decode_cache(cfg, 8, 1024))
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    c8 = jax.eval_shape(lambda: tf_lib.init_decode_cache(cfg8, 8, 1024))
    b16 = sum(np.prod(l.shape) * l.dtype.itemsize
              for l in jax.tree.leaves(c16))
    b8 = sum(np.prod(l.shape) * l.dtype.itemsize
             for l in jax.tree.leaves(c8))
    assert b8 < 0.55 * b16
