"""Checkpoint manager: exact roundtrip (incl. bf16), atomicity, GC."""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager


def _state(key):
    return {
        "w": jax.random.normal(key, (8, 16), jnp.float32),
        "b16": jax.random.normal(key, (4, 4)).astype(jnp.bfloat16),
        "step": jnp.int32(7),
        "nested": {"u": jnp.arange(5, dtype=jnp.int32)},
    }


def test_roundtrip_exact(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = _state(jax.random.PRNGKey(0))
    mgr.save(3, state, {"loss": 1.5})
    restored, step, meta = mgr.restore(state)
    assert step == 3 and meta["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = _state(jax.random.PRNGKey(1))
    mgr.save_async(5, state)
    mgr.wait()
    restored, step, _ = mgr.restore(state)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))


def test_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = _state(jax.random.PRNGKey(2))
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_tmp_dirs_never_visible(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = _state(jax.random.PRNGKey(3))
    mgr.save(1, state)
    assert not list(tmp_path.glob("*.tmp"))
    # manifest must parse and carry dtype info for the bf16 leaf
    man = json.loads(
        (tmp_path / "step_00000001" / "manifest.json").read_text())
    assert "bfloat16" in man["dtypes"]


def test_structure_mismatch_raises(tmp_path):
    import pytest
    mgr = CheckpointManager(tmp_path)
    state = _state(jax.random.PRNGKey(4))
    mgr.save(1, state)
    bad = {"only": jnp.zeros((2,))}
    with pytest.raises(AssertionError):
        mgr.restore(bad)
