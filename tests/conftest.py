"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single
real CPU device; only launch/dryrun.py forces 512 placeholder devices.
"""
import random

import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed_everything():
    random.seed(1234)
    np.random.seed(1234)


def pytest_addoption(parser):
    parser.addoption("--run-slow", action="store_true", default=False,
                     help="run slow end-to-end tests")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="slow; use --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
