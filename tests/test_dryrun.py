"""Dry-run machinery: artifact consistency + one real subprocess cell.

The full 512-device sweep runs via ``python -m repro.launch.dryrun``
(artifacts are committed under artifacts/dryrun); here we verify the
recorded artifacts are complete and self-consistent, and (slow) that
one cell lowers+compiles end-to-end in a fresh process with the forced
512-device platform.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.configs import ALL_SHAPES, ARCH_IDS, applicable, get_config

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"

pytestmark = pytest.mark.skipif(
    not ART.exists(), reason="run repro.launch.dryrun first")


def _load_all():
    return [json.loads(p.read_text()) for p in ART.glob("*.json")]


def test_every_cell_present_and_green():
    recs = _load_all()
    assert len(recs) == len(ARCH_IDS) * len(ALL_SHAPES) * 2  # 2 meshes
    by_status = {}
    for r in recs:
        by_status.setdefault(r["status"], []).append(r)
    assert "FAILED" not in by_status, by_status.get("FAILED")
    # exactly the documented long_500k skips
    skips = by_status.get("SKIPPED", [])
    assert all(r["shape"] == "long_500k" for r in skips)
    assert len(skips) == 16     # 8 full-attention archs x 2 meshes


def test_skips_match_applicability_rules():
    for r in _load_all():
        cfg = get_config(r["arch"])
        shape = next(s for s in ALL_SHAPES if s.name == r["shape"])
        ok, _ = applicable(cfg, shape)
        assert (r["status"] == "SKIPPED") == (not ok)


def test_roofline_terms_recorded_and_positive():
    for r in _load_all():
        if r["status"] != "OK":
            continue
        t = r["roofline"]
        assert t["compute_s"] > 0 and t["memory_s"] > 0
        assert t["dominant"] in ("compute", "memory", "collective")
        assert r["analytic"]["model_flops"] > 0
        assert r["hlo_raw"]["collectives"]["total"] > 0  # sharded!


def test_decode_cells_are_memory_bound():
    """Decode physics: every decode cell must be memory-dominated."""
    for r in _load_all():
        if r["status"] == "OK" and r["kind"] == "decode":
            assert r["roofline"]["dominant"] == "memory", \
                (r["arch"], r["shape"])


def test_serve_memory_fits_everywhere():
    for r in _load_all():
        if r["status"] == "OK" and r["kind"] != "train":
            assert r["memory"]["model_fits_16g_hbm"], \
                (r["arch"], r["shape"], r["mesh"])


@pytest.mark.slow
def test_one_cell_compiles_in_subprocess():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "stablelm-1.6b", "--shape", "decode_32k",
         "--mesh", "single", "--out", "/tmp/dryrun_test", "--force"],
        capture_output=True, text=True, timeout=540,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=Path(__file__).resolve().parent.parent)
    assert "1 ok, 0 skipped, 0 failed" in out.stdout, out.stdout[-2000:]
