"""`simulate_grid`: the Section-6 matrix as one vmapped dispatch.

Acceptance gate of the ensemble refactor: on a 3-load × 3-seed ×
7-policy grid every cell is decision-identical to the host event loop,
and the grid reproduces the paper's policy ordering (PE-Worst-Fit
highest acceptance, First-Fit lowest slowdown).
"""
import numpy as np
import pytest

from repro.core.types import ALL_POLICIES, Policy
from repro.sim import (
    GridSpec,
    WorkloadParams,
    generate_filtered,
    pad_streams,
    simulate_grid,
)

SMALL_SIZES = WorkloadParams(u_low=2.0, u_med=4.0, u_hi=6.0)


@pytest.fixture(scope="module")
def paper_grid():
    """3 loads × 3 seeds × 7 policies, cross-checked per cell against
    the host event loop (raises inside simulate_grid on divergence)."""
    spec = GridSpec(
        policies=ALL_POLICIES,
        arrival_factors=(1.0, 1.5, 2.0),
        seeds=(0, 1, 2),
        flex_factors=(3.0,),
        base=SMALL_SIZES,
        n_pe=64,
        n_jobs=150,
    )
    return simulate_grid(spec, capacity=64, cross_check=True,
                         record_decisions=True)


def test_grid_shape_and_counts(paper_grid):
    assert paper_grid.acceptance.shape == (7, 3, 3, 1)
    assert paper_grid.n_cells == 63
    assert (paper_grid.n_jobs > 0).all()
    assert (paper_grid.n_accepted <= paper_grid.n_jobs).all()
    # workloads are shared across policies: same job count per column
    assert (paper_grid.n_jobs == paper_grid.n_jobs[:1]).all()


def test_grid_reproduces_pe_worst_fit_highest_acceptance(paper_grid):
    """Paper headline: PE Worst Fit has the highest acceptance rate."""
    acc = paper_grid.policy_acceptance()
    best = max(acc.values())
    assert acc[Policy.PE_W.value] >= best - 0.01


def test_grid_reproduces_ff_lowest_slowdown(paper_grid):
    """Paper headline: First Fit has the lowest average slowdown."""
    sd = paper_grid.policy_slowdown()
    assert sd[Policy.FF.value] == min(sd.values())


def test_grid_acceptance_degrades_with_load(paper_grid):
    """Fig. 4 trend along the grid's load axis (mean over seeds)."""
    pe_w = list(paper_grid.policies).index(Policy.PE_W.value)
    by_load = np.nanmean(paper_grid.acceptance[pe_w], axis=(1, 2))
    assert by_load[0] > by_load[-1]


def test_grid_decisions_recorded(paper_grid):
    """record_decisions exposes per-cell (accepted, t_s) traces."""
    cell = paper_grid.decisions[0][0][0][0]      # FF, load 1.0, seed 0
    assert len(cell) == int(paper_grid.n_jobs[0, 0, 0, 0])
    assert all(isinstance(a, bool) and isinstance(t, int)
               for a, t in cell)


def test_pad_streams_masks_and_never_admits():
    """Unequal streams pad to fixed shape; padding requests are
    rejected by construction and masked out of the metrics."""
    a = generate_filtered(SMALL_SIZES.replace(n_jobs=40, n_pe=64),
                          max_pe=64)
    b = a[:17]
    batch, valid = pad_streams([a, b], 64)
    assert batch.t_a.shape == (2, len(a))
    assert valid.sum(axis=1).tolist() == [len(a), len(b)]
    # padded rows ask for more PEs than the machine has
    assert (np.asarray(batch.n_pe)[~valid] == 65).all()
    # padded arrivals never precede the stream's last real arrival
    assert (np.asarray(batch.t_a)[1, len(b):] >= b[-1].t_a).all()


def test_grid_flex_axis_raises_acceptance():
    """Fig. 6 trend: more flexibility -> higher acceptance (PE_W)."""
    r = simulate_grid(GridSpec(
        policies=(Policy.PE_W,),
        arrival_factors=(1.5,),
        seeds=(0, 1),
        flex_factors=(1.0, 5.0),
        base=SMALL_SIZES, n_pe=64, n_jobs=120), capacity=64)
    acc = np.nanmean(r.acceptance[0, 0], axis=0)     # [F]
    assert acc[1] > acc[0]


def test_grid_kernel_path_matches_dense():
    """use_kernel threads the Pallas contraction through the whole
    grid; metrics and decisions must be identical."""
    spec = GridSpec(policies=(Policy.PE_W, Policy.FF),
                    arrival_factors=(1.0,), seeds=(0,),
                    flex_factors=(3.0,), base=SMALL_SIZES,
                    n_pe=32, n_jobs=40)
    dense = simulate_grid(spec, capacity=64, record_decisions=True)
    kern = simulate_grid(spec, capacity=64, record_decisions=True,
                         use_kernel=True)
    np.testing.assert_array_equal(dense.n_accepted, kern.n_accepted)
    assert dense.decisions == kern.decisions


def test_grid_cell_overflow_grows_collectively():
    """With a tiny shared initial capacity the busier cells overflow
    mid-scan; the grow-once re-run keeps every cell host-identical
    (cross_check raises on the first divergence)."""
    spec = GridSpec(policies=(Policy.FF, Policy.PE_W),
                    arrival_factors=(1.0,), seeds=(0,),
                    flex_factors=(3.0,), base=SMALL_SIZES,
                    n_pe=64, n_jobs=60)
    r = simulate_grid(spec, capacity=8, pending_capacity=4,
                      cross_check=True)
    assert (r.n_accepted > 0).all()
