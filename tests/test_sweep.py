"""`simulate_grid`: the Section-6 matrix as one vmapped dispatch.

Acceptance gates of the ensemble refactor and the backfill axis: on a
3-load × 3-seed × 7-policy grid every cell is decision-identical to the
host event loop, the grid reproduces the paper's policy ordering
(PE-Worst-Fit highest acceptance, First-Fit lowest slowdown), and the
policy × backfill matrix runs as *one* dispatch whose backfilling modes
dominate ``none`` on acceptance (conservative bit-identically equal).
"""
import warnings

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import batch as batch_lib
from repro.core import timeline as tl_lib
from repro.core.types import ALL_POLICIES, ARRequest, Policy
from repro.sim import (
    GridSpec,
    WorkloadParams,
    generate_filtered,
    pad_streams,
    simulate_grid,
)
from repro.sim.metrics import GridResult, grid_reductions, nanmean_safe

SMALL_SIZES = WorkloadParams(u_low=2.0, u_med=4.0, u_hi=6.0)
# the backfill claims grid: small machine + relatively wide jobs, so
# fragmentation gives the EASY displacement real holes to fill (the
# regime is pinned — decisions are deterministic per seed)
BACKFILL_SIZES = WorkloadParams(u_low=2.0, u_med=3.0, u_hi=4.0)


@pytest.fixture(scope="module")
def paper_grid():
    """3 loads × 3 seeds × 7 policies, cross-checked per cell against
    the host event loop (raises inside simulate_grid on divergence)."""
    spec = GridSpec(
        policies=ALL_POLICIES,
        arrival_factors=(1.0, 1.5, 2.0),
        seeds=(0, 1, 2),
        flex_factors=(3.0,),
        base=SMALL_SIZES,
        n_pe=64,
        n_jobs=150,
    )
    return simulate_grid(spec, capacity=64, cross_check=True,
                         record_decisions=True)


@pytest.fixture(scope="module")
def backfill_grid():
    """7 policies × {none, easy, conservative} in one dispatch."""
    spec = GridSpec(
        policies=ALL_POLICIES,
        arrival_factors=(2.5,),
        seeds=(3, 5),
        flex_factors=(3.0,),
        backfill_modes=("none", "easy", "conservative"),
        base=BACKFILL_SIZES,
        n_pe=16,
        n_jobs=120,
        park_capacity=8,
    )
    return simulate_grid(spec, capacity=64, record_decisions=True)


def test_grid_shape_and_counts(paper_grid):
    assert paper_grid.acceptance.shape == (7, 1, 3, 3, 1)
    assert paper_grid.n_cells == 63
    assert paper_grid.backfill_modes == ("none",)
    assert (paper_grid.n_jobs > 0).all()
    assert (paper_grid.n_accepted <= paper_grid.n_jobs).all()
    # workloads are shared across policies: same job count per column
    assert (paper_grid.n_jobs == paper_grid.n_jobs[:1]).all()


def test_grid_reproduces_pe_worst_fit_highest_acceptance(paper_grid):
    """Paper headline: PE Worst Fit has the highest acceptance rate."""
    acc = paper_grid.policy_acceptance()
    best = max(acc.values())
    assert acc[Policy.PE_W.value] >= best - 0.01


def test_grid_reproduces_ff_lowest_slowdown(paper_grid):
    """Paper headline: First Fit has the lowest average slowdown."""
    sd = paper_grid.policy_slowdown()
    assert sd[Policy.FF.value] == min(sd.values())


def test_grid_acceptance_degrades_with_load(paper_grid):
    """Fig. 4 trend along the grid's load axis (mean over seeds)."""
    pe_w = list(paper_grid.policies).index(Policy.PE_W.value)
    by_load = np.nanmean(paper_grid.acceptance[pe_w, 0], axis=(1, 2))
    assert by_load[0] > by_load[-1]


def test_grid_decisions_recorded(paper_grid):
    """record_decisions exposes per-cell (accepted, t_s) traces."""
    cell = paper_grid.decisions[0][0][0][0][0]   # FF, none, 1.0, s0
    assert len(cell) == int(paper_grid.n_jobs[0, 0, 0, 0, 0])
    assert all(isinstance(a, bool) and isinstance(t, int)
               for a, t in cell)


def test_pad_streams_masks_and_never_admits():
    """Unequal streams pad to fixed shape; padding requests are
    rejected by construction and masked out of the metrics."""
    a = generate_filtered(SMALL_SIZES.replace(n_jobs=40, n_pe=64),
                          max_pe=64)
    b = a[:17]
    batch, valid = pad_streams([a, b], 64)
    assert batch.t_a.shape == (2, len(a))
    assert valid.sum(axis=1).tolist() == [len(a), len(b)]
    # padded rows ask for more PEs than the machine has
    assert (np.asarray(batch.n_pe)[~valid] == 65).all()
    # padded arrivals never precede the stream's last real arrival
    assert (np.asarray(batch.t_a)[1, len(b):] >= b[-1].t_a).all()


def test_grid_flex_axis_raises_acceptance():
    """Fig. 6 trend: more flexibility -> higher acceptance (PE_W)."""
    r = simulate_grid(GridSpec(
        policies=(Policy.PE_W,),
        arrival_factors=(1.5,),
        seeds=(0, 1),
        flex_factors=(1.0, 5.0),
        base=SMALL_SIZES, n_pe=64, n_jobs=120), capacity=64)
    acc = np.nanmean(r.acceptance[0, 0, 0], axis=0)     # [F]
    assert acc[1] > acc[0]


def test_grid_kernel_path_matches_dense():
    """use_kernel threads the Pallas contraction through the whole
    grid; metrics and decisions must be identical."""
    spec = GridSpec(policies=(Policy.PE_W, Policy.FF),
                    arrival_factors=(1.0,), seeds=(0,),
                    flex_factors=(3.0,), base=SMALL_SIZES,
                    n_pe=32, n_jobs=40)
    dense = simulate_grid(spec, capacity=64, record_decisions=True)
    kern = simulate_grid(spec, capacity=64, record_decisions=True,
                         use_kernel=True)
    np.testing.assert_array_equal(dense.n_accepted, kern.n_accepted)
    assert dense.decisions == kern.decisions


def test_grid_cell_overflow_grows_collectively():
    """With a tiny shared initial capacity the busier cells overflow
    mid-scan; the grow-once re-run keeps every cell host-identical
    (cross_check raises on the first divergence)."""
    spec = GridSpec(policies=(Policy.FF, Policy.PE_W),
                    arrival_factors=(1.0,), seeds=(0,),
                    flex_factors=(3.0,), base=SMALL_SIZES,
                    n_pe=64, n_jobs=60)
    r = simulate_grid(spec, capacity=8, pending_capacity=4,
                      cross_check=True)
    assert (r.n_accepted > 0).all()


# ---------------------------------------------------------------------------
# the backfill axis (DESIGN.md §6)
# ---------------------------------------------------------------------------


def test_backfill_grid_modes_dominate_none(backfill_grid):
    """Paper-claims extension: on the policy × backfill matrix, every
    policy accepts strictly more under EASY and exactly as much under
    conservative (decision-identity, asserted on the raw arrays)."""
    acc = backfill_grid.mode_policy_acceptance()
    for p in backfill_grid.policies:
        assert acc["easy"][p] > acc["none"][p], p
        assert acc["conservative"][p] == acc["none"][p], p
    # conservative is bit-identical to none, cell by cell
    b = {m: i for i, m in enumerate(backfill_grid.backfill_modes)}
    np.testing.assert_array_equal(
        backfill_grid.acceptance[:, b["conservative"]],
        backfill_grid.acceptance[:, b["none"]])
    np.testing.assert_array_equal(
        backfill_grid.slowdown[:, b["conservative"]],
        backfill_grid.slowdown[:, b["none"]])
    assert backfill_grid.decisions[0][b["conservative"]] == \
        backfill_grid.decisions[0][b["none"]]


def test_backfill_grid_keeps_policy_orderings(backfill_grid):
    """PE-Worst-Fit stays best-acceptance and First-Fit stays
    lowest-slowdown within every backfill mode."""
    acc = backfill_grid.mode_policy_acceptance()
    sd = backfill_grid.mode_policy_slowdown()
    for m in backfill_grid.backfill_modes:
        assert acc[m][Policy.PE_W.value] >= max(acc[m].values()) - 0.01
        assert sd[m][Policy.FF.value] == min(sd[m].values())


def test_backfill_grid_single_dispatch_no_per_mode_recompile():
    """The policy × backfill matrix is one vmapped dispatch (the mode
    is traced): permuting the mode assignment compiles nothing new."""
    spec = GridSpec(
        policies=(Policy.PE_W, Policy.FF),
        arrival_factors=(2.0,), seeds=(3,), flex_factors=(3.0,),
        backfill_modes=("none", "easy", "conservative"),
        base=BACKFILL_SIZES, n_pe=16, n_jobs=40, park_capacity=4)
    from repro.core import ensemble as ens_lib

    r1 = simulate_grid(spec, capacity=64)
    warm = ens_lib.admit_stream_ensemble._cache_size()
    r2 = simulate_grid(spec, capacity=64, backfill_modes=(
        "easy", "conservative", "none"))
    assert ens_lib.admit_stream_ensemble._cache_size() == warm, \
        "permuting the backfill-mode assignment recompiled the scan"
    # same cells, permuted axis: identical per-mode metrics
    for m in ("none", "easy", "conservative"):
        a1 = r1.acceptance[:, r1.backfill_modes.index(m)]
        a2 = r2.acceptance[:, r2.backfill_modes.index(m)]
        np.testing.assert_array_equal(a1, a2)


def test_backfill_grid_cross_check_against_host_oracle():
    """Differential gate at the grid level: every (policy, mode) cell
    is decision-identical to its host oracle (the event loop for
    ``none``, the BackfillOracle otherwise)."""
    spec = GridSpec(
        policies=(Policy.PE_W, Policy.DU_B, Policy.FF),
        arrival_factors=(2.0,), seeds=(3,), flex_factors=(3.0,),
        backfill_modes=("none", "easy", "conservative"),
        base=BACKFILL_SIZES, n_pe=16, n_jobs=60, park_capacity=4)
    r = simulate_grid(spec, capacity=64, cross_check=True)
    assert (r.n_accepted > 0).all()


# ---------------------------------------------------------------------------
# NaN-safe metric reductions (zero-acceptance regression)
# ---------------------------------------------------------------------------


def test_zero_acceptance_cell_is_nan_safe():
    """A cell accepting no jobs must reduce to NaN slowdown without
    dividing by zero or tripping numpy's all-NaN warnings."""
    n_pe = 8
    # every request asks for more PEs than the machine has: all reject
    jobs = [ARRequest(t_a=i, t_r=i, t_du=10, t_dl=i + 100, n_pe=16)
            for i in range(5)]
    state = tl_lib.init_state(16, n_pe, 8)
    batch = batch_lib.requests_to_batch(jobs)
    _, dec = batch_lib.admit_stream_grow(
        state, batch, Policy.PE_W, n_pe=n_pe)
    stacked = batch_lib.Decision(*[jnp.asarray(f)[None] for f in dec])
    sb = batch_lib.RequestBatch(
        *[jnp.asarray(getattr(batch, f))[None]
          for f in batch_lib.REQ_FIELDS])
    valid = np.ones((1, len(jobs)), bool)
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # any warning fails
        n_acc, n_val, rate, slowdown, util = grid_reductions(
            stacked, sb, valid, n_pe)
        assert n_acc.tolist() == [0]
        assert rate.tolist() == [0.0]
        assert np.isnan(slowdown).all()
        r = GridResult(
            policies=("PE_W",), arrival_factors=(1.0,), seeds=(0,),
            flex_factors=(3.0,), backfill_modes=("none",),
            acceptance=rate.reshape(1, 1, 1, 1, 1),
            slowdown=slowdown.reshape(1, 1, 1, 1, 1),
            utilization=util.reshape(1, 1, 1, 1, 1),
            n_jobs=n_val.reshape(1, 1, 1, 1, 1).astype(int),
            n_accepted=n_acc.reshape(1, 1, 1, 1, 1).astype(int))
        assert np.isnan(r.policy_slowdown()["PE_W"])
        assert np.isnan(r.mode_policy_slowdown()["none"]["PE_W"])
        assert r.policy_acceptance()["PE_W"] == 0.0
        assert "PE_W" in r.summary()
    # an all-padding cell additionally has NaN utilization
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        _, _, _, _, util = grid_reductions(
            stacked, sb, np.zeros((1, len(jobs)), bool), n_pe)
        assert np.isnan(util).all()
    assert np.isnan(nanmean_safe([np.nan, np.nan]))
    assert nanmean_safe([1.0, np.nan]) == 1.0
