"""Per-architecture smoke tests: REDUCED configs of the same family,
one forward/train step + prefill/decode on CPU, asserting output
shapes and no NaNs (the FULL configs are exercised via the dry-run).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as tf_lib
from repro.models.common import count_params


def _batch(cfg, B=2, T=32):
    batch = {"tokens": jnp.full((B, T), 3, jnp.int32),
             "labels": jnp.ones((B, T), jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.full(
            (B, cfg.enc_seq, cfg.d_model), 0.1, jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.full(
            (B, cfg.vision_tokens, cfg.vision_dim), 0.1, jnp.float32)
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch, key):
    cfg = get_config(arch).reduced()
    params = tf_lib.init_params(cfg, key)
    assert count_params(params) > 0
    B, T = 2, 32
    batch = _batch(cfg, B, T)
    loss, metrics = jax.jit(
        lambda p, b: tf_lib.loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    extra = {k: v for k, v in batch.items()
             if k not in ("tokens", "labels")}
    logits, cache = jax.jit(
        lambda p, t: tf_lib.prefill(p, cfg, t, extra,
                                    max_len=T + 4))(
        params, batch["tokens"])
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits))), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, cache = jax.jit(
        lambda p, c, t: tf_lib.decode_step(p, cfg, c, t, extra))(
        params, cache, tok)
    assert logits2.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2))), arch
    assert int(cache["pos"]) == T + 1


@pytest.mark.parametrize("arch", ["qwen3-4b", "granite-moe-1b-a400m",
                                  "zamba2-7b", "xlstm-1.3b"])
def test_full_config_param_counts(arch):
    """Exact configs match their published scale (eval_shape only)."""
    from repro.roofline.analysis import param_count
    cfg = get_config(arch)
    total, active = param_count(cfg)
    expected = {"qwen3-4b": (4e9, 0.6), "granite-moe-1b-a400m": (1.3e9, 0.5),
                "zamba2-7b": (7e9, 0.5), "xlstm-1.3b": (1.3e9, 0.5)}
    target, tol = expected[arch]
    assert abs(total - target) / target < tol, (arch, total)
    assert active <= total


def test_exact_config_values():
    """Spot-check the assigned table figures are encoded exactly."""
    kimi = get_config("kimi-k2-1t-a32b")
    assert (kimi.n_layers, kimi.d_model, kimi.n_heads,
            kimi.n_kv_heads) == (61, 7168, 64, 8)
    assert (kimi.n_experts, kimi.top_k, kimi.vocab) == (384, 8, 163840)
    sc = get_config("starcoder2-7b")
    assert (sc.n_layers, sc.d_model, sc.n_heads, sc.n_kv_heads,
            sc.d_ff, sc.vocab) == (32, 4608, 36, 4, 18432, 49152)
    zam = get_config("zamba2-7b")
    assert (zam.n_layers, zam.d_model, zam.ssm_state) == (81, 3584, 64)
    sm = get_config("seamless-m4t-medium")
    assert (sm.n_enc_layers, sm.n_layers, sm.vocab) == (12, 12, 256206)
