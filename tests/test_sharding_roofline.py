"""Sharding rules + roofline accounting.

``test_analytic_flops_vs_hlo``: the analytic cost model is validated
against ``compiled.cost_analysis()`` on a loop-free lowering (layers
unrolled, short sequence, full attention) — the regime where XLA's HLO
FLOP count is trustworthy (see EXPERIMENTS.md §Methodology).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ShapeConfig
from repro.models import transformer as tf_lib
from repro.roofline import analysis as roof
from repro.sharding import rules


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisibility(arch):
    """Every emitted spec must divide its dimension by 16 (the model
    axis) — the rule's own fallback guarantees it."""
    cfg = get_config(arch)
    params_s = jax.eval_shape(
        lambda k: tf_lib.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = rules.param_specs(params_s)
    leaves = jax.tree.leaves(params_s)
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    n_sharded = 0
    for leaf, spec in zip(leaves, spec_leaves):
        for i, ax in enumerate(spec):
            if ax == "model":
                assert leaf.shape[i] % rules.MODEL_AXIS_SIZE == 0, \
                    (arch, leaf.shape, spec)
                n_sharded += 1
    assert n_sharded > 0, f"{arch}: nothing sharded at all"


def test_big_weights_are_sharded_for_dense():
    cfg = get_config("minitron-8b")
    params_s = jax.eval_shape(
        lambda k: tf_lib.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = rules.param_specs(params_s)
    assert specs["layers"]["mlp"]["w_gate"] == P(None, None, "model")
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model", None)
    assert specs["tok_embed"] == P("model", None)


def test_moe_experts_sharded():
    cfg = get_config("kimi-k2-1t-a32b")
    params_s = jax.eval_shape(
        lambda k: tf_lib.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = rules.param_specs(params_s)
    # 2D expert sharding: experts over model, FFN dim over data axes
    assert specs["layers"]["moe"]["w_gate"] == P(
        None, "model", None, ("pod", "data"))
    assert specs["layers"]["moe"]["w_down"] == P(
        None, "model", ("pod", "data"), None)


def test_indivisible_heads_fall_back_to_replication():
    """starcoder2's 36 heads do not divide the 16-way model axis: the
    rules must emit replicated specs rather than invalid shardings."""
    cfg = get_config("starcoder2-7b")
    params_s = jax.eval_shape(
        lambda k: tf_lib.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = rules.param_specs(params_s)
    assert specs["layers"]["attn"]["wq"] == P(None, None, None, None)
    # but the MLP still shards (18432 % 16 == 0)
    assert specs["layers"]["mlp"]["w_gate"] == P(None, None, "model")


# ---------------------------------------------------------------------------
# roofline accounting
# ---------------------------------------------------------------------------

def test_parse_collectives():
    hlo = """
  %ag = bf16[16,128]{1,0} all-gather(bf16[1,128]{1,0} %x), dims={0}
  %ar.1 = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %rs = (f32[8]{0}) reduce-scatter(f32[64]{0} %z), dimensions={0}
    """
    got = roof.parse_collectives(hlo)
    assert got["all-gather"] == 16 * 128 * 2
    assert got["all-reduce"] == 256 * 4
    assert got["total"] > 0


def test_model_flops_identity_dense():
    """Train FLOPs ~ 6*N*D within 25% for a dense LM at short ctx
    (attention adds the rest)."""
    cfg = get_config("stablelm-1.6b")
    shape = ShapeConfig("t", 512, 8, "train")
    c = roof.step_costs(cfg, shape, {"data": 1, "model": 1})
    # >1 is possible: 6*N*D counts the input-embedding gather as a
    # matmul, which the executed program never performs.
    assert 0.7 < c.model_flops / c.flops < 1.25


def test_analytic_flops_vs_hlo():
    """Loop-free lowering: analytic forward FLOPs within 15% of XLA."""
    cfg = dataclasses.replace(
        get_config("stablelm-1.6b").reduced(), n_layers=2)
    params = tf_lib.init_params(cfg, jax.random.PRNGKey(0))
    B, T = 4, 256

    def fwd(p, tokens):
        # unrolled layers: python loop instead of scan
        x = p["tok_embed"][tokens]
        from repro.models import attention as attn_lib
        from repro.models import mlp as mlp_lib
        from repro.models.common import rms_norm
        rope = attn_lib.make_rope(cfg, T)
        for i in range(cfg.n_layers):
            pl = jax.tree.map(lambda a: a[i], p["layers"])
            h = attn_lib.self_attention(
                pl["attn"], rms_norm(x, pl["ln1"], cfg.norm_eps), cfg,
                rope)
            x = x + h
            x = x + mlp_lib.mlp(
                pl["mlp"], rms_norm(x, pl["ln2"], cfg.norm_eps))
        x = rms_norm(x, p["final_norm"], cfg.norm_eps)
        return jnp.einsum("btd,dv->btv", x, p["lm_head"])

    tokens = jnp.zeros((B, T), jnp.int32)
    compiled = jax.jit(fwd).lower(params, tokens).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # pre-0.4.27 JAX: one dict per device
        ca = ca[0]
    hlo_flops = ca["flops"]
    analytic = roof.forward_flops(cfg, B * T, T, "train")
    assert abs(analytic - hlo_flops) / hlo_flops < 0.15, \
        (analytic, hlo_flops)


def test_param_count_against_init():
    """Analytic parameter counts match the real init trees."""
    from repro.models.common import count_params
    for arch in ("stablelm-1.6b", "granite-moe-1b-a400m"):
        cfg = get_config(arch).reduced()
        params = tf_lib.init_params(cfg, jax.random.PRNGKey(0))
        total, _ = roof.param_count(cfg)
        real = count_params(params)
        assert abs(total - real) / real < 0.05, (arch, total, real)


def test_terms_dominance():
    c = roof.Costs(flops=1e18, hbm_bytes=1e12, coll_intra_bytes=1e10,
                   model_flops=9e17)
    t = c.terms(256)
    assert t["dominant"] == "compute"
    assert 0 < t["useful_ratio"] <= 1
