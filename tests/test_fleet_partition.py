"""Partitioned fleet: E cluster timelines behind one vmapped state.

Covers the routing layer (round-robin, least-loaded, best-acceptance
probes), decision identity of the bulk vmapped path with per-partition
sequential admission, cross-partition invariants (no double booking,
no allocation spanning partitions), and the fault-tolerance paths on a
partitioned core.
"""
import pytest

from repro.runtime import FleetScheduler, JobState, PartitionedCore

SPEC = dict(arch="qwen3-4b", shape="train_4k", n_chips=64, n_steps=200)


def _partition_of(fleet, job):
    return job.chips[0] // fleet.core.chips_per_part


def test_round_robin_spreads_across_partitions():
    f = FleetScheduler(n_chips=256, n_partitions=4)
    jobs = f.submit_batch([dict(SPEC) for _ in range(8)])
    assert all(j.state == JobState.RESERVED for j in jobs)
    assert [j.partition for j in jobs] == [0, 1, 2, 3, 0, 1, 2, 3]
    # no allocation spans a partition boundary
    for j in jobs:
        assert len({c // 64 for c in j.chips}) == 1
    # completions release through advance()
    f.advance(max(j.t_end for j in jobs) + 1)
    assert f.core.records() == []


def test_round_robin_lane_matches_single_cluster():
    """Each partition's stream admits exactly as a standalone fleet of
    the partition size would — the ensemble is E independent cores."""
    f = FleetScheduler(n_chips=256, n_partitions=4)
    jobs = f.submit_batch([dict(SPEC) for _ in range(8)])
    solo = FleetScheduler(n_chips=64, engine="device")
    solo_jobs = [solo.submit(**SPEC) for _ in range(2)]
    lane0 = [j for j in jobs if j.partition == 0]
    for mine, ref in zip(lane0, solo_jobs):
        assert mine.state == ref.state
        assert (mine.t_start, mine.t_end) == (ref.t_start, ref.t_end)
        assert tuple(c % 64 for c in mine.chips) == ref.chips


def test_least_loaded_routes_to_idle_partition():
    f = FleetScheduler(n_chips=256, n_partitions=4,
                       routing="least_loaded")
    # preload partition 0 with a long reservation
    f.core.add_allocation(0, 10_000_000, list(range(64)))
    jobs = f.submit_batch([dict(SPEC) for _ in range(3)])
    assert all(j.state == JobState.RESERVED for j in jobs)
    assert all(j.partition != 0 for j in jobs)
    assert len({j.partition for j in jobs}) == 3


def test_best_acceptance_probe_avoids_saturated_partition():
    f = FleetScheduler(n_chips=128, n_partitions=2,
                       routing="best_acceptance")
    # partition 0 busy for a long while: probes must land on 1
    f.core.add_allocation(0, 10_000_000, list(range(64)))
    jobs = f.submit_batch([dict(SPEC) for _ in range(2)])
    assert all(j.state == JobState.RESERVED for j in jobs)
    assert all(j.partition == 1 for j in jobs)
    # the probe searches all partitions in one dispatch; the second
    # job queues behind the first on partition 1
    assert jobs[1].t_start >= jobs[0].t_end


def test_job_wider_than_partition_rejected():
    f = FleetScheduler(n_chips=256, n_partitions=4)
    j = f.submit("qwen3-4b", "train_4k", 128, n_steps=100)
    assert j.state == JobState.REJECTED


def test_partitioned_fault_tolerance_paths():
    f = FleetScheduler(n_chips=256, n_partitions=4)
    j = f.submit(**SPEC)
    assert j.state == JobState.RESERVED
    f.advance(j.t_start + 100)
    failed = j.chips[0]
    migrated = f.fail_chip(failed)
    assert j.job_id in migrated
    assert failed not in j.chips
    assert j.preemptions == 1
    # repair reservation holds the failed chip
    busy_now = set()
    for t, b in f.core.records():
        if t <= f.now:
            busy_now = b
    assert failed in busy_now
    assert f.report_straggler(j.job_id, slowdown=1.3)
    assert f.rescale(j.job_id, 32)
    assert j.n_chips == 32
    assert j.partition == _partition_of(f, j)


def test_no_double_booking_across_partitions():
    f = FleetScheduler(n_chips=128, n_partitions=2)
    jobs = f.submit_batch([dict(SPEC) for _ in range(6)])
    seen = {}
    for j in jobs:
        if j.state != JobState.RESERVED:
            continue
        for c in j.chips:
            for (t0, t1) in seen.get(c, []):
                assert j.t_end <= t0 or j.t_start >= t1, \
                    f"chip {c} double-booked"
            seen.setdefault(c, []).append((j.t_start, j.t_end))
    for t, busy in f.core.records():
        assert len(busy) <= f.n_chips


def test_partitioned_core_validates_arguments():
    with pytest.raises(ValueError):
        PartitionedCore(100, 3)           # not divisible
    core = PartitionedCore(128, 2)
    with pytest.raises(ValueError):
        core.add_allocation(0, 10, [63, 64])    # spans partitions
    with pytest.raises(ValueError):
        core.route([], "nearest")          # unknown routing
    # best_acceptance routes now return the probe preview (PR 7);
    # the pre-PR 7 ValueError contract survives behind a deprecated
    # flag for callers that relied on it
    assert core.route([], "best_acceptance") == []
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError):
            core.route([], "best_acceptance", legacy_raise=True)
    with pytest.raises(ValueError):
        # a partitioned fleet is always device-backed
        FleetScheduler(n_chips=128, n_partitions=2, engine="host")


def test_partitioned_records_merge_lanes():
    core = PartitionedCore(128, 2)
    core.add_allocation(0, 100, [0, 1])          # lane 0
    core.add_allocation(50, 150, [64, 65])       # lane 1
    recs = core.records()
    assert recs[0] == (0, frozenset({0, 1}))
    assert (50, frozenset({0, 1, 64, 65})) in recs
    assert recs[-1] == (150, frozenset())
    core.delete_allocation(0, 100, [0, 1])
    core.delete_allocation(50, 150, [64, 65])
    assert core.records() == []
