"""Engine equivalence: literal list oracle vs numpy host vs JAX device.

The paper's worked example (Figure 1 / Section 4.2) is asserted exactly
on every engine, then a randomized workload checks that all three
engines make bit-identical decisions under every policy.
"""
import random

import pytest

from repro.core.hostsched import HostScheduler
from repro.core.listsched import ListScheduler
from repro.core.scheduler import DeviceScheduler, make_scheduler
from repro.core.types import ALL_POLICIES, ARRequest, Policy, T_INF


def _engines(n_pe, capacity=64):
    return [ListScheduler(n_pe), HostScheduler(n_pe),
            DeviceScheduler(n_pe, capacity=capacity)]


def _pes(engine, ids):
    return set(ids) if isinstance(engine, ListScheduler) else list(ids)


def _setup_paper_example(sched):
    """N=100; job1: 20 PEs [0,300); job2: 30 PEs [0,100);
    job3 (reserved): 25 PEs [800,1000)."""
    sched.add_allocation(0, 300, _pes(sched, range(0, 20)))
    sched.add_allocation(0, 100, _pes(sched, range(20, 50)))
    sched.add_allocation(800, 1000, _pes(sched, range(0, 25)))


@pytest.mark.parametrize("engine", ["list", "host", "device"])
class TestPaperExample:
    def test_records_match_paper(self, engine):
        s = make_scheduler(100, engine=engine)
        _setup_paper_example(s)
        recs = [(t, len(b)) for t, b in s.records()]
        # {t0,n1+n2}, {t1,n1}, {t3,empty->merged}, {t8,n3}, {t10,empty}
        assert recs == [(0, 50), (100, 20), (300, 0), (800, 25),
                        (1000, 0)]

    def test_candidate_starts(self, engine):
        s = make_scheduler(100, engine=engine)
        _setup_paper_example(s)
        req = ARRequest(t_a=0, t_r=200, t_du=200, t_dl=900, n_pe=40)
        if engine == "device":
            pytest.skip("device engine enumerates internally")
        # paper: t2, t3, t6, t7 (= 200, 300, 600, 700)
        assert sorted(int(t) for t in s.candidate_starts(req)) == [
            200, 300, 600, 700]

    def test_ff_picks_earliest(self, engine):
        s = make_scheduler(100, engine=engine)
        _setup_paper_example(s)
        req = ARRequest(t_a=0, t_r=200, t_du=200, t_dl=900, n_pe=40)
        alloc = s.find_allocation(req, Policy.FF)
        assert alloc.t_s == 200
        assert alloc.rectangle.n_free == 80       # N - n1
        assert (alloc.rectangle.t_begin,
                alloc.rectangle.t_end) == (100, 800)   # [t1, t8)

    def test_pe_worst_fit_picks_t3(self, engine):
        """Paper: 'Assume policy is PE Worst Fit ... t3 is chosen'."""
        s = make_scheduler(100, engine=engine)
        _setup_paper_example(s)
        req = ARRequest(t_a=0, t_r=200, t_du=200, t_dl=900, n_pe=40)
        alloc = s.find_allocation(req, Policy.PE_W)
        assert alloc.t_s == 300
        assert alloc.rectangle.n_free == 100
        # earliest-start tiebreak: t3 chosen over t6 (same rectangle)
        a2 = s.find_allocation(req, Policy.DU_B)
        assert a2.t_s == 300

    def test_add_then_delete_restores(self, engine):
        s = make_scheduler(100, engine=engine)
        _setup_paper_example(s)
        before = s.records()
        s.add_allocation(300, 500, _pes(s, range(50, 90)))
        assert s.records() != before
        s.delete_allocation(300, 500, _pes(s, range(50, 90)))
        assert s.records() == before

    def test_infeasible_returns_none(self, engine):
        s = make_scheduler(100, engine=engine)
        _setup_paper_example(s)
        req = ARRequest(t_a=0, t_r=0, t_du=250, t_dl=260, n_pe=90)
        assert s.find_allocation(req, Policy.FF) is None


def test_randomized_three_engine_equivalence():
    random.seed(7)
    n_pe = 53
    engines = _engines(n_pe)
    active, t_now, accepted = [], 0, 0
    for step in range(250):
        t_now += random.randint(0, 4)
        for job in [j for j in active if j[1] <= t_now]:
            for e in engines:
                e.delete_allocation(job[0], job[1], _pes(e, job[2]))
            active.remove(job)
        du = random.randint(1, 25)
        tr = t_now + random.randint(0, 8)
        req = ARRequest(t_a=t_now, t_r=tr, t_du=du,
                        t_dl=tr + du + random.randint(0, 40),
                        n_pe=random.randint(1, n_pe))
        pol = random.choice(list(ALL_POLICIES))
        allocs = [e.find_allocation(req, pol, t_now=t_now)
                  for e in engines]
        assert len({a is None for a in allocs}) == 1, (step, pol)
        if allocs[0] is not None:
            a0 = allocs[0]
            for a in allocs[1:]:
                assert (a.t_s, a.pe_ids) == (a0.t_s, a0.pe_ids)
                assert a.rectangle == a0.rectangle
            for e in engines:
                e.add_allocation(a0.t_s, a0.t_e, _pes(e, a0.pe_ids))
            active.append((a0.t_s, a0.t_e, a0.pe_ids))
            accepted += 1
        r0 = engines[0].records()
        assert engines[1].records() == r0 == engines[2].records()
    assert accepted > 50   # the test actually exercised allocations


def test_double_booking_raises():
    for engine in ("list", "host"):
        s = make_scheduler(10, engine=engine)
        s.add_allocation(0, 10, _pes(s, [0, 1]))
        with pytest.raises(ValueError):
            s.add_allocation(5, 15, _pes(s, [1, 2]))


def test_unbounded_rectangle_uses_t_inf():
    s = make_scheduler(10, engine="host")
    req = ARRequest(t_a=0, t_r=5, t_du=10, t_dl=100, n_pe=4)
    alloc = s.find_allocation(req, Policy.FF)
    assert alloc.t_s == 5
    assert alloc.rectangle.t_end == T_INF
    assert alloc.rectangle.n_free == 10
