"""Ensemble-axis semantics (DESIGN.md §4).

The contract: a vmapped ensemble run is *decision-identical* to E
independent single-state runs — for every policy, for mixed policies
across lanes, through the fused single step and the scanned stream,
and through collective capacity growth when one lane overflows
mid-scan while its neighbours do not.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import batch as batch_lib
from repro.core import ensemble as ens_lib
from repro.core import timeline as tl_lib
from repro.core.types import ALL_POLICIES, ARRequest, Policy

N_PE = 16


def _stream(seed, n=25, n_pe=N_PE, pile=False):
    """Arrival-ordered random stream; ``pile=True`` keeps every
    reservation live at once (forces record/pending overflow)."""
    if pile:
        return [ARRequest(t_a=i, t_r=i, t_du=5000, t_dl=i + 5000,
                          n_pe=1) for i in range(n)]
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.integers(0, 25, n))
    jobs = []
    for i in range(n):
        du = int(rng.integers(5, 60))
        tr = int(t[i] + rng.integers(0, 30))
        jobs.append(ARRequest(
            t_a=int(t[i]), t_r=tr, t_du=du,
            t_dl=tr + du + int(rng.integers(0, 120)),
            n_pe=int(rng.integers(1, n_pe + 1))))
    return jobs


def _stack(streams):
    return batch_lib.RequestBatch(*[
        jnp.stack([getattr(batch_lib.requests_to_batch(s), f)
                   for s in streams])
        for f in batch_lib.REQ_FIELDS])


def _independent(stream, policy, capacity=64, pending=32):
    state = tl_lib.init_state(capacity, N_PE, pending)
    return batch_lib.admit_stream_auto(
        state, batch_lib.requests_to_batch(stream), policy, n_pe=N_PE)


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_ensemble_stream_matches_independent_runs(policy):
    """E lanes under one policy == E separate ``admit_stream_auto``."""
    streams = [_stream(s) for s in range(4)]
    states = ens_lib.init_ensemble(4, 64, N_PE, 32)
    out, dec = ens_lib.admit_stream_ensemble_auto(
        states, _stack(streams), [policy] * 4, n_pe=N_PE)
    for i, stream in enumerate(streams):
        ref_state, ref = _independent(stream, policy)
        np.testing.assert_array_equal(
            np.asarray(ref.accepted), np.asarray(dec.accepted)[i])
        np.testing.assert_array_equal(
            np.asarray(ref.t_s), np.asarray(dec.t_s)[i])
        np.testing.assert_array_equal(
            np.asarray(ref.pe_mask), np.asarray(dec.pe_mask)[i])
        assert int(ref_state.n_accepted) == int(out.n_accepted[i])


def test_ensemble_mixed_policies_one_dispatch():
    """policy_id is traced per lane: all seven policies run on the
    same workload in a single vmapped dispatch."""
    stream = _stream(42)
    E = len(ALL_POLICIES)
    states = ens_lib.init_ensemble(E, 64, N_PE, 32)
    out, dec = ens_lib.admit_stream_ensemble_auto(
        states, _stack([stream] * E), list(ALL_POLICIES), n_pe=N_PE)
    for i, policy in enumerate(ALL_POLICIES):
        _, ref = _independent(stream, policy)
        np.testing.assert_array_equal(
            np.asarray(ref.accepted), np.asarray(dec.accepted)[i])
        np.testing.assert_array_equal(
            np.asarray(ref.t_s), np.asarray(dec.t_s)[i])


def test_ensemble_overflow_lane_grows_collectively():
    """One lane overflows both the timeline and pending buffer
    mid-scan; its neighbours do not.  The collective growth re-run
    must leave every lane identical to its independent run."""
    streams = [_stream(0, n=14, pile=True), _stream(1, n=14),
               _stream(2, n=14)]
    states = ens_lib.init_ensemble(3, 8, N_PE, 2)
    out, dec = ens_lib.admit_stream_ensemble_auto(
        states, _stack(streams), [Policy.FF] * 3, n_pe=N_PE)
    cap, pend = ens_lib.lane_capacity(out)
    assert cap > 8 and pend > 2          # grew past both limits
    assert not bool(jnp.any(out.overflow))
    for i, stream in enumerate(streams):
        _, ref = _independent(stream, Policy.FF)
        np.testing.assert_array_equal(
            np.asarray(ref.accepted), np.asarray(dec.accepted)[i])
        np.testing.assert_array_equal(
            np.asarray(ref.t_s), np.asarray(dec.t_s)[i])


def test_ensemble_growth_is_sized_by_watermark():
    """The grow-once protocol jumps straight to the max needed
    capacity across the ensemble instead of doubling repeatedly."""
    streams = [_stream(0, n=20, pile=True), _stream(1, n=20)]
    states = ens_lib.init_ensemble(2, 8, N_PE, 4)
    grow_calls = []
    orig = ens_lib.grow_ensemble

    def spy(states, cap, pend):
        grow_calls.append((cap, pend))
        return orig(states, cap, pend)

    ens_lib.grow_ensemble, saved = spy, ens_lib.grow_ensemble
    try:
        out, dec = ens_lib.admit_stream_ensemble_auto(
            states, _stack(streams), [Policy.FF] * 2, n_pe=N_PE)
    finally:
        ens_lib.grow_ensemble = saved
    # 20 concurrent 1-PE reservations need ~21 records and 20 pending
    # slots: a blind doubling cascade from (8, 4) would take 2-3
    # rounds; the watermark jump needs at most 2 runs to settle.
    assert len(grow_calls) <= 2, grow_calls
    assert not bool(jnp.any(out.overflow))
    _, ref = _independent(streams[0], Policy.FF)
    np.testing.assert_array_equal(
        np.asarray(ref.accepted), np.asarray(dec.accepted)[0])


def test_admit_ensemble_single_step():
    """The fused single step vmaps too (one request per lane)."""
    reqs = [ARRequest(t_a=0, t_r=0, t_du=10, t_dl=30, n_pe=k)
            for k in (1, 8, 16)]
    req_batch = _stack([[r] for r in reqs])
    one_step = batch_lib.RequestBatch(
        *[getattr(req_batch, f)[:, 0]       # [E] scalars per lane
          for f in batch_lib.REQ_FIELDS])
    states = ens_lib.init_ensemble(3, 32, N_PE, 8)
    out, dec = ens_lib.admit_ensemble(
        states, one_step, ens_lib.policy_ids([Policy.FF] * 3),
        n_pe=N_PE)
    assert bool(jnp.all(dec.accepted))
    np.testing.assert_array_equal(np.asarray(out.n_accepted),
                                  np.ones(3, np.int32))
    for i, r in enumerate(reqs):
        s1 = tl_lib.init_state(32, N_PE, 8)
        _, alloc = batch_lib.admit_one(s1, r, Policy.FF, n_pe=N_PE)
        assert alloc is not None
        assert alloc.t_s == int(dec.t_s[i])


def test_vmapped_update_matches_loop():
    """``timeline.update`` itself tolerates a leading ensemble axis."""
    tls = [tl_lib.empty(16, N_PE) for _ in range(3)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *tls)
    t_s = jnp.asarray([0, 10, 20], jnp.int32)
    t_e = jnp.asarray([5, 30, 25], jnp.int32)
    mask = jnp.stack([tl_lib.pe_valid_mask(4)] * 3)
    out, ovf = jax.vmap(
        lambda tl, a, b, m: tl_lib.update(tl, a, b, m, is_add=True)
    )(stacked, t_s, t_e, mask)
    assert not bool(jnp.any(ovf))
    for i in range(3):
        ref, _ = tl_lib.update(
            tls[i], t_s[i], t_e[i], mask[i], is_add=True)
        np.testing.assert_array_equal(np.asarray(ref.times),
                                      np.asarray(out.times[i]))
        np.testing.assert_array_equal(np.asarray(ref.occ),
                                      np.asarray(out.occ[i]))


def test_find_allocation_ensemble_probes_all_lanes():
    """The routing probe sees each lane's own timeline."""
    lane0 = tl_lib.init_state(32, N_PE, 8)
    lane1 = tl_lib.init_state(32, N_PE, 8)
    # lane1 is fully busy over [0, 100)
    full = jnp.asarray(tl_lib.pe_valid_mask(N_PE))
    tl1, ovf = tl_lib.update(lane1.tl, 0, 100, full, is_add=True)
    assert not bool(ovf)
    lane1 = lane1._replace(tl=tl1)
    states = ens_lib.stack_states([lane0, lane1])
    req = ARRequest(t_a=0, t_r=0, t_du=50, t_dl=60, n_pe=N_PE)
    res = ens_lib.find_allocation_ensemble(
        states, batch_lib.request_struct(req),
        jnp.int32(0), n_pe=N_PE)
    found = np.asarray(res.found)
    assert found[0] and not found[1]
    assert int(res.t_s[0]) == 0


def test_ensemble_kernel_path_matches_dense():
    """`use_kernel=True` threads the Pallas contraction through the
    vmapped scan; decisions must match the jnp path exactly."""
    streams = [_stream(s, n=12) for s in range(2)]
    states = ens_lib.init_ensemble(2, 64, N_PE, 32)
    pols = [Policy.PE_W, Policy.DU_B]
    _, dense = ens_lib.admit_stream_ensemble_auto(
        states, _stack(streams), pols, n_pe=N_PE, use_kernel=False)
    _, kern = ens_lib.admit_stream_ensemble_auto(
        states, _stack(streams), pols, n_pe=N_PE, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(dense.accepted),
                                  np.asarray(kern.accepted))
    np.testing.assert_array_equal(np.asarray(dense.t_s),
                                  np.asarray(kern.t_s))
    np.testing.assert_array_equal(np.asarray(dense.pe_mask),
                                  np.asarray(kern.pe_mask))
