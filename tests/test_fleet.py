"""Fleet runtime: admission, fault migration, stragglers, elasticity.

The invariant behind every scenario: the AR core never double-books a
chip — verified directly on the availability records after each event.
"""

from repro.core import Policy
from repro.runtime import (
    FleetScheduler,
    JobState,
    estimate_duration,
)


def _assert_no_double_booking(fleet):
    # core engines raise on double booking; records() gives busy sets
    for t, busy in fleet.core.records():
        assert len(busy) <= fleet.n_chips


def test_admission_and_completion():
    f = FleetScheduler(n_chips=512)
    j = f.submit("qwen3-4b", "train_4k", 256, n_steps=500)
    assert j.state == JobState.RESERVED
    assert len(j.chips) == 256
    f.advance(j.t_end + 1)
    assert j.state == JobState.DONE
    assert f.core.records() == []      # everything released


def test_rejection_when_fleet_saturated():
    f = FleetScheduler(n_chips=64)
    jobs = [f.submit("stablelm-1.6b", "train_4k", 64, n_steps=5000,
                     deadline_slack=0.0) for _ in range(4)]
    states = [j.state for j in jobs]
    assert states[0] == JobState.RESERVED
    assert JobState.REJECTED in states  # zero slack forces rejections


def test_chip_failure_migrates_jobs():
    f = FleetScheduler(n_chips=256)
    j = f.submit("granite-moe-1b-a400m", "train_4k", 128, n_steps=2000)
    f.advance(j.t_start + 100)
    failed_chip = j.chips[0]
    migrated = f.fail_chip(failed_chip)
    assert j.job_id in migrated
    assert failed_chip not in j.chips      # moved off the failed chip
    assert j.preemptions == 1
    _assert_no_double_booking(f)
    # repair reservation holds the chip
    busy_now = set()
    for t, b in f.core.records():
        if t <= f.now:
            busy_now = b
    assert failed_chip in busy_now


def test_failure_respects_checkpoint_granularity():
    f = FleetScheduler(n_chips=128)
    j = f.submit("stablelm-1.6b", "train_4k", 64, n_steps=4000)
    j.checkpoint_interval = 300
    f.advance(j.t_start + 650)          # two checkpoints written
    old_total = j.t_end - j.t_start
    f.fail_chip(j.chips[0])
    new_len = j.t_end - j.t_start
    # remaining = total - 600 (kept work) + restart overhead
    assert new_len == old_total - 600 + f.restart_overhead


def test_straggler_stretches_within_slack():
    f = FleetScheduler(n_chips=128)
    j = f.submit("stablelm-1.6b", "train_4k", 64, n_steps=2000,
                 deadline_slack=3.0)
    f.advance(j.t_start + 10)
    end_before = j.t_end
    assert f.report_straggler(j.job_id, slowdown=1.5)
    assert j.t_end > end_before
    assert j.t_end <= j.deadline
    _assert_no_double_booking(f)


def test_straggler_beyond_slack_fails():
    f = FleetScheduler(n_chips=128)
    j = f.submit("stablelm-1.6b", "train_4k", 64, n_steps=2000,
                 deadline_slack=0.05)
    f.advance(j.t_start + 10)
    ok = f.report_straggler(j.job_id, slowdown=50.0)
    assert not ok
    assert j.state == JobState.FAILED


def test_elastic_rescale_changes_footprint():
    f = FleetScheduler(n_chips=512)
    j = f.submit("qwen3-4b", "train_4k", 256, n_steps=1000,
                 deadline_slack=5.0)
    f.advance(j.t_start + 5)
    assert f.rescale(j.job_id, 128)
    assert j.n_chips == 128
    assert len(j.chips) == 128
    _assert_no_double_booking(f)


def test_estimate_duration_scales_with_chips():
    d256 = estimate_duration("qwen3-4b", "train_4k", 256, 100)
    d64 = estimate_duration("qwen3-4b", "train_4k", 64, 100)
    assert d64 > d256 * 2     # fewer chips -> much longer


def test_policy_affects_placement():
    """FF starts ASAP; PE_W may defer for a larger free rectangle —
    the paper's acceptance/slowdown tradeoff at fleet level."""
    for pol, attr in ((Policy.FF, "t_start"), (Policy.PE_W, "t_start")):
        f = FleetScheduler(n_chips=512, policy=pol)
        f.submit("qwen3-4b", "train_4k", 256, n_steps=2000)
        j2 = f.submit("stablelm-1.6b", "train_4k", 128, n_steps=500,
                      deadline_slack=8.0)
        if pol == Policy.FF:
            ff_start = j2.t_start
        else:
            pe_w_start = j2.t_start
    assert ff_start <= pe_w_start


def test_malleable_submission_picks_earliest_finish():
    """Paper Section 7: malleable requirements translate to a group of
    rigid requests; our criterion picks the earliest feasible finish."""
    f = FleetScheduler(n_chips=512)
    # saturate 384 chips so the 512-chip variant must wait
    f.submit("qwen3-4b", "train_4k", 384, n_steps=2000)
    j = f.submit_malleable("stablelm-1.6b", "train_4k",
                           chip_options=[64, 128, 512], n_steps=1000)
    assert j.state == JobState.RESERVED
    # 128 free chips now: the 64/128 variants can start immediately,
    # 512 can't -> malleable pick must not be 512
    assert j.n_chips in (64, 128)
    _assert_no_double_booking(f)


def test_malleable_rejected_when_nothing_fits():
    f = FleetScheduler(n_chips=64)
    f.submit("stablelm-1.6b", "train_4k", 64, n_steps=50_000,
             deadline_slack=5.0)
    j = f.submit_malleable("stablelm-1.6b", "train_4k",
                           chip_options=[32, 64], n_steps=50_000,
                           deadline=f.now + 100)
    assert j.state == JobState.REJECTED
