"""Hierarchical availability index (DESIGN.md §12).

Three invariants anchor this suite:

1. **Exact incremental consistency** — after *any* sequence of
   ``update`` / ``update_many`` / ``cancel_many`` / ``grow`` mutations
   (duplicate boundaries, T_INF clamps, R > 1 planes included), the
   incrementally-maintained tile summaries equal a from-scratch
   :func:`repro.core.availindex.build_summaries` bit-for-bit.
2. **Conservativeness** — :func:`repro.core.search.summary_reject` and
   :func:`repro.core.search.prune_candidates` only ever prove
   infeasibility the exact contraction would also find: a rejected
   request has no feasible candidate, and a pruned candidate fails its
   own availability rectangle.
3. **Pruned-vs-unpruned parity** — streams admitted with the index on
   produce bit-identical :class:`~repro.core.batch.Decision` fields to
   the index-free path across policies, backfill modes, kernel/jnp
   search, multi-resource layouts and bucketed engines; and
   ``index_tile=None`` keeps the exact index-free treedef (zero new
   leaves).

Hypothesis variants fuzz the same properties where hypothesis is
installed; the exhaustive mirrors below run everywhere.
"""
import random

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import availindex as ai
from repro.core import batch as batch_lib
from repro.core import search as search_lib
from repro.core import timeline as tl_lib
from repro.core.resources import ResourceSpec
from repro.core.scheduler import DeviceEngine
from repro.core.types import ALL_POLICIES, ARRequest, Policy, T_INF


def _assert_summaries_exact(tl):
    assert tl.ispec is not None
    ref = ai.build_summaries(tl.times, tl.occ, tl.ispec)
    for name, got, want in zip(
            ("idx_occ", "idx_minfree", "idx_maxfree"),
            (tl.idx_occ, tl.idx_minfree, tl.idx_maxfree), ref):
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want), err_msg=name)


def _random_jobs(n, n_pe, seed=0, rspec=None, du_max=30, slack_max=40):
    rng = random.Random(seed)
    jobs, t = [], 0
    for _ in range(n):
        t += rng.randint(0, 5)
        tr = t + rng.randint(0, 3)
        du = rng.randint(1, du_max)
        npe = rng.randint(1, n_pe)
        kw = {}
        if rspec is not None:
            kw["demand"] = (npe,) + tuple(
                rng.randint(0, u) for u in rspec.units[1:])
        jobs.append(ARRequest(
            t_a=t, t_r=tr, t_du=du, t_dl=tr + du + rng.randint(
                0, slack_max), n_pe=npe, **kw))
    return jobs


# ---------------------------------------------------------------------------
# IndexSpec layout
# ---------------------------------------------------------------------------


def test_index_spec_layout():
    spec = ai.IndexSpec(tile=8, units=(33, 4), words_per=(2, 1))
    assert spec.R == 2 and spec.total_words == 3
    assert spec.word_offsets == (0, 2)
    assert spec.plane_slice(1) == slice(2, 3)
    assert spec.n_tiles(32) == 4
    with pytest.raises(ValueError):
        spec.n_tiles(36)                      # not divisible
    with pytest.raises(ValueError):
        ai.IndexSpec(tile=6, units=(8,), words_per=(1,))  # not pow2
    with pytest.raises(ValueError):
        ai.IndexSpec(tile=0, units=(8,), words_per=(1,))
    with pytest.raises(ValueError):
        ai.IndexSpec(tile=8, units=(8, 4), words_per=(1,))


def test_index_spec_zero_leaf_pytree():
    spec = ai.make_index_spec(16, 64)
    leaves, treedef = jax.tree_util.tree_flatten(spec)
    assert leaves == []
    assert jax.tree_util.tree_unflatten(treedef, []) == spec


def test_make_index_spec_from_rspec():
    rs = ResourceSpec((64, 4, 8))
    spec = ai.make_index_spec(8, 64, rs)
    assert spec.units == (64, 4, 8)
    assert spec.words_per == rs.words_per
    s1 = ai.make_index_spec(8, 48)
    assert s1.units == (48,) and s1.words_per == (2,)


def test_empty_summaries_are_all_free():
    spec = ai.make_index_spec(8, 40)
    occ, minfree, maxfree = ai.empty_summaries(32, spec)
    assert occ.shape == (4, 2) and not np.asarray(occ).any()
    assert (np.asarray(minfree) == 40).all()
    assert (np.asarray(maxfree) == 40).all()


def test_init_state_validates_tile():
    with pytest.raises(ValueError):
        tl_lib.init_state(100, 8, 16, index_tile=8)   # 100 % 8 != 0
    with pytest.raises(ValueError):
        tl_lib.init_state(64, 8, 16, index_tile=6)    # not pow2
    st = tl_lib.init_state(64, 8, 16, index_tile=16)
    assert st.tl.ispec.tile == 16
    assert st.tl.idx_occ.shape == (4, 1)


def test_plane_deficit_matches_mask():
    rs = ResourceSpec((8, 4))
    spec = ai.make_index_spec(8, 8, rs)
    full = jnp.asarray(rs.valid_mask_np())
    np.testing.assert_array_equal(
        np.asarray(ai.plane_deficit(spec, full)), [0, 0])
    shrunk = jnp.asarray(rs.valid_mask_np((5, 2)))
    np.testing.assert_array_equal(
        np.asarray(ai.plane_deficit(spec, shrunk)), [3, 2])
    np.testing.assert_array_equal(
        np.asarray(ai.plane_deficit(spec, None)), [0, 0])


# ---------------------------------------------------------------------------
# exact incremental consistency
# ---------------------------------------------------------------------------


def _ops_sequence(seed, n_pe=8, capacity=64, tile=8, rspec=None,
                  n_ops=50):
    """Random add/delete/update_many/grow walk asserting exactness."""
    rng = random.Random(seed)
    spec = ai.make_index_spec(tile, n_pe, rspec)
    words = spec.total_words
    tl = tl_lib.empty(capacity, n_pe, words=words if rspec else None,
                      ispec=spec)
    added = []
    n_bits = words * 32
    for i in range(n_ops):
        r = rng.random()
        if added and r < 0.25:
            s, e, m = added.pop(rng.randrange(len(added)))
            tl, ovf = tl_lib.update(tl, s, e, m, is_add=False)
            assert not bool(ovf)
        elif r < 0.40 and len(added) >= 2:
            # batched same-direction deletes incl. inactive rows
            k = min(len(added), rng.randint(2, 4))
            picks = [added.pop(rng.randrange(len(added)))
                     for _ in range(k)]
            ts = jnp.asarray([p[0] for p in picks] + [0], jnp.int32)
            te = jnp.asarray([p[1] for p in picks] + [T_INF], jnp.int32)
            ms = jnp.stack([p[2] for p in picks] +
                           [jnp.zeros((words,), jnp.uint32)])
            act = jnp.asarray([True] * k + [False])
            tl, ovf = tl_lib.update_many(tl, ts, te, ms, act,
                                         is_add=False)
            assert not bool(ovf)
        else:
            # duplicate boundaries on purpose: coarse time grid
            s = rng.randrange(0, 200, 5)
            e = s + rng.randrange(5, 60, 5)
            ids = sorted(rng.sample(range(min(n_bits, n_pe)),
                                    rng.randint(1, min(4, n_pe))))
            m = tl_lib.ids_to_mask32(ids, words)
            t2, ovf = tl_lib.update(tl, s, e, m, is_add=True)
            if bool(ovf):
                tl = tl_lib.grow(tl, 2 * tl.capacity)
                _assert_summaries_exact(tl)
                t2, ovf = tl_lib.update(tl, s, e, m, is_add=True)
                assert not bool(ovf)
            tl = t2
            added.append((s, e, m))
        _assert_summaries_exact(tl)
    return tl


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_incremental_consistency_walk(seed):
    _ops_sequence(seed)


def test_incremental_consistency_multires():
    rs = ResourceSpec((8, 4, 3))
    _ops_sequence(7, n_pe=8, tile=4, capacity=32, rspec=rs, n_ops=35)


def test_incremental_consistency_tile_one_and_full():
    # degenerate tiles: one record per tile, and one tile per timeline
    _ops_sequence(11, tile=1, capacity=32, n_ops=25)
    _ops_sequence(12, tile=32, capacity=32, n_ops=25)


def test_tinf_clamp_is_noop_for_index():
    spec = ai.make_index_spec(8, 8)
    tl = tl_lib.empty(32, 8, ispec=spec)
    m = tl_lib.ids_to_mask32([0, 1], tl.words)
    tl, _ = tl_lib.update(tl, 5, 20, m, is_add=True)
    before = jax.tree_util.tree_map(np.asarray, tl)
    # t_e past the sentinel deactivates the interval (the no-op clamp)
    tl2, ovf = tl_lib.update(tl, 3, T_INF, m, is_add=True)
    assert not bool(ovf)
    _assert_summaries_exact(tl2)
    for a, b in zip(jax.tree_util.tree_leaves(before),
                    jax.tree_util.tree_leaves(
                        jax.tree_util.tree_map(np.asarray, tl2))):
        np.testing.assert_array_equal(a, b)


def test_update_many_all_inactive_keeps_summaries():
    spec = ai.make_index_spec(8, 8)
    tl = tl_lib.empty(32, 8, ispec=spec)
    m = tl_lib.ids_to_mask32([2], tl.words)
    tl, _ = tl_lib.update(tl, 10, 30, m, is_add=True)
    ts = jnp.zeros((3,), jnp.int32)
    te = jnp.full((3,), 5, jnp.int32)
    ms = jnp.broadcast_to(m, (3,) + m.shape)
    tl2, ovf = tl_lib.update_many(
        tl, ts, te, ms, jnp.zeros((3,), bool), is_add=True)
    assert not bool(ovf)
    _assert_summaries_exact(tl2)
    np.testing.assert_array_equal(np.asarray(tl.idx_occ),
                                  np.asarray(tl2.idx_occ))


def test_grow_state_rebuilds_index():
    st = tl_lib.init_state(16, 8, 16, index_tile=8)
    m = tl_lib.ids_to_mask32([0, 3], st.tl.words)
    tl, _ = tl_lib.update(st.tl, 5, 25, m, is_add=True)
    st = st._replace(tl=tl)
    grown = tl_lib.grow_state(st, new_capacity=64)
    assert grown.tl.ispec == st.tl.ispec
    assert grown.tl.idx_occ.shape[0] == 8
    _assert_summaries_exact(grown.tl)


def test_cancel_many_keeps_index_exact():
    st = tl_lib.init_state(64, 8, 32, index_tile=8)
    jobs = _random_jobs(20, 8, seed=5)
    st, dec = batch_lib.admit_stream_grow(
        st, batch_lib.requests_to_batch(jobs), Policy.PE_W, n_pe=8,
        auto_release=False)
    acc = np.asarray(dec.accepted)
    triples = [
        (int(t), int(e), np.asarray(dec.pe_mask)[i])
        for i, (t, e) in enumerate(zip(np.asarray(dec.t_s),
                                       np.asarray(dec.t_e)))
        if acc[i]]
    st, done = batch_lib.cancel_many(st, triples[::2],
                                     require_pending=False)
    assert all(bool(d) for d in np.asarray(done))
    _assert_summaries_exact(st.tl)


# ---------------------------------------------------------------------------
# conservativeness of the two query-side bounds
# ---------------------------------------------------------------------------


def _busy_timeline(seed, n_pe=8, capacity=64, tile=8):
    rng = random.Random(seed)
    spec = ai.make_index_spec(tile, n_pe)
    tl = tl_lib.empty(capacity, n_pe, ispec=spec)
    for _ in range(14):
        s = rng.randint(0, 150)
        e = s + rng.randint(1, 40)
        ids = sorted(rng.sample(range(n_pe), rng.randint(1, n_pe)))
        m = tl_lib.ids_to_mask32(ids, tl.words)
        tl, ovf = tl_lib.update(tl, s, e, m, is_add=True)
        assert not bool(ovf)
    return tl


@pytest.mark.parametrize("seed", range(6))
def test_summary_reject_is_conservative(seed):
    n_pe = 8
    tl = _busy_timeline(seed, n_pe=n_pe)
    bare = tl_lib.Timeline(times=tl.times, occ=tl.occ)
    rng = random.Random(100 + seed)
    deficit = jnp.zeros((1,), jnp.int32)
    n_rej = 0
    for _ in range(60):
        tr = rng.randint(0, 200)
        du = rng.randint(1, 50)
        dl = tr + du + rng.randint(0, 30)
        dem = jnp.asarray([rng.randint(1, n_pe)], jnp.int32)
        rej = bool(search_lib.summary_reject(
            tl, jnp.int32(tr), jnp.int32(du), jnp.int32(dl), dem,
            deficit))
        res = search_lib.search(
            bare, jnp.int32(tr), jnp.int32(du), jnp.int32(dl),
            dem[0], jnp.int32(0), jnp.int32(tr), n_pe=n_pe,
            use_kernel=False)
        if rej:
            n_rej += 1
            assert not bool(res.found), (seed, tr, du, dl, int(dem[0]))


def _saturated_timeline(n_pe=8, capacity=128, tile=8):
    """64 distinct rows each leaving exactly one free unit.

    Rotating the free unit keeps consecutive rows different (no merge
    collapse), so every tile over ``[0, 256)`` has ``maxfree == 1`` —
    the regime where the early-reject bound can actually prove
    ``demand >= 2`` requests infeasible.
    """
    spec = ai.make_index_spec(tile, n_pe)
    tl = tl_lib.empty(capacity, n_pe, ispec=spec)
    for k in range(64):
        ids = [i for i in range(n_pe) if i != k % n_pe]
        m = tl_lib.ids_to_mask32(ids, tl.words)
        tl, ovf = tl_lib.update(tl, 4 * k, 4 * k + 4, m, is_add=True)
        assert not bool(ovf)
    _assert_summaries_exact(tl)
    return tl


def test_summary_reject_fires_when_saturated():
    n_pe = 8
    tl = _saturated_timeline(n_pe=n_pe)
    bare = tl_lib.Timeline(times=tl.times, occ=tl.occ)
    deficit = jnp.zeros((1,), jnp.int32)
    n_rej = n_total = 0
    for tr in range(10, 180, 7):
        for du, slack, dem in ((3, 2, 2), (8, 5, 4), (5, 0, 8),
                               (4, 3, 1)):
            dl = tr + du + slack
            demand = jnp.asarray([dem], jnp.int32)
            rej = bool(search_lib.summary_reject(
                tl, jnp.int32(tr), jnp.int32(du), jnp.int32(dl),
                demand, deficit))
            res = search_lib.search(
                bare, jnp.int32(tr), jnp.int32(du), jnp.int32(dl),
                demand[0], jnp.int32(0), jnp.int32(tr), n_pe=n_pe,
                use_kernel=False)
            n_total += 1
            if rej:
                n_rej += 1
                assert not bool(res.found), (tr, du, dl, dem)
            if dem == 1:
                # maxfree == 1 can never prove a 1-unit demand out
                assert not rej
    # every demand >= 2 window inside the saturated span must reject
    assert n_rej >= n_total // 2, (n_rej, n_total)


@pytest.mark.parametrize("seed", range(4))
def test_prune_candidates_is_conservative(seed):
    n_pe = 8
    tl = _busy_timeline(seed, n_pe=n_pe)
    bare = tl_lib.Timeline(times=tl.times, occ=tl.occ)
    rng = random.Random(200 + seed)
    deficit = jnp.zeros((1,), jnp.int32)
    for _ in range(25):
        tr = rng.randint(0, 180)
        du = rng.randint(1, 40)
        dl = tr + du + rng.randint(0, 60)
        starts = search_lib.candidate_starts(
            bare, jnp.int32(tr), jnp.int32(du), jnp.int32(dl))
        dem = jnp.asarray([rng.randint(1, n_pe)], jnp.int32)
        pruned = search_lib.prune_candidates(
            tl, starts, jnp.int32(du), dem, deficit)
        rects = search_lib.availability_rectangles(
            bare, starts, jnp.int32(du), jnp.int32(tr), n_pe)
        s_np, p_np = np.asarray(starts), np.asarray(pruned)
        nf = np.asarray(rects.n_free)
        # candidate 0 is never pruned (the rejected-Decision anchor)
        assert p_np[0] == s_np[0]
        for i in range(len(s_np)):
            if p_np[i] == T_INF and s_np[i] != T_INF:
                assert nf[i] < int(dem[0]), (seed, i, s_np[i])


def test_prune_fires_when_saturated():
    # a 40-unit window fully contains at least one 32-unit tile span
    # whose OR-union leaves zero common free units -> pruned
    n_pe = 8
    tl = _saturated_timeline(n_pe=n_pe)
    du = jnp.int32(40)
    starts = search_lib.candidate_starts(
        tl_lib.Timeline(times=tl.times, occ=tl.occ),
        jnp.int32(0), du, jnp.int32(220))
    pruned = search_lib.prune_candidates(
        tl, starts, du, jnp.asarray([1], jnp.int32),
        jnp.zeros((1,), jnp.int32))
    s_np, p_np = np.asarray(starts), np.asarray(pruned)
    newly = ((p_np == T_INF) & (s_np != T_INF)).sum()
    assert newly > 0, "pruning never fired on a saturated timeline"


# ---------------------------------------------------------------------------
# pruned-vs-unpruned decision parity
# ---------------------------------------------------------------------------

_DEC_FIELDS = ("accepted", "t_s", "t_e", "pe_mask", "n_free",
               "t_begin", "t_end", "parked")


def _assert_decisions_equal(d0, d1, ctx=""):
    for f in _DEC_FIELDS:
        a, b = np.asarray(getattr(d0, f)), np.asarray(getattr(d1, f))
        np.testing.assert_array_equal(a, b, err_msg=f"{ctx}:{f}")


def _stream(jobs, policy, mode, *, index_tile, use_kernel=False,
            n_pe=16, rspec=None, capacity=128):
    xd = rspec.R - 1 if rspec is not None else 0
    st = tl_lib.init_state(capacity, n_pe, 256, park_capacity=8,
                           rspec=rspec, index_tile=index_tile)
    st, dec = batch_lib.admit_stream_grow(
        st, batch_lib.requests_to_batch(jobs, extra_demand=xd),
        policy, backfill=batch_lib.as_backfill_id(mode), n_pe=n_pe,
        use_kernel=use_kernel)
    if index_tile is not None:
        _assert_summaries_exact(st.tl)
    return dec


@pytest.mark.parametrize("policy", [Policy.FF, Policy.PE_W,
                                    Policy.PEDU_B])
@pytest.mark.parametrize("mode", ["none", "conservative", "easy"])
def test_stream_parity_policies_modes(policy, mode):
    jobs = _random_jobs(90, 16, seed=hash((policy, mode)) % 1000)
    d0 = _stream(jobs, policy, mode, index_tile=None)
    d1 = _stream(jobs, policy, mode, index_tile=16)
    _assert_decisions_equal(d0, d1, f"{policy}/{mode}")


@pytest.mark.parametrize("tile", [8, 32, 128])
def test_stream_parity_tile_sizes(tile):
    jobs = _random_jobs(80, 16, seed=tile)
    d0 = _stream(jobs, Policy.PE_W, "none", index_tile=None)
    d1 = _stream(jobs, Policy.PE_W, "none", index_tile=tile)
    _assert_decisions_equal(d0, d1, f"tile={tile}")


def test_stream_parity_kernel_path():
    jobs = _random_jobs(70, 16, seed=42)
    d0 = _stream(jobs, Policy.PEDU_W, "none", index_tile=None,
                 use_kernel=True)
    d1 = _stream(jobs, Policy.PEDU_W, "none", index_tile=16,
                 use_kernel=True)
    _assert_decisions_equal(d0, d1, "kernel")


@pytest.mark.parametrize("use_kernel", [False, True])
def test_stream_parity_multires(use_kernel):
    rs = ResourceSpec((16, 4, 6))
    jobs = _random_jobs(60, 16, seed=9, rspec=rs)
    d0 = _stream(jobs, Policy.PE_B, "none", index_tile=None, rspec=rs,
                 use_kernel=use_kernel)
    d1 = _stream(jobs, Policy.PE_B, "none", index_tile=8, rspec=rs,
                 use_kernel=use_kernel)
    _assert_decisions_equal(d0, d1, f"mr/kernel={use_kernel}")


def test_stream_parity_saturated_rejections():
    # the early-reject showcase: a dense fill phase then full-machine
    # requests whose windows sit inside the busy horizon — most steps
    # take the summary_reject branch, and every Decision field (the
    # unconditional n_free/t_begin/t_end included) must still match
    rng = random.Random(13)
    jobs, t = [], 0
    for _ in range(60):
        t += rng.randint(0, 2)
        du = rng.randint(20, 60)
        jobs.append(ARRequest(t_a=t, t_r=t, t_du=du, t_dl=t + du + 5,
                              n_pe=rng.randint(10, 16)))
    for _ in range(60):
        t += rng.randint(0, 2)
        du = rng.randint(5, 15)
        jobs.append(ARRequest(t_a=t, t_r=t, t_du=du, t_dl=t + du + 2,
                              n_pe=16))
    d0 = _stream(jobs, Policy.FF, "none", index_tile=None)
    d1 = _stream(jobs, Policy.FF, "none", index_tile=16)
    _assert_decisions_equal(d0, d1, "saturated")
    acc = np.asarray(d0.accepted)
    assert (~acc).sum() > 20       # genuinely rejection-heavy


def test_engine_bucketing_parity():
    # bucketed views slice the index when the bucket divides the tile
    # grid and drop it otherwise — decisions match the unbucketed
    # engine either way
    jobs = _random_jobs(50, 16, seed=21)
    base = DeviceEngine(16, capacity=256, bucketing=False)
    for tile in (8, 64):
        eng = DeviceEngine(16, capacity=256, bucketing=True,
                           index_tile=tile)
        for req in jobs[:25]:
            a0 = base.find_allocation(req, Policy.PE_W) \
                if tile == 8 else None
            a1 = eng.find_allocation(req, Policy.PE_W)
            if tile == 8:
                assert (a0 is None) == (a1 is None)
                if a0 is not None:
                    assert (a0.t_s, a0.t_e) == (a1.t_s, a1.t_e)


def test_index_none_treedef_is_legacy():
    s0 = tl_lib.init_state(64, 8, 16)
    s1 = tl_lib.init_state(64, 8, 16, index_tile=None)
    assert jax.tree_util.tree_structure(s0) == \
        jax.tree_util.tree_structure(s1)
    on = tl_lib.init_state(64, 8, 16, index_tile=8)
    assert len(jax.tree_util.tree_leaves(on)) == \
        len(jax.tree_util.tree_leaves(s0)) + 3


@pytest.mark.slow
def test_slow_full_matrix_parity():
    """The 1000-job x 7-policy x 3-backfill pruned-vs-unpruned gate."""
    jobs = _random_jobs(1000, 16, seed=77, du_max=40, slack_max=60)
    for policy in ALL_POLICIES:
        for mode in ("none", "conservative", "easy"):
            d0 = _stream(jobs, policy, mode, index_tile=None,
                         capacity=256)
            d1 = _stream(jobs, policy, mode, index_tile=32,
                         capacity=256)
            _assert_decisions_equal(d0, d1, f"{policy}/{mode}")


# ---------------------------------------------------------------------------
# Hypothesis fuzz (runs where hypothesis is installed)
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                           # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10_000))
    def test_hypothesis_incremental_consistency(seed):
        _ops_sequence(seed, n_ops=25)

    @settings(max_examples=15, deadline=None)
    @given(st.data())
    def test_hypothesis_stream_parity(data):
        seed = data.draw(st.integers(0, 10_000))
        policy = data.draw(st.sampled_from(list(ALL_POLICIES)))
        mode = data.draw(st.sampled_from(
            ["none", "conservative", "easy"]))
        tile = data.draw(st.sampled_from([8, 16, 64]))
        jobs = _random_jobs(40, 16, seed=seed)
        d0 = _stream(jobs, policy, mode, index_tile=None)
        d1 = _stream(jobs, policy, mode, index_tile=tile)
        _assert_decisions_equal(d0, d1)
