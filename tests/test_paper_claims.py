"""Validation of the paper's Section 6 claims (reduced N for CI).

The full 10^4-job sweeps live in benchmarks/; here 1500 jobs per point
keep CI fast while the orderings the paper reports remain stable.
"""
import pytest

from repro.core.types import ALL_POLICIES, Policy
from repro.sim import WorkloadParams, generate, run_policies


@pytest.fixture(scope="module")
def default_results():
    jobs = generate(WorkloadParams(n_jobs=1500, seed=11))
    res = run_policies(jobs, 1024, ALL_POLICIES)
    return {r.policy: r for r in res}


def test_pe_worst_fit_highest_acceptance(default_results):
    """Headline claim: 'the PE WorstFit algorithm becomes the best
    algorithm for the scheduler with the highest acceptance rate'."""
    acc = {k: v.acceptance_rate for k, v in default_results.items()}
    best = max(acc, key=acc.get)
    assert acc[Policy.PE_W.value] >= acc[best] - 0.01


def test_ff_lowest_slowdown(default_results):
    """'the jobs with the FirstFit algorithm experience the lowest
    average slowdown'."""
    sd = {k: v.avg_slowdown for k, v in default_results.items()}
    assert sd[Policy.FF.value] == min(sd.values())


def test_policy_pairings(default_results):
    """Fig. 2: PE_W ~ Du_B and PE_B ~ Du_W on acceptance rate."""
    acc = {k: v.acceptance_rate for k, v in default_results.items()}
    assert abs(acc["PE_W"] - acc["Du_B"]) < 0.02
    assert abs(acc["PE_B"] - acc["Du_W"]) < 0.02


def test_pe_w_beats_ff_on_acceptance(default_results):
    acc = {k: v.acceptance_rate for k, v in default_results.items()}
    assert acc["PE_W"] > acc["FF"]


def test_acceptance_degrades_with_load():
    """Fig. 4: higher arrival factor -> lower acceptance."""
    accs = []
    for af in (0.75, 1.5):
        jobs = generate(WorkloadParams(n_jobs=1200, seed=5,
                                       arrival_factor=af))
        r = run_policies(jobs, 1024, [Policy.PE_W])[0]
        accs.append(r.acceptance_rate)
    assert accs[1] < accs[0]


def test_acceptance_degrades_with_umed():
    """Fig. 2: larger jobs -> lower acceptance."""
    accs = []
    for umed in (5.0, 9.0):
        jobs = generate(WorkloadParams(n_jobs=1200, seed=5,
                                       u_med=umed))
        r = run_policies(jobs, 1024, [Policy.PE_W])[0]
        accs.append(r.acceptance_rate)
    assert accs[1] < accs[0]


def test_flexibility_raises_acceptance_and_slowdown():
    """Fig. 6/7: more {artime, deadline} flexibility -> higher
    acceptance for PE_W and higher slowdown."""
    rows = []
    for f in (1.0, 5.0):
        jobs = generate(WorkloadParams(n_jobs=1200, seed=5,
                                       artime_factor=f,
                                       deadline_factor=f))
        r = run_policies(jobs, 1024, [Policy.PE_W])[0]
        rows.append((r.acceptance_rate, r.avg_slowdown))
    assert rows[1][0] > rows[0][0]       # acceptance up
    assert rows[1][1] > rows[0][1]       # slowdown up


def test_device_engine_agrees_with_host_in_sim():
    """The JAX engine is a drop-in for the host engine end-to-end."""
    from repro.sim import simulate
    jobs = generate(WorkloadParams(n_jobs=60, seed=2, n_pe=64))
    jobs = [j for j in jobs if j.n_pe <= 64]
    a = simulate(jobs, 64, Policy.PE_W, engine="host")
    b = simulate(jobs, 64, Policy.PE_W, engine="device",
                 engine_kwargs={"capacity": 128})
    assert a.n_accepted == b.n_accepted
    assert a.slowdowns == b.slowdowns


def test_backfilling_modes_dominate_none_on_acceptance():
    """Scenario-axis extension (DESIGN.md §6): EASY backfilling
    accepts at least as many jobs as the paper's strict arrival-order
    admission, and conservative is decision-identical to it — on a
    fragmented small machine the EASY gain is strict.  (The full
    7-policy × 3-mode grid claim lives in tests/test_sweep.py.)"""
    import numpy as np

    from repro.core import batch as batch_lib
    from repro.core import timeline as tl_lib
    from repro.sim import generate_filtered

    n_pe = 16
    jobs = sorted(generate_filtered(WorkloadParams(
        n_jobs=120, n_pe=n_pe, seed=3, arrival_factor=2.5,
        u_low=2.0, u_med=3.0, u_hi=4.0), max_pe=n_pe),
        key=lambda j: j.t_a)
    batch = batch_lib.requests_to_batch(jobs)
    acc = {}
    for policy in (Policy.PE_W, Policy.FF):
        for mode in ("none", "easy", "conservative"):
            q = 0 if mode == "none" else 8
            state = tl_lib.init_state(64, n_pe, 128, park_capacity=q)
            _, dec = batch_lib.admit_stream_grow(
                state, batch, policy, n_pe=n_pe, backfill=mode)
            acc[(policy, mode)] = int(
                np.asarray(dec.accepted).sum())
        assert acc[(policy, "easy")] > acc[(policy, "none")]
        assert acc[(policy, "conservative")] == acc[(policy, "none")]
