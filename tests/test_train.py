"""Training integration: loss decreases, microbatch equivalence,
optimizer semantics, checkpoint restart mid-run."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import TokenPipeline
from repro.train import optim as optim_lib
from repro.train import step as step_lib


def _cfg():
    return get_config("stablelm-1.6b").reduced()


def test_loss_decreases_over_training():
    cfg = _cfg()
    opt_cfg = optim_lib.OptConfig(lr=1e-3, warmup_steps=5,
                                  total_steps=40)
    step = jax.jit(step_lib.make_train_step(cfg, opt_cfg, 2))
    params, opt = step_lib.init_train_state(
        cfg, opt_cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(cfg.vocab, 64, 8, microbatches=2, seed=0)
    losses = []
    for s in range(25):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.92
    assert int(opt.step) == 25


def test_microbatch_accumulation_matches_single_batch():
    """mb=2 over the same data == one big batch (same grads up to
    f32 accumulation noise)."""
    cfg = _cfg()
    opt_cfg = optim_lib.OptConfig(lr=1e-3, warmup_steps=0,
                                  total_steps=10, grad_clip=1e9)
    params, opt = step_lib.init_train_state(
        cfg, opt_cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(cfg.vocab, 32, 8, microbatches=2, seed=1)
    batch2 = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    batch1 = {k: v.reshape(1, -1, *v.shape[2:]) for k, v in
              batch2.items()}
    step1 = jax.jit(step_lib.make_train_step(cfg, opt_cfg, 1))
    step2 = jax.jit(step_lib.make_train_step(cfg, opt_cfg, 2))
    p1, _, m1 = step1(params, opt, batch1)
    p2, _, m2 = step2(params, opt, batch2)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-3)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m2["grad_norm"]), rtol=1e-3)
    # Adam normalises per-coordinate, so accumulation-order noise can
    # move a parameter by O(lr); bf16 storage adds ~0.4% more.
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=0.1, atol=3e-3)


def test_adam_update_within_trust_region():
    """Adam normalises per-coordinate: one step moves no parameter by
    more than ~lr (+ weight decay), regardless of gradient scale."""
    cfg = _cfg()
    lr = 0.01
    opt_cfg = optim_lib.OptConfig(lr=lr, warmup_steps=0,
                                  total_steps=10, weight_decay=0.0,
                                  grad_clip=1e9)
    params, opt = step_lib.init_train_state(
        cfg, opt_cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(cfg.vocab, 32, 4, microbatches=1, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    step = jax.jit(step_lib.make_train_step(cfg, opt_cfg, 1))
    p1, _, m = step(params, opt, batch)
    assert float(m["grad_norm"]) > 0
    delta = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert delta <= 1.5 * lr


def test_schedule_warmup_and_decay():
    cfg = optim_lib.OptConfig(lr=1e-3, warmup_steps=10,
                              total_steps=100)
    s0 = float(optim_lib.schedule(cfg, jnp.int32(1)))
    s_w = float(optim_lib.schedule(cfg, jnp.int32(10)))
    s_end = float(optim_lib.schedule(cfg, jnp.int32(100)))
    assert s0 < s_w
    assert abs(s_w - 1e-3) < 1e-5
    assert s_end < 0.2 * s_w


def test_train_driver_restart(tmp_path):
    from repro.launch.train import run
    out1 = run("stablelm-1.6b", steps=6, smoke=True, batch=4, seq=32,
               ckpt_dir=str(tmp_path), ckpt_every=3, microbatches=1,
               log_every=100)
    assert out1["steps_run"] == 6
    out2 = run("stablelm-1.6b", steps=9, smoke=True, batch=4, seq=32,
               ckpt_dir=str(tmp_path), ckpt_every=3, microbatches=1,
               log_every=100)
    assert out2["resumed_from"] == 6
    assert out2["steps_run"] == 3
