"""Workload model: LANL-CM5 constraints of Section 6.1."""
import numpy as np

from repro.sim.workload import (
    RUNTIME_VALUES,
    WorkloadParams,
    generate,
    mean_job_area,
)


def _jobs(**kw):
    return generate(WorkloadParams(n_jobs=1500, seed=3).replace(**kw))


def test_sizes_are_powers_of_two_in_range():
    sizes = np.array([j.n_pe for j in _jobs()])
    assert np.all((sizes & (sizes - 1)) == 0)      # powers of two
    assert sizes.min() >= 32 and sizes.max() <= 1024


def test_runtimes_from_discrete_set():
    durs = {j.t_du for j in _jobs()}
    assert durs <= set(int(v) for v in RUNTIME_VALUES)
    assert len(durs) >= 4          # several classes actually used


def test_request_ordering_constraints():
    for j in _jobs():
        assert j.t_a <= j.t_r
        assert j.t_dl >= j.t_r + j.t_du


def test_umed_increases_mean_area():
    areas = []
    for umed in (5.0, 7.0, 9.0):
        a = mean_job_area(WorkloadParams(u_med=umed, seed=0))
        areas.append(a)
    assert areas[0] < areas[1] < areas[2]


def test_arrival_factor_compresses_time():
    j1 = _jobs(arrival_factor=1.0)
    j2 = _jobs(arrival_factor=2.0)
    span1 = j1[-1].t_a - j1[0].t_a
    span2 = j2[-1].t_a - j2[0].t_a
    assert abs(span2 - span1 / 2) < span1 * 0.05


def test_deadline_factor_zero_gives_immediate_deadlines():
    for j in _jobs(deadline_factor=0.0):
        assert j.t_dl == j.t_r + j.t_du


def test_artime_factor_zero_gives_immediate_ready():
    for j in _jobs(artime_factor=0.0):
        assert j.t_r == j.t_a


def test_size_runtime_correlation_negative_p():
    jobs = _jobs()
    sizes = np.array([j.n_pe for j in jobs], dtype=np.float64)
    durs = np.array([j.t_du for j in jobs], dtype=np.float64)
    big = durs[sizes >= 512].mean()
    small = durs[sizes <= 64].mean()
    assert big > small     # larger jobs run longer on average


def test_determinism():
    a = _jobs()
    b = _jobs()
    assert a == b
