"""Serving-step factories: batched prefill and single-token decode.

``serve_step`` for the ``decode_*`` shapes is one new token against a
populated KV cache / recurrent state of ``seq_len`` context — exactly
what the assignment's decode cells lower.  A minimal batched engine
(`generate`) drives prefill+decode loops for the examples and tests;
production batching policy (continuous batching, eviction) lives in
runtime/fleet.py at the job level.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf_lib


def make_prefill_step(cfg: ModelConfig,
                      max_len: Optional[int] = None) -> Callable:
    def prefill_step(params, tokens, extra=None):
        return tf_lib.prefill(params, cfg, tokens, extra,
                              max_len=max_len or tokens.shape[1])
    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, tokens, extra=None):
        return tf_lib.decode_step(params, cfg, cache, tokens, extra)
    return decode_step


def greedy_token(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]


def generate(params, cfg: ModelConfig, prompt: jax.Array,
             n_tokens: int, extra: Optional[Dict] = None,
             jit: bool = True) -> jax.Array:
    """Greedy generation: prefill the prompt then decode ``n_tokens``."""
    b, t = prompt.shape
    prefill_fn = make_prefill_step(cfg, max_len=t + n_tokens)
    decode_fn = make_decode_step(cfg)
    if jit:
        prefill_fn = jax.jit(prefill_fn)
        decode_fn = jax.jit(decode_fn)
    logits, cache = prefill_fn(params, prompt, extra)
    tok = greedy_token(logits)
    out = [tok]
    for _ in range(n_tokens - 1):
        logits, cache = decode_fn(params, cache, tok, extra)
        tok = greedy_token(logits)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
