"""Subpackage."""
