"""Roofline accounting: analytic cost model + compiled-HLO extraction.

Three-term roofline per (arch x shape x mesh), TPU v5e constants:

    compute    = FLOPs / (chips * 197e12 bf16 FLOP/s)
    memory     = HBM bytes / (chips * 819e9 B/s)
    collective = collective bytes / (chips * 50e9 B/s per ICI link)

Why an analytic model: ``compiled.cost_analysis()`` on XLA:CPU counts
every ``while`` body ONCE regardless of trip count (verified in
EXPERIMENTS.md §Methodology), so any program with scan-over-layers,
chunked SSM scans, or blockwise attention under-reports by 10-100x.
The dry-run therefore records BOTH the raw HLO numbers and this
closed-form model; ``tests/test_roofline.py`` validates the model
against ``cost_analysis`` on loop-free (fully unrolled, small-T)
variants to <15%.

Collective bytes are additionally parsed from ``compiled.as_text()``
(all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute operand sizes) as the structural cross-check.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Tuple

from repro.configs.base import ModelConfig, ShapeConfig

# --- TPU v5e hardware constants (per chip) --------------------------------
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link
BYTES_PER_PARAM = 2        # bf16


@dataclasses.dataclass
class Costs:
    """Whole-step costs (global, not per chip)."""

    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_intra_bytes: float = 0.0   # ICI (within pod)
    coll_inter_bytes: float = 0.0   # DCI (across pods)
    n_params: float = 0.0
    n_active_params: float = 0.0
    model_flops: float = 0.0        # 6*N*D (6*N_active*D for MoE)

    def terms(self, chips: int) -> Dict[str, float]:
        # inter-pod links are far scarcer; model DCI as 1/4 ICI per chip
        t_comp = self.flops / (chips * PEAK_FLOPS)
        t_mem = self.hbm_bytes / (chips * HBM_BW)
        t_coll = (self.coll_intra_bytes / (chips * ICI_BW)
                  + self.coll_inter_bytes / (chips * ICI_BW / 4))
        dom = max(("compute", t_comp), ("memory", t_mem),
                  ("collective", t_coll), key=lambda kv: kv[1])[0]
        return {"compute_s": t_comp, "memory_s": t_mem,
                "collective_s": t_coll, "dominant": dom,
                "useful_ratio": (self.model_flops / self.flops
                                 if self.flops else 0.0)}


# ---------------------------------------------------------------------------
# parameter counts
# ---------------------------------------------------------------------------

def param_count(cfg: ModelConfig) -> Tuple[float, float]:
    """(total, active-per-token) parameter counts."""
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    attn = d * hd * (hq + 2 * hkv) + hq * hd * d
    mlp = 3 * d * f
    total = active = 0.0
    fam = cfg.family
    if fam in ("dense",):
        per_layer = attn + mlp
        total = active = cfg.n_layers * per_layer
    elif fam == "moe":
        router = d * cfg.n_experts
        expert = 3 * d * f
        per_layer = attn + router + cfg.n_experts * expert
        per_layer_active = attn + router + cfg.top_k * expert
        total = cfg.n_layers * per_layer
        active = cfg.n_layers * per_layer_active
    elif fam == "hybrid":
        total = active = cfg.n_layers * _mamba_params(cfg) + attn + mlp
    elif fam == "ssm":
        n_s, _ = _xlstm_split(cfg)
        total = active = ((cfg.n_layers - n_s) * _mlstm_params(cfg)
                          + n_s * _slstm_params(cfg))
    elif fam == "encdec":
        cross = attn + mlp
        total = active = (cfg.n_enc_layers * (attn + mlp)
                          + cfg.n_layers * (attn + mlp + cross)
                          + d * d)
    elif fam == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        total = active = (cfg.n_layers * (attn + mlp)
                          + n_cross * (attn + mlp)
                          + cfg.vision_dim * d)
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return total + emb, active + emb


def _mamba_params(cfg: ModelConfig) -> float:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = di // cfg.ssm_head_dim
    return (d * (2 * di + 2 * n + h) + cfg.conv_width * (di + 2 * n)
            + di * d + di)


def _mlstm_params(cfg: ModelConfig) -> float:
    d = cfg.d_model
    return d * 3 * d + 2 * d * cfg.n_heads + 2 * d * d


def _slstm_params(cfg: ModelConfig) -> float:
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    return 4 * d * d + h * hd * 4 * hd + d * d


def _xlstm_split(cfg: ModelConfig) -> Tuple[int, int]:
    every = cfg.slstm_every or (cfg.n_layers + 1)
    n_s = cfg.n_layers // every
    return n_s, every - 1


# ---------------------------------------------------------------------------
# FLOPs (forward, per *token*; attention terms take the context length)
# ---------------------------------------------------------------------------

def _attn_flops_token(cfg: ModelConfig, ctx: float) -> float:
    d, hd = cfg.d_model, cfg.hd
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    proj = 2 * d * hd * (hq + 2 * hkv) + 2 * hq * hd * d
    sdpa = 4 * hq * hd * ctx
    return proj + sdpa


def _mlp_flops_token(cfg: ModelConfig) -> float:
    return 6 * cfg.d_model * cfg.d_ff


def _moe_flops_token(cfg: ModelConfig) -> float:
    router = 2 * cfg.d_model * cfg.n_experts
    experts = (6 * cfg.d_model * cfg.d_ff * cfg.top_k
               * cfg.capacity_factor)
    return router + experts


def _mamba_flops_token(cfg: ModelConfig, chunk: float) -> float:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = di // cfg.ssm_head_dim
    proj = 2 * d * (2 * di + 2 * n + h) + 2 * di * d
    conv = 2 * cfg.conv_width * (di + 2 * n)
    intra = 2 * chunk * n + 2 * chunk * di       # cb + weighted sum
    inter = 4 * di * n                           # y_inter + state update
    return proj + conv + intra + inter


def _mlstm_flops_token(cfg: ModelConfig, chunk: float) -> float:
    d = cfg.d_model
    hd = d // cfg.n_heads
    proj = 2 * d * 3 * d + 4 * d * d             # qkv + gate + out
    intra = 4 * chunk * d
    inter = 4 * d * hd
    return proj + intra + inter


def _slstm_flops_token(cfg: ModelConfig) -> float:
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    return 8 * d * d + 2 * h * hd * 4 * hd + 2 * d * d


def forward_flops(cfg: ModelConfig, n_tokens: float, ctx: float,
                  mode: str) -> float:
    """Forward FLOPs for ``n_tokens`` each attending over ``ctx``."""
    fam = cfg.family
    d = cfg.d_model
    win_ctx = min(ctx, cfg.window) if cfg.long_attention == "window" \
        else ctx
    chunk = min(256.0, max(ctx, 1.0))
    per_tok = 0.0
    if fam in ("dense", "moe"):
        layer = _attn_flops_token(cfg, ctx) + (
            _moe_flops_token(cfg) if fam == "moe"
            else _mlp_flops_token(cfg))
        per_tok = cfg.n_layers * layer
    elif fam == "hybrid":
        n_apps = -(-cfg.n_layers // cfg.attn_every)
        per_tok = (cfg.n_layers * _mamba_flops_token(cfg, chunk)
                   + n_apps * (_attn_flops_token(cfg, win_ctx)
                               + _mlp_flops_token(cfg)))
    elif fam == "ssm":
        n_s, _ = _xlstm_split(cfg)
        per_tok = ((cfg.n_layers - n_s) * _mlstm_flops_token(cfg, chunk)
                   + n_s * _slstm_flops_token(cfg))
    elif fam == "encdec":
        enc_tokens = cfg.enc_seq
        enc = cfg.n_enc_layers * (_attn_flops_token(cfg, enc_tokens)
                                  + _mlp_flops_token(cfg))
        cross_kv = (2 * 2 * cfg.n_kv_heads * cfg.hd * d * enc_tokens
                    * cfg.n_layers)
        cross_tok = (2 * d * cfg.n_heads * cfg.hd
                     + 4 * cfg.n_heads * cfg.hd * enc_tokens
                     + 2 * cfg.n_heads * cfg.hd * d
                     + _mlp_flops_token(cfg))
        dec = cfg.n_layers * (_attn_flops_token(cfg, ctx)
                              + _mlp_flops_token(cfg) + cross_tok)
        return (n_tokens * dec + enc * enc_tokens + cross_kv
                + n_tokens * 2 * d * cfg.vocab)
    elif fam == "vlm":
        src = cfg.vision_tokens
        n_cross = cfg.n_layers // cfg.cross_attn_every
        cross_kv = 2 * 2 * cfg.n_kv_heads * cfg.hd * d * src * n_cross
        cross_tok = (2 * d * cfg.n_heads * cfg.hd
                     + 4 * cfg.n_heads * cfg.hd * src
                     + 2 * cfg.n_heads * cfg.hd * d
                     + _mlp_flops_token(cfg))
        per_tok = (cfg.n_layers * (_attn_flops_token(cfg, ctx)
                                   + _mlp_flops_token(cfg))
                   + n_cross * cross_tok)
        return (n_tokens * per_tok + cross_kv
                + n_tokens * 2 * d * cfg.vocab)
    logits = 2 * d * cfg.vocab
    return n_tokens * (per_tok + logits)


# ---------------------------------------------------------------------------
# whole-step cost model
# ---------------------------------------------------------------------------

def step_costs(cfg: ModelConfig, shape: ShapeConfig,
               mesh_shape: Dict[str, int],
               microbatches: int = 1,
               opt_state_bytes_per_param: int = 8) -> Costs:
    n_total, n_active = param_count(cfg)
    chips = 1
    for v in mesh_shape.values():
        chips *= v
    tp = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    pods = mesh_shape.get("pod", 1)
    c = Costs(n_params=n_total, n_active_params=n_active)
    d = cfg.d_model
    act_bytes = 2  # bf16

    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        # executed attention context: the plain-SDPA path (T <= 8192)
        # runs the full masked T x T matmul; the blockwise path skips
        # future KV blocks, approaching the causal T/2 average.
        ctx = shape.seq_len if shape.seq_len <= 8192 \
            else shape.seq_len / 2
        fwd = forward_flops(cfg, toks, ctx, "train")
        c.flops = 3 * fwd                 # fwd + 2x bwd
        c.model_flops = 6 * n_active * toks
        # HBM: params/grads/opt traffic + rematerialised activations
        param_traffic = (3 * n_total * BYTES_PER_PARAM           # read f+b, write
                         + 2 * n_total * 4                        # grad rw (f32)
                         + 2 * n_total * opt_state_bytes_per_param)
        layer_act = toks * d * act_bytes
        n_lay = cfg.n_layers + getattr(cfg, "n_enc_layers", 0)
        act_traffic = 6 * n_lay * layer_act   # save+reload+recompute
        c.hbm_bytes = param_traffic + act_traffic
        # collectives: DP grad reduce + ZeRO gather + TP activation
        ring = 2 * (dp - 1) / dp if dp > 1 else 0.0
        grad_bytes = n_total * BYTES_PER_PARAM * ring
        tp_ring = 2 * (tp - 1) / tp if tp > 1 else 0.0
        # 2 all-reduces per layer (attn out + mlp out) on [B,T,d];
        # under sequence parallelism the psum lowers to reduce-scatter
        # + all-gather: half the ring bytes.
        sp = 0.5 if cfg.seq_parallel else 1.0
        tp_bytes = 2 * n_lay * toks * d * act_bytes * tp_ring * sp
        if cfg.family == "moe":
            # EP all-to-all: dispatch+combine, 2x each way; int8
            # payloads (+ bf16 scales) cut bytes to ~0.53x.
            a2a_scale = (0.5 + 1.0 / d) if cfg.moe_quant_dispatch \
                else 1.0
            tp_bytes += 4 * cfg.n_layers * toks * cfg.top_k * d \
                * act_bytes / tp * a2a_scale
        inter_frac = (pods - 1) / pods if pods > 1 else 0.0
        c.coll_inter_bytes = grad_bytes * inter_frac
        c.coll_intra_bytes = grad_bytes * (1 - inter_frac) + tp_bytes
    elif shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        ctx = shape.seq_len if shape.seq_len <= 8192 \
            else shape.seq_len / 2
        c.flops = forward_flops(cfg, toks, ctx, "prefill")
        c.model_flops = 2 * n_active * toks
        c.hbm_bytes = (n_total * BYTES_PER_PARAM
                       + 8 * (cfg.n_layers
                              + getattr(cfg, "n_enc_layers", 0))
                       * toks * d * act_bytes)
        tp_ring = 2 * (tp - 1) / tp if tp > 1 else 0.0
        c.coll_intra_bytes = 2 * cfg.n_layers * toks * d * act_bytes \
            * tp_ring
    else:  # decode: one token per sequence against ctx
        toks = shape.global_batch
        ctx = shape.seq_len
        c.flops = forward_flops(cfg, toks, ctx, "decode")
        c.model_flops = 2 * n_active * toks
        cache = _decode_cache_bytes(cfg, shape)
        c.hbm_bytes = n_total * BYTES_PER_PARAM + cache
        tp_ring = 2 * (tp - 1) / tp if tp > 1 else 0.0
        c.coll_intra_bytes = 2 * cfg.n_layers * toks * d * act_bytes \
            * tp_ring
    return c


def _decode_cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Bytes read from the KV cache / recurrent state per decode step."""
    b, s = shape.global_batch, shape.seq_len
    eff = min(s, cfg.window) if cfg.long_attention == "window" else s
    fam = cfg.family
    kv_bytes = (1.0 + 2.0 / cfg.hd) if cfg.kv_cache_dtype == "int8" \
        else BYTES_PER_PARAM
    kv_row = 2 * cfg.n_kv_heads * cfg.hd * kv_bytes
    if fam in ("dense", "moe", "vlm", "encdec"):
        n_l = cfg.n_layers
        extra = 0.0
        if fam == "vlm":
            extra = (cfg.n_layers // cfg.cross_attn_every) * \
                cfg.vision_tokens * kv_row * b
        if fam == "encdec":
            extra = cfg.n_layers * cfg.enc_seq * kv_row * b
        return n_l * b * eff * kv_row + extra
    if fam == "hybrid":
        n_apps = -(-cfg.n_layers // cfg.attn_every)
        di = cfg.ssm_expand * cfg.d_model
        h = di // cfg.ssm_head_dim
        ssm_state = cfg.n_layers * b * h * cfg.ssm_head_dim \
            * cfg.ssm_state * 4
        return n_apps * b * min(eff, cfg.window) * kv_row + 2 * ssm_state
    if fam == "ssm":
        n_s, _ = _xlstm_split(cfg)
        hd = cfg.d_model // cfg.n_heads
        m_state = (cfg.n_layers - n_s) * b * cfg.n_heads * hd * hd * 4
        s_state = n_s * b * cfg.d_model * 4 * 4
        return 2 * (m_state + s_state)
    return 0.0


# ---------------------------------------------------------------------------
# compiled-HLO collective extraction
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u64|u32|s8|u8|pred)"
                       r"\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s8": 1, "u8": 1,
                "pred": 1}
_COLL_RE = re.compile(
    r"^\s*(?:%\S+\s*=\s*)?(\([^)]*\)|\S+)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(", re.M)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for x in dims.split(","):
            if x:
                n *= int(x)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op, by op kind.

    NOTE: ops inside ``while`` bodies are counted once (XLA prints the
    body once); the analytic model is authoritative for loop-carried
    collectives and this parse is the structural cross-check.
    """
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0.0) + _shape_bytes(shape_txt)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out
