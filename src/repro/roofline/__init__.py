"""Subpackage."""
