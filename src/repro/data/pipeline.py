"""Deterministic synthetic token pipeline, sharded per data-parallel rank.

Production-shaped: each host produces only its DP shard of the global
batch from a seed + step index (restart-safe: the stream is a pure
function of (seed, step), so checkpoint restart replays exactly), with
a background prefetch thread keeping ``prefetch`` batches ready.

The synthetic distribution is a Zipfian unigram mix with a Markov
component so that losses move meaningfully during the integration
tests (pure-uniform tokens give a flat loss surface).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class TokenPipeline:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 microbatches: int = 1, dp_rank: int = 0,
                 dp_size: int = 1, seed: int = 0,
                 extra_shapes: Optional[Dict] = None,
                 prefetch: int = 2):
        assert global_batch % (dp_size * microbatches) == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.mb = microbatches
        self.local_batch = global_batch // dp_size
        self.dp_rank = dp_rank
        self.seed = seed
        self.extra_shapes = extra_shapes or {}
        # Zipf-ish unigram distribution over a capped support
        support = min(vocab, 32_768)
        ranks = np.arange(1, support + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._support = support
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- pure batch function (restart-safe) ----------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.dp_rank)
        b, t = self.local_batch, self.seq_len
        base = rng.choice(self._support, size=(b, t + 1),
                          p=self._probs)
        # Markov smoothing: with p=0.3 repeat the previous token + 1
        rep = rng.random((b, t + 1)) < 0.3
        shifted = np.roll(base, 1, axis=1) + 1
        tokens = np.where(rep, shifted % self.vocab, base).astype(
            np.int32)
        batch = {
            "tokens": tokens[:, :-1].reshape(self.mb, b // self.mb, t),
            "labels": tokens[:, 1:].reshape(self.mb, b // self.mb, t),
        }
        for name, (shape, dtype) in self.extra_shapes.items():
            batch[name] = rng.standard_normal(
                (self.mb, b // self.mb, *shape)).astype(dtype) * 0.1
        return batch

    # -- prefetch thread ------------------------------------------------
    def start(self, from_step: int = 0) -> None:
        def worker():
            step = from_step
            while not self._stop.is_set():
                try:
                    self._q.put(self.batch_at(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def next_prefetched(self) -> Dict[str, np.ndarray]:
        return self._q.get()
