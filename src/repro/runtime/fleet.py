"""FleetScheduler: the paper's AR core managing TPU chips for ML jobs.

Integration of the reproduction with the training/serving framework:
the production fleet (2 pods x 256 chips) is the paper's multiprocessor
system — PEs are chips.  Every training or serving run of an assigned
architecture is an AR request: ``n_pe`` = the job's chip footprint,
``t_du`` = estimated steps x roofline step time (from
:mod:`repro.roofline.analysis`), ``t_r``/``t_dl`` from the user's SLO.
Admission, placement and policy choice reuse :mod:`repro.core`
unchanged — the scheduler engine is the deliverable, the fleet is its
first production consumer.

Fault tolerance (the general-deadline slack is what makes this work —
the paper's central observation):

* ``fail_chip``: the chip gets a repair reservation; every job holding
  it has its reservation deleted and its *remaining* work (back to the
  last checkpoint) re-submitted as a new AR request within the original
  deadline.
* ``report_straggler``: a job running slower than its reservation is
  re-reserved with the stretched duration while its deadline slack
  absorbs the slip.
* ``rescale``: elastic re-reservation of the remaining work on a
  different chip count (duration rescaled by the roofline model).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Dict, List, Optional, Sequence

from repro.configs import get_config, shape_by_name
from repro.core import ARRequest, Policy, make_scheduler
from repro.core import batch as batch_lib
from repro.roofline import analysis as roof


class JobState(str, enum.Enum):
    REJECTED = "rejected"
    RESERVED = "reserved"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"


@dataclasses.dataclass
class FleetJob:
    job_id: int
    arch: str
    shape: str
    n_chips: int
    n_steps: int
    submit_time: int
    ready: int
    deadline: int
    state: JobState = JobState.RESERVED
    t_start: int = -1
    t_end: int = -1
    chips: tuple = ()
    checkpoint_interval: int = 600        # seconds of work per ckpt
    work_done: int = 0                    # seconds of completed work
    preemptions: int = 0

    @property
    def step_time(self) -> float:
        return (self.t_end - self.t_start) / max(self.n_steps, 1)


def estimate_duration(arch: str, shape_name: str, n_chips: int,
                      n_steps: int, efficiency: float = 0.5) -> int:
    """Roofline-model duration estimate for ``n_steps`` on ``n_chips``.

    ``efficiency`` discounts peak (achieved fraction of roofline).
    """
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    model = min(16, n_chips)
    mesh = {"data": max(n_chips // model, 1), "model": model}
    costs = roof.step_costs(cfg, shape, mesh)
    terms = costs.terms(n_chips)
    step_s = max(terms["compute_s"], terms["memory_s"],
                 terms["collective_s"]) / efficiency
    return max(int(step_s * n_steps) + 1, 60)


class FleetScheduler:
    def __init__(self, n_chips: int = 512,
                 policy: Policy = Policy.PE_W,
                 engine: str = "host",
                 repair_seconds: int = 1800,
                 restart_overhead: int = 120):
        self.n_chips = n_chips
        self.policy = policy
        self.core = make_scheduler(n_chips, engine=engine)
        self.repair_seconds = repair_seconds
        self.restart_overhead = restart_overhead
        self.jobs: Dict[int, FleetJob] = {}
        self._ids = itertools.count()
        self.now = 0
        self.events: List[tuple] = []     # (time, kind, job_id) log

    # ------------------------------------------------------------------
    def advance(self, t: int) -> None:
        """Move the fleet clock; complete reservations that finished."""
        assert t >= self.now
        self.now = t
        for job in self.jobs.values():
            if job.state in (JobState.RESERVED, JobState.RUNNING):
                if job.t_start <= t and job.state == JobState.RESERVED:
                    job.state = JobState.RUNNING
                if job.t_end <= t:
                    job.work_done = job.t_end - job.t_start
                    job.state = JobState.DONE
                    self.core.delete_allocation(
                        job.t_start, job.t_end, list(job.chips))
                    self.events.append((t, "complete", job.job_id))

    # ------------------------------------------------------------------
    def _build_job(self, arch: str, shape: str, n_chips: int,
                   n_steps: int, ready: Optional[int] = None,
                   deadline_slack: float = 2.0):
        """Shared job/request construction for submit and submit_batch."""
        dur = estimate_duration(arch, shape, n_chips, n_steps)
        ready = self.now if ready is None else ready
        deadline = ready + int(dur * (1.0 + deadline_slack))
        job = FleetJob(
            job_id=next(self._ids), arch=arch, shape=shape,
            n_chips=n_chips, n_steps=n_steps, submit_time=self.now,
            ready=ready, deadline=deadline)
        req = ARRequest(t_a=self.now, t_r=ready, t_du=dur,
                        t_dl=deadline, n_pe=n_chips)
        return job, req

    def _record_decision(self, job: FleetJob,
                         alloc, committed: bool) -> FleetJob:
        """Book-keep one admission outcome (alloc already committed
        when ``committed``; otherwise commit it here)."""
        if alloc is None:
            job.state = JobState.REJECTED
            self.events.append((self.now, "reject", job.job_id))
        else:
            if not committed:
                self.core.add_allocation(alloc.t_s, alloc.t_e,
                                         list(alloc.pe_ids))
            job.t_start, job.t_end = alloc.t_s, alloc.t_e
            job.chips = alloc.pe_ids
            self.events.append((self.now, "reserve", job.job_id))
        self.jobs[job.job_id] = job
        return job

    def submit(self, arch: str, shape: str, n_chips: int,
               n_steps: int, ready: Optional[int] = None,
               deadline_slack: float = 2.0,
               policy: Optional[Policy] = None) -> FleetJob:
        """Admission-control one job; returns it (possibly REJECTED)."""
        job, req = self._build_job(arch, shape, n_chips, n_steps,
                                   ready, deadline_slack)
        alloc = self.core.find_allocation(
            req, policy or self.policy, t_now=self.now)
        return self._record_decision(job, alloc, committed=False)

    # ------------------------------------------------------------------
    def submit_batch(self, specs: Sequence[Dict],
                     policy: Optional[Policy] = None) -> List[FleetJob]:
        """Bulk admission control: one device scan for many jobs.

        Each spec is a dict with the keyword arguments of
        :meth:`submit` (``arch``, ``shape``, ``n_chips``, ``n_steps``,
        optional ``ready``/``deadline_slack``).  On a device-engine
        core the whole batch goes through ``core.admit_stream`` — a
        single jitted ``lax.scan`` with no per-job host round-trips;
        decisions are identical to sequential submission because the
        scan commits each accepted job before considering the next.
        Completion release stays with :meth:`advance`
        (``auto_release=False``).  Other engines fall back to the
        sequential loop.
        """
        pol = policy or self.policy
        if not hasattr(self.core, "admit_stream"):
            return [self.submit(policy=pol, **spec) for spec in specs]
        built = [self._build_job(**spec) for spec in specs]
        decisions = self.core.admit_stream([req for _, req in built],
                                           pol, auto_release=False)
        return [
            self._record_decision(job, alloc, committed=True)
            for (job, _), alloc in zip(
                built, batch_lib.decisions_to_allocations(decisions))]

    # ------------------------------------------------------------------
    def submit_malleable(self, arch: str, shape: str,
                         chip_options: List[int], n_steps: int,
                         ready: Optional[int] = None,
                         deadline: Optional[int] = None) -> FleetJob:
        """Malleable AR job (paper Section 7): the request's PE count is
        not fixed.  Per the paper's proposal, the malleable requirement
        is *translated into a group of rigid requests* (one per chip
        count, with the duration rescaled by the roofline model) and
        ``findAllocation`` evaluates each; the completion-time-earliest
        feasible allocation wins (the "new criterion" the paper leaves
        open — earliest finish maximises remaining fleet flexibility).
        Each rigid variant is searched with FF so that the cross-
        variant earliest-finish comparison is coherent.
        """
        ready = self.now if ready is None else ready
        best = None           # (finish_time, alloc, n_chips, dur)
        durations = {n: estimate_duration(arch, shape, n, n_steps)
                     for n in chip_options}
        dl = deadline if deadline is not None else \
            ready + int(2.0 * max(durations.values()))
        for n_chips in sorted(chip_options):
            dur = durations[n_chips]
            if ready + dur > dl:
                continue      # this rigid variant cannot meet the SLO
            req = ARRequest(t_a=self.now, t_r=ready, t_du=dur,
                            t_dl=dl, n_pe=n_chips)
            alloc = self.core.find_allocation(req, Policy.FF,
                                              t_now=self.now)
            if alloc is None:
                continue
            finish = alloc.t_s + dur
            if best is None or finish < best[0]:
                best = (finish, alloc, n_chips, dur)
        job = FleetJob(
            job_id=next(self._ids), arch=arch, shape=shape,
            n_chips=best[2] if best else min(chip_options),
            n_steps=n_steps, submit_time=self.now, ready=ready,
            deadline=dl)
        if best is None:
            job.state = JobState.REJECTED
            self.events.append((self.now, "reject-malleable",
                                job.job_id))
        else:
            _, alloc, n_chips, dur = best
            self.core.add_allocation(alloc.t_s, alloc.t_e,
                                     list(alloc.pe_ids))
            job.t_start, job.t_end = alloc.t_s, alloc.t_e
            job.chips = alloc.pe_ids
            self.events.append((self.now, "reserve-malleable",
                                job.job_id))
        self.jobs[job.job_id] = job
        return job

    # ------------------------------------------------------------------
    def _release(self, job: FleetJob) -> None:
        self.core.delete_allocation(job.t_start, job.t_end,
                                    list(job.chips))
        job.chips = ()

    def _resubmit_remainder(self, job: FleetJob, extra_duration: int = 0,
                            n_chips: Optional[int] = None) -> bool:
        """Re-reserve the job's remaining work within its deadline."""
        done = max(0, min(self.now, job.t_end) - job.t_start)
        ckpt_done = (done // job.checkpoint_interval) \
            * job.checkpoint_interval
        total = job.t_end - job.t_start
        remaining = total - ckpt_done + self.restart_overhead \
            + extra_duration
        n_chips = n_chips or job.n_chips
        if n_chips != job.n_chips:
            frac = remaining / max(total, 1)
            full = estimate_duration(job.arch, job.shape, n_chips,
                                     job.n_steps)
            remaining = int(full * frac) + self.restart_overhead
        if self.now + remaining > job.deadline:
            job.state = JobState.FAILED
            self.events.append((self.now, "deadline-miss", job.job_id))
            return False
        req = ARRequest(t_a=self.now, t_r=self.now, t_du=remaining,
                        t_dl=job.deadline, n_pe=n_chips)
        alloc = self.core.find_allocation(req, self.policy,
                                          t_now=self.now)
        if alloc is None:
            job.state = JobState.FAILED
            self.events.append((self.now, "no-capacity", job.job_id))
            return False
        self.core.add_allocation(alloc.t_s, alloc.t_e,
                                 list(alloc.pe_ids))
        job.t_start, job.t_end = alloc.t_s, alloc.t_e
        job.chips = alloc.pe_ids
        job.n_chips = n_chips
        job.preemptions += 1
        job.state = JobState.RESERVED if alloc.t_s > self.now \
            else JobState.RUNNING
        self.events.append((self.now, "re-reserve", job.job_id))
        return True

    # ------------------------------------------------------------------
    def fail_chip(self, chip_id: int) -> List[int]:
        """Hardware failure: repair-reserve the chip, migrate its jobs."""
        affected = [j for j in self.jobs.values()
                    if chip_id in j.chips
                    and j.state in (JobState.RESERVED, JobState.RUNNING)]
        for job in affected:
            self._release(job)
        # the chip is unavailable while under repair
        self.core.add_allocation(
            self.now, self.now + self.repair_seconds, [chip_id])
        self.events.append((self.now, "chip-fail", chip_id))
        migrated = []
        for job in affected:
            if self._resubmit_remainder(job):
                migrated.append(job.job_id)
        return migrated

    def report_straggler(self, job_id: int,
                         slowdown: float = 1.5) -> bool:
        """The job is running ``slowdown``x slower than reserved:
        stretch its reservation into the deadline slack."""
        job = self.jobs[job_id]
        if job.state not in (JobState.RUNNING, JobState.RESERVED):
            return False
        remaining = max(job.t_end - self.now, 0)
        extra = int(remaining * (slowdown - 1.0))
        self._release(job)
        self.events.append((self.now, "straggler", job_id))
        return self._resubmit_remainder(job, extra_duration=extra)

    def rescale(self, job_id: int, new_n_chips: int) -> bool:
        """Elastic scaling: move the remaining work to a new footprint."""
        job = self.jobs[job_id]
        if job.state not in (JobState.RUNNING, JobState.RESERVED):
            return False
        self._release(job)
        self.events.append((self.now, "rescale", job_id))
        return self._resubmit_remainder(job, n_chips=new_n_chips)

    # ------------------------------------------------------------------
    def utilisation(self, horizon: int) -> float:
        area = sum(
            (min(j.t_end, self.now + horizon) - max(j.t_start, self.now))
            * j.n_chips
            for j in self.jobs.values()
            if j.state in (JobState.RESERVED, JobState.RUNNING)
            and j.t_end > self.now)
        return area / (self.n_chips * horizon)

    def summary(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for j in self.jobs.values():
            out[j.state.value] = out.get(j.state.value, 0) + 1
        return out
