"""FleetScheduler: the paper's AR core managing TPU chips for ML jobs.

Integration of the reproduction with the training/serving framework:
the production fleet (2 pods x 256 chips) is the paper's multiprocessor
system — PEs are chips.  Every training or serving run of an assigned
architecture is an AR request: ``n_pe`` = the job's chip footprint,
``t_du`` = estimated steps x roofline step time (from
:mod:`repro.roofline.analysis`), ``t_r``/``t_dl`` from the user's SLO.
Admission, placement and policy choice reuse :mod:`repro.core`
unchanged — the scheduler engine is the deliverable, the fleet is its
first production consumer.

With ``n_partitions > 1`` the fleet is *partitioned*: the chips split
into equal partitions, each one lane of a single vmapped scheduler
state (:class:`PartitionedCore`, DESIGN.md §4).  Bulk submissions are
routed across partitions (round-robin, least-loaded, or
best-acceptance probes) and admitted in one device dispatch; jobs
never span partitions.

Fault tolerance (the general-deadline slack is what makes this work —
the paper's central observation):

* ``fail_chip``: the chip gets a repair reservation; every job holding
  it has its reservation deleted and its *remaining* work (back to the
  last checkpoint) re-submitted as a new AR request within the original
  deadline.
* ``report_straggler``: a job running slower than its reservation is
  re-reserved with the stretched duration while its deadline slack
  absorbs the slip.
* ``rescale``: elastic re-reservation of the remaining work on a
  different chip count (duration rescaled by the roofline model).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.api import ReservationService, ServiceConfig
from repro.api.config import ROUTINGS  # noqa: F401  (re-export)
from repro.configs import get_config, shape_by_name
from repro.core import ARRequest, Policy
from repro.core import batch as batch_lib
from repro.core import ensemble as ens_lib
from repro.core import timeline as tl_lib
from repro.core.policies import policy_index
from repro.core.types import Allocation, T_INF
from repro.launch.mesh import resolve_placement
from repro.roofline import analysis as roof
from repro.sharding import rules as shard_rules


class JobState(str, enum.Enum):
    REJECTED = "rejected"
    RESERVED = "reserved"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"


@dataclasses.dataclass
class FleetJob:
    job_id: int
    arch: str
    shape: str
    n_chips: int
    n_steps: int
    submit_time: int
    ready: int
    deadline: int
    state: JobState = JobState.RESERVED
    t_start: int = -1
    t_end: int = -1
    chips: tuple = ()
    partition: int = -1                   # -1: unpartitioned fleet
    checkpoint_interval: int = 600        # seconds of work per ckpt
    work_done: int = 0                    # seconds of completed work
    preemptions: int = 0

    @property
    def step_time(self) -> float:
        return (self.t_end - self.t_start) / max(self.n_steps, 1)


def estimate_duration(arch: str, shape_name: str, n_chips: int,
                      n_steps: int, efficiency: float = 0.5) -> int:
    """Roofline-model duration estimate for ``n_steps`` on ``n_chips``.

    ``efficiency`` discounts peak (achieved fraction of roofline).
    """
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    model = min(16, n_chips)
    mesh = {"data": max(n_chips // model, 1), "model": model}
    costs = roof.step_costs(cfg, shape, mesh)
    terms = costs.terms(n_chips)
    step_s = max(terms["compute_s"], terms["memory_s"],
                 terms["collective_s"]) / efficiency
    return max(int(step_s * n_steps) + 1, 60)


# -- on-device fleet matching (DESIGN.md §9) ---------------------------
#
# Policies whose slot *selection* depends only on occupancy inside the
# request's own search window [t_r, t_dl): FF orders by start time and
# the PE policies by free-PE count at the candidate, both window-local
# quantities.  The duration policies score by rectangle extent, which
# reaches outside the window to the nearest blocking boundary, so any
# same-round commit on a lane may move their chosen start.
_WINDOW_LOCAL_POLICIES = frozenset(
    (Policy.FF, Policy.PE_B, Policy.PE_W))


@jax.jit
def _match_scan(found, t_s, t_e, t_r, t_dl, pending, window_local,
                monotone):
    """One matching round over an ``[N, E]`` probe tensor.

    A ``lax.scan`` over the requests in arrival order.  The carry
    tracks, per lane, whether this round committed to it plus a
    bounding interval ``[cmin, cmax)`` over the round's committed
    slots, and one scalar bounding interval ``[dmin, dmax)`` over the
    *deferred* requests' search windows.  Request i's probe of lane e
    is *valid* (still equal to a fresh sequential probe) iff no
    earlier commit this round can have changed e's answer: for
    window-local policies that means no committed slot overlapping
    ``[t_r_i, t_dl_i)``; for rectangle-scored policies any commit on
    the lane invalidates it.  The bounding intervals are conservative
    — false overlaps only defer, never misroute.

    Sequential order also demands that a request never finalizes
    *ahead* of a still-deferred earlier arrival whose eventual commit
    could change its answer (or be changed by its commit): request i
    additionally requires its window to be disjoint from every
    deferred window so far (``clear``); rectangle policies require no
    deferral at all.

    Finalization (bit-exact vs the sequential probe-commit oracle):
    pick ``lane* = argmin`` start over *valid feasible* lanes (ties
    to the lowest index, as ``np.argmin``).  Finalize when every lane
    is valid and the row is clear — then it equals a fresh sequential
    probe.  Otherwise finalize only under ready-start dominance:
    ``t_s[lane*] == t_r_i``, the row is clear, and every lane below
    ``lane*`` is valid — no lane can start before the ready time and
    equal starts lose the tie to ``lane*``.  FF starts are monotone
    under added occupancy, so under FF an invalid lane below ``lane*``
    whose stale start already exceeds ``t_r_i`` is also safe
    (``monotone``).  Rejection is final regardless of staleness: a
    commit only adds occupancy, so a row infeasible on every lane
    stays infeasible.  Everything else defers to the next round's
    re-probe.  The first pending request of a round always resolves,
    so a round finalizes at least one request.
    """
    n_lanes = found.shape[1]
    lane_idx = jnp.arange(n_lanes)

    def step(carry, x):
        committed, cmin, cmax, dmin, dmax, any_def = carry
        f, ts, te, tr, tdl, live = x
        overlap = (tr < cmax) & (tdl > cmin)
        valid = jnp.where(window_local, ~(committed & overlap),
                          ~committed)
        clear = jnp.where(window_local,
                          ~((tr < dmax) & (tdl > dmin)), ~any_def)
        tv = jnp.where(f & valid, ts, T_INF)
        tvs = jnp.where(f, ts, T_INF)         # stale, unmasked
        best = jnp.min(tv)
        lane = jnp.argmin(tv).astype(jnp.int32)
        feasible = best < T_INF
        all_valid = jnp.all(valid) & clear
        safe_below = valid | (lane_idx >= lane) \
            | (monotone & (tvs > tr))
        dominant = feasible & (best == tr) & clear \
            & jnp.all(safe_below)
        assign = live & feasible & (all_valid | dominant)
        reject = live & ~jnp.any(f)
        defer = live & ~assign & ~reject
        onehot = (lane_idx == lane) & assign
        committed = committed | onehot
        cmin = jnp.where(onehot, jnp.minimum(cmin, ts), cmin)
        cmax = jnp.where(onehot, jnp.maximum(cmax, te), cmax)
        dmin = jnp.where(defer, jnp.minimum(dmin, tr), dmin)
        dmax = jnp.where(defer, jnp.maximum(dmax, tdl), dmax)
        any_def = any_def | defer
        out_lane = jnp.where(assign, lane, jnp.int32(-1))
        return ((committed, cmin, cmax, dmin, dmax, any_def),
                (out_lane, reject))

    init = (jnp.zeros((n_lanes,), bool),
            jnp.full((n_lanes,), T_INF, jnp.int32),
            jnp.zeros((n_lanes,), jnp.int32),
            jnp.int32(T_INF), jnp.int32(0), jnp.asarray(False))
    _, (lanes, rejects) = jax.lax.scan(
        step, init, (found, t_s, t_e, t_r, t_dl, pending))
    return lanes, rejects


@jax.jit
def _least_loaded_scan(load, n_pe, t_du):
    """Greedy least-loaded routing over the device load vector.

    Identical decision sequence to the host greedy it replaces: lane =
    argmin of committed + planned PE-seconds (float32 on both sides so
    accumulation order ties break identically), planned area added
    before the next request.  The scratch copy is never written back —
    committed load lands only after the grouped commit.
    """

    def step(ld, x):
        npe, tdu = x
        lane = jnp.argmin(ld).astype(jnp.int32)
        ld = ld.at[lane].add(npe.astype(ld.dtype) * tdu.astype(ld.dtype))
        return ld, lane

    _, lanes = jax.lax.scan(step, load, (n_pe, t_du))
    return lanes


class PartitionedCore:
    """E cluster partitions behind one vmapped scheduler state.

    The fleet's chips are split into ``n_partitions`` equal partitions;
    each partition is one lane of a stacked
    :class:`~repro.core.timeline.SchedulerState` (DESIGN.md §4), so
    bulk admission steps every partition in a single jitted dispatch
    (``admit_stream_ensemble``) and the best-acceptance probe searches
    all partitions at once (``find_allocation_ensemble``).

    The interface mirrors the single-cluster engines — ``find`` /
    ``add`` / ``delete`` with *global* chip ids — plus the routed bulk
    path :meth:`admit_stream_allocations`.  An allocation never spans
    partitions: requests wider than a partition are rejected.

    Bulk ingress is one-dispatch-shaped for every routing (DESIGN.md
    §9): ``least_loaded`` routes with a device scan over the
    device-resident load vector, ``best_acceptance`` runs bounded
    probe → match → grouped-commit rounds over an ``[N, E]`` probe
    tensor, and all routings commit through one grouped
    ``admit_stream_ensemble_auto`` dispatch.  ``self.dispatches``
    counts device dispatches for the ingress benchmarks.

    With ``backfill`` set (and ``auto_release=True``, required) every
    partition lane carries the PR 4 deferral queue: rejected requests
    park (up to ``park_capacity``) and retry as completed
    reservations release on :meth:`release_until`.
    """

    #: probe → match → commit rounds before the exact sequential
    #: fallback takes the remaining (pathologically colliding) requests
    match_max_rounds: int = 8

    def __init__(self, n_chips: int, n_partitions: int,
                 capacity: int = 128, pending_capacity: int = 256,
                 use_kernel: bool = False, placement="auto",
                 park_capacity: int = 0, backfill: str = "none",
                 auto_release: bool = False,
                 match_rounds: Optional[int] = None,
                 index_tile: Optional[int] = None):
        if n_partitions < 1 or n_chips % n_partitions:
            raise ValueError(
                f"n_chips={n_chips} not divisible into "
                f"{n_partitions} partitions")
        if backfill != "none" and not auto_release:
            raise ValueError(
                "backfilling partitions replay parked requests from "
                "the pending-release buffer; auto_release must be on")
        if backfill != "none" and park_capacity <= 0:
            raise ValueError(
                "backfilling partitions need park_capacity > 0")
        self.n_chips = n_chips
        self.n_partitions = n_partitions
        self.chips_per_part = n_chips // n_partitions
        self.use_kernel = use_kernel
        self.backfill = backfill
        self.auto_release = auto_release
        # partition axis -> mesh data axis (DESIGN.md §8): the bulk
        # admission dispatch steps each device's partition slice
        # locally; decisions are placement-invariant
        self.mesh = resolve_placement(placement, n_partitions)
        # probe → match rounds pay only when the [N, E] probe tensor
        # genuinely evaluates in parallel — sharded over >1 device or
        # offloaded to the availscan kernel.  On a single host device
        # every probe row is the same serial availability scan the
        # fused matcher already runs per step, so rounds would only
        # add redundant re-probe compute: go straight to the exact
        # fused scan.  ``match_rounds`` overrides the auto choice.
        if match_rounds is None:
            probe_parallel = use_kernel or (
                self.mesh is not None and self.mesh.devices.size > 1)
            match_rounds = self.match_max_rounds if probe_parallel \
                else 0
        self.match_max_rounds = int(match_rounds)
        # index_tile attaches the hierarchical availability index
        # (DESIGN.md §12) to every partition lane: the [N, E] probe's
        # vmapped search early-rejects summary-infeasible lanes to the
        # same sentinels a full contraction would produce, prefiltering
        # the match rounds without changing a single routing decision
        self.states = self._put(ens_lib.init_ensemble(
            n_partitions, capacity, self.chips_per_part,
            pending_capacity, park_capacity, index_tile=index_tile))
        self._backfills = ens_lib.backfill_ids(backfill, n_partitions)
        # committed PE-seconds per partition (least-loaded routing):
        # authoritative float32 host ledger + an async device copy so
        # routing scans never pull load back to the host
        self._load_host = np.zeros(n_partitions, np.float32)
        self._load_dev = self._put_load(self._load_host)
        self._rr = 0                      # round-robin cursor
        self.dispatches = 0               # device dispatch counter
        self.last_match_rounds = 0        # rounds of the last matcher

    def _put(self, tree):
        return shard_rules.shard_ensemble(self.mesh, tree)

    def _put_load(self, arr) -> jax.Array:
        vec = jnp.asarray(arr, jnp.float32)
        if self.mesh is not None:
            vec = jax.device_put(vec, shard_rules.fit_sharding(
                self.mesh, vec.shape, shard_rules.lane_spec(1)))
        return vec

    @property
    def load(self) -> List[float]:
        """Committed PE-seconds per partition (host view)."""
        return [float(x) for x in self._load_host]

    @load.setter
    def load(self, values) -> None:
        self._load_host = np.asarray(values, np.float32).copy()
        self._load_dev = self._put_load(self._load_host)

    def _bump_load(self, lane: int, delta: float) -> None:
        self._load_host[lane] += np.float32(delta)
        self._load_dev = self._put_load(self._load_host)

    # -- global chip ids <-> (lane, local) -----------------------------
    def _split(self, pes: Sequence[int]):
        lanes = {p // self.chips_per_part for p in pes}
        if len(lanes) != 1:
            raise ValueError(
                f"allocation spans partitions {sorted(lanes)}")
        lane = lanes.pop()
        return lane, [p - lane * self.chips_per_part for p in pes]

    def _mask(self, local_pes: Sequence[int]) -> jax.Array:
        return tl_lib.ids_to_mask32(local_pes,
                                    self.states.tl.occ.shape[-1])

    def _globalize(self, lane: int, dec) -> Optional[Allocation]:
        alloc = batch_lib.decision_to_allocation(dec)
        if alloc is None:
            return None
        off = lane * self.chips_per_part
        return dataclasses.replace(
            alloc, pe_ids=tuple(p + off for p in alloc.pe_ids))

    # -- the three classic operations (global chip ids) ----------------
    def _lane_update(self, lane: int, t_s: int, t_e: int,
                     local_pes: Sequence[int], is_add: bool) -> None:
        mask = self._mask(local_pes)
        for _ in range(batch_lib.MAX_DOUBLINGS + 1):
            tl = jax.tree_util.tree_map(
                lambda x: x[lane], self.states.tl)
            new_tl, overflow, n_keep = tl_lib.update(
                tl, t_s, t_e, mask, is_add=is_add, with_count=True)
            self.dispatches += 1
            if not bool(overflow):
                self.states = self.states._replace(
                    tl=jax.tree_util.tree_map(
                        lambda full, one: full.at[lane].set(one),
                        self.states.tl, new_tl))
                return
            # watermark protocol (DESIGN.md §3/§4): grow every lane
            # once to the needed record count
            cap = self.states.tl.times.shape[-1]
            self.states = self._put(ens_lib.grow_ensemble(
                self.states,
                max(2 * cap, tl_lib.next_pow2(int(n_keep))),
                self.states.pend_te.shape[-1]))
        raise RuntimeError("partition timeline kept overflowing")

    def add_allocation(self, t_s: int, t_e: int,
                       pes: Sequence[int]) -> None:
        lane, local = self._split(pes)
        self._lane_update(lane, t_s, t_e, local, is_add=True)
        self._bump_load(lane, (t_e - t_s) * len(local))

    def delete_allocation(self, t_s: int, t_e: int,
                          pes: Sequence[int]) -> None:
        lane, local = self._split(pes)
        self._lane_update(lane, t_s, t_e, local, is_add=False)
        self._bump_load(lane, -(t_e - t_s) * len(local))

    def release_until(self, t_now: int) -> None:
        """Advance the auto-release clock on every partition lane."""
        self.states = self._put(
            ens_lib.release_until_ensemble(self.states, t_now))
        self.dispatches += 1

    # -- pre-staged probe structs (reused placement pin) ---------------
    def stage_request(self, req: ARRequest) -> batch_lib.RequestBatch:
        """Stage one request's scalar struct on the fleet placement.

        Pass the result to :meth:`find_allocation` via ``struct=`` to
        reuse the transfer across repeated probes of the same request
        (e.g. the malleable-variant sweep probing per chip count).
        """
        struct = batch_lib.request_struct(req)
        if self.mesh is not None:
            struct = jax.device_put(
                struct, NamedSharding(self.mesh, PartitionSpec()))
        return struct

    def stage_requests(self, requests: Sequence[ARRequest]
                       ) -> batch_lib.RequestBatch:
        """Stage an ``[N]`` request batch, replicated on the mesh.

        One transfer feeds every probe round of the batched matcher.
        """
        batch = batch_lib.requests_to_batch(requests)
        if self.mesh is not None:
            batch = jax.device_put(
                batch, NamedSharding(self.mesh, PartitionSpec()))
        return batch

    def find_allocation(self, req: Optional[ARRequest], policy: Policy,
                        t_now: Optional[int] = None, *,
                        struct: Optional[batch_lib.RequestBatch] = None
                        ) -> Optional[Allocation]:
        """Best-acceptance probe: search every partition in one
        vmapped dispatch, take the earliest feasible start (ties to
        the lowest lane).

        ``struct`` (from :meth:`stage_request`) skips the per-call
        host staging so repeated probes re-use one pinned transfer.
        """
        if struct is None:
            struct = self.stage_request(req)
        if t_now is not None:
            # the search reads its "now" from the struct's t_a
            struct = struct._replace(t_a=jnp.int32(t_now))
        res = ens_lib.find_allocation_ensemble(
            self.states, struct, jnp.int32(policy_index(policy)),
            n_pe=self.chips_per_part, use_kernel=self.use_kernel)
        self.dispatches += 1
        res = jax.tree_util.tree_map(np.asarray, res)   # one sync
        if not res.found.any():
            return None
        t_s = np.where(res.found, res.t_s, T_INF)
        lane = int(np.argmin(t_s))        # argmin ties -> lowest lane
        one = jax.tree_util.tree_map(lambda x: x[lane], res)
        alloc = batch_lib.search_result_to_allocation(one)
        off = lane * self.chips_per_part
        return dataclasses.replace(
            alloc, pe_ids=tuple(p + off for p in alloc.pe_ids))

    # -- routed bulk admission (one-dispatch ingress, DESIGN.md §9) ----
    def route(self, requests: Sequence[ARRequest], routing: str, *,
              policy: Policy = Policy.FF,
              legacy_raise: bool = False) -> List[int]:
        """Assign a partition lane to every request (no commit).

        Every routing returns one lane per request.
        ``best_acceptance`` returns the matcher's probe preview: one
        shared ``[N, E]`` probe of the current timelines under
        ``policy``, each request taking its earliest feasible start
        (ties to the lowest lane), ``-1`` where no partition can host
        it.  The preview is commit-free and therefore ignores
        intra-batch contention — :meth:`admit_stream_allocations` is
        the authoritative matcher (it re-probes between commit
        rounds).  ``legacy_raise=True`` restores the pre-PR 7
        ValueError contract and is deprecated.
        """
        if routing not in ROUTINGS:
            raise ValueError(
                f"unknown routing {routing!r}; pick one of {ROUTINGS}")
        if routing == "best_acceptance":
            if legacy_raise:
                warnings.warn(
                    "route(legacy_raise=True) is deprecated: "
                    "best_acceptance now returns the matcher's lane "
                    "preview instead of raising",
                    DeprecationWarning, stacklevel=2)
                raise ValueError(
                    "best_acceptance routes by probing the timelines, "
                    "not by pre-assignment; use "
                    "admit_stream_allocations")
            if not requests:
                return []
            reqs = self.stage_requests(requests)
            res = ens_lib.find_allocations_ensemble(
                self.states, reqs, jnp.int32(policy_index(policy)),
                n_pe=self.chips_per_part, use_kernel=self.use_kernel)
            self.dispatches += 1
            found = np.asarray(res.found)
            t_s = np.where(found, np.asarray(res.t_s), T_INF)
            lanes = np.argmin(t_s, axis=1)
            return [int(lane) if found[i].any() else -1
                    for i, lane in enumerate(lanes)]
        E = self.n_partitions
        if routing == "round_robin":
            lanes = [(self._rr + i) % E for i in range(len(requests))]
            self._rr = (self._rr + len(requests)) % E
            return lanes
        # least_loaded: greedy argmin over committed + planned area,
        # scanned on device over the device-resident load vector
        if not requests:
            return []
        reqs = self.stage_requests(requests)
        lanes = _least_loaded_scan(self._load_dev, reqs.n_pe,
                                   reqs.t_du)
        self.dispatches += 1
        return [int(x) for x in np.asarray(lanes)]

    def _commit_grouped(self, requests: Sequence[ARRequest],
                        lanes: Sequence[int], policy: Policy
                        ) -> List[Optional[Allocation]]:
        """Commit routed requests in ONE grouped ensemble dispatch."""
        batch, _, slots = batch_lib.scatter_streams(
            requests, lanes, self.n_partitions, self.chips_per_part)
        states, dec = ens_lib.admit_stream_ensemble_auto(
            self.states, self._put(batch),
            jnp.full((self.n_partitions,), policy_index(policy),
                     jnp.int32),
            n_pe=self.chips_per_part, backfills=self._backfills,
            auto_release=self.auto_release,
            use_kernel=self.use_kernel)
        # growth (if any) re-materialized the lanes; re-pin placement
        self.states = self._put(states)
        self.dispatches += 1
        dec = jax.tree_util.tree_map(np.asarray, dec)   # one sync
        allocs = []
        for lane, pos in slots:
            one = jax.tree_util.tree_map(
                lambda x, lane=lane, pos=pos: x[lane][pos], dec)
            alloc = self._globalize(lane, one)
            if alloc is not None:
                self._load_host[lane] += np.float32(
                    (alloc.t_e - alloc.t_s) * len(alloc.pe_ids))
            allocs.append(alloc)
        self._load_dev = self._put_load(self._load_host)
        return allocs

    def _admit_best_acceptance(self, requests: Sequence[ARRequest],
                               policy: Policy
                               ) -> List[Optional[Allocation]]:
        """Batched best-acceptance: probe × match × commit rounds.

        Each round is three dispatches — the ``[N, E]`` probe
        (:func:`~repro.core.ensemble.find_allocations_ensemble`), the
        :func:`_match_scan` assignment, and one grouped commit — plus
        two small host syncs, independent of N.  The matcher
        finalizes every request whose probe row provably equals a
        fresh sequential probe (see :func:`_match_scan`); the rest
        re-probe next round.  When the rounds stop paying (resolution
        slows, :attr:`match_max_rounds` hit, the core auto-releases
        so probe staleness is no longer monotone, or the probe cannot
        parallelize — ``match_max_rounds=0`` on single-device
        non-kernel cores) the remainder goes
        through the fused device-sequential matcher
        (:func:`~repro.core.ensemble.match_stream_ensemble`) in one
        dispatch.  Either way the total dispatch count is bounded by
        the round limit — never by N — and decisions are bit-exact vs
        the sequential probe-commit oracle for every policy.
        """
        n_req = len(requests)
        pid = jnp.int32(policy_index(policy))
        pending = np.ones(n_req, bool)
        allocs: List[Optional[Allocation]] = [None] * n_req
        rounds = 0
        # the rounds protocol proves probe rows fresh from commits
        # only ever *adding* occupancy; auto-releasing lanes violate
        # that, so they go straight to the exact fused matcher (as do
        # cores whose probe doesn't parallelize: match_max_rounds=0)
        if not self.auto_release and self.match_max_rounds > 0:
            reqs = self.stage_requests(requests)
            window_local = jnp.asarray(
                policy in _WINDOW_LOCAL_POLICIES)
            monotone = jnp.asarray(policy == Policy.FF)
            while pending.any() and rounds < self.match_max_rounds:
                rounds += 1
                live = int(pending.sum())
                res = ens_lib.find_allocations_ensemble(
                    self.states, reqs, pid, n_pe=self.chips_per_part,
                    use_kernel=self.use_kernel)
                res = shard_rules.shard_probe(self.mesh, res)
                self.dispatches += 1
                lanes_d, rejects_d = _match_scan(
                    res.found, res.t_s, res.t_e, reqs.t_r, reqs.t_dl,
                    jnp.asarray(pending), window_local, monotone)
                self.dispatches += 1
                lanes = np.asarray(lanes_d)      # one small sync
                rejects = np.asarray(rejects_d)
                take = lanes >= 0
                pending &= ~(rejects | take)
                sel = np.flatnonzero(take)
                if sel.size:
                    committed = self._commit_grouped(
                        [requests[i] for i in sel],
                        lanes[sel].tolist(), policy)
                    for i, alloc in zip(sel, committed):
                        allocs[i] = alloc
                if live - int(pending.sum()) < max(1, live // 4):
                    break      # colliding tail: fused matcher is cheaper
        # exact fused device-sequential matcher for the tail
        if pending.any():
            idx = np.flatnonzero(pending)
            tail = [requests[i] for i in idx]
            # pad to a power of two so tail lengths reuse compilations
            n_pad = max(tl_lib.next_pow2(len(tail)), 1)
            fill = batch_lib.filler_request(
                self.chips_per_part, tail[-1].t_a)
            batch = self.stage_requests(
                tail + [fill] * (n_pad - len(tail)))
            states, lanes_d, decs_d = ens_lib.match_stream_ensemble_auto(
                self.states, batch, pid, n_pe=self.chips_per_part,
                backfills=self._backfills,
                auto_release=self.auto_release,
                use_kernel=self.use_kernel)
            self.states = self._put(states)
            self.dispatches += 1
            lanes = np.asarray(lanes_d)          # one sync
            decs = jax.tree_util.tree_map(np.asarray, decs_d)
            for k, i in enumerate(idx):
                lane = int(lanes[k])
                if lane < 0:
                    continue
                one = jax.tree_util.tree_map(
                    lambda x, k=k: x[k], decs)
                alloc = self._globalize(lane, one)
                if alloc is not None:
                    self._load_host[lane] += np.float32(
                        (alloc.t_e - alloc.t_s) * len(alloc.pe_ids))
                allocs[i] = alloc
            self._load_dev = self._put_load(self._load_host)
        self.last_match_rounds = rounds
        return allocs

    def admit_stream_allocations(
        self, requests: Sequence[ARRequest], policy: Policy,
        routing: str = "round_robin",
    ) -> List[Optional[Allocation]]:
        """Bulk admission across partitions, one grouped dispatch.

        ``round_robin`` / ``least_loaded`` route up front (host cursor
        / device load scan) and admit all lanes in one vmapped
        ``admit_stream`` dispatch.  ``best_acceptance`` runs the
        batched matcher (:meth:`_admit_best_acceptance`): bounded
        probe → match → grouped-commit rounds instead of the old
        per-request probe/commit round-trips, decision-identical to
        sequential probing.  Completion release stays with the fleet
        unless the core was built with ``auto_release=True``.
        """
        if not requests:
            return []
        if routing == "best_acceptance":
            return self._admit_best_acceptance(list(requests), policy)
        lanes = self.route(requests, routing)
        return self._commit_grouped(requests, lanes, policy)

    # -- debug / test view ---------------------------------------------
    def records(self) -> List[tuple]:
        """Merged (time, busy-global-chip-set) view across partitions."""
        lanes = []
        for lane in range(self.n_partitions):
            times = np.asarray(self.states.tl.times[lane])
            occ = np.asarray(self.states.tl.occ[lane])
            rows = [(int(t), frozenset(
                p + lane * self.chips_per_part
                for p in batch_lib.mask32_to_ids(o)))
                for t, o in zip(times, occ) if t < T_INF]
            lanes.append(rows)
        bounds = sorted({t for rows in lanes for t, _ in rows})
        out, prev = [], frozenset()
        for t in bounds:
            busy = set()
            for rows in lanes:
                cur = frozenset()
                for rt, rb in rows:
                    if rt <= t:
                        cur = rb
                    else:
                        break
                busy |= cur
            busy = frozenset(busy)
            if busy != prev:
                out.append((t, busy))
                prev = busy
        return out


class FleetScheduler:
    """Admission control for the chip fleet — a
    :class:`~repro.api.ReservationService` client.

    The fleet owns job bookkeeping, fault handling and completion
    release (``advance``); all reservation decisions go through one
    service session.  Completion release stays with the fleet
    (``auto_release=False``), bulk admission uses one-shot
    :meth:`~repro.api.Session.offer` calls, and the classic three
    operations reach the underlying engine via ``session.engine``
    (kept as ``self.core``).
    """

    def __init__(self, n_chips: int = 512,
                 policy: Policy = Policy.PE_W,
                 engine: Optional[str] = None,
                 repair_seconds: int = 1800,
                 restart_overhead: int = 120,
                 n_partitions: int = 1,
                 routing: str = "round_robin",
                 use_kernel: bool = False,
                 index_tile: Optional[int] = None):
        self.n_chips = n_chips
        self.policy = policy
        if n_partitions > 1:
            if engine is not None:
                raise ValueError(
                    "a partitioned fleet is always device-backed "
                    "(one vmapped state); drop the engine argument")
            cfg = ServiceConfig(
                n_pe=n_chips, engine="device", policy=policy,
                n_partitions=n_partitions, routing=routing,
                use_kernel=use_kernel, auto_release=False,
                chunk_size=None, index_tile=index_tile)
        else:
            if index_tile is not None and (engine or "host") != "device":
                raise ValueError("index_tile needs the device engine")
            cfg = ServiceConfig.from_engine_kwargs(
                n_chips, engine or "host",
                **({"use_kernel": use_kernel}
                   if (engine or "host") == "device" else {})
            ).replace(policy=policy, auto_release=False,
                      chunk_size=None, index_tile=index_tile)
        self.service = ReservationService(cfg)
        self.session = self.service.session()
        self.core = self.session.engine
        self.n_partitions = n_partitions
        self.routing = routing
        self.repair_seconds = repair_seconds
        self.restart_overhead = restart_overhead
        self.jobs: Dict[int, FleetJob] = {}
        self._ids = itertools.count()
        self.now = 0
        self.events: List[tuple] = []     # (time, kind, job_id) log

    # ------------------------------------------------------------------
    def advance(self, t: int) -> None:
        """Move the fleet clock; complete reservations that finished."""
        assert t >= self.now
        self.now = t
        for job in self.jobs.values():
            if job.state in (JobState.RESERVED, JobState.RUNNING):
                if job.t_start <= t and job.state == JobState.RESERVED:
                    job.state = JobState.RUNNING
                if job.t_end <= t:
                    job.work_done = job.t_end - job.t_start
                    job.state = JobState.DONE
                    self.core.delete_allocation(
                        job.t_start, job.t_end, list(job.chips))
                    self.events.append((t, "complete", job.job_id))

    # ------------------------------------------------------------------
    def _build_job(self, arch: str, shape: str, n_chips: int,
                   n_steps: int, ready: Optional[int] = None,
                   deadline_slack: float = 2.0):
        """Shared job/request construction for submit and submit_batch."""
        dur = estimate_duration(arch, shape, n_chips, n_steps)
        ready = self.now if ready is None else ready
        deadline = ready + int(dur * (1.0 + deadline_slack))
        job = FleetJob(
            job_id=next(self._ids), arch=arch, shape=shape,
            n_chips=n_chips, n_steps=n_steps, submit_time=self.now,
            ready=ready, deadline=deadline)
        req = ARRequest(t_a=self.now, t_r=ready, t_du=dur,
                        t_dl=deadline, n_pe=n_chips)
        return job, req

    def _record_decision(self, job: FleetJob,
                         alloc, committed: bool) -> FleetJob:
        """Book-keep one admission outcome (alloc already committed
        when ``committed``; otherwise commit it here)."""
        if alloc is None:
            job.state = JobState.REJECTED
            self.events.append((self.now, "reject", job.job_id))
        else:
            if not committed:
                self.core.add_allocation(alloc.t_s, alloc.t_e,
                                         list(alloc.pe_ids))
            job.t_start, job.t_end = alloc.t_s, alloc.t_e
            job.chips = alloc.pe_ids
            if self.n_partitions > 1:
                job.partition = \
                    alloc.pe_ids[0] // self.core.chips_per_part
            self.events.append((self.now, "reserve", job.job_id))
        self.jobs[job.job_id] = job
        return job

    def submit(self, arch: str, shape: str, n_chips: int,
               n_steps: int, ready: Optional[int] = None,
               deadline_slack: float = 2.0,
               policy: Optional[Policy] = None) -> FleetJob:
        """Admission-control one job; returns it (possibly REJECTED)."""
        job, req = self._build_job(arch, shape, n_chips, n_steps,
                                   ready, deadline_slack)
        alloc = self.core.find_allocation(
            req, policy or self.policy, t_now=self.now)
        return self._record_decision(job, alloc, committed=False)

    # ------------------------------------------------------------------
    def submit_batch(self, specs: Sequence[Dict],
                     policy: Optional[Policy] = None,
                     routing: Optional[str] = None) -> List[FleetJob]:
        """Bulk admission control: one device scan for many jobs.

        Each spec is a dict with the keyword arguments of
        :meth:`submit` (``arch``, ``shape``, ``n_chips``, ``n_steps``,
        optional ``ready``/``deadline_slack``).

        On a partitioned fleet the batch is routed across partitions
        (``routing`` overrides the fleet default: round-robin, least
        loaded, or best-acceptance probes) and all partitions admit in
        one vmapped dispatch.  On a device engine the whole batch is
        one session :meth:`~repro.api.Session.offer` — a single jitted
        ``lax.scan`` with no per-job host round-trips; decisions are
        identical to sequential submission because the scan commits
        each accepted job before considering the next.  Host/list
        engines admit through the same verb (the session's reference
        loop).  Completion release stays with :meth:`advance`
        (``auto_release=False``).
        """
        pol = policy or self.policy
        built = [self._build_job(**spec) for spec in specs]
        res = self.session.offer(
            [req for _, req in built], policy=pol,
            routing=(routing or self.routing)
            if self.n_partitions > 1 else None)
        return [self._record_decision(job, alloc, committed=True)
                for (job, _), alloc in zip(built, res.allocations())]

    # ------------------------------------------------------------------
    def submit_malleable(self, arch: str, shape: str,
                         chip_options: List[int], n_steps: int,
                         ready: Optional[int] = None,
                         deadline: Optional[int] = None) -> FleetJob:
        """Malleable AR job (paper Section 7): the request's PE count is
        not fixed.  Per the paper's proposal, the malleable requirement
        is *translated into a group of rigid requests* (one per chip
        count, with the duration rescaled by the roofline model) and
        ``findAllocation`` evaluates each; the completion-time-earliest
        feasible allocation wins (the "new criterion" the paper leaves
        open — earliest finish maximises remaining fleet flexibility).
        Each rigid variant is searched with FF so that the cross-
        variant earliest-finish comparison is coherent.
        """
        ready = self.now if ready is None else ready
        best = None           # (finish_time, alloc, n_chips, dur)
        durations = {n: estimate_duration(arch, shape, n, n_steps)
                     for n in chip_options}
        dl = deadline if deadline is not None else \
            ready + int(2.0 * max(durations.values()))
        for n_chips in sorted(chip_options):
            dur = durations[n_chips]
            if ready + dur > dl:
                continue      # this rigid variant cannot meet the SLO
            req = ARRequest(t_a=self.now, t_r=ready, t_du=dur,
                            t_dl=dl, n_pe=n_chips)
            alloc = self.core.find_allocation(req, Policy.FF,
                                              t_now=self.now)
            if alloc is None:
                continue
            finish = alloc.t_s + dur
            if best is None or finish < best[0]:
                best = (finish, alloc, n_chips, dur)
        job = FleetJob(
            job_id=next(self._ids), arch=arch, shape=shape,
            n_chips=best[2] if best else min(chip_options),
            n_steps=n_steps, submit_time=self.now, ready=ready,
            deadline=dl)
        if best is None:
            job.state = JobState.REJECTED
            self.events.append((self.now, "reject-malleable",
                                job.job_id))
        else:
            _, alloc, n_chips, dur = best
            self.core.add_allocation(alloc.t_s, alloc.t_e,
                                     list(alloc.pe_ids))
            job.t_start, job.t_end = alloc.t_s, alloc.t_e
            job.chips = alloc.pe_ids
            if self.n_partitions > 1:
                job.partition = \
                    alloc.pe_ids[0] // self.core.chips_per_part
            self.events.append((self.now, "reserve-malleable",
                                job.job_id))
        self.jobs[job.job_id] = job
        return job

    # ------------------------------------------------------------------
    def _release(self, job: FleetJob) -> None:
        self.core.delete_allocation(job.t_start, job.t_end,
                                    list(job.chips))
        job.chips = ()

    def _resubmit_remainder(self, job: FleetJob, extra_duration: int = 0,
                            n_chips: Optional[int] = None) -> bool:
        """Re-reserve the job's remaining work within its deadline."""
        done = max(0, min(self.now, job.t_end) - job.t_start)
        ckpt_done = (done // job.checkpoint_interval) \
            * job.checkpoint_interval
        total = job.t_end - job.t_start
        remaining = total - ckpt_done + self.restart_overhead \
            + extra_duration
        n_chips = n_chips or job.n_chips
        if n_chips != job.n_chips:
            frac = remaining / max(total, 1)
            full = estimate_duration(job.arch, job.shape, n_chips,
                                     job.n_steps)
            remaining = int(full * frac) + self.restart_overhead
        if self.now + remaining > job.deadline:
            job.state = JobState.FAILED
            self.events.append((self.now, "deadline-miss", job.job_id))
            return False
        req = ARRequest(t_a=self.now, t_r=self.now, t_du=remaining,
                        t_dl=job.deadline, n_pe=n_chips)
        alloc = self.core.find_allocation(req, self.policy,
                                          t_now=self.now)
        if alloc is None:
            job.state = JobState.FAILED
            self.events.append((self.now, "no-capacity", job.job_id))
            return False
        self.core.add_allocation(alloc.t_s, alloc.t_e,
                                 list(alloc.pe_ids))
        job.t_start, job.t_end = alloc.t_s, alloc.t_e
        job.chips = alloc.pe_ids
        if self.n_partitions > 1:
            job.partition = alloc.pe_ids[0] // self.core.chips_per_part
        job.n_chips = n_chips
        job.preemptions += 1
        job.state = JobState.RESERVED if alloc.t_s > self.now \
            else JobState.RUNNING
        self.events.append((self.now, "re-reserve", job.job_id))
        return True

    # ------------------------------------------------------------------
    def fail_chip(self, chip_id: int) -> List[int]:
        """Hardware failure: repair-reserve the chip, migrate its jobs."""
        affected = [j for j in self.jobs.values()
                    if chip_id in j.chips
                    and j.state in (JobState.RESERVED, JobState.RUNNING)]
        for job in affected:
            self._release(job)
        # the chip is unavailable while under repair
        self.core.add_allocation(
            self.now, self.now + self.repair_seconds, [chip_id])
        self.events.append((self.now, "chip-fail", chip_id))
        migrated = []
        for job in affected:
            if self._resubmit_remainder(job):
                migrated.append(job.job_id)
        return migrated

    def report_straggler(self, job_id: int,
                         slowdown: float = 1.5) -> bool:
        """The job is running ``slowdown``x slower than reserved:
        stretch its reservation into the deadline slack."""
        job = self.jobs[job_id]
        if job.state not in (JobState.RUNNING, JobState.RESERVED):
            return False
        remaining = max(job.t_end - self.now, 0)
        extra = int(remaining * (slowdown - 1.0))
        self._release(job)
        self.events.append((self.now, "straggler", job_id))
        return self._resubmit_remainder(job, extra_duration=extra)

    def rescale(self, job_id: int, new_n_chips: int) -> bool:
        """Elastic scaling: move the remaining work to a new footprint."""
        job = self.jobs[job_id]
        if job.state not in (JobState.RUNNING, JobState.RESERVED):
            return False
        self._release(job)
        self.events.append((self.now, "rescale", job_id))
        return self._resubmit_remainder(job, n_chips=new_n_chips)

    # ------------------------------------------------------------------
    def utilisation(self, horizon: int) -> float:
        area = sum(
            (min(j.t_end, self.now + horizon) - max(j.t_start, self.now))
            * j.n_chips
            for j in self.jobs.values()
            if j.state in (JobState.RESERVED, JobState.RUNNING)
            and j.t_end > self.now)
        return area / (self.n_chips * horizon)

    def summary(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for j in self.jobs.values():
            out[j.state.value] = out.get(j.state.value, 0) + 1
        return out
