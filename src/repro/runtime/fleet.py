"""FleetScheduler: the paper's AR core managing TPU chips for ML jobs.

Integration of the reproduction with the training/serving framework:
the production fleet (2 pods x 256 chips) is the paper's multiprocessor
system — PEs are chips.  Every training or serving run of an assigned
architecture is an AR request: ``n_pe`` = the job's chip footprint,
``t_du`` = estimated steps x roofline step time (from
:mod:`repro.roofline.analysis`), ``t_r``/``t_dl`` from the user's SLO.
Admission, placement and policy choice reuse :mod:`repro.core`
unchanged — the scheduler engine is the deliverable, the fleet is its
first production consumer.

With ``n_partitions > 1`` the fleet is *partitioned*: the chips split
into equal partitions, each one lane of a single vmapped scheduler
state (:class:`PartitionedCore`, DESIGN.md §4).  Bulk submissions are
routed across partitions (round-robin, least-loaded, or
best-acceptance probes) and admitted in one device dispatch; jobs
never span partitions.

Fault tolerance (the general-deadline slack is what makes this work —
the paper's central observation):

* ``fail_chip``: the chip gets a repair reservation; every job holding
  it has its reservation deleted and its *remaining* work (back to the
  last checkpoint) re-submitted as a new AR request within the original
  deadline.
* ``report_straggler``: a job running slower than its reservation is
  re-reserved with the stretched duration while its deadline slack
  absorbs the slip.
* ``rescale``: elastic re-reservation of the remaining work on a
  different chip count (duration rescaled by the roofline model).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.api import ReservationService, ServiceConfig
from repro.api.config import ROUTINGS  # noqa: F401  (re-export)
from repro.configs import get_config, shape_by_name
from repro.core import ARRequest, Policy
from repro.core import batch as batch_lib
from repro.core import ensemble as ens_lib
from repro.core import timeline as tl_lib
from repro.core.batch import pad_streams
from repro.core.policies import policy_index
from repro.core.types import Allocation, T_INF
from repro.launch.mesh import resolve_placement
from repro.roofline import analysis as roof
from repro.sharding import rules as shard_rules


class JobState(str, enum.Enum):
    REJECTED = "rejected"
    RESERVED = "reserved"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"


@dataclasses.dataclass
class FleetJob:
    job_id: int
    arch: str
    shape: str
    n_chips: int
    n_steps: int
    submit_time: int
    ready: int
    deadline: int
    state: JobState = JobState.RESERVED
    t_start: int = -1
    t_end: int = -1
    chips: tuple = ()
    partition: int = -1                   # -1: unpartitioned fleet
    checkpoint_interval: int = 600        # seconds of work per ckpt
    work_done: int = 0                    # seconds of completed work
    preemptions: int = 0

    @property
    def step_time(self) -> float:
        return (self.t_end - self.t_start) / max(self.n_steps, 1)


def estimate_duration(arch: str, shape_name: str, n_chips: int,
                      n_steps: int, efficiency: float = 0.5) -> int:
    """Roofline-model duration estimate for ``n_steps`` on ``n_chips``.

    ``efficiency`` discounts peak (achieved fraction of roofline).
    """
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    model = min(16, n_chips)
    mesh = {"data": max(n_chips // model, 1), "model": model}
    costs = roof.step_costs(cfg, shape, mesh)
    terms = costs.terms(n_chips)
    step_s = max(terms["compute_s"], terms["memory_s"],
                 terms["collective_s"]) / efficiency
    return max(int(step_s * n_steps) + 1, 60)


class PartitionedCore:
    """E cluster partitions behind one vmapped scheduler state.

    The fleet's chips are split into ``n_partitions`` equal partitions;
    each partition is one lane of a stacked
    :class:`~repro.core.timeline.SchedulerState` (DESIGN.md §4), so
    bulk admission steps every partition in a single jitted dispatch
    (``admit_stream_ensemble``) and the best-acceptance probe searches
    all partitions at once (``find_allocation_ensemble``).

    The interface mirrors the single-cluster engines — ``find`` /
    ``add`` / ``delete`` with *global* chip ids — plus the routed bulk
    path :meth:`admit_stream_allocations`.  An allocation never spans
    partitions: requests wider than a partition are rejected.
    """

    def __init__(self, n_chips: int, n_partitions: int,
                 capacity: int = 128, pending_capacity: int = 256,
                 use_kernel: bool = False, placement="auto"):
        if n_partitions < 1 or n_chips % n_partitions:
            raise ValueError(
                f"n_chips={n_chips} not divisible into "
                f"{n_partitions} partitions")
        self.n_chips = n_chips
        self.n_partitions = n_partitions
        self.chips_per_part = n_chips // n_partitions
        self.use_kernel = use_kernel
        # partition axis -> mesh data axis (DESIGN.md §8): the bulk
        # admission dispatch steps each device's partition slice
        # locally; decisions are placement-invariant
        self.mesh = resolve_placement(placement, n_partitions)
        self.states = self._put(ens_lib.init_ensemble(
            n_partitions, capacity, self.chips_per_part,
            pending_capacity))
        # committed PE-seconds per partition (least-loaded routing)
        self.load = [0.0] * n_partitions
        self._rr = 0                      # round-robin cursor

    def _put(self, tree):
        return shard_rules.shard_ensemble(self.mesh, tree)

    # -- global chip ids <-> (lane, local) -----------------------------
    def _split(self, pes: Sequence[int]):
        lanes = {p // self.chips_per_part for p in pes}
        if len(lanes) != 1:
            raise ValueError(
                f"allocation spans partitions {sorted(lanes)}")
        lane = lanes.pop()
        return lane, [p - lane * self.chips_per_part for p in pes]

    def _mask(self, local_pes: Sequence[int]) -> jax.Array:
        return tl_lib.ids_to_mask32(local_pes,
                                    self.states.tl.occ.shape[-1])

    def _globalize(self, lane: int, dec) -> Optional[Allocation]:
        alloc = batch_lib.decision_to_allocation(dec)
        if alloc is None:
            return None
        off = lane * self.chips_per_part
        return dataclasses.replace(
            alloc, pe_ids=tuple(p + off for p in alloc.pe_ids))

    # -- the three classic operations (global chip ids) ----------------
    def _lane_update(self, lane: int, t_s: int, t_e: int,
                     local_pes: Sequence[int], is_add: bool) -> None:
        mask = self._mask(local_pes)
        for _ in range(batch_lib.MAX_DOUBLINGS + 1):
            tl = jax.tree_util.tree_map(
                lambda x: x[lane], self.states.tl)
            new_tl, overflow, n_keep = tl_lib.update(
                tl, t_s, t_e, mask, is_add=is_add, with_count=True)
            if not bool(overflow):
                self.states = self.states._replace(
                    tl=jax.tree_util.tree_map(
                        lambda full, one: full.at[lane].set(one),
                        self.states.tl, new_tl))
                return
            # watermark protocol (DESIGN.md §3/§4): grow every lane
            # once to the needed record count
            cap = self.states.tl.times.shape[-1]
            self.states = self._put(ens_lib.grow_ensemble(
                self.states,
                max(2 * cap, tl_lib.next_pow2(int(n_keep))),
                self.states.pend_te.shape[-1]))
        raise RuntimeError("partition timeline kept overflowing")

    def add_allocation(self, t_s: int, t_e: int,
                       pes: Sequence[int]) -> None:
        lane, local = self._split(pes)
        self._lane_update(lane, t_s, t_e, local, is_add=True)
        self.load[lane] += (t_e - t_s) * len(local)

    def delete_allocation(self, t_s: int, t_e: int,
                          pes: Sequence[int]) -> None:
        lane, local = self._split(pes)
        self._lane_update(lane, t_s, t_e, local, is_add=False)
        self.load[lane] -= (t_e - t_s) * len(local)

    def find_allocation(self, req: ARRequest, policy: Policy,
                        t_now: Optional[int] = None
                        ) -> Optional[Allocation]:
        """Best-acceptance probe: search every partition in one
        vmapped dispatch, take the earliest feasible start (ties to
        the lowest lane)."""
        struct = batch_lib.request_struct(req)
        if t_now is not None:
            # the search reads its "now" from the struct's t_a
            struct = struct._replace(t_a=jnp.int32(t_now))
        res = ens_lib.find_allocation_ensemble(
            self.states, struct, jnp.int32(policy_index(policy)),
            n_pe=self.chips_per_part, use_kernel=self.use_kernel)
        res = jax.tree_util.tree_map(np.asarray, res)   # one sync
        if not res.found.any():
            return None
        t_s = np.where(res.found, res.t_s, T_INF)
        lane = int(np.argmin(t_s))        # argmin ties -> lowest lane
        one = jax.tree_util.tree_map(lambda x: x[lane], res)
        alloc = batch_lib.search_result_to_allocation(one)
        off = lane * self.chips_per_part
        return dataclasses.replace(
            alloc, pe_ids=tuple(p + off for p in alloc.pe_ids))

    # -- routed bulk admission (one vmapped dispatch) ------------------
    def route(self, requests: Sequence[ARRequest],
              routing: str) -> List[int]:
        """Assign a partition lane to every request (no commit)."""
        if routing not in ROUTINGS:
            raise ValueError(
                f"unknown routing {routing!r}; pick one of {ROUTINGS}")
        if routing == "best_acceptance":
            raise ValueError(
                "best_acceptance routes by probing the timelines, not "
                "by pre-assignment; use admit_stream_allocations")
        E = self.n_partitions
        if routing == "round_robin":
            lanes = [(self._rr + i) % E for i in range(len(requests))]
            self._rr = (self._rr + len(requests)) % E
            return lanes
        # least_loaded: greedy argmin over committed + planned area
        load = list(self.load)
        lanes = []
        for req in requests:
            lane = int(np.argmin(load))
            lanes.append(lane)
            load[lane] += req.n_pe * req.t_du
        return lanes

    def admit_stream_allocations(
        self, requests: Sequence[ARRequest], policy: Policy,
        routing: str = "round_robin",
    ) -> List[Optional[Allocation]]:
        """Bulk admission across partitions.

        ``round_robin`` / ``least_loaded`` group the requests per lane
        and admit all lanes in *one* vmapped ``admit_stream`` dispatch
        (completion release stays with the fleet: ``auto_release`` is
        off).  ``best_acceptance`` probes all partitions per request
        (vmapped search) and commits to the earliest feasible start —
        sequential commits, maximal acceptance.
        """
        if routing == "best_acceptance":
            out: List[Optional[Allocation]] = []
            for req in requests:
                alloc = self.find_allocation(req, policy)
                if alloc is not None:
                    self.add_allocation(alloc.t_s, alloc.t_e,
                                        list(alloc.pe_ids))
                out.append(alloc)
            return out
        lanes = self.route(requests, routing)
        E = self.n_partitions
        streams: List[List[ARRequest]] = [[] for _ in range(E)]
        slot: List[tuple] = []            # request i -> (lane, pos)
        for req, lane in zip(requests, lanes):
            slot.append((lane, len(streams[lane])))
            streams[lane].append(req)
        batch, _ = pad_streams(streams, self.chips_per_part)
        states, dec = ens_lib.admit_stream_ensemble_auto(
            self.states, self._put(batch),
            jnp.full((E,), policy_index(policy), jnp.int32),
            n_pe=self.chips_per_part, auto_release=False,
            use_kernel=self.use_kernel)
        # growth (if any) re-materialized the lanes; re-pin placement
        self.states = self._put(states)
        dec = jax.tree_util.tree_map(np.asarray, dec)   # one sync
        allocs = []
        for lane, pos in slot:
            one = jax.tree_util.tree_map(
                lambda x, lane=lane, pos=pos: x[lane][pos], dec)
            alloc = self._globalize(lane, one)
            if alloc is not None:
                self.load[lane] += \
                    (alloc.t_e - alloc.t_s) * len(alloc.pe_ids)
            allocs.append(alloc)
        return allocs

    # -- debug / test view ---------------------------------------------
    def records(self) -> List[tuple]:
        """Merged (time, busy-global-chip-set) view across partitions."""
        lanes = []
        for lane in range(self.n_partitions):
            times = np.asarray(self.states.tl.times[lane])
            occ = np.asarray(self.states.tl.occ[lane])
            rows = [(int(t), frozenset(
                p + lane * self.chips_per_part
                for p in batch_lib.mask32_to_ids(o)))
                for t, o in zip(times, occ) if t < T_INF]
            lanes.append(rows)
        bounds = sorted({t for rows in lanes for t, _ in rows})
        out, prev = [], frozenset()
        for t in bounds:
            busy = set()
            for rows in lanes:
                cur = frozenset()
                for rt, rb in rows:
                    if rt <= t:
                        cur = rb
                    else:
                        break
                busy |= cur
            busy = frozenset(busy)
            if busy != prev:
                out.append((t, busy))
                prev = busy
        return out


class FleetScheduler:
    """Admission control for the chip fleet — a
    :class:`~repro.api.ReservationService` client.

    The fleet owns job bookkeeping, fault handling and completion
    release (``advance``); all reservation decisions go through one
    service session.  Completion release stays with the fleet
    (``auto_release=False``), bulk admission uses one-shot
    :meth:`~repro.api.Session.offer` calls, and the classic three
    operations reach the underlying engine via ``session.engine``
    (kept as ``self.core``).
    """

    def __init__(self, n_chips: int = 512,
                 policy: Policy = Policy.PE_W,
                 engine: Optional[str] = None,
                 repair_seconds: int = 1800,
                 restart_overhead: int = 120,
                 n_partitions: int = 1,
                 routing: str = "round_robin",
                 use_kernel: bool = False):
        self.n_chips = n_chips
        self.policy = policy
        if n_partitions > 1:
            if engine is not None:
                raise ValueError(
                    "a partitioned fleet is always device-backed "
                    "(one vmapped state); drop the engine argument")
            cfg = ServiceConfig(
                n_pe=n_chips, engine="device", policy=policy,
                n_partitions=n_partitions, routing=routing,
                use_kernel=use_kernel, auto_release=False,
                chunk_size=None)
        else:
            cfg = ServiceConfig.from_engine_kwargs(
                n_chips, engine or "host",
                **({"use_kernel": use_kernel}
                   if (engine or "host") == "device" else {})
            ).replace(policy=policy, auto_release=False,
                      chunk_size=None)
        self.service = ReservationService(cfg)
        self.session = self.service.session()
        self.core = self.session.engine
        self.n_partitions = n_partitions
        self.routing = routing
        self.repair_seconds = repair_seconds
        self.restart_overhead = restart_overhead
        self.jobs: Dict[int, FleetJob] = {}
        self._ids = itertools.count()
        self.now = 0
        self.events: List[tuple] = []     # (time, kind, job_id) log

    # ------------------------------------------------------------------
    def advance(self, t: int) -> None:
        """Move the fleet clock; complete reservations that finished."""
        assert t >= self.now
        self.now = t
        for job in self.jobs.values():
            if job.state in (JobState.RESERVED, JobState.RUNNING):
                if job.t_start <= t and job.state == JobState.RESERVED:
                    job.state = JobState.RUNNING
                if job.t_end <= t:
                    job.work_done = job.t_end - job.t_start
                    job.state = JobState.DONE
                    self.core.delete_allocation(
                        job.t_start, job.t_end, list(job.chips))
                    self.events.append((t, "complete", job.job_id))

    # ------------------------------------------------------------------
    def _build_job(self, arch: str, shape: str, n_chips: int,
                   n_steps: int, ready: Optional[int] = None,
                   deadline_slack: float = 2.0):
        """Shared job/request construction for submit and submit_batch."""
        dur = estimate_duration(arch, shape, n_chips, n_steps)
        ready = self.now if ready is None else ready
        deadline = ready + int(dur * (1.0 + deadline_slack))
        job = FleetJob(
            job_id=next(self._ids), arch=arch, shape=shape,
            n_chips=n_chips, n_steps=n_steps, submit_time=self.now,
            ready=ready, deadline=deadline)
        req = ARRequest(t_a=self.now, t_r=ready, t_du=dur,
                        t_dl=deadline, n_pe=n_chips)
        return job, req

    def _record_decision(self, job: FleetJob,
                         alloc, committed: bool) -> FleetJob:
        """Book-keep one admission outcome (alloc already committed
        when ``committed``; otherwise commit it here)."""
        if alloc is None:
            job.state = JobState.REJECTED
            self.events.append((self.now, "reject", job.job_id))
        else:
            if not committed:
                self.core.add_allocation(alloc.t_s, alloc.t_e,
                                         list(alloc.pe_ids))
            job.t_start, job.t_end = alloc.t_s, alloc.t_e
            job.chips = alloc.pe_ids
            if self.n_partitions > 1:
                job.partition = \
                    alloc.pe_ids[0] // self.core.chips_per_part
            self.events.append((self.now, "reserve", job.job_id))
        self.jobs[job.job_id] = job
        return job

    def submit(self, arch: str, shape: str, n_chips: int,
               n_steps: int, ready: Optional[int] = None,
               deadline_slack: float = 2.0,
               policy: Optional[Policy] = None) -> FleetJob:
        """Admission-control one job; returns it (possibly REJECTED)."""
        job, req = self._build_job(arch, shape, n_chips, n_steps,
                                   ready, deadline_slack)
        alloc = self.core.find_allocation(
            req, policy or self.policy, t_now=self.now)
        return self._record_decision(job, alloc, committed=False)

    # ------------------------------------------------------------------
    def submit_batch(self, specs: Sequence[Dict],
                     policy: Optional[Policy] = None,
                     routing: Optional[str] = None) -> List[FleetJob]:
        """Bulk admission control: one device scan for many jobs.

        Each spec is a dict with the keyword arguments of
        :meth:`submit` (``arch``, ``shape``, ``n_chips``, ``n_steps``,
        optional ``ready``/``deadline_slack``).

        On a partitioned fleet the batch is routed across partitions
        (``routing`` overrides the fleet default: round-robin, least
        loaded, or best-acceptance probes) and all partitions admit in
        one vmapped dispatch.  On a device engine the whole batch is
        one session :meth:`~repro.api.Session.offer` — a single jitted
        ``lax.scan`` with no per-job host round-trips; decisions are
        identical to sequential submission because the scan commits
        each accepted job before considering the next.  Host/list
        engines admit through the same verb (the session's reference
        loop).  Completion release stays with :meth:`advance`
        (``auto_release=False``).
        """
        pol = policy or self.policy
        built = [self._build_job(**spec) for spec in specs]
        res = self.session.offer(
            [req for _, req in built], policy=pol,
            routing=(routing or self.routing)
            if self.n_partitions > 1 else None)
        return [self._record_decision(job, alloc, committed=True)
                for (job, _), alloc in zip(built, res.allocations())]

    # ------------------------------------------------------------------
    def submit_malleable(self, arch: str, shape: str,
                         chip_options: List[int], n_steps: int,
                         ready: Optional[int] = None,
                         deadline: Optional[int] = None) -> FleetJob:
        """Malleable AR job (paper Section 7): the request's PE count is
        not fixed.  Per the paper's proposal, the malleable requirement
        is *translated into a group of rigid requests* (one per chip
        count, with the duration rescaled by the roofline model) and
        ``findAllocation`` evaluates each; the completion-time-earliest
        feasible allocation wins (the "new criterion" the paper leaves
        open — earliest finish maximises remaining fleet flexibility).
        Each rigid variant is searched with FF so that the cross-
        variant earliest-finish comparison is coherent.
        """
        ready = self.now if ready is None else ready
        best = None           # (finish_time, alloc, n_chips, dur)
        durations = {n: estimate_duration(arch, shape, n, n_steps)
                     for n in chip_options}
        dl = deadline if deadline is not None else \
            ready + int(2.0 * max(durations.values()))
        for n_chips in sorted(chip_options):
            dur = durations[n_chips]
            if ready + dur > dl:
                continue      # this rigid variant cannot meet the SLO
            req = ARRequest(t_a=self.now, t_r=ready, t_du=dur,
                            t_dl=dl, n_pe=n_chips)
            alloc = self.core.find_allocation(req, Policy.FF,
                                              t_now=self.now)
            if alloc is None:
                continue
            finish = alloc.t_s + dur
            if best is None or finish < best[0]:
                best = (finish, alloc, n_chips, dur)
        job = FleetJob(
            job_id=next(self._ids), arch=arch, shape=shape,
            n_chips=best[2] if best else min(chip_options),
            n_steps=n_steps, submit_time=self.now, ready=ready,
            deadline=dl)
        if best is None:
            job.state = JobState.REJECTED
            self.events.append((self.now, "reject-malleable",
                                job.job_id))
        else:
            _, alloc, n_chips, dur = best
            self.core.add_allocation(alloc.t_s, alloc.t_e,
                                     list(alloc.pe_ids))
            job.t_start, job.t_end = alloc.t_s, alloc.t_e
            job.chips = alloc.pe_ids
            if self.n_partitions > 1:
                job.partition = \
                    alloc.pe_ids[0] // self.core.chips_per_part
            self.events.append((self.now, "reserve-malleable",
                                job.job_id))
        self.jobs[job.job_id] = job
        return job

    # ------------------------------------------------------------------
    def _release(self, job: FleetJob) -> None:
        self.core.delete_allocation(job.t_start, job.t_end,
                                    list(job.chips))
        job.chips = ()

    def _resubmit_remainder(self, job: FleetJob, extra_duration: int = 0,
                            n_chips: Optional[int] = None) -> bool:
        """Re-reserve the job's remaining work within its deadline."""
        done = max(0, min(self.now, job.t_end) - job.t_start)
        ckpt_done = (done // job.checkpoint_interval) \
            * job.checkpoint_interval
        total = job.t_end - job.t_start
        remaining = total - ckpt_done + self.restart_overhead \
            + extra_duration
        n_chips = n_chips or job.n_chips
        if n_chips != job.n_chips:
            frac = remaining / max(total, 1)
            full = estimate_duration(job.arch, job.shape, n_chips,
                                     job.n_steps)
            remaining = int(full * frac) + self.restart_overhead
        if self.now + remaining > job.deadline:
            job.state = JobState.FAILED
            self.events.append((self.now, "deadline-miss", job.job_id))
            return False
        req = ARRequest(t_a=self.now, t_r=self.now, t_du=remaining,
                        t_dl=job.deadline, n_pe=n_chips)
        alloc = self.core.find_allocation(req, self.policy,
                                          t_now=self.now)
        if alloc is None:
            job.state = JobState.FAILED
            self.events.append((self.now, "no-capacity", job.job_id))
            return False
        self.core.add_allocation(alloc.t_s, alloc.t_e,
                                 list(alloc.pe_ids))
        job.t_start, job.t_end = alloc.t_s, alloc.t_e
        job.chips = alloc.pe_ids
        if self.n_partitions > 1:
            job.partition = alloc.pe_ids[0] // self.core.chips_per_part
        job.n_chips = n_chips
        job.preemptions += 1
        job.state = JobState.RESERVED if alloc.t_s > self.now \
            else JobState.RUNNING
        self.events.append((self.now, "re-reserve", job.job_id))
        return True

    # ------------------------------------------------------------------
    def fail_chip(self, chip_id: int) -> List[int]:
        """Hardware failure: repair-reserve the chip, migrate its jobs."""
        affected = [j for j in self.jobs.values()
                    if chip_id in j.chips
                    and j.state in (JobState.RESERVED, JobState.RUNNING)]
        for job in affected:
            self._release(job)
        # the chip is unavailable while under repair
        self.core.add_allocation(
            self.now, self.now + self.repair_seconds, [chip_id])
        self.events.append((self.now, "chip-fail", chip_id))
        migrated = []
        for job in affected:
            if self._resubmit_remainder(job):
                migrated.append(job.job_id)
        return migrated

    def report_straggler(self, job_id: int,
                         slowdown: float = 1.5) -> bool:
        """The job is running ``slowdown``x slower than reserved:
        stretch its reservation into the deadline slack."""
        job = self.jobs[job_id]
        if job.state not in (JobState.RUNNING, JobState.RESERVED):
            return False
        remaining = max(job.t_end - self.now, 0)
        extra = int(remaining * (slowdown - 1.0))
        self._release(job)
        self.events.append((self.now, "straggler", job_id))
        return self._resubmit_remainder(job, extra_duration=extra)

    def rescale(self, job_id: int, new_n_chips: int) -> bool:
        """Elastic scaling: move the remaining work to a new footprint."""
        job = self.jobs[job_id]
        if job.state not in (JobState.RUNNING, JobState.RESERVED):
            return False
        self._release(job)
        self.events.append((self.now, "rescale", job_id))
        return self._resubmit_remainder(job, n_chips=new_n_chips)

    # ------------------------------------------------------------------
    def utilisation(self, horizon: int) -> float:
        area = sum(
            (min(j.t_end, self.now + horizon) - max(j.t_start, self.now))
            * j.n_chips
            for j in self.jobs.values()
            if j.state in (JobState.RESERVED, JobState.RUNNING)
            and j.t_end > self.now)
        return area / (self.n_chips * horizon)

    def summary(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for j in self.jobs.values():
            out[j.state.value] = out.get(j.state.value, 0) + 1
        return out
