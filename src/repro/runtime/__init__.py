"""Fleet runtime: AR scheduling of ML jobs on the chip fleet."""
from repro.runtime.fleet import FleetJob, FleetScheduler, JobState, estimate_duration  # noqa: F401
