"""Fleet runtime: AR scheduling of ML jobs on the chip fleet."""
from repro.runtime.fleet import (  # noqa: F401
    FleetJob,
    FleetScheduler,
    JobState,
    PartitionedCore,
    estimate_duration,
)
