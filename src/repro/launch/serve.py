"""Serving driver: batched prefill + decode on the local device.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --smoke --batch 4 --prompt-len 64 --gen 32

Measures prefill latency and decode throughput; with ``--int8-kv`` the
quantised cache path is used (EXPERIMENTS.md §Perf C1).  On real
accelerators the same entry point serves the full config on the
production mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as tf_lib
from repro.serve import engine as serve_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--int8-kv", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if args.int8_kv:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    key = jax.random.PRNGKey(0)
    params = tf_lib.init_params(cfg, key)
    extra = {}
    if cfg.family == "encdec":
        extra["enc_frames"] = jax.random.normal(
            key, (args.batch, cfg.enc_seq, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        extra["image_embeds"] = jax.random.normal(
            key, (args.batch, cfg.vision_tokens, cfg.vision_dim)) * 0.1
    prompt = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)

    max_len = args.prompt_len + args.gen
    prefill = jax.jit(serve_lib.make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(serve_lib.make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, prompt, extra)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    tok = serve_lib.greedy_token(logits)
    # warm the decode path, then measure
    logits, cache = decode(params, cache, tok, extra)
    t0 = time.time()
    out = [tok]
    for _ in range(args.gen - 1):
        tok = serve_lib.greedy_token(logits)
        logits, cache = decode(params, cache, tok, extra)
        out.append(tok)
    logits.block_until_ready()
    t_decode = time.time() - t0
    tokens = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} kv={cfg.kv_cache_dtype} batch={args.batch}")
    print(f"prefill({args.prompt_len} tok): {t_prefill*1e3:.1f} ms")
    print(f"decode: {(args.gen - 1) * args.batch / t_decode:.1f} tok/s "
          f"({t_decode / (args.gen - 1) * 1e3:.1f} ms/step)")
    print(f"sample tokens[0,:8]: {tokens[0, :8].tolist()}")


if __name__ == "__main__":
    main()
