"""Subpackage."""
