"""End-to-end training driver with checkpoint/restart fault tolerance.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen3-4b --steps 200 --smoke

``--smoke`` runs the reduced config on the local device (the CPU path
used by tests and the quickstart); without it the full config is
launched on the production mesh (requires real accelerators; on this
box use dryrun.py instead).  Restart-safety: if the checkpoint
directory already has state, training resumes from the latest step —
kill the process at any point and rerun the same command.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import TokenPipeline
from repro.launch.mesh import make_production_mesh
from repro.models.common import mesh_context
from repro.train import optim as optim_lib
from repro.train import step as step_lib


def run(arch: str, steps: int, smoke: bool, batch: int, seq: int,
        ckpt_dir: str, ckpt_every: int, microbatches: int,
        lr: float = 3e-4, log_every: int = 10, config=None,
        use_mesh: bool = None) -> dict:
    cfg = config if config is not None else get_config(arch)
    if smoke:
        cfg = cfg.reduced() if config is None else cfg
        mesh = None
    else:
        mesh = make_production_mesh()
    opt_cfg = optim_lib.OptConfig(lr=lr, warmup_steps=min(50, steps // 5),
                                  total_steps=steps)
    train_step = jax.jit(step_lib.make_train_step(
        cfg, opt_cfg, microbatches))

    extra_shapes = {}
    if cfg.family == "encdec":
        extra_shapes["enc_frames"] = ((cfg.enc_seq, cfg.d_model),
                                      np.float32)
    if cfg.family == "vlm":
        extra_shapes["image_embeds"] = (
            (cfg.vision_tokens, cfg.vision_dim), np.float32)
    pipe = TokenPipeline(cfg.vocab, seq, batch,
                         microbatches=microbatches,
                         extra_shapes=extra_shapes, seed=0)
    mgr = CheckpointManager(ckpt_dir, keep=2)

    with mesh_context(mesh):
        params, opt_state = step_lib.init_train_state(
            cfg, opt_cfg, jax.random.PRNGKey(0))
        start = 0
        if mgr.latest_step() is not None:
            (params, opt_state), start, meta = mgr.restore(
                (params, opt_state))
            print(f"[restore] resumed from step {start} "
                  f"(loss was {meta.get('loss')})")
        losses = []
        t0 = time.time()
        for s in range(start, steps):
            batch_np = pipe.batch_at(s)
            batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt_state, metrics = train_step(
                params, opt_state, batch_dev)
            loss = float(metrics["loss"])
            losses.append(loss)
            if (s + 1) % log_every == 0:
                dt = (time.time() - t0) / log_every
                print(f"step {s+1:5d} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f} ms/step", flush=True)
                t0 = time.time()
            if (s + 1) % ckpt_every == 0 or s + 1 == steps:
                mgr.save_async(s + 1, (params, opt_state),
                               {"loss": loss, "arch": arch})
        mgr.wait()
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "steps_run": len(losses), "resumed_from": start}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()
    out = run(args.arch, args.steps, args.smoke, args.batch, args.seq,
              str(Path(args.ckpt_dir) / args.arch), args.ckpt_every,
              args.microbatches)
    print(f"done: {out}")


if __name__ == "__main__":
    main()
