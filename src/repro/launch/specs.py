"""ShapeDtypeStruct input stand-ins + shardings for every cell.

``input_specs(cfg, shape, mesh)`` returns (args, in_shardings) for the
step function that the cell lowers:

* ``train``   -> ``train_step(params, opt_state, batch)`` with batch
  leaves ``[mb, B/mb, ...]`` (microbatch axis scanned in the step);
* ``prefill`` -> ``prefill(params, tokens, extra)``;
* ``decode``  -> ``decode_step(params, cache, tokens, extra)`` with the
  cache shaped for ``seq_len`` context (the decode cells' semantics:
  one new token against a full KV cache / recurrent state).

Weak-type-correct, shardable, zero device allocation.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import data_shards
from repro.models import transformer as tf_lib
from repro.sharding.rules import fit_sharding


def default_microbatches(cfg: ModelConfig, shape: ShapeConfig,
                         mesh: Optional[Mesh]) -> int:
    if shape.kind != "train":
        return 1
    shards = data_shards(mesh) if mesh is not None else 1
    per_shard = max(shape.global_batch // shards, 1)
    # Larger models accumulate more to bound live activations.
    want = 8 if cfg.d_model >= 3584 else (4 if cfg.d_model >= 2048 else 2)
    return max(1, min(want, per_shard))


def _extra_struct(cfg: ModelConfig, batch_dims: Tuple[int, ...]
                  ) -> Dict[str, jax.ShapeDtypeStruct]:
    extra: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "encdec":
        extra["enc_frames"] = jax.ShapeDtypeStruct(
            (*batch_dims, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        extra["image_embeds"] = jax.ShapeDtypeStruct(
            (*batch_dims, cfg.vision_tokens, cfg.vision_dim),
            jnp.bfloat16)
    return extra


def _bd(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      microbatches: int):
    b_mb = shape.global_batch // microbatches
    structs: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct(
            (microbatches, b_mb, shape.seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct(
            (microbatches, b_mb, shape.seq_len), jnp.int32),
    }
    structs.update(_extra_struct(cfg, (microbatches, b_mb)))
    bd = _bd(mesh)
    shardings = {
        k: fit_sharding(mesh, v.shape,
                        P(None, bd, *([None] * (v.ndim - 2))))
        for k, v in structs.items()
    }
    return structs, shardings


def serve_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    bd = _bd(mesh)
    b = shape.global_batch
    if shape.kind == "prefill":
        structs = {
            "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
            **_extra_struct(cfg, (b,)),
        }
        shardings = {
            k: fit_sharding(mesh, v.shape,
                            P(bd, *([None] * (v.ndim - 1))))
            for k, v in structs.items()
        }
        return structs, shardings
    # decode: cache for seq_len context + one token
    cache = jax.eval_shape(
        lambda: tf_lib.init_decode_cache(cfg, b, shape.seq_len))
    if cfg.family in ("encdec", "vlm"):
        src = cfg.enc_seq if cfg.family == "encdec" else cfg.vision_tokens
        n_cl = (cfg.n_layers if cfg.family == "encdec"
                else cfg.n_layers // cfg.cross_attn_every)
        kv = jax.ShapeDtypeStruct(
            (n_cl, b, src, cfg.n_kv_heads, cfg.hd), jnp.bfloat16)
        cache["cross_kv"] = (kv, kv)
    structs = {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        **_extra_struct(cfg, (b,)),
    }
    shardings = {
        "cache": cache_shardings(cache, mesh),
        "tokens": fit_sharding(mesh, structs["tokens"].shape,
                               P(bd, None)),
    }
    for k, v in structs.items():
        if k not in shardings:
            shardings[k] = fit_sharding(
                mesh, v.shape, P(bd, *([None] * (v.ndim - 1))))
    return structs, shardings


def cache_shardings(cache, mesh: Mesh):
    """Decode-cache shardings: batch over data axes; the first
    divisible trailing axis over "model" (S for attention caches —
    context-parallel decode — heads/state lanes for recurrent states).
    Axes that do not divide the mesh extent are replicated, matching
    the divisibility fallback inside the model code."""
    bd = _bd(mesh)
    bd_n = 1
    for a in bd:
        bd_n *= mesh.shape[a]
    model_n = mesh.shape.get("model", 1)

    def spec(path, leaf) -> NamedSharding:
        names = [getattr(pe, "key", getattr(pe, "name", ""))
                 for pe in path]
        nd = leaf.ndim
        if nd == 0 or "pos" in names:
            return NamedSharding(mesh, P())
        axes: list = [None] * nd
        b_ax = 1 if nd >= 2 else 0
        if leaf.shape[b_ax] % bd_n == 0:
            axes[b_ax] = bd
        for i in range(b_ax + 1, nd):
            if leaf.shape[i] % model_n == 0:
                axes[i] = "model"
                break
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map_with_path(spec, cache)
