"""Mesh construction: the production dry-run shapes and the runtime
lane meshes the service layer shards ensembles over.

``make_production_mesh`` is a function (not a module constant) so that
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain the placeholder devices.

``make_lane_mesh`` / ``resolve_placement`` are the runtime seam
(DESIGN.md §8): ``ServiceConfig.placement`` resolves here to the 1-D
``("data",)`` mesh that :mod:`repro.api.service` shards the stacked
ensemble axis over.  On a single-device host the resolution degrades to
:func:`make_host_mesh`'s single-device data axis, so placement never
changes semantics — only where lanes live.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
from jax.sharding import Mesh


def _make_mesh(shape, axes) -> Mesh:
    # jax.sharding.AxisType post-dates the pinned jax; pass it when
    # present (explicit Auto matches the default), else omit it.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """1x1 mesh on the single real CPU device (tests, smoke runs)."""
    return _make_mesh((1, 1), ("data", "model"))


def data_shards(mesh: Mesh) -> int:
    n = mesh.shape.get("data", 1)
    return n * mesh.shape.get("pod", 1)


def make_lane_mesh(n_lanes: int,
                   max_shards: Optional[int] = None) -> Mesh:
    """1-D ``("data",)`` mesh for sharding an ensemble/partition axis.

    GSPMD input shardings must divide the sharded extent, so the mesh
    takes the *largest divisor* of ``n_lanes`` that fits the local
    device count (optionally capped by ``max_shards``): 63 lanes on 8
    devices shard 7-way, 504 lanes shard 8-way, and a prime lane count
    on one device degrades to :func:`make_host_mesh` — identical
    decisions either way, only the placement differs.
    """
    if n_lanes < 1:
        raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
    n_dev = len(jax.devices())
    if max_shards is not None:
        n_dev = min(n_dev, max_shards)
    d = max(k for k in range(1, max(n_dev, 1) + 1) if n_lanes % k == 0)
    if d == 1:
        return make_host_mesh()
    return _make_mesh((d,), ("data",))


def resolve_placement(placement: Union[None, str, int],
                      n_lanes: int) -> Optional[Mesh]:
    """``ServiceConfig.placement`` -> the mesh lanes shard over.

    ``None`` / ``"single"`` disables sharding entirely (the pre-mesh
    single-device path); ``"auto"`` shards over every local device via
    :func:`make_lane_mesh`; ``"host"`` pins the 1x1
    :func:`make_host_mesh`; an ``int`` caps the shard count.
    """
    if placement is None or placement == "single":
        return None
    if placement == "host":
        return make_host_mesh()
    if placement == "auto":
        return make_lane_mesh(n_lanes)
    if isinstance(placement, int):
        return make_lane_mesh(n_lanes, max_shards=placement)
    raise ValueError(f"unknown placement {placement!r}")
