"""Production mesh construction (single-pod and multi-pod).

``make_production_mesh`` is a function (not a module constant) so that
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain the placeholder devices.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh() -> Mesh:
    """1x1 mesh on the single real CPU device (tests, smoke runs)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto, jax.sharding.AxisType.Auto))


def data_shards(mesh: Mesh) -> int:
    n = mesh.shape.get("data", 1)
    return n * mesh.shape.get("pod", 1)
