import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the production mesh, constructs
ShapeDtypeStruct stand-ins for every input (no device allocation),
lowers the appropriate step function with explicit in/out shardings,
compiles it, and records:

* ``memory_analysis()``  — per-device argument/output/temp bytes
  (proves the program fits, or quantifies by how much it does not);
* ``cost_analysis()``    — raw HLO FLOPs/bytes (loop bodies counted
  once; see roofline/analysis.py for why the analytic model is also
  recorded);
* collective bytes parsed from ``compiled.as_text()``;
* the analytic roofline terms.

Usage:
    python -m repro.launch.dryrun --arch all --shape all --mesh both
    python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import (
    ALL_SHAPES,
    ARCH_IDS,
    applicable,
    get_config,
    shape_by_name,
)
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tf_lib
from repro.models.common import mesh_context
from repro.roofline import analysis as roof
from repro.serve import engine as serve_lib
from repro.sharding import rules
from repro.sharding.rules import fit_sharding
from repro.train import optim as optim_lib
from repro.train import step as step_lib

from jax.sharding import NamedSharding, PartitionSpec as P


def _mesh_shape_dict(mesh) -> dict:
    return {k: int(v) for k, v in mesh.shape.items()}


def _sharded_bytes(structs, shardings) -> int:
    """Exact per-device bytes of a pytree under its NamedShardings."""
    total = 0
    for leaf, sh in zip(jax.tree.leaves(structs),
                        jax.tree.leaves(
                            shardings,
                            is_leaf=lambda x: isinstance(
                                x, NamedSharding))):
        shape = sh.shard_shape(leaf.shape)
        n = 1
        for d in shape:
            n *= d
        total += n * leaf.dtype.itemsize
    return total


def _activation_estimate(cfg, shape, mesh, mb: int) -> float:
    """Modeled per-device peak activation bytes (bf16, remat'd scan:
    one residual per layer per microbatch + one layer's working set)."""
    chips = 1
    for v in mesh.shape.values():
        chips *= int(v)
    d = cfg.d_model
    n_lay = cfg.n_layers + cfg.n_enc_layers
    if shape.kind == "train":
        toks_mb = shape.global_batch * shape.seq_len / mb
        resid = n_lay * toks_mb * d * 2
        work = 6 * toks_mb * d * 2 + toks_mb * max(cfg.d_ff, d) * 2
        return (resid + work) / chips * 1.3
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        kv = (2 * n_lay * toks * cfg.n_kv_heads * cfg.hd * 2)
        work = 8 * toks * d * 2
        return (kv + work) / chips * 1.3
    toks = shape.global_batch
    return 4 * toks * d * max(cfg.n_layers, 1) * 2 / chips * 1.3


OPT_OVERRIDES = dict(seq_parallel=True, moe_quant_dispatch=True,
                     kv_cache_dtype="int8")


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               keep_hlo: bool = False, opt: bool = False) -> dict:
    cfg = get_config(arch)
    if opt:
        cfg = dataclasses.replace(cfg, **OPT_OVERRIDES)
    shape = shape_by_name(shape_name)
    ok, why = applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind}
    if not ok:
        rec.update(status="SKIPPED", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    key_s = jax.ShapeDtypeStruct((2,), jax.numpy.uint32)
    params_s = jax.eval_shape(
        lambda k: tf_lib.init_params(cfg, k), key_s)
    p_specs = rules.to_named(mesh, rules.param_specs(params_s))
    mb = specs_lib.default_microbatches(cfg, shape, mesh)
    rec["microbatches"] = mb

    with mesh_context(mesh):
        if shape.kind == "train":
            big = cfg.d_model >= 7000   # 1T-class: bf16 moments
            opt_cfg = optim_lib.OptConfig(
                state_dtype="bfloat16" if big else "float32")
            opt_s = jax.eval_shape(
                lambda p: optim_lib.init(p, opt_cfg), params_s)
            z_specs = rules.to_named(mesh, rules.zero_specs(
                rules.param_specs(params_s), params_s, mesh))
            o_specs = optim_lib.OptState(
                step=NamedSharding(mesh, P()), mu=z_specs, nu=z_specs)
            batch_s, batch_sh = specs_lib.train_batch_specs(
                cfg, shape, mesh, mb)
            step = step_lib.make_train_step(
                cfg, opt_cfg, mb,
                accum_dtype=jax.numpy.bfloat16 if big
                else jax.numpy.float32)
            jitted = jax.jit(
                step,
                in_shardings=(p_specs, o_specs, batch_sh),
                out_shardings=(p_specs, o_specs,
                               NamedSharding(mesh, P())),
                donate_argnums=(0, 1))
            lowered = jitted.lower(params_s, opt_s, batch_s)
        elif shape.kind == "prefill":
            in_s, in_sh = specs_lib.serve_specs(cfg, shape, mesh)
            step = serve_lib.make_prefill_step(cfg, max_len=shape.seq_len)
            tokens_s = in_s.pop("tokens")
            tokens_sh = in_sh.pop("tokens")
            extra_s = in_s or None
            extra_sh = in_sh or None
            cache_sh = jax.eval_shape(
                lambda p, t, e: step(p, t, e), params_s, tokens_s,
                extra_s)
            bd = tuple(a for a in ("pod", "data")
                       if a in mesh.axis_names)
            out_sh = (fit_sharding(mesh, cache_sh[0].shape, P(bd, None)),
                      specs_lib.cache_shardings(cache_sh[1], mesh))
            jitted = jax.jit(
                step, in_shardings=(p_specs, tokens_sh, extra_sh),
                out_shardings=out_sh)
            lowered = jitted.lower(params_s, tokens_s, extra_s)
        else:  # decode
            in_s, in_sh = specs_lib.serve_specs(cfg, shape, mesh)
            step = serve_lib.make_decode_step(cfg)
            bd = tuple(a for a in ("pod", "data")
                       if a in mesh.axis_names)
            extra_keys = [k for k in in_s
                          if k not in ("cache", "tokens")]
            extra_s = {k: in_s[k] for k in extra_keys} or None
            extra_sh = {k: in_sh[k] for k in extra_keys} or None
            jitted = jax.jit(
                step,
                in_shardings=(p_specs, in_sh["cache"], in_sh["tokens"],
                              extra_sh),
                out_shardings=(fit_sharding(
                    mesh, (shape.global_batch, cfg.vocab), P(bd, None)),
                               in_sh["cache"]),
                donate_argnums=(1,))
            lowered = jitted.lower(params_s, in_s["cache"],
                                   in_s["tokens"], extra_s)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # pre-0.4.27 JAX: one dict per device
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    colls = roof.parse_collectives(hlo)
    # exact per-device argument bytes at the *intended* dtypes (the CPU
    # backend upconverts bf16 dots to f32, inflating memory_analysis;
    # see EXPERIMENTS.md §Methodology)
    args_dev = _sharded_bytes(params_s, p_specs)
    if shape.kind == "train":
        args_dev += _sharded_bytes(
            (opt_s.mu, opt_s.nu), (o_specs.mu, o_specs.nu))
        args_dev += _sharded_bytes(batch_s, batch_sh)
    elif shape.kind == "decode":
        args_dev += _sharded_bytes(in_s["cache"], in_sh["cache"])
    act_dev = _activation_estimate(cfg, shape, mesh, mb)
    model_dev_total = args_dev + act_dev
    costs = roof.step_costs(
        cfg, shape, _mesh_shape_dict(mesh), microbatches=mb,
        opt_state_bytes_per_param=(4 if cfg.d_model >= 7000 else 8))
    chips = 512 if multi_pod else 256
    terms = costs.terms(chips)
    per_dev_bytes = (ma.argument_size_in_bytes
                     + ma.temp_size_in_bytes
                     + ma.output_size_in_bytes
                     - ma.alias_size_in_bytes)
    rec.update(
        status="OK",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory={
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "per_device_total": per_dev_bytes,
            "fits_16g_hbm": bool(per_dev_bytes < 16e9),
            # intended-dtype model (CPU backend inflates bf16 -> f32)
            "model_args_bytes": args_dev,
            "model_act_bytes": int(act_dev),
            "model_per_device_total": int(model_dev_total),
            "model_fits_16g_hbm": bool(model_dev_total < 16e9),
        },
        hlo_raw={
            "flops": ca.get("flops", -1.0),
            "bytes_accessed": ca.get("bytes accessed", -1.0),
            "collectives": colls,
            "n_hlo_lines": hlo.count("\n"),
        },
        analytic=dataclasses.asdict(costs),
        roofline=terms,
    )
    if keep_hlo:
        rec["hlo_text"] = hlo
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="beyond-paper perf knobs: SP + int8 MoE a2a "
                         "+ int8 KV cache (EXPERIMENTS.md §Perf)")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = [s.name for s in ALL_SHAPES] if args.shape == "all" \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
                path = outdir / f"{tag}.json"
                if path.exists() and not args.force:
                    print(f"[cached] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape, multi, opt=args.opt)
                except Exception as e:           # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "status": "FAILED", "error": str(e),
                           "traceback": traceback.format_exc()}
                path.write_text(json.dumps(rec, indent=2))
                st = rec["status"]
                n_ok += st == "OK"
                n_skip += st == "SKIPPED"
                n_fail += st == "FAILED"
                if st == "OK":
                    r = rec["roofline"]
                    print(f"  OK lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"dom={r['dominant']} "
                          f"comp={r['compute_s']:.2e}s "
                          f"mem={r['memory_s']:.2e}s "
                          f"coll={r['collective_s']:.2e}s "
                          f"fits={rec['memory']['model_fits_16g_hbm']}"
                          f"(raw={rec['memory']['fits_16g_hbm']})",
                          flush=True)
                elif st == "SKIPPED":
                    print(f"  SKIPPED ({rec['reason'][:60]})")
                else:
                    print(f"  FAILED: {rec['error'][:200]}")
    print(f"dry-run complete: {n_ok} ok, {n_skip} skipped, "
          f"{n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
