"""The multi-tenant admission tables (DESIGN.md §10).

The paper's scheduler admits anonymous requests; the production
service (ROADMAP "multi-tenant service hardening") attributes every
request to a *tenant* and enforces per-tenant policy at admission:

``TenantSpec``
    The host-side configuration: per-tenant fair-share weights,
    PE-seconds quotas, concurrent-reservation caps, the overdue
    grace window, and the telemetry EWMA coefficient.  Frozen and
    validated once by ``ServiceConfig``.
``TenantTable``
    The device-resident state: a pytree of ``[T]`` per-tenant
    accumulators plus per-slot ownership columns for the pending
    buffer and the deferral queue.  The tenant axis ``T`` is a
    *static* shape; every weight/quota/cap is a **traced leaf**, so
    reconfiguring tenants never recompiles — exactly like the traced
    policy and backfill ids of the fused admit step.
``HostTenantAccounts``
    The numpy mirror used by the differential ``TenantOracle`` and
    the host-routed partition gate.  All fractional accounting is
    float32 on both sides with identical expression shapes, so the
    device table and the host mirror agree **bit-for-bit** (the same
    contract the PR 4 backfill oracle established for decisions).

The table hangs off ``SchedulerState.tenants`` as an *optional*
trailing field: ``None`` contributes no pytree leaves, so zero-tenant
sessions compile the byte-identical graphs they had before tenancy
existed.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

#: int32 "+infinity" for unlimited concurrent-reservation caps.
_I32_MAX = 2**31 - 1

#: Supported over-quota dispositions.  ``"park"`` (defer instead of
#: reject) is reserved for a later PR: parking an over-quota request
#: would hold a reservation mark for work the tenant may never be
#: allowed to run.
OVER_QUOTA_MODES = ("reject",)


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """Host-side tenant configuration (``ServiceConfig.tenants``).

    ``weights``
        one positive fair-share weight per tenant; the tuple length
        *is* the tenant count.  Equal weights make the fair-share
        ranking provably bit-identical to FCFS (DESIGN.md §10).
    ``quotas``
        per-tenant lifetime PE-seconds budgets (``None`` entries are
        unlimited); an admission that would exceed the budget is
        rejected *before* search.
    ``max_live``
        per-tenant concurrent-reservation caps (``None`` = unlimited).
    ``over_quota``
        disposition of gated requests; only ``"reject"`` today.
    ``grace``
        overdue-reservation grace window: on ``Session.tick(t)`` a
        reservation still held past ``t_e + grace`` is reaped
        (batch-deleted, charged to its tenant).  ``None`` disables
        reaping.
    ``ewma_alpha``
        coefficient of the telemetry EWMAs (acceptance, slowdown,
        occupancy).
    """

    weights: Tuple[float, ...] = (1.0,)
    quotas: Optional[Tuple[Optional[float], ...]] = None
    max_live: Optional[Tuple[Optional[int], ...]] = None
    over_quota: str = "reject"
    grace: Optional[int] = None
    ewma_alpha: float = 0.05

    def __post_init__(self):
        if not self.weights:
            raise ValueError("TenantSpec needs at least one tenant "
                             "(weights is empty)")
        ws = tuple(float(w) for w in self.weights)
        object.__setattr__(self, "weights", ws)
        if any(not np.isfinite(w) or w <= 0 for w in ws):
            raise ValueError(
                f"tenant weights must be positive and finite, got "
                f"{self.weights}")
        for name in ("quotas", "max_live"):
            vals = getattr(self, name)
            if vals is None:
                continue
            vals = tuple(vals)
            object.__setattr__(self, name, vals)
            if len(vals) != len(ws):
                raise ValueError(
                    f"{len(vals)} {name} entries for "
                    f"{len(ws)} tenants")
            if any(v is not None and v <= 0 for v in vals):
                raise ValueError(
                    f"{name} entries must be positive (or None for "
                    f"unlimited), got {vals}")
        if self.over_quota not in OVER_QUOTA_MODES:
            raise ValueError(
                f"unknown over_quota {self.over_quota!r}; supported: "
                f"{OVER_QUOTA_MODES} (over_quota='park' is not "
                f"implemented: parking an over-quota request would "
                f"reserve capacity the tenant may never get)")
        if self.grace is not None and self.grace < 0:
            raise ValueError(
                f"grace must be >= 0 (seconds past t_e), got "
                f"{self.grace}")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError(
                f"ewma_alpha must be in (0, 1], got {self.ewma_alpha}")

    @property
    def n_tenants(self) -> int:
        return len(self.weights)

    def quota_array(self) -> np.ndarray:
        """float32[T] PE-seconds budgets; inf = unlimited."""
        if self.quotas is None:
            return np.full(self.n_tenants, np.inf, np.float32)
        return np.asarray(
            [np.inf if q is None else float(q) for q in self.quotas],
            np.float32)

    def max_live_array(self) -> np.ndarray:
        """int32[T] concurrent caps; INT32_MAX = unlimited."""
        if self.max_live is None:
            return np.full(self.n_tenants, _I32_MAX, np.int32)
        return np.asarray(
            [_I32_MAX if m is None else int(m) for m in self.max_live],
            np.int32)

    def padded(self, n_tenants: int) -> "TenantSpec":
        """This spec widened to ``n_tenants`` with neutral tenants.

        The padding tenants (weight 1, unlimited) never receive
        requests; padding lets heterogeneous per-lane specs share one
        static tenant axis (the sweep's tenant-mix axis).
        """
        if n_tenants < self.n_tenants:
            raise ValueError(
                f"cannot pad {self.n_tenants} tenants down to "
                f"{n_tenants}")
        pad = n_tenants - self.n_tenants
        if pad == 0:
            return self
        return dataclasses.replace(
            self,
            weights=self.weights + (1.0,) * pad,
            quotas=None if self.quotas is None
            else self.quotas + (None,) * pad,
            max_live=None if self.max_live is None
            else self.max_live + (None,) * pad)


class TenantTable(NamedTuple):
    """Device-resident per-tenant state (a JAX pytree, DESIGN.md §10).

    Configuration leaves (traced — changing values never recompiles):
    ``weight``/``quota``/``max_live``/``alpha``.  Accounting leaves:
    ``used`` (lifetime PE-seconds admitted), ``live`` (currently held
    reservations), the lifetime counters, and the telemetry EWMAs.
    Ownership columns attribute every pending-buffer slot
    (``pend_tenant``) and deferral-queue slot (``park_tenant``, plus
    the arrival stamp ``park_ta`` that feeds the fair-share key) to a
    tenant; ``-1`` marks an unowned slot.
    """

    weight: jax.Array        # float32[T] fair-share weights
    quota: jax.Array         # float32[T] PE-seconds budget; inf = none
    max_live: jax.Array      # int32[T] concurrent cap; I32_MAX = none
    used: jax.Array          # float32[T] lifetime PE-seconds admitted
    live: jax.Array          # int32[T] currently held reservations
    n_accepted: jax.Array    # int32[T]
    n_rejected: jax.Array    # int32[T] (all rejections, incl. gated)
    n_quota_rejected: jax.Array  # int32[T] rejected by the quota gate
    n_parked: jax.Array      # int32[T] accepted into the deferral queue
    n_reaped: jax.Array      # int32[T] reservations reaped overdue
    acc_ewma: jax.Array      # float32[T] per-tenant acceptance EWMA
    slow_ewma: jax.Array     # float32[T] per-tenant slowdown EWMA
    occ_ewma: jax.Array      # float32 scalar machine-occupancy EWMA
    alpha: jax.Array         # float32 scalar EWMA coefficient (traced)
    pend_tenant: jax.Array   # int32[K] pending-slot owner; -1 = free
    park_tenant: jax.Array   # int32[Q] queue-slot owner; -1 = free
    park_ta: jax.Array       # int32[Q] queue-slot arrival time

    @property
    def n_tenants(self) -> int:
        return self.weight.shape[-1]


def init_table(spec: TenantSpec, pending_capacity: int,
               park_capacity: int) -> TenantTable:
    """Fresh all-zero device table for one timeline's buffers."""
    T = spec.n_tenants
    # distinct buffers per leaf: aliased zeros would break jit
    # donation (XLA rejects donating one buffer twice)
    zi = lambda: jnp.zeros((T,), jnp.int32)
    zf = lambda: jnp.zeros((T,), jnp.float32)
    return TenantTable(
        weight=jnp.asarray(spec.weights, jnp.float32),
        quota=jnp.asarray(spec.quota_array()),
        max_live=jnp.asarray(spec.max_live_array()),
        used=zf(), live=zi(),
        n_accepted=zi(), n_rejected=zi(), n_quota_rejected=zi(),
        n_parked=zi(), n_reaped=zi(),
        acc_ewma=zf(), slow_ewma=zf(),
        occ_ewma=jnp.float32(0.0),
        alpha=jnp.float32(spec.ewma_alpha),
        pend_tenant=jnp.full((pending_capacity,), -1, jnp.int32),
        park_tenant=jnp.full((park_capacity,), -1, jnp.int32),
        park_ta=jnp.zeros((park_capacity,), jnp.int32),
    )


def stack_tables(specs, pending_capacity: int,
                 park_capacity: int) -> TenantTable:
    """Per-lane specs -> one stacked ``[E, ...]`` table.

    Heterogeneous lane specs are padded to the widest tenant count
    (:meth:`TenantSpec.padded`); ``None`` entries become neutral
    equal-weight unlimited tables, which are decision-identical to no
    table at all (the FCFS-equivalence invariant, DESIGN.md §10).
    """
    specs = list(specs)
    T = max((s.n_tenants for s in specs if s is not None), default=1)
    tables = [
        init_table((s or TenantSpec(weights=(1.0,) * T)).padded(T),
                   pending_capacity, park_capacity)
        for s in specs]
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *tables)


def grow_table(table: TenantTable,
               new_pending_capacity: int) -> TenantTable:
    """Pad the pending ownership column to a grown pending buffer."""
    K = table.pend_tenant.shape[0]
    assert new_pending_capacity >= K
    pad = new_pending_capacity - K
    if pad == 0:
        return table
    return table._replace(pend_tenant=jnp.concatenate(
        [table.pend_tenant, jnp.full((pad,), -1, jnp.int32)]))


def fair_key(table: TenantTable, t_now: jax.Array) -> jax.Array:
    """The weighted wait-time fair-share key of every queue slot.

    ``key = weight[owner] * float32(t_now - t_a)``: float32 on device
    and host alike, so the differential oracle ranks bit-identically.
    Free slots produce garbage keys; every consumer masks by slot
    liveness first.  With equal weights the (-key, seq) order reduces
    exactly to FCFS seq order — arrival stamps are non-decreasing in
    seq, and float32 scaling of non-negative waits is monotone — the
    invariant ``tests/test_tenancy.py`` locks down.
    """
    T = table.weight.shape[-1]
    tid = jnp.clip(table.park_tenant, 0, T - 1)
    wait = (jnp.asarray(t_now, jnp.int32)
            - table.park_ta).astype(jnp.float32)
    return jnp.take(table.weight, tid) * wait


def _ewma(e: np.float32, x: np.float32, a: np.float32) -> np.float32:
    """One float32 EWMA step, matching XLA's compilation bit-for-bit.

    XLA contracts ``e*(1-a) + x*a`` into fused multiply-adds: both
    float32 products stay exact and only the final sum rounds.  A
    float64 evaluation reproduces that (f32 products are exact in f64)
    where the naive two-rounding numpy expression drifts by ULPs.
    """
    one = np.float32(1.0)
    return np.float32(np.float64(e) * np.float64(one - a)
                      + np.float64(x) * np.float64(a))


class HostTenantAccounts:
    """Numpy mirror of :class:`TenantTable` accounting (bit-exact).

    Shared by the differential :class:`~repro.core.hostsched.
    TenantOracle` and the host-routed partition quota gate.  Every
    fractional update reproduces the device expression shape in
    float32, so ``snapshot()`` matches the device table bit-for-bit
    after identical request streams.
    """

    def __init__(self, spec: TenantSpec):
        self.spec = spec
        T = spec.n_tenants
        self.weight = np.asarray(spec.weights, np.float32)
        self.quota = spec.quota_array()
        self.max_live = spec.max_live_array()
        self.used = np.zeros(T, np.float32)
        self.live = np.zeros(T, np.int32)
        self.n_accepted = np.zeros(T, np.int32)
        self.n_rejected = np.zeros(T, np.int32)
        self.n_quota_rejected = np.zeros(T, np.int32)
        self.n_parked = np.zeros(T, np.int32)
        self.n_reaped = np.zeros(T, np.int32)
        self.acc_ewma = np.zeros(T, np.float32)
        self.slow_ewma = np.zeros(T, np.float32)
        self.occ_ewma = np.float32(0.0)
        self.alpha = np.float32(spec.ewma_alpha)

    @property
    def n_tenants(self) -> int:
        return self.spec.n_tenants

    def clip_tid(self, tenant: int) -> int:
        return min(max(int(tenant), 0), self.n_tenants - 1)

    def allowed(self, tid: int, n_pe: int, t_du: int) -> bool:
        """The quota gate: same float32 compare as the device."""
        demand = np.float32(n_pe) * np.float32(t_du)
        return bool(
            (self.used[tid] + demand <= self.quota[tid])
            and (self.live[tid] < self.max_live[tid]))

    def record(self, tid: int, *, accepted: bool, blocked: bool,
               parked: bool, occ_frac: np.float32,
               t_e: int = -1, t_r: int = 0, t_du: int = 1,
               n_pe: int = 0) -> None:
        """One real request's accounting (mirrors ``_admit_impl``)."""
        one = np.float32(1.0)
        a = self.alpha
        if accepted:
            self.used[tid] = np.float32(
                self.used[tid]
                + np.float32(n_pe) * np.float32(t_du))
            self.live[tid] += 1
            self.n_accepted[tid] += 1
            if parked:
                self.n_parked[tid] += 1
            slow = np.float32(t_e - t_r) / np.float32(t_du)
            self.slow_ewma[tid] = _ewma(self.slow_ewma[tid], slow, a)
        else:
            self.n_rejected[tid] += 1
            if blocked:
                self.n_quota_rejected[tid] += 1
        x = one if accepted else np.float32(0.0)
        self.acc_ewma[tid] = _ewma(self.acc_ewma[tid], x, a)
        self.occ_ewma = _ewma(self.occ_ewma, np.float32(occ_frac), a)

    def release(self, tenant: int) -> None:
        if tenant >= 0:
            self.live[self.clip_tid(tenant)] -= 1

    def reap(self, tenant: int) -> None:
        if tenant >= 0:
            tid = self.clip_tid(tenant)
            self.live[tid] -= 1
            self.n_reaped[tid] += 1

    def snapshot(self) -> dict:
        """Same layout as :func:`repro.tenancy.telemetry.snapshot`."""
        return dict(
            weight=self.weight.copy(), quota=self.quota.copy(),
            max_live=self.max_live.copy(),
            used=self.used.copy(), live=self.live.copy(),
            n_accepted=self.n_accepted.copy(),
            n_rejected=self.n_rejected.copy(),
            n_quota_rejected=self.n_quota_rejected.copy(),
            n_parked=self.n_parked.copy(),
            n_reaped=self.n_reaped.copy(),
            acc_ewma=self.acc_ewma.copy(),
            slow_ewma=self.slow_ewma.copy(),
            occ_ewma=np.float32(self.occ_ewma))
