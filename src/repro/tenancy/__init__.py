"""Multi-tenant admission: quotas, fair-share, reaping (DESIGN.md §10).

Public surface:

- :class:`TenantSpec` — host-side config (``ServiceConfig.tenants``).
- :class:`TenantTable` — device-resident per-tenant state pytree,
  threaded through the fused admit step as the optional
  ``SchedulerState.tenants`` field.
- :func:`snapshot` / :func:`tenant_view` — poll-cheap telemetry.
"""
from .table import (HostTenantAccounts, TenantSpec, TenantTable,
                    fair_key, grow_table, init_table, stack_tables)
from .telemetry import snapshot, tenant_view

__all__ = [
    "TenantSpec", "TenantTable", "HostTenantAccounts",
    "init_table", "stack_tables", "grow_table", "fair_key",
    "snapshot", "tenant_view",
]
