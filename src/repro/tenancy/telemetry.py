"""Poll-cheap telemetry snapshots of a device :class:`TenantTable`.

The counters live *in* the table, updated inside the fused admit step
(the same lazy-accumulator discipline as the service's
``_defer_accepted`` counter: nothing is read back per step).  A
snapshot is therefore one ``device_get`` of the whole table pytree —
and the service caches it until the state actually changes, so
polling an idle session costs zero device dispatches
(``tests/test_tenancy.py::test_idle_metrics_zero_device_fetches``).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from .table import TenantTable

#: Table fields surfaced per tenant by :func:`tenant_view`.
_PER_TENANT = ("weight", "quota", "max_live", "used", "live",
               "n_accepted", "n_rejected", "n_quota_rejected",
               "n_parked", "n_reaped", "acc_ewma", "slow_ewma")


def snapshot(table: TenantTable, fetch=None) -> Dict[str, np.ndarray]:
    """One fused host read of every tenant counter.

    ``fetch`` is the device->host transfer function (defaults to
    ``jax.device_get``); the service injects its counted
    ``_device_fetch`` hook so tests can assert poll cost.
    """
    if fetch is None:
        import jax
        fetch = jax.device_get
    host = fetch({f: getattr(table, f) for f in _PER_TENANT
                  + ("occ_ewma",)})
    out = {k: np.asarray(v) for k, v in host.items()}
    out["occ_ewma"] = np.float32(out["occ_ewma"])
    return out


def tenant_view(snap: Dict[str, np.ndarray], tenant: int) -> Dict:
    """One tenant's scalar slice of a :func:`snapshot` dict.

    Works on per-lane stacked snapshots too (leading ensemble axes
    are preserved; only the trailing tenant axis is indexed).
    """
    n = np.asarray(snap["weight"]).shape[-1]
    if not 0 <= tenant < n:
        raise ValueError(f"tenant {tenant} out of range [0, {n})")
    out = {}
    for k in _PER_TENANT:
        col = np.asarray(snap[k])[..., tenant]
        out[k] = col.item() if col.ndim == 0 else col
    out["tenant"] = tenant
    out["occ_ewma"] = snap["occ_ewma"]
    return out
