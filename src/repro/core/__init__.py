"""Core library: the paper's advance-reservation scheduling technique.

Three interchangeable engines implement the slot-based availability
structure and the seven policies of the paper:

* :mod:`repro.core.listsched`  — literal Python-set oracle (Section 4).
* :mod:`repro.core.hostsched`  — vectorised numpy bitmask engine.
* :mod:`repro.core.timeline` / :mod:`repro.core.search` — JAX device
  engine (dense tensors, MXU contractions, optional Pallas kernel).
"""
from repro.core.types import (  # noqa: F401
    ALL_POLICIES,
    BACKFILL_MODES,
    Allocation,
    ARRequest,
    BackfillMode,
    Policy,
    Rectangle,
    T_INF,
    backfill_index,
)
from repro.core.scheduler import make_scheduler  # noqa: F401
from repro.core.batch import (  # noqa: F401
    Decision,
    RequestBatch,
    RequestRing,
    admit,
    admit_stream,
    admit_stream_grow,
    requests_to_batch,
)
from repro.core.timeline import SchedulerState, init_state  # noqa: F401
from repro.core.ensemble import (  # noqa: F401
    admit_ensemble,
    admit_stream_ensemble,
    admit_stream_ensemble_auto,
    find_allocation_ensemble,
    init_ensemble,
    member,
    stack_states,
)
