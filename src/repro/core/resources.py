"""Static multi-resource layout descriptor (DESIGN.md §11).

The availability timeline generalises from one packed PE bitmask per
record to a *resource occupancy matrix*: one packed bitplane per
resource, concatenated along the existing uint32 word axis.  Plane
``r`` covers ``units[r]`` schedulable units and occupies the word
range ``[word_offsets[r], word_offsets[r] + words_per[r])``; resource
0 is always the paper's PE plane.  With ``R == 1`` the layout is
byte-identical to the scalar timeline, which is what makes the R=1
bit-identity argument a layout statement rather than a code-path one.

:class:`ResourceSpec` is *static* configuration: it is registered as a
zero-leaf pytree node (the spec itself is the aux data), so it can ride
inside :class:`~repro.core.timeline.SchedulerState` without adding
array leaves — legacy ``rspec=None`` states keep their exact treedef,
and rspec-carrying states stay hashable/static under ``jit``, ``vmap``
and donation for free.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np

_WORD = 32


def _n_words(units: int) -> int:
    return (units + _WORD - 1) // _WORD


@dataclasses.dataclass(frozen=True)
class ResourceSpec:
    """Per-resource unit counts; ``units[0]`` is the primary PE plane.

    Frozen and hashable: two specs with equal ``units`` are
    interchangeable as static jit arguments.
    """

    units: Tuple[int, ...]

    def __post_init__(self) -> None:
        units = tuple(int(u) for u in self.units)
        if not units:
            raise ValueError("ResourceSpec needs at least one resource")
        if any(u <= 0 for u in units):
            raise ValueError(f"resource units must be positive: {units}")
        object.__setattr__(self, "units", units)

    @property
    def R(self) -> int:
        return len(self.units)

    @property
    def n_pe(self) -> int:
        return self.units[0]

    @property
    def words_per(self) -> Tuple[int, ...]:
        return tuple(_n_words(u) for u in self.units)

    @property
    def word_offsets(self) -> Tuple[int, ...]:
        offs, acc = [], 0
        for w in self.words_per:
            offs.append(acc)
            acc += w
        return tuple(offs)

    @property
    def total_words(self) -> int:
        return sum(self.words_per)

    @property
    def total_bits(self) -> int:
        return self.total_words * _WORD

    def plane_slice(self, r: int) -> slice:
        """Word-axis slice of plane ``r``."""
        off = self.word_offsets[r]
        return slice(off, off + self.words_per[r])

    def bit_offset(self, r: int) -> int:
        """Global bit id of unit 0 of plane ``r``."""
        return self.word_offsets[r] * _WORD

    def valid_bits_np(self,
                      live_units: Optional[Sequence[int]] = None
                      ) -> np.ndarray:
        """0/1 uint32[total_bits]: the schedulable units of each plane.

        ``live_units`` optionally shrinks planes for heterogeneous
        machine lanes (``live_units[r] <= units[r]``); padding between
        ``live_units[r]`` and the plane's word boundary stays 0, so
        popcount contractions over masked free words never see it.
        """
        live = self.units if live_units is None else tuple(live_units)
        if len(live) != self.R:
            raise ValueError(
                f"live_units has {len(live)} entries, spec has {self.R}")
        bits = np.zeros(self.total_bits, dtype=np.uint32)
        for r, (u, lu) in enumerate(zip(self.units, live)):
            lu = int(lu)
            if not 0 < lu <= u:
                raise ValueError(
                    f"live_units[{r}]={lu} outside (0, {u}]")
            o = self.bit_offset(r)
            bits[o:o + lu] = 1
        return bits

    def valid_mask_np(self,
                      live_units: Optional[Sequence[int]] = None
                      ) -> np.ndarray:
        """Packed uint32[total_words] valid-unit mask (see above)."""
        bits = self.valid_bits_np(live_units)
        b = bits.reshape(self.total_words, _WORD)
        shifts = np.arange(_WORD, dtype=np.uint32)
        return ((b << shifts).sum(axis=1)).astype(np.uint32)

    def demand_tail(self, demand: Optional[Sequence[int]],
                    n_pe: int) -> Tuple[int, ...]:
        """Validate a request's demand vector, return planes 1..R-1.

        ``demand`` is the full per-resource vector; ``None`` means
        "PEs only" (zero demand on every secondary plane).  Plane 0
        must agree with the request's ``n_pe`` so the primary-plane
        feasibility test can keep riding on ``n_pe`` unchanged.
        """
        if demand is None:
            return (0,) * (self.R - 1)
        d = tuple(int(x) for x in demand)
        if len(d) != self.R:
            raise ValueError(
                f"demand has {len(d)} entries, spec has {self.R}")
        if d[0] != int(n_pe):
            raise ValueError(
                f"demand[0]={d[0]} must equal n_pe={int(n_pe)}")
        for r, x in enumerate(d):
            if not 0 <= x <= self.units[r]:
                raise ValueError(
                    f"demand[{r}]={x} outside [0, {self.units[r]}]")
        return d[1:]


# Zero-leaf pytree registration: the spec is its own aux data.  It
# contributes nothing to flattened leaves (so tree_map / broadcast /
# donation ignore it) and everything to the treedef (so jit treats it
# as static and retraces when — and only when — the spec changes).
jax.tree_util.register_pytree_node(
    ResourceSpec,
    lambda r: ((), r),
    lambda aux, _: aux,
)
