"""Hierarchical availability index: per-tile timeline summaries (DESIGN.md §12).

The paper's central claim is a data structure "that enables efficient
search and update operations" — yet the flat packed-bitmask timeline
makes every search contract all ``S`` records.  This module adds the
classic augmented-summary fix (cf. the Enhanced Red-Black-Tree paper,
PAPERS.md): the ``S`` timeline records are grouped into ``NT = S / T``
tiles of ``T`` consecutive records, and three tiny summary arrays ride
next to the timeline:

``idx_occ : uint32[NT, W]``
    bitwise OR of the tile's occupancy rows — the union of every busy
    unit over the tile's span.
``idx_minfree : int32[NT, R]``
    ``units[r] - popcount_r(idx_occ[k])``: an *upper bound* on the free
    units any window fully containing tile ``k`` can see (the window's
    busy union is a superset of the tile OR), per resource plane.
``idx_maxfree : int32[NT, R]``
    max over the tile's rows of the row's free units: an upper bound
    on the free units of any window that covers *at least one* row of
    tile ``k`` (a window's free count never exceeds any covering
    row's).

Both bounds are *conservative by construction*: they only ever prove
infeasibility that the exact search would also find, so consumers
(candidate pruning, early-reject admission, fleet probe prefiltering —
see :mod:`repro.core.search`) keep decisions bit-identical.

Padding rows (``times == T_INF``, ``occ == 0``) contribute nothing to
``idx_occ`` and a full-free row to ``idx_maxfree`` — exactly the
semantics of the all-free region they stand for, so partially-padded
tail tiles need no special casing.

:class:`IndexSpec` is static configuration and registers as a zero-leaf
pytree node (the :class:`~repro.core.resources.ResourceSpec` idiom), so
an indexed timeline adds exactly three array leaves and ``ispec=None``
timelines keep their legacy leaf set.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_WORD = 32


def _n_words(units: int) -> int:
    return (units + _WORD - 1) // _WORD


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Static layout of the hierarchical availability index.

    ``tile`` records per summary tile (a power of two, so every grown
    power-of-two capacity stays divisible), plus the per-plane unit
    counts and packed word widths needed to popcount summaries without
    reaching back to a :class:`~repro.core.resources.ResourceSpec`
    (scalar timelines have none).  Frozen and hashable: equal specs are
    interchangeable static jit arguments.
    """

    tile: int
    units: Tuple[int, ...]
    words_per: Tuple[int, ...]

    def __post_init__(self) -> None:
        tile = int(self.tile)
        if tile < 1 or (tile & (tile - 1)) != 0:
            raise ValueError(
                f"index tile must be a positive power of two: {tile}")
        units = tuple(int(u) for u in self.units)
        words = tuple(int(w) for w in self.words_per)
        if not units or len(units) != len(words):
            raise ValueError(
                f"units/words_per mismatch: {units} vs {words}")
        object.__setattr__(self, "tile", tile)
        object.__setattr__(self, "units", units)
        object.__setattr__(self, "words_per", words)

    @property
    def R(self) -> int:
        return len(self.units)

    @property
    def total_words(self) -> int:
        return sum(self.words_per)

    @property
    def word_offsets(self) -> Tuple[int, ...]:
        offs, acc = [], 0
        for w in self.words_per:
            offs.append(acc)
            acc += w
        return tuple(offs)

    def plane_slice(self, r: int) -> slice:
        off = self.word_offsets[r]
        return slice(off, off + self.words_per[r])

    def n_tiles(self, capacity: int) -> int:
        if capacity % self.tile != 0:
            raise ValueError(
                f"capacity {capacity} not divisible by tile {self.tile}")
        return capacity // self.tile


def make_index_spec(tile: int, n_pe: int, rspec=None) -> IndexSpec:
    """Build the spec for a scalar (``rspec=None``) or vector layout."""
    if rspec is None:
        return IndexSpec(tile=tile, units=(int(n_pe),),
                         words_per=(_n_words(int(n_pe)),))
    return IndexSpec(tile=tile, units=tuple(rspec.units),
                     words_per=tuple(rspec.words_per))


def plane_counts(words: jax.Array, ispec: IndexSpec) -> jax.Array:
    """Per-plane popcount of packed rows: ``[..., W] -> int32[..., R]``."""
    c = jax.lax.population_count(words)
    return jnp.stack(
        [jnp.sum(c[..., ispec.plane_slice(r)], axis=-1)
         for r in range(ispec.R)], axis=-1).astype(jnp.int32)


def build_summaries(times: jax.Array, occ: jax.Array, ispec: IndexSpec):
    """Canonical summaries: ``(idx_occ, idx_minfree, idx_maxfree)``.

    The maintenance in :mod:`repro.core.timeline` applies exactly this
    to the post-update rows (a handful of fused popcount/reduce ops at
    practical tile counts), asserted by the property suite in
    ``tests/test_availindex.py``.
    """
    S, W = occ.shape
    T = ispec.tile
    NT = ispec.n_tiles(S)
    units = jnp.asarray(ispec.units, jnp.int32)
    occ3 = occ.reshape(NT, T, W)
    idx_occ = jax.lax.reduce(
        occ3, np.uint32(0), jax.lax.bitwise_or, (1,))       # [NT, W]
    idx_minfree = units[None, :] - plane_counts(idx_occ, ispec)
    row_free = units[None, :] - plane_counts(occ, ispec)    # [S, R]
    idx_maxfree = jnp.max(row_free.reshape(NT, T, ispec.R), axis=1)
    return idx_occ, idx_minfree, idx_maxfree


def empty_summaries(capacity: int, ispec: IndexSpec):
    """Summaries of an all-free timeline (every row is padding)."""
    NT = ispec.n_tiles(capacity)
    units = jnp.asarray(ispec.units, jnp.int32)
    return (jnp.zeros((NT, ispec.total_words), jnp.uint32),
            jnp.broadcast_to(units[None, :], (NT, ispec.R)),
            jnp.broadcast_to(units[None, :], (NT, ispec.R)))


def plane_deficit(ispec: IndexSpec,
                  valid_mask: Optional[jax.Array]) -> jax.Array:
    """int32[R]: nominal units minus this lane's schedulable units.

    Summaries store *nominal* free counts (``units[r]`` minus busy
    bits); the search-side free counts are relative to the lane's
    ``valid_mask``.  Occupancy is always a subset of the valid mask
    (timeline invariant), so the two differ by exactly this constant
    per plane, and summary bounds adjust by subtracting it.
    """
    units = jnp.asarray(ispec.units, jnp.int32)
    if valid_mask is None:
        return jnp.zeros_like(units)
    return units - plane_counts(valid_mask, ispec)


# Zero-leaf pytree registration (the ResourceSpec idiom): the spec is
# its own aux data, so it lives in the treedef — static under jit,
# invisible to tree_map / donation / sharding.
jax.tree_util.register_pytree_node(
    IndexSpec,
    lambda s: ((), s),
    lambda aux, _: aux,
)
