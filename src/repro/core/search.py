"""Device-side ``findAllocation`` (Algorithm 3), fully vectorised.

The paper's per-candidate scan — "for every optional start time, get the
free PEs in the window, then expand to the maximum availability
rectangle" — is reformulated as two dense matrix products over the
bit-expanded occupancy (DESIGN.md §2):

    busy[P, pe]     = (overlap[P, S] @ occ_bits[S, pe]) > 0
    blocking[P, S]  = (free[P, pe]   @ occ_bits[S, pe]^T) > 0

so the whole search maps onto the MXU.  The rectangle bounds are then
masked min/max reductions over the slot axis.  ``kernels/availscan``
implements the same contraction as a Pallas kernel; this module is the
pure-jnp path (and the oracle the kernel is tested against).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import availindex as idx_lib
from repro.core import policies as policies_lib
from repro.core import timeline as tl_lib
from repro.core.timeline import Timeline
from repro.core.types import T_INF


class SearchResult(NamedTuple):
    found: jax.Array      # bool
    t_s: jax.Array        # int32 chosen start
    t_e: jax.Array        # int32 chosen end
    pe_mask: jax.Array    # uint32[W] chosen PEs
    n_free: jax.Array     # int32 free PEs in the winning rectangle
    t_begin: jax.Array    # int32 rectangle begin
    t_end: jax.Array      # int32 rectangle end


class Rectangles(NamedTuple):
    """Per-candidate maximum availability rectangles.

    ``n_free`` counts plane-0 (PE) units; under a multi-resource
    layout ``n_free_tail`` carries the free-unit counts of planes
    1..R-1 (``None`` on the scalar path — the field defaults keep the
    legacy pytree structure unchanged).
    """

    starts: jax.Array    # int32[P]
    n_free: jax.Array    # int32[P]
    t_begin: jax.Array   # int32[P]
    t_end: jax.Array     # int32[P]
    valid: jax.Array     # bool[P]
    n_free_tail: Optional[jax.Array] = None  # int32[P, R-1]


def candidate_starts(tl: Timeline, t_r: jax.Array, t_du: jax.Array,
                     t_dl: jax.Array) -> jax.Array:
    """int32[2S+2] candidates; infeasible slots padded with T_INF.

    Candidates are the ready time, the latest start, every boundary in
    range, and every boundary shifted left by the duration (end-aligned
    placements) — the paper's Section 4.2 enumeration.

    The sorted array is *deduplicated and compacted* (DESIGN.md §7):
    distinct live candidates ascending at the front, all duplicates
    and out-of-window slots collapsed into the ``T_INF`` tail.
    Duplicates share their first occurrence's start value, hence its
    rectangle and policy score, so dropping them never changes the
    selected start; compaction makes the effective candidate count
    track *live* boundaries instead of static capacity, which is what
    lets the availscan kernel skip all-padding tiles.
    """
    lo = t_r
    hi = t_dl - t_du

    def in_range(x):
        return (x >= lo) & (x <= hi) & (x < T_INF)

    c_bound = jnp.where(in_range(tl.times), tl.times, T_INF)
    shifted = jnp.where(tl.times < T_INF, tl.times - t_du, T_INF)
    c_shift = jnp.where(in_range(shifted), shifted, T_INF)
    ends = jnp.stack([lo, hi]).astype(jnp.int32)
    cand = jnp.sort(jnp.concatenate([ends, c_bound, c_shift]))
    # dedupe + compact: keep the first occurrence of each distinct
    # live value, scatter the survivors to the front in order.
    P = cand.shape[0]
    keep = (cand < T_INF) & jnp.concatenate(
        [jnp.ones((1,), bool), cand[1:] != cand[:-1]])
    dest = jnp.where(keep, jnp.cumsum(keep) - 1, P)
    return jnp.full((P + 1,), T_INF, jnp.int32).at[dest].set(
        jnp.where(keep, cand, T_INF))[:P]


def _index_demand(ispec, n_req: jax.Array,
                  demand_tail: Optional[jax.Array]) -> jax.Array:
    """int32[R] full per-plane demand vector for index bounds."""
    head = jnp.asarray(n_req, jnp.int32)[None]
    if ispec.R == 1 or demand_tail is None:
        return jnp.concatenate(
            [head, jnp.zeros((ispec.R - 1,), jnp.int32)])
    return jnp.concatenate(
        [head, jnp.asarray(demand_tail, jnp.int32)])


def summary_reject(tl: Timeline, t_r: jax.Array, t_du: jax.Array,
                   t_dl: jax.Array, demand: jax.Array,
                   deficit: jax.Array) -> jax.Array:
    """Conservative whole-request infeasibility proof (DESIGN.md §12).

    True only when *no* window ``[s, s + t_du)`` with ``s`` in
    ``[t_r, t_dl - t_du]`` can be feasible, so the caller may skip the
    full search and emit the exact rejected result.  Two proofs:

    1. capacity: some plane demands more units than the lane has;
    2. tile max-free: with ``t_r >= times[0]``, every window start
       lands inside some record's interval, that record's tile
       intersects the span ``[t_r, t_dl)``, and a window's free count
       never exceeds a covering row's — so if *every* tile
       intersecting the span proves ``maxfree - deficit < demand`` on
       some plane, every window is infeasible.

    An empty timeline (``times[0] == T_INF``) or a window reaching
    past the last record (whose all-free row summarises to
    ``maxfree == units``) never rejects — conservativeness needs no
    special cases.
    """
    ispec = tl.ispec
    S, T = tl.capacity, ispec.tile
    NT = S // T
    units = jnp.asarray(ispec.units, jnp.int32)
    lo = jnp.asarray(t_r, jnp.int32)
    hi = jnp.asarray(t_dl, jnp.int32) - jnp.asarray(t_du, jnp.int32)
    cap_reject = jnp.any(demand > units - deficit)
    tile_t0 = tl.times.reshape(NT, T)[:, 0]
    tile_end = jnp.concatenate(
        [tile_t0[1:], jnp.array([T_INF], jnp.int32)])
    intersect = (tile_t0 < jnp.asarray(t_dl, jnp.int32)) \
        & (tile_end > lo)
    bad = jnp.any(tl.idx_maxfree - deficit[None, :]
                  < demand[None, :], axis=1)              # [NT]
    guard = (hi >= lo) & (tl.times[0] <= lo)
    tile_reject = (guard & jnp.any(intersect)
                   & jnp.all(~intersect | bad))
    return cap_reject | tile_reject


def prune_candidates(tl: Timeline, starts: jax.Array, t_du: jax.Array,
                     demand: jax.Array,
                     deficit: jax.Array) -> jax.Array:
    """Mask summary-infeasible candidates to the ``T_INF`` sentinel.

    A candidate window fully containing tile ``k`` unions at least
    ``idx_occ[k]`` into its busy mask, so its free count is bounded by
    ``idx_minfree[k] - deficit`` per plane; any contained tile proving
    ``< demand`` makes the candidate truly infeasible.  Conservative:
    pruned candidates could never win selection, so decisions are
    bit-identical — and candidate 0 (the all-infeasible fallback the
    rejected-decision fields report) is never pruned.
    """
    ispec = tl.ispec
    S, T = tl.capacity, ispec.tile
    NT = S // T
    a = jnp.minimum(starts, T_INF - t_du)
    b = a + t_du
    tile_last = tl.times.reshape(NT, T)[:, -1]
    tile_nxt0 = tl_lib.next_times(tl).reshape(NT, T)[:, 0]
    contained = (tile_last[None, :] < b[:, None]) \
        & (tile_nxt0[None, :] > a[:, None])               # [P, NT]
    bad = jnp.any(tl.idx_minfree - deficit[None, :]
                  < demand[None, :], axis=1)              # [NT]
    prune = jnp.any(contained & bad[None, :], axis=1)
    keep0 = jnp.arange(starts.shape[0]) > 0
    return jnp.where(prune & keep0, T_INF, starts)


def availability_rectangles(
    tl: Timeline, starts: jax.Array, t_du: jax.Array, t_now: jax.Array,
    n_pe: int, *, rspec=None, valid_mask: Optional[jax.Array] = None,
) -> Rectangles:
    """Maximum availability rectangle per candidate (Algorithm 3 l.6-9).

    The pure-jnp reference path computes both contractions directly on
    the *packed* uint32 occupancy words (bitwise OR / AND + popcount)
    instead of bit-expanding to a ``[S, n_pe]`` float matrix: the
    booleans are identical to the MXU formulation of DESIGN.md §2
    (which the Pallas kernel keeps), but each uint32 op covers 32 PEs,
    so the hot contraction shrinks ~32x on CPU/VPU hardware.

    Invalid candidates (``T_INF`` padding) are masked to fixed
    sentinels (``n_free = t_begin = t_end = 0``) so the kernel path
    can skip all-padding tiles and still match this reference
    element-for-element; sentinels can never win selection (invalid
    candidates are never feasible) and the all-infeasible fallback
    index 0 is always a live candidate.

    Multi-resource layouts (DESIGN.md §11) pass ``rspec``: the free
    union is masked with the lane's ``valid_mask`` (defaulting to the
    spec's full padded layout) and popcounted *per bitplane*, yielding
    the plane-0 ``n_free`` the policies score plus ``n_free_tail`` for
    the vector fit test.  With ``R == 1`` and a full valid mask the
    counts — and the blocking booleans, since occupancy bits only ever
    appear on valid units — are identical to the scalar path.
    """
    nxt = tl_lib.next_times(tl)
    valid = starts < T_INF
    a = jnp.minimum(starts, T_INF - t_du)       # avoid int32 overflow
    b = a + t_du
    # window overlap and busy-unit union (bitwise OR over packed words)
    ov = ((tl.times[None, :] < b[:, None]) &
          (nxt[None, :] > a[:, None]))                          # [P, S]
    busy_w = jax.lax.reduce(
        jnp.where(ov[:, :, None], tl.occ[None, :, :], jnp.uint32(0)),
        np.uint32(0), jax.lax.bitwise_or, (1,))                 # [P, W]
    n_free_tail = None
    if rspec is None:
        # occupancy words never set bits past n_pe (timeline
        # invariant), so the popcount of the busy union counts real
        # PEs only
        n_free = (n_pe - jnp.sum(
            jax.lax.population_count(busy_w), axis=1).astype(jnp.int32))
        free_w = ~busy_w                                        # [P, W]
    else:
        if valid_mask is None:
            valid_mask = jnp.asarray(rspec.valid_mask_np())
        free_w = ~busy_w & valid_mask[None, :]                  # [P, W]
        counts = jax.lax.population_count(free_w)
        plane_free = [
            jnp.sum(counts[:, rspec.plane_slice(r)],
                    axis=1).astype(jnp.int32)
            for r in range(rspec.R)]
        n_free = plane_free[0]
        if rspec.R > 1:
            n_free_tail = jnp.stack(plane_free[1:], axis=1)
        else:
            n_free_tail = jnp.zeros((starts.shape[0], 0), jnp.int32)
    # blocking slots: a slot blocks iff it occupies any free unit
    # (bitwise AND against the free-word union; junk free bits past
    # n_pe never match because occupancy words are clean there)
    blocking = jnp.any(
        (free_w[:, None, :] & tl.occ[None, :, :]) != 0, axis=2)  # [P, S]
    left = blocking & (nxt[None, :] <= a[:, None])
    t_begin = jnp.max(jnp.where(left, nxt[None, :], -T_INF), axis=1)
    t_begin = jnp.minimum(jnp.maximum(t_begin, t_now), a)
    right = blocking & (tl.times[None, :] >= b[:, None])
    t_end = jnp.min(jnp.where(right, tl.times[None, :], T_INF), axis=1)
    zero = jnp.int32(0)
    return Rectangles(
        starts=starts,
        n_free=jnp.where(valid, n_free, zero),
        t_begin=jnp.where(valid, t_begin, zero),
        t_end=jnp.where(valid, t_end, zero),
        valid=valid,
        n_free_tail=(None if n_free_tail is None
                     else jnp.where(valid[:, None], n_free_tail, zero)))


def _winning_pe_mask(tl: Timeline, t_s: jax.Array, t_du: jax.Array,
                     n_req: jax.Array, n_pe: int) -> jax.Array:
    """Lowest-index ``n_req`` free PEs over the winning window."""
    a = jnp.minimum(t_s, T_INF - t_du)
    busy = tl_lib.window_busy(tl, a, a + t_du)          # uint32[W]
    free_bits = (1 - tl_lib.unpack_bits(busy[None, :], n_pe)[0]
                 ).astype(jnp.int32)                    # [n_pe]
    csum = jnp.cumsum(free_bits)
    sel = (free_bits == 1) & (csum <= n_req)
    W = tl.words
    sel_padded = jnp.zeros((W * 32,), jnp.uint32).at[:n_pe].set(
        sel.astype(jnp.uint32))
    return tl_lib.pack_bits(sel_padded[None, :])[0]


def _winning_mask_mr(tl: Timeline, t_s: jax.Array, t_du: jax.Array,
                     n_req: jax.Array, demand_tail: jax.Array,
                     rspec, valid_mask: jax.Array) -> jax.Array:
    """Lowest-index free *valid* units per plane over the window.

    The plane-0 pick matches :func:`_winning_pe_mask` bit-for-bit on
    a full-width lane (invalid bits are never free, so the cumsum
    walks the same unit order); secondary planes allocate their
    ``demand_tail[r-1]`` units the same way in their own bit range.
    """
    a = jnp.minimum(t_s, T_INF - t_du)
    busy = tl_lib.window_busy(tl, a, a + t_du)      # uint32[W]
    free_w = ~busy & valid_mask
    out = []
    for r in range(rspec.R):
        wr = rspec.words_per[r]
        fb = tl_lib.unpack_bits(
            free_w[None, rspec.plane_slice(r)],
            wr * 32)[0].astype(jnp.int32)           # [wr*32]
        need = n_req if r == 0 else demand_tail[r - 1]
        sel = (fb == 1) & (jnp.cumsum(fb) <= need)
        out.append(tl_lib.pack_bits(
            sel.astype(jnp.uint32)[None, :])[0])
    return jnp.concatenate(out)


def search(
    tl: Timeline,
    t_r: jax.Array,
    t_du: jax.Array,
    t_dl: jax.Array,
    n_req: jax.Array,
    policy_id: jax.Array,
    t_now: jax.Array,
    *,
    n_pe: int,
    use_kernel: bool = False,
    rspec=None,
    demand_tail: Optional[jax.Array] = None,
    valid_mask: Optional[jax.Array] = None,
) -> SearchResult:
    """Full Algorithm 3: candidates -> rectangles -> policy -> PE pick.

    Trace-time body, deliberately not jitted: :func:`find_allocation`
    wraps it for standalone use, :mod:`repro.core.batch` inlines it
    into the fused ``admit`` step so find+commit compile as one
    program, and :mod:`repro.core.ensemble` vmaps it over stacked
    timelines (all inputs tolerate a leading ensemble axis — the
    kernel path included).

    ``rspec`` switches to the multi-resource vector fit (DESIGN.md
    §11): a candidate is feasible iff plane 0 fits ``n_req`` *and*
    every secondary plane fits its ``demand_tail`` entry, policies
    keep scoring the plane-0 ``n_free``, and the winning mask spans
    all planes.  ``valid_mask`` (default: the spec's full layout)
    carries per-lane machine sizes.

    An indexed timeline (``tl.ispec`` set, DESIGN.md §12) adds two
    conservative fast paths: a whole-search early-reject ``lax.cond``
    that proves no feasible window exists and emits the exact
    rejected result without enumerating candidates (the dominant win
    on saturated streams — and, vmapped, the fleet probe's lane
    prefilter), and — on the kernel path only — summary pruning that
    masks provably-infeasible candidates to the ``T_INF`` sentinel so
    the availscan kernels' data-driven tile skip drops their tiles
    (the jnp reference path evaluates every candidate slot at fixed
    shape, so pruning there saves nothing).  Both are conservative
    (summary-infeasible implies truly infeasible), so every result
    stays bit-identical to the index-free search.
    """
    if rspec is not None:
        if valid_mask is None:
            valid_mask = jnp.asarray(rspec.valid_mask_np())
        if demand_tail is None:
            demand_tail = jnp.zeros((rspec.R - 1,), jnp.int32)
        demand_tail = jnp.asarray(demand_tail, jnp.int32)
    if tl.ispec is not None:
        demand_vec = _index_demand(tl.ispec, n_req, demand_tail)
        deficit = idx_lib.plane_deficit(tl.ispec, valid_mask)
        reject = summary_reject(tl, t_r, t_du, t_dl, demand_vec,
                                deficit)

        def _rejected(_):
            # bit-exact cheap branch: selection over an all-infeasible
            # candidate set falls back to index 0, whose start is the
            # minimum live candidate — min(t_r, t_dl - t_du) — and the
            # rejected Decision reports that candidate's rectangle
            starts0 = jnp.minimum(
                jnp.asarray(t_r, jnp.int32),
                jnp.asarray(t_dl, jnp.int32)
                - jnp.asarray(t_du, jnp.int32))[None]
            rects = availability_rectangles(
                tl, starts0, t_du, t_now, n_pe, rspec=rspec,
                valid_mask=valid_mask)
            return SearchResult(
                found=jnp.asarray(False),
                t_s=starts0[0],
                t_e=starts0[0] + jnp.asarray(t_du, jnp.int32),
                pe_mask=jnp.zeros((tl.words,), jnp.uint32),
                n_free=rects.n_free[0],
                t_begin=rects.t_begin[0],
                t_end=rects.t_end[0],
            )

        def _full(_):
            return _search_full(
                tl, t_r, t_du, t_dl, n_req, policy_id, t_now,
                n_pe=n_pe, use_kernel=use_kernel, rspec=rspec,
                demand_tail=demand_tail, valid_mask=valid_mask,
                demand_vec=demand_vec, deficit=deficit)

        return jax.lax.cond(reject, _rejected, _full, 0)
    return _search_full(
        tl, t_r, t_du, t_dl, n_req, policy_id, t_now, n_pe=n_pe,
        use_kernel=use_kernel, rspec=rspec, demand_tail=demand_tail,
        valid_mask=valid_mask, demand_vec=None, deficit=None)


def _search_full(
    tl: Timeline,
    t_r: jax.Array,
    t_du: jax.Array,
    t_dl: jax.Array,
    n_req: jax.Array,
    policy_id: jax.Array,
    t_now: jax.Array,
    *,
    n_pe: int,
    use_kernel: bool,
    rspec,
    demand_tail: Optional[jax.Array],
    valid_mask: Optional[jax.Array],
    demand_vec: Optional[jax.Array],
    deficit: Optional[jax.Array],
) -> SearchResult:
    """The candidate enumeration half of :func:`search` (see there)."""
    starts = candidate_starts(tl, t_r, t_du, t_dl)
    if tl.ispec is not None and use_kernel:
        # summary pruning feeds the availscan kernels' data-driven
        # tile skip: a pruned start becomes T_INF padding, so its
        # tile never loads.  The jnp reference path evaluates every
        # candidate slot at fixed shape regardless, so pruning there
        # is pure per-request cost — the mask changes nothing the
        # where-select downstream wouldn't (pruned candidates are
        # truly infeasible and could never win selection either way).
        starts = prune_candidates(tl, starts, t_du, demand_vec,
                                  deficit)
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        # fused path: rectangles + policy selection in one kernel —
        # the per-candidate vectors never round-trip through HBM
        sel = kernel_ops.search_select(
            tl, starts, t_du, t_now, n_req, policy_id, n_pe=n_pe,
            rspec=rspec, demand_tail=demand_tail,
            valid_mask=valid_mask)
        if sel is not None:
            found = sel["found"]
            t_s = starts[sel["best"]]
            if rspec is None:
                pe_mask = _winning_pe_mask(tl, t_s, t_du, n_req, n_pe)
            else:
                pe_mask = _winning_mask_mr(
                    tl, t_s, t_du, n_req, demand_tail, rspec,
                    valid_mask)
            return SearchResult(
                found=found,
                t_s=t_s,
                t_e=t_s + t_du,
                pe_mask=jnp.where(found, pe_mask, jnp.uint32(0)),
                n_free=sel["n_free"],
                t_begin=sel["t_begin"],
                t_end=sel["t_end"],
            )
    # jnp reference path — also the fallback when search_select
    # returned None (shape beyond the kernel VMEM budget; the unfused
    # kernel entry exists for the element-wise oracle tests)
    rects = availability_rectangles(tl, starts, t_du, t_now, n_pe,
                                    rspec=rspec, valid_mask=valid_mask)
    feasible = rects.valid & (rects.n_free >= n_req)
    if rspec is not None and rspec.R > 1:
        feasible = feasible & jnp.all(
            rects.n_free_tail >= demand_tail[None, :], axis=1)
    duration = rects.t_end - rects.t_begin
    best, found = policies_lib.select(
        policy_id, rects.n_free, duration, rects.starts, feasible)
    t_s = rects.starts[best]
    if rspec is None:
        pe_mask = _winning_pe_mask(tl, t_s, t_du, n_req, n_pe)
    else:
        pe_mask = _winning_mask_mr(
            tl, t_s, t_du, n_req, demand_tail, rspec, valid_mask)
    return SearchResult(
        found=found,
        t_s=t_s,
        t_e=t_s + t_du,
        pe_mask=jnp.where(found, pe_mask, jnp.uint32(0)),
        n_free=rects.n_free[best],
        t_begin=rects.t_begin[best],
        t_end=rects.t_end[best],
    )


find_allocation = functools.partial(
    jax.jit, static_argnames=("n_pe", "use_kernel", "rspec"))(search)


def replacement_search(
    tl: Timeline,
    t_r: jax.Array,
    t_du: jax.Array,
    t_dl: jax.Array,
    n_req: jax.Array,
    policy_id: jax.Array,
    t_now: jax.Array,
    *,
    n_pe: int,
    use_kernel: bool = False,
    rspec=None,
    demand_tail: Optional[jax.Array] = None,
    valid_mask: Optional[jax.Array] = None,
) -> SearchResult:
    """The backfill feasibility check: re-place a parked reservation.

    Identical to :func:`search` except the window is clamped to what is
    still reachable — candidates start at ``max(t_r, t_now)`` — so a
    deferral-queue entry can only be re-placed at a start it could
    really make.  Because a live parked reservation always satisfies
    ``t_now < t_s <= t_dl - t_du``, the clamped window is never empty.
    Used by the retry-on-release sweep (earliest-start re-placement)
    and the EASY displacement transaction (:mod:`repro.core.batch`).
    """
    return search(tl, jnp.maximum(t_r, t_now), t_du, t_dl, n_req,
                  policy_id, t_now, n_pe=n_pe, use_kernel=use_kernel,
                  rspec=rspec, demand_tail=demand_tail,
                  valid_mask=valid_mask)
