"""Device-side ``findAllocation`` (Algorithm 3), fully vectorised.

The paper's per-candidate scan — "for every optional start time, get the
free PEs in the window, then expand to the maximum availability
rectangle" — is reformulated as two dense matrix products over the
bit-expanded occupancy (DESIGN.md §2):

    busy[P, pe]     = (overlap[P, S] @ occ_bits[S, pe]) > 0
    blocking[P, S]  = (free[P, pe]   @ occ_bits[S, pe]^T) > 0

so the whole search maps onto the MXU.  The rectangle bounds are then
masked min/max reductions over the slot axis.  ``kernels/availscan``
implements the same contraction as a Pallas kernel; this module is the
pure-jnp path (and the oracle the kernel is tested against).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policies as policies_lib
from repro.core import timeline as tl_lib
from repro.core.timeline import Timeline
from repro.core.types import T_INF


class SearchResult(NamedTuple):
    found: jax.Array      # bool
    t_s: jax.Array        # int32 chosen start
    t_e: jax.Array        # int32 chosen end
    pe_mask: jax.Array    # uint32[W] chosen PEs
    n_free: jax.Array     # int32 free PEs in the winning rectangle
    t_begin: jax.Array    # int32 rectangle begin
    t_end: jax.Array      # int32 rectangle end


class Rectangles(NamedTuple):
    """Per-candidate maximum availability rectangles.

    ``n_free`` counts plane-0 (PE) units; under a multi-resource
    layout ``n_free_tail`` carries the free-unit counts of planes
    1..R-1 (``None`` on the scalar path — the field defaults keep the
    legacy pytree structure unchanged).
    """

    starts: jax.Array    # int32[P]
    n_free: jax.Array    # int32[P]
    t_begin: jax.Array   # int32[P]
    t_end: jax.Array     # int32[P]
    valid: jax.Array     # bool[P]
    n_free_tail: Optional[jax.Array] = None  # int32[P, R-1]


def candidate_starts(tl: Timeline, t_r: jax.Array, t_du: jax.Array,
                     t_dl: jax.Array) -> jax.Array:
    """int32[2S+2] candidates; infeasible slots padded with T_INF.

    Candidates are the ready time, the latest start, every boundary in
    range, and every boundary shifted left by the duration (end-aligned
    placements) — the paper's Section 4.2 enumeration.

    The sorted array is *deduplicated and compacted* (DESIGN.md §7):
    distinct live candidates ascending at the front, all duplicates
    and out-of-window slots collapsed into the ``T_INF`` tail.
    Duplicates share their first occurrence's start value, hence its
    rectangle and policy score, so dropping them never changes the
    selected start; compaction makes the effective candidate count
    track *live* boundaries instead of static capacity, which is what
    lets the availscan kernel skip all-padding tiles.
    """
    lo = t_r
    hi = t_dl - t_du

    def in_range(x):
        return (x >= lo) & (x <= hi) & (x < T_INF)

    c_bound = jnp.where(in_range(tl.times), tl.times, T_INF)
    shifted = jnp.where(tl.times < T_INF, tl.times - t_du, T_INF)
    c_shift = jnp.where(in_range(shifted), shifted, T_INF)
    ends = jnp.stack([lo, hi]).astype(jnp.int32)
    cand = jnp.sort(jnp.concatenate([ends, c_bound, c_shift]))
    # dedupe + compact: keep the first occurrence of each distinct
    # live value, scatter the survivors to the front in order.
    P = cand.shape[0]
    keep = (cand < T_INF) & jnp.concatenate(
        [jnp.ones((1,), bool), cand[1:] != cand[:-1]])
    dest = jnp.where(keep, jnp.cumsum(keep) - 1, P)
    return jnp.full((P + 1,), T_INF, jnp.int32).at[dest].set(
        jnp.where(keep, cand, T_INF))[:P]


def availability_rectangles(
    tl: Timeline, starts: jax.Array, t_du: jax.Array, t_now: jax.Array,
    n_pe: int, *, rspec=None, valid_mask: Optional[jax.Array] = None,
) -> Rectangles:
    """Maximum availability rectangle per candidate (Algorithm 3 l.6-9).

    The pure-jnp reference path computes both contractions directly on
    the *packed* uint32 occupancy words (bitwise OR / AND + popcount)
    instead of bit-expanding to a ``[S, n_pe]`` float matrix: the
    booleans are identical to the MXU formulation of DESIGN.md §2
    (which the Pallas kernel keeps), but each uint32 op covers 32 PEs,
    so the hot contraction shrinks ~32x on CPU/VPU hardware.

    Invalid candidates (``T_INF`` padding) are masked to fixed
    sentinels (``n_free = t_begin = t_end = 0``) so the kernel path
    can skip all-padding tiles and still match this reference
    element-for-element; sentinels can never win selection (invalid
    candidates are never feasible) and the all-infeasible fallback
    index 0 is always a live candidate.

    Multi-resource layouts (DESIGN.md §11) pass ``rspec``: the free
    union is masked with the lane's ``valid_mask`` (defaulting to the
    spec's full padded layout) and popcounted *per bitplane*, yielding
    the plane-0 ``n_free`` the policies score plus ``n_free_tail`` for
    the vector fit test.  With ``R == 1`` and a full valid mask the
    counts — and the blocking booleans, since occupancy bits only ever
    appear on valid units — are identical to the scalar path.
    """
    nxt = tl_lib.next_times(tl)
    valid = starts < T_INF
    a = jnp.minimum(starts, T_INF - t_du)       # avoid int32 overflow
    b = a + t_du
    # window overlap and busy-unit union (bitwise OR over packed words)
    ov = ((tl.times[None, :] < b[:, None]) &
          (nxt[None, :] > a[:, None]))                          # [P, S]
    busy_w = jax.lax.reduce(
        jnp.where(ov[:, :, None], tl.occ[None, :, :], jnp.uint32(0)),
        np.uint32(0), jax.lax.bitwise_or, (1,))                 # [P, W]
    n_free_tail = None
    if rspec is None:
        # occupancy words never set bits past n_pe (timeline
        # invariant), so the popcount of the busy union counts real
        # PEs only
        n_free = (n_pe - jnp.sum(
            jax.lax.population_count(busy_w), axis=1).astype(jnp.int32))
        free_w = ~busy_w                                        # [P, W]
    else:
        if valid_mask is None:
            valid_mask = jnp.asarray(rspec.valid_mask_np())
        free_w = ~busy_w & valid_mask[None, :]                  # [P, W]
        counts = jax.lax.population_count(free_w)
        plane_free = [
            jnp.sum(counts[:, rspec.plane_slice(r)],
                    axis=1).astype(jnp.int32)
            for r in range(rspec.R)]
        n_free = plane_free[0]
        if rspec.R > 1:
            n_free_tail = jnp.stack(plane_free[1:], axis=1)
        else:
            n_free_tail = jnp.zeros((starts.shape[0], 0), jnp.int32)
    # blocking slots: a slot blocks iff it occupies any free unit
    # (bitwise AND against the free-word union; junk free bits past
    # n_pe never match because occupancy words are clean there)
    blocking = jnp.any(
        (free_w[:, None, :] & tl.occ[None, :, :]) != 0, axis=2)  # [P, S]
    left = blocking & (nxt[None, :] <= a[:, None])
    t_begin = jnp.max(jnp.where(left, nxt[None, :], -T_INF), axis=1)
    t_begin = jnp.minimum(jnp.maximum(t_begin, t_now), a)
    right = blocking & (tl.times[None, :] >= b[:, None])
    t_end = jnp.min(jnp.where(right, tl.times[None, :], T_INF), axis=1)
    zero = jnp.int32(0)
    return Rectangles(
        starts=starts,
        n_free=jnp.where(valid, n_free, zero),
        t_begin=jnp.where(valid, t_begin, zero),
        t_end=jnp.where(valid, t_end, zero),
        valid=valid,
        n_free_tail=(None if n_free_tail is None
                     else jnp.where(valid[:, None], n_free_tail, zero)))


def _winning_pe_mask(tl: Timeline, t_s: jax.Array, t_du: jax.Array,
                     n_req: jax.Array, n_pe: int) -> jax.Array:
    """Lowest-index ``n_req`` free PEs over the winning window."""
    a = jnp.minimum(t_s, T_INF - t_du)
    busy = tl_lib.window_busy(tl, a, a + t_du)          # uint32[W]
    free_bits = (1 - tl_lib.unpack_bits(busy[None, :], n_pe)[0]
                 ).astype(jnp.int32)                    # [n_pe]
    csum = jnp.cumsum(free_bits)
    sel = (free_bits == 1) & (csum <= n_req)
    W = tl.words
    sel_padded = jnp.zeros((W * 32,), jnp.uint32).at[:n_pe].set(
        sel.astype(jnp.uint32))
    return tl_lib.pack_bits(sel_padded[None, :])[0]


def _winning_mask_mr(tl: Timeline, t_s: jax.Array, t_du: jax.Array,
                     n_req: jax.Array, demand_tail: jax.Array,
                     rspec, valid_mask: jax.Array) -> jax.Array:
    """Lowest-index free *valid* units per plane over the window.

    The plane-0 pick matches :func:`_winning_pe_mask` bit-for-bit on
    a full-width lane (invalid bits are never free, so the cumsum
    walks the same unit order); secondary planes allocate their
    ``demand_tail[r-1]`` units the same way in their own bit range.
    """
    a = jnp.minimum(t_s, T_INF - t_du)
    busy = tl_lib.window_busy(tl, a, a + t_du)      # uint32[W]
    free_w = ~busy & valid_mask
    out = []
    for r in range(rspec.R):
        wr = rspec.words_per[r]
        fb = tl_lib.unpack_bits(
            free_w[None, rspec.plane_slice(r)],
            wr * 32)[0].astype(jnp.int32)           # [wr*32]
        need = n_req if r == 0 else demand_tail[r - 1]
        sel = (fb == 1) & (jnp.cumsum(fb) <= need)
        out.append(tl_lib.pack_bits(
            sel.astype(jnp.uint32)[None, :])[0])
    return jnp.concatenate(out)


def search(
    tl: Timeline,
    t_r: jax.Array,
    t_du: jax.Array,
    t_dl: jax.Array,
    n_req: jax.Array,
    policy_id: jax.Array,
    t_now: jax.Array,
    *,
    n_pe: int,
    use_kernel: bool = False,
    rspec=None,
    demand_tail: Optional[jax.Array] = None,
    valid_mask: Optional[jax.Array] = None,
) -> SearchResult:
    """Full Algorithm 3: candidates -> rectangles -> policy -> PE pick.

    Trace-time body, deliberately not jitted: :func:`find_allocation`
    wraps it for standalone use, :mod:`repro.core.batch` inlines it
    into the fused ``admit`` step so find+commit compile as one
    program, and :mod:`repro.core.ensemble` vmaps it over stacked
    timelines (all inputs tolerate a leading ensemble axis — the
    kernel path included).

    ``rspec`` switches to the multi-resource vector fit (DESIGN.md
    §11): a candidate is feasible iff plane 0 fits ``n_req`` *and*
    every secondary plane fits its ``demand_tail`` entry, policies
    keep scoring the plane-0 ``n_free``, and the winning mask spans
    all planes.  ``valid_mask`` (default: the spec's full layout)
    carries per-lane machine sizes.
    """
    starts = candidate_starts(tl, t_r, t_du, t_dl)
    if rspec is not None:
        if valid_mask is None:
            valid_mask = jnp.asarray(rspec.valid_mask_np())
        if demand_tail is None:
            demand_tail = jnp.zeros((rspec.R - 1,), jnp.int32)
        demand_tail = jnp.asarray(demand_tail, jnp.int32)
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        # fused path: rectangles + policy selection in one kernel —
        # the per-candidate vectors never round-trip through HBM
        sel = kernel_ops.search_select(
            tl, starts, t_du, t_now, n_req, policy_id, n_pe=n_pe,
            rspec=rspec, demand_tail=demand_tail,
            valid_mask=valid_mask)
        if sel is not None:
            found = sel["found"]
            t_s = starts[sel["best"]]
            if rspec is None:
                pe_mask = _winning_pe_mask(tl, t_s, t_du, n_req, n_pe)
            else:
                pe_mask = _winning_mask_mr(
                    tl, t_s, t_du, n_req, demand_tail, rspec,
                    valid_mask)
            return SearchResult(
                found=found,
                t_s=t_s,
                t_e=t_s + t_du,
                pe_mask=jnp.where(found, pe_mask, jnp.uint32(0)),
                n_free=sel["n_free"],
                t_begin=sel["t_begin"],
                t_end=sel["t_end"],
            )
    # jnp reference path — also the fallback when search_select
    # returned None (shape beyond the kernel VMEM budget; the unfused
    # kernel entry exists for the element-wise oracle tests)
    rects = availability_rectangles(tl, starts, t_du, t_now, n_pe,
                                    rspec=rspec, valid_mask=valid_mask)
    feasible = rects.valid & (rects.n_free >= n_req)
    if rspec is not None and rspec.R > 1:
        feasible = feasible & jnp.all(
            rects.n_free_tail >= demand_tail[None, :], axis=1)
    duration = rects.t_end - rects.t_begin
    best, found = policies_lib.select(
        policy_id, rects.n_free, duration, rects.starts, feasible)
    t_s = rects.starts[best]
    if rspec is None:
        pe_mask = _winning_pe_mask(tl, t_s, t_du, n_req, n_pe)
    else:
        pe_mask = _winning_mask_mr(
            tl, t_s, t_du, n_req, demand_tail, rspec, valid_mask)
    return SearchResult(
        found=found,
        t_s=t_s,
        t_e=t_s + t_du,
        pe_mask=jnp.where(found, pe_mask, jnp.uint32(0)),
        n_free=rects.n_free[best],
        t_begin=rects.t_begin[best],
        t_end=rects.t_end[best],
    )


find_allocation = functools.partial(
    jax.jit, static_argnames=("n_pe", "use_kernel", "rspec"))(search)


def replacement_search(
    tl: Timeline,
    t_r: jax.Array,
    t_du: jax.Array,
    t_dl: jax.Array,
    n_req: jax.Array,
    policy_id: jax.Array,
    t_now: jax.Array,
    *,
    n_pe: int,
    use_kernel: bool = False,
    rspec=None,
    demand_tail: Optional[jax.Array] = None,
    valid_mask: Optional[jax.Array] = None,
) -> SearchResult:
    """The backfill feasibility check: re-place a parked reservation.

    Identical to :func:`search` except the window is clamped to what is
    still reachable — candidates start at ``max(t_r, t_now)`` — so a
    deferral-queue entry can only be re-placed at a start it could
    really make.  Because a live parked reservation always satisfies
    ``t_now < t_s <= t_dl - t_du``, the clamped window is never empty.
    Used by the retry-on-release sweep (earliest-start re-placement)
    and the EASY displacement transaction (:mod:`repro.core.batch`).
    """
    return search(tl, jnp.maximum(t_r, t_now), t_du, t_dl, n_req,
                  policy_id, t_now, n_pe=n_pe, use_kernel=use_kernel,
                  rspec=rspec, demand_tail=demand_tail,
                  valid_mask=valid_mask)
