"""Device-side ``findAllocation`` (Algorithm 3), fully vectorised.

The paper's per-candidate scan — "for every optional start time, get the
free PEs in the window, then expand to the maximum availability
rectangle" — is reformulated as two dense matrix products over the
bit-expanded occupancy (DESIGN.md §2):

    busy[P, pe]     = (overlap[P, S] @ occ_bits[S, pe]) > 0
    blocking[P, S]  = (free[P, pe]   @ occ_bits[S, pe]^T) > 0

so the whole search maps onto the MXU.  The rectangle bounds are then
masked min/max reductions over the slot axis.  ``kernels/availscan``
implements the same contraction as a Pallas kernel; this module is the
pure-jnp path (and the oracle the kernel is tested against).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import policies as policies_lib
from repro.core import timeline as tl_lib
from repro.core.timeline import Timeline
from repro.core.types import T_INF


class SearchResult(NamedTuple):
    found: jax.Array      # bool
    t_s: jax.Array        # int32 chosen start
    t_e: jax.Array        # int32 chosen end
    pe_mask: jax.Array    # uint32[W] chosen PEs
    n_free: jax.Array     # int32 free PEs in the winning rectangle
    t_begin: jax.Array    # int32 rectangle begin
    t_end: jax.Array      # int32 rectangle end


class Rectangles(NamedTuple):
    """Per-candidate maximum availability rectangles."""

    starts: jax.Array    # int32[P]
    n_free: jax.Array    # int32[P]
    t_begin: jax.Array   # int32[P]
    t_end: jax.Array     # int32[P]
    valid: jax.Array     # bool[P]


def candidate_starts(tl: Timeline, t_r: jax.Array, t_du: jax.Array,
                     t_dl: jax.Array) -> jax.Array:
    """int32[2S+2] candidates; infeasible slots padded with T_INF.

    Candidates are the ready time, the latest start, every boundary in
    range, and every boundary shifted left by the duration (end-aligned
    placements) — the paper's Section 4.2 enumeration.
    """
    lo = t_r
    hi = t_dl - t_du

    def in_range(x):
        return (x >= lo) & (x <= hi) & (x < T_INF)

    c_bound = jnp.where(in_range(tl.times), tl.times, T_INF)
    shifted = jnp.where(tl.times < T_INF, tl.times - t_du, T_INF)
    c_shift = jnp.where(in_range(shifted), shifted, T_INF)
    ends = jnp.stack([lo, hi]).astype(jnp.int32)
    return jnp.sort(jnp.concatenate([ends, c_bound, c_shift]))


def availability_rectangles(
    tl: Timeline, starts: jax.Array, t_du: jax.Array, t_now: jax.Array,
    n_pe: int,
) -> Rectangles:
    """Maximum availability rectangle per candidate (Algorithm 3 l.6-9)."""
    occ_bits = tl_lib.unpack_bits(tl.occ, n_pe).astype(jnp.float32)
    nxt = tl_lib.next_times(tl)
    valid = starts < T_INF
    a = jnp.minimum(starts, T_INF - t_du)       # avoid int32 overflow
    b = a + t_du
    # window overlap and busy-PE union (first MXU contraction)
    ov = ((tl.times[None, :] < b[:, None]) &
          (nxt[None, :] > a[:, None])).astype(jnp.float32)      # [P, S]
    busy = jax.lax.dot(ov, occ_bits) > 0.5                      # [P, pe]
    free = ~busy                                                # [P, pe]
    n_free = jnp.sum(free, axis=1).astype(jnp.int32)
    # blocking slots: a slot blocks iff it occupies any free PE
    # (second MXU contraction, contracting the PE axis)
    blocking = jax.lax.dot_general(
        free.astype(jnp.float32), occ_bits,
        dimension_numbers=(((1,), (1,)), ((), ()))) > 0.5        # [P, S]
    left = blocking & (nxt[None, :] <= a[:, None])
    t_begin = jnp.max(jnp.where(left, nxt[None, :], -T_INF), axis=1)
    t_begin = jnp.minimum(jnp.maximum(t_begin, t_now), a)
    right = blocking & (tl.times[None, :] >= b[:, None])
    t_end = jnp.min(jnp.where(right, tl.times[None, :], T_INF), axis=1)
    return Rectangles(starts=starts, n_free=n_free, t_begin=t_begin,
                      t_end=t_end, valid=valid)


def _winning_pe_mask(tl: Timeline, t_s: jax.Array, t_du: jax.Array,
                     n_req: jax.Array, n_pe: int) -> jax.Array:
    """Lowest-index ``n_req`` free PEs over the winning window."""
    a = jnp.minimum(t_s, T_INF - t_du)
    busy = tl_lib.window_busy(tl, a, a + t_du)          # uint32[W]
    free_bits = (1 - tl_lib.unpack_bits(busy[None, :], n_pe)[0]
                 ).astype(jnp.int32)                    # [n_pe]
    csum = jnp.cumsum(free_bits)
    sel = (free_bits == 1) & (csum <= n_req)
    W = tl.words
    sel_padded = jnp.zeros((W * 32,), jnp.uint32).at[:n_pe].set(
        sel.astype(jnp.uint32))
    return tl_lib.pack_bits(sel_padded[None, :])[0]


def search(
    tl: Timeline,
    t_r: jax.Array,
    t_du: jax.Array,
    t_dl: jax.Array,
    n_req: jax.Array,
    policy_id: jax.Array,
    t_now: jax.Array,
    *,
    n_pe: int,
    use_kernel: bool = False,
) -> SearchResult:
    """Full Algorithm 3: candidates -> rectangles -> policy -> PE pick.

    Trace-time body, deliberately not jitted: :func:`find_allocation`
    wraps it for standalone use, :mod:`repro.core.batch` inlines it
    into the fused ``admit`` step so find+commit compile as one
    program, and :mod:`repro.core.ensemble` vmaps it over stacked
    timelines (all inputs tolerate a leading ensemble axis — the
    kernel path included).
    """
    starts = candidate_starts(tl, t_r, t_du, t_dl)
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        rects = kernel_ops.availability_rectangles(
            tl, starts, t_du, t_now, n_pe=n_pe)
    else:
        rects = availability_rectangles(tl, starts, t_du, t_now, n_pe)
    feasible = rects.valid & (rects.n_free >= n_req)
    duration = rects.t_end - rects.t_begin
    best, found = policies_lib.select(
        policy_id, rects.n_free, duration, rects.starts, feasible)
    t_s = rects.starts[best]
    pe_mask = _winning_pe_mask(tl, t_s, t_du, n_req, n_pe)
    return SearchResult(
        found=found,
        t_s=t_s,
        t_e=t_s + t_du,
        pe_mask=jnp.where(found, pe_mask, jnp.uint32(0)),
        n_free=rects.n_free[best],
        t_begin=rects.t_begin[best],
        t_end=rects.t_end[best],
    )


find_allocation = functools.partial(
    jax.jit, static_argnames=("n_pe", "use_kernel"))(search)


def replacement_search(
    tl: Timeline,
    t_r: jax.Array,
    t_du: jax.Array,
    t_dl: jax.Array,
    n_req: jax.Array,
    policy_id: jax.Array,
    t_now: jax.Array,
    *,
    n_pe: int,
    use_kernel: bool = False,
) -> SearchResult:
    """The backfill feasibility check: re-place a parked reservation.

    Identical to :func:`search` except the window is clamped to what is
    still reachable — candidates start at ``max(t_r, t_now)`` — so a
    deferral-queue entry can only be re-placed at a start it could
    really make.  Because a live parked reservation always satisfies
    ``t_now < t_s <= t_dl - t_du``, the clamped window is never empty.
    Used by the retry-on-release sweep (earliest-start re-placement)
    and the EASY displacement transaction (:mod:`repro.core.batch`).
    """
    return search(tl, jnp.maximum(t_r, t_now), t_du, t_dl, n_req,
                  policy_id, t_now, n_pe=n_pe, use_kernel=use_kernel)
