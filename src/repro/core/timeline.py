"""JAX device engine: the availability timeline as a dense tensor.

TPU adaptation of the paper's ``AvailRectList`` (see DESIGN.md §2): the
linked list of ``{time, busy-PE-set}`` records becomes a fixed-capacity
struct-of-arrays pytree.  All operations are functional, jit-compatible,
and use ``jax.lax`` control flow only — no host round-trips.

Layout
------
``times : int32[S]``      sorted boundaries; ``T_INF`` marks padding
``occ   : uint32[S, W]``  busy-unit bitmask during ``[times[i], times[i+1])``

``W`` packs one bitplane per resource, concatenated on the word axis
(DESIGN.md §11): plane ``r`` of a
:class:`~repro.core.resources.ResourceSpec` owns the word range
``rspec.plane_slice(r)`` and bit ``u`` of that plane is unit ``u`` of
resource ``r``.  The default scalar configuration (``rspec=None``) is
the single PE plane ``W == n_words(n_pe)`` — the paper's layout — and
every operation below is word-count agnostic, so both configurations
run the same code.

Invariants (asserted in tests, preserved by ``update``):
  * valid entries are strictly sorted and precede all padding;
  * consecutive valid rows differ (merged records, paper's "clean");
  * the first valid row is non-empty; occupancy after the last valid
    boundary is empty (all free), as is before the first;
  * bits past each plane's unit count (and outside a lane's valid
    mask) are never set.
"""
from __future__ import annotations

import functools
import operator
from typing import Any, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import availindex as idx_lib
from repro.core.types import T_INF

_WORD = 32


def n_words(n_pe: int) -> int:
    return (n_pe + _WORD - 1) // _WORD


def next_pow2(n: int) -> int:
    """Smallest power of two >= ``n`` (and >= 2) — growth sizing."""
    return 1 << max(int(n) - 1, 1).bit_length()


class Timeline(NamedTuple):
    """Fixed-capacity availability timeline (a JAX pytree).

    The optional hierarchical availability index (DESIGN.md §12) rides
    along as three summary arrays plus the static zero-leaf
    :class:`~repro.core.availindex.IndexSpec`; all default ``None``,
    so index-free timelines keep their legacy leaf set and compiled
    graphs.  When present, every update refreshes the summaries from
    the post-update rows, so they always equal
    :func:`~repro.core.availindex.build_summaries` of the current
    timeline (see :func:`_reindex` for why the refresh is a plain
    recompute rather than a dirty-tile select).
    """

    times: jax.Array  # int32[S]
    occ: jax.Array    # uint32[S, W]
    idx_occ: Optional[jax.Array] = None      # uint32[S/T, W]
    idx_minfree: Optional[jax.Array] = None  # int32[S/T, R]
    idx_maxfree: Optional[jax.Array] = None  # int32[S/T, R]
    ispec: Optional[Any] = None              # static IndexSpec

    @property
    def capacity(self) -> int:
        return self.times.shape[0]

    @property
    def words(self) -> int:
        return self.occ.shape[1]

    def n_valid(self) -> jax.Array:
        return jnp.sum(self.times < T_INF).astype(jnp.int32)


def empty(capacity: int, n_pe: int,
          words: Optional[int] = None,
          ispec: Optional[Any] = None) -> Timeline:
    """All-free timeline; ``words`` overrides the single-plane width
    (multi-resource layouts pass ``rspec.total_words``).  ``ispec``
    attaches the hierarchical availability index (DESIGN.md §12)."""
    W = n_words(n_pe) if words is None else int(words)
    out = Timeline(
        times=jnp.full((capacity,), T_INF, dtype=jnp.int32),
        occ=jnp.zeros((capacity, W), dtype=jnp.uint32),
    )
    if ispec is not None:
        if ispec.total_words != W:
            raise ValueError(
                f"ispec covers {ispec.total_words} words, timeline "
                f"has {W}")
        i_occ, i_min, i_max = idx_lib.empty_summaries(capacity, ispec)
        out = out._replace(idx_occ=i_occ, idx_minfree=i_min,
                           idx_maxfree=i_max, ispec=ispec)
    return out


def _reindex(new_tl: Timeline, ispec) -> Timeline:
    """Index maintenance after an update (DESIGN.md §12).

    Recomputes the tile summaries from the post-update rows.  An
    earlier incremental variant kept the old summaries for tiles
    wholly before the first changed row via a dirty-from where-select;
    the select chain (searchsorted + iota + three broadcast selects)
    measured *slower* on CPU than the handful of fused popcount/reduce
    ops it reuses, and the recompute is bit-identical on clean tiles
    anyway (their rows are unchanged and the summaries are
    deterministic), so the simple form is canonical — the property
    suite pins it against :func:`~repro.core.availindex.build_summaries`
    either way.
    """
    f_occ, f_min, f_max = idx_lib.build_summaries(
        new_tl.times, new_tl.occ, ispec)
    return new_tl._replace(
        idx_occ=f_occ, idx_minfree=f_min, idx_maxfree=f_max,
        ispec=ispec,
    )


class SchedulerState(NamedTuple):
    """Complete functional scheduler state (a JAX pytree, DESIGN.md §3).

    The timeline plus the device-side pending-release buffer of
    committed reservations (``pend_te == T_INF`` marks a free slot) and
    run counters.  ``overflow`` latches when either the timeline or the
    pending buffer ran out of capacity: from then on every further
    fused-admission step is a no-op and the host wrapper must grow the
    state and re-run (see :mod:`repro.core.batch`).

    ``hw_records`` / ``hw_pending`` are high-water marks: the most
    timeline records (including the overflowing count, which may exceed
    the capacity) and pending slots any step needed so far.  The host
    wrappers read them to grow once to the max needed capacity —
    across a whole ensemble when the leading axis is vmapped
    (DESIGN.md §4) — instead of doubling blindly per retry.

    The ``park_*`` arrays are the bounded backfilling deferral queue
    (DESIGN.md §6): accepted-but-delayed requests hold their
    reservation mark here (start / end / PE mask occupy the timeline
    like any committed reservation) together with the request window
    needed to re-place them (``park_tr`` / ``park_tdl`` / ``park_npe``)
    and an FCFS sequence number (``park_seq``; ``T_INF`` marks a free
    slot, the minimum live value is the head of queue).  The queue
    capacity ``Q`` is a *static* shape: ``Q == 0`` (the default)
    compiles every backfill branch away, so pre-backfill callers keep
    their exact graphs.
    """

    tl: Timeline
    pend_ts: jax.Array    # int32[K] reservation starts
    pend_te: jax.Array    # int32[K] reservation ends; T_INF = free slot
    pend_mask: jax.Array  # uint32[K, W] reserved-PE bitmasks
    n_accepted: jax.Array  # int32 scalar
    n_released: jax.Array  # int32 scalar
    overflow: jax.Array    # bool scalar
    hw_records: jax.Array  # int32 scalar: max records any update needed
    hw_pending: jax.Array  # int32 scalar: max pending slots needed
    park_ts: jax.Array    # int32[Q] parked reservation starts
    park_te: jax.Array    # int32[Q] parked reservation ends
    park_mask: jax.Array  # uint32[Q, W] parked reserved-PE bitmasks
    park_tr: jax.Array    # int32[Q] ready times (re-place window lo)
    park_tdl: jax.Array   # int32[Q] deadlines (re-place window hi)
    park_npe: jax.Array   # int32[Q] PEs requested
    park_seq: jax.Array   # int32[Q] FCFS sequence; T_INF = free slot
    park_retry: jax.Array  # bool scalar: a cancel freed future
    #                        capacity; the next EASY admit step runs
    #                        the retry-on-release sweep once
    park_next_seq: jax.Array  # int32 scalar: next sequence to assign
    n_parked: jax.Array    # int32 scalar: lifetime parks
    n_promoted: jax.Array  # int32 scalar: lifetime promotions
    n_moved: jax.Array     # int32 scalar: lifetime reservation moves
    hw_parked: jax.Array   # int32 scalar: max live queue entries
    #: Optional multi-tenant table (``repro.tenancy.TenantTable``,
    #: DESIGN.md §10).  ``None`` — the default — contributes no pytree
    #: leaves, so zero-tenant sessions trace, donate, and shard the
    #: byte-identical graphs they had before tenancy existed.
    tenants: Optional[Any] = None
    #: Multi-resource extension (DESIGN.md §11), all ``None`` by
    #: default so scalar states keep their exact treedef and graphs:
    #: ``park_dem`` holds the secondary-plane demand vectors of parked
    #: requests (plane 0 stays in ``park_npe``); ``lane_valid`` is the
    #: packed valid-unit mask of this lane (heterogeneous machine
    #: sizes shrink it below the spec's padded word layout); ``rspec``
    #: is the static :class:`~repro.core.resources.ResourceSpec` —
    #: a zero-leaf pytree node, so it lives in the treedef, not in
    #: the buffers.
    park_dem: Optional[jax.Array] = None   # int32[Q, R-1]
    lane_valid: Optional[jax.Array] = None  # uint32[W]
    rspec: Optional[Any] = None

    @property
    def pending_capacity(self) -> int:
        return self.pend_te.shape[0]

    @property
    def park_capacity(self) -> int:
        return self.park_seq.shape[0]


def init_state(capacity: int, n_pe: int,
               pending_capacity: int = 256,
               park_capacity: int = 0,
               tenants: Optional[Any] = None,
               rspec: Optional[Any] = None,
               live_units=None,
               index_tile: Optional[int] = None) -> SchedulerState:
    """Fresh all-free scheduler state.

    ``park_capacity`` sizes the backfilling deferral queue; the default
    0 statically disables every backfill code path (identical compiled
    graphs to the pre-backfill core).  ``tenants`` optionally attaches
    a ``repro.tenancy.TenantTable`` (its buffer columns must match
    ``pending_capacity`` / ``park_capacity``).

    ``rspec`` (a :class:`~repro.core.resources.ResourceSpec` with
    ``units[0] == n_pe``) switches the state to the multi-resource
    layout: the occupancy and every reservation mask widen to
    ``rspec.total_words`` words, secondary-plane demands of parked
    requests persist in ``park_dem``, and ``live_units`` optionally
    shrinks this lane's schedulable units per plane (heterogeneous
    machine sizes; ``live_units[0] <= n_pe``).

    ``index_tile`` (a power of two dividing ``capacity``) attaches the
    hierarchical availability index (DESIGN.md §12): per-tile timeline
    summaries refreshed by every update, consumed for conservative
    candidate pruning and early-reject admission.  The
    default ``None`` keeps the index-free legacy treedef and graphs.
    """
    if rspec is not None and rspec.n_pe != n_pe:
        raise ValueError(
            f"rspec.units[0]={rspec.n_pe} must equal n_pe={n_pe}")
    if live_units is not None and rspec is None:
        raise ValueError("live_units requires rspec")
    ispec = None
    if index_tile is not None:
        ispec = idx_lib.make_index_spec(index_tile, n_pe, rspec)
        ispec.n_tiles(capacity)   # validates divisibility
    words = n_words(n_pe) if rspec is None else rspec.total_words
    park_dem = None
    if rspec is not None and rspec.R > 1 and park_capacity > 0:
        park_dem = jnp.zeros((park_capacity, rspec.R - 1), jnp.int32)
    lane_valid = None
    if rspec is not None:
        lane_valid = jnp.asarray(rspec.valid_mask_np(live_units))
    return SchedulerState(
        tl=empty(capacity, n_pe, words=words, ispec=ispec),
        pend_ts=jnp.full((pending_capacity,), T_INF, jnp.int32),
        pend_te=jnp.full((pending_capacity,), T_INF, jnp.int32),
        pend_mask=jnp.zeros((pending_capacity, words),
                            jnp.uint32),
        n_accepted=jnp.int32(0),
        n_released=jnp.int32(0),
        overflow=jnp.asarray(False),
        hw_records=jnp.int32(0),
        hw_pending=jnp.int32(0),
        park_ts=jnp.full((park_capacity,), T_INF, jnp.int32),
        park_te=jnp.full((park_capacity,), T_INF, jnp.int32),
        park_mask=jnp.zeros((park_capacity, words),
                            jnp.uint32),
        park_tr=jnp.zeros((park_capacity,), jnp.int32),
        park_tdl=jnp.zeros((park_capacity,), jnp.int32),
        park_npe=jnp.zeros((park_capacity,), jnp.int32),
        park_seq=jnp.full((park_capacity,), T_INF, jnp.int32),
        park_retry=jnp.asarray(False),
        park_next_seq=jnp.int32(0),
        n_parked=jnp.int32(0),
        n_promoted=jnp.int32(0),
        n_moved=jnp.int32(0),
        hw_parked=jnp.int32(0),
        tenants=tenants,
        park_dem=park_dem,
        lane_valid=lane_valid,
        rspec=rspec,
    )


def grow_state(state: SchedulerState,
               new_capacity: int | None = None,
               new_pending_capacity: int | None = None) -> SchedulerState:
    """Host-side capacity growth of timeline and/or pending buffer.

    Padding rows never change decisions, so re-running a request stream
    on a grown copy of the pre-stream state is deterministic.
    """
    out = state
    if new_capacity is not None:
        out = out._replace(tl=grow(out.tl, new_capacity))
    if new_pending_capacity is not None:
        K = out.pending_capacity
        assert new_pending_capacity >= K
        pad = new_pending_capacity - K
        out = out._replace(
            pend_ts=jnp.concatenate(
                [out.pend_ts, jnp.full((pad,), T_INF, jnp.int32)]),
            pend_te=jnp.concatenate(
                [out.pend_te, jnp.full((pad,), T_INF, jnp.int32)]),
            pend_mask=jnp.concatenate(
                [out.pend_mask,
                 jnp.zeros((pad, out.pend_mask.shape[1]), jnp.uint32)]),
        )
        if out.tenants is not None:
            out = out._replace(tenants=out.tenants._replace(
                pend_tenant=jnp.concatenate(
                    [out.tenants.pend_tenant,
                     jnp.full((pad,), -1, jnp.int32)])))
    return out


def pe_valid_mask(n_pe: int) -> np.ndarray:
    """uint32[W] with exactly the first ``n_pe`` bits set."""
    W = n_words(n_pe)
    bits = np.zeros(W * _WORD, dtype=np.uint32)
    bits[:n_pe] = 1
    return pack_bits(bits[None, :])[0]


def ids_to_mask32(pe_ids, words: int,
                  n_pe: Optional[int] = None) -> jax.Array:
    """Sorted-or-not PE id sequence -> uint32[words] bitmask.

    Host-side only: ids must be concrete non-negative integers below
    ``n_pe`` (below ``words * 32`` when ``n_pe`` is ``None``), with no
    duplicates.  Traced values are rejected with a ``TypeError`` — a
    tracer cannot be scattered into a host numpy buffer, and silently
    mis-building a mask would corrupt the timeline invariants.
    """
    if isinstance(pe_ids, jax.core.Tracer):
        raise TypeError(
            "ids_to_mask32 is host-side: got a traced id sequence; "
            "build masks inside jit with pack_bits instead")
    limit = words * _WORD if n_pe is None else int(n_pe)
    bits = np.zeros(words * _WORD, dtype=np.uint32)
    for i in pe_ids:
        if isinstance(i, jax.core.Tracer):
            raise TypeError(
                f"ids_to_mask32 is host-side: got traced id {i!r}")
        try:
            idx = int(operator.index(
                i.item() if isinstance(i, (jax.Array, np.ndarray))
                else i))
        except TypeError as e:
            raise TypeError(
                f"PE id {i!r} is not an integer") from e
        if not 0 <= idx < limit:
            raise ValueError(
                f"PE id {idx} out of range [0, {limit})")
        if bits[idx]:
            raise ValueError(f"duplicate PE id {idx}")
        bits[idx] = 1
    return jnp.asarray(pack_bits(bits[None, :])[0])


def pack_bits(bits: np.ndarray | jax.Array) -> jax.Array:
    """[..., W*32] 0/1 -> uint32 [..., W] little-endian within words."""
    xp = jnp if isinstance(bits, jax.Array) else np
    *lead, nbits = bits.shape
    assert nbits % _WORD == 0
    b = bits.reshape(*lead, nbits // _WORD, _WORD).astype(xp.uint32)
    shifts = xp.arange(_WORD, dtype=xp.uint32)
    return (b << shifts).sum(axis=-1).astype(xp.uint32)


def unpack_bits(words: jax.Array, n_pe: int) -> jax.Array:
    """uint32 [..., W] -> 0/1 int8 [..., n_pe]."""
    shifts = jnp.arange(_WORD, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*words.shape[:-1], words.shape[-1] * _WORD)[
        ..., :n_pe].astype(jnp.int8)


def occupancy_at(tl: Timeline, t: jax.Array) -> jax.Array:
    """Busy bitmask in effect at instant ``t`` (zeros outside records)."""
    idx = jnp.searchsorted(tl.times, t, side="right") - 1
    in_range = (idx >= 0) & (jnp.take(tl.times, jnp.maximum(idx, 0)) < T_INF)
    row = jnp.take(tl.occ, jnp.clip(idx, 0, tl.capacity - 1), axis=0)
    return jnp.where(in_range, row, jnp.uint32(0))


def next_times(tl: Timeline) -> jax.Array:
    """End of each slot's interval; padding rows get ``T_INF``."""
    return jnp.concatenate(
        [tl.times[1:], jnp.array([T_INF], dtype=jnp.int32)])


def _merge_compact(ext_t: jax.Array, ext_o: jax.Array, S: int,
                   words: int) -> Tuple[Timeline, jax.Array, jax.Array]:
    """Shared epilogue of every update: merge + scatter-compact.

    ``ext_t``/``ext_o`` are the time-sorted extended rows (originals
    plus inserted boundaries, already range-updated).  Keeps rows whose
    occupancy differs from the previous kept row — duplicates carry
    identical occupancy after the range update, so comparing against
    the immediate predecessor suffices — then scatter-compacts the
    survivors back into capacity ``S``.
    """
    R = ext_t.shape[0]
    prev = jnp.concatenate(
        [jnp.zeros((1, words), jnp.uint32), ext_o[:-1]])
    keep = (ext_t < T_INF) & jnp.any(ext_o != prev, axis=1)
    pos = jnp.cumsum(keep) - 1
    dest = jnp.where(keep, pos, R - 1)
    out_t = jnp.full((R,), T_INF, jnp.int32).at[dest].set(
        jnp.where(keep, ext_t, T_INF))
    out_o = jnp.zeros((R, words), jnp.uint32).at[dest].set(
        jnp.where(keep[:, None], ext_o, jnp.uint32(0)))
    n_keep = jnp.sum(keep).astype(jnp.int32)
    overflow = n_keep > S
    return Timeline(times=out_t[:S], occ=out_o[:S]), overflow, n_keep


@functools.partial(jax.jit, static_argnames=("is_add", "with_count"))
def update(tl: Timeline, t_s: jax.Array, t_e: jax.Array,
           mask: jax.Array, *, is_add: bool,
           with_count: bool = False
           ) -> Union[Tuple[Timeline, jax.Array],
                      Tuple[Timeline, jax.Array, jax.Array]]:
    """Functional ``addAllocation`` / ``deleteAllocation`` (Algorithms 1-2).

    Inserts the two boundary records, ORs (or AND-NOTs) ``mask`` into
    every record in ``[t_s, t_e)``, merges redundant records, and
    re-compacts into the same capacity.  Returns ``(new_tl, overflow)``
    where ``overflow`` flags that the compacted timeline needed more
    than ``S`` records (callers must grow and retry — see scheduler).
    With ``with_count=True`` returns ``(new_tl, overflow, n_keep)``
    where ``n_keep`` is the record count the result *needed* (it may
    exceed the capacity ``S``) — the growth wrappers use it to size
    the retry in one step.

    Sort-free (DESIGN.md §7): the timeline is sorted by invariant, so
    the two boundary records are placed with ``searchsorted`` and a
    shift-gather instead of re-lexsorting all ``S + 2`` rows on every
    insert.  Bit-identical to :func:`update_lexsort` (the retained
    oracle, asserted by ``tests/test_timeline_fast.py``).
    """
    S = tl.capacity
    t_s = jnp.asarray(t_s, jnp.int32)
    t_e = jnp.asarray(t_e, jnp.int32)
    # 0. clamp malformed intervals to a provable no-op.  A ``t_e`` at
    #    or past the ``T_INF`` sentinel would make the half-open range
    #    update ``t < t_e`` cover the padding tail forever (occupancy
    #    that can never be released — a silently corrupted invariant);
    #    map such intervals to the empty ``[T_INF, T_INF) x 0`` update,
    #    whose inserted boundary rows the merge pass drops.
    valid_iv = (t_s < t_e) & (t_e < T_INF)
    t_s = jnp.where(valid_iv, t_s, T_INF)
    t_e = jnp.where(valid_iv, t_e, T_INF)
    mask = jnp.where(valid_iv, mask, jnp.zeros_like(mask))
    # 1. merged positions of the two inserted boundary records: after
    #    all originals of equal time ('right'), and — matching the
    #    retained lexsort oracle's stable tie-break — the t_s record
    #    before the t_e record when the two coincide.
    i_s = jnp.searchsorted(tl.times, t_s, side="right").astype(jnp.int32)
    i_e = jnp.searchsorted(tl.times, t_e, side="right").astype(jnp.int32)
    pos_s = i_s + (t_e < t_s).astype(jnp.int32)
    pos_e = i_e + (t_s <= t_e).astype(jnp.int32)
    # 2. shift-gather the originals around the two insertion points;
    #    inserted records inherit the occupancy in effect at their
    #    instant.
    idx = jnp.arange(S + 2, dtype=jnp.int32)
    src = idx - (idx > pos_s).astype(jnp.int32) \
        - (idx > pos_e).astype(jnp.int32)
    src = jnp.clip(src, 0, S - 1)
    ext_t = jnp.where(
        idx == pos_s, t_s,
        jnp.where(idx == pos_e, t_e, tl.times[src]))
    ext_o = jnp.where(
        (idx == pos_s)[:, None], occupancy_at(tl, t_s)[None, :],
        jnp.where((idx == pos_e)[:, None],
                  occupancy_at(tl, t_e)[None, :], tl.occ[src]))
    # 3. apply the range update.
    in_range = (ext_t >= t_s) & (ext_t < t_e)
    if is_add:
        upd = ext_o | mask[None, :]
    else:
        upd = ext_o & ~mask[None, :]
    ext_o = jnp.where(in_range[:, None], upd, ext_o)
    # 4.-5. merge + scatter-compact back to capacity S.
    out, overflow, n_keep = _merge_compact(ext_t, ext_o, S, tl.words)
    if tl.ispec is not None:
        out = _reindex(out, tl.ispec)
    if with_count:
        return out, overflow, n_keep
    return out, overflow


@functools.partial(jax.jit, static_argnames=("is_add", "with_count"))
def update_lexsort(tl: Timeline, t_s: jax.Array, t_e: jax.Array,
                   mask: jax.Array, *, is_add: bool,
                   with_count: bool = False
                   ) -> Union[Tuple[Timeline, jax.Array],
                              Tuple[Timeline, jax.Array, jax.Array]]:
    """The original lexsort-based :func:`update` (the PR 1-4 hot path).

    Retained as the bit-exactness oracle for the sort-free
    implementations: ``tests/test_timeline_fast.py`` fuzzes
    :func:`update` and :func:`update_many` against it.  Not used on
    any hot path.
    """
    S = tl.capacity
    t_s = jnp.asarray(t_s, jnp.int32)
    t_e = jnp.asarray(t_e, jnp.int32)
    # 1. extend with the two (possibly duplicate) boundary records,
    #    inheriting the occupancy in effect at each instant.
    ext_t = jnp.concatenate([tl.times, jnp.stack([t_s, t_e])])
    ext_o = jnp.concatenate(
        [tl.occ, jnp.stack([occupancy_at(tl, t_s), occupancy_at(tl, t_e)])])
    is_new = jnp.zeros(S + 2, jnp.int32).at[S:].set(1)
    # 2. stable order: by time, originals before inserted duplicates so
    #    that the merge pass removes the duplicate.
    perm = jnp.lexsort((is_new, ext_t))
    ext_t, ext_o = ext_t[perm], ext_o[perm]
    # 3. apply the range update.
    in_range = (ext_t >= t_s) & (ext_t < t_e)
    if is_add:
        upd = ext_o | mask[None, :]
    else:
        upd = ext_o & ~mask[None, :]
    ext_o = jnp.where(in_range[:, None], upd, ext_o)
    # 4.-5. merge + scatter-compact back to capacity S.
    out, overflow, n_keep = _merge_compact(ext_t, ext_o, S, tl.words)
    if tl.ispec is not None:
        out = _reindex(out, tl.ispec)
    if with_count:
        return out, overflow, n_keep
    return out, overflow


@functools.partial(jax.jit, static_argnames=("is_add", "with_count"))
def update_many(tl: Timeline, t_s: jax.Array, t_e: jax.Array,
                masks: jax.Array, active: jax.Array, *, is_add: bool,
                with_count: bool = False
                ) -> Union[Tuple[Timeline, jax.Array],
                           Tuple[Timeline, jax.Array, jax.Array]]:
    """Batched ``update``: K same-direction intervals, one merge pass.

    Applies every interval ``[t_s[k], t_e[k]) x masks[k]`` with
    ``active[k]`` set — all adds or all deletes (``is_add`` is
    static).  Same-direction interval updates commute (a segment's
    occupancy is the OR / AND-NOT of the union of covering masks) and
    the merged compacted timeline is a *canonical* representation of
    the occupancy step function, so one batched pass is bit-identical
    to applying the K intervals through :func:`update` sequentially —
    the decision-safety argument of DESIGN.md §7 — while paying one
    boundary union + one segment-mask pass + one merge/compact
    instead of K.

    ``overflow`` flags that the final compacted result needed more
    than ``S`` records (``n_keep`` with ``with_count=True``); unlike
    a sequential chain there are no intermediate states, so a batch
    whose *end state* fits never overflows even if some sequential
    order would have spiked past ``S`` transiently.
    """
    S, W = tl.capacity, tl.words
    K = t_s.shape[0]
    t_s = jnp.asarray(t_s, jnp.int32)
    t_e = jnp.asarray(t_e, jnp.int32)
    # malformed intervals (t_e at/past the T_INF sentinel) would smear
    # their mask over the padding tail; deactivate them — the same
    # no-op clamp as :func:`update`.
    active = jnp.asarray(active, bool) & (t_s < t_e) & (t_e < T_INF)
    R = S + 2 * K
    # 1. boundary records: both endpoints of every active interval;
    #    inactive intervals contribute T_INF rows, which the merge
    #    drops.  Inserted records go after originals of equal time;
    #    ties among boundaries break by position (t_s block first).
    b_t = jnp.where(jnp.concatenate([active, active]),
                    jnp.concatenate([t_s, t_e]), T_INF)
    base = jnp.searchsorted(tl.times, b_t, side="right").astype(jnp.int32)
    lt = b_t[None, :] < b_t[:, None]
    tie = (b_t[None, :] == b_t[:, None]) & \
        (jnp.arange(2 * K)[None, :] < jnp.arange(2 * K)[:, None])
    rank = jnp.sum(lt | tie, axis=1).astype(jnp.int32)
    pos_b = base + rank
    # originals shift right past every boundary strictly below them
    pos_o = jnp.arange(S, dtype=jnp.int32) + jnp.sum(
        b_t[None, :] < tl.times[:, None], axis=1).astype(jnp.int32)
    # 2. scatter originals + boundaries into the merged order (the
    #    positions are pairwise distinct and cover [0, R) exactly).
    occ_b = jax.vmap(occupancy_at, in_axes=(None, 0))(tl, b_t)
    ext_t = jnp.zeros((R,), jnp.int32).at[pos_o].set(
        tl.times).at[pos_b].set(b_t)
    ext_o = jnp.zeros((R, W), jnp.uint32).at[pos_o].set(
        tl.occ).at[pos_b].set(occ_b)
    # 3. segment-mask union: OR (add) / AND-NOT (delete) of every
    #    active interval covering each record's instant.
    cover = active[None, :] & (t_s[None, :] <= ext_t[:, None]) & \
        (ext_t[:, None] < t_e[None, :])                        # [R, K]
    union = jax.lax.reduce(
        jnp.where(cover[:, :, None], masks[None, :, :], jnp.uint32(0)),
        np.uint32(0), jax.lax.bitwise_or, (1,))                # [R, W]
    if is_add:
        ext_o = ext_o | union
    else:
        ext_o = ext_o & ~union
    # 4.-5. merge + scatter-compact back to capacity S.
    out, overflow, n_keep = _merge_compact(ext_t, ext_o, S, W)
    if tl.ispec is not None:
        out = _reindex(out, tl.ispec)
    if with_count:
        return out, overflow, n_keep
    return out, overflow


@jax.jit
def window_busy(tl: Timeline, a: jax.Array, b: jax.Array) -> jax.Array:
    """Union of busy masks over records intersecting ``[a, b)``."""
    nxt = next_times(tl)
    ov = (tl.times < b) & (nxt > a)
    masked = jnp.where(ov[:, None], tl.occ, jnp.uint32(0))
    return jax.lax.reduce(masked, np.uint32(0), jax.lax.bitwise_or, (0,))


def grow(tl: Timeline, new_capacity: int) -> Timeline:
    """Host-side capacity growth (static shape change; not jitted).

    An attached index is re-materialised at the new tile count (the
    old tiles' values are unchanged — padding rows summarise to the
    all-free sentinel — but the arrays change shape, so a fresh build
    is the simplest bit-exact form).
    """
    assert new_capacity >= tl.capacity
    pad = new_capacity - tl.capacity
    out = Timeline(
        times=jnp.concatenate(
            [tl.times, jnp.full((pad,), T_INF, jnp.int32)]),
        occ=jnp.concatenate(
            [tl.occ, jnp.zeros((pad, tl.words), jnp.uint32)]),
    )
    if tl.ispec is not None:
        i_occ, i_min, i_max = idx_lib.build_summaries(
            out.times, out.occ, tl.ispec)
        out = out._replace(idx_occ=i_occ, idx_minfree=i_min,
                           idx_maxfree=i_max, ispec=tl.ispec)
    return out


def from_host(times: np.ndarray, occ64: np.ndarray, n_pe: int,
              capacity: int) -> Timeline:
    """Build a device timeline from the host engine's uint64 rows."""
    S = times.shape[0]
    assert S <= capacity, "host timeline exceeds device capacity"
    bits = np.zeros((S, n_words(n_pe) * _WORD), dtype=np.uint32)
    for w in range(occ64.shape[1]):
        lo = (occ64[:, w] & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        hi = (occ64[:, w] >> np.uint64(32)).astype(np.uint32)
        if 2 * w * _WORD < bits.shape[1]:
            bits[:, 2 * w * _WORD:(2 * w + 1) * _WORD] = _expand32(lo)
        if (2 * w + 1) * _WORD < bits.shape[1]:
            bits[:, (2 * w + 1) * _WORD:(2 * w + 2) * _WORD] = _expand32(hi)
    tl = empty(capacity, n_pe)
    return Timeline(
        times=tl.times.at[:S].set(jnp.asarray(times, jnp.int32)),
        occ=tl.occ.at[:S].set(pack_bits(bits)),
    )


def _expand32(words: np.ndarray) -> np.ndarray:
    shifts = np.arange(_WORD, dtype=np.uint32)
    return ((words[:, None] >> shifts) & np.uint32(1)).astype(np.uint32)
