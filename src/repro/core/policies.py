"""Policy scoring for the device engine.

Section 5 of the paper, vectorised: every policy minimises a primary
score with an earliest-start tiebreak.  Scores are computed in *exact
integer arithmetic* — float32 cannot distinguish durations near 2**31
(spacing 256), which would silently turn Du/PEDu policies into FF among
unbounded rectangles.  The PE x duration product (up to ~2**42) is kept
exact by splitting it into two lexicographically ordered int32 keys.

``policy_index`` gives the stable integer id used by the jitted search
(traced, so switching policy does not trigger recompilation).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ALL_POLICIES, Policy

POLICY_IDS = {p: i for i, p in enumerate(ALL_POLICIES)}


def policy_index(policy: Policy) -> int:
    return POLICY_IDS[policy]


def integer_keys(policy_id: jax.Array, n_free: jax.Array,
                 duration: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Exact (key1, key2) minimisation keys for ``policy_id``.

    The product ``n_free * duration`` is decomposed as
    ``p_hi * 2**16 + p_lo`` with ``p_lo < 2**16`` so that ``(p_hi,
    p_lo)`` compares identically to the true 42-bit product while both
    components fit int32 (requires ``n_free < 2**11``, i.e. up to 2048
    PEs — asserted by the scheduler facade).
    """
    nf = n_free.astype(jnp.int32)
    du = duration.astype(jnp.int32)
    du_hi = du >> 16
    du_lo = du & 0xFFFF
    p_lo_raw = nf * du_lo
    p_hi = nf * du_hi + (p_lo_raw >> 16)
    p_lo = p_lo_raw & 0xFFFF
    zero = jnp.zeros_like(nf)
    key1 = jnp.stack([zero, nf, -nf, du, -du, p_hi, -p_hi])
    key2 = jnp.stack([zero, zero, zero, zero, zero, p_lo, -p_lo])
    return key1[policy_id], key2[policy_id]


def select(policy_id: jax.Array, n_free: jax.Array, duration: jax.Array,
           starts: jax.Array, feasible: jax.Array) -> Tuple[jax.Array,
                                                            jax.Array]:
    """Pick the best feasible candidate for ``policy_id``.

    Returns ``(best_index, found)``: the lexicographic
    (key1, key2, t_s) minimum over feasible candidates, earliest index
    on full ties.  Computed sort-free (DESIGN.md §7) as three chained
    masked min-reductions plus a first-true pick — identical to the
    stable three-key lexsort it replaces, without sorting the
    candidate axis on every admission step.
    """
    big = jnp.iinfo(jnp.int32).max
    key1, key2 = integer_keys(policy_id, n_free, duration)
    key1 = jnp.where(feasible, key1, big)
    key2 = jnp.where(feasible, key2, big)
    tiebreak = jnp.where(feasible, starts, big)
    m1 = jnp.min(key1)
    e1 = key1 == m1
    m2 = jnp.min(jnp.where(e1, key2, big))
    e2 = e1 & (key2 == m2)
    m3 = jnp.min(jnp.where(e2, tiebreak, big))
    best = jnp.argmax(e2 & (tiebreak == m3))
    return best, feasible[best]
