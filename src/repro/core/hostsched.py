"""Fast numpy bitmask engine for the paper's data structure.

Same semantics as :mod:`repro.core.listsched` (the literal oracle) but
PE sets are uint64 bitmask rows and every operation is vectorised numpy.
This engine drives the 10^4-job discrete-event simulations of Section 6
at interactive speed; it is also the host-side fallback of the device
engine.

Representation
--------------
``times  : int64[S]``   sorted slot boundaries
``occ    : uint64[S,W]`` busy-PE bitmask during ``[times[i], times[i+1])``
with all PEs free before ``times[0]`` and from ``times[-1]`` on (the
last row is always all-zero, mirroring the paper's ``{t, null}``
terminator record).
"""
from __future__ import annotations

import heapq
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import (
    Allocation,
    ARRequest,
    BackfillMode,
    Policy,
    Rectangle,
    T_INF,
    policy_score,
)

_WORD = 64


def n_words(n_pe: int) -> int:
    return (n_pe + _WORD - 1) // _WORD


def mask_from_ids(ids: Iterable[int], n_pe: int) -> np.ndarray:
    m = np.zeros(n_words(n_pe), dtype=np.uint64)
    arr = np.fromiter(ids, dtype=np.int64) if not isinstance(
        ids, np.ndarray) else ids.astype(np.int64)
    if arr.size == 0:
        return m
    if arr.min() < 0 or arr.max() >= n_pe:
        raise ValueError("PE id out of range")
    np.bitwise_or.at(m, arr // _WORD,
                     np.uint64(1) << (arr % _WORD).astype(np.uint64))
    return m


def ids_from_mask(mask: np.ndarray) -> Tuple[int, ...]:
    bits = np.unpackbits(mask.view(np.uint8), bitorder="little")
    return tuple(np.nonzero(bits)[0].tolist())


def popcount(mask: np.ndarray) -> np.ndarray:
    """Population count, summed over the trailing word axis."""
    return np.bitwise_count(mask).sum(axis=-1).astype(np.int64)


def lowest_bits(mask: np.ndarray, k: int) -> np.ndarray:
    """Mask of the ``k`` lowest set bits of ``mask`` (1-D word array)."""
    out = np.zeros_like(mask)
    remaining = k
    for w in range(mask.shape[0]):
        word = int(mask[w])
        take = 0
        while word and remaining:
            b = word & -word
            take |= b
            word ^= b
            remaining -= 1
        out[w] = np.uint64(take)
        if not remaining:
            break
    if remaining:
        raise ValueError(f"asked for {k} bits, mask has too few")
    return out


def _policy_primary(policy: Policy, n_free: np.ndarray,
                    t_begin: np.ndarray,
                    t_end: np.ndarray) -> np.ndarray:
    """Lexicographic primary key, identical to ``types.policy_score``
    but vectorised (the ``t_s`` tiebreak stays with the caller)."""
    dur = (t_end - t_begin).astype(np.float64)
    nf = n_free.astype(np.float64)
    if policy == Policy.FF:
        return np.zeros_like(nf)
    if policy == Policy.PE_B:
        return nf
    if policy == Policy.PE_W:
        return -nf
    if policy == Policy.DU_B:
        return dur
    if policy == Policy.DU_W:
        return -dur
    if policy == Policy.PEDU_B:
        return nf * dur
    if policy == Policy.PEDU_W:
        return -nf * dur
    raise ValueError(policy)  # pragma: no cover


class HostScheduler:
    """Vectorised availability timeline + the three paper operations."""

    def __init__(self, n_pe: int, candidate_chunk: int = 128):
        self.n_pe = n_pe
        self.W = n_words(n_pe)
        self._chunk = candidate_chunk
        self.times = np.zeros(0, dtype=np.int64)
        self.occ = np.zeros((0, self.W), dtype=np.uint64)
        # bits >= n_pe never participate; keep a validity mask for safety
        self._pe_mask = mask_from_ids(range(n_pe), n_pe)

    # ------------------------------------------------------------------
    # state helpers
    # ------------------------------------------------------------------
    @property
    def n_slots(self) -> int:
        return int(self.times.shape[0])

    def _next_times(self) -> np.ndarray:
        if self.n_slots == 0:
            return np.zeros(0, dtype=np.int64)
        return np.concatenate([self.times[1:], [T_INF]])

    def _busy_row_at(self, t: int) -> np.ndarray:
        i = int(np.searchsorted(self.times, t, side="right")) - 1
        if i < 0 or i >= self.n_slots:
            return np.zeros(self.W, dtype=np.uint64)
        return self.occ[i].copy()

    def _insert_boundaries(self, t_s: int, t_e: int) -> None:
        """Insert both boundary records with one reallocation."""
        new_t, new_rows = [], []
        for t in (t_s, t_e):
            i = int(np.searchsorted(self.times, t, side="left"))
            if not (i < self.n_slots and self.times[i] == t):
                new_t.append(t)
                new_rows.append(self._busy_row_at(t))
        if not new_t:
            return
        idx = np.searchsorted(self.times, new_t, side="left")
        self.times = np.insert(self.times, idx, new_t)
        self.occ = np.insert(self.occ, idx, np.array(new_rows), axis=0)

    def _insert_boundary(self, t: int) -> None:
        self._insert_boundaries(t, t)

    def _clean(self) -> None:
        n = self.n_slots
        if n == 0:
            return
        keep = np.empty(n, dtype=bool)
        keep[0] = bool(self.occ[0].any())
        if n > 1:
            np.any(self.occ[1:] != self.occ[:-1], axis=1,
                   out=keep[1:])
        if not keep.all():
            self.times = self.times[keep]
            self.occ = self.occ[keep]

    # ------------------------------------------------------------------
    # Algorithms 1 and 2
    # ------------------------------------------------------------------
    def add_allocation(self, t_s: int, t_e: int,
                       pes: Sequence[int] | np.ndarray) -> None:
        mask = pes if isinstance(pes, np.ndarray) \
            else mask_from_ids(pes, self.n_pe)
        if t_s >= t_e:
            raise ValueError("empty interval")
        self._insert_boundaries(t_s, t_e)
        lo = int(np.searchsorted(self.times, t_s, side="left"))
        hi = int(np.searchsorted(self.times, t_e, side="left"))
        if np.any(self.occ[lo:hi] & mask):
            raise ValueError("double booking")
        self.occ[lo:hi] |= mask
        self._clean()

    def delete_allocation(self, t_s: int, t_e: int,
                          pes: Sequence[int] | np.ndarray) -> None:
        mask = pes if isinstance(pes, np.ndarray) \
            else mask_from_ids(pes, self.n_pe)
        self._insert_boundaries(t_s, t_e)
        lo = int(np.searchsorted(self.times, t_s, side="left"))
        hi = int(np.searchsorted(self.times, t_e, side="left"))
        if np.any((self.occ[lo:hi] & mask) != mask):
            raise ValueError("deleting PEs that were not reserved")
        self.occ[lo:hi] &= ~mask
        self._clean()

    # ------------------------------------------------------------------
    # Algorithm 3 — fully vectorised over candidate start times
    # ------------------------------------------------------------------
    def window_busy(self, a: int, b: int) -> np.ndarray:
        if self.n_slots == 0:
            return np.zeros(self.W, dtype=np.uint64)
        ov = (self.times < b) & (self._next_times() > a)
        if not ov.any():
            return np.zeros(self.W, dtype=np.uint64)
        return np.bitwise_or.reduce(self.occ[ov], axis=0)

    def candidate_starts(self, req: ARRequest) -> np.ndarray:
        lo, hi = req.t_r, req.t_dl - req.t_du
        cands = [np.array([lo, hi], dtype=np.int64)]
        if self.n_slots:
            t = self.times
            cands.append(t[(t >= lo) & (t <= hi)])
            shifted = t - req.t_du
            cands.append(shifted[(shifted >= lo) & (shifted <= hi)])
        return np.unique(np.concatenate(cands))

    def _rect_core(self, starts: np.ndarray, t_du: int,
                   t_now: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Free-word rectangles ``(free[P, W], t_begin, t_end)``.

        §Perf iteration A2 (EXPERIMENTS.md): windows over a *sorted*
        timeline cover contiguous slot ranges ``[lo_c, hi_c)``, so the
        busy union is a segmented OR (``np.bitwise_or.reduceat``) —
        O(S·W) total instead of the former O(S·C·W) masked reduction —
        and the rectangle bounds expand outward with an early-
        terminating frontier (geometric expected step count), instead
        of testing every (slot, candidate) pair.

        The popcount stays with the caller: :meth:`_rectangles` takes
        one global count, the multi-resource subclass contracts
        ``free`` against each plane's mask instead.
        """
        P = starts.shape[0]
        if self.n_slots == 0:
            free = np.broadcast_to(
                self._pe_mask, (P, self.W)).copy()
            return (free,
                    np.minimum(t_now, starts.astype(np.int64)),
                    np.full(P, T_INF, np.int64))
        a = starts.astype(np.int64)
        b = a + t_du
        # overlapping slots form the contiguous range [lo, hi)
        lo = np.searchsorted(self._next_times(), a, side="right")
        hi = np.searchsorted(self.times, b, side="left")
        lo = np.minimum(lo, hi)                     # empty -> lo == hi
        # segmented OR over [lo, hi) via reduceat on interleaved offsets
        busy = np.zeros((P, self.W), dtype=np.uint64)
        nonempty = hi > lo
        if nonempty.any():
            idx = np.empty(2 * int(nonempty.sum()), dtype=np.int64)
            idx[0::2] = lo[nonempty]
            idx[1::2] = hi[nonempty]
            # reduceat segments alternate [lo:hi) and [hi:next_lo);
            # guard a trailing lo == n_slots (reduceat requires < n)
            seg = np.bitwise_or.reduceat(
                self.occ, np.minimum(idx, self.n_slots - 1), axis=0)
            busy[nonempty] = seg[0::2]
        free = ~busy & self._pe_mask                # [P, W]
        nxt = self._next_times()
        # ---- rectangle bounds --------------------------------------
        # hybrid (§Perf A2b): a one-shot dense [S,P,W] pass wins while
        # S*P is small (numpy call overhead dominates); the early-
        # terminating outward frontier wins asymptotically.
        if self.n_slots * P * self.W <= 262_144:
            blocking = np.any(
                (self.occ[:, None, :] & free[None, :, :]) != 0,
                axis=2)                             # [S, P]
            left = blocking & (nxt[:, None] <= a[None, :])
            tb = np.where(left, nxt[:, None],
                          np.int64(-T_INF)).max(axis=0)
            t_begin = np.minimum(np.maximum(tb, t_now), a)
            right = blocking & (self.times[:, None] >= b[None, :])
            t_end = np.where(right, self.times[:, None],
                             np.int64(T_INF)).min(axis=0)
            return free, t_begin, t_end
        t_begin = np.full(P, np.int64(t_now))
        t_end = np.full(P, np.int64(T_INF))
        # left: first blocking slot at lo-1, lo-2, ... (usually 1 step)
        pos = lo.copy() - 1
        act = np.arange(P)[pos >= 0]
        while act.size:
            p = pos[act]
            blocked = np.any(self.occ[p] & free[act], axis=1)
            hit = act[blocked]
            t_begin[hit] = nxt[pos[hit]]
            act = act[~blocked]
            pos[act] -= 1
            act = act[pos[act] >= 0]
        t_begin = np.minimum(np.maximum(t_begin, t_now), a)
        # right: first blocking slot at hi, hi+1, ...
        pos = hi.copy()
        act = np.arange(P)[pos < self.n_slots]
        while act.size:
            p = pos[act]
            blocked = np.any(self.occ[p] & free[act], axis=1)
            hit = act[blocked]
            t_end[hit] = self.times[pos[hit]]
            act = act[~blocked]
            pos[act] += 1
            act = act[pos[act] < self.n_slots]
        return free, t_begin, t_end

    def _rectangles(self, starts: np.ndarray, t_du: int,
                    t_now: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorised rectangles ``(n_free, t_begin, t_end)``."""
        free, t_begin, t_end = self._rect_core(starts, t_du, t_now)
        return popcount(free), t_begin, t_end

    def find_allocation(
        self,
        req: ARRequest,
        policy: Policy,
        t_now: Optional[int] = None,
    ) -> Optional[Allocation]:
        t_now = req.t_a if t_now is None else t_now
        starts = self.candidate_starts(req)
        n_free, t_begin, t_end = self._rectangles(starts, req.t_du, t_now)
        feas = n_free >= req.n_pe
        if not feas.any():
            return None
        primary = np.where(
            feas, _policy_primary(policy, n_free, t_begin, t_end),
            np.inf)
        tiebreak = np.where(feas, starts, T_INF)
        order = np.lexsort((tiebreak, primary))
        best = int(order[0])
        rect = Rectangle(t_s=int(starts[best]), t_begin=int(t_begin[best]),
                         t_end=int(t_end[best]), n_free=int(n_free[best]))
        busy = self.window_busy(rect.t_s, rect.t_s + req.t_du)
        free = ~busy & self._pe_mask
        chosen = lowest_bits(free, req.n_pe)
        return Allocation(
            t_s=rect.t_s,
            t_e=rect.t_s + req.t_du,
            pe_ids=ids_from_mask(chosen),
            rectangle=rect,
        )

    # ------------------------------------------------------------------
    # introspection (tests compare against the literal oracle)
    # ------------------------------------------------------------------
    def records(self) -> List[Tuple[int, frozenset]]:
        return [(int(t), frozenset(ids_from_mask(row)))
                for t, row in zip(self.times, self.occ)]


class MultiHostScheduler(HostScheduler):
    """Host mirror of the multi-resource timeline (DESIGN.md §11).

    The bit space is the device's *global* bit space — ``rspec
    .total_words * 32`` bits with plane ``r`` on the contiguous range
    starting at ``rspec.bit_offset(r)`` — so host unit ids equal the
    ids :func:`repro.core.batch.mask32_to_ids` decodes from device
    masks, and records compare verbatim in the differential suites.
    ``live_units`` shrinks planes for heterogeneous machine lanes;
    bits outside a plane's live range never join ``_pe_mask`` and so
    are never counted or allocated.

    Feasibility is the vector test: every plane's free count must
    cover its demand.  Policy scoring stays on the primary-plane
    (PE) count, exactly like the device path.
    """

    def __init__(self, rspec, live_units=None,
                 candidate_chunk: int = 128):
        super().__init__(rspec.total_bits,
                         candidate_chunk=candidate_chunk)
        self.rspec = rspec
        valid = rspec.valid_bits_np(live_units)
        self._pe_mask = mask_from_ids(
            np.nonzero(valid)[0], rspec.total_bits)
        self._plane_masks = []
        for r in range(rspec.R):
            o = rspec.bit_offset(r)
            w = rspec.words_per[r] * 32
            ids = o + np.nonzero(valid[o:o + w])[0]
            self._plane_masks.append(
                mask_from_ids(ids, rspec.total_bits))

    def _demand_vec(self, req: ARRequest) -> Tuple[int, ...]:
        tail = self.rspec.demand_tail(
            getattr(req, "demand", None), req.n_pe)
        return (int(req.n_pe),) + tail

    def find_allocation(
        self,
        req: ARRequest,
        policy: Policy,
        t_now: Optional[int] = None,
    ) -> Optional[Allocation]:
        t_now = req.t_a if t_now is None else t_now
        demand = self._demand_vec(req)
        starts = self.candidate_starts(req)
        free, t_begin, t_end = self._rect_core(
            starts, req.t_du, t_now)
        plane_free = np.stack(
            [popcount(free & pm) for pm in self._plane_masks],
            axis=1)                                     # [P, R]
        n_free = plane_free[:, 0]
        feas = np.all(
            plane_free >= np.asarray(demand, np.int64)[None, :],
            axis=1)
        if not feas.any():
            return None
        primary = np.where(
            feas, _policy_primary(policy, n_free, t_begin, t_end),
            np.inf)
        tiebreak = np.where(feas, starts, T_INF)
        order = np.lexsort((tiebreak, primary))
        best = int(order[0])
        rect = Rectangle(
            t_s=int(starts[best]), t_begin=int(t_begin[best]),
            t_end=int(t_end[best]), n_free=int(n_free[best]))
        busy = self.window_busy(rect.t_s, rect.t_s + req.t_du)
        free_w = ~busy & self._pe_mask
        # lowest free units per plane, like the device winning mask
        chosen = np.zeros_like(free_w)
        for r, pm in enumerate(self._plane_masks):
            chosen |= lowest_bits(free_w & pm, demand[r])
        return Allocation(
            t_s=rect.t_s,
            t_e=rect.t_s + req.t_du,
            pe_ids=ids_from_mask(chosen),
            rectangle=rect,
        )


class BackfillOracle:
    """Host event-loop oracle for the backfilling admission modes.

    Used only by tests (DESIGN.md §6): a literal Python re-statement of
    the device pipeline — promote due parked reservations, release due
    completions, EASY retry sweep, search, commit-or-park, EASY
    displacement transaction — over a :class:`HostScheduler` timeline.
    The differential suites assert the device ``admit_stream`` is
    bit-identical to :meth:`admit` called per request, and the
    ``moves`` log carries every reservation move for the safety-
    invariant property tests (conservative never moves anything; EASY
    never delays the head of queue or a committed start).
    """

    def __init__(self, n_pe: int, policy: Policy, mode,
                 park_capacity: int = 8):
        self.sched = HostScheduler(n_pe)
        self.n_pe = n_pe
        self.policy = policy
        self.mode = BackfillMode(mode)
        self.Q = park_capacity
        self.parked: List[dict] = []      # ordered by _order_key
        # heap (t_e, heap_seq, t_s, ids, tenant); tenant -1 = anonymous
        self.completions: List[tuple] = []
        self._next_seq = 0
        self._heap_seq = 0
        self.n_parked = self.n_promoted = self.n_moved = 0
        self.retry_flag = False   # armed by cancel, consumed per admit
        # (seq, old_t_s, new_t_s, was_head, event) per reservation move
        self.moves: List[tuple] = []

    # -- tenancy hooks (DESIGN.md §10) ---------------------------------
    # The base oracle is single-tenant: FCFS order, anonymous owners,
    # no accounting.  TenantOracle overrides exactly these four hooks;
    # everything else (promote / release / retry / displace / commit)
    # stays shared, so the two oracles differ only where the device
    # paths differ.
    def _order_key(self, entry: dict, t_now: int) -> tuple:
        """Queue-sweep priority of a parked entry (ascending)."""
        return (entry["seq"],)

    def _tenant_of(self, req: ARRequest) -> int:
        return -1

    def _on_release(self, tenant: int) -> None:
        """A held reservation left the machine (release or cancel)."""

    def _on_reap(self, tenant: int) -> None:
        """A held reservation was reaped overdue."""

    # -- helpers -------------------------------------------------------
    def _heap_push(self, t_s: int, t_e: int, ids,
                   tenant: int = -1) -> None:
        heapq.heappush(self.completions,
                       (t_e, self._heap_seq, t_s, tuple(ids), tenant))
        self._heap_seq += 1

    def _promote_due(self, t_now: int) -> None:
        self.parked.sort(key=lambda p: self._order_key(p, t_now))
        still = []
        for p in self.parked:
            if p["t_s"] <= t_now:
                self._heap_push(p["t_s"], p["t_e"], p["pe_ids"],
                                p.get("tenant", -1))
                self.n_promoted += 1
            else:
                still.append(p)
        self.parked = still

    def _release_due(self, t_now: int) -> None:
        while self.completions and self.completions[0][0] <= t_now:
            t_e, _, t_s, ids, tenant = heapq.heappop(self.completions)
            self.sched.delete_allocation(t_s, t_e, list(ids))
            self._on_release(tenant)

    def _replacement(self, entry: dict, t_now: int,
                     policy: Policy) -> Optional[Allocation]:
        """The clamped-window re-placement search of a parked entry."""
        req = ARRequest(
            t_a=t_now, t_r=max(entry["t_r"], t_now),
            t_du=entry["t_e"] - entry["t_s"], t_dl=entry["t_dl"],
            n_pe=entry["n_pe"], demand=entry.get("demand"))
        return self.sched.find_allocation(req, policy, t_now=t_now)

    def _retry_parked(self, t_now: int) -> None:
        """EASY retry-on-release sweep: pull reservations earlier
        (never later), in ``_order_key`` order (FCFS, or weighted
        fair-share on the tenant oracle); runs once after a cancel
        armed the latch (only a cancel frees *future* capacity)."""
        for p in sorted(self.parked,
                        key=lambda q: self._order_key(q, t_now)):
            self.sched.delete_allocation(p["t_s"], p["t_e"],
                                         list(p["pe_ids"]))
            alloc = self._replacement(p, t_now, Policy.FF)
            if alloc is not None and alloc.t_s < p["t_s"]:
                self.moves.append((p["seq"], p["t_s"], alloc.t_s,
                                   self._is_head(p, t_now), "retry"))
                p["t_s"], p["t_e"] = alloc.t_s, alloc.t_e
                p["pe_ids"] = alloc.pe_ids
                self.n_moved += 1
            self.sched.add_allocation(p["t_s"], p["t_e"],
                                      list(p["pe_ids"]))

    def _is_head(self, entry: dict, t_now: int) -> bool:
        if not self.parked:
            return False
        head = min(self.parked,
                   key=lambda p: self._order_key(p, t_now))
        return entry["seq"] == head["seq"]

    def _commit_or_park(self, req: ARRequest, t_s: int, t_e: int,
                        pe_ids) -> bool:
        """Book an accepted reservation; returns whether it parked."""
        parks = (self.mode != BackfillMode.NONE
                 and t_s > req.t_r and len(self.parked) < self.Q)
        if parks:
            self.parked.append(dict(
                seq=self._next_seq, t_s=t_s, t_e=t_e, t_r=req.t_r,
                t_dl=req.t_dl, n_pe=req.n_pe, pe_ids=tuple(pe_ids),
                tenant=self._tenant_of(req), t_a=req.t_a,
                demand=req.demand))
            self._next_seq += 1
            self.n_parked += 1
        else:
            self._heap_push(t_s, t_e, pe_ids, self._tenant_of(req))
        return parks

    def _displace(self, req: ARRequest) -> Optional[Allocation]:
        """The EASY transaction: move non-head reservations for req."""
        snap = (self.sched.times.copy(), self.sched.occ.copy(),
                [dict(p) for p in self.parked])
        head_seq = min(self.parked,
                       key=lambda p: self._order_key(p, req.t_a))["seq"]
        nonhead = sorted((p for p in self.parked
                          if p["seq"] != head_seq),
                         key=lambda p: self._order_key(p, req.t_a))
        for p in nonhead:
            self.sched.delete_allocation(p["t_s"], p["t_e"],
                                         list(p["pe_ids"]))
        alloc = self.sched.find_allocation(req, self.policy,
                                           t_now=req.t_a)
        moves = []
        ok = alloc is not None
        if ok:
            self.sched.add_allocation(alloc.t_s, alloc.t_e,
                                      list(alloc.pe_ids))
            for p in nonhead:
                re = self._replacement(p, req.t_a, Policy.FF)
                if re is None:
                    ok = False
                    break
                if re.t_s != p["t_s"]:
                    moves.append((p["seq"], p["t_s"], re.t_s, False,
                                  "displace"))
                p["t_s"], p["t_e"] = re.t_s, re.t_e
                p["pe_ids"] = re.pe_ids
                self.sched.add_allocation(re.t_s, re.t_e,
                                          list(re.pe_ids))
        if not ok:
            self.sched.times, self.sched.occ, self.parked = \
                snap[0], snap[1], snap[2]
            return None
        self.moves.extend(moves)
        self.n_moved += len(moves)
        return alloc

    # -- one admission step (mirrors the device _admit_impl) ----------
    def admit(self, req: ARRequest) -> Tuple[bool, int, bool]:
        """Decide one arrival; returns ``(accepted, t_s, parked)``."""
        t_now = req.t_a
        self._promote_due(t_now)
        self._release_due(t_now)
        if self.mode == BackfillMode.EASY and self.parked \
                and self.retry_flag:
            self._retry_parked(t_now)
        self.retry_flag = False
        alloc = self.sched.find_allocation(req, self.policy,
                                           t_now=t_now)
        if alloc is None and self.mode == BackfillMode.EASY \
                and len(self.parked) >= 2:
            # a lone head cannot be displaced around: the transaction
            # would re-run the identical failed search (device parity)
            alloc = self._displace(req)
            if alloc is None:
                return False, -1, False
            parked = self._commit_or_park(req, alloc.t_s, alloc.t_e,
                                          alloc.pe_ids)
            return True, alloc.t_s, parked
        if alloc is None:
            return False, -1, False
        self.sched.add_allocation(alloc.t_s, alloc.t_e,
                                  list(alloc.pe_ids))
        parked = self._commit_or_park(req, alloc.t_s, alloc.t_e,
                                      alloc.pe_ids)
        return True, alloc.t_s, parked

    def run(self, jobs) -> List[Tuple[bool, int]]:
        """Admit an arrival-ordered stream; per-job (accepted, t_s)."""
        return [self.admit(r)[:2] for r in jobs]

    def tick(self, t_now: int) -> None:
        """Advance time only: promote and release everything due."""
        self._promote_due(t_now)
        self._release_due(t_now)

    def cancel(self, t_s: int, t_e: int, pe_ids) -> bool:
        """Withdraw a parked or committed reservation; arms the
        EASY retry-on-release sweep (mirrors ``cancel_step``)."""
        key = (t_s, t_e, tuple(pe_ids))
        for p in self.parked:
            if (p["t_s"], p["t_e"], tuple(p["pe_ids"])) == key:
                self.parked.remove(p)
                self._on_release(p.get("tenant", -1))
                break
        else:
            match = [c for c in self.completions
                     if (c[2], c[0], c[3]) == key]
            if not match:
                return False
            self.completions.remove(match[0])
            heapq.heapify(self.completions)
            self._on_release(match[0][4])
        self.sched.delete_allocation(t_s, t_e, list(pe_ids))
        self.retry_flag = True
        return True

    def pending(self) -> List[dict]:
        """FCFS deferral-queue view, same layout as the device
        :func:`repro.core.batch.parked_entries`."""
        out = []
        for p in sorted(self.parked, key=lambda q: q["seq"]):
            d = dict(seq=p["seq"], t_s=p["t_s"], t_e=p["t_e"],
                     t_r=p["t_r"], t_dl=p["t_dl"], n_pe=p["n_pe"],
                     pe_ids=tuple(p["pe_ids"]))
            if p.get("demand") is not None:
                d["demand"] = tuple(p["demand"])
            out.append(d)
        return out

    def records(self):
        return self.sched.records()


class MultiResourceOracle(BackfillOracle):
    """Differential mirror of the multi-resource device admit path.

    :class:`BackfillOracle` with its timeline swapped for a
    :class:`MultiHostScheduler` — every shared sweep (promote /
    release / retry / displace / commit-or-park) already threads the
    request's ``demand`` vector through the parked entries, so the
    vector feasibility test is the only behavioural difference.
    ``live_units`` mirrors a heterogeneous machine lane.
    """

    def __init__(self, rspec, policy: Policy, mode,
                 park_capacity: int = 8, live_units=None):
        super().__init__(rspec.n_pe, policy, mode, park_capacity)
        self.rspec = rspec
        self.sched = MultiHostScheduler(rspec, live_units=live_units)


class TenantOracle(BackfillOracle):
    """Differential mirror of the multi-tenant device admit path.

    Wraps :class:`BackfillOracle` with the same
    :class:`repro.tenancy.HostTenantAccounts` arithmetic the device
    tenancy gate uses (identical f32 operation order, so the mirrored
    counters are bit-exact): the quota gate runs *after* queue work and
    *before* the placement search, the parked-queue sweeps order by the
    weighted fair-share key instead of FCFS, and ``reap`` deletes
    overdue completions past ``t_e + grace`` charging the owner.
    """

    def __init__(self, n_pe: int, policy: Policy, mode, spec,
                 park_capacity: int = 8):
        super().__init__(n_pe, policy, mode, park_capacity)
        from repro.tenancy import HostTenantAccounts
        self.spec = spec
        self.accounts = HostTenantAccounts(spec)
        self.grace = spec.grace
        self.n_reaped = 0

    # -- hook overrides ------------------------------------------------
    def _order_key(self, entry: dict, t_now: int) -> tuple:
        # device fair_key: weight[tid] * f32(t_now - park_ta), max-key
        # min-seq — negate for the host's ascending sorts.
        tid = self.accounts.clip_tid(entry.get("tenant", 0))
        wait = np.float32(np.int32(t_now) - np.int32(entry["t_a"]))
        return (-(self.accounts.weight[tid] * wait), entry["seq"])

    def _tenant_of(self, req: ARRequest) -> int:
        return int(req.tenant)

    def _on_release(self, tenant: int) -> None:
        self.accounts.release(tenant)

    def _on_reap(self, tenant: int) -> None:
        self.accounts.reap(tenant)

    # -- gated admission ----------------------------------------------
    def admit(self, req: ARRequest) -> Tuple[bool, int, bool]:
        t_now = req.t_a
        # Queue work precedes the gate (device: gate is computed after
        # _promote_due/_release_due/_retry_parked, before the search).
        # super().admit() re-runs these sweeps at the same t_now: both
        # are no-ops then (nothing new is due, retry latch consumed).
        self._promote_due(t_now)
        self._release_due(t_now)
        if self.mode == BackfillMode.EASY and self.parked \
                and self.retry_flag:
            self._retry_parked(t_now)
        self.retry_flag = False
        # occupancy sampled post-queue-work, like the device occ_ewma
        occ_frac = (np.float32(popcount(self.sched._busy_row_at(t_now)))
                    / np.float32(self.n_pe))
        tid = self.accounts.clip_tid(self._tenant_of(req))
        if not self.accounts.allowed(tid, req.n_pe, req.t_du):
            self.accounts.record(tid, accepted=False, blocked=True,
                                 parked=False, occ_frac=occ_frac)
            return False, -1, False
        accepted, t_s, parked = super().admit(req)
        self.accounts.record(
            tid, accepted=accepted, blocked=False, parked=parked,
            occ_frac=occ_frac,
            t_e=(t_s + req.t_du) if accepted else -1,
            t_r=req.t_r, t_du=req.t_du, n_pe=req.n_pe)
        return accepted, t_s, parked

    def reap(self, t_now: int) -> int:
        """Delete reservations overdue past ``t_e + grace``; mirrors
        :func:`repro.core.batch.reap_step` (no promotion first)."""
        if self.grace is None:
            return 0
        cutoff = t_now - self.grace
        reaped = 0
        while self.completions and self.completions[0][0] <= cutoff:
            t_e, _, t_s, ids, tenant = heapq.heappop(self.completions)
            self.sched.delete_allocation(t_s, t_e, list(ids))
            self._on_reap(tenant)
            reaped += 1
        self.n_reaped += reaped
        return reaped


class FleetRoutingOracle:
    """Sequential probe-commit mirror of the partitioned fleet ingress.

    Used only by tests (DESIGN.md §9): ``E`` independent
    :class:`HostScheduler` lanes admitting one request at a time — the
    literal pre-batching host loop that
    :meth:`repro.runtime.fleet.PartitionedCore.admit_stream_allocations`
    replaced.  The device matcher must reproduce this decision
    sequence bit-exactly for every routing:

    ``best_acceptance``
        probe every lane, take the earliest feasible start (ties to
        the lowest lane), commit, repeat.
    ``least_loaded``
        route the whole batch greedily by committed + planned
        PE-seconds (planned area accumulates on a scratch copy, as the
        device routing scan does), then probe/commit each request on
        its routed lane only.
    ``round_robin``
        a striding cursor, probe/commit on the routed lane only.
    """

    def __init__(self, n_chips: int, n_partitions: int):
        if n_partitions < 1 or n_chips % n_partitions:
            raise ValueError(
                f"n_chips={n_chips} not divisible into "
                f"{n_partitions} partitions")
        self.chips_per_part = n_chips // n_partitions
        self.lanes = [HostScheduler(self.chips_per_part)
                      for _ in range(n_partitions)]
        self.load = np.zeros(n_partitions, np.float32)
        self._rr = 0

    def _commit(self, lane: int, alloc: Allocation) -> Allocation:
        self.lanes[lane].add_allocation(
            alloc.t_s, alloc.t_e, list(alloc.pe_ids))
        self.load[lane] += np.float32(
            (alloc.t_e - alloc.t_s) * len(alloc.pe_ids))
        off = lane * self.chips_per_part
        return Allocation(
            t_s=alloc.t_s, t_e=alloc.t_e,
            pe_ids=tuple(p + off for p in alloc.pe_ids),
            rectangle=alloc.rectangle)

    def _admit_best(self, req: ARRequest,
                    policy: Policy) -> Optional[Allocation]:
        best_lane, best = -1, None
        for e, sched in enumerate(self.lanes):
            a = sched.find_allocation(req, policy)
            if a is not None and (best is None or a.t_s < best.t_s):
                best_lane, best = e, a
        if best is None:
            return None
        return self._commit(best_lane, best)

    def admit_batch(self, requests: Sequence[ARRequest],
                    policy: Policy,
                    routing: str = "best_acceptance"
                    ) -> List[Optional[Allocation]]:
        if routing == "best_acceptance":
            return [self._admit_best(r, policy) for r in requests]
        E = len(self.lanes)
        if routing == "round_robin":
            lanes = [(self._rr + i) % E
                     for i in range(len(requests))]
            self._rr = (self._rr + len(requests)) % E
        elif routing == "least_loaded":
            scratch = self.load.copy()
            lanes = []
            for r in requests:
                lane = int(np.argmin(scratch))
                scratch[lane] += np.float32(r.n_pe) * np.float32(r.t_du)
                lanes.append(lane)
        else:
            raise ValueError(f"unknown routing {routing!r}")
        out: List[Optional[Allocation]] = []
        for r, lane in zip(requests, lanes):
            a = self.lanes[lane].find_allocation(r, policy)
            out.append(self._commit(lane, a) if a is not None else None)
        return out

    def records(self) -> List[Tuple[int, frozenset]]:
        """Merged (time, busy-global-chip-set) view across lanes."""
        rows = []
        for e, sched in enumerate(self.lanes):
            off = e * self.chips_per_part
            rows.append([(t, frozenset(p + off for p in b))
                         for t, b in sched.records()])
        bounds = sorted({t for lane in rows for t, _ in lane})
        out, prev = [], frozenset()
        for t in bounds:
            busy = set()
            for lane in rows:
                cur = frozenset()
                for rt, rb in lane:
                    if rt <= t:
                        cur = rb
                    else:
                        break
                busy |= cur
            busy = frozenset(busy)
            if busy != prev:
                out.append((t, busy))
                prev = busy
        return out
