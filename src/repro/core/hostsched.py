"""Fast numpy bitmask engine for the paper's data structure.

Same semantics as :mod:`repro.core.listsched` (the literal oracle) but
PE sets are uint64 bitmask rows and every operation is vectorised numpy.
This engine drives the 10^4-job discrete-event simulations of Section 6
at interactive speed; it is also the host-side fallback of the device
engine.

Representation
--------------
``times  : int64[S]``   sorted slot boundaries
``occ    : uint64[S,W]`` busy-PE bitmask during ``[times[i], times[i+1])``
with all PEs free before ``times[0]`` and from ``times[-1]`` on (the
last row is always all-zero, mirroring the paper's ``{t, null}``
terminator record).
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import (
    Allocation,
    ARRequest,
    Policy,
    Rectangle,
    T_INF,
    policy_score,
)

_WORD = 64


def n_words(n_pe: int) -> int:
    return (n_pe + _WORD - 1) // _WORD


def mask_from_ids(ids: Iterable[int], n_pe: int) -> np.ndarray:
    m = np.zeros(n_words(n_pe), dtype=np.uint64)
    arr = np.fromiter(ids, dtype=np.int64) if not isinstance(
        ids, np.ndarray) else ids.astype(np.int64)
    if arr.size == 0:
        return m
    if arr.min() < 0 or arr.max() >= n_pe:
        raise ValueError("PE id out of range")
    np.bitwise_or.at(m, arr // _WORD,
                     np.uint64(1) << (arr % _WORD).astype(np.uint64))
    return m


def ids_from_mask(mask: np.ndarray) -> Tuple[int, ...]:
    bits = np.unpackbits(mask.view(np.uint8), bitorder="little")
    return tuple(np.nonzero(bits)[0].tolist())


def popcount(mask: np.ndarray) -> np.ndarray:
    """Population count, summed over the trailing word axis."""
    return np.bitwise_count(mask).sum(axis=-1).astype(np.int64)


def lowest_bits(mask: np.ndarray, k: int) -> np.ndarray:
    """Mask of the ``k`` lowest set bits of ``mask`` (1-D word array)."""
    out = np.zeros_like(mask)
    remaining = k
    for w in range(mask.shape[0]):
        word = int(mask[w])
        take = 0
        while word and remaining:
            b = word & -word
            take |= b
            word ^= b
            remaining -= 1
        out[w] = np.uint64(take)
        if not remaining:
            break
    if remaining:
        raise ValueError(f"asked for {k} bits, mask has too few")
    return out


class HostScheduler:
    """Vectorised availability timeline + the three paper operations."""

    def __init__(self, n_pe: int, candidate_chunk: int = 128):
        self.n_pe = n_pe
        self.W = n_words(n_pe)
        self._chunk = candidate_chunk
        self.times = np.zeros(0, dtype=np.int64)
        self.occ = np.zeros((0, self.W), dtype=np.uint64)
        # bits >= n_pe never participate; keep a validity mask for safety
        self._pe_mask = mask_from_ids(range(n_pe), n_pe)

    # ------------------------------------------------------------------
    # state helpers
    # ------------------------------------------------------------------
    @property
    def n_slots(self) -> int:
        return int(self.times.shape[0])

    def _next_times(self) -> np.ndarray:
        if self.n_slots == 0:
            return np.zeros(0, dtype=np.int64)
        return np.concatenate([self.times[1:], [T_INF]])

    def _busy_row_at(self, t: int) -> np.ndarray:
        i = int(np.searchsorted(self.times, t, side="right")) - 1
        if i < 0 or i >= self.n_slots:
            return np.zeros(self.W, dtype=np.uint64)
        return self.occ[i].copy()

    def _insert_boundaries(self, t_s: int, t_e: int) -> None:
        """Insert both boundary records with one reallocation."""
        new_t, new_rows = [], []
        for t in (t_s, t_e):
            i = int(np.searchsorted(self.times, t, side="left"))
            if not (i < self.n_slots and self.times[i] == t):
                new_t.append(t)
                new_rows.append(self._busy_row_at(t))
        if not new_t:
            return
        idx = np.searchsorted(self.times, new_t, side="left")
        self.times = np.insert(self.times, idx, new_t)
        self.occ = np.insert(self.occ, idx, np.array(new_rows), axis=0)

    def _insert_boundary(self, t: int) -> None:
        self._insert_boundaries(t, t)

    def _clean(self) -> None:
        n = self.n_slots
        if n == 0:
            return
        keep = np.empty(n, dtype=bool)
        keep[0] = bool(self.occ[0].any())
        if n > 1:
            np.any(self.occ[1:] != self.occ[:-1], axis=1,
                   out=keep[1:])
        if not keep.all():
            self.times = self.times[keep]
            self.occ = self.occ[keep]

    # ------------------------------------------------------------------
    # Algorithms 1 and 2
    # ------------------------------------------------------------------
    def add_allocation(self, t_s: int, t_e: int,
                       pes: Sequence[int] | np.ndarray) -> None:
        mask = pes if isinstance(pes, np.ndarray) \
            else mask_from_ids(pes, self.n_pe)
        if t_s >= t_e:
            raise ValueError("empty interval")
        self._insert_boundaries(t_s, t_e)
        lo = int(np.searchsorted(self.times, t_s, side="left"))
        hi = int(np.searchsorted(self.times, t_e, side="left"))
        if np.any(self.occ[lo:hi] & mask):
            raise ValueError("double booking")
        self.occ[lo:hi] |= mask
        self._clean()

    def delete_allocation(self, t_s: int, t_e: int,
                          pes: Sequence[int] | np.ndarray) -> None:
        mask = pes if isinstance(pes, np.ndarray) \
            else mask_from_ids(pes, self.n_pe)
        self._insert_boundaries(t_s, t_e)
        lo = int(np.searchsorted(self.times, t_s, side="left"))
        hi = int(np.searchsorted(self.times, t_e, side="left"))
        if np.any((self.occ[lo:hi] & mask) != mask):
            raise ValueError("deleting PEs that were not reserved")
        self.occ[lo:hi] &= ~mask
        self._clean()

    # ------------------------------------------------------------------
    # Algorithm 3 — fully vectorised over candidate start times
    # ------------------------------------------------------------------
    def window_busy(self, a: int, b: int) -> np.ndarray:
        if self.n_slots == 0:
            return np.zeros(self.W, dtype=np.uint64)
        ov = (self.times < b) & (self._next_times() > a)
        if not ov.any():
            return np.zeros(self.W, dtype=np.uint64)
        return np.bitwise_or.reduce(self.occ[ov], axis=0)

    def candidate_starts(self, req: ARRequest) -> np.ndarray:
        lo, hi = req.t_r, req.t_dl - req.t_du
        cands = [np.array([lo, hi], dtype=np.int64)]
        if self.n_slots:
            t = self.times
            cands.append(t[(t >= lo) & (t <= hi)])
            shifted = t - req.t_du
            cands.append(shifted[(shifted >= lo) & (shifted <= hi)])
        return np.unique(np.concatenate(cands))

    def _rectangles(self, starts: np.ndarray, t_du: int,
                    t_now: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorised rectangle computation for all candidate starts.

        §Perf iteration A2 (EXPERIMENTS.md): windows over a *sorted*
        timeline cover contiguous slot ranges ``[lo_c, hi_c)``, so the
        busy union is a segmented OR (``np.bitwise_or.reduceat``) —
        O(S·W) total instead of the former O(S·C·W) masked reduction —
        and the rectangle bounds expand outward with an early-
        terminating frontier (geometric expected step count), instead
        of testing every (slot, candidate) pair.
        """
        P = starts.shape[0]
        if self.n_slots == 0:
            return (np.full(P, self.n_pe, np.int64),
                    np.minimum(t_now, starts.astype(np.int64)),
                    np.full(P, T_INF, np.int64))
        a = starts.astype(np.int64)
        b = a + t_du
        # overlapping slots form the contiguous range [lo, hi)
        lo = np.searchsorted(self._next_times(), a, side="right")
        hi = np.searchsorted(self.times, b, side="left")
        lo = np.minimum(lo, hi)                     # empty -> lo == hi
        # segmented OR over [lo, hi) via reduceat on interleaved offsets
        busy = np.zeros((P, self.W), dtype=np.uint64)
        nonempty = hi > lo
        if nonempty.any():
            idx = np.empty(2 * int(nonempty.sum()), dtype=np.int64)
            idx[0::2] = lo[nonempty]
            idx[1::2] = hi[nonempty]
            # reduceat segments alternate [lo:hi) and [hi:next_lo);
            # guard a trailing lo == n_slots (reduceat requires < n)
            seg = np.bitwise_or.reduceat(
                self.occ, np.minimum(idx, self.n_slots - 1), axis=0)
            busy[nonempty] = seg[0::2]
        free = ~busy & self._pe_mask                # [P, W]
        n_free = popcount(free)
        nxt = self._next_times()
        # ---- rectangle bounds --------------------------------------
        # hybrid (§Perf A2b): a one-shot dense [S,P,W] pass wins while
        # S*P is small (numpy call overhead dominates); the early-
        # terminating outward frontier wins asymptotically.
        if self.n_slots * P * self.W <= 262_144:
            blocking = np.any(
                (self.occ[:, None, :] & free[None, :, :]) != 0,
                axis=2)                             # [S, P]
            left = blocking & (nxt[:, None] <= a[None, :])
            tb = np.where(left, nxt[:, None],
                          np.int64(-T_INF)).max(axis=0)
            t_begin = np.minimum(np.maximum(tb, t_now), a)
            right = blocking & (self.times[:, None] >= b[None, :])
            t_end = np.where(right, self.times[:, None],
                             np.int64(T_INF)).min(axis=0)
            return n_free, t_begin, t_end
        t_begin = np.full(P, np.int64(t_now))
        t_end = np.full(P, np.int64(T_INF))
        # left: first blocking slot at lo-1, lo-2, ... (usually 1 step)
        pos = lo.copy() - 1
        act = np.arange(P)[pos >= 0]
        while act.size:
            p = pos[act]
            blocked = np.any(self.occ[p] & free[act], axis=1)
            hit = act[blocked]
            t_begin[hit] = nxt[pos[hit]]
            act = act[~blocked]
            pos[act] -= 1
            act = act[pos[act] >= 0]
        t_begin = np.minimum(np.maximum(t_begin, t_now), a)
        # right: first blocking slot at hi, hi+1, ...
        pos = hi.copy()
        act = np.arange(P)[pos < self.n_slots]
        while act.size:
            p = pos[act]
            blocked = np.any(self.occ[p] & free[act], axis=1)
            hit = act[blocked]
            t_end[hit] = self.times[pos[hit]]
            act = act[~blocked]
            pos[act] += 1
            act = act[pos[act] < self.n_slots]
        return n_free, t_begin, t_end

    def find_allocation(
        self,
        req: ARRequest,
        policy: Policy,
        t_now: Optional[int] = None,
    ) -> Optional[Allocation]:
        t_now = req.t_a if t_now is None else t_now
        starts = self.candidate_starts(req)
        n_free, t_begin, t_end = self._rectangles(starts, req.t_du, t_now)
        feas = n_free >= req.n_pe
        if not feas.any():
            return None
        # Lexicographic (primary, t_s) minimisation, identical to
        # types.policy_score but vectorised.
        dur = (t_end - t_begin).astype(np.float64)
        nf = n_free.astype(np.float64)
        if policy == Policy.FF:
            primary = np.zeros_like(nf)
        elif policy == Policy.PE_B:
            primary = nf
        elif policy == Policy.PE_W:
            primary = -nf
        elif policy == Policy.DU_B:
            primary = dur
        elif policy == Policy.DU_W:
            primary = -dur
        elif policy == Policy.PEDU_B:
            primary = nf * dur
        elif policy == Policy.PEDU_W:
            primary = -nf * dur
        else:  # pragma: no cover
            raise ValueError(policy)
        primary = np.where(feas, primary, np.inf)
        tiebreak = np.where(feas, starts, T_INF)
        order = np.lexsort((tiebreak, primary))
        best = int(order[0])
        rect = Rectangle(t_s=int(starts[best]), t_begin=int(t_begin[best]),
                         t_end=int(t_end[best]), n_free=int(n_free[best]))
        busy = self.window_busy(rect.t_s, rect.t_s + req.t_du)
        free = ~busy & self._pe_mask
        chosen = lowest_bits(free, req.n_pe)
        return Allocation(
            t_s=rect.t_s,
            t_e=rect.t_s + req.t_du,
            pe_ids=ids_from_mask(chosen),
            rectangle=rect,
        )

    # ------------------------------------------------------------------
    # introspection (tests compare against the literal oracle)
    # ------------------------------------------------------------------
    def records(self) -> List[Tuple[int, frozenset]]:
        return [(int(t), frozenset(ids_from_mask(row)))
                for t, row in zip(self.times, self.occ)]
