"""Ensemble axis: E independent scheduler timelines in one dispatch.

The functional core (:mod:`repro.core.batch`) is pure, so a whole
*ensemble* of schedulers — E independent timelines, pending buffers and
overflow flags — is just a :class:`~repro.core.timeline.SchedulerState`
pytree with a leading axis, stepped in lockstep by ``jax.vmap``
(DESIGN.md §4).  One jitted dispatch then advances every lane: the
Section-6 sweep grid (`sim/sweep.py`) runs policies × loads × seeds ×
flexibilities as lanes of one vmapped scan, and the partitioned fleet
(`runtime/fleet.py`) runs its cluster partitions the same way.

Because the lanes share one stacked buffer, they share static shapes:
capacity growth is collective.  The auto wrapper reads the per-lane
high-water marks after an overflowing run and grows *once* to the max
needed capacity across the ensemble, then re-runs deterministically
from the pre-run snapshot — same protocol as the single-lane wrappers,
sized by the worst lane.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch as batch_lib
from repro.core import search as search_lib
from repro.core import timeline as tl_lib
from repro.core.batch import Decision, RequestBatch
from repro.core.policies import policy_index
from repro.core.timeline import SchedulerState
from repro.core.types import T_INF


def init_ensemble(n_ensemble: int, capacity: int, n_pe: int,
                  pending_capacity: int = 256,
                  park_capacity: int = 0,
                  tenants=None, rspec=None,
                  machine_units=None,
                  index_tile=None) -> SchedulerState:
    """E fresh all-free lanes as one stacked state pytree.

    ``tenants`` is an optional single-lane
    :class:`~repro.tenancy.TenantTable` broadcast to every lane (pass a
    pre-stacked table via :func:`stack_states` of per-lane
    ``init_state`` calls for heterogeneous lanes instead).

    ``rspec`` installs a shared multi-resource layout (DESIGN.md §11);
    ``machine_units`` — one live-unit tuple per lane — then shrinks
    each lane's valid mask for heterogeneous machine sizes, all lanes
    keeping the same padded word shape.

    ``index_tile`` attaches the hierarchical availability index
    (DESIGN.md §12) to every lane; the summary leaves broadcast and
    shard like any other timeline leaf.
    """
    one = tl_lib.init_state(capacity, n_pe, pending_capacity,
                            park_capacity, tenants=tenants,
                            rspec=rspec, index_tile=index_tile)
    out = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n_ensemble,) + x.shape), one)
    if machine_units is not None:
        if rspec is None:
            raise ValueError("machine_units requires rspec")
        if len(machine_units) != n_ensemble:
            raise ValueError(
                f"{len(machine_units)} machine_units entries for "
                f"{n_ensemble} lanes")
        out = out._replace(lane_valid=jnp.stack(
            [jnp.asarray(rspec.valid_mask_np(mu))
             for mu in machine_units]))
    return out


def stack_states(states: Sequence[SchedulerState]) -> SchedulerState:
    """Stack equally-shaped single-lane states along a new leading axis."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *states)


def member(states: SchedulerState, i: int) -> SchedulerState:
    """Extract lane ``i`` as a single-lane state."""
    return jax.tree_util.tree_map(lambda x: x[i], states)


def set_member(states: SchedulerState, i: int,
               lane: SchedulerState) -> SchedulerState:
    """Write a single-lane state back into lane ``i``."""
    return jax.tree_util.tree_map(
        lambda full, one: full.at[i].set(one), states, lane)


def ensemble_size(states: SchedulerState) -> int:
    return states.pend_te.shape[0]


def lane_capacity(states: SchedulerState) -> Tuple[int, int]:
    """(timeline capacity, pending capacity) of each lane."""
    return states.tl.times.shape[-1], states.pend_te.shape[-1]


def policy_ids(policies) -> jax.Array:
    """int32[E] policy ids from policies / ids (one per lane)."""
    return jnp.asarray(
        [p if isinstance(p, (int, np.integer)) else policy_index(p)
         for p in policies], jnp.int32)


def backfill_ids(modes, n_ensemble: int) -> jax.Array:
    """int32[E] backfill-mode ids from one mode or one per lane."""
    from repro.core.types import backfill_index

    if modes is None:
        return jnp.zeros((n_ensemble,), jnp.int32)
    if isinstance(modes, jax.Array):
        return modes
    if isinstance(modes, (str, int, np.integer)) or not hasattr(
            modes, "__len__"):
        return jnp.full((n_ensemble,), backfill_index(modes),
                        jnp.int32)
    return jnp.asarray([backfill_index(m) for m in modes], jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("n_pe", "auto_release", "use_kernel"))
def admit_ensemble(states: SchedulerState, reqs: RequestBatch,
                   pids: jax.Array, bids: jax.Array = None, *,
                   n_pe: int, auto_release: bool = True,
                   use_kernel: bool = False
                   ) -> Tuple[SchedulerState, Decision]:
    """One fused admission step on every lane (`vmap` of ``admit``).

    ``reqs`` carries one request per lane (leading axis E); ``pids``
    is ``int32[E]`` so every lane can run a different policy without
    recompilation, and ``bids`` (``int32[E]``, optional) a per-lane
    backfill mode the same way.
    """
    if bids is None:
        bids = jnp.zeros_like(pids)

    def one(s, r, p, b):
        return batch_lib.admit(s, r, p, b, n_pe=n_pe,
                               auto_release=auto_release,
                               use_kernel=use_kernel)

    return jax.vmap(one)(states, reqs, pids, bids)


@functools.partial(
    jax.jit, static_argnames=("n_pe", "auto_release", "use_kernel"))
def admit_stream_ensemble(states: SchedulerState, batches: RequestBatch,
                          pids: jax.Array, bids: jax.Array = None, *,
                          n_pe: int, auto_release: bool = True,
                          use_kernel: bool = False
                          ) -> Tuple[SchedulerState, Decision]:
    """Scan a per-lane request stream through every lane in lockstep.

    ``batches`` fields are ``int32[E, N]`` (per-lane arrival-ordered
    streams, padded to a common length with never-feasible requests —
    see :func:`repro.core.batch.pad_streams`).  Returns the stacked
    states and ``[E, N]`` decisions of ``vmap(admit_stream)``.
    ``bids`` optionally runs a different backfill mode per lane (the
    Section-6 policy × backfill grid is one such dispatch).
    """
    if bids is None:
        bids = jnp.zeros_like(pids)

    def one(s, b, p, bf):
        return batch_lib.admit_stream(s, b, p, bf, n_pe=n_pe,
                                      auto_release=auto_release,
                                      use_kernel=use_kernel)

    return jax.vmap(one)(states, batches, pids, bids)


@functools.partial(
    jax.jit, static_argnames=("n_pe", "auto_release", "use_kernel"),
    donate_argnums=(0,))
def admit_stream_ensemble_donated(
        states: SchedulerState, batches: RequestBatch,
        pids: jax.Array, bids: jax.Array = None, *,
        n_pe: int, auto_release: bool = True,
        use_kernel: bool = False
        ) -> Tuple[SchedulerState, Decision]:
    """:func:`admit_stream_ensemble` with donated state buffers.

    The ensemble counterpart of
    :func:`repro.core.batch.admit_stream_donated`: XLA reuses the
    stacked state buffers for the output (allocation-free steady
    state, sharding preserved), and overflow rolls the *whole
    ensemble* back to the pre-call state inside the dispatch —
    matching the collective grow-once protocol, which re-runs every
    lane from the pre-run snapshot anyway.  The rollback latch is
    sticky across calls (any lane latched -> the call is
    state-preserving), so chunked offers can pipeline with a single
    deferred overflow read (DESIGN.md §8).
    """
    if bids is None:
        bids = jnp.zeros_like(pids)

    def one(s, b, p, bf):
        return batch_lib.admit_stream(s, b, p, bf, n_pe=n_pe,
                                      auto_release=auto_release,
                                      use_kernel=use_kernel)

    out, dec = jax.vmap(one)(states, batches, pids, bids)
    ovf = states.overflow | out.overflow
    rolled = batch_lib._where_tree(jnp.any(ovf), states, out)
    rolled = rolled._replace(
        overflow=ovf,
        hw_records=jnp.maximum(states.hw_records, out.hw_records),
        hw_pending=jnp.maximum(states.hw_pending, out.hw_pending))
    return rolled, dec


@functools.partial(jax.jit, static_argnames=("n_pe", "use_kernel"))
def find_allocation_ensemble(states: SchedulerState, req: RequestBatch,
                             pid: jax.Array, *, n_pe: int,
                             use_kernel: bool = False
                             ) -> search_lib.SearchResult:
    """Probe one request against every lane's timeline (no commit).

    The request and policy are shared (unbatched); only the state is
    vmapped — this is the fleet's best-acceptance routing probe.
    """

    def one(s):
        return search_lib.search(
            s.tl, req.t_r, req.t_du, req.t_dl, req.n_pe, pid, req.t_a,
            n_pe=n_pe, use_kernel=use_kernel, rspec=s.rspec,
            demand_tail=req.demand, valid_mask=s.lane_valid)

    return jax.vmap(one)(states)


@functools.partial(jax.jit, static_argnames=("n_pe", "use_kernel"))
def find_allocations_ensemble(states: SchedulerState,
                              reqs: RequestBatch, pid: jax.Array,
                              *, n_pe: int, use_kernel: bool = False
                              ) -> search_lib.SearchResult:
    """Probe N requests against every lane's timeline (no commit).

    The request-batched fleet ingress probe (DESIGN.md §9): an outer
    vmap over the ``[N]``-leaved request batch of the per-lane search
    vmap, so one dispatch yields a :class:`SearchResult` with
    ``[N, E]`` leaves — row i is request i's feasibility / start /
    score on every partition, all evaluated against the *same*
    pre-batch state.  Each row uses its own request's ``t_a`` as
    "now", matching a sequential probe at arrival time.
    """

    def one_req(r):
        def one_lane(s):
            return search_lib.search(
                s.tl, r.t_r, r.t_du, r.t_dl, r.n_pe, pid, r.t_a,
                n_pe=n_pe, use_kernel=use_kernel, rspec=s.rspec,
                demand_tail=r.demand, valid_mask=s.lane_valid)

        return jax.vmap(one_lane)(states)

    return jax.vmap(one_req)(reqs)


@functools.partial(
    jax.jit, static_argnames=("n_pe", "auto_release", "use_kernel"))
def match_stream_ensemble(states: SchedulerState, reqs: RequestBatch,
                          pid: jax.Array, bids: jax.Array = None, *,
                          n_pe: int, auto_release: bool = False,
                          use_kernel: bool = False
                          ) -> Tuple[SchedulerState, jax.Array,
                                     batch_lib.Decision]:
    """Fused sequential best-acceptance matching: one scan, N requests.

    The device mirror of the host probe-commit loop (DESIGN.md §9):
    a ``lax.scan`` over the arrival-ordered ``[N]`` request batch
    where each step probes every lane
    (:func:`find_allocation_ensemble`'s body), picks the earliest
    feasible start (ties to the lowest lane, as ``np.argmin``) and
    admits on that lane only — the other lanes admit a never-feasible
    filler carrying the same arrival time, so with
    ``auto_release=True`` every lane's release/backfill clock still
    advances per arrival.  Decisions are bit-identical to N sequential
    ``find_allocation`` + commit round-trips, at zero host syncs.

    Returns ``(states, lanes, decisions)``: ``lanes`` is ``int32[N]``
    with the committed lane per request (``-1`` rejected), and
    ``decisions`` the per-request :class:`~repro.core.batch.Decision`
    from the chosen lane.  Overflow follows the watermark protocol —
    on any latched lane, re-run from the pre-call snapshot after
    growing (:func:`match_stream_ensemble_auto`).
    """
    E = ensemble_size(states)
    if bids is None:
        bids = jnp.zeros((E,), jnp.int32)
    pids = jnp.broadcast_to(jnp.asarray(pid, jnp.int32), (E,))
    lane_ids = jnp.arange(E, dtype=jnp.int32)

    def step(ss, r):
        def probe(s):
            return search_lib.search(
                s.tl, r.t_r, r.t_du, r.t_dl, r.n_pe, pid, r.t_a,
                n_pe=n_pe, use_kernel=use_kernel, rspec=s.rspec,
                demand_tail=r.demand, valid_mask=s.lane_valid)

        res = jax.vmap(probe)(ss)
        tv = jnp.where(res.found & ~ss.overflow, res.t_s, T_INF)
        lane = jnp.argmin(tv).astype(jnp.int32)
        feasible = jnp.min(tv) < T_INF
        sel = (lane_ids == lane) & feasible
        per = batch_lib.RequestBatch(
            t_a=jnp.broadcast_to(r.t_a, (E,)),
            t_r=jnp.where(sel, r.t_r, r.t_a),
            t_du=jnp.where(sel, r.t_du, jnp.int32(1)),
            t_dl=jnp.where(sel, r.t_dl, r.t_a + 1),
            n_pe=jnp.where(sel, r.n_pe, jnp.int32(n_pe + 1)),
            demand=(None if r.demand is None else
                    jnp.broadcast_to(r.demand, (E,) + r.demand.shape)))

        def one(s, q, p, b):
            return batch_lib._admit_impl(
                s, q, p, b, n_pe=n_pe, auto_release=auto_release,
                use_kernel=use_kernel)

        ss, dec = jax.vmap(one)(ss, per, pids, bids)
        mine = jax.tree_util.tree_map(lambda x: x[lane], dec)
        out_lane = jnp.where(mine.accepted & feasible, lane,
                             jnp.int32(-1))
        return ss, (out_lane, mine)

    states, (lanes, decs) = jax.lax.scan(step, states, reqs)
    return states, lanes, decs


def match_stream_ensemble_auto(
    states: SchedulerState, reqs: RequestBatch, pid, *,
    n_pe: int, backfills=None, auto_release: bool = False,
    use_kernel: bool = False,
    max_growths: int = batch_lib.MAX_DOUBLINGS,
) -> Tuple[SchedulerState, jax.Array, batch_lib.Decision]:
    """:func:`match_stream_ensemble` with collective overflow growth.

    Same grow-once-and-re-run protocol as
    :func:`admit_stream_ensemble_auto`: an overflowing run is
    discarded, every lane grows to the worst high-water mark, and the
    whole scan re-runs from the pre-call snapshot — lanes that did not
    overflow reproduce their decisions exactly.
    """
    if not isinstance(pid, jax.Array):
        pid = jnp.int32(pid if isinstance(pid, (int, np.integer))
                        else policy_index(pid))
    bids = backfill_ids(backfills, ensemble_size(states))
    start = states
    for attempt in range(max_growths + 1):
        out, lanes, decs = match_stream_ensemble(
            start, reqs, pid, bids, n_pe=n_pe,
            auto_release=auto_release, use_kernel=use_kernel)
        if not bool(jnp.any(out.overflow)):
            return out, lanes, decs
        if attempt < max_growths:
            need_r = int(jnp.max(out.hw_records))
            need_p = int(jnp.max(out.hw_pending))
            probe = member(start, 0)
            new_cap, new_pend = batch_lib.grown_capacities(
                probe, need_r, need_p)
            start = grow_ensemble(start, new_cap, new_pend)
    cap, pend = lane_capacity(start)
    raise batch_lib.GrowthError(
        f"match_stream_ensemble still overflowing after "
        f"{max_growths + 1} attempts (last tried capacity "
        f"{cap}, pending {pend})")


def grow_ensemble(states: SchedulerState, new_capacity: int,
                  new_pending_capacity: int) -> SchedulerState:
    """Collective capacity growth of every lane (shared static shape)."""
    return jax.vmap(lambda s: tl_lib.grow_state(
        s, new_capacity=new_capacity,
        new_pending_capacity=new_pending_capacity))(states)


release_due_ensemble = jax.jit(
    jax.vmap(batch_lib.release_due, in_axes=(0, None)))


reap_step_ensemble = jax.jit(
    jax.vmap(batch_lib.reap_step, in_axes=(0, None, 0)))


def reap_until_ensemble(states: SchedulerState, t_now: int,
                        grace, *,
                        max_growths: int = batch_lib.MAX_DOUBLINGS
                        ) -> SchedulerState:
    """Per-lane overdue-reservation reaping with collective growth.

    The ensemble counterpart of :func:`repro.core.batch.reap_until`
    (DESIGN.md §10): every lane batch-deletes reservations whose end
    passed more than ``grace`` ago (one shared grace or one per lane;
    ``T_INF`` disables a lane), charging usage back to the owning
    tenants, under the same worst-lane grow-once protocol as
    :func:`release_until_ensemble`.
    """
    g = jnp.broadcast_to(jnp.asarray(grace, jnp.int32),
                         (ensemble_size(states),))
    start = states
    for attempt in range(max_growths + 1):
        out = reap_step_ensemble(start, jnp.int32(t_now), g)
        if not bool(jnp.any(out.overflow)):
            return out
        if attempt < max_growths:
            new_cap, new_pend = batch_lib.grown_capacities(
                member(start, 0), int(jnp.max(out.hw_records)),
                int(jnp.max(out.hw_pending)))
            start = grow_ensemble(start, new_cap, new_pend)
    cap, pend = lane_capacity(start)
    raise RuntimeError(
        f"reap_until_ensemble still overflowing after "
        f"{max_growths + 1} attempts (last tried capacity "
        f"{cap}, pending {pend})")


def release_until_ensemble(states: SchedulerState, t_now: int, *,
                           max_growths: int = batch_lib.MAX_DOUBLINGS
                           ) -> SchedulerState:
    """Per-lane release-due advancement with collective growth.

    The ensemble session's ``tick(t)``: every lane deletes its pending
    reservations ending by ``t_now`` in one vmapped dispatch; a lane
    overflow (a deletion splitting a merged record) grows all lanes
    once to the worst watermark and re-runs from the pre-tick snapshot.
    ``max_growths=0`` raises on the first overflow instead.
    """
    start = states
    for attempt in range(max_growths + 1):
        out = release_due_ensemble(start, jnp.int32(t_now))
        if not bool(jnp.any(out.overflow)):
            return out
        if attempt < max_growths:
            new_cap, new_pend = batch_lib.grown_capacities(
                member(start, 0), int(jnp.max(out.hw_records)),
                int(jnp.max(out.hw_pending)))
            start = grow_ensemble(start, new_cap, new_pend)
    cap, pend = lane_capacity(start)
    raise RuntimeError(
        f"release_until_ensemble still overflowing after "
        f"{max_growths + 1} attempts (last tried capacity "
        f"{cap}, pending {pend})")


def grow_rollback_ensemble(states: SchedulerState) -> SchedulerState:
    """Grow a rolled-back (latched) ensemble and clear every latch.

    The collective counterpart of
    :func:`repro.core.batch.grow_rollback`: a donated overflow
    returned the pre-run stacked state carrying the failed run's
    per-lane watermarks, so it is its own growth reference — grow all
    lanes once to the worst watermark.
    """
    new_cap, new_pend = batch_lib.grown_capacities(
        member(states, 0), int(jnp.max(states.hw_records)),
        int(jnp.max(states.hw_pending)))
    out = grow_ensemble(states, new_cap, new_pend)
    return out._replace(overflow=jnp.zeros_like(out.overflow))


def admit_stream_ensemble_auto(
    states: SchedulerState, batches: RequestBatch, policies, *,
    n_pe: int, backfills=None, auto_release: bool = True,
    use_kernel: bool = False,
    max_growths: int = batch_lib.MAX_DOUBLINGS,
    donate: bool = False,
) -> Tuple[SchedulerState, Decision]:
    """Run :func:`admit_stream_ensemble`, growing on any lane overflow.

    On overflow the ensemble grows *once* to the max needed capacity
    across all lanes (their high-water marks) and the whole grid
    re-runs from the pre-run snapshot; lanes that did not overflow
    reproduce their decisions exactly (padding never changes
    decisions), so the result equals E independent auto runs.
    ``max_growths=0`` raises on the first overflow instead (before any
    state mutation).

    ``donate=True`` dispatches
    :func:`admit_stream_ensemble_donated`: the caller's stacked state
    is consumed (growth re-materializes from the in-dispatch rollback
    via :func:`grow_rollback_ensemble`; a terminal overflow raises
    :class:`~repro.core.batch.GrowthError` carrying the rolled-back
    state).  Decisions are bit-identical to the non-donated path.
    """
    pids = policies if isinstance(policies, jax.Array) \
        else policy_ids(policies)
    bids = backfill_ids(backfills, pids.shape[0])
    fn = admit_stream_ensemble_donated if donate \
        else admit_stream_ensemble
    start = states
    for attempt in range(max_growths + 1):
        out, dec = fn(
            start, batches, pids, bids, n_pe=n_pe,
            auto_release=auto_release, use_kernel=use_kernel)
        if not bool(jnp.any(out.overflow)):
            return out, dec
        if attempt < max_growths:
            if donate:
                start = grow_rollback_ensemble(out)
            else:
                need_r = int(jnp.max(out.hw_records))
                need_p = int(jnp.max(out.hw_pending))
                probe = member(start, 0)
                new_cap, new_pend = batch_lib.grown_capacities(
                    probe, need_r, need_p)
                start = grow_ensemble(start, new_cap, new_pend)
    cap, pend = lane_capacity(out if donate else start)
    raise batch_lib.GrowthError(
        f"admit_stream_ensemble still overflowing after "
        f"{max_growths + 1} attempts (last tried capacity "
        f"{cap}, pending {pend})",
        state=out if donate else None)
