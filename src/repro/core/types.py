"""Core value types for advance-reservation scheduling.

The paper characterises an AR request by the 5-tuple
``(t_a, t_r, t_du, t_dl, n_pe)`` (Section 3).  All times are integer
seconds; using integers keeps the timeline arithmetic exact on both the
host engines and the int32 device engine.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

# Sentinel for "+infinity" on the int32 device path.  Host paths use the
# same value so that all three engines agree bit-for-bit.
T_INF: int = 2**31 - 1


class Policy(str, enum.Enum):
    """The seven scheduling policies of Section 5."""

    FF = "FF"          # First Fit: earliest feasible start time
    PE_B = "PE_B"      # PE Best Fit: min free PEs in the rectangle
    PE_W = "PE_W"      # PE Worst Fit: max free PEs in the rectangle
    DU_B = "Du_B"      # Duration Best Fit: min rectangle duration
    DU_W = "Du_W"      # Duration Worst Fit: max rectangle duration
    PEDU_B = "PEDu_B"  # PE-Duration Best Fit: min PEs * duration
    PEDU_W = "PEDu_W"  # PE-Duration Worst Fit: max PEs * duration


ALL_POLICIES: Tuple[Policy, ...] = tuple(Policy)


class BackfillMode(str, enum.Enum):
    """Admission-order relaxation of the deferral queue (DESIGN.md §6).

    ``NONE`` is the paper's strict arrival-order admission: every
    accepted request commits its start immediately and immutably.
    Under the backfilling modes an accepted request whose chosen start
    is *delayed* past its ready time (``t_s > t_r``) parks in a bounded
    FCFS pending queue holding a reservation mark instead:

    ``CONSERVATIVE``
        every parked request holds an immovable reservation; later
        arrivals may only backfill into holes that delay nobody —
        decision-identical to ``NONE`` (the paper's admission *is*
        conservative backfilling), but the queue is observable and
        promotion/commit timing is explicit.
    ``EASY``
        only the head-of-queue reservation binds.  A retry sweep may
        pull parked reservations *earlier* (never later), and an
        otherwise-rejected arrival may displace non-head parked
        reservations inside their deadline windows (transactionally:
        it is admitted only if every displaced job still fits).
    """

    NONE = "none"
    EASY = "easy"
    CONSERVATIVE = "conservative"


BACKFILL_MODES: Tuple[BackfillMode, ...] = tuple(BackfillMode)
BACKFILL_IDS = {m: i for i, m in enumerate(BACKFILL_MODES)}


def backfill_index(mode) -> int:
    """Any mode spelling -> its traced int32 id (none=0/easy/cons)."""
    if isinstance(mode, str) and not isinstance(mode, BackfillMode):
        mode = BackfillMode(mode)
    if isinstance(mode, BackfillMode):
        return BACKFILL_IDS[mode]
    mode = int(mode)
    if not 0 <= mode < len(BACKFILL_MODES):
        raise ValueError(
            f"backfill id {mode} out of range; valid ids are "
            f"{dict((m.value, i) for m, i in BACKFILL_IDS.items())}")
    return mode


@dataclasses.dataclass(frozen=True)
class ARRequest:
    """An advance-reservation request (paper Section 3).

    Attributes:
      t_a:  arrival time of the request.
      t_r:  ready time (earliest start), ``t_r >= t_a``.
      t_du: duration on the current cluster.
      t_dl: deadline, ``t_dl >= t_r + t_du``.  Equality means an
            *immediate* deadline; inequality a *general* deadline.
      n_pe: number of processing elements required.
      tenant: owning tenant id for multi-tenant sessions (DESIGN.md
            §10); ignored (and harmless) when tenancy is off.
      demand: optional full per-resource demand vector for
            multi-resource sessions (DESIGN.md §11); ``demand[0]``
            must equal ``n_pe`` (validated against the session's
            :class:`~repro.core.resources.ResourceSpec` at offer
            time).  ``None`` means "PEs only".
    """

    t_a: int
    t_r: int
    t_du: int
    t_dl: int
    n_pe: int
    tenant: int = 0
    demand: Optional[Tuple[int, ...]] = None

    def __post_init__(self) -> None:
        if self.t_r < self.t_a:
            raise ValueError(f"t_r={self.t_r} < t_a={self.t_a}")
        if self.t_du <= 0:
            raise ValueError(f"t_du={self.t_du} must be positive")
        if self.t_dl < self.t_r + self.t_du:
            raise ValueError(
                f"infeasible request: t_dl={self.t_dl} < t_r+t_du="
                f"{self.t_r + self.t_du}")
        if self.n_pe <= 0:
            raise ValueError(f"n_pe={self.n_pe} must be positive")
        if self.tenant < 0:
            raise ValueError(f"tenant={self.tenant} must be >= 0")
        if self.demand is not None:
            d = tuple(int(x) for x in self.demand)
            if not d or d[0] != self.n_pe:
                raise ValueError(
                    f"demand[0] must equal n_pe={self.n_pe}: "
                    f"got {d}")
            if any(x < 0 for x in d):
                raise ValueError(f"demand must be >= 0: got {d}")
            object.__setattr__(self, "demand", d)

    @property
    def latest_start(self) -> int:
        return self.t_dl - self.t_du

    @property
    def slack(self) -> int:
        """Scheduling slack: how far the start may slip past ``t_r``."""
        return self.t_dl - self.t_du - self.t_r


@dataclasses.dataclass(frozen=True)
class Rectangle:
    """A maximum availability rectangle for one candidate start time.

    ``{t_s, T_begin, T_end, PE_free}`` of Algorithm 3: the widest time
    extent ``[t_begin, t_end)`` over which the ``n_free`` PEs that are
    free throughout the job window ``[t_s, t_s + t_du)`` stay free.
    """

    t_s: int
    t_begin: int
    t_end: int
    n_free: int

    @property
    def duration(self) -> int:
        return self.t_end - self.t_begin

    @property
    def area(self) -> int:
        return self.n_free * self.duration


@dataclasses.dataclass(frozen=True)
class Allocation:
    """A successful placement decision returned by ``findAllocation``."""

    t_s: int
    t_e: int
    pe_ids: Tuple[int, ...]          # identities of the allocated PEs
    rectangle: Optional[Rectangle] = None

    @property
    def n_pe(self) -> int:
        return len(self.pe_ids)


def policy_score(policy: Policy, rect: Rectangle) -> Tuple[float, int]:
    """Lexicographic minimisation key shared by every engine.

    All policies minimise ``(primary, t_s)`` — the earliest feasible
    start breaks ties (Section 5: "the earliest feasible start time will
    be chosen").  Worst-fit variants negate the primary term.
    """
    dur = float(rect.duration)
    if policy == Policy.FF:
        primary = 0.0                       # pure earliest-start
    elif policy == Policy.PE_B:
        primary = float(rect.n_free)
    elif policy == Policy.PE_W:
        primary = -float(rect.n_free)
    elif policy == Policy.DU_B:
        primary = dur
    elif policy == Policy.DU_W:
        primary = -dur
    elif policy == Policy.PEDU_B:
        primary = float(rect.n_free) * dur
    elif policy == Policy.PEDU_W:
        primary = -float(rect.n_free) * dur
    else:  # pragma: no cover - exhaustive enum
        raise ValueError(f"unknown policy {policy}")
    return (primary, rect.t_s)
