"""Engine facade: one API over the literal, host, and device engines.

The engine registry behind :class:`repro.api.ReservationService`: every
engine exposes the paper's three operations.  The device engine is a
thin stateful wrapper over the functional core: its whole state is one
:class:`~repro.core.timeline.SchedulerState` pytree and every mutation
goes through the pure jitted functions in :mod:`repro.core.batch` /
:mod:`repro.core.timeline`.  Capacity overflow follows the grow-once
high-water protocol (DESIGN.md §3): each overflowing run records the
record / pending-slot counts it *needed* (``hw_records`` /
``hw_pending``), and the host grows straight to the next power of two
covering that watermark (``grown_capacities``) before the
deterministic re-run — so callers never see a fixed limit and growth
is amortised O(1).  On top of the classic three operations the device
engine exposes the fused single-step ``admit`` and the scanned
``admit_stream`` batched path (DESIGN.md §3).

``make_scheduler(engine=...)`` and ``DeviceScheduler`` remain as
deprecated shims over the service API (sessions carry the same engines
plus the streaming verbs).
"""
from __future__ import annotations

import warnings
from typing import Optional, Sequence, Union

import numpy as np

import jax.numpy as jnp

from repro.core import batch as batch_lib
from repro.core import search as search_lib
from repro.core import timeline as tl_lib
from repro.core.hostsched import HostScheduler
from repro.core.listsched import ListScheduler
from repro.core.policies import policy_index
from repro.core.types import Allocation, ARRequest, Policy, T_INF


class DeviceEngine:
    """Device-resident scheduler with the HostScheduler interface."""

    def __init__(self, n_pe: int, capacity: int = 256,
                 use_kernel: bool = False, bucketing: bool = True,
                 pending_capacity: int = 256, park_capacity: int = 0,
                 tenants=None, rspec=None, live_units=None,
                 index_tile: Optional[int] = None):
        self.n_pe = n_pe
        self.use_kernel = use_kernel
        # §Perf iteration A3: the dense search costs O(P*S*n_pe) at the
        # *capacity* S; slicing to the smallest power-of-two bucket
        # covering the live records cuts the work ~quadratically when
        # the timeline is mostly empty (each bucket jit-compiles once).
        self.bucketing = bucketing
        # valid-record count for bucketing; None = stale (recomputed
        # lazily on the next search so the streaming hot path never
        # pays the device reduction)
        self._n_valid: Optional[int] = 0
        table = None
        if tenants is not None:
            from repro.tenancy import init_table
            table = init_table(tenants, pending_capacity, park_capacity)
        self.state = tl_lib.init_state(capacity, n_pe, pending_capacity,
                                       park_capacity, tenants=table,
                                       rspec=rspec,
                                       live_units=live_units,
                                       index_tile=index_tile)

    # -- helpers -------------------------------------------------------
    @property
    def tl(self) -> tl_lib.Timeline:
        return self.state.tl

    def _set_tl(self, new_tl: tl_lib.Timeline) -> None:
        self.state = self.state._replace(tl=new_tl)
        self._n_valid = None

    def _mask32(self, pes: Sequence[int]) -> jnp.ndarray:
        # on multi-resource states ids are *global* bit ids spanning
        # every plane, so the word-width bound applies; single-resource
        # states validate against the machine size
        limit = None if self.state.rspec is not None else self.n_pe
        return tl_lib.ids_to_mask32(pes, self.tl.words, n_pe=limit)

    def _update(self, t_s: int, t_e: int, pes, is_add: bool) -> None:
        mask = pes if not isinstance(pes, (list, tuple, set, range)) \
            else self._mask32(sorted(pes))
        new_tl, overflow, n_keep = tl_lib.update(
            self.tl, t_s, t_e, mask, is_add=is_add, with_count=True)
        if bool(overflow):
            # grow once to the needed record count (rare; amortised
            # O(1)) — the same watermark protocol as the batched path
            self.state = tl_lib.grow_state(
                self.state, new_capacity=max(
                    2 * self.tl.capacity,
                    tl_lib.next_pow2(int(n_keep))))
            new_tl, overflow = tl_lib.update(
                self.tl, t_s, t_e, mask, is_add=is_add)
            assert not bool(overflow)
        self._set_tl(new_tl)

    def _search_view(self) -> tl_lib.Timeline:
        """Smallest power-of-two prefix covering the valid records."""
        if not self.bucketing:
            return self.tl
        if self._n_valid is None:
            self._n_valid = int(self.tl.n_valid())
        k = 16
        while k < self._n_valid:
            k *= 2
        k = min(k, self.tl.capacity)
        ispec = self.tl.ispec
        if ispec is not None and k % ispec.tile == 0:
            # prefix tiles summarize the identical prefix rows, so the
            # sliced index is the exact index of the sliced timeline
            nt = k // ispec.tile
            return tl_lib.Timeline(
                times=self.tl.times[:k], occ=self.tl.occ[:k],
                idx_occ=self.tl.idx_occ[:nt],
                idx_minfree=self.tl.idx_minfree[:nt],
                idx_maxfree=self.tl.idx_maxfree[:nt],
                ispec=ispec)
        # tile larger than the bucket: search the bucket index-free
        # (conservative pruning means decisions are identical either
        # way; each bucket size compiles its own graph regardless)
        return tl_lib.Timeline(times=self.tl.times[:k],
                               occ=self.tl.occ[:k])

    # -- the three operations ------------------------------------------
    def add_allocation(self, t_s: int, t_e: int, pes) -> None:
        self._update(t_s, t_e, pes, is_add=True)

    def delete_allocation(self, t_s: int, t_e: int, pes) -> None:
        self._update(t_s, t_e, pes, is_add=False)

    def find_allocation(self, req: ARRequest, policy: Policy,
                        t_now: Optional[int] = None) -> Optional[Allocation]:
        t_now = req.t_a if t_now is None else t_now
        kw = {}
        spec = self.state.rspec
        if spec is not None:
            kw = dict(
                rspec=spec,
                demand_tail=jnp.asarray(
                    spec.demand_tail(req.demand, req.n_pe),
                    jnp.int32),
                valid_mask=self.state.lane_valid)
        res = search_lib.find_allocation(
            self._search_view(),
            jnp.int32(req.t_r), jnp.int32(req.t_du), jnp.int32(req.t_dl),
            jnp.int32(req.n_pe), jnp.int32(policy_index(policy)),
            jnp.int32(t_now), n_pe=self.n_pe, use_kernel=self.use_kernel,
            **kw)
        return batch_lib.search_result_to_allocation(res)

    # -- the fused batched path (DESIGN.md §3) -------------------------
    def admit(self, req: ARRequest, policy: Policy,
              auto_release: bool = True) -> Optional[Allocation]:
        """Fused find+commit in one device dispatch.

        With ``auto_release`` (default) the committed reservation joins
        the pending-release buffer and every earlier reservation ending
        by ``req.t_a`` is deleted first — do not mix this mode with
        manual ``delete_allocation`` of the same reservations.
        """
        self.state, alloc = batch_lib.admit_one(
            self.state, req, policy, n_pe=self.n_pe,
            auto_release=auto_release, use_kernel=self.use_kernel)
        self._n_valid = None
        return alloc

    def admit_stream(self,
                     requests: Union[batch_lib.RequestBatch,
                                     Sequence[ARRequest]],
                     policy: Policy,
                     auto_release: bool = True) -> batch_lib.Decision:
        """Admit a whole arrival-ordered stream with one ``lax.scan``.

        Returns the stacked per-request :class:`~repro.core.batch.Decision`
        (convert with ``batch.decisions_to_allocations`` for host use).
        Overflow mid-scan grows the state and re-runs deterministically.
        """
        if not isinstance(requests, batch_lib.RequestBatch):
            xd = (0 if self.state.rspec is None
                  else self.state.rspec.R - 1)
            requests = batch_lib.requests_to_batch(
                list(requests), extra_demand=xd)
        self.state, dec = batch_lib.admit_stream_grow(
            self.state, requests, policy, n_pe=self.n_pe,
            auto_release=auto_release, use_kernel=self.use_kernel)
        self._n_valid = None
        return dec

    def records(self):
        times = np.asarray(self.tl.times)
        occ = np.asarray(self.tl.occ)
        out = []
        for t, row in zip(times, occ):
            if t >= T_INF:
                continue
            out.append((int(t), frozenset(batch_lib.mask32_to_ids(row))))
        return out


ENGINES = {
    "list": ListScheduler,
    "host": HostScheduler,
    "device": DeviceEngine,
}


def _make_engine(n_pe: int, engine: str = "host", **kwargs):
    """Engine factory (no deprecation warning — internal use)."""
    try:
        cls = ENGINES[engine]
    except KeyError:
        raise ValueError(
            f"unknown engine {engine!r}; pick one of {sorted(ENGINES)}")
    return cls(n_pe, **kwargs)


# ---------------------------------------------------------------------------
# deprecated shims over the service API
# ---------------------------------------------------------------------------


class DeviceScheduler(DeviceEngine):
    """Deprecated alias of :class:`DeviceEngine`.

    .. deprecated:: PR 3
       Construct a :class:`repro.api.ReservationService` and open a
       session; ``Session`` exposes the same three operations plus the
       streaming verbs, and ``session.engine`` is the underlying
       :class:`DeviceEngine` where raw access is needed.
    """

    def __init__(self, *args, **kwargs):
        warnings.warn(
            "DeviceScheduler is deprecated: use repro.api."
            "ReservationService(ServiceConfig(n_pe=..., "
            "engine='device')).session() — the session has the same "
            "three operations plus offer/tick/cancel, and "
            "session.engine exposes the raw DeviceEngine",
            DeprecationWarning, stacklevel=2)
        super().__init__(*args, **kwargs)


def make_scheduler(n_pe: int, engine: str = "host", **kwargs):
    """Deprecated factory over the three interchangeable engines.

    .. deprecated:: PR 3
       Use :class:`repro.api.ReservationService`: ``ReservationService(
       ServiceConfig(n_pe=..., engine=...)).session().engine`` returns
       the identical engine object, and the session adds the streaming
       verbs (``offer`` / ``tick`` / ``cancel``).
    """
    warnings.warn(
        "make_scheduler is deprecated: use repro.api."
        "ReservationService(ServiceConfig(n_pe=..., engine=..., ...))"
        ".session() (session.engine is the raw engine object)",
        DeprecationWarning, stacklevel=2)
    from repro.api import ReservationService, ServiceConfig
    cfg = ServiceConfig.from_engine_kwargs(n_pe, engine, **kwargs)
    return ReservationService(cfg).session().engine
