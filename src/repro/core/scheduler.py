"""Engine facade: one API over the literal, host, and device engines.

``make_scheduler(engine=...)`` returns an object with the paper's three
operations.  The device engine keeps its state on the accelerator as a
:class:`~repro.core.timeline.Timeline` pytree and runs the jitted
search; capacity overflow triggers host-side growth (double and retry),
so callers never see a fixed limit.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core import search as search_lib
from repro.core import timeline as tl_lib
from repro.core.hostsched import HostScheduler, ids_from_mask, mask_from_ids
from repro.core.listsched import ListScheduler
from repro.core.policies import policy_index
from repro.core.types import Allocation, ARRequest, Policy, Rectangle, T_INF

import jax.numpy as jnp


class DeviceScheduler:
    """Device-resident scheduler with the HostScheduler interface."""

    def __init__(self, n_pe: int, capacity: int = 256,
                 use_kernel: bool = False, bucketing: bool = True):
        self.n_pe = n_pe
        self.use_kernel = use_kernel
        # §Perf iteration A3: the dense search costs O(P*S*n_pe) at the
        # *capacity* S; slicing to the smallest power-of-two bucket
        # covering the live records cuts the work ~quadratically when
        # the timeline is mostly empty (each bucket jit-compiles once).
        self.bucketing = bucketing
        self._n_valid = 0
        self.tl = tl_lib.empty(capacity, n_pe)

    # -- helpers -------------------------------------------------------
    def _mask32(self, pes: Sequence[int]) -> jnp.ndarray:
        W = self.tl.words
        bits = np.zeros(W * 32, dtype=np.uint32)
        for i in pes:
            bits[i] = 1
        return jnp.asarray(tl_lib.pack_bits(bits[None, :])[0])

    def _update(self, t_s: int, t_e: int, pes, is_add: bool) -> None:
        mask = pes if not isinstance(pes, (list, tuple, set, range)) \
            else self._mask32(sorted(pes))
        new_tl, overflow = tl_lib.update(
            self.tl, t_s, t_e, mask, is_add=is_add)
        if bool(overflow):
            # static-shape growth, then retry (rare; amortised O(1))
            self.tl = tl_lib.grow(self.tl, 2 * self.tl.capacity)
            new_tl, overflow = tl_lib.update(
                self.tl, t_s, t_e, mask, is_add=is_add)
            assert not bool(overflow)
        self.tl = new_tl
        self._n_valid = int(new_tl.n_valid())

    def _search_view(self) -> tl_lib.Timeline:
        """Smallest power-of-two prefix covering the valid records."""
        if not self.bucketing:
            return self.tl
        k = 16
        while k < self._n_valid:
            k *= 2
        k = min(k, self.tl.capacity)
        return tl_lib.Timeline(times=self.tl.times[:k],
                               occ=self.tl.occ[:k])

    # -- the three operations ------------------------------------------
    def add_allocation(self, t_s: int, t_e: int, pes) -> None:
        self._update(t_s, t_e, pes, is_add=True)

    def delete_allocation(self, t_s: int, t_e: int, pes) -> None:
        self._update(t_s, t_e, pes, is_add=False)

    def find_allocation(self, req: ARRequest, policy: Policy,
                        t_now: Optional[int] = None) -> Optional[Allocation]:
        t_now = req.t_a if t_now is None else t_now
        res = search_lib.find_allocation(
            self._search_view(),
            jnp.int32(req.t_r), jnp.int32(req.t_du), jnp.int32(req.t_dl),
            jnp.int32(req.n_pe), jnp.int32(policy_index(policy)),
            jnp.int32(t_now), n_pe=self.n_pe, use_kernel=self.use_kernel)
        if not bool(res.found):
            return None
        mask32 = np.asarray(res.pe_mask)
        # repack uint32 words into uint64 for id extraction
        W64 = (mask32.shape[0] + 1) // 2
        m64 = np.zeros(W64, dtype=np.uint64)
        for w in range(mask32.shape[0]):
            m64[w // 2] |= np.uint64(mask32[w]) << np.uint64(32 * (w % 2))
        return Allocation(
            t_s=int(res.t_s), t_e=int(res.t_e),
            pe_ids=ids_from_mask(m64),
            rectangle=Rectangle(
                t_s=int(res.t_s), t_begin=int(res.t_begin),
                t_end=int(res.t_end), n_free=int(res.n_free)),
        )

    def records(self):
        times = np.asarray(self.tl.times)
        occ = np.asarray(self.tl.occ)
        out = []
        for t, row in zip(times, occ):
            if t >= T_INF:
                continue
            ids = []
            for w, word in enumerate(row):
                word = int(word)
                while word:
                    b = word & -word
                    ids.append(w * 32 + b.bit_length() - 1)
                    word ^= b
            out.append((int(t), frozenset(ids)))
        return out


ENGINES = {
    "list": ListScheduler,
    "host": HostScheduler,
    "device": DeviceScheduler,
}


def make_scheduler(n_pe: int, engine: str = "host", **kwargs):
    """Factory over the three interchangeable engines."""
    try:
        cls = ENGINES[engine]
    except KeyError:
        raise ValueError(
            f"unknown engine {engine!r}; pick one of {sorted(ENGINES)}")
    return cls(n_pe, **kwargs)
