"""Literal reference implementation of the paper's data structure.

This module follows Section 4 of the paper as directly as possible: the
availability of the cluster is a sorted list of ``{time, busy-PE-set}``
records (``AvailRectList``); the busy set of record ``i`` holds during
``[time_i, time_{i+1})``; before the first record and from the last
record onwards every PE is free (the last record always carries an empty
set).  Sets are real Python ``set`` objects and every operation walks the
list exactly the way the paper's Algorithms 1-3 describe.

It is deliberately *unoptimised*: it exists as the semantic oracle that
the fast numpy host engine (`hostsched.py`) and the JAX/Pallas device
engine (`timeline.py` / `search.py` / `kernels/availscan.py`) are tested
against.
"""
from __future__ import annotations

import bisect
from typing import List, Optional, Set, Tuple

from repro.core.types import (
    Allocation,
    ARRequest,
    Policy,
    Rectangle,
    T_INF,
    policy_score,
)


class ListScheduler:
    """The paper's ``AvailRectList`` with the three basic operations."""

    def __init__(self, n_pe: int):
        if n_pe <= 0:
            raise ValueError("n_pe must be positive")
        self.n_pe = n_pe
        self._all_pes: Set[int] = set(range(n_pe))
        # Parallel sorted arrays: times[i] is the instant at which the
        # busy set changes to busy[i].
        self.times: List[int] = []
        self.busy: List[Set[int]] = []

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    def _busy_at(self, t: int) -> Set[int]:
        """Busy set in effect at instant ``t`` (empty outside records)."""
        i = bisect.bisect_right(self.times, t) - 1
        if i < 0 or i >= len(self.times):
            return set()
        return set(self.busy[i])

    def _insert_boundary(self, t: int) -> None:
        """Ensure a record exists exactly at ``t`` (inheriting state)."""
        i = bisect.bisect_left(self.times, t)
        if i < len(self.times) and self.times[i] == t:
            return
        inherited = self._busy_at(t)
        self.times.insert(i, t)
        self.busy.insert(i, inherited)

    def _clean(self) -> None:
        """Merge redundant records (paper: 'clean possible redundant
        records').  A record is redundant when its busy set equals the
        previous record's busy set; a leading record with an empty busy
        set is redundant as well (everything is free before the first
        record anyway)."""
        out_t: List[int] = []
        out_b: List[Set[int]] = []
        prev: Set[int] = set()
        for t, b in zip(self.times, self.busy):
            if b == prev:
                continue
            out_t.append(t)
            out_b.append(b)
            prev = b
        self.times, self.busy = out_t, out_b

    # ------------------------------------------------------------------
    # Algorithm 1 / Algorithm 2
    # ------------------------------------------------------------------
    def add_allocation(self, t_s: int, t_e: int, pes: Set[int]) -> None:
        if not t_s < t_e:
            raise ValueError("empty interval")
        if not pes <= self._all_pes:
            raise ValueError("unknown PE id")
        self._insert_boundary(t_s)
        self._insert_boundary(t_e)
        lo = bisect.bisect_left(self.times, t_s)
        hi = bisect.bisect_left(self.times, t_e)
        for i in range(lo, hi):
            if self.busy[i] & pes:
                raise ValueError(
                    f"double booking of PEs {self.busy[i] & pes} in "
                    f"[{self.times[i]}, ...)")
            self.busy[i] = self.busy[i] | pes
        self._clean()

    def delete_allocation(self, t_s: int, t_e: int, pes: Set[int]) -> None:
        self._insert_boundary(t_s)
        self._insert_boundary(t_e)
        lo = bisect.bisect_left(self.times, t_s)
        hi = bisect.bisect_left(self.times, t_e)
        for i in range(lo, hi):
            if not pes <= self.busy[i]:
                raise ValueError("deleting PEs that were not reserved")
            self.busy[i] = self.busy[i] - pes
        self._clean()

    # ------------------------------------------------------------------
    # Algorithm 3
    # ------------------------------------------------------------------
    def window_busy(self, a: int, b: int) -> Set[int]:
        """Union of busy sets over all records intersecting ``[a, b)``."""
        acc: Set[int] = set()
        n = len(self.times)
        for i in range(n):
            start = self.times[i]
            end = self.times[i + 1] if i + 1 < n else T_INF
            if start < b and end > a:
                acc |= self.busy[i]
        return acc

    def candidate_starts(self, req: ARRequest) -> List[int]:
        """Feasible-start candidates: the ready time, the latest start,
        every existing slot boundary in range, and every boundary shifted
        left by the duration (end-aligned placements).  Matches the
        paper's Section 4.2 example (candidates t2, t3, t6, t7)."""
        lo, hi = req.t_r, req.t_dl - req.t_du
        cands = {lo, hi}
        for t in self.times:
            if lo <= t <= hi:
                cands.add(t)
            if lo <= t - req.t_du <= hi:
                cands.add(t - req.t_du)
        return sorted(cands)

    def rectangle(self, t_s: int, t_du: int, t_now: int) -> Rectangle:
        """Maximum availability rectangle for the window
        ``[t_s, t_s + t_du)`` (paper Algorithm 3 line 7)."""
        a, b = t_s, t_s + t_du
        busy_union = self.window_busy(a, b)
        free = self._all_pes - busy_union
        t_begin, t_end = t_now, T_INF
        n = len(self.times)
        for i in range(n):
            start = self.times[i]
            end = self.times[i + 1] if i + 1 < n else T_INF
            if not (self.busy[i] & free):
                continue  # not blocking: its busy PEs are all outside F
            if end <= a and end > t_begin:
                t_begin = end
            if start >= b and start < t_end:
                t_end = start
        t_begin = min(t_begin, a)
        return Rectangle(t_s=t_s, t_begin=t_begin, t_end=t_end,
                         n_free=len(free))

    def find_allocation(
        self,
        req: ARRequest,
        policy: Policy,
        t_now: Optional[int] = None,
    ) -> Optional[Allocation]:
        t_now = req.t_a if t_now is None else t_now
        feasible: List[Rectangle] = []
        for t_s in self.candidate_starts(req):
            rect = self.rectangle(t_s, req.t_du, t_now)
            if rect.n_free >= req.n_pe:
                feasible.append(rect)
        if not feasible:
            return None
        best = min(feasible, key=lambda r: policy_score(policy, r))
        busy_union = self.window_busy(best.t_s, best.t_s + req.t_du)
        free = sorted(self._all_pes - busy_union)
        return Allocation(
            t_s=best.t_s,
            t_e=best.t_s + req.t_du,
            pe_ids=tuple(free[: req.n_pe]),
            rectangle=best,
        )

    # ------------------------------------------------------------------
    # introspection used by tests
    # ------------------------------------------------------------------
    def records(self) -> List[Tuple[int, frozenset]]:
        return [(t, frozenset(b)) for t, b in zip(self.times, self.busy)]

    def busy_count_at(self, t: int) -> int:
        return len(self._busy_at(t))
