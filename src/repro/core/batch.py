"""Fused device-resident admission: ``(state, request) -> (state, decision)``.

The per-request engine pays a host round-trip per job: ``find_allocation``
syncs ``found``/the PE mask back to Python, which then issues ``update``
as a second dispatch.  This module makes the scheduler core functional
(DESIGN.md §3): :class:`~repro.core.timeline.SchedulerState` carries the
dense timeline plus a pending-release buffer of committed reservations,
:func:`admit` is one pure jitted step that fuses ``deleteAllocation`` of
due completions, ``findAllocation`` (Algorithm 3) and ``addAllocation``,
and :func:`admit_stream` scans a struct-of-arrays request batch through
that step with ``jax.lax.scan`` — whole experiments admit on-device.

Capacity overflow (timeline records or pending slots) latches
``state.overflow``; every later step becomes a no-op so the truncated
state is never consulted, and the host wrappers
(:func:`admit_stream_grow`, :func:`admit_one`) grow the state and
deterministically re-run the stream from its pre-run snapshot.

Streaming arrivals stage through the fixed-capacity
:class:`RequestRing` and leave as constant-shape chunks, which is what
lets :class:`repro.api.Session` admit continuously with zero
re-padding and zero recompilation after warmup.
"""
from __future__ import annotations

import functools
import warnings
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as search_lib
from repro.core import timeline as tl_lib
from repro.core.policies import policy_index
from repro.core.timeline import SchedulerState
from repro.core.types import Allocation, ARRequest, Rectangle, T_INF

# Growth retries before the host wrappers give up (2**8 x the initial
# capacity is far beyond any stream the int32 timeline can describe).
MAX_DOUBLINGS = 8


class RequestBatch(NamedTuple):
    """Struct-of-arrays AR request stream, sorted by arrival time.

    Each field is ``int32[N]``; a slice along the leading axis is a
    single request, which is exactly what ``lax.scan`` feeds to the
    fused step.
    """

    t_a: jax.Array
    t_r: jax.Array
    t_du: jax.Array
    t_dl: jax.Array
    n_pe: jax.Array


class Decision(NamedTuple):
    """Per-request admission outcome (scalar per step, ``[N]`` stacked)."""

    accepted: jax.Array   # bool
    t_s: jax.Array        # int32; -1 when rejected
    t_e: jax.Array        # int32; -1 when rejected
    pe_mask: jax.Array    # uint32[W]; 0 when rejected
    n_free: jax.Array     # int32 winning-rectangle free PEs
    t_begin: jax.Array    # int32 winning-rectangle begin
    t_end: jax.Array      # int32 winning-rectangle end


def requests_to_batch(jobs: Sequence[ARRequest]) -> RequestBatch:
    """Pack host requests into the device struct-of-arrays layout."""
    return RequestBatch(
        t_a=jnp.asarray([j.t_a for j in jobs], jnp.int32),
        t_r=jnp.asarray([j.t_r for j in jobs], jnp.int32),
        t_du=jnp.asarray([j.t_du for j in jobs], jnp.int32),
        t_dl=jnp.asarray([j.t_dl for j in jobs], jnp.int32),
        n_pe=jnp.asarray([j.n_pe for j in jobs], jnp.int32),
    )


def request_struct(req: ARRequest) -> RequestBatch:
    """A single request as a scalar struct (for :func:`admit`)."""
    return RequestBatch(
        t_a=jnp.int32(req.t_a), t_r=jnp.int32(req.t_r),
        t_du=jnp.int32(req.t_du), t_dl=jnp.int32(req.t_dl),
        n_pe=jnp.int32(req.n_pe))


def filler_request(n_pe: int, t_a: int) -> ARRequest:
    """A never-feasible padding request (asks for ``n_pe + 1`` PEs).

    Rejected without touching the timeline; it carries the arrival time
    of the last real request *already admitted* so it can never reorder
    releases (a filler stamped past a still-staged request would
    trigger its releases early).
    """
    return ARRequest(t_a=t_a, t_r=t_a, t_du=1, t_dl=t_a + 1,
                     n_pe=n_pe + 1)


def check_arrival_order(requests: Sequence[ARRequest],
                        last_t_a: int) -> None:
    """Validate t_a monotonicity of a whole slice before any mutation,
    so a rejected offer/push leaves the caller's state untouched."""
    last = last_t_a
    for r in requests:
        if r.t_a < last:
            raise ValueError(
                f"requests must be arrival-ordered across offers: "
                f"got t_a={r.t_a} after t_a={last}")
        last = r.t_a


def pad_streams(streams, n_pe: int) -> Tuple[RequestBatch, np.ndarray]:
    """Stack variable-length request streams into ``[C, N]`` + mask.

    Padding requests (:func:`filler_request`) ask for ``n_pe + 1`` PEs
    — never feasible, so they are rejected without touching the
    timeline; they arrive after the stream's last real request, so they
    cannot reorder releases either.  Decisions at padded positions must
    be masked out with the returned ``valid`` array (the ensemble
    consumers do).
    """
    C = len(streams)
    N = max((len(s) for s in streams), default=0)
    N = max(N, 1)
    fields = {f: np.zeros((C, N), np.int32)
              for f in RequestBatch._fields}
    valid = np.zeros((C, N), bool)
    for c, stream in enumerate(streams):
        last = stream[-1].t_a if stream else 0
        for i in range(N):
            if i < len(stream):
                r = stream[i]
                valid[c, i] = True
            else:
                r = filler_request(n_pe, last)
            fields["t_a"][c, i] = r.t_a
            fields["t_r"][c, i] = r.t_r
            fields["t_du"][c, i] = r.t_du
            fields["t_dl"][c, i] = r.t_dl
            fields["n_pe"][c, i] = r.n_pe
    return RequestBatch(**{k: jnp.asarray(v)
                           for k, v in fields.items()}), valid


class RequestRing:
    """Fixed-capacity FIFO staging ring for streaming admission.

    The online path of :class:`repro.api.Session`: arriving requests
    are staged here (host-side numpy storage — arrivals come from the
    host anyway) and leave as *fixed-shape* device chunks via
    :meth:`pop_chunk`, so the jitted ``admit_stream`` sees constant
    shapes across calls no matter how the arrivals are grouped.  Slots
    are reused modulo ``capacity``; the ring never re-pads or
    reallocates, and a full ring rejects the push (callers drain first).
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self.capacity = capacity
        self._buf = {f: np.zeros(capacity, np.int32)
                     for f in RequestBatch._fields}
        self._head = 0          # index of the oldest staged request
        self.count = 0          # staged (not yet popped) requests
        self.pushed = 0         # lifetime pushes
        self.popped = 0         # lifetime pops (valid only)
        self.wrapped = False    # a slot has been reused (index wrapped)
        self.last_t_a = 0       # arrival time of the newest push
        self.last_popped_t_a = 0  # arrival time of the newest pop

    @property
    def free(self) -> int:
        return self.capacity - self.count

    def push(self, requests: Sequence[ARRequest]) -> None:
        """Stage arrival-ordered requests; raises when they don't fit.

        All-or-nothing: the whole slice is validated before any slot
        is written, so a rejected push leaves the ring untouched.
        """
        if len(requests) > self.free:
            raise OverflowError(
                f"ring full: {len(requests)} requests, "
                f"{self.free}/{self.capacity} slots free — pop a chunk "
                f"first or configure a larger ring_capacity")
        check_arrival_order(requests, self.last_t_a)
        for r in requests:
            i = (self._head + self.count) % self.capacity
            if self.pushed >= self.capacity:
                self.wrapped = True
            self._buf["t_a"][i] = r.t_a
            self._buf["t_r"][i] = r.t_r
            self._buf["t_du"][i] = r.t_du
            self._buf["t_dl"][i] = r.t_dl
            self._buf["n_pe"][i] = r.n_pe
            self.count += 1
            self.pushed += 1
            self.last_t_a = r.t_a

    def _pop_chunk_host(self, chunk: int, n_pe: int,
                        n: Optional[int] = None):
        """As :meth:`pop_chunk` but numpy fields (for lane stacking).

        ``n`` caps how many staged requests to dequeue (default: up to
        ``chunk``); the remaining positions hold filler.
        """
        n = min(chunk, self.count) if n is None \
            else min(n, chunk, self.count)
        idx = (self._head + np.arange(chunk)) % self.capacity
        fields = {f: self._buf[f][idx].copy()
                  for f in RequestBatch._fields}
        valid = np.arange(chunk) < n
        if n > 0:
            self.last_popped_t_a = int(fields["t_a"][n - 1])
        if n < chunk:
            # filler is stamped with the newest *popped* arrival, never
            # a still-staged one — stamping past staged requests would
            # release their predecessors early and change decisions
            pad = filler_request(n_pe, self.last_popped_t_a)
            for f in RequestBatch._fields:
                fields[f][n:] = getattr(pad, f)
        self._head = (self._head + n) % self.capacity
        self.count -= n
        self.popped += n
        return fields, valid

    def pop_chunk(self, chunk: int,
                  n_pe: int) -> Tuple[RequestBatch, np.ndarray]:
        """Dequeue up to ``chunk`` requests as one fixed-shape batch.

        Always returns arrays of length ``chunk``: missing tail
        positions hold :func:`filler_request` padding and are flagged
        ``False`` in the returned ``valid`` mask.
        """
        fields, valid = self._pop_chunk_host(chunk, n_pe)
        return RequestBatch(**{k: jnp.asarray(v)
                               for k, v in fields.items()}), valid

    def snapshot(self) -> dict:
        """Copy of the ring's mutable state (see :meth:`restore`)."""
        return {"buf": {f: v.copy() for f, v in self._buf.items()},
                "head": self._head, "count": self.count,
                "pushed": self.pushed, "popped": self.popped,
                "wrapped": self.wrapped, "last_t_a": self.last_t_a,
                "last_popped_t_a": self.last_popped_t_a}

    def restore(self, snap: dict) -> None:
        for f, v in snap["buf"].items():
            self._buf[f][:] = v
        self._head = snap["head"]
        self.count = snap["count"]
        self.pushed = snap["pushed"]
        self.popped = snap["popped"]
        self.wrapped = snap["wrapped"]
        self.last_t_a = snap["last_t_a"]
        self.last_popped_t_a = snap["last_popped_t_a"]


def pop_chunk_ensemble(rings: Sequence[RequestRing], chunk: int,
                       n_pe: int, full_only: bool = False
                       ) -> Tuple[RequestBatch, np.ndarray]:
    """Pop one fixed-shape chunk from every lane's ring, stacked.

    Returns an ``[E, chunk]`` :class:`RequestBatch` plus the matching
    ``valid`` mask; lanes with fewer than ``chunk`` staged requests are
    padded with :func:`filler_request`.  With ``full_only`` a lane
    below a full chunk keeps its requests staged and contributes only
    filler (the ``flush=False`` contract: partial remainders wait).
    """
    fields = {f: np.zeros((len(rings), chunk), np.int32)
              for f in RequestBatch._fields}
    valid = np.zeros((len(rings), chunk), bool)
    for e, ring in enumerate(rings):
        n = 0 if full_only and ring.count < chunk else None
        lane_fields, lane_valid = ring._pop_chunk_host(chunk, n_pe,
                                                       n=n)
        for f in RequestBatch._fields:
            fields[f][e] = lane_fields[f]
        valid[e] = lane_valid
    return RequestBatch(**{k: jnp.asarray(v)
                           for k, v in fields.items()}), valid


def _where_tree(pred, if_true, if_false):
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(pred, a, b), if_true, if_false)


def release_due(state: SchedulerState, t_now: jax.Array) -> SchedulerState:
    """Delete every pending reservation with ``t_e <= t_now``.

    Mirrors the host simulator's completion heap: earliest end first.
    Reservations never share a PE over overlapping intervals, so the
    deletions commute and the loop order only has to be deterministic.
    Amortised one iteration per admitted job.
    """

    def pending_due(s: SchedulerState):
        return jnp.any(s.pend_te <= t_now) & ~s.overflow

    def release_one(s: SchedulerState) -> SchedulerState:
        i = jnp.argmin(s.pend_te)
        new_tl, ovf, n_keep = tl_lib.update(
            s.tl, s.pend_ts[i], s.pend_te[i], s.pend_mask[i],
            is_add=False, with_count=True)
        # the slot is freed even on overflow so the loop always makes
        # progress; an overflowed stream is re-run anyway.
        return s._replace(
            tl=_where_tree(ovf, s.tl, new_tl),
            pend_ts=s.pend_ts.at[i].set(T_INF),
            pend_te=s.pend_te.at[i].set(T_INF),
            pend_mask=s.pend_mask.at[i].set(jnp.uint32(0)),
            n_released=s.n_released
            + jnp.where(ovf, 0, 1).astype(jnp.int32),
            overflow=s.overflow | ovf,
            hw_records=jnp.maximum(s.hw_records, n_keep),
        )

    return jax.lax.while_loop(pending_due, release_one, state)


def _admit_impl(state: SchedulerState, req: RequestBatch,
                policy_id: jax.Array, *, n_pe: int,
                auto_release: bool,
                use_kernel: bool = False) -> Tuple[SchedulerState, Decision]:
    if auto_release:
        state = release_due(state, req.t_a)
    # NB: searches at full capacity S — the per-request engine's
    # power-of-two bucketing needs the host-visible record count, which
    # does not exist inside a fixed-shape scan.  The fusion win (no
    # host round-trips) dominates; keep initial `capacity` modest and
    # let overflow growth size S to the workload.
    res = search_lib.search(
        state.tl, req.t_r, req.t_du, req.t_dl, req.n_pe, policy_id,
        req.t_a, n_pe=n_pe, use_kernel=use_kernel)
    found = res.found & ~state.overflow

    def commit(s: SchedulerState) -> SchedulerState:
        new_tl, ovf, n_keep = tl_lib.update(
            s.tl, res.t_s, res.t_e, res.pe_mask, is_add=True,
            with_count=True)
        hw_pending = s.hw_pending
        if auto_release:
            free = s.pend_te == T_INF
            slot = jnp.argmax(free)
            n_used = jnp.sum(~free).astype(jnp.int32) + 1
            hw_pending = jnp.maximum(hw_pending, n_used)
            ovf = ovf | ~jnp.any(free)
            pend_ts = jnp.where(
                ovf, s.pend_ts, s.pend_ts.at[slot].set(res.t_s))
            pend_te = jnp.where(
                ovf, s.pend_te, s.pend_te.at[slot].set(res.t_e))
            pend_mask = jnp.where(
                ovf, s.pend_mask, s.pend_mask.at[slot].set(res.pe_mask))
        else:
            pend_ts, pend_te, pend_mask = \
                s.pend_ts, s.pend_te, s.pend_mask
        # an overflowing update returns a truncated timeline — keep the
        # pre-commit state so the retry starts from consistent data.
        return s._replace(
            tl=_where_tree(ovf, s.tl, new_tl),
            pend_ts=pend_ts, pend_te=pend_te, pend_mask=pend_mask,
            n_accepted=s.n_accepted
            + jnp.where(ovf, 0, 1).astype(jnp.int32),
            overflow=s.overflow | ovf,
            hw_records=jnp.maximum(s.hw_records, n_keep),
            hw_pending=hw_pending,
        )

    state = jax.lax.cond(found, commit, lambda s: s, state)
    accepted = found & ~state.overflow
    return state, Decision(
        accepted=accepted,
        t_s=jnp.where(accepted, res.t_s, jnp.int32(-1)),
        t_e=jnp.where(accepted, res.t_e, jnp.int32(-1)),
        pe_mask=jnp.where(accepted, res.pe_mask, jnp.uint32(0)),
        n_free=res.n_free,
        t_begin=res.t_begin,
        t_end=res.t_end,
    )


@functools.partial(
    jax.jit, static_argnames=("n_pe", "auto_release", "use_kernel"))
def admit(state: SchedulerState, req: RequestBatch,
          policy_id: jax.Array, *, n_pe: int,
          auto_release: bool = True,
          use_kernel: bool = False) -> Tuple[SchedulerState, Decision]:
    """One fused admission step: release due -> search -> commit.

    ``auto_release=False`` skips the pending-release bookkeeping for
    callers (e.g. the fleet) that manage completions themselves.
    """
    return _admit_impl(state, req, policy_id, n_pe=n_pe,
                       auto_release=auto_release, use_kernel=use_kernel)


@functools.partial(
    jax.jit, static_argnames=("n_pe", "auto_release", "use_kernel"))
def admit_stream(state: SchedulerState, batch: RequestBatch,
                 policy_id: jax.Array, *, n_pe: int,
                 auto_release: bool = True,
                 use_kernel: bool = False
                 ) -> Tuple[SchedulerState, Decision]:
    """Scan a whole arrival-ordered request stream on-device."""

    def step(s, r):
        return _admit_impl(s, r, policy_id, n_pe=n_pe,
                           auto_release=auto_release,
                           use_kernel=use_kernel)

    return jax.lax.scan(step, state, batch)


# ---------------------------------------------------------------------------
# host wrappers: overflow -> grow -> deterministic re-run
# ---------------------------------------------------------------------------


def grown_capacities(state: SchedulerState, need_records: int,
                     need_pending: int) -> Tuple[int, int]:
    """New (capacity, pending_capacity) sized by the high-water marks.

    ``need_records`` / ``need_pending`` are the max watermarks observed
    in the overflowing run (across the whole ensemble for the vmapped
    wrappers).  A structure whose watermark fits keeps its size; one
    that overflowed jumps straight to the next power of two covering
    the need (at least doubling, so the retry loop always progresses
    even when the watermark stalled at the first-overflow step).
    """
    cap, pend = state.tl.capacity, state.pending_capacity
    new_cap = cap if need_records <= cap \
        else max(2 * cap, tl_lib.next_pow2(need_records))
    new_pend = pend if need_pending <= pend \
        else max(2 * pend, tl_lib.next_pow2(need_pending))
    if (new_cap, new_pend) == (cap, pend):
        # overflow latched without a usable watermark: double both.
        new_cap, new_pend = 2 * cap, 2 * pend
    return new_cap, new_pend


def _grown(state: SchedulerState, run: SchedulerState) -> SchedulerState:
    """Grow the pre-run snapshot to what the failed ``run`` needed."""
    new_cap, new_pend = grown_capacities(
        state, int(run.hw_records), int(run.hw_pending))
    return tl_lib.grow_state(
        state, new_capacity=new_cap, new_pending_capacity=new_pend)


def admit_stream_grow(state: SchedulerState, batch: RequestBatch,
                      policy, *, n_pe: int, auto_release: bool = True,
                      use_kernel: bool = False,
                      max_growths: int = MAX_DOUBLINGS
                      ) -> Tuple[SchedulerState, Decision]:
    """Run :func:`admit_stream`, growing capacity on overflow.

    Each retry re-runs the *full* batch from the original (grown)
    pre-run state; padding never changes decisions, so the result is
    identical to a run that started with enough capacity.  This is the
    growth step behind :meth:`repro.api.Session.offer`, which feeds it
    fixed-shape ring-buffer chunks so steady-state streaming never
    recompiles.  ``max_growths=0`` forbids growth entirely: the first
    overflow raises before any state mutation (the service's
    ``auto_grow=False`` mode).
    """
    pid = jnp.int32(
        policy if isinstance(policy, (int, np.integer))
        else policy_index(policy))
    start = state
    for attempt in range(max_growths + 1):
        out, dec = admit_stream(start, batch, pid, n_pe=n_pe,
                                auto_release=auto_release,
                                use_kernel=use_kernel)
        if not bool(out.overflow):
            return out, dec
        if attempt < max_growths:
            start = _grown(start, out)
    raise RuntimeError(
        f"admit_stream still overflowing after {max_growths + 1} "
        f"attempts (last tried capacity {start.tl.capacity}, "
        f"pending {start.pending_capacity}; needed records "
        f"{int(out.hw_records)}, pending {int(out.hw_pending)})")


def admit_stream_auto(state: SchedulerState, batch: RequestBatch,
                      policy, *, n_pe: int, auto_release: bool = True,
                      use_kernel: bool = False
                      ) -> Tuple[SchedulerState, Decision]:
    """Deprecated alias of :func:`admit_stream_grow`.

    .. deprecated:: PR 3
       Use :class:`repro.api.ReservationService` — a
       :meth:`~repro.api.Session.offer` session streams fixed-shape
       chunks with zero recompilation — or call
       :func:`admit_stream_grow` directly for one-shot batches.
    """
    warnings.warn(
        "admit_stream_auto is deprecated: open a repro.api."
        "ReservationService session and use Session.offer(requests) "
        "(or admit_stream_grow for a one-shot batch)",
        DeprecationWarning, stacklevel=2)
    return admit_stream_grow(state, batch, policy, n_pe=n_pe,
                             auto_release=auto_release,
                             use_kernel=use_kernel)


def admit_one(state: SchedulerState, req: ARRequest, policy, *,
              n_pe: int, auto_release: bool = True,
              use_kernel: bool = False
              ) -> Tuple[SchedulerState, Optional[Allocation]]:
    """Single fused admission with growth retry; host-typed result."""
    pid = jnp.int32(policy_index(policy))
    start = state
    for attempt in range(MAX_DOUBLINGS + 1):
        out, dec = admit(start, request_struct(req), pid, n_pe=n_pe,
                         auto_release=auto_release,
                         use_kernel=use_kernel)
        if not bool(out.overflow):
            return out, decision_to_allocation(dec)
        if attempt < MAX_DOUBLINGS:
            start = _grown(start, out)
    raise RuntimeError(
        f"admit still overflowing after {MAX_DOUBLINGS + 1} attempts "
        f"(last tried capacity {start.tl.capacity}, "
        f"pending {start.pending_capacity})")


# ---------------------------------------------------------------------------
# session verbs: release-due advancement and cancellation
# ---------------------------------------------------------------------------


release_due_step = jax.jit(release_due)


def release_until(state: SchedulerState, t_now: int, *,
                  max_growths: int = MAX_DOUBLINGS) -> SchedulerState:
    """Host wrapper of :func:`release_due` with overflow growth.

    The service's ``tick(t)``: deletes every pending reservation ending
    by ``t_now``.  A deletion can split a merged record and overflow
    the timeline; the retry re-runs from the pre-tick snapshot on a
    grown state, which is deterministic.  ``max_growths=0`` raises on
    the first overflow instead (before any state mutation).
    """
    start = state
    for attempt in range(max_growths + 1):
        out = release_due_step(start, jnp.int32(t_now))
        if not bool(out.overflow):
            return out
        if attempt < max_growths:
            start = _grown(start, out)
    raise RuntimeError(
        f"release_until still overflowing after {max_growths + 1} "
        f"attempts (last tried capacity {start.tl.capacity})")


@functools.partial(jax.jit, static_argnames=("require_pending",))
def cancel_step(state: SchedulerState, t_s: jax.Array, t_e: jax.Array,
                mask: jax.Array, *, require_pending: bool = True
                ) -> Tuple[SchedulerState, jax.Array]:
    """Withdraw one committed reservation in a single fused dispatch.

    Deletes ``[t_s, t_e) x mask`` from the timeline and clears the
    matching pending-release slot.  With ``require_pending`` (the
    auto-release sessions) a reservation that is not pending — already
    released, cancelled, or never admitted — is a no-op returning
    ``False``, so cancel is idempotent and can never corrupt the
    timeline.  Overflow latches as in :func:`admit`; host callers grow
    and retry (:func:`cancel_one`).
    """
    match = (state.pend_ts == t_s) & (state.pend_te == t_e) & \
        jnp.all(state.pend_mask == mask[None, :], axis=1)
    found = jnp.any(match)
    ok = found if require_pending else jnp.asarray(True)
    ok = ok & ~state.overflow
    new_tl, ovf, n_keep = tl_lib.update(
        state.tl, t_s, t_e, mask, is_add=False, with_count=True)
    ovf = ovf & ok
    do = ok & ~ovf
    slot = jnp.argmax(match)
    clear = found & do
    cleared_ts = state.pend_ts.at[slot].set(T_INF)
    cleared_te = state.pend_te.at[slot].set(T_INF)
    cleared_mask = state.pend_mask.at[slot].set(jnp.uint32(0))
    out = state._replace(
        tl=_where_tree(do, new_tl, state.tl),
        pend_ts=jnp.where(clear, cleared_ts, state.pend_ts),
        pend_te=jnp.where(clear, cleared_te, state.pend_te),
        pend_mask=jnp.where(clear, cleared_mask, state.pend_mask),
        overflow=state.overflow | ovf,
        hw_records=jnp.maximum(state.hw_records,
                               jnp.where(ok, n_keep, 0)),
    )
    return out, do


def cancel_one(state: SchedulerState, t_s: int, t_e: int,
               mask: jax.Array, *, require_pending: bool = True,
               max_growths: int = MAX_DOUBLINGS
               ) -> Tuple[SchedulerState, bool]:
    """Host wrapper of :func:`cancel_step` with overflow growth."""
    start = state
    for attempt in range(max_growths + 1):
        out, done = cancel_step(
            start, jnp.int32(t_s), jnp.int32(t_e), mask,
            require_pending=require_pending)
        if not bool(out.overflow):
            return out, bool(done)
        if attempt < max_growths:
            start = _grown(start, out)
    raise RuntimeError(
        f"cancel still overflowing after {max_growths + 1} "
        f"attempts (last tried capacity {start.tl.capacity})")


# ---------------------------------------------------------------------------
# host-side decision unpacking
# ---------------------------------------------------------------------------


def mask32_to_ids(mask32: np.ndarray) -> Tuple[int, ...]:
    """uint32[W] bitmask -> sorted tuple of PE ids."""
    bits = np.unpackbits(
        np.ascontiguousarray(mask32, dtype="<u4").view(np.uint8),
        bitorder="little")
    return tuple(int(i) for i in np.nonzero(bits)[0])


def decision_to_allocation(dec: Decision) -> Optional[Allocation]:
    """One scalar :class:`Decision` -> host :class:`Allocation`."""
    if not bool(dec.accepted):
        return None
    return Allocation(
        t_s=int(dec.t_s), t_e=int(dec.t_e),
        pe_ids=mask32_to_ids(np.asarray(dec.pe_mask)),
        rectangle=Rectangle(
            t_s=int(dec.t_s), t_begin=int(dec.t_begin),
            t_end=int(dec.t_end), n_free=int(dec.n_free)),
    )


def search_result_to_allocation(res) -> Optional[Allocation]:
    """One scalar ``SearchResult`` -> host :class:`Allocation`."""
    if not bool(res.found):
        return None
    return Allocation(
        t_s=int(res.t_s), t_e=int(res.t_e),
        pe_ids=mask32_to_ids(np.asarray(res.pe_mask)),
        rectangle=Rectangle(
            t_s=int(res.t_s), t_begin=int(res.t_begin),
            t_end=int(res.t_end), n_free=int(res.n_free)),
    )


def decisions_to_allocations(dec: Decision) -> List[Optional[Allocation]]:
    """Stacked decisions -> one host allocation (or None) per request."""
    accepted = np.asarray(dec.accepted)
    t_s = np.asarray(dec.t_s)
    t_e = np.asarray(dec.t_e)
    masks = np.asarray(dec.pe_mask)
    n_free = np.asarray(dec.n_free)
    t_begin = np.asarray(dec.t_begin)
    t_end = np.asarray(dec.t_end)
    out: List[Optional[Allocation]] = []
    for i in range(accepted.shape[0]):
        if not accepted[i]:
            out.append(None)
            continue
        out.append(Allocation(
            t_s=int(t_s[i]), t_e=int(t_e[i]),
            pe_ids=mask32_to_ids(masks[i]),
            rectangle=Rectangle(
                t_s=int(t_s[i]), t_begin=int(t_begin[i]),
                t_end=int(t_end[i]), n_free=int(n_free[i]))))
    return out
