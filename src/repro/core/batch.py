"""Fused device-resident admission: ``(state, request) -> (state, decision)``.

The per-request engine pays a host round-trip per job: ``find_allocation``
syncs ``found``/the PE mask back to Python, which then issues ``update``
as a second dispatch.  This module makes the scheduler core functional
(DESIGN.md §3): :class:`~repro.core.timeline.SchedulerState` carries the
dense timeline plus a pending-release buffer of committed reservations,
:func:`admit` is one pure jitted step that fuses ``deleteAllocation`` of
due completions, ``findAllocation`` (Algorithm 3) and ``addAllocation``,
and :func:`admit_stream` scans a struct-of-arrays request batch through
that step with ``jax.lax.scan`` — whole experiments admit on-device.

Capacity overflow (timeline records or pending slots) latches
``state.overflow``; every later step becomes a no-op so the truncated
state is never consulted, and the host wrappers
(:func:`admit_stream_grow`, :func:`admit_one`) grow the state and
deterministically re-run the stream from its pre-run snapshot.

Streaming arrivals stage through the fixed-capacity
:class:`RequestRing` and leave as constant-shape chunks, which is what
lets :class:`repro.api.Session` admit continuously with zero
re-padding and zero recompilation after warmup.
"""
from __future__ import annotations

import functools
import warnings
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as search_lib
from repro.core import timeline as tl_lib
from repro.core.policies import policy_index
from repro.core.timeline import SchedulerState
from repro.tenancy import table as tenancy_lib
from repro.core.types import (
    Allocation,
    ARRequest,
    BackfillMode,
    Rectangle,
    T_INF,
    backfill_index,
)

# Growth retries before the host wrappers give up (2**8 x the initial
# capacity is far beyond any stream the int32 timeline can describe).
MAX_DOUBLINGS = 8

# Traced backfill-mode ids (see repro.core.types.BackfillMode).
BF_NONE = backfill_index(BackfillMode.NONE)
BF_EASY = backfill_index(BackfillMode.EASY)
BF_CONSERVATIVE = backfill_index(BackfillMode.CONSERVATIVE)


def as_backfill_id(backfill) -> jax.Array:
    """Any backfill spelling -> its traced int32 id.

    Accepts a mode name / :class:`~repro.core.types.BackfillMode` /
    validated id, an already-traced array (passed through), or a
    1-tuple (the single-lane spelling of the per-lane config form).
    """
    if isinstance(backfill, jax.Array):
        return backfill
    if isinstance(backfill, (tuple, list)):
        if len(backfill) != 1:
            raise ValueError(
                f"{len(backfill)} backfill modes for a single lane "
                f"(per-lane tuples belong to ensemble callers)")
        backfill = backfill[0]
    return jnp.int32(backfill_index(backfill))


class RequestBatch(NamedTuple):
    """Struct-of-arrays AR request stream, sorted by arrival time.

    Each field is ``int32[N]``; a slice along the leading axis is a
    single request, which is exactly what ``lax.scan`` feeds to the
    fused step.  ``tenant`` is the optional ownership column of
    multi-tenant sessions (DESIGN.md §10): ``None`` — the default —
    contributes no pytree leaf, so zero-tenant batches keep their
    exact pre-tenancy structure (and compiled graphs).  ``demand`` is
    the optional multi-resource tail column (DESIGN.md §11):
    ``int32[N, R-1]`` secondary-plane demands (plane 0 *is* ``n_pe``);
    ``None`` for single-resource sessions, again leaf-free.
    """

    t_a: jax.Array
    t_r: jax.Array
    t_du: jax.Array
    t_dl: jax.Array
    n_pe: jax.Array
    tenant: Optional[jax.Array] = None
    demand: Optional[jax.Array] = None   # int32[N, R-1] tail demands


#: The paper's five request coordinates — the always-present subset of
#: :class:`RequestBatch` fields.  Staging/padding sites iterate this
#: (not ``RequestBatch._fields``) so the optional tenant column is
#: materialised only for multi-tenant sessions.
REQ_FIELDS: Tuple[str, ...] = ("t_a", "t_r", "t_du", "t_dl", "n_pe")


def _req_field(r: ARRequest, f: str):
    """Read one staging column off a host request.

    ``demand<k>`` columns (k >= 1) read plane ``k`` of the request's
    demand vector; requests without one stage zeros there (PEs only).
    Everything else is a plain attribute.
    """
    if f.startswith("demand"):
        k = int(f[len("demand"):])
        return 0 if r.demand is None else int(r.demand[k])
    return getattr(r, f)


def _demand_fields(extra_demand: int) -> Tuple[str, ...]:
    """Staging column names of the demand tail (planes 1..R-1)."""
    return tuple(f"demand{k}" for k in range(1, extra_demand + 1))


def _fields_to_batch(fields: dict) -> RequestBatch:
    """Column dict (possibly with demand<k> columns) -> RequestBatch.

    The per-plane demand columns are stacked into the single
    ``int32[..., R-1]`` tail array along a new trailing axis; without
    any such column ``demand`` stays ``None`` (leaf-free).
    """
    plain = {k: jnp.asarray(v) for k, v in fields.items()
             if not k.startswith("demand")}
    dcols = sorted((k for k in fields if k.startswith("demand")),
                   key=lambda k: int(k[len("demand"):]))
    if dcols:
        plain["demand"] = jnp.stack(
            [jnp.asarray(fields[k], jnp.int32) for k in dcols],
            axis=-1)
    return RequestBatch(**plain)


class Decision(NamedTuple):
    """Per-request admission outcome (scalar per step, ``[N]`` stacked)."""

    accepted: jax.Array   # bool
    t_s: jax.Array        # int32; -1 when rejected
    t_e: jax.Array        # int32; -1 when rejected
    pe_mask: jax.Array    # uint32[W]; 0 when rejected
    n_free: jax.Array     # int32 winning-rectangle free PEs
    t_begin: jax.Array    # int32 winning-rectangle begin
    t_end: jax.Array      # int32 winning-rectangle end
    parked: jax.Array     # bool: accepted into the deferral queue
    #                       (reservation may still move under EASY)


def requests_to_batch(jobs: Sequence[ARRequest],
                      with_tenant: bool = False,
                      extra_demand: int = 0) -> RequestBatch:
    """Pack host requests into the device struct-of-arrays layout.

    ``extra_demand`` (= R - 1) adds the multi-resource tail column;
    jobs without a demand vector contribute zero tail demand.
    """
    return RequestBatch(
        t_a=jnp.asarray([j.t_a for j in jobs], jnp.int32),
        t_r=jnp.asarray([j.t_r for j in jobs], jnp.int32),
        t_du=jnp.asarray([j.t_du for j in jobs], jnp.int32),
        t_dl=jnp.asarray([j.t_dl for j in jobs], jnp.int32),
        n_pe=jnp.asarray([j.n_pe for j in jobs], jnp.int32),
        tenant=jnp.asarray([j.tenant for j in jobs], jnp.int32)
        if with_tenant else None,
        demand=jnp.asarray(
            [[_req_field(j, f) for f in _demand_fields(extra_demand)]
             for j in jobs], jnp.int32) if extra_demand else None,
    )


def request_struct(req: ARRequest,
                   with_tenant: bool = False,
                   extra_demand: int = 0) -> RequestBatch:
    """A single request as a scalar struct (for :func:`admit`)."""
    return RequestBatch(
        t_a=jnp.int32(req.t_a), t_r=jnp.int32(req.t_r),
        t_du=jnp.int32(req.t_du), t_dl=jnp.int32(req.t_dl),
        n_pe=jnp.int32(req.n_pe),
        tenant=jnp.int32(req.tenant) if with_tenant else None,
        demand=jnp.asarray(
            [_req_field(req, f) for f in _demand_fields(extra_demand)],
            jnp.int32) if extra_demand else None)


def filler_request(n_pe: int, t_a: int) -> ARRequest:
    """A never-feasible padding request (asks for ``n_pe + 1`` PEs).

    Rejected without touching the timeline; it carries the arrival time
    of the last real request *already admitted* so it can never reorder
    releases (a filler stamped past a still-staged request would
    trigger its releases early).
    """
    return ARRequest(t_a=t_a, t_r=t_a, t_du=1, t_dl=t_a + 1,
                     n_pe=n_pe + 1)


def check_arrival_order(requests: Sequence[ARRequest],
                        last_t_a: int) -> None:
    """Validate t_a monotonicity of a whole slice before any mutation,
    so a rejected offer/push leaves the caller's state untouched."""
    last = last_t_a
    for r in requests:
        if r.t_a < last:
            raise ValueError(
                f"requests must be arrival-ordered across offers: "
                f"got t_a={r.t_a} after t_a={last}")
        last = r.t_a


def pad_streams(streams, n_pe: int, with_tenant: bool = False,
                extra_demand: int = 0
                ) -> Tuple[RequestBatch, np.ndarray]:
    """Stack variable-length request streams into ``[C, N]`` + mask.

    Padding requests (:func:`filler_request`) ask for ``n_pe + 1`` PEs
    — never feasible, so they are rejected without touching the
    timeline; they arrive after the stream's last real request, so they
    cannot reorder releases either.  Decisions at padded positions must
    be masked out with the returned ``valid`` array (the ensemble
    consumers do).  ``with_tenant`` adds the tenant ownership column
    (filler positions carry tenant 0, which the admit step never
    charges — filler is detected by its infeasible PE ask).
    """
    C = len(streams)
    N = max((len(s) for s in streams), default=0)
    N = max(N, 1)
    names = (REQ_FIELDS + (("tenant",) if with_tenant else ())
             + _demand_fields(extra_demand))
    fields = {f: np.zeros((C, N), np.int32) for f in names}
    valid = np.zeros((C, N), bool)
    for c, stream in enumerate(streams):
        last = stream[-1].t_a if stream else 0
        for i in range(N):
            if i < len(stream):
                r = stream[i]
                valid[c, i] = True
            else:
                r = filler_request(n_pe, last)
            for f in names:
                fields[f][c, i] = _req_field(r, f)
    return _fields_to_batch(fields), valid


def scatter_streams(requests: Sequence[ARRequest],
                    lanes: Sequence[int], n_lanes: int, n_pe: int,
                    extra_demand: int = 0
                    ) -> Tuple[RequestBatch, np.ndarray, list]:
    """Group routed requests into per-lane padded streams.

    ``lanes[i]`` is the lane assigned to ``requests[i]``; the return
    value is ``(batch, valid, slots)`` where ``batch``/``valid`` come
    from :func:`pad_streams` over ``n_lanes`` streams and ``slots[i] =
    (lane, pos)`` locates request i's decision in the ``[C, N]``
    layout.  Within a lane the arrival order of the input sequence is
    preserved — the grouped commit admits each lane's requests in the
    same order a sequential router would have.
    """
    streams: list = [[] for _ in range(n_lanes)]
    slots = []
    for req, lane in zip(requests, lanes):
        slots.append((int(lane), len(streams[lane])))
        streams[lane].append(req)
    batch, valid = pad_streams(streams, n_pe,
                               extra_demand=extra_demand)
    return batch, valid, slots


class RequestRing:
    """Fixed-capacity FIFO staging ring for streaming admission.

    The online path of :class:`repro.api.Session`: arriving requests
    are staged here (host-side numpy storage — arrivals come from the
    host anyway) and leave as *fixed-shape* device chunks via
    :meth:`pop_chunk`, so the jitted ``admit_stream`` sees constant
    shapes across calls no matter how the arrivals are grouped.  Slots
    are reused modulo ``capacity``; the ring never re-pads or
    reallocates, and a full ring rejects the push (callers drain first).
    """

    def __init__(self, capacity: int, with_tenant: bool = False,
                 extra_demand: int = 0):
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self.capacity = capacity
        self._fields = (REQ_FIELDS + (("tenant",) if with_tenant
                                      else ())
                        + _demand_fields(extra_demand))
        self._buf = {f: np.zeros(capacity, np.int32)
                     for f in self._fields}
        self._head = 0          # index of the oldest staged request
        self.count = 0          # staged (not yet popped) requests
        self.pushed = 0         # lifetime pushes
        self.popped = 0         # lifetime pops (valid only)
        self.wrapped = False    # a slot has been reused (index wrapped)
        self.last_t_a = 0       # arrival time of the newest push
        self.last_popped_t_a = 0  # arrival time of the newest pop

    @property
    def free(self) -> int:
        return self.capacity - self.count

    def push(self, requests: Sequence[ARRequest]) -> None:
        """Stage arrival-ordered requests; raises when they don't fit.

        All-or-nothing: the whole slice is validated before any slot
        is written, so a rejected push leaves the ring untouched.
        """
        if len(requests) > self.free:
            raise OverflowError(
                f"ring full: {len(requests)} requests, "
                f"{self.free}/{self.capacity} slots free — pop a chunk "
                f"first or configure a larger ring_capacity")
        check_arrival_order(requests, self.last_t_a)
        for r in requests:
            i = (self._head + self.count) % self.capacity
            if self.pushed >= self.capacity:
                self.wrapped = True
            for f in self._fields:
                self._buf[f][i] = _req_field(r, f)
            self.count += 1
            self.pushed += 1
            self.last_t_a = r.t_a

    def _pop_chunk_host(self, chunk: int, n_pe: int,
                        n: Optional[int] = None):
        """As :meth:`pop_chunk` but numpy fields (for lane stacking).

        ``n`` caps how many staged requests to dequeue (default: up to
        ``chunk``); the remaining positions hold filler.
        """
        n = min(chunk, self.count) if n is None \
            else min(n, chunk, self.count)
        idx = (self._head + np.arange(chunk)) % self.capacity
        fields = {f: self._buf[f][idx].copy()
                  for f in self._fields}
        valid = np.arange(chunk) < n
        if n > 0:
            self.last_popped_t_a = int(fields["t_a"][n - 1])
        if n < chunk:
            # filler is stamped with the newest *popped* arrival, never
            # a still-staged one — stamping past staged requests would
            # release their predecessors early and change decisions
            pad = filler_request(n_pe, self.last_popped_t_a)
            for f in self._fields:
                fields[f][n:] = _req_field(pad, f)
        self._head = (self._head + n) % self.capacity
        self.count -= n
        self.popped += n
        return fields, valid

    def pop_chunk(self, chunk: int,
                  n_pe: int) -> Tuple[RequestBatch, np.ndarray]:
        """Dequeue up to ``chunk`` requests as one fixed-shape batch.

        Always returns arrays of length ``chunk``: missing tail
        positions hold :func:`filler_request` padding and are flagged
        ``False`` in the returned ``valid`` mask.
        """
        fields, valid = self._pop_chunk_host(chunk, n_pe)
        return _fields_to_batch(fields), valid

    def snapshot(self) -> dict:
        """Copy of the ring's mutable state (see :meth:`restore`)."""
        return {"buf": {f: v.copy() for f, v in self._buf.items()},
                "head": self._head, "count": self.count,
                "pushed": self.pushed, "popped": self.popped,
                "wrapped": self.wrapped, "last_t_a": self.last_t_a,
                "last_popped_t_a": self.last_popped_t_a}

    def restore(self, snap: dict) -> None:
        for f, v in snap["buf"].items():
            self._buf[f][:] = v
        self._head = snap["head"]
        self.count = snap["count"]
        self.pushed = snap["pushed"]
        self.popped = snap["popped"]
        self.wrapped = snap["wrapped"]
        self.last_t_a = snap["last_t_a"]
        self.last_popped_t_a = snap["last_popped_t_a"]


def pop_chunk_ensemble(rings: Sequence[RequestRing], chunk: int,
                       n_pe: int, full_only: bool = False
                       ) -> Tuple[RequestBatch, np.ndarray]:
    """Pop one fixed-shape chunk from every lane's ring, stacked.

    Returns an ``[E, chunk]`` :class:`RequestBatch` plus the matching
    ``valid`` mask; lanes with fewer than ``chunk`` staged requests are
    padded with :func:`filler_request`.  With ``full_only`` a lane
    below a full chunk keeps its requests staged and contributes only
    filler (the ``flush=False`` contract: partial remainders wait).
    """
    names = rings[0]._fields if rings else REQ_FIELDS
    fields = {f: np.zeros((len(rings), chunk), np.int32)
              for f in names}
    valid = np.zeros((len(rings), chunk), bool)
    for e, ring in enumerate(rings):
        n = 0 if full_only and ring.count < chunk else None
        lane_fields, lane_valid = ring._pop_chunk_host(chunk, n_pe,
                                                       n=n)
        for f in names:
            fields[f][e] = lane_fields[f]
        valid[e] = lane_valid
    return _fields_to_batch(fields), valid


def _where_tree(pred, if_true, if_false):
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(pred, a, b), if_true, if_false)


def _promote_due(state: SchedulerState,
                 t_now: jax.Array) -> SchedulerState:
    """Commit parked reservations whose start time has arrived.

    A deferral-queue entry with ``t_s <= t_now`` is running (or about
    to): its reservation becomes immovable and moves to the
    pending-release buffer, freeing the queue slot.  All due entries
    promote in one vectorised pass (DESIGN.md §7): the k-th due entry
    in promotion order takes the k-th free pending slot in index order
    — exactly the assignment the old one-at-a-time ``while_loop``
    produced, without threading the full state through a loop carry.
    The whole pass sits behind ``lax.cond`` on a due-entry predicate,
    so steps with an idle queue pay one ``any`` reduction.

    Promotion order is FCFS (sequence number); multi-tenant states
    rank by the weighted fair-share key instead — highest
    ``weight * wait`` first, seq breaking ties — which reduces
    *bit-identically* to FCFS under equal weights (DESIGN.md §10).
    """
    t_now = jnp.asarray(t_now, jnp.int32)
    K = state.pending_capacity

    def promote(s: SchedulerState) -> SchedulerState:
        due = (s.park_seq < T_INF) & (s.park_ts <= t_now)
        free = s.pend_te == T_INF
        n_free = jnp.sum(free).astype(jnp.int32)
        n_due = jnp.sum(due).astype(jnp.int32)
        seq = jnp.where(due, s.park_seq, T_INF)
        if s.tenants is not None:
            # weighted fair-share rank: count due entries strictly
            # ahead (higher key, or equal key and earlier seq)
            key = tenancy_lib.fair_key(s.tenants, t_now)
            ahead = due[None, :] & (
                (key[None, :] > key[:, None])
                | ((key[None, :] == key[:, None])
                   & (seq[None, :] < seq[:, None])))
            rank = jnp.sum(ahead, axis=1).astype(jnp.int32)
        else:
            # FCFS rank among due entries (sequence numbers are unique)
            rank = jnp.sum(
                (seq[None, :] < seq[:, None]) & due[None, :],
                axis=1).astype(jnp.int32)
        promoted = due & (rank < n_free)
        # k-th free pending slot (index order) for FCFS rank k
        frank = (jnp.cumsum(free) - 1).astype(jnp.int32)
        slot_of_rank = jnp.full((K + 1,), K, jnp.int32).at[
            jnp.where(free, frank, K)].set(
            jnp.arange(K, dtype=jnp.int32))
        dest = jnp.where(promoted,
                         slot_of_rank[jnp.clip(rank, 0, K)], K)

        def scat(pend, park, fill):
            ext = jnp.concatenate([pend, pend[:1]])
            return ext.at[dest].set(
                jnp.where(_bcast(promoted, park), park, fill))[:K]

        ovf = n_due > n_free
        n_prom = jnp.minimum(n_due, n_free)
        used0 = jnp.sum(~free).astype(jnp.int32)
        out = s._replace(
            pend_ts=scat(s.pend_ts, s.park_ts, jnp.int32(0)),
            pend_te=scat(s.pend_te, s.park_te, jnp.int32(0)),
            pend_mask=scat(s.pend_mask, s.park_mask, jnp.uint32(0)),
            park_ts=jnp.where(promoted, T_INF, s.park_ts),
            park_te=jnp.where(promoted, T_INF, s.park_te),
            park_mask=jnp.where(promoted[:, None], jnp.uint32(0),
                                s.park_mask),
            park_seq=jnp.where(promoted, T_INF, s.park_seq),
            n_promoted=s.n_promoted + n_prom,
            overflow=s.overflow | ovf,
            hw_pending=jnp.maximum(
                s.hw_pending,
                jnp.where(ovf, jnp.int32(K + 1), used0 + n_prom)),
        )
        if s.tenants is not None:
            # ownership follows the reservation: queue slot -> pending
            # slot (the scatter reuses `dest`); freed queue slots
            # return to unowned
            tn = s.tenants
            out = out._replace(tenants=tn._replace(
                pend_tenant=scat(tn.pend_tenant, tn.park_tenant,
                                 jnp.int32(-1)),
                park_tenant=jnp.where(promoted, -1, tn.park_tenant),
                park_ta=jnp.where(promoted, 0, tn.park_ta),
            ))
        return out

    pred = (jnp.any((state.park_seq < T_INF)
                    & (state.park_ts <= t_now)) & ~state.overflow)
    return jax.lax.cond(pred, promote, lambda s: s, state)


def _bcast(pred: jax.Array, like: jax.Array) -> jax.Array:
    """Broadcast a [K] predicate against [K]- or [K, W]-shaped data."""
    return pred if like.ndim == 1 else pred[:, None]


# Static batch width of the fused multi-release: one `update_many`
# deletes up to this many due reservations per pass.  Typical steps
# have 0-2 due completions, so one pass nearly always suffices while
# the scratch rows stay at S + 2 * chunk.
RELEASE_CHUNK = 8


def release_due(state: SchedulerState, t_now: jax.Array) -> SchedulerState:
    """Delete every pending reservation with ``t_e <= t_now``.

    With a deferral queue (``park_capacity > 0``) parked reservations
    whose start has arrived are promoted into the pending-release
    buffer first, so a later due end is released in the same pass.
    This is the session ``tick`` entry; the fused admit step gates the
    promotion together with the retry sweep under one queue-work cond
    (see ``_admit_impl``).
    """
    if state.park_capacity:
        state = _promote_due(state, t_now)
    return _release_pending(state, t_now)


def _release_pending(state: SchedulerState, t_now: jax.Array, *,
                     count_reaped: bool = False) -> SchedulerState:
    """The release loop proper (no promotion).

    Reservations never share a PE over overlapping intervals, so the
    deletions commute and — the timeline being a canonical
    representation of its occupancy step function — one fused
    multi-interval delete is bit-identical to the old one-at-a-time
    loop (DESIGN.md §7).  Up to :data:`RELEASE_CHUNK` due reservations
    are deleted per ``update_many`` call; the ``while_loop`` only
    iterates when more completions than that fall due at once.

    Multi-tenant states return each freed slot's ownership and
    decrement the owner's live count; with ``count_reaped`` (the
    overdue-reaping entry, :func:`reap_until`) the deletion is also
    charged to the owner's ``n_reaped`` counter.
    """
    t_now = jnp.asarray(t_now, jnp.int32)
    CH = min(RELEASE_CHUNK, state.pending_capacity)
    W = state.pend_mask.shape[1]

    def pending_due(s: SchedulerState):
        return jnp.any(s.pend_te <= t_now) & ~s.overflow

    def release_chunk(s: SchedulerState) -> SchedulerState:
        due = s.pend_te <= t_now
        rank = jnp.cumsum(due) - 1
        chosen = due & (rank < CH)
        dest = jnp.where(chosen, rank, CH)
        sel_ts = jnp.zeros((CH + 1,), jnp.int32).at[dest].set(
            jnp.where(chosen, s.pend_ts, 0))[:CH]
        sel_te = jnp.zeros((CH + 1,), jnp.int32).at[dest].set(
            jnp.where(chosen, s.pend_te, 0))[:CH]
        sel_mk = jnp.zeros((CH + 1, W), jnp.uint32).at[dest].set(
            jnp.where(chosen[:, None], s.pend_mask,
                      jnp.uint32(0)))[:CH]
        act = jnp.zeros((CH + 1,), bool).at[dest].set(chosen)[:CH]
        new_tl, ovf, n_keep = tl_lib.update_many(
            s.tl, sel_ts, sel_te, sel_mk, act, is_add=False,
            with_count=True)
        # slots are freed even on overflow so the loop always makes
        # progress; an overflowed stream is re-run anyway.
        out = s._replace(
            tl=_where_tree(ovf, s.tl, new_tl),
            pend_ts=jnp.where(chosen, T_INF, s.pend_ts),
            pend_te=jnp.where(chosen, T_INF, s.pend_te),
            pend_mask=jnp.where(chosen[:, None], jnp.uint32(0),
                                s.pend_mask),
            n_released=s.n_released + jnp.where(
                ovf, 0, jnp.sum(chosen)).astype(jnp.int32),
            overflow=s.overflow | ovf,
            hw_records=jnp.maximum(s.hw_records, n_keep),
        )
        if s.tenants is not None:
            tn = s.tenants
            T = tn.n_tenants
            tid = jnp.clip(tn.pend_tenant, 0, T - 1)
            dec = jnp.where(chosen & (tn.pend_tenant >= 0), 1,
                            0).astype(jnp.int32)
            upd = dict(
                live=tn.live.at[tid].add(-dec),
                pend_tenant=jnp.where(chosen, -1, tn.pend_tenant))
            if count_reaped:
                upd["n_reaped"] = tn.n_reaped.at[tid].add(dec)
            out = out._replace(tenants=tn._replace(**upd))
        return out

    return jax.lax.while_loop(pending_due, release_chunk, state)


@jax.jit
def reap_step(state: SchedulerState, t_now: jax.Array,
              grace: jax.Array) -> SchedulerState:
    """Batch-delete reservations overdue past the tenant grace window.

    A reservation is overdue at ``t_now`` iff ``t_e + grace <=
    t_now``, i.e. ``t_e <= t_now - grace`` — so reaping *is* the
    fused release loop evaluated at the shifted cutoff, with the
    freed slots additionally charged to their owners' ``n_reaped``.
    Only meaningful for sessions that track completions themselves
    (``auto_release=False``): with auto-release every reservation is
    released at ``t_e``, before any grace window can elapse.
    """
    cutoff = (jnp.asarray(t_now, jnp.int32)
              - jnp.asarray(grace, jnp.int32))
    return _release_pending(state, cutoff, count_reaped=True)


def reap_until(state: SchedulerState, t_now: int, grace: int, *,
               max_growths: int = MAX_DOUBLINGS) -> SchedulerState:
    """Host wrapper of :func:`reap_step` with overflow growth.

    The tenancy half of ``Session.tick(t)`` (DESIGN.md §10): mirrors
    :func:`release_until`'s grow-and-rerun loop — a deletion can
    split a merged record and overflow the timeline.
    """
    start = state
    for attempt in range(max_growths + 1):
        out = reap_step(start, jnp.int32(t_now), jnp.int32(grace))
        if not bool(out.overflow):
            return out
        if attempt < max_growths:
            start = _grown(start, out)
    raise RuntimeError(
        f"reap_until still overflowing after {max_growths + 1} "
        f"attempts (last tried capacity {start.tl.capacity})")


def _retry_parked(state: SchedulerState, t_now: jax.Array,
                  bf: jax.Array, *, n_pe: int,
                  use_kernel: bool) -> SchedulerState:
    """EASY retry-on-release sweep: pull parked reservations earlier.

    In FCFS order each live queue entry is lifted off the timeline,
    re-searched with :func:`~repro.core.search.replacement_search`
    (earliest feasible start, the classic backfilling reservation), and
    moved only when the new start is *strictly earlier* — so the sweep
    can never delay anybody, the head included.  It runs only when the
    ``park_retry`` latch is set, i.e. after a cancellation freed
    *future* capacity: completions free only past records (durations
    are exact), and proactively compacting reservations toward ``now``
    crowds exactly the region where new arrivals' deadline windows
    live, hurting acceptance.  Conservative mode never sweeps: its
    reservations are frozen at admission, which keeps conservative
    decision-identical to ``none``.
    """
    Q = state.park_capacity
    t_now = jnp.asarray(t_now, jnp.int32)

    def sweep(s0: SchedulerState) -> SchedulerState:
        def body(_, carry):
            s, done = carry
            cand = (s.park_seq < T_INF) & ~done
            i = _select_next(s, cand, t_now)
            act = jnp.any(cand) & ~s.overflow
            t_du = s.park_te[i] - s.park_ts[i]
            tl1, ovf1, nk1 = tl_lib.update(
                s.tl, s.park_ts[i], s.park_te[i], s.park_mask[i],
                is_add=False, with_count=True)
            res = search_lib.replacement_search(
                tl1, s.park_tr[i], t_du, s.park_tdl[i],
                s.park_npe[i], jnp.int32(0), t_now, n_pe=n_pe,
                use_kernel=use_kernel, rspec=s.rspec,
                demand_tail=_park_demand(s, i),
                valid_mask=s.lane_valid)
            better = act & ~ovf1 & res.found & (res.t_s < s.park_ts[i])
            new_ts = jnp.where(better, res.t_s, s.park_ts[i])
            new_te = new_ts + t_du
            new_mk = jnp.where(better, res.pe_mask, s.park_mask[i])
            tl2, ovf2, nk2 = tl_lib.update(
                tl1, new_ts, new_te, new_mk, is_add=True,
                with_count=True)
            apply = act & ~ovf1 & ~ovf2
            s = s._replace(
                tl=_where_tree(apply, tl2, s.tl),
                park_ts=s.park_ts.at[i].set(
                    jnp.where(apply & better, new_ts, s.park_ts[i])),
                park_te=s.park_te.at[i].set(
                    jnp.where(apply & better, new_te, s.park_te[i])),
                park_mask=s.park_mask.at[i].set(
                    jnp.where(apply & better, new_mk, s.park_mask[i])),
                n_moved=s.n_moved
                + jnp.where(apply & better, 1, 0).astype(jnp.int32),
                overflow=s.overflow | (act & (ovf1 | ovf2)),
                hw_records=jnp.maximum(
                    s.hw_records,
                    jnp.where(act, jnp.maximum(nk1, nk2), 0)),
            )
            return (s, done.at[i].set(True))

        out, _ = jax.lax.fori_loop(
            0, Q, body, (s0, jnp.zeros((Q,), bool)))
        return out

    pred = ((bf == BF_EASY) & state.park_retry
            & jnp.any(state.park_seq < T_INF) & ~state.overflow)
    return jax.lax.cond(pred, sweep, lambda s: s, state)
    # NB: the caller (_admit_impl) consumes the park_retry latch per
    # admit step whether or not the sweep fired.


def _park_demand(s: SchedulerState, i: jax.Array):
    """Demand tail of queue entry ``i`` (``None`` on R=1 states)."""
    return None if s.park_dem is None else s.park_dem[i]


def _select_next(s: SchedulerState, cand: jax.Array,
                 t_now: jax.Array) -> jax.Array:
    """Index of the next queue entry to serve among ``cand`` slots.

    FCFS (minimum sequence number); multi-tenant states pick the
    maximum weighted fair-share key instead, seq breaking ties —
    bit-identical to FCFS under equal weights (DESIGN.md §10).  Safe
    when nothing is a candidate (callers gate on ``jnp.any(cand)``).
    """
    if s.tenants is None:
        return jnp.argmin(jnp.where(cand, s.park_seq, T_INF))
    key = tenancy_lib.fair_key(s.tenants, t_now)
    best = jnp.max(jnp.where(cand, key, -jnp.inf))
    return jnp.argmin(jnp.where(cand & (key == best), s.park_seq,
                                T_INF))


def _no_displace(state: SchedulerState, req: RequestBatch,
                 policy_id: jax.Array):
    zero = jnp.int32(0)
    return state, search_lib.SearchResult(
        found=jnp.asarray(False), t_s=zero, t_e=zero,
        pe_mask=jnp.zeros((state.tl.words,), jnp.uint32),
        n_free=zero, t_begin=zero, t_end=zero)


def _displace(state: SchedulerState, req: RequestBatch,
              policy_id: jax.Array, *, n_pe: int, use_kernel: bool):
    """EASY displacement: admit ``req`` by moving non-head reservations.

    The transaction of DESIGN.md §6: lift every *non-head* deferral-
    queue reservation off the timeline, place the arriving request
    (its own policy, full deadline window) around the committed
    reservations plus the protected head, then re-place the lifted
    entries in FCFS order at their earliest feasible start inside their
    own deadline windows.  The request is admitted only if every lifted
    entry still fits — otherwise the whole transaction rolls back and
    the request is rejected, exactly as under ``none``.  The head-of-
    queue reservation and every committed start are untouched by
    construction (the EASY safety invariant).

    Returns the (possibly unchanged) state and a
    :class:`~repro.core.search.SearchResult` whose ``found`` flags the
    transaction outcome.  Any capacity overflow inside the transaction
    latches ``state.overflow`` regardless of the outcome, so the host
    grow-and-re-run protocol stays deterministic.
    """
    Q = state.park_capacity
    s = state
    active = s.park_seq < T_INF
    head = _select_next(s, active, req.t_a)
    nonhead = active & (jnp.arange(Q) != head)

    # batched lift: every non-head parked reservation comes off the
    # timeline in ONE fused multi-interval delete (DESIGN.md §7) —
    # the lifts commute, so this is bit-identical to the old
    # per-entry fori_loop of updates.
    tl, ovf, hw = tl_lib.update_many(
        s.tl, s.park_ts, s.park_te, s.park_mask, nonhead,
        is_add=False, with_count=True)
    tl = _where_tree(ovf, s.tl, tl)

    res_r = search_lib.search(
        tl, req.t_r, req.t_du, req.t_dl, req.n_pe, policy_id,
        req.t_a, n_pe=n_pe, use_kernel=use_kernel, rspec=s.rspec,
        demand_tail=req.demand, valid_mask=s.lane_valid)
    # a t_e at the horizon sentinel would commit as a no-op record
    # (timeline.update clamps it away) — reject it instead, matching
    # the admit step's guard
    ok = res_r.found & ~ovf & (res_r.t_e < jnp.int32(T_INF))
    tl2, o2, nk2 = tl_lib.update(
        tl, jnp.where(ok, res_r.t_s, 0), jnp.where(ok, res_r.t_e, 1),
        jnp.where(ok, res_r.pe_mask, jnp.uint32(0)), is_add=True,
        with_count=True)
    ovf = ovf | (ok & o2)
    tl = _where_tree(ok & ~o2, tl2, tl)
    hw = jnp.maximum(hw, jnp.where(ok, nk2, 0))

    def re_body(_, carry):
        tl, ovf, hw, ok, done, pts, pte, pmk, moved = carry
        cand = nonhead & ~done
        i = _select_next(s, cand, req.t_a)
        act = jnp.any(cand) & ok & ~ovf
        t_du = s.park_te[i] - s.park_ts[i]
        res = search_lib.replacement_search(
            tl, s.park_tr[i], t_du, s.park_tdl[i], s.park_npe[i],
            jnp.int32(0), req.t_a, n_pe=n_pe, use_kernel=use_kernel,
            rspec=s.rspec, demand_tail=_park_demand(s, i),
            valid_mask=s.lane_valid)
        okp = act & res.found
        tl2, o2, nk = tl_lib.update(
            tl, jnp.where(okp, res.t_s, 0),
            jnp.where(okp, res.t_s + t_du, 1),
            jnp.where(okp, res.pe_mask, jnp.uint32(0)), is_add=True,
            with_count=True)
        return (
            _where_tree(okp & ~o2, tl2, tl),
            ovf | (okp & o2),
            jnp.maximum(hw, jnp.where(okp, nk, 0)),
            ok & (res.found | ~act),
            done.at[i].set(True),
            pts.at[i].set(jnp.where(okp, res.t_s, pts[i])),
            pte.at[i].set(jnp.where(okp, res.t_s + t_du, pte[i])),
            pmk.at[i].set(jnp.where(okp, res.pe_mask, pmk[i])),
            moved + jnp.where(
                okp & (res.t_s != s.park_ts[i]), 1, 0
            ).astype(jnp.int32),
        )

    tl, ovf, hw, ok, _, pts, pte, pmk, moved = jax.lax.fori_loop(
        0, Q, re_body,
        (tl, ovf, hw, ok, jnp.zeros((Q,), bool), s.park_ts,
         s.park_te, s.park_mask, jnp.int32(0)))

    commit = ok & ~ovf
    out = s._replace(
        tl=_where_tree(commit, tl, s.tl),
        park_ts=jnp.where(commit, pts, s.park_ts),
        park_te=jnp.where(commit, pte, s.park_te),
        park_mask=jnp.where(commit, pmk, s.park_mask),
        n_moved=s.n_moved + jnp.where(commit, moved, 0),
        overflow=s.overflow | ovf,
        hw_records=jnp.maximum(s.hw_records, hw),
    )
    return out, res_r._replace(found=commit)


def _admit_impl(state: SchedulerState, req: RequestBatch,
                policy_id: jax.Array, backfill_id, *, n_pe: int,
                auto_release: bool,
                use_kernel: bool = False) -> Tuple[SchedulerState, Decision]:
    Q = state.park_capacity
    bf = jnp.asarray(backfill_id, jnp.int32)
    backfilling = bool(Q) and auto_release
    if backfilling:
        # promote-due + release + retry sweep under ONE queue-work
        # cond (DESIGN.md §7): a step whose queue holds nothing due
        # and whose retry latch is unarmed — every step on an
        # idle-queue stream — pays one predicate and the plain
        # release loop, i.e. mode-`none` cost.
        t_now = jnp.asarray(req.t_a, jnp.int32)
        live = state.park_seq < T_INF
        queue_pred = ((jnp.any(live & (state.park_ts <= t_now))
                       | ((bf == BF_EASY) & state.park_retry
                          & jnp.any(live)))
                      & ~state.overflow)

        def queue_work(s: SchedulerState) -> SchedulerState:
            s = _promote_due(s, t_now)
            s = _release_pending(s, t_now)
            return _retry_parked(s, t_now, bf, n_pe=n_pe,
                                 use_kernel=use_kernel)

        state = jax.lax.cond(
            queue_pred, queue_work,
            lambda s: _release_pending(s, t_now), state)
        # the retry latch is consumed per admit step either way
        state = state._replace(park_retry=jnp.asarray(False))
    elif auto_release:
        state = release_due(state, req.t_a)
    tenancy = state.tenants is not None
    # tenancy needs the pending buffer as its reservation ledger even
    # without auto-release (overdue reaping batch-deletes from it;
    # client cancels clear it); zero-tenant callers keep their exact
    # pre-tenancy graphs.
    track_pending = auto_release or tenancy
    if tenancy:
        # ---- quota gate (DESIGN.md §10): after queue work — the
        # gate must see post-release live counts, like the host
        # oracle — but strictly *before* search.
        tn0 = state.tenants
        T = tn0.n_tenants
        tid = jnp.clip(
            jnp.asarray(0 if req.tenant is None else req.tenant,
                        jnp.int32), 0, T - 1)
        # filler padding (requests_to_batch rings/grids) asks for
        # n_pe + 1 PEs; it belongs to no tenant and must neither be
        # gated nor charged
        real = req.n_pe <= jnp.int32(n_pe)
        demand = (req.n_pe.astype(jnp.float32)
                  * req.t_du.astype(jnp.float32))
        orig_tr, orig_tdu = req.t_r, req.t_du
        occ_row = tl_lib.occupancy_at(
            state.tl, jnp.asarray(req.t_a, jnp.int32))
        if state.rspec is not None:
            # telemetry stays a PE-utilisation fraction: count only
            # the primary plane's words of the multi-resource row
            occ_row = occ_row[state.rspec.plane_slice(0)]
        occ_frac = (jax.lax.population_count(occ_row).sum()
                    .astype(jnp.float32) / jnp.float32(n_pe))
        within = ((tn0.used[tid] + demand <= tn0.quota[tid])
                  & (tn0.live[tid] < tn0.max_live[tid]))
        blocked = real & ~within
        # an over-quota request is rewritten never-feasible (the
        # filler trick): search, displacement, commit and park all
        # no-op naturally, with zero extra branches in the hot path
        req = req._replace(
            t_r=jnp.where(blocked, req.t_a, req.t_r),
            t_du=jnp.where(blocked, jnp.int32(1), req.t_du),
            t_dl=jnp.where(blocked, req.t_a + jnp.int32(1),
                           req.t_dl),
            n_pe=jnp.where(blocked, jnp.int32(n_pe + 1), req.n_pe))
    else:
        blocked = jnp.asarray(False)
    # NB: searches at full capacity S — the per-request engine's
    # power-of-two bucketing needs the host-visible record count, which
    # does not exist inside a fixed-shape scan.  The fusion win (no
    # host round-trips) dominates; keep initial `capacity` modest and
    # let overflow growth size S to the workload.
    res = search_lib.search(
        state.tl, req.t_r, req.t_du, req.t_dl, req.n_pe, policy_id,
        req.t_a, n_pe=n_pe, use_kernel=use_kernel, rspec=state.rspec,
        demand_tail=req.demand, valid_mask=state.lane_valid)
    # reject a win whose end clamps to the horizon sentinel: committing
    # it would be a silent no-op under timeline.update's T_INF guard,
    # leaving an "accepted" decision with no occupancy behind it
    found = (res.found & ~state.overflow
             & (res.t_e < jnp.int32(T_INF)))
    t_s, t_e, pe_mask = res.t_s, res.t_e, res.pe_mask
    n_free, t_begin, t_end = res.n_free, res.t_begin, res.t_end
    need_add = jnp.asarray(True)
    if backfilling:
        # EASY fallback: an otherwise-rejected request may displace
        # non-head parked reservations (transactional; see _displace).
        # With fewer than two live entries there is nothing to lift —
        # the transaction would re-run the identical failed search —
        # so it is skipped (identical decisions, no wasted searches).
        # over-quota requests never displace: the transaction's lifts
        # could latch overflow for work the gate already rejected
        can_try = ((bf == BF_EASY) & ~res.found & ~state.overflow
                   & ~blocked
                   & (jnp.sum(state.park_seq < T_INF) >= 2))
        state, dres = jax.lax.cond(
            can_try,
            functools.partial(_displace, n_pe=n_pe,
                              use_kernel=use_kernel),
            _no_displace, state, req, policy_id)
        found = jnp.where(can_try, dres.found, found)
        t_s = jnp.where(can_try, dres.t_s, t_s)
        t_e = jnp.where(can_try, dres.t_e, t_e)
        pe_mask = jnp.where(can_try, dres.pe_mask, pe_mask)
        n_free = jnp.where(can_try, dres.n_free, n_free)
        t_begin = jnp.where(can_try, dres.t_begin, t_begin)
        t_end = jnp.where(can_try, dres.t_end, t_end)
        # the displacement transaction already wrote r to the timeline
        need_add = ~can_try
        free_park = state.park_seq == jnp.int32(T_INF)
        parks = ((bf != BF_NONE) & (t_s > req.t_r)
                 & jnp.any(free_park))
    else:
        parks = jnp.asarray(False)

    def commit(s: SchedulerState) -> SchedulerState:
        new_tl, ovf, n_keep = tl_lib.update(
            s.tl, jnp.where(need_add, t_s, 0),
            jnp.where(need_add, t_e, 1),
            jnp.where(need_add, pe_mask, jnp.uint32(0)), is_add=True,
            with_count=True)
        ovf = ovf & need_add
        hw_pending = s.hw_pending
        if track_pending:
            free = s.pend_te == T_INF
            slot = jnp.argmax(free)
            n_used = jnp.sum(~free).astype(jnp.int32) + 1
            to_pend = ~parks
            hw_pending = jnp.maximum(
                hw_pending, jnp.where(to_pend, n_used, 0))
            ovf = ovf | (to_pend & ~jnp.any(free))
            wr = to_pend & ~ovf
            pend_ts = jnp.where(
                wr, s.pend_ts.at[slot].set(t_s), s.pend_ts)
            pend_te = jnp.where(
                wr, s.pend_te.at[slot].set(t_e), s.pend_te)
            pend_mask = jnp.where(
                wr, s.pend_mask.at[slot].set(pe_mask), s.pend_mask)
        else:
            pend_ts, pend_te, pend_mask = \
                s.pend_ts, s.pend_te, s.pend_mask
        out = s._replace(
            # an overflowing update returns a truncated timeline —
            # keep the pre-commit state so the retry starts from
            # consistent data.
            tl=_where_tree(ovf, s.tl, new_tl),
            pend_ts=pend_ts, pend_te=pend_te, pend_mask=pend_mask,
            n_accepted=s.n_accepted
            + jnp.where(ovf, 0, 1).astype(jnp.int32),
            overflow=s.overflow | ovf,
            hw_records=jnp.maximum(s.hw_records, n_keep),
            hw_pending=hw_pending,
        )
        if tenancy:
            # ownership of the new pending slot (queue slots are
            # owned by park_write below)
            tn = s.tenants
            out = out._replace(tenants=tn._replace(
                pend_tenant=jnp.where(
                    wr, tn.pend_tenant.at[slot].set(tid),
                    tn.pend_tenant)))
        if backfilling:
            # park bookkeeping sits behind its own cond: an accept
            # that starts at its ready time (the overwhelmingly
            # common case — always, on an idle-queue stream) pays one
            # predicate instead of seven queue-array scatters
            def park_write(o: SchedulerState) -> SchedulerState:
                pslot = jnp.argmax(free_park)
                live = jnp.sum(~free_park).astype(jnp.int32) + 1
                o = o._replace(
                    park_ts=o.park_ts.at[pslot].set(t_s),
                    park_te=o.park_te.at[pslot].set(t_e),
                    park_mask=o.park_mask.at[pslot].set(pe_mask),
                    park_tr=o.park_tr.at[pslot].set(req.t_r),
                    park_tdl=o.park_tdl.at[pslot].set(req.t_dl),
                    park_npe=o.park_npe.at[pslot].set(req.n_pe),
                    park_seq=o.park_seq.at[pslot].set(o.park_next_seq),
                    park_next_seq=o.park_next_seq + 1,
                    n_parked=o.n_parked + 1,
                    hw_parked=jnp.maximum(o.hw_parked, live),
                )
                if o.park_dem is not None:
                    # the queue entry keeps its demand tail so later
                    # re-placements (EASY sweep / displacement) search
                    # with the full vector
                    dem_row = (req.demand if req.demand is not None
                               else jnp.zeros_like(o.park_dem[0]))
                    o = o._replace(
                        park_dem=o.park_dem.at[pslot].set(dem_row))
                if tenancy:
                    tno = o.tenants
                    o = o._replace(tenants=tno._replace(
                        park_tenant=tno.park_tenant.at[pslot].set(
                            tid),
                        # the fair-share wait clock starts at arrival
                        park_ta=tno.park_ta.at[pslot].set(req.t_a),
                    ))
                return o

            out = jax.lax.cond(parks & ~ovf, park_write,
                               lambda o: o, out)
        return out

    state = jax.lax.cond(found, commit, lambda s: s, state)
    accepted = found & ~state.overflow
    if tenancy:
        # ---- per-tenant accounting and telemetry EWMAs: lazy
        # device-resident accumulators (one scatter block per step,
        # nothing read back).  Filler padding (real=False) and
        # overflowed steps (re-run from the pre-run snapshot anyway)
        # charge nothing, so the table matches the host oracle, which
        # sees neither.  Expression shapes mirror
        # HostTenantAccounts.record float32-for-float32.
        tn = state.tenants
        ok_upd = real & ~state.overflow
        one = jnp.float32(1.0)
        a = tn.alpha
        acc_i = jnp.where(ok_upd & accepted, 1, 0).astype(jnp.int32)
        rej_i = jnp.where(ok_upd & ~accepted, 1, 0).astype(jnp.int32)
        qrej_i = jnp.where(ok_upd & blocked, 1, 0).astype(jnp.int32)
        prk_i = jnp.where(ok_upd & accepted & parks, 1,
                          0).astype(jnp.int32)
        acc_x = jnp.where(accepted, one, jnp.float32(0.0))
        new_acc = tn.acc_ewma[tid] * (one - a) + acc_x * a
        slow_x = ((t_e - orig_tr).astype(jnp.float32)
                  / orig_tdu.astype(jnp.float32))
        new_slow = tn.slow_ewma[tid] * (one - a) + slow_x * a
        new_occ = tn.occ_ewma * (one - a) + occ_frac * a
        state = state._replace(tenants=tn._replace(
            used=tn.used.at[tid].add(
                jnp.where(ok_upd & accepted, demand,
                          jnp.float32(0.0))),
            live=tn.live.at[tid].add(acc_i),
            n_accepted=tn.n_accepted.at[tid].add(acc_i),
            n_rejected=tn.n_rejected.at[tid].add(rej_i),
            n_quota_rejected=tn.n_quota_rejected.at[tid].add(qrej_i),
            n_parked=tn.n_parked.at[tid].add(prk_i),
            acc_ewma=tn.acc_ewma.at[tid].set(
                jnp.where(ok_upd, new_acc, tn.acc_ewma[tid])),
            slow_ewma=tn.slow_ewma.at[tid].set(
                jnp.where(ok_upd & accepted, new_slow,
                          tn.slow_ewma[tid])),
            occ_ewma=jnp.where(ok_upd, new_occ, tn.occ_ewma),
        ))
    return state, Decision(
        accepted=accepted,
        t_s=jnp.where(accepted, t_s, jnp.int32(-1)),
        t_e=jnp.where(accepted, t_e, jnp.int32(-1)),
        pe_mask=jnp.where(accepted, pe_mask, jnp.uint32(0)),
        n_free=n_free,
        t_begin=t_begin,
        t_end=t_end,
        parked=accepted & parks,
    )


@functools.partial(
    jax.jit, static_argnames=("n_pe", "auto_release", "use_kernel"))
def admit(state: SchedulerState, req: RequestBatch,
          policy_id: jax.Array, backfill_id=BF_NONE, *, n_pe: int,
          auto_release: bool = True,
          use_kernel: bool = False) -> Tuple[SchedulerState, Decision]:
    """One fused admission step: release due -> retry -> search -> commit.

    ``auto_release=False`` skips the pending-release bookkeeping for
    callers (e.g. the fleet) that manage completions themselves.
    ``backfill_id`` is the traced deferral mode (none/easy/
    conservative); it only matters when the state carries a deferral
    queue (``park_capacity > 0``).
    """
    return _admit_impl(state, req, policy_id, backfill_id, n_pe=n_pe,
                       auto_release=auto_release, use_kernel=use_kernel)


@functools.partial(
    jax.jit, static_argnames=("n_pe", "auto_release", "use_kernel"))
def admit_stream(state: SchedulerState, batch: RequestBatch,
                 policy_id: jax.Array, backfill_id=BF_NONE, *,
                 n_pe: int, auto_release: bool = True,
                 use_kernel: bool = False
                 ) -> Tuple[SchedulerState, Decision]:
    """Scan a whole arrival-ordered request stream on-device."""
    bf = jnp.asarray(backfill_id, jnp.int32)

    def step(s, r):
        return _admit_impl(s, r, policy_id, bf, n_pe=n_pe,
                           auto_release=auto_release,
                           use_kernel=use_kernel)

    return jax.lax.scan(step, state, batch)


@functools.partial(
    jax.jit, static_argnames=("n_pe", "auto_release", "use_kernel"),
    donate_argnums=(0,))
def admit_stream_donated(state: SchedulerState, batch: RequestBatch,
                         policy_id: jax.Array, backfill_id=BF_NONE, *,
                         n_pe: int, auto_release: bool = True,
                         use_kernel: bool = False
                         ) -> Tuple[SchedulerState, Decision]:
    """:func:`admit_stream` with the state buffers *donated*.

    Donation lets XLA reuse the input buffers for the output, so the
    steady-state step is allocation-free — but it consumes the
    caller's only copy, which collides with the grow-once protocol's
    "re-run the batch from the pre-run snapshot".  The resolution is
    rollback-on-overflow (DESIGN.md §8): when the overflow latch is
    (or becomes) set, this function returns the *pre-call* state —
    rolled back inside the dispatch — carrying the sticky latch and
    the run's high-water marks.  The host can then grow once
    (:func:`grow_rollback`) and re-run deterministically; the
    discarded run's decisions were going to be re-computed anyway,
    and the watermarks only size growth, never decisions.

    The latch is sticky *across* calls: a donated call entered with
    ``overflow`` already set returns its input state unchanged (its
    decisions are garbage and must be discarded) — this is what lets
    the service pipeline chunks without a per-chunk overflow read.
    """
    bf = jnp.asarray(backfill_id, jnp.int32)

    def step(s, r):
        return _admit_impl(s, r, policy_id, bf, n_pe=n_pe,
                           auto_release=auto_release,
                           use_kernel=use_kernel)

    out, dec = jax.lax.scan(step, state, batch)
    ovf = state.overflow | out.overflow
    rolled = _where_tree(jnp.any(ovf), state, out)
    rolled = rolled._replace(
        overflow=ovf,
        hw_records=jnp.maximum(state.hw_records, out.hw_records),
        hw_pending=jnp.maximum(state.hw_pending, out.hw_pending))
    return rolled, dec


# ---------------------------------------------------------------------------
# host wrappers: overflow -> grow -> deterministic re-run
# ---------------------------------------------------------------------------


class GrowthError(RuntimeError):
    """Overflow with growth exhausted or forbidden.

    ``state``, when set, is the rolled-back pre-run state of a
    *donated* attempt: the caller's input buffers were consumed, so a
    donating caller must reinstall this state to stay usable (the
    service backends do).  Non-donated attempts leave the caller's
    state untouched and set ``state=None``.
    """

    def __init__(self, msg: str, state: Optional[SchedulerState] = None):
        super().__init__(msg)
        self.state = state


def grown_capacities(state: SchedulerState, need_records: int,
                     need_pending: int) -> Tuple[int, int]:
    """New (capacity, pending_capacity) sized by the high-water marks.

    ``need_records`` / ``need_pending`` are the max watermarks observed
    in the overflowing run (across the whole ensemble for the vmapped
    wrappers).  A structure whose watermark fits keeps its size; one
    that overflowed jumps straight to the next power of two covering
    the need (at least doubling, so the retry loop always progresses
    even when the watermark stalled at the first-overflow step).
    """
    cap, pend = state.tl.capacity, state.pending_capacity
    new_cap = cap if need_records <= cap \
        else max(2 * cap, tl_lib.next_pow2(need_records))
    new_pend = pend if need_pending <= pend \
        else max(2 * pend, tl_lib.next_pow2(need_pending))
    if (new_cap, new_pend) == (cap, pend):
        # overflow latched without a usable watermark: double both.
        new_cap, new_pend = 2 * cap, 2 * pend
    return new_cap, new_pend


def _grown(state: SchedulerState, run: SchedulerState) -> SchedulerState:
    """Grow the pre-run snapshot to what the failed ``run`` needed."""
    new_cap, new_pend = grown_capacities(
        state, int(run.hw_records), int(run.hw_pending))
    return tl_lib.grow_state(
        state, new_capacity=new_cap, new_pending_capacity=new_pend)


def grow_rollback(state: SchedulerState) -> SchedulerState:
    """Grow a rolled-back (latched) state and clear its latch.

    The donated-path counterpart of :func:`_grown`: a
    :func:`admit_stream_donated` overflow returns the pre-run state
    carrying the failed run's watermarks, so the rollback state *is*
    its own growth reference.  ``grow_state`` copies the latch
    verbatim, which would keep every retry a no-op — clear it.
    """
    out = _grown(state, state)
    return out._replace(overflow=jnp.zeros_like(out.overflow))


def admit_stream_grow(state: SchedulerState, batch: RequestBatch,
                      policy, *, n_pe: int, backfill=BF_NONE,
                      auto_release: bool = True,
                      use_kernel: bool = False,
                      max_growths: int = MAX_DOUBLINGS,
                      donate: bool = False
                      ) -> Tuple[SchedulerState, Decision]:
    """Run :func:`admit_stream`, growing capacity on overflow.

    Each retry re-runs the *full* batch from the original (grown)
    pre-run state; padding never changes decisions, so the result is
    identical to a run that started with enough capacity.  This is the
    growth step behind :meth:`repro.api.Session.offer`, which feeds it
    fixed-shape ring-buffer chunks so steady-state streaming never
    recompiles.  ``max_growths=0`` forbids growth entirely: the first
    overflow raises before any state mutation (the service's
    ``auto_grow=False`` mode).

    ``donate=True`` dispatches :func:`admit_stream_donated` instead —
    the caller's state buffers are consumed and must not be reused
    (the overflow retry re-materializes via :func:`grow_rollback`; a
    terminal overflow raises :class:`GrowthError` carrying the
    rolled-back state so the caller can reinstall it).  Decisions are
    bit-identical to the non-donated path.
    """
    pid = jnp.int32(
        policy if isinstance(policy, (int, np.integer))
        else policy_index(policy))
    bfid = as_backfill_id(backfill)
    fn = admit_stream_donated if donate else admit_stream
    start = state
    for attempt in range(max_growths + 1):
        out, dec = fn(start, batch, pid, bfid, n_pe=n_pe,
                      auto_release=auto_release,
                      use_kernel=use_kernel)
        if not bool(out.overflow):
            return out, dec
        if attempt < max_growths:
            # donated: `out` IS the rolled-back pre-run state (fresh
            # buffers), so growth re-materializes outside the donated
            # dispatch and the retry owns its input exclusively again
            start = grow_rollback(out) if donate else _grown(start, out)
    raise GrowthError(
        f"admit_stream still overflowing after {max_growths + 1} "
        f"attempts (last tried capacity "
        f"{(out if donate else start).tl.capacity}, "
        f"pending {(out if donate else start).pending_capacity}; "
        f"needed records {int(out.hw_records)}, "
        f"pending {int(out.hw_pending)})",
        state=out if donate else None)


def admit_stream_auto(state: SchedulerState, batch: RequestBatch,
                      policy, *, n_pe: int, auto_release: bool = True,
                      use_kernel: bool = False
                      ) -> Tuple[SchedulerState, Decision]:
    """Deprecated alias of :func:`admit_stream_grow`.

    .. deprecated:: PR 3
       Use :class:`repro.api.ReservationService` — a
       :meth:`~repro.api.Session.offer` session streams fixed-shape
       chunks with zero recompilation — or call
       :func:`admit_stream_grow` directly for one-shot batches.
    """
    warnings.warn(
        "admit_stream_auto is deprecated: open a repro.api."
        "ReservationService session and use Session.offer(requests) "
        "(or admit_stream_grow for a one-shot batch)",
        DeprecationWarning, stacklevel=2)
    return admit_stream_grow(state, batch, policy, n_pe=n_pe,
                             auto_release=auto_release,
                             use_kernel=use_kernel)


def admit_one(state: SchedulerState, req: ARRequest, policy, *,
              n_pe: int, backfill=BF_NONE, auto_release: bool = True,
              use_kernel: bool = False
              ) -> Tuple[SchedulerState, Optional[Allocation]]:
    """Single fused admission with growth retry; host-typed result."""
    pid = jnp.int32(policy_index(policy))
    bfid = as_backfill_id(backfill)
    xd = 0 if state.rspec is None else state.rspec.R - 1
    start = state
    for attempt in range(MAX_DOUBLINGS + 1):
        out, dec = admit(start, request_struct(req, extra_demand=xd),
                         pid, bfid,
                         n_pe=n_pe, auto_release=auto_release,
                         use_kernel=use_kernel)
        if not bool(out.overflow):
            return out, decision_to_allocation(dec)
        if attempt < MAX_DOUBLINGS:
            start = _grown(start, out)
    raise RuntimeError(
        f"admit still overflowing after {MAX_DOUBLINGS + 1} attempts "
        f"(last tried capacity {start.tl.capacity}, "
        f"pending {start.pending_capacity})")


# ---------------------------------------------------------------------------
# session verbs: release-due advancement and cancellation
# ---------------------------------------------------------------------------


release_due_step = jax.jit(release_due)


def release_until(state: SchedulerState, t_now: int, *,
                  max_growths: int = MAX_DOUBLINGS) -> SchedulerState:
    """Host wrapper of :func:`release_due` with overflow growth.

    The service's ``tick(t)``: deletes every pending reservation ending
    by ``t_now``.  A deletion can split a merged record and overflow
    the timeline; the retry re-runs from the pre-tick snapshot on a
    grown state, which is deterministic.  ``max_growths=0`` raises on
    the first overflow instead (before any state mutation).
    """
    start = state
    for attempt in range(max_growths + 1):
        out = release_due_step(start, jnp.int32(t_now))
        if not bool(out.overflow):
            return out
        if attempt < max_growths:
            start = _grown(start, out)
    raise RuntimeError(
        f"release_until still overflowing after {max_growths + 1} "
        f"attempts (last tried capacity {start.tl.capacity})")


@functools.partial(jax.jit, static_argnames=("require_pending",))
def cancel_step(state: SchedulerState, t_s: jax.Array, t_e: jax.Array,
                mask: jax.Array, *, require_pending: bool = True
                ) -> Tuple[SchedulerState, jax.Array]:
    """Withdraw one committed reservation in a single fused dispatch.

    Deletes ``[t_s, t_e) x mask`` from the timeline and clears the
    matching pending-release slot.  With ``require_pending`` (the
    auto-release sessions) a reservation that is not pending — already
    released, cancelled, or never admitted — is a no-op returning
    ``False``, so cancel is idempotent and can never corrupt the
    timeline.  Overflow latches as in :func:`admit`; host callers grow
    and retry (:func:`cancel_one`).
    """
    match = (state.pend_ts == t_s) & (state.pend_te == t_e) & \
        jnp.all(state.pend_mask == mask[None, :], axis=1)
    found = jnp.any(match)
    if state.park_capacity:
        # a parked (deferral-queue) reservation is cancellable too
        pmatch = (state.park_ts == t_s) & (state.park_te == t_e) & \
            jnp.all(state.park_mask == mask[None, :], axis=1) & \
            (state.park_seq < T_INF)
        pfound = jnp.any(pmatch)
        found = found | pfound
    ok = found if require_pending else jnp.asarray(True)
    ok = ok & ~state.overflow
    new_tl, ovf, n_keep = tl_lib.update(
        state.tl, t_s, t_e, mask, is_add=False, with_count=True)
    ovf = ovf & ok
    do = ok & ~ovf
    slot = jnp.argmax(match)
    clear = jnp.any(match) & do
    cleared_ts = state.pend_ts.at[slot].set(T_INF)
    cleared_te = state.pend_te.at[slot].set(T_INF)
    cleared_mask = state.pend_mask.at[slot].set(jnp.uint32(0))
    out = state._replace(
        tl=_where_tree(do, new_tl, state.tl),
        pend_ts=jnp.where(clear, cleared_ts, state.pend_ts),
        pend_te=jnp.where(clear, cleared_te, state.pend_te),
        pend_mask=jnp.where(clear, cleared_mask, state.pend_mask),
        overflow=state.overflow | ovf,
        hw_records=jnp.maximum(state.hw_records,
                               jnp.where(ok, n_keep, 0)),
    )
    if state.park_capacity:
        pslot = jnp.argmax(pmatch)
        pclear = pfound & do
        out = out._replace(
            park_ts=jnp.where(
                pclear, out.park_ts.at[pslot].set(T_INF), out.park_ts),
            park_te=jnp.where(
                pclear, out.park_te.at[pslot].set(T_INF), out.park_te),
            park_mask=jnp.where(
                pclear, out.park_mask.at[pslot].set(jnp.uint32(0)),
                out.park_mask),
            park_seq=jnp.where(
                pclear, out.park_seq.at[pslot].set(T_INF),
                out.park_seq),
            # a successful withdrawal frees future capacity: arm the
            # EASY retry-on-release sweep for the next admit step
            park_retry=out.park_retry | do,
        )
    if state.tenants is not None:
        tn = state.tenants
        T = tn.n_tenants
        ctid = jnp.clip(tn.pend_tenant[slot], 0, T - 1)
        dec = jnp.where(clear & (tn.pend_tenant[slot] >= 0), 1,
                        0).astype(jnp.int32)
        upd = dict(
            live=tn.live.at[ctid].add(-dec),
            pend_tenant=jnp.where(
                clear, tn.pend_tenant.at[slot].set(-1),
                tn.pend_tenant))
        if state.park_capacity:
            ptid = jnp.clip(tn.park_tenant[pslot], 0, T - 1)
            pdec = jnp.where(pclear & (tn.park_tenant[pslot] >= 0),
                             1, 0).astype(jnp.int32)
            upd["live"] = upd["live"].at[ptid].add(-pdec)
            upd["park_tenant"] = jnp.where(
                pclear, tn.park_tenant.at[pslot].set(-1),
                tn.park_tenant)
            upd["park_ta"] = jnp.where(
                pclear, tn.park_ta.at[pslot].set(0), tn.park_ta)
        out = out._replace(tenants=tn._replace(**upd))
    return out, do


def cancel_one(state: SchedulerState, t_s: int, t_e: int,
               mask: jax.Array, *, require_pending: bool = True,
               max_growths: int = MAX_DOUBLINGS
               ) -> Tuple[SchedulerState, bool]:
    """Host wrapper of :func:`cancel_step` with overflow growth."""
    start = state
    for attempt in range(max_growths + 1):
        out, done = cancel_step(
            start, jnp.int32(t_s), jnp.int32(t_e), mask,
            require_pending=require_pending)
        if not bool(out.overflow):
            return out, bool(done)
        if attempt < max_growths:
            start = _grown(start, out)
    raise RuntimeError(
        f"cancel still overflowing after {max_growths + 1} "
        f"attempts (last tried capacity {start.tl.capacity})")


@functools.partial(jax.jit, static_argnames=("require_pending",))
def cancel_many_step(state: SchedulerState, t_s: jax.Array,
                     t_e: jax.Array, masks: jax.Array,
                     active: jax.Array, *,
                     require_pending: bool = True
                     ) -> Tuple[SchedulerState, jax.Array]:
    """Withdraw up to K committed reservations in one fused dispatch.

    The batched sibling of :func:`cancel_step`, built on
    ``timeline.update_many``: all matched reservations are deleted in
    one boundary-union + merge pass and their pending (or parked)
    slots cleared together.  Cancellations of distinct reservations
    commute, so this is decision-identical to K sequential cancels
    (callers must not repeat a reservation within one batch — the
    host wrapper deduplicates).  Returns the new state and a bool[K]
    of per-entry outcomes (``require_pending`` semantics as in
    :func:`cancel_step`).
    """
    K = t_s.shape[0]
    active = jnp.asarray(active, bool)
    pmatch = (state.pend_ts[None, :] == t_s[:, None]) & \
        (state.pend_te[None, :] == t_e[:, None]) & \
        jnp.all(state.pend_mask[None, :, :] == masks[:, None, :],
                axis=2)                                       # [K, P]
    found = jnp.any(pmatch, axis=1)
    if state.park_capacity:
        kmatch = (state.park_ts[None, :] == t_s[:, None]) & \
            (state.park_te[None, :] == t_e[:, None]) & \
            jnp.all(state.park_mask[None, :, :] == masks[:, None, :],
                    axis=2) & (state.park_seq[None, :] < T_INF)
        kfound = jnp.any(kmatch, axis=1)
        found = found | kfound
    ok = (found if require_pending else jnp.ones((K,), bool))
    ok = ok & active & ~state.overflow
    new_tl, ovf, n_keep = tl_lib.update_many(
        state.tl, t_s, t_e, masks, ok, is_add=False, with_count=True)
    do = ok & ~ovf
    P = state.pending_capacity
    slot = jnp.argmax(pmatch, axis=1)
    clear = jnp.zeros((P + 1,), bool).at[
        jnp.where(do & jnp.any(pmatch, axis=1), slot, P)].set(
        True)[:P]
    out = state._replace(
        tl=_where_tree(ovf, state.tl, new_tl),
        pend_ts=jnp.where(clear, T_INF, state.pend_ts),
        pend_te=jnp.where(clear, T_INF, state.pend_te),
        pend_mask=jnp.where(clear[:, None], jnp.uint32(0),
                            state.pend_mask),
        overflow=state.overflow | ovf,
        hw_records=jnp.maximum(state.hw_records,
                               jnp.where(jnp.any(ok), n_keep, 0)),
    )
    if state.park_capacity:
        Q = state.park_capacity
        pslot = jnp.argmax(kmatch, axis=1)
        pclear = jnp.zeros((Q + 1,), bool).at[
            jnp.where(do & kfound, pslot, Q)].set(True)[:Q]
        out = out._replace(
            park_ts=jnp.where(pclear, T_INF, out.park_ts),
            park_te=jnp.where(pclear, T_INF, out.park_te),
            park_mask=jnp.where(pclear[:, None], jnp.uint32(0),
                                out.park_mask),
            park_seq=jnp.where(pclear, T_INF, out.park_seq),
            # a successful withdrawal frees future capacity: arm the
            # EASY retry-on-release sweep for the next admit step
            park_retry=out.park_retry | jnp.any(do),
        )
    if state.tenants is not None:
        tn = state.tenants
        T = tn.n_tenants
        ctid = jnp.clip(tn.pend_tenant, 0, T - 1)
        dec = jnp.where(clear & (tn.pend_tenant >= 0), 1,
                        0).astype(jnp.int32)
        upd = dict(
            live=tn.live.at[ctid].add(-dec),
            pend_tenant=jnp.where(clear, -1, tn.pend_tenant))
        if state.park_capacity:
            ptid = jnp.clip(tn.park_tenant, 0, T - 1)
            pdec = jnp.where(pclear & (tn.park_tenant >= 0), 1,
                             0).astype(jnp.int32)
            upd["live"] = upd["live"].at[ptid].add(-pdec)
            upd["park_tenant"] = jnp.where(pclear, -1,
                                           tn.park_tenant)
            upd["park_ta"] = jnp.where(pclear, 0, tn.park_ta)
        out = out._replace(tenants=tn._replace(**upd))
    return out, do


def cancel_many(state: SchedulerState, entries, *,
                require_pending: bool = True,
                max_growths: int = MAX_DOUBLINGS
                ) -> Tuple[SchedulerState, List[bool]]:
    """Host wrapper of :func:`cancel_many_step` with overflow growth.

    ``entries`` is a sequence of ``(t_s, t_e, mask)`` triples.
    Under ``require_pending`` repeated triples within one batch are
    deduplicated on the host: the first occurrence cancels, later
    duplicates report ``False`` — exactly what sequential
    :func:`cancel_one` calls return, since the first cancel clears
    the matching slot.  With ``require_pending=False`` sequential
    cancels are blind deletes that report ``True`` every time, so
    duplicates stay active (the batched AND-NOT union is idempotent
    on occupancy) and report ``True`` as well.
    """
    entries = list(entries)
    if not entries:
        return state, []
    W = state.tl.words
    if require_pending:
        seen: dict = {}
        dup = np.zeros(len(entries), bool)
        for i, (ts, te, mk) in enumerate(entries):
            key = (int(ts), int(te), bytes(np.asarray(mk)))
            if key in seen:
                dup[i] = True
            seen[key] = i
        act = jnp.asarray(~dup)
    else:
        act = jnp.ones((len(entries),), bool)
    # pad K to the next power of two (inactive rows) so varying batch
    # sizes share O(log K) compiled shapes instead of one per size
    K_pad = tl_lib.next_pow2(len(entries)) \
        if len(entries) > 1 else 1
    pad = K_pad - len(entries)
    act = jnp.concatenate([act, jnp.zeros((pad,), bool)])
    t_s = jnp.asarray([e[0] for e in entries] + [0] * pad, jnp.int32)
    t_e = jnp.asarray([e[1] for e in entries] + [0] * pad, jnp.int32)
    masks = jnp.asarray(np.stack(
        [np.asarray(e[2], np.uint32).reshape(W) for e in entries]
        + [np.zeros(W, np.uint32)] * pad))
    start = state
    for attempt in range(max_growths + 1):
        out, done = cancel_many_step(
            start, t_s, t_e, masks, act,
            require_pending=require_pending)
        if not bool(out.overflow):
            return out, [bool(d) for d in
                         np.asarray(done)[:len(entries)]]
        if attempt < max_growths:
            start = _grown(start, out)
    raise RuntimeError(
        f"cancel_many still overflowing after {max_growths + 1} "
        f"attempts (last tried capacity {start.tl.capacity})")


# ---------------------------------------------------------------------------
# host-side decision unpacking
# ---------------------------------------------------------------------------


def parked_entries(state: SchedulerState) -> List[dict]:
    """Host view of the deferral queue in FCFS order.

    One dict per live entry: the reservation mark (``t_s``/``t_e``/
    ``pe_ids``), the request window it can still be re-placed in
    (``t_r``/``t_dl``/``n_pe``) and its arrival sequence number.  The
    first entry is the head of queue (protected under EASY).
    """
    seq = np.asarray(state.park_seq)
    ts = np.asarray(state.park_ts)
    te = np.asarray(state.park_te)
    tr = np.asarray(state.park_tr)
    tdl = np.asarray(state.park_tdl)
    npe = np.asarray(state.park_npe)
    masks = np.asarray(state.park_mask)
    dem = (np.asarray(state.park_dem)
           if state.park_dem is not None else None)
    tenant = (np.asarray(state.tenants.park_tenant)
              if state.tenants is not None else None)
    t_a = (np.asarray(state.tenants.park_ta)
           if state.tenants is not None else None)
    out = []
    for i in np.argsort(seq, kind="stable"):
        if seq[i] >= T_INF:
            continue
        entry = dict(
            seq=int(seq[i]), t_s=int(ts[i]), t_e=int(te[i]),
            t_r=int(tr[i]), t_dl=int(tdl[i]), n_pe=int(npe[i]),
            pe_ids=mask32_to_ids(masks[i]))
        if dem is not None:
            entry["demand"] = ((int(npe[i]),)
                               + tuple(int(x) for x in dem[i]))
        if tenant is not None:
            entry["tenant"] = int(tenant[i])
            entry["t_a"] = int(t_a[i])
        out.append(entry)
    return out


def mask32_to_ids(mask32: np.ndarray) -> Tuple[int, ...]:
    """uint32[W] bitmask -> sorted tuple of PE ids."""
    bits = np.unpackbits(
        np.ascontiguousarray(mask32, dtype="<u4").view(np.uint8),
        bitorder="little")
    return tuple(int(i) for i in np.nonzero(bits)[0])


def decision_to_allocation(dec: Decision) -> Optional[Allocation]:
    """One scalar :class:`Decision` -> host :class:`Allocation`."""
    if not bool(dec.accepted):
        return None
    return Allocation(
        t_s=int(dec.t_s), t_e=int(dec.t_e),
        pe_ids=mask32_to_ids(np.asarray(dec.pe_mask)),
        rectangle=Rectangle(
            t_s=int(dec.t_s), t_begin=int(dec.t_begin),
            t_end=int(dec.t_end), n_free=int(dec.n_free)),
    )


def search_result_to_allocation(res) -> Optional[Allocation]:
    """One scalar ``SearchResult`` -> host :class:`Allocation`."""
    if not bool(res.found):
        return None
    return Allocation(
        t_s=int(res.t_s), t_e=int(res.t_e),
        pe_ids=mask32_to_ids(np.asarray(res.pe_mask)),
        rectangle=Rectangle(
            t_s=int(res.t_s), t_begin=int(res.t_begin),
            t_end=int(res.t_end), n_free=int(res.n_free)),
    )


def decisions_to_allocations(dec: Decision) -> List[Optional[Allocation]]:
    """Stacked decisions -> one host allocation (or None) per request."""
    accepted = np.asarray(dec.accepted)
    t_s = np.asarray(dec.t_s)
    t_e = np.asarray(dec.t_e)
    masks = np.asarray(dec.pe_mask)
    n_free = np.asarray(dec.n_free)
    t_begin = np.asarray(dec.t_begin)
    t_end = np.asarray(dec.t_end)
    out: List[Optional[Allocation]] = []
    for i in range(accepted.shape[0]):
        if not accepted[i]:
            out.append(None)
            continue
        out.append(Allocation(
            t_s=int(t_s[i]), t_e=int(t_e[i]),
            pe_ids=mask32_to_ids(masks[i]),
            rectangle=Rectangle(
                t_s=int(t_s[i]), t_begin=int(t_begin[i]),
                t_end=int(t_end[i]), n_free=int(n_free[i]))))
    return out
