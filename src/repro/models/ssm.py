"""Mamba2 (SSD) block: chunked-parallel training, O(1) recurrent decode.

The selective state-space recurrence with scalar-per-head decay

    h_t = exp(dt_t * A_h) h_{t-1} + dt_t * (B_t (x) x_t)
    y_t = C_t . h_t + D_h x_t

is computed chunk-parallel for training/prefill (intra-chunk
quasi-attention + inter-chunk state carry via ``lax.scan``) and as the
plain recurrence for decode.  B/C are a single shared group (G=1).
This is the sub-quadratic path that makes the hybrid family runnable at
524k context.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, dense_init, rms_norm, shard

CHUNK = 256


class SSMState(NamedTuple):
    h: jax.Array        # [B, H, P, N] state
    conv: jax.Array     # [B, W-1, d_conv] conv tail


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def init_ssm(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    d_in, h, p_dim, n = dims(cfg)
    d_conv = d_in + 2 * n          # conv runs over [x, B, C]
    return {
        "w_in": dense_init(kg(), (d, 2 * d_in + 2 * n + h), d, dtype),
        "conv_w": dense_init(kg(), (cfg.conv_width, d_conv),
                             cfg.conv_width, dtype),
        "conv_b": jnp.zeros((d_conv,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((d_in,), dtype),
        "w_out": dense_init(kg(), (d_in, d), d_in, dtype),
    }


def _split_proj(p: Dict, u: jax.Array, cfg: ModelConfig):
    d_in, h, p_dim, n = dims(cfg)
    zxbcdt = jnp.einsum("btd,de->bte", u, p["w_in"])
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * n], axis=-1)
    return z, xbc, dt


def _conv(p: Dict, xbc: jax.Array, tail: jax.Array) -> Tuple[jax.Array,
                                                             jax.Array]:
    """Causal depthwise conv over time; returns output and new tail."""
    w = p["conv_w"]                          # [W, C]
    width = w.shape[0]
    padded = jnp.concatenate([tail, xbc], axis=1)
    out = sum(
        padded[:, i:padded.shape[1] - (width - 1 - i)] * w[i]
        for i in range(width))
    out = jax.nn.silu(out + p["conv_b"])
    new_tail = padded[:, -(width - 1):]
    return out, new_tail


def _gates(p: Dict, dt: jax.Array) -> Tuple[jax.Array, jax.Array]:
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                 # [H], negative decay rates
    return dt, a


def ssm_forward(p: Dict, u: jax.Array, cfg: ModelConfig,
                state: SSMState | None = None
                ) -> Tuple[jax.Array, SSMState]:
    """Full-sequence chunked forward.  u: [B, T, d]."""
    b, t, _ = u.shape
    d_in, h, p_dim, n = dims(cfg)
    z, xbc, dt = _split_proj(p, u, cfg)
    if state is None:
        tail = jnp.zeros((b, cfg.conv_width - 1, xbc.shape[-1]), xbc.dtype)
        h0 = jnp.zeros((b, h, p_dim, n), jnp.float32)
    else:
        tail, h0 = state.conv, state.h
    xbc, new_tail = _conv(p, xbc, tail)
    x, bmat, cmat = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    x = x.reshape(b, t, h, p_dim)
    x = shard(x, "batch", None, "ssm_heads", None)
    dtv, a = _gates(p, dt)

    L = min(CHUNK, t)
    assert t % L == 0, (t, L)
    nc = t // L

    def chunk(x_c, b_c, c_c, dt_c, h_in):
        """One chunk: x [B,L,H,P], b/c [B,L,N], dt [B,L,H], h [B,H,P,N]."""
        da = dt_c * a                                    # [B,L,H]
        cum = jnp.cumsum(da, axis=1)                     # log-decay prefix
        # intra-chunk quasi-attention
        cb = jnp.einsum("bln,bsn->bls", c_c, b_c)        # [B,L,L]
        rel = cum[:, :, None] - cum[:, None]             # [B,L,L,H]
        tri = jnp.tril(jnp.ones((L, L), bool))
        w_att = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0)
        w_att = w_att * cb[..., None]                    # [B,L,L,H]
        dx = x_c * dt_c[..., None]                       # [B,L,H,P]
        y_intra = jnp.einsum("blsh,bshp->blhp",
                             w_att.astype(x_c.dtype), dx)
        # inter-chunk contribution from the carried state
        y_inter = jnp.einsum("bln,bhpn->blhp", c_c, h_in) \
            * jnp.exp(cum).transpose(0, 1, 2)[..., None]
        # state update
        dec_end = jnp.exp(cum[:, -1])                    # [B,H]
        w_state = jnp.exp(cum[:, -1:, :] - cum)          # [B,L,H]
        h_out = h_in * dec_end[:, :, None, None] + jnp.einsum(
            "blhp,bln,blh->bhpn", dx.astype(jnp.float32),
            b_c.astype(jnp.float32), w_state)
        return (y_intra + y_inter).astype(x_c.dtype), h_out

    def scan_body(h_c, inp):
        x_c, b_c, c_c, dt_c = inp
        y, h_next = chunk(x_c, b_c, c_c, dt_c, h_c)
        return h_next, y

    resh = lambda v, feat: v.reshape(b, nc, L, *feat).swapaxes(0, 1)
    xs = (resh(x, (h, p_dim)), resh(bmat, (n,)), resh(cmat, (n,)),
          resh(dtv, (h,)))
    h_fin, ys = jax.lax.scan(scan_body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, t, h, p_dim)
    y = y + x * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, t, d_in) * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["w_out"])
    return shard(out, "batch", None, "model"), SSMState(h=h_fin,
                                                        conv=new_tail)


def ssm_decode(p: Dict, u: jax.Array, cfg: ModelConfig,
               state: SSMState) -> Tuple[jax.Array, SSMState]:
    """Single-token recurrent step.  u: [B, 1, d]."""
    b = u.shape[0]
    d_in, h, p_dim, n = dims(cfg)
    z, xbc, dt = _split_proj(p, u, cfg)
    xbc, new_tail = _conv(p, xbc, state.conv)
    x, bmat, cmat = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    x = x.reshape(b, 1, h, p_dim)[:, 0]                  # [B,H,P]
    dtv, a = _gates(p, dt)
    dtv = dtv[:, 0]                                      # [B,H]
    decay = jnp.exp(dtv * a)                             # [B,H]
    h_new = state.h * decay[:, :, None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", x.astype(jnp.float32),
        bmat[:, 0].astype(jnp.float32), dtv)
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0], h_new.astype(x.dtype))
    y = y + x * p["d_skip"][None, :, None].astype(x.dtype)
    y = y.reshape(b, 1, d_in) * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["w_out"])
    return shard(out, "batch", None, "model"), SSMState(h=h_new,
                                                        conv=new_tail)


def init_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    d_in, h, p_dim, n = dims(cfg)
    return SSMState(
        h=jnp.zeros((batch, h, p_dim, n), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, d_in + 2 * n), dtype),
    )
