"""Gated (SwiGLU) feed-forward block with TP sharding on the hidden axis."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, dense_init, shard


def init_mlp(kg: KeyGen, d_model: int, d_ff: int, dtype) -> Dict:
    return {
        "w_gate": dense_init(kg(), (d_model, d_ff), d_model, dtype),
        "w_up": dense_init(kg(), (d_model, d_ff), d_model, dtype),
        "w_down": dense_init(kg(), (d_ff, d_model), d_ff, dtype),
    }


def mlp(p: Dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(jnp.einsum("btd,df->btf", x, p["w_gate"]))
    h = h * jnp.einsum("btd,df->btf", x, p["w_up"])
    h = shard(h, "batch", None, "ff")
    out = jnp.einsum("btf,fd->btd", h, p["w_down"])
    return shard(out, "batch", None, "model")
