"""Mixture-of-experts layer with capacity-based sparse dispatch.

Sort-based dispatch (Megablocks-style, adapted to static shapes): the
(token, k) assignments are ranked per expert via one stable sort, then
scattered into an ``[E, C]`` index table — no ``[T, E, C]`` one-hot is
ever materialised, so the per-device activation footprint stays
``O(E_local * C * d)``.  Experts are sharded over the "model" axis
(expert parallelism); the scatter/gather pair lowers to all-to-all
collectives on the production mesh.

Aux losses: standard load-balancing loss (Switch) + router z-loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, dense_init, shard


def init_moe(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": dense_init(kg(), (d, e), d, jnp.float32),
        "w_gate": dense_init(kg(), (e, d, f), d, dtype),
        "w_up": dense_init(kg(), (e, d, f), d, dtype),
        "w_down": dense_init(kg(), (e, f, d), f, dtype),
    }


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(cap, cfg.top_k)


def moe(p: Dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, Dict]:
    """x: [B, T, d] -> (out [B, T, d], aux losses)."""
    b, t, d = x.shape
    n = b * t
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(n, cfg)
    xf = x.reshape(n, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # [n, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # ---- rank of each assignment within its expert (stable sort) ----
    flat_e = expert_ids.reshape(-1)                          # [n*k]
    order = jnp.argsort(flat_e, stable=True)                 # group by expert
    ranked = jnp.zeros_like(flat_e).at[order].set(
        jnp.arange(n * k, dtype=flat_e.dtype))
    seg_start = jnp.searchsorted(flat_e[order], jnp.arange(e))
    pos_in_expert = ranked - seg_start[flat_e]               # [n*k]
    keep = pos_in_expert < cap                               # drop overflow

    # ---- dispatch: scatter token rows into the [E, C] table ----------
    slot = jnp.where(keep, flat_e * cap + pos_in_expert, e * cap)
    token_of = jnp.repeat(jnp.arange(n), k)
    table = jnp.full((e * cap + 1,), n, jnp.int32).at[slot].set(
        token_of.astype(jnp.int32), mode="drop")
    table = table[:-1].reshape(e, cap)                       # [E, C]
    if cfg.moe_quant_dispatch:
        # int8 all-to-all payloads (EXPERIMENTS.md §Perf B2): the
        # gather that crosses the EP boundary moves 1 byte/element +
        # one bf16 scale per token instead of 2 bytes/element.
        scale = jnp.max(jnp.abs(xf.astype(jnp.float32)), axis=-1,
                        keepdims=True) / 127.0 + 1e-9
        xq = jnp.round(xf.astype(jnp.float32) / scale).astype(jnp.int8)
        xq = jnp.concatenate([xq, jnp.zeros((1, d), jnp.int8)])
        sq = jnp.concatenate(
            [scale.astype(jnp.bfloat16), jnp.ones((1, 1), jnp.bfloat16)])
        ex_q = xq[table]                                     # [E, C, d]
        ex_q = shard(ex_q, "experts", None, None)
        ex_s = shard(sq[table], "experts", None, None)       # [E, C, 1]
        ex_in = (ex_q.astype(jnp.float32)
                 * ex_s.astype(jnp.float32)).astype(x.dtype)
    else:
        xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)])
        ex_in = xpad[table]                                  # [E, C, d]
        ex_in = shard(ex_in, "experts", None, None)

    # ---- expert computation (dense einsum over local experts) --------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ex_in, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", ex_in, p["w_up"])
    ex_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])      # [E, C, d]
    ex_out = shard(ex_out, "experts", None, None)

    # ---- combine: gather back and weight by the gates -----------------
    if cfg.moe_quant_dispatch:
        s_out = jnp.max(jnp.abs(ex_out.astype(jnp.float32)), axis=-1,
                        keepdims=True) / 127.0 + 1e-9
        oq = jnp.round(ex_out.astype(jnp.float32) / s_out).astype(
            jnp.int8)
        oq = shard(oq, "experts", None, None)
        vals = (oq.reshape(-1, d).astype(jnp.float32)
                * s_out.reshape(-1, 1))
    else:
        vals = ex_out.reshape(-1, d).astype(jnp.float32)
    weighted = vals * _slot_gate(gate_vals, keep, slot, e, cap)[..., None]
    flat_out = jnp.zeros((n + 1, d), jnp.float32).at[
        table.reshape(-1)].add(weighted, mode="drop")
    out = flat_out[:n].reshape(b, t, d)
    out = shard(out, "batch", None, "model")

    # ---- aux losses ----------------------------------------------------
    me = jnp.mean(probs, axis=0)                              # [e]
    ce = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32), axis=0)
    aux = {
        "load_balance": e * jnp.sum(me * ce),
        "router_z": jnp.mean(
            jnp.square(jax.nn.logsumexp(logits, axis=-1))),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out.astype(x.dtype), aux


def _slot_gate(gate_vals: jax.Array, keep: jax.Array, slot: jax.Array,
               e: int, cap: int) -> jax.Array:
    """Gate weight aligned with the [E*C] slot table rows."""
    flat_g = gate_vals.reshape(-1)
    g = jnp.zeros((e * cap + 1,), flat_g.dtype).at[slot].set(
        jnp.where(keep, flat_g, 0.0), mode="drop")
    return g[:-1].reshape(e, cap).reshape(-1)
