"""xLSTM blocks: mLSTM (matrix memory, parallel+recurrent) and sLSTM.

mLSTM training/prefill uses the stabilised parallel (quadratic) form;
decode uses the O(1) recurrence over the matrix memory ``C`` — the
sub-quadratic path that makes 524k-token decode runnable.  sLSTM keeps
per-unit scalar memory with recurrent mixing and is evaluated with
``lax.scan`` over time.  Blocks follow the xLSTM paper's pre-LN
up/down-projection structure with a multiplicative gate branch.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import KeyGen, dense_init, rms_norm, shard


class MLSTMState(NamedTuple):
    c: jax.Array    # [B, H, K, V] matrix memory
    n: jax.Array    # [B, H, K]
    m: jax.Array    # [B, H]


class SLSTMState(NamedTuple):
    h: jax.Array    # [B, H, D]
    c: jax.Array
    n: jax.Array
    m: jax.Array


def _heads(cfg: ModelConfig) -> Tuple[int, int]:
    h = cfg.n_heads
    return h, cfg.d_model // h


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    h, hd = _heads(cfg)
    return {
        "w_qkv": dense_init(kg(), (d, h, 3 * hd), d, dtype),
        "w_if": dense_init(kg(), (d, h, 2), d, jnp.float32),
        "b_if": jnp.concatenate(
            [jnp.zeros((h, 1)), 3.0 * jnp.ones((h, 1))], -1
        ).astype(jnp.float32),
        "w_gate": dense_init(kg(), (d, d), d, dtype),
        "norm": jnp.ones((h, hd), dtype),
        "w_out": dense_init(kg(), (d, d), d, dtype),
    }


def _mlstm_proj(p: Dict, x: jax.Array, cfg: ModelConfig):
    h, hd = _heads(cfg)
    qkv = jnp.einsum("btd,dhe->bthe", x, p["w_qkv"])
    q, k, v = jnp.split(qkv, 3, axis=-1)                  # [B,T,H,hd]
    gates = jnp.einsum("btd,dhg->bthg",
                       x.astype(jnp.float32), p["w_if"]) + p["b_if"]
    i_pre, f_pre = gates[..., 0], gates[..., 1]           # [B,T,H]
    return (shard(q, "batch", None, "ssm_heads", None),
            shard(k, "batch", None, "ssm_heads", None),
            shard(v, "batch", None, "ssm_heads", None), i_pre, f_pre)


MLSTM_CHUNK = 256
_M_INIT = -1e30


def mlstm_parallel(p: Dict, x: jax.Array, cfg: ModelConfig,
                   state: Optional[MLSTMState] = None,
                   return_state: bool = False):
    """Chunkwise-parallel stabilised form.  x: [B,T,d].

    Intra-chunk: quadratic decay-weighted attention (L x L).  Inter-
    chunk: the matrix memory (C, n, m) is carried by ``lax.scan``, so
    peak memory is O(T*L) instead of O(T^2) — required at 32k prefill.
    """
    b, t, d = x.shape
    h, hd = _heads(cfg)
    q, k, v, i_pre, f_pre = _mlstm_proj(p, x, cfg)
    k = k / jnp.sqrt(hd).astype(k.dtype)
    log_f = jax.nn.log_sigmoid(f_pre)                     # [B,T,H]
    if state is None:
        state = init_mlstm_state(cfg, b)

    L = min(MLSTM_CHUNK, t)
    assert t % L == 0, (t, L)
    nc = t // L
    tri = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]

    def chunk(carry: MLSTMState, inp):
        q_c, k_c, v_c, i_c, lf_c = inp          # [B,L,H,*] / [B,L,H]
        fc = jnp.cumsum(lf_c, axis=1)           # inclusive prefix
        # intra-chunk log weights D[t,s] = F_t - F_s + i_s   (s <= t)
        dmat = jnp.where(tri, fc[:, :, None] - fc[:, None] + i_c[:, None],
                         _M_INIT)               # [B,L,L,H]
        m_intra = jnp.max(dmat, axis=2)         # [B,L,H]
        m_inter = fc + carry.m[:, None]         # [B,L,H]
        m_t = jnp.maximum(m_intra, m_inter)
        w = jnp.exp(dmat - m_t[:, :, None])
        scores = jnp.einsum("blhk,bshk->blsh", q_c,
                            k_c).astype(jnp.float32) * w
        inter_scale = jnp.exp(m_inter - m_t)    # [B,L,H]
        num = jnp.einsum("blsh,bshv->blhv", scores,
                         v_c.astype(jnp.float32))
        num = num + jnp.einsum("blhk,bhkv->blhv", q_c.astype(jnp.float32),
                               carry.c) * inter_scale[..., None]
        den = jnp.sum(scores, axis=2) + jnp.einsum(
            "blhk,bhk->blh", q_c.astype(jnp.float32),
            carry.n) * inter_scale
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # ---- state carry ----
        f_tot = fc[:, -1]                       # [B,H]
        m_out = jnp.maximum(f_tot + carry.m,
                            jnp.max(fc[:, -1:] - fc + i_c, axis=1))
        c_scale = jnp.exp(f_tot + carry.m - m_out)
        s_scale = jnp.exp((fc[:, -1:] - fc + i_c) - m_out[:, None])
        c_new = carry.c * c_scale[..., None, None] + jnp.einsum(
            "blhk,blhv,blh->bhkv", k_c.astype(jnp.float32),
            v_c.astype(jnp.float32), s_scale)
        n_new = carry.n * c_scale[..., None] + jnp.einsum(
            "blhk,blh->bhk", k_c.astype(jnp.float32), s_scale)
        return MLSTMState(c_new, n_new, m_out), y.astype(x.dtype)

    resh = lambda a: a.reshape(b, nc, L, *a.shape[2:]).swapaxes(0, 1)
    final, ys = jax.lax.scan(
        chunk, state, (resh(q), resh(k), resh(v), resh(i_pre), resh(log_f)))
    out = ys.swapaxes(0, 1).reshape(b, t, h, hd)
    out = _mlstm_out(p, out, x, cfg)
    if return_state:
        return out, final
    return out


def mlstm_decode(p: Dict, x: jax.Array, cfg: ModelConfig,
                 state: MLSTMState) -> Tuple[jax.Array, MLSTMState]:
    """One-token recurrence.  x: [B,1,d]."""
    h, hd = _heads(cfg)
    q, k, v, i_pre, f_pre = _mlstm_proj(p, x, cfg)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                   # [B,H,hd]
    i_pre, f_pre = i_pre[:, 0], f_pre[:, 0]               # [B,H]
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + state.m, i_pre)
    f_s = jnp.exp(log_f + state.m - m_new)[..., None]
    i_s = jnp.exp(i_pre - m_new)[..., None]
    kf = k.astype(jnp.float32) / jnp.sqrt(hd)
    c_new = state.c * f_s[..., None] + i_s[..., None] * (
        kf[..., :, None] * v.astype(jnp.float32)[..., None, :])
    n_new = state.n * f_s + i_s * kf
    num = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), c_new)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), n_new)),
        jnp.exp(-m_new))
    out = (num / den[..., None]).astype(x.dtype)[:, None]  # [B,1,H,hd]
    return _mlstm_out(p, out, x, cfg), MLSTMState(c_new, n_new, m_new)


def _mlstm_out(p: Dict, heads_out: jax.Array, x: jax.Array,
               cfg: ModelConfig) -> jax.Array:
    b, t = x.shape[:2]
    heads_out = rms_norm(heads_out, p["norm"], cfg.norm_eps)
    flat = heads_out.reshape(b, t, cfg.d_model)
    gate = jax.nn.silu(jnp.einsum("btd,de->bte", x, p["w_gate"]))
    out = jnp.einsum("bte,ed->btd", flat * gate, p["w_out"])
    return shard(out, "batch", None, "model")


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    h, hd = _heads(cfg)
    return MLSTMState(
        c=jnp.zeros((batch, h, hd, hd), jnp.float32),
        n=jnp.zeros((batch, h, hd), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    h, hd = _heads(cfg)
    return {
        "w_x": dense_init(kg(), (d, h, 4 * hd), d, jnp.float32),
        "r_h": dense_init(kg(), (h, hd, 4 * hd), hd, jnp.float32),
        "bias": jnp.zeros((h, 4 * hd), jnp.float32),
        "norm": jnp.ones((h, hd), dtype),
        "w_out": dense_init(kg(), (d, d), d, dtype),
    }


def slstm_forward(p: Dict, x: jax.Array, cfg: ModelConfig,
                  state: Optional[SLSTMState] = None
                  ) -> Tuple[jax.Array, SLSTMState]:
    """Recurrent scan over time.  x: [B,T,d]."""
    b, t, d = x.shape
    h, hd = _heads(cfg)
    if state is None:
        state = init_slstm_state(cfg, b)
    xg = jnp.einsum("btd,dhe->bthe", x.astype(jnp.float32),
                    p["w_x"]) + p["bias"]                  # [B,T,H,4hd]

    def step(s: SLSTMState, xg_t):
        rg = jnp.einsum("bhk,hke->bhe", s.h, p["r_h"])
        g = xg_t + rg                                      # [B,H,4hd]
        zi, ii, fi, oi = jnp.split(g, 4, axis=-1)
        z = jnp.tanh(zi)
        o = jax.nn.sigmoid(oi)
        log_f = jax.nn.log_sigmoid(fi)
        m_new = jnp.maximum(log_f + s.m, ii)
        i_s = jnp.exp(ii - m_new)
        f_s = jnp.exp(log_f + s.m - m_new)
        c_new = f_s * s.c + i_s * z
        n_new = jnp.maximum(f_s * s.n + i_s, 1e-6)
        h_new = o * c_new / n_new
        return SLSTMState(h_new, c_new, n_new, m_new), h_new

    xg_t = xg.swapaxes(0, 1)                               # [T,B,H,4hd]
    final, hs = jax.lax.scan(step, state, xg_t)
    hs = hs.swapaxes(0, 1)                                 # [B,T,H,hd]
    hs = rms_norm(hs.astype(x.dtype), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", hs.reshape(b, t, d), p["w_out"])
    return shard(out, "batch", None, "model"), final


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    h, hd = _heads(cfg)
    zero = jnp.zeros((batch, h, hd), jnp.float32)
    return SLSTMState(h=zero, c=zero, n=zero + 1e-6,
                      m=jnp.full((batch, h, hd), -1e30, jnp.float32))
