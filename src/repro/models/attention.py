"""Grouped-query attention: training, prefill, and cached decode paths.

Covers the needs of the assigned pool: GQA with arbitrary kv-head
counts (MHA when ``n_kv_heads == n_heads``), optional qk-norm (qwen3),
RoPE, cross-attention (seamless decoder, llama-vision), and a sliding-
window cached path used by the hybrid family at 500k context.

Softmax runs in f32; logits are scaled by ``1/sqrt(hd)``.  All einsums
keep the head axis explicit so TP sharding (heads over "model") applies
without reshapes.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import (
    KeyGen,
    apply_rope,
    dense_init,
    rms_norm,
    rope_freqs,
    shard,
)

NEG_INF = -1e30


def init_attention(kg: KeyGen, cfg: ModelConfig, dtype,
                   cross: bool = False) -> Dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": dense_init(kg(), (d, hq, hd), d, dtype),
        "wk": dense_init(kg(), (d, hkv, hd), d, dtype),
        "wv": dense_init(kg(), (d, hkv, hd), d, dtype),
        "wo": dense_init(kg(), (hq, hd, d), hq * hd, dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_q(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    return shard(q, "batch", None, "heads", None)


def _project_kv(p: Dict, x: jax.Array, cfg: ModelConfig) -> Tuple[
        jax.Array, jax.Array]:
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if "k_norm" in p:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return k, v


def _expand_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """Repeat KV heads to the full query-head count.

    Keeps every attention einsum head-local under TP even when the KV
    head count does not divide the model axis (the repeated tensor has
    Hq heads, which the rules shard); the repeat of a replicated or
    head-sharded input is local.
    """
    if n_rep == 1:
        return x
    # no explicit constraint: GSPMD propagates the right layout from
    # the surrounding einsum (heads-sharded in train/prefill, context-
    # sharded in decode); forcing "heads" here fights the decode layout.
    return jnp.repeat(x, n_rep, axis=2)


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
          mask: Optional[jax.Array], n_rep: int) -> jax.Array:
    """q: [B,T,Hq,hd]; k,v: [B,S,Hkv,hd]; mask broadcastable [B,1,T,S]."""
    b, t, hq, hd = q.shape
    k = _expand_kv(k, n_rep)
    v = _expand_kv(v, n_rep)
    logits = jnp.einsum("bthk,bshk->bhts", q, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    if mask is not None:
        logits = logits + jnp.where(mask, 0.0, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhts,bshk->bthk", probs, v)
    return out


# Above this many query positions, the full [T, S] score matrix is
# replaced by the blockwise online-softmax path (flash-style in XLA).
BLOCKWISE_THRESHOLD = 8192
Q_BLOCK = 1024
KV_BLOCK = 1024


def _blockwise_sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
                    n_rep: int, window: int = 0) -> jax.Array:
    """Causal online-softmax attention, O(Lq * S) memory per block.

    ``lax.map`` over query blocks; inner ``fori_loop`` visits only the
    KV blocks at or before the query block (plus the window bound), so
    runtime work matches the causal triangle.
    """
    b, t, hq, hd = q.shape
    s = k.shape[1]
    lq, lkv = min(Q_BLOCK, t), min(KV_BLOCK, s)
    nq = t // lq
    scale = 1.0 / np.sqrt(hd)

    def one_q_block(iq):
        q_i = jax.lax.dynamic_slice_in_dim(q, iq * lq, lq, axis=1)
        q_pos = iq * lq + jnp.arange(lq)

        def body(jk, carry):
            m, den, acc = carry
            k_j = _expand_kv(
                jax.lax.dynamic_slice_in_dim(k, jk * lkv, lkv, axis=1),
                n_rep)
            v_j = _expand_kv(
                jax.lax.dynamic_slice_in_dim(v, jk * lkv, lkv, axis=1),
                n_rep)
            kv_pos = jk * lkv + jnp.arange(lkv)
            logits = jnp.einsum("bthk,bshk->bhts", q_i,
                                k_j).astype(jnp.float32) * scale
            mask = q_pos[:, None] >= kv_pos[None, :]
            if window:
                mask &= q_pos[:, None] - kv_pos[None, :] < window
            logits = logits + jnp.where(mask, 0.0, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            den_new = den * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None].astype(acc.dtype) + jnp.einsum(
                "bhts,bshk->bhtk", p.astype(v.dtype), v_j)
            return m_new, den_new, acc_new

        shape = (b, hq, lq)
        init = (jnp.full(shape, -jnp.inf, jnp.float32),
                jnp.zeros(shape, jnp.float32),
                jnp.zeros(shape + (hd,), v.dtype))
        n_blocks = (iq * lq + lq + lkv - 1) // lkv  # causal upper bound
        m, den, acc = jax.lax.fori_loop(0, n_blocks, body, init)
        out = acc / jnp.maximum(den, 1e-30)[..., None].astype(acc.dtype)
        return out                                 # [B,H,Lq,hd]

    outs = jax.lax.map(one_q_block, jnp.arange(nq))   # [nq,B,H,Lq,hd]
    out = jnp.moveaxis(outs, 0, 2)                    # [B,H,nq,Lq,hd]
    return out.reshape(b, hq, t, hd).transpose(0, 2, 1, 3)


def self_attention(p: Dict, x: jax.Array, cfg: ModelConfig,
                   rope: Tuple[jax.Array, jax.Array],
                   positions: Optional[jax.Array] = None,
                   window: int = 0, return_kv: bool = False):
    """Causal self-attention over a full sequence (train / prefill)."""
    b, t, _ = x.shape
    q = _project_q(p, x, cfg)
    k, v = _project_kv(p, x, cfg)
    cos, sin = rope
    q = apply_rope(q, cos, sin, positions)
    k = apply_rope(k, cos, sin, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    if t > BLOCKWISE_THRESHOLD and t % Q_BLOCK == 0:
        out = _blockwise_sdpa(q, k, v, n_rep, window)
    else:
        idx = jnp.arange(t)
        mask = idx[None, :, None] >= idx[None, None, :]
        if window:
            mask = mask & (idx[None, :, None] - idx[None, None, :] < window)
        out = _sdpa(q, k, v, mask[:, None], n_rep)
    out = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    out = shard(out, "batch", None, "model")
    if return_kv:
        # collected for the decode cache, which is context-sharded
        k = shard(k, "batch", "seq_sp", None, None)
        v = shard(v, "batch", "seq_sp", None, None)
        return out, (k, v)
    return out


def cross_attention(p: Dict, x: jax.Array, kv_cache: Tuple[jax.Array,
                                                           jax.Array],
                    cfg: ModelConfig,
                    enc_mask: Optional[jax.Array] = None) -> jax.Array:
    """Attend from decoder states to precomputed encoder K/V."""
    k, v = kv_cache
    q = _project_q(p, x, cfg)
    mask = None if enc_mask is None else enc_mask[:, None, None, :]
    out = _sdpa(q, k, v, mask, cfg.n_heads // cfg.n_kv_heads)
    out = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return shard(out, "batch", None, "model")


def encoder_kv(p: Dict, enc_out: jax.Array, cfg: ModelConfig) -> Tuple[
        jax.Array, jax.Array]:
    return _project_kv(p, enc_out, cfg)


# ---------------------------------------------------------------------------
# cached decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype) -> Dict[str, jax.Array]:
    hkv, hd = cfg.n_kv_heads, cfg.hd
    store = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
    mk = lambda: shard(jnp.zeros((batch, max_len, hkv, hd), store),
                       "batch", "seq_sp", None, None)
    cache = {"k": mk(), "v": mk()}
    if cfg.kv_cache_dtype == "int8":
        # per-(position, head) dequantisation scales
        mks = lambda: shard(
            jnp.zeros((batch, max_len, hkv), jnp.bfloat16),
            "batch", "seq_sp", None)
        cache["k_scale"] = mks()
        cache["v_scale"] = mks()
    return cache


def quantize_kv(x: jax.Array):
    """bf16 [.., S, H, hd] -> (int8 values, bf16 per-(S,H) scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 \
        + 1e-9
    q = jnp.round(x.astype(jnp.float32)
                  / scale[..., None]).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequant_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)


def decode_attention(p: Dict, x: jax.Array, cache: Dict[str, jax.Array],
                     pos: jax.Array, cfg: ModelConfig,
                     rope: Tuple[jax.Array, jax.Array],
                     window: int = 0) -> Tuple[jax.Array, Dict]:
    """One-token decode: update the KV cache at ``pos`` and attend.

    x: [B, 1, d]; cache k/v: [B, S, Hkv, hd]; pos: scalar int32.
    With ``window > 0`` the cache is a ring buffer of ``window`` slots
    (sliding-window attention for the 500k hybrid decode).
    """
    b = x.shape[0]
    s_max = cache["k"].shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = _project_q(p, x, cfg)
    k_new, v_new = _project_kv(p, x, cfg)
    cos, sin = rope
    q = apply_rope(q, cos, sin, positions)
    k_new = apply_rope(k_new, cos, sin, positions)
    slot = jnp.where(window > 0, pos % jnp.maximum(s_max, 1), pos)
    # decode KV caches shard the *sequence* axis over the model axis
    # (context-parallel decode): softmax/combine reductions over S then
    # lower to psums, and head-count divisibility never matters.
    new_cache = {}
    if cfg.kv_cache_dtype == "int8":
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k_store = jax.lax.dynamic_update_slice(
            cache["k"], kq, (0, slot, 0, 0))
        v_store = jax.lax.dynamic_update_slice(
            cache["v"], vq, (0, slot, 0, 0))
        k_sc = jax.lax.dynamic_update_slice(
            cache["k_scale"], ks, (0, slot, 0))
        v_sc = jax.lax.dynamic_update_slice(
            cache["v_scale"], vs, (0, slot, 0))
        k_store = shard(k_store, "batch", "seq_sp", None, None)
        v_store = shard(v_store, "batch", "seq_sp", None, None)
        new_cache = {"k": k_store, "v": v_store,
                     "k_scale": k_sc, "v_scale": v_sc}
        k = dequant_kv(k_store, k_sc, x.dtype)
        v = dequant_kv(v_store, v_sc, x.dtype)
    else:
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new, (0, slot, 0, 0))
        k = shard(k, "batch", "seq_sp", None, None)
        v = shard(v, "batch", "seq_sp", None, None)
        new_cache = {"k": k, "v": v}
    idx = jnp.arange(s_max)
    if window:
        valid = (idx[None, :] <= slot) | (pos >= s_max)
    else:
        valid = idx[None, :] <= pos
    mask = valid[:, None, None, :]   # [1,1,1,S] broadcast over batch
    out = _sdpa(q, k, v, mask, cfg.n_heads // cfg.n_kv_heads)
    out = jnp.einsum("bthk,hkd->btd", out, p["wo"])
    return shard(out, "batch", None, "model"), new_cache


def make_rope(cfg: ModelConfig, max_pos: int) -> Tuple[jax.Array,
                                                       jax.Array]:
    return rope_freqs(cfg.hd, max_pos, cfg.rope_theta)
