"""Model assembly for the assigned architecture pool.

One functional API over six families (dense / moe / hybrid / ssm /
encdec / vlm):

    params = init_params(cfg, key)
    loss, metrics = loss_fn(params, cfg, batch)          # training
    logits, cache = prefill(params, cfg, tokens, extra)  # serving
    logits, cache = decode_step(params, cfg, cache, tokens)

Homogeneous layer stacks are stacked ``[L, ...]`` and executed with
``lax.scan`` (compact HLO, fast 512-device compiles).  Heterogeneous
interleaves run as grouped scans: zamba2 is 14 groups of [shared-attn;
6 x mamba2], xLSTM is groups of [7 x mLSTM; sLSTM].  Prefill collects
per-layer roped K/V as scan outputs; decode carries per-layer caches as
scanned xs/ys.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import mlp as mlp_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.common import (
    KeyGen,
    dense_init,
    embed_init,
    rms_norm,
    shard,
)

MAX_ROPE_POS = 1 << 20    # covers 524k decode with headroom


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(kg: KeyGen, cfg: ModelConfig, kind: str, dt) -> Dict:
    p: Dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), dt)}
    if kind == "attn_mlp":
        p["attn"] = attn_lib.init_attention(kg, cfg, dt)
        p["ln2"] = jnp.ones((cfg.d_model,), dt)
        p["mlp"] = mlp_lib.init_mlp(kg, cfg.d_model, cfg.d_ff, dt)
    elif kind == "attn_moe":
        p["attn"] = attn_lib.init_attention(kg, cfg, dt)
        p["ln2"] = jnp.ones((cfg.d_model,), dt)
        p["moe"] = moe_lib.init_moe(kg, cfg, dt)
    elif kind == "mamba":
        p["ssm"] = ssm_lib.init_ssm(kg, cfg, dt)
    elif kind == "mlstm":
        p["mlstm"] = xlstm_lib.init_mlstm(kg, cfg, dt)
    elif kind == "slstm":
        p["slstm"] = xlstm_lib.init_slstm(kg, cfg, dt)
    elif kind == "cross":
        p["attn"] = attn_lib.init_attention(kg, cfg, dt, cross=True)
        p["ln2"] = jnp.ones((cfg.d_model,), dt)
        p["mlp"] = mlp_lib.init_mlp(kg, cfg.d_model, cfg.d_ff, dt)
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_mlp"] = jnp.zeros((), jnp.float32)
    else:
        raise ValueError(kind)
    return p


def _stack_layers(kg: KeyGen, cfg: ModelConfig, kind: str, n: int,
                  dt) -> Dict:
    layers = [_init_layer(kg, cfg, kind, dt) for _ in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def _hybrid_groups(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_full_groups, group_size, remainder) for the zamba2 stack."""
    g = cfg.attn_every
    return cfg.n_layers // g, g, cfg.n_layers % g


def _xlstm_groups(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_slstm, mlstm_per_group)."""
    every = cfg.slstm_every or (cfg.n_layers + 1)
    n_s = cfg.n_layers // every
    return n_s, every - 1


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    kg = KeyGen(key)
    dt = _dtype(cfg)
    p: Dict[str, Any] = {
        "tok_embed": embed_init(kg(), (cfg.vocab, cfg.d_model), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(
            kg(), (cfg.d_model, cfg.vocab), cfg.d_model, dt)

    fam = cfg.family
    if fam in ("dense", "moe"):
        kind = "attn_moe" if fam == "moe" else "attn_mlp"
        p["layers"] = _stack_layers(kg, cfg, kind, cfg.n_layers, dt)
    elif fam == "hybrid":
        p["layers"] = _stack_layers(kg, cfg, "mamba", cfg.n_layers, dt)
        p["shared_attn"] = _init_layer(kg, cfg, "attn_mlp", dt)
    elif fam == "ssm":
        n_s, _ = _xlstm_groups(cfg)
        p["layers"] = _stack_layers(
            kg, cfg, "mlstm", cfg.n_layers - n_s, dt)
        if n_s:
            p["slstm_layers"] = _stack_layers(kg, cfg, "slstm", n_s, dt)
    elif fam == "encdec":
        p["enc_embed_proj"] = dense_init(
            kg(), (cfg.d_model, cfg.d_model), cfg.d_model, dt)
        p["enc_layers"] = _stack_layers(
            kg, cfg, "attn_mlp", cfg.n_enc_layers, dt)
        p["enc_norm"] = jnp.ones((cfg.d_model,), dt)
        p["layers"] = _stack_layers(kg, cfg, "attn_mlp", cfg.n_layers, dt)
        p["cross_layers"] = _stack_layers(
            kg, cfg, "cross", cfg.n_layers, dt)
    elif fam == "vlm":
        p["img_proj"] = dense_init(
            kg(), (cfg.vision_dim, cfg.d_model), cfg.vision_dim, dt)
        p["layers"] = _stack_layers(kg, cfg, "attn_mlp", cfg.n_layers, dt)
        n_cross = cfg.n_layers // cfg.cross_attn_every
        p["cross_layers"] = _stack_layers(kg, cfg, "cross", n_cross, dt)
    else:
        raise ValueError(fam)
    return p


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def _attn_block(pl: Dict, x, cfg, rope, window=0, return_kv=False):
    res = attn_lib.self_attention(
        pl["attn"], rms_norm(x, pl["ln1"], cfg.norm_eps), cfg, rope,
        window=window, return_kv=return_kv)
    h, kv = res if return_kv else (res, None)
    x = x + h
    if "moe" in pl:
        h, aux = moe_lib.moe(pl["moe"],
                             rms_norm(x, pl["ln2"], cfg.norm_eps), cfg)
    else:
        h = mlp_lib.mlp(pl["mlp"], rms_norm(x, pl["ln2"], cfg.norm_eps))
        aux = {}
    out = x + h
    if cfg.seq_parallel:
        # Megatron-SP: the residual stream lives sequence-sharded, so
        # the per-block psums lower to reduce-scatter (+ all-gather at
        # the next projection) — half the all-reduce ring bytes.
        out = shard(out, "batch", "seq_sp", None)
    return out, aux, kv


def _attn_block_decode(pl: Dict, x, cache, pos, cfg, rope, window=0):
    h, cache = attn_lib.decode_attention(
        pl["attn"], rms_norm(x, pl["ln1"], cfg.norm_eps), cache, pos,
        cfg, rope, window=window)
    x = x + h
    if "moe" in pl:
        h, _ = moe_lib.moe(pl["moe"],
                           rms_norm(x, pl["ln2"], cfg.norm_eps), cfg)
    else:
        h = mlp_lib.mlp(pl["mlp"], rms_norm(x, pl["ln2"], cfg.norm_eps))
    return x + h, cache


def _cross_block(pl: Dict, x, enc_kv, cfg, gated: bool):
    h = attn_lib.cross_attention(
        pl["attn"], rms_norm(x, pl["ln1"], cfg.norm_eps), enc_kv, cfg)
    if gated:
        h = h * jnp.tanh(pl["gate_attn"]).astype(h.dtype)
    x = x + h
    h = mlp_lib.mlp(pl["mlp"], rms_norm(x, pl["ln2"], cfg.norm_eps))
    if gated:
        h = h * jnp.tanh(pl["gate_mlp"]).astype(h.dtype)
    return x + h


def _remat(fn):
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.nothing_saveable)


# ---------------------------------------------------------------------------
# full-sequence forward
# ---------------------------------------------------------------------------

class ForwardOut(NamedTuple):
    hidden: jax.Array
    aux: Dict[str, jax.Array]
    kv: Any           # per-layer roped K/V (prefill mode) or None
    states: Any       # recurrent states (hybrid/ssm prefill) or None


def forward(params: Dict, cfg: ModelConfig, tokens: jax.Array,
            extra: Optional[Dict[str, jax.Array]] = None,
            collect: bool = False) -> ForwardOut:
    """Full-sequence forward.  ``collect=True`` gathers decode caches."""
    extra = extra or {}
    dt = _dtype(cfg)
    b, t = tokens.shape
    x = params["tok_embed"][tokens]
    x = shard(x, "batch", None, "model")
    rope = attn_lib.make_rope(cfg, max(t, 1))
    fam = cfg.family
    aux: Dict[str, jax.Array] = {}
    kv_out, states_out = None, None

    if fam in ("dense", "moe"):
        def body(carry, pl):
            y, a, kv = _attn_block(pl, carry, cfg, rope,
                                   return_kv=collect)
            return y, (a, kv) if collect else a
        x, ys = jax.lax.scan(_remat(body), x, params["layers"])
        auxs = ys[0] if collect else ys
        if collect:
            kv_out = ys[1]
        if fam == "moe":
            aux = {k: jnp.mean(v) for k, v in auxs.items()}

    elif fam == "hybrid":
        x, kv_out, states_out = _hybrid_forward(
            params, cfg, x, rope, collect)

    elif fam == "ssm":
        x, states_out = _xlstm_forward(params, cfg, x, collect)

    elif fam == "encdec":
        rope = attn_lib.make_rope(cfg, max(t, cfg.enc_seq))
        enc_out = _encode(params, cfg, extra["enc_frames"], rope)
        enc_kvs = _cross_kvs(params["cross_layers"], enc_out, cfg)

        def body(carry, inp):
            pl, cl, ekv = inp
            y, _, kv = _attn_block(pl, carry, cfg, rope,
                                   return_kv=collect)
            y = _cross_block(cl, y, ekv, cfg, gated=False)
            return y, kv
        x, kv_out = jax.lax.scan(
            _remat(body), x,
            (params["layers"], params["cross_layers"], enc_kvs))
        states_out = enc_kvs

    elif fam == "vlm":
        img = jnp.einsum("bnv,vd->bnd",
                         extra["image_embeds"].astype(dt),
                         params["img_proj"])
        img_kvs = _cross_kvs(params["cross_layers"], img, cfg)
        every = cfg.cross_attn_every

        def body(carry, inp):
            i, pl = inp
            y, _, kv = _attn_block(pl, carry, cfg, rope,
                                   return_kv=collect)

            def with_cross(z):
                ci = i // every
                cl = jax.tree.map(lambda a: a[ci],
                                  params["cross_layers"])
                ckv = jax.tree.map(lambda a: a[ci], img_kvs)
                return _cross_block(cl, z, ckv, cfg, gated=True)
            y = jax.lax.cond((i + 1) % every == 0, with_cross,
                             lambda z: z, y)
            return y, kv
        idx = jnp.arange(cfg.n_layers)
        x, kv_out = jax.lax.scan(_remat(body), x,
                                 (idx, params["layers"]))
        states_out = img_kvs
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return ForwardOut(hidden=x, aux=aux, kv=kv_out, states=states_out)


def _hybrid_forward(params, cfg, x, rope, collect):
    """Grouped scan: [shared-attn; G x mamba] x n_groups (+ remainder)."""
    n_g, g, rem = _hybrid_groups(cfg)
    shared = params["shared_attn"]
    window = cfg.window if cfg.long_attention == "window" else 0

    def mamba_body(carry, pl):
        y, st = ssm_lib.ssm_forward(pl["ssm"], carry, cfg)
        out = carry + y
        if cfg.seq_parallel:
            out = shard(out, "batch", "seq_sp", None)
        return out, st

    def group_body(carry, grp_params):
        y, _, kv = _attn_block(shared, carry, cfg, rope, window=window,
                               return_kv=collect)
        y, sts = jax.lax.scan(_remat(mamba_body), y, grp_params)
        return y, (kv, sts)

    main = jax.tree.map(
        lambda a: a[:n_g * g].reshape(n_g, g, *a.shape[1:]),
        params["layers"])
    x, (kvs, states) = jax.lax.scan(_remat(group_body), x, main)
    states = jax.tree.map(
        lambda a: a.reshape(n_g * g, *a.shape[2:]), states)
    all_states = [states]
    kv_list = [kvs] if collect else None
    if rem:
        x, _, kv = _attn_block(shared, x, cfg, rope, window=window,
                               return_kv=collect)
        tail = jax.tree.map(lambda a: a[n_g * g:], params["layers"])
        x, sts = jax.lax.scan(_remat(mamba_body), x, tail)
        all_states.append(sts)
        if collect:
            kv_list.append(jax.tree.map(lambda a: a[None], kv))
    states = jax.tree.map(lambda *xs: jnp.concatenate(xs), *all_states) \
        if len(all_states) > 1 else all_states[0]
    kvs = (jax.tree.map(lambda *xs: jnp.concatenate(xs), *kv_list)
           if collect and len(kv_list) > 1 else
           (kv_list[0] if collect else None))
    return x, kvs, states


def _xlstm_forward(params, cfg, x, collect):
    n_s, per_group = _xlstm_groups(cfg)

    def m_body(carry, pl):
        out = xlstm_lib.mlstm_parallel(
            pl["mlstm"], rms_norm(carry, pl["ln1"], cfg.norm_eps), cfg,
            return_state=collect)
        h, st = out if collect else (out, None)
        y = carry + h
        if cfg.seq_parallel:
            y = shard(y, "batch", "seq_sp", None)
        return y, st

    if n_s == 0:
        x, sts = jax.lax.scan(_remat(m_body), x, params["layers"])
        return x, {"mlstm": sts, "slstm": None}
    m_states, s_states = [], []
    for gidx in range(n_s):
        grp = jax.tree.map(
            lambda a: a[gidx * per_group:(gidx + 1) * per_group],
            params["layers"])
        x, sts = jax.lax.scan(_remat(m_body), x, grp)
        m_states.append(sts)
        sl = jax.tree.map(lambda a: a[gidx], params["slstm_layers"])
        h, s_st = xlstm_lib.slstm_forward(
            sl["slstm"], rms_norm(x, sl["ln1"], cfg.norm_eps), cfg)
        x = x + h
        s_states.append(s_st)
    n_used = n_s * per_group
    if (cfg.n_layers - n_s) - n_used > 0:
        rest = jax.tree.map(lambda a: a[n_used:], params["layers"])
        x, sts = jax.lax.scan(_remat(m_body), x, rest)
        m_states.append(sts)
    if not collect:
        return x, None
    return x, {
        "mlstm": jax.tree.map(lambda *xs: jnp.concatenate(xs), *m_states)
        if len(m_states) > 1 else m_states[0],
        "slstm": _stack_tree(s_states) if s_states else None,
    }


def _encode(params, cfg, frames, rope):
    """Bidirectional encoder over (stub) audio frame embeddings."""
    x = jnp.einsum("btd,de->bte", frames.astype(_dtype(cfg)),
                   params["enc_embed_proj"])
    x = shard(x, "batch", None, "model")

    def body(carry, pl):
        xn = rms_norm(carry, pl["ln1"], cfg.norm_eps)
        q = attn_lib._project_q(pl["attn"], xn, cfg)
        k, v = attn_lib._project_kv(pl["attn"], xn, cfg)
        cos, sin = rope
        q = attn_lib.apply_rope(q, cos, sin)
        k = attn_lib.apply_rope(k, cos, sin)
        h = attn_lib._sdpa(q, k, v, None, cfg.n_heads // cfg.n_kv_heads)
        h = jnp.einsum("bthk,hkd->btd", h, pl["attn"]["wo"])
        y = carry + h
        h = mlp_lib.mlp(pl["mlp"], rms_norm(y, pl["ln2"], cfg.norm_eps))
        return y + h, None

    x, _ = jax.lax.scan(_remat(body), x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kvs(cross_layers, states, cfg):
    """Precompute encoder/image K,V for every cross-attention layer."""
    def kv(pl):
        return attn_lib.encoder_kv(pl["attn"], states, cfg)
    return jax.vmap(kv)(cross_layers)


def _stack_tree(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ---------------------------------------------------------------------------
# training loss
# ---------------------------------------------------------------------------

def loss_fn(params: Dict, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    out = forward(params, cfg, batch["tokens"],
                  {k: v for k, v in batch.items()
                   if k not in ("tokens", "labels")})
    head = params.get("lm_head")
    head = params["tok_embed"].T if head is None else head
    logits = jnp.einsum("btd,dv->btv", out.hidden, head)
    logits = shard(logits, "batch", None, "vocab").astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                               axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = nll
    metrics = {"nll": nll}
    if "load_balance" in out.aux:
        loss = loss + 0.01 * out.aux["load_balance"] \
            + 1e-3 * out.aux["router_z"]
        metrics.update(out.aux)
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode
# ---------------------------------------------------------------------------

def _attn_cache_len(cfg: ModelConfig, max_len: int) -> int:
    if cfg.long_attention == "window":
        return min(max_len, cfg.window)
    return max_len


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Allocate the family-appropriate decode cache (zeros)."""
    dt = _dtype(cfg)
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    fam = cfg.family
    kv_len = _attn_cache_len(cfg, max_len)
    if fam in ("dense", "moe", "encdec", "vlm"):
        cache["attn"] = _stack_tree(
            [attn_lib.init_cache(cfg, batch, kv_len, dt)
             for _ in range(cfg.n_layers)])
    if fam == "hybrid":
        n_g, g, rem = _hybrid_groups(cfg)
        n_apps = n_g + (1 if rem else 0)
        cache["attn"] = _stack_tree(
            [attn_lib.init_cache(cfg, batch, min(kv_len, cfg.window)
                                 if cfg.long_attention == "window"
                                 else kv_len, dt)
             for _ in range(n_apps)])
        cache["ssm"] = _stack_tree(
            [ssm_lib.init_state(cfg, batch, dt)
             for _ in range(cfg.n_layers)])
    if fam == "ssm":
        n_s, _ = _xlstm_groups(cfg)
        cache["mlstm"] = _stack_tree(
            [xlstm_lib.init_mlstm_state(cfg, batch)
             for _ in range(cfg.n_layers - n_s)])
        if n_s:
            cache["slstm"] = _stack_tree(
                [xlstm_lib.init_slstm_state(cfg, batch)
                 for _ in range(n_s)])
    return cache


def prefill(params: Dict, cfg: ModelConfig, tokens: jax.Array,
            extra: Optional[Dict[str, jax.Array]] = None,
            max_len: Optional[int] = None) -> Tuple[jax.Array, Dict]:
    """Process the prompt, build the decode cache, return last logits."""
    extra = extra or {}
    b, t = tokens.shape
    max_len = max_len or t
    out = forward(params, cfg, tokens, extra, collect=True)
    head = params.get("lm_head")
    head = params["tok_embed"].T if head is None else head
    logits = jnp.einsum("bd,dv->bv", out.hidden[:, -1], head)
    cache = init_decode_cache(cfg, b, max_len)
    cache["pos"] = jnp.full((), t, jnp.int32)
    fam = cfg.family
    if out.kv is not None and "attn" in cache:
        k, v = out.kv
        kv_len = cache["attn"]["k"].shape[2]
        take = min(t, kv_len)
        dus = lambda c, u: jax.lax.dynamic_update_slice_in_dim(
            c, u, 0, axis=2)
        if cfg.kv_cache_dtype == "int8":
            kq, ks = attn_lib.quantize_kv(k)
            vq, vs = attn_lib.quantize_kv(v)
            cache["attn"] = {
                "k": dus(cache["attn"]["k"], kq[:, :, t - take:t]),
                "v": dus(cache["attn"]["v"], vq[:, :, t - take:t]),
                "k_scale": dus(cache["attn"]["k_scale"],
                               ks[:, :, t - take:t]),
                "v_scale": dus(cache["attn"]["v_scale"],
                               vs[:, :, t - take:t]),
            }
        else:
            cache["attn"] = {
                "k": dus(cache["attn"]["k"], k[:, :, t - take:t]),
                "v": dus(cache["attn"]["v"], v[:, :, t - take:t]),
            }
    if fam == "hybrid":
        cache["ssm"] = out.states
    if fam == "ssm":
        cache["mlstm"] = out.states["mlstm"]
        if out.states["slstm"] is not None:
            cache["slstm"] = out.states["slstm"]
    if fam in ("encdec", "vlm"):
        cache["cross_kv"] = out.states
    return logits.astype(jnp.float32), cache


def decode_step(params: Dict, cfg: ModelConfig, cache: Dict,
                tokens: jax.Array,
                extra: Optional[Dict[str, jax.Array]] = None
                ) -> Tuple[jax.Array, Dict]:
    """One decode step.  tokens: [B, 1] -> logits [B, vocab]."""
    extra = extra or {}
    pos = cache["pos"]
    x = params["tok_embed"][tokens]
    x = shard(x, "batch", None, "model")
    rope = attn_lib.make_rope(cfg, MAX_ROPE_POS)
    fam = cfg.family
    new_cache = dict(cache)
    window = cfg.window if cfg.long_attention == "window" else 0

    if fam in ("dense", "moe"):
        def body(carry, inp):
            pl, c = inp
            y, c2 = _attn_block_decode(pl, carry, c, pos, cfg, rope,
                                       window=window)
            return y, c2
        x, new_attn = jax.lax.scan(
            body, x, (params["layers"], cache["attn"]))
        new_cache["attn"] = new_attn

    elif fam == "hybrid":
        x, new_cache = _hybrid_decode(params, cfg, x, cache, new_cache,
                                      pos, rope)
    elif fam == "ssm":
        x, new_cache = _xlstm_decode(params, cfg, x, cache, new_cache)
    elif fam in ("encdec", "vlm"):
        x, new_cache = _crossdec_step(params, cfg, x, cache, new_cache,
                                      pos, rope, window)
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head")
    head = params["tok_embed"].T if head is None else head
    logits = jnp.einsum("btd,dv->btv", x, head)
    logits = shard(logits, "batch", None, "vocab")
    new_cache["pos"] = pos + 1
    return logits[:, 0].astype(jnp.float32), new_cache


def _hybrid_decode(params, cfg, x, cache, new_cache, pos, rope):
    n_g, g, rem = _hybrid_groups(cfg)
    shared = params["shared_attn"]
    window = cfg.window if cfg.long_attention == "window" else 0

    def mamba_body(carry, inp):
        pl, st = inp
        y, st2 = ssm_lib.ssm_decode(pl["ssm"], carry, cfg, st)
        return carry + y, st2

    def group_body(carry, inp):
        grp_params, attn_c, ssm_c = inp
        y, attn_c2 = _attn_block_decode(shared, carry, attn_c, pos, cfg,
                                        rope, window=window)
        y, ssm_c2 = jax.lax.scan(mamba_body, y, (grp_params, ssm_c))
        return y, (attn_c2, ssm_c2)

    main_p = jax.tree.map(
        lambda a: a[:n_g * g].reshape(n_g, g, *a.shape[1:]),
        params["layers"])
    main_s = jax.tree.map(
        lambda a: a[:n_g * g].reshape(n_g, g, *a.shape[1:]),
        cache["ssm"])
    main_attn = jax.tree.map(lambda a: a[:n_g], cache["attn"])
    x, (new_attn, new_ssm) = jax.lax.scan(
        group_body, x, (main_p, main_attn, main_s))
    new_ssm = jax.tree.map(
        lambda a: a.reshape(n_g * g, *a.shape[2:]), new_ssm)
    if rem:
        attn_c = jax.tree.map(lambda a: a[n_g], cache["attn"])
        x, attn_c2 = _attn_block_decode(shared, x, attn_c, pos, cfg,
                                        rope, window=window)
        tail_p = jax.tree.map(lambda a: a[n_g * g:], params["layers"])
        tail_s = jax.tree.map(lambda a: a[n_g * g:], cache["ssm"])
        x, tail_s2 = jax.lax.scan(mamba_body, x, (tail_p, tail_s))
        new_attn = jax.tree.map(
            lambda a, u: jnp.concatenate([a, u[None]]), new_attn,
            attn_c2)
        new_ssm = jax.tree.map(
            lambda a, u: jnp.concatenate([a, u]), new_ssm, tail_s2)
    new_cache["attn"] = new_attn
    new_cache["ssm"] = new_ssm
    return x, new_cache


def _xlstm_decode(params, cfg, x, cache, new_cache):
    n_s, per_group = _xlstm_groups(cfg)

    def m_body(carry, inp):
        pl, st = inp
        xn = rms_norm(carry, pl["ln1"], cfg.norm_eps)
        h, st2 = xlstm_lib.mlstm_decode(pl["mlstm"], xn, cfg, st)
        return carry + h, st2

    if n_s == 0:
        x, new_m = jax.lax.scan(m_body, x,
                                (params["layers"], cache["mlstm"]))
        new_cache["mlstm"] = new_m
        return x, new_cache
    new_m_states, new_s_states = [], []
    for gidx in range(n_s):
        sl_ = slice(gidx * per_group, (gidx + 1) * per_group)
        grp = jax.tree.map(lambda a: a[sl_], params["layers"])
        m_grp = jax.tree.map(lambda a: a[sl_], cache["mlstm"])
        x, new_m = jax.lax.scan(m_body, x, (grp, m_grp))
        new_m_states.append(new_m)
        sl = jax.tree.map(lambda a: a[gidx], params["slstm_layers"])
        s_st = jax.tree.map(lambda a: a[gidx], cache["slstm"])
        h, s2 = xlstm_lib.slstm_forward(
            sl["slstm"], rms_norm(x, sl["ln1"], cfg.norm_eps), cfg,
            state=s_st)
        x = x + h
        new_s_states.append(s2)
    n_used = n_s * per_group
    if (cfg.n_layers - n_s) - n_used > 0:
        rest = jax.tree.map(lambda a: a[n_used:], params["layers"])
        m_rest = jax.tree.map(lambda a: a[n_used:], cache["mlstm"])
        x, new_m = jax.lax.scan(m_body, x, (rest, m_rest))
        new_m_states.append(new_m)
    new_cache["mlstm"] = jax.tree.map(
        lambda *xs: jnp.concatenate(xs), *new_m_states) \
        if len(new_m_states) > 1 else new_m_states[0]
    new_cache["slstm"] = _stack_tree(new_s_states)
    return x, new_cache


def _crossdec_step(params, cfg, x, cache, new_cache, pos, rope, window):
    fam = cfg.family
    if fam == "encdec":
        def body(carry, inp):
            pl, cl, ekv, c = inp
            y, c2 = _attn_block_decode(pl, carry, c, pos, cfg, rope,
                                       window=window)
            y = _cross_block(cl, y, ekv, cfg, gated=False)
            return y, c2
        x, new_attn = jax.lax.scan(
            body, x, (params["layers"], params["cross_layers"],
                      cache["cross_kv"], cache["attn"]))
        new_cache["attn"] = new_attn
        return x, new_cache
    every = cfg.cross_attn_every

    def body(carry, inp):
        i, pl, c = inp
        y, c2 = _attn_block_decode(pl, carry, c, pos, cfg, rope,
                                   window=window)

        def with_cross(z):
            ci = i // every
            cl = jax.tree.map(lambda a: a[ci], params["cross_layers"])
            ckv = jax.tree.map(lambda a: a[ci], cache["cross_kv"])
            return _cross_block(cl, z, ckv, cfg, gated=True)
        y = jax.lax.cond((i + 1) % every == 0, with_cross,
                         lambda z: z, y)
        return y, c2
    idx = jnp.arange(cfg.n_layers)
    x, new_attn = jax.lax.scan(
        body, x, (idx, params["layers"], cache["attn"]))
    new_cache["attn"] = new_attn
    return x, new_cache
