"""Shared model building blocks: norms, RoPE, init, sharding helpers.

No flax/optax on this box — modules are (init, apply) function pairs
over plain dict pytrees.  Sharding is expressed through logical
constraints: model code calls ``shard(x, *logical_axes)`` and the
active :class:`MeshContext` maps logical axes to mesh axes (or is a
no-op on a single device), so the same model runs in unit tests and on
the 512-chip production mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# logical-axis sharding context
# ---------------------------------------------------------------------------

_STATE = threading.local()

# logical axis -> mesh axis (None = replicated).  "data" composes the
# pod axis on multi-pod meshes so that the batch shards across pods too.
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,            # sequence (sharded only under SP configs)
    "seq_sp": "model",      # sequence under sequence/context parallelism
    "model": None,          # d_model / residual: replicated
    "heads": "model",       # attention heads (TP)
    "kv_heads": "model",
    "ff": "model",          # MLP hidden (TP)
    "vocab": "model",       # embedding / logits (TP)
    "experts": "model",     # MoE experts (EP)
    "expert_cap": None,
    "ssm_heads": "model",   # SSM / mLSTM heads (TP)
    "state": None,
}


class MeshContext:
    def __init__(self, mesh: Optional[Mesh], rules: Optional[dict] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def spec(self, *logical: Optional[str]) -> P:
        axes = []
        used = set()
        for name in logical:
            if name is None:
                axes.append(None)
                continue
            mesh_axis = self.rules.get(name)
            # drop mesh axes that are unavailable or already used
            if isinstance(mesh_axis, tuple):
                mesh_axis = tuple(
                    a for a in mesh_axis
                    if self.mesh is not None and a in self.mesh.axis_names
                    and a not in used)
                for a in mesh_axis:
                    used.add(a)
                axes.append(mesh_axis if mesh_axis else None)
            else:
                if (mesh_axis is None or self.mesh is None
                        or mesh_axis not in self.mesh.axis_names
                        or mesh_axis in used):
                    axes.append(None)
                else:
                    used.add(mesh_axis)
                    axes.append(mesh_axis)
        return P(*axes)


def current_ctx() -> MeshContext:
    return getattr(_STATE, "ctx", None) or MeshContext(None)


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = MeshContext(mesh, rules)
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axis names.

    No-op off-mesh; per-dimension, axes whose mesh extent does not
    divide the array dimension are dropped (replicated fallback — e.g.
    8 KV heads on a 16-way model axis).
    """
    ctx = current_ctx()
    if ctx.mesh is None:
        return x
    spec = ctx.spec(*logical)
    fixed = tuple(
        s if x.shape[i] % _axis_size(ctx.mesh, s) == 0 else None
        for i, s in enumerate(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*fixed)))


def named_sharding(*logical: Optional[str]) -> Optional[NamedSharding]:
    ctx = current_ctx()
    if ctx.mesh is None:
        return None
    return NamedSharding(ctx.mesh, ctx.spec(*logical))


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array,
             eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def rope_freqs(head_dim: int, max_pos: int, theta: float) -> Tuple[
        jax.Array, jax.Array]:
    inv = 1.0 / (theta ** (
        jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    pos = jnp.arange(max_pos, dtype=jnp.float32)
    ang = pos[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               positions: Optional[jax.Array] = None) -> jax.Array:
    """x: [B, T, H, hd]; cos/sin: [max_pos, hd/2]; positions: [B, T]."""
    if positions is None:
        cos_t = cos[: x.shape[1]][None, :, None, :]
        sin_t = sin[: x.shape[1]][None, :, None, :]
    else:
        cos_t = cos[positions][:, :, None, :]
        sin_t = sin[positions][:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos_t - x2 * sin_t, x2 * cos_t + x1 * sin_t], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# initialisation
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape: Sequence[int], fan_in: int,
               dtype=jnp.bfloat16) -> jax.Array:
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, tuple(shape), jnp.float32)
            * scale).astype(dtype)


def embed_init(key: jax.Array, shape: Sequence[int],
               dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, tuple(shape), jnp.float32)
            * 0.02).astype(dtype)


class KeyGen:
    """Split-on-demand PRNG key source for init code."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub


def count_params(params) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree.leaves(params)))
