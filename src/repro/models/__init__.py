"""Subpackage."""
