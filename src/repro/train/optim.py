"""AdamW with ZeRO-shardable state and a warmup+cosine schedule.

No optax on this box; the update is ~40 lines and keeps the moments in
a configurable dtype (fp32 default, bf16 for the trillion-parameter
config where fp32 moments exceed HBM — see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"    # "bfloat16" for the 1T config


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params, cfg: OptConfig) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def update(grads, state: OptState, params,
           cfg: OptConfig) -> Tuple[Any, OptState, jax.Array]:
    """One AdamW step; returns (params, state, grad_norm)."""
    dt = jnp.dtype(cfg.state_dtype)
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = mu32 / bc1
        vhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mu32.astype(dt), nu32.astype(dt)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step=step, mu=new_mu, nu=new_nu), gnorm
