"""Subpackage."""
