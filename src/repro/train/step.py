"""Training-step factory: remat + microbatched gradient accumulation.

``make_train_step`` closes over the config and returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable
for ``jax.jit`` with explicit in/out shardings.  Batches arrive with a
leading microbatch axis ``[mb, B/mb, ...]``; gradients accumulate in
fp32 across a ``lax.scan`` over microbatches (one optimizer step per
call, MaxText-style).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf_lib
from repro.train import optim as optim_lib


def make_train_step(cfg: ModelConfig, opt_cfg: optim_lib.OptConfig,
                    microbatches: int = 1,
                    accum_dtype=jnp.float32) -> Callable:
    grad_fn = jax.value_and_grad(
        lambda p, b: tf_lib.loss_fn(p, cfg, b), has_aux=True)

    def train_step(params, opt_state: optim_lib.OptState,
                   batch: Dict[str, jax.Array]
                   ) -> Tuple[Any, optim_lib.OptState, Dict]:
        if microbatches == 1:
            mb = jax.tree.map(lambda x: x[0], batch)
            (loss, metrics), grads = grad_fn(params, mb)
        else:
            def accum(carry, mb):
                g_acc, m_acc = carry
                (loss, metrics), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            m0 = {"loss": jnp.zeros((), jnp.float32),
                  "nll": jnp.zeros((), jnp.float32)}
            # probe metrics structure with a zero-grad eval of mb 0
            m0 = jax.eval_shape(
                lambda p, b: grad_fn(p, b)[0][1], params,
                jax.tree.map(lambda x: x[0], batch))
            m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
            (grads, msum), _ = jax.lax.scan(accum, (g0, m0), batch)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, msum)

        new_params, new_opt, gnorm = optim_lib.update(
            grads, opt_state, params, opt_cfg)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return new_params, new_opt, metrics

    return train_step


def init_train_state(cfg: ModelConfig, opt_cfg: optim_lib.OptConfig,
                     key: jax.Array):
    params = tf_lib.init_params(cfg, key)
    opt_state = optim_lib.init(params, opt_cfg)
    return params, opt_state
