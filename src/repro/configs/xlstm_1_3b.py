"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks (xLSTM[7:1]).

48L d_model=2048 4H d_ff=0 vocab=50304 [arXiv:2405.04517].  One sLSTM
block per 8 (6 sLSTM total); mLSTM matrix memory gives O(1)-state
decode, so long_500k runs recurrently.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    slstm_every=8,
)
