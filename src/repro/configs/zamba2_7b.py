"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000 ssm_state=64
[arXiv:2411.15242].  Shared attention+MLP block applied every 6 mamba
blocks (weights shared across applications).  long_500k runs with the
SSM state + windowed shared attention (sub-quadratic; DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    long_attention="window",
    window=4096,
)
