"""Model/shape configuration dataclasses for the assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture from the assigned pool (exact figures in each
    ``configs/<id>.py``).  ``family`` selects the block assembly:
    dense | moe | hybrid (Mamba2+shared attn) | ssm (xLSTM) |
    encdec | vlm.
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM / Mamba2 (hybrid family) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    attn_every: int = 0               # shared attn applied every k blocks
    # --- xLSTM ---
    slstm_every: int = 0              # one sLSTM block every k blocks
    # --- encoder-decoder ---
    n_enc_layers: int = 0
    enc_seq: int = 1536               # audio frames fed to the encoder
    # --- VLM ---
    cross_attn_every: int = 0
    vision_tokens: int = 0
    vision_dim: int = 1280            # stub frontend embedding width
    # --- misc ---
    frontend: str = "none"            # none | audio | vision
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # long-context decode strategy for the attention component:
    # "full" (KV cache = context), "window" (sliding window KV).
    long_attention: str = "full"
    window: int = 4096
    # --- beyond-paper perf knobs (EXPERIMENTS.md §Perf) ---
    seq_parallel: bool = False        # Megatron-SP residual sharding
    moe_quant_dispatch: bool = False  # int8 expert all-to-all payloads
    kv_cache_dtype: str = "bfloat16"  # "int8" halves decode cache traffic

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_decoder_only(self) -> bool:
        return self.family not in ("encdec",)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4),
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            head_dim=32,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            cross_attn_every=min(self.cross_attn_every, 2)
            if self.cross_attn_every else 0,
            slstm_every=min(self.slstm_every, 2)
            if self.slstm_every else 0,
            enc_seq=32,
            vision_tokens=min(self.vision_tokens, 16) if self.vision_tokens
            else 0,
            vision_dim=64,
            window=64,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape x step-kind) cell of the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"
    microbatches: int = 1      # gradient-accumulation steps (train only)


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, per DESIGN.md §4."""
    if shape.name == "long_500k":
        if cfg.family in ("hybrid", "ssm"):
            return True, ""
        return False, ("full-attention architecture: 500k dense decode is "
                       "the quadratic regime the spec says to skip")
    return True, ""
