"""kimi-k2-1t-a32b [moe]: trillion-parameter MoE (paper-table config).

61L d_model=7168 64H (GQA kv=8) d_ff=2048(expert) vocab=163840,
384 experts top-8 [arXiv:2501.kimi2].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab=163840,
    n_experts=384,
    top_k=8,
)
