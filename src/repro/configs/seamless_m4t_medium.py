"""seamless-m4t-medium [audio]: enc-dec multimodal backbone.

12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206
[arXiv:2308.11596; hf].  The speech frontend is a STUB: input_specs()
provides precomputed frame embeddings; encoder/decoder backbones are
real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    enc_seq=1536,
    frontend="audio",
)
