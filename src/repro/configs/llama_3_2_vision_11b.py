"""llama-3.2-vision-11b [vlm]: cross-attention image layers.

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256
[hf:meta-llama/Llama-3.2-11B-Vision].  Vision frontend is a STUB:
input_specs() provides precomputed patch embeddings (vision_dim=1280);
the gated cross-attention layers (every 5th) and projector are real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    cross_attn_every=5,
    vision_tokens=1601,
    vision_dim=1280,
    frontend="vision",
    rope_theta=5e5,
)
