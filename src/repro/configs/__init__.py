"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    ModelConfig,
    PREFILL_32K,
    ShapeConfig,
    TRAIN_4K,
    applicable,
    shape_by_name,
)

_MODULES = {
    "seamless-m4t-medium": "seamless_m4t_medium",
    "zamba2-7b": "zamba2_7b",
    "minitron-8b": "minitron_8b",
    "starcoder2-7b": "starcoder2_7b",
    "stablelm-1.6b": "stablelm_1_6b",
    "qwen3-4b": "qwen3_4b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "xlstm-1.3b": "xlstm_1_3b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    try:
        mod_name = _MODULES[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
