"""Pure-jnp oracle for the availscan kernel.

The reference semantics live in
:func:`repro.core.search.availability_rectangles`; this module re-exports
them under the conventional ``kernels/ref.py`` name so kernel tests
sweep shapes/dtypes against one canonical implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import search as search_lib
from repro.core.timeline import Timeline


def availability_rectangles(
    tl: Timeline, starts: jax.Array, t_du: jax.Array, t_now: jax.Array,
    n_pe: int,
) -> search_lib.Rectangles:
    return search_lib.availability_rectangles(tl, starts, t_du, t_now, n_pe)


def window_busy_dense(occ_bits: jax.Array, times: jax.Array,
                      nxt: jax.Array, a: jax.Array,
                      b: jax.Array) -> jax.Array:
    """Slot-loop oracle for the kernel's first contraction (tests)."""
    ov = (times[None, :] < b[:, None]) & (nxt[None, :] > a[:, None])
    return jnp.einsum("ps,se->pe", ov.astype(jnp.float32), occ_bits) > 0.5
