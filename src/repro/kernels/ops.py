"""Jit'd public wrapper around the availscan Pallas kernel.

Prepares the dense operands from a :class:`~repro.core.timeline.Timeline`
(bit-expansion, lane padding), invokes the kernel, and post-processes
the raw tile outputs back into the exact semantics of the pure-jnp
reference (:func:`repro.core.search.availability_rectangles`).

On shapes beyond the kernel's single-block VMEM budget the wrapper
transparently falls back to the reference path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import search as search_lib
from repro.core import timeline as tl_lib
from repro.core.timeline import Timeline
from repro.core.types import T_INF
from repro.kernels import availscan as _k

# Single-block VMEM budget: S * n_pe f32 occupancy <= 8 MiB.
_MAX_OCC_ELEMS = 2 * 1024 * 1024


def _interpret_mode() -> bool:
    # Real TPU executes the compiled kernel; anywhere else (this
    # container is CPU-only) runs the kernel body in interpret mode.
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def availability_rectangles(
    tl: Timeline, starts: jax.Array, t_du: jax.Array, t_now: jax.Array,
    n_pe: int,
) -> search_lib.Rectangles:
    """Kernel-backed drop-in for ``search.availability_rectangles``."""
    S = tl.capacity
    S_pad = _round_up(max(S, _k._LANE), _k._LANE)
    n_pe_pad = _round_up(max(n_pe, _k._LANE), _k._LANE)
    if S_pad * n_pe_pad > _MAX_OCC_ELEMS:
        return search_lib.availability_rectangles(
            tl, starts, t_du, t_now, n_pe)

    occ_bits = tl_lib.unpack_bits(tl.occ, n_pe).astype(jnp.float32)
    occ_bits = jnp.pad(
        occ_bits, ((0, S_pad - S), (0, n_pe_pad - n_pe)))
    times = jnp.pad(tl.times, (0, S_pad - S), constant_values=T_INF)
    nxt = jnp.pad(tl_lib.next_times(tl), (0, S_pad - S),
                  constant_values=T_INF)

    valid = starts < T_INF
    a = jnp.minimum(starts, T_INF - t_du)   # avoid int32 overflow
    b = a + t_du

    nfree_raw, tb_raw, te_raw = _k.availscan(
        occ_bits, times, nxt, a, b, interpret=_interpret_mode())

    n_free = nfree_raw - (n_pe_pad - n_pe)   # padded PE bits are never busy
    t_begin = jnp.minimum(jnp.maximum(tb_raw, t_now), a)
    t_end = te_raw
    return search_lib.Rectangles(
        starts=starts, n_free=n_free, t_begin=t_begin, t_end=t_end,
        valid=valid)
