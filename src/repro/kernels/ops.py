"""Jit'd public wrappers around the availscan Pallas kernels.

Prepares the dense operands from a :class:`~repro.core.timeline.Timeline`
(bit-expansion, lane padding), invokes the kernel, and post-processes
the raw tile outputs back into the exact semantics of the pure-jnp
reference (:func:`repro.core.search.availability_rectangles`).

Occupancy awareness (DESIGN.md §7, §12): the live candidate mask — the
non-``T_INF`` entries of the deduplicated, compacted (and possibly
index-pruned) candidate array — is reduced to per-tile live counts and
threaded into the kernel as a scalar-prefetch operand so dead tiles
are skipped wherever they sit (prefix padding or pruned holes), and
the invalid tail is masked to the same sentinels the reference
produces, keeping the two paths element-identical.

:func:`search_select` exposes the fused availscan + policy-selection
kernel (the per-candidate vectors never leave the kernel); the
``search`` hot path uses it on the kernel path.

On shapes beyond the kernel's single-block VMEM budget the wrappers
transparently fall back to the reference path (``search_select``
returns ``None`` and the caller runs the jnp chain).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import search as search_lib
from repro.core import timeline as tl_lib
from repro.core.timeline import Timeline
from repro.core.types import T_INF
from repro.kernels import availscan as _k

# Single-block VMEM budget: S * n_pe f32 occupancy <= 8 MiB.
_MAX_OCC_ELEMS = 2 * 1024 * 1024


def _interpret_mode() -> bool:
    # Real TPU executes the compiled kernel; anywhere else (this
    # container is CPU-only) runs the kernel body in interpret mode.
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _padded_operands(tl: Timeline, n_pe: int):
    """Lane-padded dense operands shared by both kernel entries."""
    S = tl.capacity
    S_pad = _round_up(max(S, _k._LANE), _k._LANE)
    n_pe_pad = _round_up(max(n_pe, _k._LANE), _k._LANE)
    if S_pad * n_pe_pad > _MAX_OCC_ELEMS:
        return None
    occ_bits = tl_lib.unpack_bits(tl.occ, n_pe).astype(jnp.float32)
    occ_bits = jnp.pad(
        occ_bits, ((0, S_pad - S), (0, n_pe_pad - n_pe)))
    times = jnp.pad(tl.times, (0, S_pad - S), constant_values=T_INF)
    nxt = jnp.pad(tl_lib.next_times(tl), (0, S_pad - S),
                  constant_values=T_INF)
    return occ_bits, times, nxt, n_pe_pad


def _padded_operands_mr(tl: Timeline, rspec,
                        valid_mask: Optional[jax.Array]):
    """Multi-resource operands: the bit axis spans every plane's word
    range, and the plane-selector matrix ``psel[bit, r]`` (1 iff the
    bit is a valid unit of plane ``r``) both excludes padding/masked
    units from the free counts and routes each plane to its own output
    lane — so no pad correction exists on this path."""
    if rspec.R > _k._LANE:
        return None
    S = tl.capacity
    n_bits = rspec.total_bits
    S_pad = _round_up(max(S, _k._LANE), _k._LANE)
    n_bits_pad = _round_up(max(n_bits, _k._LANE), _k._LANE)
    if S_pad * n_bits_pad > _MAX_OCC_ELEMS:
        return None
    occ_bits = tl_lib.unpack_bits(tl.occ, n_bits).astype(jnp.float32)
    occ_bits = jnp.pad(
        occ_bits, ((0, S_pad - S), (0, n_bits_pad - n_bits)))
    times = jnp.pad(tl.times, (0, S_pad - S), constant_values=T_INF)
    nxt = jnp.pad(tl_lib.next_times(tl), (0, S_pad - S),
                  constant_values=T_INF)
    if valid_mask is None:
        valid_mask = jnp.asarray(rspec.valid_mask_np())
    plane_id = np.full(n_bits_pad, -1, np.int32)
    for r in range(rspec.R):
        o = rspec.bit_offset(r)
        plane_id[o:o + rspec.words_per[r] * 32] = r
    vb = tl_lib.unpack_bits(
        valid_mask[None, :], n_bits)[0].astype(jnp.float32)
    vb = jnp.pad(vb, (0, n_bits_pad - n_bits))
    psel = (jnp.asarray(plane_id)[:, None] ==
            jnp.arange(_k._LANE, dtype=jnp.int32)[None, :]
            ).astype(jnp.float32) * vb[:, None]
    return occ_bits, times, nxt, psel


def availability_rectangles(
    tl: Timeline, starts: jax.Array, t_du: jax.Array, t_now: jax.Array,
    n_pe: int, *, rspec=None, valid_mask: Optional[jax.Array] = None,
) -> search_lib.Rectangles:
    """Kernel-backed drop-in for ``search.availability_rectangles``."""
    if rspec is not None:
        ops = _padded_operands_mr(tl, rspec, valid_mask)
        if ops is None:
            return search_lib.availability_rectangles(
                tl, starts, t_du, t_now, n_pe, rspec=rspec,
                valid_mask=valid_mask)
        occ_bits, times, nxt, psel = ops
        valid = starts < T_INF
        a = jnp.minimum(starts, T_INF - t_du)
        b = a + t_du
        nfp_raw, tb_raw, te_raw = _k.availscan_mr(
            occ_bits, psel, times, nxt, a, b, valid,
            interpret=_interpret_mode())
        zero = jnp.int32(0)
        t_begin = jnp.minimum(jnp.maximum(tb_raw, t_now), a)
        return search_lib.Rectangles(
            starts=starts,
            n_free=jnp.where(valid, nfp_raw[:, 0], zero),
            t_begin=jnp.where(valid, t_begin, zero),
            t_end=jnp.where(valid, te_raw, zero),
            valid=valid,
            n_free_tail=jnp.where(
                valid[:, None], nfp_raw[:, 1:rspec.R], zero))
    ops = _padded_operands(tl, n_pe)
    if ops is None:
        return search_lib.availability_rectangles(
            tl, starts, t_du, t_now, n_pe)
    occ_bits, times, nxt, n_pe_pad = ops

    valid = starts < T_INF
    a = jnp.minimum(starts, T_INF - t_du)   # avoid int32 overflow
    b = a + t_du

    nfree_raw, tb_raw, te_raw = _k.availscan(
        occ_bits, times, nxt, a, b, valid,
        interpret=_interpret_mode())

    zero = jnp.int32(0)
    n_free = nfree_raw - (n_pe_pad - n_pe)   # padded PE bits never busy
    t_begin = jnp.minimum(jnp.maximum(tb_raw, t_now), a)
    # invalid candidates (skipped tiles included) take the reference
    # sentinels, keeping kernel and jnp paths element-identical
    return search_lib.Rectangles(
        starts=starts,
        n_free=jnp.where(valid, n_free, zero),
        t_begin=jnp.where(valid, t_begin, zero),
        t_end=jnp.where(valid, te_raw, zero),
        valid=valid)


def search_select(
    tl: Timeline, starts: jax.Array, t_du: jax.Array, t_now: jax.Array,
    n_req: jax.Array, policy_id: jax.Array, n_pe: int, *,
    rspec=None, demand_tail: Optional[jax.Array] = None,
    valid_mask: Optional[jax.Array] = None,
) -> Optional[dict]:
    """Fused availscan + policy selection on the kernel path.

    Returns ``None`` when the shape exceeds the kernel budget (caller
    falls back to the jnp chain); otherwise a dict with the winning
    candidate: ``found``, ``best`` (index into ``starts``) and its
    post-processed ``n_free`` / ``t_begin`` / ``t_end`` — bit-identical
    to ``availability_rectangles`` + ``policies.select``.

    ``rspec`` dispatches to the multi-resource kernel: the demand tail
    joins the scalar-prefetch row and feasibility AND-reduces across
    planes (DESIGN.md §11).
    """
    if rspec is not None:
        ops = _padded_operands_mr(tl, rspec, valid_mask)
        if ops is None:
            return None
        occ_bits, times, nxt, psel = ops
        live = starts < T_INF
        a = jnp.minimum(starts, T_INF - t_du)
        b = a + t_du
        if demand_tail is None:
            demand_tail = jnp.zeros((rspec.R - 1,), jnp.int32)
        scalars = jnp.concatenate([
            jnp.stack([jnp.asarray(policy_id, jnp.int32),
                       jnp.asarray(n_req, jnp.int32),
                       jnp.asarray(t_now, jnp.int32)]),
            jnp.asarray(demand_tail, jnp.int32)])
        acc = _k.availscan_select_mr(
            occ_bits, psel, times, nxt, starts, a, b, scalars, live,
            n_res=rspec.R, interpret=_interpret_mode())
        return dict(found=acc[7] > 0, best=acc[3], n_free=acc[4],
                    t_begin=acc[5], t_end=acc[6])
    ops = _padded_operands(tl, n_pe)
    if ops is None:
        return None
    occ_bits, times, nxt, n_pe_pad = ops
    live = starts < T_INF
    a = jnp.minimum(starts, T_INF - t_du)
    b = a + t_du
    scalars = jnp.stack([
        jnp.asarray(policy_id, jnp.int32),
        jnp.asarray(n_req, jnp.int32), jnp.asarray(t_now, jnp.int32),
        jnp.int32(n_pe_pad - n_pe)])
    acc = _k.availscan_select(
        occ_bits, times, nxt, starts, a, b, scalars, live,
        interpret=_interpret_mode())
    return dict(found=acc[7] > 0, best=acc[3], n_free=acc[4],
                t_begin=acc[5], t_end=acc[6])
