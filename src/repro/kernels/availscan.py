"""Pallas TPU kernels for the availability-rectangle scan.

This is the paper's computational hot spot: ``findAllocation`` spends
``O(p * u * v)`` testing every candidate start against every slot
(Section 4.2 complexity analysis).  The TPU formulation turns the scan
into two MXU contractions per candidate tile (DESIGN.md §2):

    busy[Pt, pe]    = overlap[Pt, S] @ occ_bits[S, pe]      (window union)
    blocking[Pt, S] = free[Pt, pe]   @ occ_bits[S, pe]^T    (rect expansion)

Grid: one program per tile of ``Pt`` candidate start times.  The
occupancy matrix (the shared operand) is mapped to a single grid-
invariant VMEM block, so it is DMA'd from HBM once and reused by every
candidate tile — the TPU analogue of the paper's "organise availability
for efficient search".  All comparisons stay in exact int32; only the
0/1 contraction operands are f32 (counts < 2**24, exact).

Occupancy awareness (DESIGN.md §7, §12): the candidate array arrives
deduplicated and compacted (live starts first, ``T_INF`` tail — see
``search.candidate_starts``), and *per-tile live candidate counts*
ride in as a scalar-prefetch operand.  Tiles whose count is zero are
skipped with ``pl.when``: they write sentinel outputs without touching
the MXU.  The counts are data-driven rather than prefix-driven: the
hierarchical availability index prunes summary-infeasible candidates
to ``T_INF`` *holes* mid-array (``search.prune_candidates``), and a
tile is skippable exactly when every one of its candidates is padding
or pruned — on an unpruned compacted array this degenerates to the
PR 5 live-prefix skip bit-for-bit.

:func:`availscan_select` additionally fuses the policy selection
(``policies.select``) into the kernel epilogue: each tile reduces its
candidates to a lexicographic best and folds it into a running-best
accumulator across the sequential grid, so only one 8-lane result row
leaves the kernel — the per-candidate ``[P]`` vectors (and the
``[Pt, S]`` blocking matrix) never round-trip through HBM.

VMEM budget per program (defaults Pt=128, S<=1024, n_pe<=2048):
occ_bits f32[S, pe] = 8 MiB worst case + tiles ~1.5 MiB < 16 MiB.
The ops.py wrapper falls back to the pure-jnp path beyond these bounds.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.types import T_INF

# Tile of candidate start times evaluated by one program instance.
DEFAULT_PT = 128
# TPU lane width; S and n_pe are padded to multiples of this.
_LANE = 128
_BIG = jnp.iinfo(jnp.int32).max


def _tile_rects(a, b, times, nxt, occ):
    """The two MXU contractions + rectangle bounds for one tile."""
    ov = ((times[None, :] < b[:, None]) &
          (nxt[None, :] > a[:, None])).astype(jnp.float32)     # [Pt, S]
    busy = jax.lax.dot(ov, occ,
                       preferred_element_type=jnp.float32)     # [Pt, pe]
    free = (busy < 0.5)
    nfree = jnp.sum(free.astype(jnp.int32), axis=1)
    blocking = jax.lax.dot_general(
        free.astype(jnp.float32), occ,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) > 0.5              # [Pt, S]
    left = blocking & (nxt[None, :] <= a[:, None])
    tb = jnp.max(jnp.where(left, nxt[None, :], -T_INF), axis=1)
    right = blocking & (times[None, :] >= b[:, None])
    te = jnp.min(jnp.where(right, times[None, :], T_INF), axis=1)
    return nfree, tb, te


def _tile_rects_mr(a, b, times, nxt, occ, psel):
    """Multi-resource tile (DESIGN.md §11): a third MXU dot against the
    plane-selector matrix ``psel[bit, r]`` (1 iff the global bit is a
    *valid* unit of resource plane ``r``) yields per-plane free-unit
    counts in one contraction — column 0 is the policy-scored PE count,
    columns 1..R-1 feed the vector fit test.  The blocking contraction
    is unchanged: occupancy bits only exist on valid units, so the
    unmasked free operand ANDs to the same booleans."""
    ov = ((times[None, :] < b[:, None]) &
          (nxt[None, :] > a[:, None])).astype(jnp.float32)     # [Pt, S]
    busy = jax.lax.dot(ov, occ,
                       preferred_element_type=jnp.float32)     # [Pt, bit]
    free = (busy < 0.5).astype(jnp.float32)
    nfree_planes = jax.lax.dot(
        free, psel,
        preferred_element_type=jnp.float32).astype(jnp.int32)  # [Pt, 128]
    blocking = jax.lax.dot_general(
        free, occ,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) > 0.5              # [Pt, S]
    left = blocking & (nxt[None, :] <= a[:, None])
    tb = jnp.max(jnp.where(left, nxt[None, :], -T_INF), axis=1)
    right = blocking & (times[None, :] >= b[:, None])
    te = jnp.min(jnp.where(right, times[None, :], T_INF), axis=1)
    return nfree_planes, tb, te


def _tile_live(live: jax.Array, P_pad: int, pt: int) -> jax.Array:
    """i32[P_pad/pt] live-candidate count per tile (0 = skippable)."""
    lv = _pad_to(live.astype(jnp.int32), P_pad, 0)
    return jnp.sum(lv.reshape(P_pad // pt, pt), axis=1)


def _availscan_kernel(tlive_ref, a_ref, b_ref, times_ref, nxt_ref,
                      occ_ref, nfree_ref, tb_ref, te_ref, *, pt):
    i = pl.program_id(0)
    live = tlive_ref[i] > 0

    @pl.when(live)
    def _():
        nfree, tb, te = _tile_rects(
            a_ref[:, 0], b_ref[:, 0], times_ref[0, :], nxt_ref[0, :],
            occ_ref[...])
        nfree_ref[:, 0] = nfree
        tb_ref[:, 0] = tb
        te_ref[:, 0] = te

    @pl.when(~live)
    def _():
        # all-padding tile: sentinel outputs, no MXU work.  The ops.py
        # wrapper masks every invalid candidate to the reference
        # sentinels afterwards, so these values are never observed.
        nfree_ref[:, 0] = jnp.zeros((pt,), jnp.int32)
        tb_ref[:, 0] = jnp.full((pt,), -T_INF, jnp.int32)
        te_ref[:, 0] = jnp.full((pt,), T_INF, jnp.int32)


def _pad_to(x: jax.Array, size: int, fill) -> jax.Array:
    pad = size - x.shape[0]
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(
    jax.jit, static_argnames=("pt", "interpret"))
def availscan(
    occ_bits: jax.Array,   # f32[S, n_pe_padded] 0/1 occupancy
    times: jax.Array,      # i32[S]
    nxt: jax.Array,        # i32[S]
    a: jax.Array,          # i32[P] window starts (overflow-clamped)
    b: jax.Array,          # i32[P] window ends
    live: jax.Array,       # bool/i32[P]: candidate is live (not
    #                        T_INF padding, not summary-pruned)
    *,
    pt: int = DEFAULT_PT,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Tiled scan over candidates, skipping all-dead tiles.

    Returns raw ``(n_free, t_begin_raw, t_end_raw)`` — ``n_free`` still
    counts PE-axis padding (caller subtracts) and the bounds carry
    ``-T_INF`` / ``T_INF`` sentinels when unblocked (caller clamps).
    ``live`` reduces to per-tile counts in the scalar-prefetch operand:
    tiles with no live candidate (all padding or all summary-pruned)
    skip both contractions.
    """
    S, n_pe_p = occ_bits.shape
    assert S % _LANE == 0 and n_pe_p % _LANE == 0, (S, n_pe_p)
    P = a.shape[0]
    P_pad = -(-P // pt) * pt
    a_p = _pad_to(a, P_pad, T_INF - 1)[:, None]
    b_p = _pad_to(b, P_pad, T_INF)[:, None]
    tlive = _tile_live(live, P_pad, pt)
    grid = (P_pad // pt,)
    nfree, tb, te = pl.pallas_call(
        functools.partial(_availscan_kernel, pt=pt),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((pt, 1), lambda i, s: (i, 0)),      # a
                pl.BlockSpec((pt, 1), lambda i, s: (i, 0)),      # b
                pl.BlockSpec((1, S), lambda i, s: (0, 0)),       # times
                pl.BlockSpec((1, S), lambda i, s: (0, 0)),       # nxt
                pl.BlockSpec((S, n_pe_p), lambda i, s: (0, 0)),  # occ
            ],
            out_specs=[
                pl.BlockSpec((pt, 1), lambda i, s: (i, 0)),
                pl.BlockSpec((pt, 1), lambda i, s: (i, 0)),
                pl.BlockSpec((pt, 1), lambda i, s: (i, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((P_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((P_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((P_pad, 1), jnp.int32),
        ],
        interpret=interpret,
    )(tlive, a_p, b_p,
      times[None, :], nxt[None, :], occ_bits)
    return nfree[:P, 0], tb[:P, 0], te[:P, 0]


def _availscan_kernel_mr(tlive_ref, a_ref, b_ref, times_ref, nxt_ref,
                         occ_ref, psel_ref, nfp_ref, tb_ref, te_ref,
                         *, pt):
    i = pl.program_id(0)
    live = tlive_ref[i] > 0

    @pl.when(live)
    def _():
        nfp, tb, te = _tile_rects_mr(
            a_ref[:, 0], b_ref[:, 0], times_ref[0, :], nxt_ref[0, :],
            occ_ref[...], psel_ref[...])
        nfp_ref[...] = nfp
        tb_ref[:, 0] = tb
        te_ref[:, 0] = te

    @pl.when(~live)
    def _():
        nfp_ref[...] = jnp.zeros((pt, _LANE), jnp.int32)
        tb_ref[:, 0] = jnp.full((pt,), -T_INF, jnp.int32)
        te_ref[:, 0] = jnp.full((pt,), T_INF, jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("pt", "interpret"))
def availscan_mr(
    occ_bits: jax.Array,   # f32[S, n_bits_padded] 0/1 occupancy
    psel: jax.Array,       # f32[n_bits_padded, 128] plane selector
    times: jax.Array,      # i32[S]
    nxt: jax.Array,        # i32[S]
    a: jax.Array,          # i32[P] window starts (overflow-clamped)
    b: jax.Array,          # i32[P] window ends
    live: jax.Array,       # bool/i32[P]: candidate is live
    *,
    pt: int = DEFAULT_PT,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Multi-resource :func:`availscan`: same tile-skip scan, but the
    free counts come back per plane (``n_free_planes[P, 128]``, column
    ``r`` = valid free units of resource ``r``) and need no padding
    correction — the plane selector already excludes padding and
    masked-out units."""
    S, n_bits_p = occ_bits.shape
    assert S % _LANE == 0 and n_bits_p % _LANE == 0, (S, n_bits_p)
    P = a.shape[0]
    P_pad = -(-P // pt) * pt
    a_p = _pad_to(a, P_pad, T_INF - 1)[:, None]
    b_p = _pad_to(b, P_pad, T_INF)[:, None]
    tlive = _tile_live(live, P_pad, pt)
    grid = (P_pad // pt,)
    nfp, tb, te = pl.pallas_call(
        functools.partial(_availscan_kernel_mr, pt=pt),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((pt, 1), lambda i, s: (i, 0)),      # a
                pl.BlockSpec((pt, 1), lambda i, s: (i, 0)),      # b
                pl.BlockSpec((1, S), lambda i, s: (0, 0)),       # times
                pl.BlockSpec((1, S), lambda i, s: (0, 0)),       # nxt
                pl.BlockSpec((S, n_bits_p), lambda i, s: (0, 0)),  # occ
                pl.BlockSpec((n_bits_p, _LANE),
                             lambda i, s: (0, 0)),               # psel
            ],
            out_specs=[
                pl.BlockSpec((pt, _LANE), lambda i, s: (i, 0)),
                pl.BlockSpec((pt, 1), lambda i, s: (i, 0)),
                pl.BlockSpec((pt, 1), lambda i, s: (i, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((P_pad, _LANE), jnp.int32),
            jax.ShapeDtypeStruct((P_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((P_pad, 1), jnp.int32),
        ],
        interpret=interpret,
    )(tlive, a_p, b_p,
      times[None, :], nxt[None, :], occ_bits, psel)
    return nfp[:P, :], tb[:P, 0], te[:P, 0]


def _integer_keys_tile(policy_id, n_free, duration):
    """In-kernel mirror of ``policies.integer_keys`` (where-chain)."""
    nf = n_free.astype(jnp.int32)
    du = duration.astype(jnp.int32)
    du_hi = du >> 16
    du_lo = du & 0xFFFF
    p_lo_raw = nf * du_lo
    p_hi = nf * du_hi + (p_lo_raw >> 16)
    p_lo = p_lo_raw & 0xFFFF
    zero = jnp.zeros_like(nf)
    key1 = jnp.where(
        policy_id == 1, nf, jnp.where(
            policy_id == 2, -nf, jnp.where(
                policy_id == 3, du, jnp.where(
                    policy_id == 4, -du, jnp.where(
                        policy_id == 5, p_hi, jnp.where(
                            policy_id == 6, -p_hi, zero))))))
    key2 = jnp.where(policy_id == 5, p_lo,
                     jnp.where(policy_id == 6, -p_lo, zero))
    return key1, key2


def _availscan_select_kernel(scal_ref, tlive_ref, starts_ref, a_ref,
                             b_ref, times_ref, nxt_ref, occ_ref,
                             acc_ref, *, pt):
    i = pl.program_id(0)
    policy_id = scal_ref[0]
    n_req = scal_ref[1]
    t_now = scal_ref[2]
    pad_corr = scal_ref[3]

    @pl.when(i == 0)
    def _():
        # lexicographic +inf on the four comparison lanes: no tile
        # has contributed yet (built from iota — pallas kernels may
        # not capture constant arrays)
        lane = jax.lax.iota(jnp.int32, 8)
        acc_ref[0, :] = jnp.where(lane < 4, _BIG, 0)

    @pl.when(tlive_ref[i] > 0)
    def _():
        starts = starts_ref[:, 0]
        a = a_ref[:, 0]
        nfree_raw, tb_raw, te_raw = _tile_rects(
            a, b_ref[:, 0], times_ref[0, :], nxt_ref[0, :],
            occ_ref[...])
        valid = starts < T_INF
        # the exact post-processing of the ops.py wrapper / jnp ref
        zero = jnp.zeros((pt,), jnp.int32)
        n_free = jnp.where(valid, nfree_raw - pad_corr, zero)
        t_begin = jnp.where(
            valid, jnp.minimum(jnp.maximum(tb_raw, t_now), a), zero)
        t_end = jnp.where(valid, te_raw, zero)
        # the exact scoring of policies.select
        feasible = valid & (n_free >= n_req)
        key1, key2 = _integer_keys_tile(policy_id, n_free,
                                        t_end - t_begin)
        key1 = jnp.where(feasible, key1, _BIG)
        key2 = jnp.where(feasible, key2, _BIG)
        tb = jnp.where(feasible, starts, _BIG)
        # tile-local lexicographic min of (key1, key2, tb, index)
        idx = i * pt + jax.lax.iota(jnp.int32, pt)
        m1 = jnp.min(key1)
        e1 = key1 == m1
        m2 = jnp.min(jnp.where(e1, key2, _BIG))
        e2 = e1 & (key2 == m2)
        m3 = jnp.min(jnp.where(e2, tb, _BIG))
        e3 = e2 & (tb == m3)
        m4 = jnp.min(jnp.where(e3, idx, _BIG))
        win = e3 & (idx == m4)

        def pick(v):
            return jnp.sum(jnp.where(win, v, 0).astype(jnp.int32))

        row = jnp.stack([m1, m2, m3, m4, pick(n_free), pick(t_begin),
                         pick(t_end), pick(feasible.astype(jnp.int32))])
        # fold into the running best: strict lexicographic less on
        # (key1, key2, tb, index) — index is unique, so ties cannot
        # occur and "first tile wins" falls out of the index key.
        acc = acc_ref[0, :]
        less = (row[0] < acc[0]) | (
            (row[0] == acc[0]) & ((row[1] < acc[1]) | (
                (row[1] == acc[1]) & ((row[2] < acc[2]) | (
                    (row[2] == acc[2]) & (row[3] < acc[3]))))))
        acc_ref[0, :] = jnp.where(less, row, acc)


@functools.partial(
    jax.jit, static_argnames=("pt", "interpret"))
def availscan_select(
    occ_bits: jax.Array,   # f32[S, n_pe_padded] 0/1 occupancy
    times: jax.Array,      # i32[S]
    nxt: jax.Array,        # i32[S]
    starts: jax.Array,     # i32[P] candidate starts (T_INF padded)
    a: jax.Array,          # i32[P] window starts (overflow-clamped)
    b: jax.Array,          # i32[P] window ends
    scalars: jax.Array,    # i32[4]: policy, n_req, t_now, pad
    live: jax.Array,       # bool[P] live (unpruned) candidate mask
    *,
    pt: int = DEFAULT_PT,
    interpret: bool = True,
) -> jax.Array:
    """Fused availscan + policy selection (one int32[8] result row).

    Row layout: ``key1, key2, start_key, best_index, n_free, t_begin,
    t_end, feasible`` of the winning candidate — post-processed values
    (pad-corrected ``n_free``, clamped ``t_begin``), bit-identical to
    the jnp ``availability_rectangles`` + ``policies.select`` chain.
    Tiles whose per-tile live count is zero are skipped entirely; on
    compacted (prefix-live) inputs this degenerates to the old
    ``i*pt < n_live`` prefix skip, and index pruning punches holes
    without ever skipping a tile that still holds a live candidate.
    """
    S, n_pe_p = occ_bits.shape
    assert S % _LANE == 0 and n_pe_p % _LANE == 0, (S, n_pe_p)
    P = a.shape[0]
    P_pad = -(-P // pt) * pt
    tlive = _tile_live(live, P_pad, pt)
    starts_p = _pad_to(starts, P_pad, T_INF)[:, None]
    a_p = _pad_to(a, P_pad, T_INF - 1)[:, None]
    b_p = _pad_to(b, P_pad, T_INF)[:, None]
    grid = (P_pad // pt,)
    acc = pl.pallas_call(
        functools.partial(_availscan_select_kernel, pt=pt),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((pt, 1), lambda i, s, t: (i, 0)),   # starts
                pl.BlockSpec((pt, 1), lambda i, s, t: (i, 0)),   # a
                pl.BlockSpec((pt, 1), lambda i, s, t: (i, 0)),   # b
                pl.BlockSpec((1, S), lambda i, s, t: (0, 0)),    # times
                pl.BlockSpec((1, S), lambda i, s, t: (0, 0)),    # nxt
                pl.BlockSpec((S, n_pe_p),
                             lambda i, s, t: (0, 0)),            # occ
            ],
            out_specs=pl.BlockSpec((1, 8), lambda i, s, t: (0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((1, 8), jnp.int32),
        interpret=interpret,
    )(scalars.astype(jnp.int32), tlive, starts_p, a_p, b_p,
      times[None, :], nxt[None, :], occ_bits)
    return acc[0]


def _availscan_select_kernel_mr(scal_ref, tlive_ref, starts_ref, a_ref,
                                b_ref, times_ref, nxt_ref, occ_ref,
                                psel_ref, acc_ref, *, pt, n_res):
    i = pl.program_id(0)
    policy_id = scal_ref[0]
    n_req = scal_ref[1]
    t_now = scal_ref[2]

    @pl.when(i == 0)
    def _():
        lane = jax.lax.iota(jnp.int32, 8)
        acc_ref[0, :] = jnp.where(lane < 4, _BIG, 0)

    @pl.when(tlive_ref[i] > 0)
    def _():
        starts = starts_ref[:, 0]
        a = a_ref[:, 0]
        nfp_raw, tb_raw, te_raw = _tile_rects_mr(
            a, b_ref[:, 0], times_ref[0, :], nxt_ref[0, :],
            occ_ref[...], psel_ref[...])
        valid = starts < T_INF
        zero = jnp.zeros((pt,), jnp.int32)
        # plane-0 counts are already valid-masked by the selector —
        # no pad correction; otherwise the exact post-processing of
        # the ops.py wrapper / jnp reference
        n_free = jnp.where(valid, nfp_raw[:, 0], zero)
        t_begin = jnp.where(
            valid, jnp.minimum(jnp.maximum(tb_raw, t_now), a), zero)
        t_end = jnp.where(valid, te_raw, zero)
        # vector fit: AND-reduce the per-plane demand tests (the
        # demand tail rides in the scalar-prefetch operand; n_res is
        # static, so this loop unrolls at trace time)
        feasible = valid & (n_free >= n_req)
        for r in range(1, n_res):
            feasible = feasible & (nfp_raw[:, r] >= scal_ref[2 + r])
        key1, key2 = _integer_keys_tile(policy_id, n_free,
                                        t_end - t_begin)
        key1 = jnp.where(feasible, key1, _BIG)
        key2 = jnp.where(feasible, key2, _BIG)
        tb = jnp.where(feasible, starts, _BIG)
        idx = i * pt + jax.lax.iota(jnp.int32, pt)
        m1 = jnp.min(key1)
        e1 = key1 == m1
        m2 = jnp.min(jnp.where(e1, key2, _BIG))
        e2 = e1 & (key2 == m2)
        m3 = jnp.min(jnp.where(e2, tb, _BIG))
        e3 = e2 & (tb == m3)
        m4 = jnp.min(jnp.where(e3, idx, _BIG))
        win = e3 & (idx == m4)

        def pick(v):
            return jnp.sum(jnp.where(win, v, 0).astype(jnp.int32))

        row = jnp.stack([m1, m2, m3, m4, pick(n_free), pick(t_begin),
                         pick(t_end), pick(feasible.astype(jnp.int32))])
        acc = acc_ref[0, :]
        less = (row[0] < acc[0]) | (
            (row[0] == acc[0]) & ((row[1] < acc[1]) | (
                (row[1] == acc[1]) & ((row[2] < acc[2]) | (
                    (row[2] == acc[2]) & (row[3] < acc[3]))))))
        acc_ref[0, :] = jnp.where(less, row, acc)


@functools.partial(
    jax.jit, static_argnames=("pt", "n_res", "interpret"))
def availscan_select_mr(
    occ_bits: jax.Array,   # f32[S, n_bits_padded] 0/1 occupancy
    psel: jax.Array,       # f32[n_bits_padded, 128] plane selector
    times: jax.Array,      # i32[S]
    nxt: jax.Array,        # i32[S]
    starts: jax.Array,     # i32[P] candidate starts (T_INF padded)
    a: jax.Array,          # i32[P] window starts (overflow-clamped)
    b: jax.Array,          # i32[P] window ends
    scalars: jax.Array,    # i32[2+n_res]: policy, n_req, t_now,
    #                        demand[1..n_res-1]
    live: jax.Array,       # bool[P] live (unpruned) candidate mask
    *,
    pt: int = DEFAULT_PT,
    n_res: int = 1,
    interpret: bool = True,
) -> jax.Array:
    """Multi-resource :func:`availscan_select` (DESIGN.md §11).

    Same one-row fused epilogue, but feasibility AND-reduces the
    per-plane fit tests against the demand tail carried in the
    scalar-prefetch operand, and ``n_free`` comes valid-masked from
    the plane-selector contraction (no pad correction).  A separate
    kernel so the scalar layout of the R=1 legacy kernel — and its
    compiled graph — stays untouched.
    """
    S, n_bits_p = occ_bits.shape
    assert S % _LANE == 0 and n_bits_p % _LANE == 0, (S, n_bits_p)
    assert scalars.shape[0] == 2 + n_res, (scalars.shape, n_res)
    P = a.shape[0]
    P_pad = -(-P // pt) * pt
    tlive = _tile_live(live, P_pad, pt)
    starts_p = _pad_to(starts, P_pad, T_INF)[:, None]
    a_p = _pad_to(a, P_pad, T_INF - 1)[:, None]
    b_p = _pad_to(b, P_pad, T_INF)[:, None]
    grid = (P_pad // pt,)
    acc = pl.pallas_call(
        functools.partial(_availscan_select_kernel_mr, pt=pt,
                          n_res=n_res),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((pt, 1), lambda i, s, t: (i, 0)),   # starts
                pl.BlockSpec((pt, 1), lambda i, s, t: (i, 0)),   # a
                pl.BlockSpec((pt, 1), lambda i, s, t: (i, 0)),   # b
                pl.BlockSpec((1, S), lambda i, s, t: (0, 0)),    # times
                pl.BlockSpec((1, S), lambda i, s, t: (0, 0)),    # nxt
                pl.BlockSpec((S, n_bits_p),
                             lambda i, s, t: (0, 0)),            # occ
                pl.BlockSpec((n_bits_p, _LANE),
                             lambda i, s, t: (0, 0)),            # psel
            ],
            out_specs=pl.BlockSpec((1, 8), lambda i, s, t: (0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((1, 8), jnp.int32),
        interpret=interpret,
    )(scalars.astype(jnp.int32), tlive, starts_p, a_p, b_p,
      times[None, :], nxt[None, :], occ_bits, psel)
    return acc[0]
