"""Pallas TPU kernel for the availability-rectangle scan.

This is the paper's computational hot spot: ``findAllocation`` spends
``O(p * u * v)`` testing every candidate start against every slot
(Section 4.2 complexity analysis).  The TPU formulation turns the scan
into two MXU contractions per candidate tile (DESIGN.md §2):

    busy[Pt, pe]    = overlap[Pt, S] @ occ_bits[S, pe]      (window union)
    blocking[Pt, S] = free[Pt, pe]   @ occ_bits[S, pe]^T    (rect expansion)

Grid: one program per tile of ``Pt`` candidate start times.  The
occupancy matrix (the shared operand) is mapped to a single grid-
invariant VMEM block, so it is DMA'd from HBM once and reused by every
candidate tile — the TPU analogue of the paper's "organise availability
for efficient search".  All comparisons stay in exact int32; only the
0/1 contraction operands are f32 (counts < 2**24, exact).

VMEM budget per program (defaults Pt=128, S<=1024, n_pe<=2048):
occ_bits f32[S, pe] = 8 MiB worst case + tiles ~1.5 MiB < 16 MiB.
The ops.py wrapper falls back to the pure-jnp path beyond these bounds.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.types import T_INF

# Tile of candidate start times evaluated by one program instance.
DEFAULT_PT = 128
# TPU lane width; S and n_pe are padded to multiples of this.
_LANE = 128


def _availscan_kernel(a_ref, b_ref, times_ref, nxt_ref, occ_ref,
                      nfree_ref, tb_ref, te_ref):
    a = a_ref[:, 0]            # i32[Pt]
    b = b_ref[:, 0]            # i32[Pt]
    times = times_ref[0, :]    # i32[S]
    nxt = nxt_ref[0, :]        # i32[S]
    occ = occ_ref[...]         # f32[S, n_pe] 0/1

    # --- window overlap and busy-PE union (MXU contraction 1) --------
    ov = ((times[None, :] < b[:, None]) &
          (nxt[None, :] > a[:, None])).astype(jnp.float32)     # [Pt, S]
    busy = jax.lax.dot(ov, occ,
                       preferred_element_type=jnp.float32)     # [Pt, pe]
    free = (busy < 0.5)
    nfree_ref[:, 0] = jnp.sum(free.astype(jnp.int32), axis=1)

    # --- blocking slots (MXU contraction 2, contracting the PE axis) -
    blocking = jax.lax.dot_general(
        free.astype(jnp.float32), occ,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) > 0.5              # [Pt, S]

    # --- rectangle bounds: masked max/min over the slot axis ---------
    left = blocking & (nxt[None, :] <= a[:, None])
    tb_ref[:, 0] = jnp.max(
        jnp.where(left, nxt[None, :], -T_INF), axis=1)
    right = blocking & (times[None, :] >= b[:, None])
    te_ref[:, 0] = jnp.min(
        jnp.where(right, times[None, :], T_INF), axis=1)


def _pad_to(x: jax.Array, size: int, fill) -> jax.Array:
    pad = size - x.shape[0]
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(
    jax.jit, static_argnames=("pt", "interpret"))
def availscan(
    occ_bits: jax.Array,   # f32[S, n_pe_padded] 0/1 occupancy
    times: jax.Array,      # i32[S]
    nxt: jax.Array,        # i32[S]
    a: jax.Array,          # i32[P] window starts (overflow-clamped)
    b: jax.Array,          # i32[P] window ends
    *,
    pt: int = DEFAULT_PT,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Tiled scan over candidates.

    Returns raw ``(n_free, t_begin_raw, t_end_raw)`` — ``n_free`` still
    counts PE-axis padding (caller subtracts) and the bounds carry
    ``-T_INF`` / ``T_INF`` sentinels when unblocked (caller clamps).
    """
    S, n_pe_p = occ_bits.shape
    assert S % _LANE == 0 and n_pe_p % _LANE == 0, (S, n_pe_p)
    P = a.shape[0]
    P_pad = -(-P // pt) * pt
    a_p = _pad_to(a, P_pad, T_INF - 1)[:, None]
    b_p = _pad_to(b, P_pad, T_INF)[:, None]
    grid = (P_pad // pt,)
    nfree, tb, te = pl.pallas_call(
        _availscan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((pt, 1), lambda i: (i, 0)),       # a
            pl.BlockSpec((pt, 1), lambda i: (i, 0)),       # b
            pl.BlockSpec((1, S), lambda i: (0, 0)),        # times
            pl.BlockSpec((1, S), lambda i: (0, 0)),        # nxt
            pl.BlockSpec((S, n_pe_p), lambda i: (0, 0)),   # occ_bits
        ],
        out_specs=[
            pl.BlockSpec((pt, 1), lambda i: (i, 0)),
            pl.BlockSpec((pt, 1), lambda i: (i, 0)),
            pl.BlockSpec((pt, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((P_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((P_pad, 1), jnp.int32),
        ],
        interpret=interpret,
    )(a_p, b_p, times[None, :], nxt[None, :], occ_bits)
    return nfree[:P, 0], tb[:P, 0], te[:P, 0]
