"""Checkpoint manager: atomic, asynchronous, restart-safe.

Layout: ``<dir>/step_<n>/`` holding one ``.npy`` per flattened pytree
leaf plus a ``manifest.json`` (treedef, shapes, dtypes, step, config
digest).  Writes go to ``step_<n>.tmp/`` and are renamed into place
(atomic on POSIX), so a crash mid-save never corrupts the latest
checkpoint — the fault-tolerance contract restart relies on.

``save_async`` snapshots to host memory synchronously (cheap) and
writes on a worker thread so the train loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy cannot round-trip ml_dtypes through .npy; store as bit-views
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_savable(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _VIEW_AS:
        return arr.view(_VIEW_AS[name]), name
    return arr, name


def _from_savable(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _VIEW_AS:
        return arr.view(getattr(ml_dtypes, name))
    return arr


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def all_steps(self) -> list:
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp") and \
                    (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any,
             metadata: Optional[dict] = None) -> Path:
        """Blocking save with atomic rename."""
        leaves, treedef = jax.tree.flatten(state)
        host = [np.asarray(x) for x in leaves]
        return self._write(step, host, treedef, metadata or {})

    def save_async(self, step: int, state: Any,
                   metadata: Optional[dict] = None) -> None:
        """Snapshot now, write in the background."""
        self.wait()
        leaves, treedef = jax.tree.flatten(state)
        host = [np.asarray(x) for x in leaves]   # device->host snapshot

        def work():
            self._write(step, host, treedef, metadata or {})

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_leaves, treedef,
               metadata: dict) -> Path:
        with self._lock:
            final = self._step_dir(step)
            tmp = Path(str(final) + ".tmp")
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            dtypes = []
            for i, arr in enumerate(host_leaves):
                savable, name = _to_savable(arr)
                dtypes.append(name)
                np.save(tmp / f"leaf_{i:05d}.npy", savable)
            manifest = {
                "step": step,
                "n_leaves": len(host_leaves),
                "dtypes": dtypes,
                "treedef": str(treedef),
                "metadata": metadata,
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()
            return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, like: Any, step: Optional[int] = None
                ) -> Tuple[Any, int, dict]:
        """Restore into the structure (and shardings) of ``like``."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = jax.tree.flatten(like)
        assert manifest["n_leaves"] == len(leaves), \
            "checkpoint/model structure mismatch"
        dtypes = manifest.get("dtypes", [None] * len(leaves))
        restored = []
        for i, leaf in enumerate(leaves):
            arr = np.load(d / f"leaf_{i:05d}.npy")
            if dtypes[i]:
                arr = _from_savable(arr, dtypes[i])
            if hasattr(leaf, "sharding") and leaf.sharding is not None:
                restored.append(
                    jax.device_put(arr, leaf.sharding))
            else:
                restored.append(jax.numpy.asarray(arr))
        return (jax.tree.unflatten(treedef, restored), step,
                manifest["metadata"])
