"""`repro.api` — the reservation service facade (DESIGN.md §5).

One streaming session API over engines, ensembles and partitions::

    from repro.api import ReservationService, ServiceConfig

    svc = ReservationService(ServiceConfig(n_pe=64))
    session = svc.session()
    result = session.offer(requests)     # fixed-shape chunked admission
    session.tick(now)                    # release due completions
    session.cancel(result.allocations()[0])
"""
from repro.api.config import (  # noqa: F401
    BACKFILLS,
    ENGINE_NAMES,
    ROUTINGS,
    ServiceConfig,
)
from repro.api.service import (  # noqa: F401
    OfferResult,
    ReservationService,
    Session,
)
