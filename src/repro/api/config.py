"""`ServiceConfig`: one declarative knob set for every scheduler shape.

The pre-service entry points each grew their own constructor surface —
``make_scheduler(engine=..., capacity=...)``, ``DeviceScheduler(
bucketing=..., pending_capacity=...)``, ``PartitionedCore(n_partitions,
...)``, the ensemble initialisers — with diverging defaults and
overflow conventions.  `ServiceConfig` subsumes them: a single frozen
dataclass names the engine, the admission policy, the machine size, the
capacity + grow-once policy, the ensemble lane count, the partition
count and routing, the Pallas-kernel switch, and the streaming chunk /
ring geometry.  :class:`repro.api.ReservationService` validates it once
and every session it opens inherits the same semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Tuple, Union

from repro.core import batch as batch_lib
from repro.core.types import BackfillMode, Policy

#: The three engine implementations (see DESIGN.md §1).
ENGINE_NAMES = ("list", "host", "device")

#: Partition routing strategies (see DESIGN.md §4).
ROUTINGS = ("round_robin", "least_loaded", "best_acceptance")

#: Backfilling admission modes (see DESIGN.md §6).
BACKFILLS = tuple(m.value for m in BackfillMode)

#: Named lane placements (see DESIGN.md §8); an int caps shard count.
PLACEMENTS = ("auto", "single", "host")


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Complete configuration of a :class:`~repro.api.ReservationService`.

    Engine / policy
        ``engine`` picks the availability-structure implementation
        (``list`` oracle, ``host`` numpy, ``device`` JAX); ``policy``
        is the default Section-5 admission policy (overridable per
        ``offer``); ``use_kernel`` swaps the dense search for the
        Pallas kernel on the device engine.

    Capacity and the grow-once policy
        ``capacity`` / ``pending_capacity`` size the device timeline
        and pending-release buffer.  With ``auto_grow`` (default) an
        overflowing run grows *once* to the high-water mark it recorded
        (``grown_capacities``, DESIGN.md §3) and re-runs
        deterministically; ``max_growths`` caps that retry loop.
        ``auto_grow=False`` raises ``RuntimeError`` on the first
        overflow for callers that need hard bounds: the overflowing
        dispatch commits nothing and its requests return to the ring
        (earlier chunks of the same offer remain committed — atomicity
        is per chunk).  Partitioned sessions, whose core grows
        internally, require ``auto_grow=True``.

    Scale-out axes
        ``lanes > 1`` stacks that many independent timelines behind one
        vmapped state (the Section-6 grid); ``n_partitions > 1`` splits
        the machine into equal cluster partitions routed by
        ``routing`` (the fleet).  The two axes are exclusive — a lane
        is a *replica* of the whole machine, a partition is a *slice*
        of it.

    Streaming
        ``chunk_size`` is the fixed admission-chunk length of
        :meth:`~repro.api.Session.offer`: arrivals stage in a
        ``ring_capacity``-slot :class:`~repro.core.batch.RequestRing`
        and admit in constant-shape chunks, so steady-state streaming
        never re-pads and never recompiles.  ``chunk_size=None``
        selects one-shot mode (each ``offer`` admits its whole batch in
        one scan — the pre-materialised-experiment shape).

    Backfilling
        ``backfill`` picks the deferral-queue admission mode
        (DESIGN.md §6): ``"none"`` (the paper's strict arrival-order
        admission), ``"conservative"`` (accepted-but-delayed requests
        park in a bounded FCFS queue holding immovable reservations —
        decision-identical to ``none`` with an observable queue) or
        ``"easy"`` (only the head's reservation binds: parked
        reservations may be pulled earlier by the retry sweep, and an
        otherwise-rejected arrival may displace non-head parked jobs
        inside their deadline windows).  On ensemble sessions a tuple
        gives one mode per lane — the mode is *traced*, so mixing
        modes never recompiles.  ``backfill_queue`` sizes the queue
        (static shape; a full queue degrades gracefully: delayed
        requests commit immovably as under ``none``).  Backfilling
        needs the device engine with ``auto_release=True``.
        Partitioned sessions backfill too (every partition lane
        carries its own deferral queue) but share a single mode
        across lanes.  :meth:`~repro.api.Session.pending` exposes the
        live queue.

    Placement and donation (DESIGN.md §8)
        ``placement`` names the device mesh ensemble lanes and cluster
        partitions shard over: ``"auto"`` (default) spreads the lane
        axis over every local device via
        :func:`repro.launch.mesh.make_lane_mesh` — on a single-device
        host this resolves through
        :func:`repro.launch.mesh.make_host_mesh` and behavior is
        unchanged; ``"host"`` pins that 1x1 mesh explicitly; an int
        caps the shard count; ``"single"``/``None`` disables sharding
        entirely.  Decisions are bit-identical across placements (the
        lane axis is embarrassingly parallel).  ``donate`` (default)
        donates the scheduler-state buffers into the jitted admission
        dispatches (``jax.jit(..., donate_argnums=...)``) so the
        steady-state step re-uses its buffers instead of allocating;
        overflow growth re-materializes outside the donated path
        (rollback-on-overflow, DESIGN.md §8) and remains
        deterministic.  With ``donate`` and ``auto_grow``, chunked
        offers also pipeline: the host stages chunk k+1 while the
        device admits chunk k, and the only synchronization is one
        overflow read at the end of the offer.

    ``auto_release=False`` hands completion release to the caller
    (``cancel`` / ``delete_allocation``) instead of the on-device
    pending buffer — the fleet's mode.  Partitioned sessions support
    both: with ``auto_release=True`` every partition lane carries a
    pending-release buffer and :meth:`~repro.api.Session.tick`
    advances all lanes in one dispatch (required when partitions
    backfill).

    Multi-tenancy (DESIGN.md §10)
        ``tenants`` installs a :class:`repro.tenancy.TenantSpec`:
        per-tenant PE-seconds quotas and concurrency caps gate
        admission *before* the search, weighted fair-share replaces
        FCFS in the deferral queue's promote/retry order, and
        ``Session.tick`` reaps overdue reservations past
        ``spec.grace``.  On ensemble sessions a tuple gives one spec
        per lane (``None`` entries leave that lane single-tenant);
        partitioned sessions share one spec, enforced at the host
        router.  ``tenants=None`` (default) adds no pytree leaves —
        the compiled graphs are the ones a tenancy-free build traces.

    Multi-resource and heterogeneous lanes (DESIGN.md §11)
        ``resources`` generalises the machine from one PE pool to a
        static per-resource unit vector (e.g. ``(64, 4, 8)`` = PEs,
        GPUs, licenses); ``resources[0]`` must equal ``n_pe``.  Every
        resource gets its own packed bitplane on the timeline word
        axis and requests may carry a full ``demand`` vector.
        ``machine_sizes`` gives ensemble lanes heterogeneous machine
        sizes: one live-PE count per lane, each ``0 < m <= n_pe``
        (lanes keep the padded ``n_pe`` word shape; dead PEs are
        masked out of every fit test).  Both are device-engine
        features and exclusive with ``n_partitions > 1``.

    Hierarchical availability index (DESIGN.md §12)
        ``index_tile`` attaches per-tile availability summaries to
        every device timeline: candidate pruning, early-reject
        admission and fleet probe prefiltering consume them, with
        decisions provably bit-identical to the index-free path
        (conservative pruning).  A power of two dividing ``capacity``
        (tile size in timeline records); ``None`` (default) adds no
        pytree leaves — the compiled graphs are exactly the ones an
        index-free build traces.

    ``engine_kwargs`` forwards host/list-engine constructor knobs
    (e.g. ``HostScheduler``'s ``candidate_chunk``); device knobs are
    first-class config fields.
    """

    n_pe: int
    engine: str = "device"
    policy: Policy = Policy.PE_W
    capacity: int = 128
    pending_capacity: int = 256
    auto_grow: bool = True
    max_growths: int = batch_lib.MAX_DOUBLINGS
    auto_release: bool = True
    use_kernel: bool = False
    bucketing: bool = True
    lanes: int = 1
    n_partitions: int = 1
    routing: str = "round_robin"
    chunk_size: Optional[int] = 64
    ring_capacity: int = 256
    backfill: Union[str, Tuple[str, ...]] = "none"
    backfill_queue: int = 8
    placement: Union[None, str, int] = "auto"
    donate: bool = True
    tenants: Optional[Any] = None
    resources: Optional[Tuple[int, ...]] = None
    machine_sizes: Optional[Tuple[int, ...]] = None
    index_tile: Optional[int] = None
    engine_kwargs: Optional[Mapping[str, Any]] = None

    def __post_init__(self):
        if self.n_pe < 1:
            raise ValueError(f"n_pe must be >= 1, got {self.n_pe}")
        if self.engine not in ENGINE_NAMES:
            raise ValueError(
                f"unknown engine {self.engine!r}; pick one of "
                f"{ENGINE_NAMES}")
        if isinstance(self.policy, str):
            object.__setattr__(self, "policy", Policy(self.policy))
        if self.lanes < 1 or self.n_partitions < 1:
            raise ValueError("lanes and n_partitions must be >= 1")
        if self.lanes > 1 and self.n_partitions > 1:
            raise ValueError(
                "lanes (whole-machine replicas) and n_partitions "
                "(machine slices) are exclusive scale-out axes")
        if (self.lanes > 1 or self.n_partitions > 1) \
                and self.engine != "device":
            raise ValueError(
                "ensemble lanes and partitions are vmapped device "
                "states; use engine='device'")
        if self.n_partitions > 1 and self.n_pe % self.n_partitions:
            raise ValueError(
                f"n_pe={self.n_pe} not divisible into "
                f"{self.n_partitions} partitions")
        if self.n_partitions > 1 and not self.auto_grow:
            raise ValueError(
                "the partitioned core grows internally; "
                "auto_grow=False is not supported with n_partitions>1")
        if self.engine_kwargs and self.engine == "device":
            raise ValueError(
                "device-engine knobs are first-class config fields "
                "(capacity/pending_capacity/use_kernel/bucketing); "
                "engine_kwargs is for host/list engines")
        if self.max_growths < 0:
            raise ValueError("max_growths must be >= 0")
        if self.routing not in ROUTINGS:
            raise ValueError(
                f"unknown routing {self.routing!r}; pick one of "
                f"{ROUTINGS}")
        if self.chunk_size is not None:
            if self.chunk_size < 1:
                raise ValueError("chunk_size must be >= 1 or None")
            if self.ring_capacity < self.chunk_size:
                raise ValueError(
                    f"ring_capacity ({self.ring_capacity}) must hold "
                    f"at least one chunk ({self.chunk_size})")
        if self.capacity < 2 or self.pending_capacity < 1:
            raise ValueError("capacity >= 2 and pending_capacity >= 1")
        bf = self.backfill
        if isinstance(bf, str):
            if bf not in BACKFILLS:
                raise ValueError(
                    f"unknown backfill {bf!r}; pick one of {BACKFILLS}")
        else:
            bf = tuple(bf)
            object.__setattr__(self, "backfill", bf)
            unknown = [m for m in bf if m not in BACKFILLS]
            if unknown:
                raise ValueError(
                    f"unknown backfill modes {unknown}; pick from "
                    f"{BACKFILLS}")
            if self.n_partitions > 1:
                raise ValueError(
                    "partition lanes share one backfill mode; pass a "
                    "single name (per-lane tuples are for ensemble "
                    "sessions)")
            if len(bf) != self.lanes:
                raise ValueError(
                    f"{len(bf)} backfill modes for {self.lanes} lanes "
                    f"(a tuple gives one mode per ensemble lane)")
        pl = self.placement
        if isinstance(pl, bool) or not (
                pl is None or isinstance(pl, (str, int))):
            raise ValueError(
                f"placement must be one of {PLACEMENTS}, a positive "
                f"int shard cap, or None; got {pl!r}")
        if isinstance(pl, str) and pl not in PLACEMENTS:
            raise ValueError(
                f"unknown placement {pl!r}; pick one of {PLACEMENTS} "
                f"(or an int shard cap / None)")
        if isinstance(pl, int) and pl < 1:
            raise ValueError(
                f"an int placement caps the shard count and must be "
                f">= 1, got {pl}")
        if self.backfilling:
            if self.engine != "device":
                raise ValueError(
                    "backfilling runs on the device deferral queue; "
                    "use engine='device'")
            if not self.auto_release:
                raise ValueError(
                    "backfilling promotes parked reservations through "
                    "the pending-release buffer; it requires "
                    "auto_release=True")
            if self.backfill_queue < 1:
                raise ValueError(
                    "backfill_queue must be >= 1 when backfilling")
        if self.tenants is not None:
            # hoisted tenant-config validation: every unreachable
            # combination fails here at construction, not at first
            # offer (the same discipline as the tuple-backfill hoist)
            from repro.tenancy import TenantSpec
            tn = self.tenants
            if isinstance(tn, (list, tuple)):
                tn = tuple(tn)
                object.__setattr__(self, "tenants", tn)
                if self.n_partitions > 1:
                    raise ValueError(
                        "partition lanes share one tenant spec; pass "
                        "a single TenantSpec (per-lane tuples are for "
                        "ensemble sessions)")
                if len(tn) != self.lanes:
                    raise ValueError(
                        f"{len(tn)} tenant specs for {self.lanes} "
                        f"lanes (a tuple gives one spec per ensemble "
                        f"lane; use None for single-tenant lanes)")
                bad = [type(s).__name__ for s in tn
                       if s is not None and not isinstance(s, TenantSpec)]
                if bad:
                    raise ValueError(
                        f"tenants tuple entries must be TenantSpec or "
                        f"None, got {bad}")
                specs = [s for s in tn if s is not None]
            elif isinstance(tn, TenantSpec):
                specs = [tn]
            else:
                raise ValueError(
                    f"tenants must be a TenantSpec (or a per-lane "
                    f"tuple of TenantSpec/None), got "
                    f"{type(tn).__name__}")
            if self.engine != "device":
                raise ValueError(
                    "tenancy lives in the device state pytree; use "
                    "engine='device'")
            for s in specs:
                if s.n_tenants > self.pending_capacity:
                    raise ValueError(
                        f"max tenants ({s.n_tenants}) exceeds the "
                        f"pending-queue size (pending_capacity="
                        f"{self.pending_capacity}); every tenant must "
                        f"be able to hold at least one live "
                        f"reservation")
        if self.resources is not None:
            rs = tuple(int(x) for x in self.resources)
            object.__setattr__(self, "resources", rs)
            if not rs or rs[0] != self.n_pe:
                raise ValueError(
                    f"resources[0] must equal n_pe={self.n_pe}: "
                    f"got {rs}")
            if any(x < 1 for x in rs):
                raise ValueError(
                    f"every resource needs >= 1 unit: got {rs}")
            if self.engine != "device":
                raise ValueError(
                    "multi-resource timelines live in the device "
                    "state pytree; use engine='device'")
            if self.n_partitions > 1:
                raise ValueError(
                    "resources and n_partitions>1 are not supported "
                    "together (partitions slice the single PE pool)")
        if self.machine_sizes is not None:
            ms = tuple(int(x) for x in self.machine_sizes)
            object.__setattr__(self, "machine_sizes", ms)
            if self.engine != "device":
                raise ValueError(
                    "machine_sizes masks the device timeline; use "
                    "engine='device'")
            if self.n_partitions > 1:
                raise ValueError(
                    "machine_sizes and n_partitions>1 are not "
                    "supported together")
            if self.tenants is not None:
                raise ValueError(
                    "machine_sizes with tenants is not supported "
                    "(tenant PE-seconds accounting assumes "
                    "homogeneous lanes)")
            if len(ms) != self.lanes:
                raise ValueError(
                    f"{len(ms)} machine_sizes for {self.lanes} lanes "
                    f"(one live-PE count per ensemble lane)")
            bad = [m for m in ms if not 0 < m <= self.n_pe]
            if bad:
                raise ValueError(
                    f"machine_sizes entries must be in (0, n_pe="
                    f"{self.n_pe}]: got {bad}")
        if self.index_tile is not None:
            it = int(self.index_tile)
            object.__setattr__(self, "index_tile", it)
            if self.engine != "device":
                raise ValueError(
                    "the availability index lives in the device state "
                    "pytree; use engine='device'")
            if it < 1 or (it & (it - 1)) != 0:
                raise ValueError(
                    f"index_tile must be a positive power of two "
                    f"(so every grown capacity stays divisible): "
                    f"got {it}")
            if self.capacity % it:
                raise ValueError(
                    f"capacity ({self.capacity}) must be divisible "
                    f"by index_tile ({it})")

    @property
    def rspec(self):
        """The session's :class:`~repro.core.resources.ResourceSpec`.

        ``None`` on plain single-resource configs; ``machine_sizes``
        without ``resources`` implies an R=1 spec (heterogeneous
        lanes need the masked fit-test path).
        """
        if self.resources is None and self.machine_sizes is None:
            return None
        from repro.core.resources import ResourceSpec
        return ResourceSpec(self.resources
                            if self.resources is not None
                            else (self.n_pe,))

    @property
    def extra_demand(self) -> int:
        """Staged demand-tail width (R-1) for rings and batches."""
        spec = self.rspec
        return 0 if spec is None else spec.R - 1

    @property
    def machine_units(self) -> Optional[Tuple[Tuple[int, ...], ...]]:
        """Per-lane live-unit tuples for heterogeneous ensembles."""
        if self.machine_sizes is None:
            return None
        spec = self.rspec
        return tuple((m,) + spec.units[1:] for m in self.machine_sizes)

    @property
    def backfilling(self) -> bool:
        """Whether any lane runs a non-``none`` backfill mode."""
        bf = self.backfill
        modes = (bf,) if isinstance(bf, str) else bf
        return any(m != BackfillMode.NONE.value for m in modes)

    @property
    def park_capacity(self) -> int:
        """Static deferral-queue shape: 0 when no lane backfills."""
        return self.backfill_queue if self.backfilling else 0

    @property
    def tenancy(self) -> bool:
        """Whether any lane carries a tenant table."""
        tn = self.tenants
        if tn is None:
            return False
        if isinstance(tn, tuple):
            return any(s is not None for s in tn)
        return True

    @property
    def lane_tenant_specs(self) -> Optional[Tuple[Any, ...]]:
        """Per-lane tenant specs (length ``lanes``), or None."""
        if not self.tenancy:
            return None
        tn = self.tenants
        if isinstance(tn, tuple):
            return tn
        return (tn,) * self.lanes

    def replace(self, **changes) -> "ServiceConfig":
        return dataclasses.replace(self, **changes)

    @classmethod
    def from_engine_kwargs(cls, n_pe: int, engine: str = "host",
                           **kwargs) -> "ServiceConfig":
        """Translate legacy ``make_scheduler`` kwargs to a config.

        The deprecation shims route through here so old call sites
        keep their exact semantics: device kwargs map onto the
        first-class config fields (with the legacy ``capacity=256``
        default), host/list kwargs pass through ``engine_kwargs`` to
        the engine constructor — which still rejects unknown names,
        exactly as before.
        """
        if engine != "device":
            return cls(n_pe=n_pe, engine=engine,
                       engine_kwargs=dict(kwargs) or None)
        known = {"capacity", "pending_capacity", "use_kernel",
                 "bucketing"}
        unknown = set(kwargs) - known
        if unknown:
            raise TypeError(
                f"unknown device engine kwargs {sorted(unknown)}; "
                f"supported: {sorted(known)}")
        defaults = {f.name: f.default for f in dataclasses.fields(cls)}
        merged = {k: kwargs.get(k, defaults[k]) for k in known}
        # the legacy DeviceScheduler defaulted capacity to 256
        if "capacity" not in kwargs:
            merged["capacity"] = 256
        return cls(n_pe=n_pe, engine=engine, **merged)


PolicyLike = Union[Policy, int, str]


def policy_id_of(policy: PolicyLike) -> int:
    """Any policy spelling -> its traced int32 id."""
    from repro.core.policies import policy_index

    if isinstance(policy, str):
        policy = Policy(policy)
    if isinstance(policy, Policy):
        return policy_index(policy)
    return int(policy)
