"""`ReservationService`: one streaming session API over every engine.

The paper's scheduler is a long-lived service admitting *dynamically
arriving* AR requests.  This module is that service: a
:class:`ReservationService` is configured once by a
:class:`~repro.api.config.ServiceConfig` and opens :class:`Session`\\ s
— each session carries device-resident scheduler state across calls
and exposes one coherent verb set over every backend shape (single
timeline, ensemble lanes, cluster partitions, host/list oracles):

``offer(requests)``
    Streaming admission.  Arrivals stage in a fixed-capacity
    :class:`~repro.core.batch.RequestRing` and admit in constant-shape
    ``chunk_size`` chunks of the jitted ``admit_stream`` scan, so a
    session admits continuously with **zero re-padding and zero
    recompilation** after warmup — regardless of how callers group
    their arrivals.  ``chunk_size=None`` selects one-shot mode (each
    offer is one whole-batch scan: the pre-materialised-experiment
    path of ``simulate_batched`` / ``simulate_grid``).
``tick(t)``
    Release-due advancement: delete every pending reservation ending
    by ``t`` (the simulator's completion heap, as a verb).
``cancel(...)``
    Withdraw a committed reservation (idempotent on auto-release
    sessions: an already-released reservation returns ``False``).
``snapshot()`` / ``restore(...)``
    O(1) capture of the functional state — what-if probing for free.
``metrics()``
    Admission counters, growth events, chunk statistics.

Capacity overflow follows the grow-once high-water protocol everywhere
(DESIGN.md §3/§4): the failed dispatch reports the capacity it needed,
the host grows once, and the chunk re-runs deterministically — so
chunked decisions are bit-identical to a one-shot scan that started
with enough capacity.

The classic three operations (``find_allocation`` / ``add_allocation``
/ ``delete_allocation``) remain available on every session, delegating
to the underlying engine, so pre-service consumers (the fleet, the
simulator oracle) migrate without semantic change.
"""
from __future__ import annotations

import copy
import dataclasses
import heapq
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.api.config import ROUTINGS, ServiceConfig, policy_id_of
from repro.core import batch as batch_lib
from repro.core import ensemble as ens_lib
from repro.core import timeline as tl_lib
from repro.core.batch import Decision, RequestBatch, RequestRing
from repro.core.scheduler import DeviceEngine, _make_engine
from repro.core.types import Allocation, ARRequest, Policy, T_INF
from repro.launch.mesh import data_shards, resolve_placement
from repro.sharding import rules as shard_rules


class OfferResult:
    """Outcome of one :meth:`Session.offer` call.

    ``decision`` / ``batch`` / ``valid`` are the stacked fixed-shape
    arrays actually admitted (``[M]``, or ``[E, M]`` on ensemble
    sessions) — ``valid`` masks out ring filler, and consumers reduce
    metrics from them on-device.  :meth:`allocations` unpacks host
    :class:`~repro.core.types.Allocation` objects (or ``None`` per
    rejection) in the order the requests were offered.  Host/list
    sessions build ``decision`` from numpy and leave ``batch`` unset;
    partitioned sessions provide allocations only.

    Pipelined sessions return *deferred* results: the offer's chunks
    are in flight with their overflow latches unread, and any field
    access (or the next state-reading session verb) drains the whole
    in-flight queue in one device sync (DESIGN.md §9).
    """

    def __init__(self, decision: Optional[Decision] = None,
                 batch: Optional[RequestBatch] = None,
                 valid: Optional[np.ndarray] = None,
                 _allocations: Optional[
                     List[Optional[Allocation]]] = None,
                 _finalize: Optional[Any] = None):
        self._decision = decision
        self._batch = batch
        self._valid = valid
        self._allocations = _allocations
        self._finalize = _finalize

    def _materialize(self) -> None:
        if self._finalize is not None:
            fin, self._finalize = self._finalize, None
            fin()

    @property
    def decision(self) -> Optional[Decision]:
        self._materialize()
        return self._decision

    @property
    def batch(self) -> Optional[RequestBatch]:
        self._materialize()
        return self._batch

    @property
    def valid(self) -> Optional[np.ndarray]:
        self._materialize()
        return self._valid

    @property
    def n_offered(self) -> int:
        self._materialize()
        if self._valid is not None:
            return int(np.asarray(self._valid).sum())
        return len(self._allocations or [])

    @property
    def n_accepted(self) -> int:
        self._materialize()
        if self._decision is not None:
            acc = np.asarray(self._decision.accepted)
            return int((acc & np.asarray(self._valid)).sum())
        return sum(a is not None for a in (self._allocations or []))

    def allocations(self) -> List[Optional[Allocation]]:
        """Host allocations for the *valid* offered requests, in order.

        Single-lane sessions only (on ensemble results, index
        ``decision``/``valid`` per lane instead).
        """
        self._materialize()
        if self._allocations is not None:
            return self._allocations
        if self._decision is None:
            return []
        acc = np.asarray(self._decision.accepted)
        if acc.ndim != 1:
            raise ValueError(
                "allocations() is per-lane on ensemble results; use "
                "decision/valid with a lane index")
        allocs = batch_lib.decisions_to_allocations(self._decision)
        self._allocations = [
            a for a, v in zip(allocs, self._valid) if v]
        return self._allocations


def _empty_result() -> OfferResult:
    return OfferResult(decision=None, batch=None, valid=None,
                       _allocations=[])


def _device_fetch(tree):
    """The service's device->host transfer point for metric reads.

    Every poll-path transfer funnels through here so tests can count
    device syncs (``tests/test_tenancy.py::test_idle_metrics_*``):
    an idle ``Session.metrics()`` must perform **zero** calls — the
    device-derived block is cached until the state changes.
    """
    return jax.device_get(tree)


def _mask_np(pe_ids, words: int) -> np.ndarray:
    """PE ids -> uint32[W] bitmask, numpy-only (no device round-trip)."""
    m = np.zeros(words, np.uint32)
    for i in pe_ids:
        m[i // 32] |= np.uint32(1 << (i % 32))
    return m


def _check_demands(rspec, reqs) -> None:
    """Validate request demand vectors against the session's layout.

    On multi-resource sessions every carried ``demand`` must match the
    :class:`~repro.core.resources.ResourceSpec` (length, plane-0 ==
    ``n_pe``, per-plane range); on plain sessions a demand naming
    secondary resources is an error — silently dropping it would admit
    requests against resources the session does not model.
    """
    if rspec is not None:
        for r in reqs:
            rspec.demand_tail(r.demand, r.n_pe)
        return
    for r in reqs:
        if r.demand is not None and len(r.demand) > 1:
            raise ValueError(
                f"request carries a {len(r.demand)}-resource demand "
                f"but this session is single-resource; set "
                f"ServiceConfig.resources")




def _concat_tree(chunks: List[Any], axis: int):
    """Concatenate a list of equally-structured pytrees."""
    if len(chunks) == 1:
        return chunks[0]
    return jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=axis), *chunks)


def _push_front(ring: RequestRing, rows: List[dict], lta: int) -> int:
    """Reinsert popped requests at the *front* of a ring, in order.

    The terminal-overflow restage path: ``rows`` were popped from this
    very ring, so front-insertion restores their original position
    ahead of anything pushed later.  ``lta`` rewinds the filler
    stamp (``last_popped_t_a``) to the newest arrival actually
    decided, so future partial chunks cannot release staged requests'
    predecessors early.  Returns how many rows did not fit (dropped).
    """
    kept = rows[:ring.free]
    for row in reversed(kept):
        ring._head = (ring._head - 1) % ring.capacity
        for f in ring._fields:
            ring._buf[f][ring._head] = row[f]
        ring.count += 1
        ring.popped -= 1
    ring.last_popped_t_a = lta
    return len(rows) - len(kept)


class Session:
    """One long-lived scheduler conversation (state lives on device).

    Create via :meth:`ReservationService.session`.  All admission
    verbs require arrival-ordered traffic (``t_a`` non-decreasing
    across calls), exactly like the paper's event loop.
    """

    def __init__(self, service: "ReservationService"):
        self.service = service
        self.config = service.config
        cfg = self.config
        self._counters = dict(offered=0, accepted=0, released=0,
                              reaped=0, cancelled=0, chunks=0,
                              growths=0, one_shot_scans=0)
        self._backend = _make_backend(cfg, self._counters)

    # -- identity ------------------------------------------------------
    @property
    def engine(self):
        """The underlying engine object (three-op surface)."""
        return self._backend.engine

    # -- the streaming verb set ----------------------------------------
    def offer(self, requests, *, policy=None, routing: Optional[str] = None,
              flush: bool = True) -> OfferResult:
        """Admit newly arrived requests; returns their decisions.

        ``requests`` is an arrival-ordered sequence of
        :class:`~repro.core.types.ARRequest` (on ensemble sessions: one
        such sequence per lane).  With ``flush`` (default) every
        offered request is decided before returning — a final partial
        chunk is padded with never-feasible filler, which cannot change
        decisions.  ``flush=False`` only admits full chunks and leaves
        the remainder staged in the ring for the next offer (or
        :meth:`flush`).

        ``policy`` overrides the config default for this call (one
        policy, or one per lane on ensemble sessions); ``routing``
        applies to partitioned sessions only.
        """
        return self._backend.offer(requests, policy=policy,
                                   routing=routing, flush=flush)

    def flush(self, *, policy=None) -> OfferResult:
        """Decide any requests still staged by ``offer(flush=False)``."""
        return self._backend.offer((), policy=policy, routing=None,
                                   flush=True)

    def tick(self, t: int) -> int:
        """Advance to time ``t``: release reservations ending by ``t``.

        Returns the number of reservations released.  Only meaningful
        on auto-release sessions (the service tracks completions);
        sessions with ``auto_release=False`` hand release back to the
        caller via :meth:`cancel` / ``delete_allocation``.
        """
        return self._backend.tick(t)

    def cancel(self, alloc: Optional[Allocation] = None, *,
               t_s: Optional[int] = None, t_e: Optional[int] = None,
               pe_ids: Optional[Sequence[int]] = None,
               lane: int = 0) -> bool:
        """Withdraw one committed reservation; ``True`` if it was held.

        Pass the :class:`~repro.core.types.Allocation` returned at
        admission (or its ``t_s``/``t_e``/``pe_ids`` triple).  On
        ensemble sessions ``lane`` names the timeline the reservation
        was admitted on (elsewhere it must stay 0).  On auto-release
        sessions cancelling an unknown or already-released reservation
        is a safe no-op returning ``False``.
        """
        if alloc is not None:
            t_s, t_e, pe_ids = alloc.t_s, alloc.t_e, alloc.pe_ids
        if t_s is None or t_e is None or pe_ids is None:
            raise ValueError(
                "cancel needs an Allocation or t_s/t_e/pe_ids")
        return self._backend.cancel(int(t_s), int(t_e), list(pe_ids),
                                    lane=lane)

    def cancel_many(self, allocs: Sequence[Allocation],
                    lane: int = 0) -> List[bool]:
        """Withdraw several committed reservations at once.

        On single-engine sessions all cancellations apply in *one*
        fused dispatch (``timeline.update_many`` deletes every matched
        interval in a single boundary-union + merge pass — DESIGN.md
        §7); other backends fall back to sequential :meth:`cancel`.
        Returns one bool per allocation, matching sequential-cancel
        semantics: on auto-release sessions repeated allocations
        report ``False`` after their first occurrence (the slot is
        already cleared); with ``auto_release=False`` cancels are
        blind deletes and every entry reports ``True``, exactly as
        repeated :meth:`cancel` calls would.
        """
        triples = [(int(a.t_s), int(a.t_e), list(a.pe_ids))
                   for a in allocs]
        return self._backend.cancel_many(triples, lane=lane)

    def snapshot(self):
        """Opaque capture of the whole session state (cheap: pytrees
        are immutable, only ring/heap staging is copied)."""
        return (self._backend.snapshot(), dict(self._counters))

    def restore(self, snap) -> None:
        """Rewind the session to a :meth:`snapshot`."""
        payload, counters = snap
        self._backend.restore(payload)
        self._counters.clear()
        self._counters.update(counters)

    def records(self) -> list:
        """Host view of the availability timeline (merged records)."""
        return self._backend.records()

    def pending(self, lane: int = 0) -> list:
        """The live backfilling deferral queue, FCFS order.

        One dict per parked reservation (``seq``/``t_s``/``t_e``/
        ``t_r``/``t_dl``/``n_pe``/``pe_ids``; the first entry is the
        head of queue).  Empty on non-backfilling sessions.  On
        ensemble sessions ``lane`` names the timeline to inspect.
        """
        return self._backend.pending(lane)

    def metrics(self, tenant: Optional[int] = None) -> Dict[str, Any]:
        """Admission counters plus capacity / streaming geometry.

        On multi-tenant sessions the ``"tenants"`` key carries the
        per-tenant telemetry arrays (weights, quotas, usage, live
        counts, acceptance/slowdown EWMAs — DESIGN.md §10), read in
        one fused device fetch and cached until the state changes,
        so polling an idle session costs zero device syncs.
        ``metrics(tenant=i)`` returns tenant ``i``'s scalar view.
        """
        # backend.metrics() first: it folds the lazily accumulated
        # device-side accepted count into the shared counters dict
        backend = self._backend.metrics()
        out = dict(self._counters)
        out.update(backend)
        out.update(engine=self.config.engine, n_pe=self.config.n_pe,
                   lanes=self.config.lanes,
                   n_partitions=self.config.n_partitions,
                   chunk_size=self.config.chunk_size,
                   backfill=self.config.backfill)
        if tenant is not None:
            snap = out.get("tenants")
            if snap is None:
                raise ValueError(
                    "metrics(tenant=...) needs a multi-tenant "
                    "session (set ServiceConfig.tenants)")
            from repro.tenancy import tenant_view
            return tenant_view(snap, tenant)
        return out

    # -- the classic three operations ----------------------------------
    def find_allocation(self, req: ARRequest, policy=None,
                        t_now: Optional[int] = None
                        ) -> Optional[Allocation]:
        pol = self._backend.resolve_policy(policy)
        return self._backend.find_allocation(req, pol, t_now=t_now)

    def add_allocation(self, t_s: int, t_e: int,
                       pes: Sequence[int]) -> None:
        self._backend.add_allocation(t_s, t_e, pes)

    def delete_allocation(self, t_s: int, t_e: int,
                          pes: Sequence[int]) -> None:
        self._backend.delete_allocation(t_s, t_e, pes)


class ReservationService:
    """The facade: validate one config, open any number of sessions.

    >>> svc = ReservationService(ServiceConfig(n_pe=64))
    >>> session = svc.session()
    >>> result = session.offer(requests)        # stream in arrivals
    >>> session.tick(now)                        # release completions
    """

    def __init__(self, config: Optional[ServiceConfig] = None,
                 **kwargs):
        if config is None:
            config = ServiceConfig(**kwargs)
        elif kwargs:
            config = config.replace(**kwargs)
        self.config = config
        self.sessions: List[Session] = []

    def session(self) -> Session:
        """Open a fresh session (independent all-free state)."""
        s = Session(self)
        self.sessions.append(s)
        return s

    def metrics(self) -> Dict[str, Any]:
        """Config echo plus per-session counters."""
        return {
            "config": dataclasses.asdict(self.config),
            "n_sessions": len(self.sessions),
            "sessions": [s.metrics() for s in self.sessions],
        }


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------


def _make_backend(cfg: ServiceConfig, counters: Dict[str, int]):
    if cfg.n_partitions > 1:
        return _PartitionBackend(cfg, counters)
    if cfg.lanes > 1:
        return _EnsembleBackend(cfg, counters)
    if cfg.engine == "device":
        return _StreamBackend(cfg, counters)
    return _HostBackend(cfg, counters)


class _BackendBase:
    """Shared policy resolution + three-op delegation to ``engine``."""

    def __init__(self, cfg: ServiceConfig, counters: Dict[str, int]):
        self.cfg = cfg
        self.counters = counters
        # `_retained`: an outstanding snapshot/restore aliases our
        # state buffers, so donating them would invalidate it; the
        # next successful admit produces fresh buffers and clears it.
        self._retained = False
        self._acc_dev = None      # lazily synced accepted count
        # device-derived metrics block, cached until the state
        # changes: idle polls re-serve it with zero device syncs
        self._dev_metrics: Optional[Dict[str, Any]] = None

    def resolve_policy(self, policy) -> Policy:
        if policy is None:
            return self.cfg.policy
        if isinstance(policy, str):
            return Policy(policy)
        return policy

    @property
    def growth_budget(self) -> int:
        """Growth retries allowed per dispatch: 0 under
        ``auto_grow=False`` — an overflowing dispatch raises without
        growing or committing anything.  Atomicity is per dispatch
        (chunk): earlier chunks of the same ``offer`` stand, and the
        overflowing chunk's requests return to the ring."""
        return self.cfg.max_growths if self.cfg.auto_grow else 0

    def _grow_guard(self, before: Tuple[int, int],
                    after: Tuple[int, int]) -> None:
        if after != before:
            self.counters["growths"] += 1

    def _donate_ok(self) -> bool:
        return self.cfg.donate and not self._retained

    def _defer_accepted(self, decision, valid) -> None:
        """Accumulate the accepted count on-device, no host sync.

        :meth:`_sync_counters` (called from ``metrics``/``snapshot``)
        folds the accumulator into ``counters["accepted"]`` — this is
        what keeps ``offer`` free of per-call device round-trips.
        """
        n = jnp.sum(
            jnp.logical_and(jnp.asarray(decision.accepted),
                            jnp.asarray(valid)),
            dtype=jnp.int32)
        self._acc_dev = n if self._acc_dev is None else \
            self._acc_dev + n

    def _sync_counters(self) -> None:
        if self._acc_dev is not None:
            self.counters["accepted"] += int(
                _device_fetch(self._acc_dev))
            self._acc_dev = None

    def pending(self, lane: int = 0) -> list:
        if lane != 0:
            raise ValueError("lane applies to ensemble sessions")
        return []

    def cancel_many(self, triples, lane: int = 0) -> List[bool]:
        """Withdraw several reservations; sequential fallback.

        The single-engine backend overrides this with one fused
        ``timeline.update_many`` dispatch (DESIGN.md §7).
        """
        return [self.cancel(ts, te, list(pes), lane=lane)
                for ts, te, pes in triples]

    # three ops: default engine delegation
    def find_allocation(self, req, policy, t_now=None):
        return self.engine.find_allocation(req, policy, t_now=t_now)

    def add_allocation(self, t_s, t_e, pes):
        self.engine.add_allocation(t_s, t_e, list(pes))

    def delete_allocation(self, t_s, t_e, pes):
        self.engine.delete_allocation(t_s, t_e, list(pes))

    def records(self):
        return self.engine.records()


class _StreamBackend(_BackendBase):
    """Single device timeline with ring-buffer chunked streaming."""

    def __init__(self, cfg, counters):
        super().__init__(cfg, counters)
        mu = cfg.machine_units
        self.engine = DeviceEngine(
            cfg.n_pe, capacity=cfg.capacity, use_kernel=cfg.use_kernel,
            bucketing=cfg.bucketing,
            pending_capacity=cfg.pending_capacity,
            park_capacity=cfg.park_capacity,
            tenants=cfg.tenants, rspec=cfg.rspec,
            live_units=mu[0] if mu is not None else None,
            index_tile=cfg.index_tile)
        self._rspec = cfg.rspec
        self._n_tenants = cfg.tenants.n_tenants if cfg.tenancy else 0
        self._grace = cfg.tenants.grace if cfg.tenancy else None
        self._bf = batch_lib.BF_NONE if not cfg.backfilling else \
            batch_lib.as_backfill_id(cfg.backfill)
        self.ring = RequestRing(cfg.ring_capacity,
                                with_tenant=cfg.tenancy,
                                extra_demand=cfg.extra_demand) \
            if cfg.chunk_size else None
        # pipelined offers whose overflow latches are still unread:
        # one dict per offer, drained together in one device sync
        self._inflight: List[dict] = []

    @property
    def _state(self):
        return self.engine.state

    @_state.setter
    def _state(self, s):
        self.engine.state = s
        self.engine._n_valid = None      # lazily recomputed on search
        self._dev_metrics = None         # device metrics went stale

    def _check_tenants(self, reqs) -> None:
        if self._n_tenants:
            for r in reqs:
                if r.tenant >= self._n_tenants:
                    raise ValueError(
                        f"request tenant {r.tenant} out of range "
                        f"[0, {self._n_tenants}) for this session's "
                        f"TenantSpec")
        _check_demands(self._rspec, reqs)

    def _capacities(self):
        s = self._state
        return (s.tl.capacity, s.pending_capacity)

    def _admit_batch(self, batch: RequestBatch, pid: int) -> Decision:
        before = self._capacities()
        try:
            state, dec = batch_lib.admit_stream_grow(
                self._state, batch, pid, n_pe=self.cfg.n_pe,
                backfill=self._bf,
                auto_release=self.cfg.auto_release,
                use_kernel=self.cfg.use_kernel,
                max_growths=self.growth_budget,
                donate=self._donate_ok())
        except batch_lib.GrowthError as e:
            if e.state is not None:
                # the donated attempt consumed our buffers; reinstall
                # the in-dispatch rollback (latch cleared) so the
                # session stays usable after the raise
                self._state = e.state._replace(
                    overflow=jnp.zeros_like(e.state.overflow))
            raise
        self._grow_guard(before, (state.tl.capacity,
                                  state.pending_capacity))
        self._state = state
        self._retained = False
        return dec

    def pending(self, lane: int = 0) -> list:
        if lane != 0:
            raise ValueError("lane applies to ensemble sessions")
        self._drain_inflight()
        return batch_lib.parked_entries(self._state)

    # three ops + records read (or mutate) the live state: settle any
    # in-flight pipelined offers first
    def find_allocation(self, req, policy, t_now=None):
        self._drain_inflight()
        return self.engine.find_allocation(req, policy, t_now=t_now)

    def add_allocation(self, t_s, t_e, pes):
        self._drain_inflight()
        self.engine.add_allocation(t_s, t_e, list(pes))

    def delete_allocation(self, t_s, t_e, pes):
        self._drain_inflight()
        self.engine.delete_allocation(t_s, t_e, list(pes))

    def records(self):
        self._drain_inflight()
        return self.engine.records()

    def offer(self, requests, *, policy, routing, flush) -> OfferResult:
        if routing is not None:
            raise ValueError("routing applies to partitioned sessions")
        if not flush and self.ring is None:
            raise ValueError(
                "flush=False staging needs the ring buffer; this "
                "session is one-shot (chunk_size=None)")
        pid = policy_id_of(self.resolve_policy(policy))
        if isinstance(requests, RequestBatch):
            # pre-packed batch: the pre-materialised-experiment path
            if self.ring is not None:
                raise ValueError(
                    "a pre-packed RequestBatch bypasses the ring; use "
                    "chunk_size=None (one-shot mode) or offer "
                    "ARRequest sequences")
            n = requests.t_a.shape[0]
            self.counters["offered"] += n
            dec = self._admit_batch(requests, pid)
            self.counters["one_shot_scans"] += 1
            res = OfferResult(decision=dec, batch=requests,
                              valid=np.ones(n, bool))
            self._defer_accepted(res.decision, res.valid)
            return res
        reqs = list(requests)
        self._check_tenants(reqs)
        if self.ring is None:
            self.counters["offered"] += len(reqs)
            if not reqs:
                return _empty_result()
            batch = batch_lib.requests_to_batch(
                reqs, with_tenant=bool(self._n_tenants),
                extra_demand=self.cfg.extra_demand)
            dec = self._admit_batch(batch, pid)
            self.counters["one_shot_scans"] += 1
            valid = np.ones(len(reqs), bool)
            res = OfferResult(decision=dec, batch=batch, valid=valid)
            self._defer_accepted(res.decision, res.valid)
            return res
        batch_lib.check_arrival_order(reqs, self.ring.last_t_a)
        self.counters["offered"] += len(reqs)
        if self._donate_ok() and self.growth_budget > 0:
            return self._offer_pipelined(reqs, pid, flush)
        self._drain_inflight()
        return self._offer_eager(reqs, pid, flush)

    def _offer_eager(self, reqs, pid, flush) -> OfferResult:
        chunk = self.cfg.chunk_size
        decs: List[Decision] = []
        batches: List[RequestBatch] = []
        valids: List[np.ndarray] = []

        def drain_one():
            # keep the ring intact if the chunk raises (auto_grow=False
            # overflow): the popped requests stay staged for a retry
            ring_snap = self.ring.snapshot()
            batch, valid = self.ring.pop_chunk(chunk, self.cfg.n_pe)
            try:
                decs.append(self._admit_batch(batch, pid))
            except Exception:
                self.ring.restore(ring_snap)
                raise
            batches.append(batch)
            valids.append(valid)
            self.counters["chunks"] += 1

        i = 0
        while i < len(reqs):
            take = min(self.ring.free, len(reqs) - i)
            self.ring.push(reqs[i:i + take])
            i += take
            while self.ring.count >= chunk:
                drain_one()
        if flush:
            while self.ring.count:
                drain_one()
        if not decs:
            return _empty_result()
        res = OfferResult(decision=_concat_tree(decs, axis=0),
                          batch=_concat_tree(batches, axis=0),
                          valid=np.concatenate(valids))
        self._defer_accepted(res.decision, res.valid)
        return res

    def _offer_pipelined(self, reqs, pid, flush) -> OfferResult:
        """Chunked drain over the double-buffered device ring.

        Zero per-chunk synchronization: every chunk's admit goes
        through :func:`~repro.core.batch.admit_stream_donated`
        (allocation-free, async), and while the device runs chunk k
        the host pops and uploads chunk k+1 from the ring.  The
        overflow latches are not read here at all: the offer registers
        itself on ``_inflight`` and returns a *deferred*
        :class:`OfferResult`, so consecutive offers keep pipelining
        with zero device syncs between them.  The first result-field
        access or state-reading verb calls :meth:`_drain_inflight`,
        which reads every outstanding latch in one stacked
        ``device_get`` (DESIGN.md §8/§9).  On overflow (rare) the
        sticky in-dispatch rollback left the state exactly at the
        first latched chunk, so the tail replays deterministically on
        a grown state — decisions bit-identical to the eager
        per-chunk path.
        """
        chunk = self.cfg.chunk_size
        decs: List[Decision] = []
        batches: List[RequestBatch] = []
        valids: List[np.ndarray] = []
        ovfs: List[jax.Array] = []
        ltas: List[int] = [self.ring.last_popped_t_a]
        staged = None

        def stage():
            popped = self.ring.pop_chunk(chunk, self.cfg.n_pe)
            ltas.append(self.ring.last_popped_t_a)
            return popped

        def dispatch(cur) -> None:
            batch, valid = cur
            state, dec = batch_lib.admit_stream_donated(
                self._state, batch, jnp.int32(pid), self._bf,
                n_pe=self.cfg.n_pe,
                auto_release=self.cfg.auto_release,
                use_kernel=self.cfg.use_kernel)
            self._state = state
            # jnp.any copies the latch into a fresh buffer: the next
            # dispatch donates `state` (this leaf included) away
            ovfs.append(jnp.any(state.overflow))
            decs.append(dec)
            batches.append(batch)
            valids.append(valid)
            self.counters["chunks"] += 1

        def drain(more) -> None:
            nonlocal staged
            while staged is not None or more():
                cur = staged if staged is not None else stage()
                staged = None
                dispatch(cur)          # device admits chunk k ...
                if more():
                    staged = stage()   # ... host stages chunk k+1

        i = 0
        while i < len(reqs):
            take = min(self.ring.free, len(reqs) - i)
            self.ring.push(reqs[i:i + take])
            i += take
            drain(lambda: self.ring.count >= chunk)
        if flush:
            drain(lambda: self.ring.count > 0)
        if not decs:
            return _empty_result()
        res = OfferResult(_finalize=self._drain_inflight)
        self._inflight.append(dict(ovfs=ovfs, decs=decs,
                                   batches=batches, valids=valids,
                                   ltas=ltas, pid=pid, result=res))
        return res

    def _drain_inflight(self) -> None:
        """Settle every in-flight pipelined offer in one device sync.

        Reads all outstanding overflow latches with a single stacked
        ``device_get``.  In the common all-clear case every offer's
        decisions are already correct and just need concatenating.  On
        a latch, the sticky in-dispatch rollback made every dispatch
        from the first latched chunk on state-preserving, so
        ``_state`` is the pre-latch state sized by the failed tail's
        watermarks: grow once from the rollback, replay the owning
        offer's tail, then replay *all* chunks of every later offer
        (their original decisions are garbage) — observably identical
        to the eager per-chunk path.
        """
        if not self._inflight:
            return
        inflight, self._inflight = self._inflight, []
        all_ovfs = [o for ctx in inflight for o in ctx["ovfs"]]
        # the drain's single synchronization point: all latches at once
        latched = np.asarray(_device_fetch(jnp.stack(all_ovfs)))
        err = None
        if latched.any():
            g = int(latched.argmax())     # first latched dispatch
            c = 0                          # -> (offer c, its chunk g)
            while g >= len(inflight[c]["ovfs"]):
                g -= len(inflight[c]["ovfs"])
                c += 1
            for ci in range(c, len(inflight)):
                ctx = inflight[ci]
                err = self._replay_chunks(
                    g if ci == c else 0, ctx, rollback=(ci == c))
                if err is not None:
                    # terminal overflow: every later dispatch was
                    # state-preserving.  Restage undecided requests in
                    # arrival order — newest offer pushed first so the
                    # oldest tail ends up at the ring head.
                    for later in reversed(inflight[ci + 1:]):
                        self.counters["chunks"] -= len(
                            later["batches"])
                        self._restage_tail(0, later["batches"],
                                           later["valids"],
                                           later["ltas"])
                        del later["decs"][:], later["batches"][:], \
                            later["valids"][:]
                    k = ctx["fail_k"]
                    self._restage_tail(k, ctx["batches"],
                                       ctx["valids"], ctx["ltas"])
                    del ctx["decs"][k:], ctx["batches"][k:], \
                        ctx["valids"][k:]
                    break
        for ctx in inflight:
            res = ctx["result"]
            res._finalize = None
            if ctx["decs"]:
                res._decision = _concat_tree(ctx["decs"], axis=0)
                res._batch = _concat_tree(ctx["batches"], axis=0)
                res._valid = np.concatenate(ctx["valids"])
                self._defer_accepted(res._decision, res._valid)
            else:
                res._allocations = []
        if err is not None:
            raise err

    def _replay_chunks(self, j: int, ctx: dict, *,
                       rollback: bool) -> Optional[Exception]:
        """Re-run one offer's chunks ``j..`` after a latched overflow.

        ``rollback`` grows the rolled-back state first (only the offer
        owning the first latched chunk; later offers replay on the
        already-healthy state).  On terminal overflow the offer is
        truncated at the failing chunk (``ctx["fail_k"]``) and the
        :class:`~repro.core.batch.GrowthError` is returned for the
        caller to restage and re-raise.
        """
        if rollback:
            before = self._capacities()
            self._state = batch_lib.grow_rollback(self._state)
            self._grow_guard(before, self._capacities())
        batches, decs = ctx["batches"], ctx["decs"]
        for k in range(j, len(batches)):
            try:
                decs[k] = self._admit_batch(batches[k], ctx["pid"])
            except batch_lib.GrowthError as e:
                ctx["fail_k"] = k
                self.counters["chunks"] -= len(batches) - k
                return e
        return None

    def _restage_tail(self, k: int, batches, valids, ltas) -> None:
        """Return undecided chunks ``k..`` to the front of the ring.

        Terminal overflow during a replay: the eager path would have
        left these requests staged, so reinsert them ahead of anything
        pushed later (order preserved — they were popped from here).
        Requests that no longer fit are dropped with a warning; the
        session itself stays usable on the rolled-back state.
        """
        rows = []
        names = self.ring._fields
        for batch, valid in zip(batches[k:], valids[k:]):
            fields = {f: np.asarray(getattr(batch, f))
                      for f in names}
            for i in np.flatnonzero(valid):
                rows.append({f: int(fields[f][i]) for f in names})
        dropped = _push_front(self.ring, rows, ltas[k])
        if dropped:
            warnings.warn(
                f"ring full while restaging after terminal overflow: "
                f"{dropped} undecided requests dropped",
                RuntimeWarning, stacklevel=2)

    def tick(self, t: int) -> int:
        if not self.cfg.auto_release:
            return self._reap(t)
        self._drain_inflight()
        before_rel = int(self._state.n_released)
        before = self._capacities()
        state = batch_lib.release_until(
            self._state, t, max_growths=self.growth_budget)
        self._grow_guard(before, (state.tl.capacity,
                                  state.pending_capacity))
        self._state = state
        released = int(state.n_released) - before_rel
        self.counters["released"] += released
        return released

    def _reap(self, t: int) -> int:
        """Overdue-reservation reaping (DESIGN.md §10).

        With ``auto_release=False`` the caller owns completion release
        — but a multi-tenant session with a ``grace`` window still
        reclaims reservations held past ``t_e + grace`` on ``tick``,
        batch-deleting them and charging the usage (``n_reaped``) to
        the owning tenant.  Auto-release sessions never reap: their
        ``tick`` already deletes everything ending by ``t``, which is
        strictly earlier than ``t - grace``.
        """
        if self._grace is None:
            return 0
        self._drain_inflight()
        before_rel = int(self._state.n_released)
        before = self._capacities()
        state = batch_lib.reap_until(
            self._state, t, self._grace,
            max_growths=self.growth_budget)
        self._grow_guard(before, (state.tl.capacity,
                                  state.pending_capacity))
        self._state = state
        reaped = int(state.n_released) - before_rel
        self.counters["reaped"] += reaped
        return reaped

    def cancel(self, t_s: int, t_e: int, pe_ids: List[int],
               lane: int = 0) -> bool:
        if lane != 0:
            raise ValueError("lane applies to ensemble sessions")
        self._drain_inflight()
        mask = tl_lib.ids_to_mask32(pe_ids, self._state.tl.words)
        before = self._capacities()
        state, done = batch_lib.cancel_one(
            self._state, t_s, t_e, mask,
            require_pending=self.cfg.auto_release,
            max_growths=self.growth_budget)
        self._grow_guard(before, (state.tl.capacity,
                                  state.pending_capacity))
        self._state = state
        self.counters["cancelled"] += int(done)
        return done

    def cancel_many(self, triples, lane: int = 0) -> List[bool]:
        if lane != 0:
            raise ValueError("lane applies to ensemble sessions")
        self._drain_inflight()
        W = self._state.tl.words
        entries = [(ts, te, tl_lib.ids_to_mask32(pes, W))
                   for ts, te, pes in triples]
        before = self._capacities()
        state, done = batch_lib.cancel_many(
            self._state, entries,
            require_pending=self.cfg.auto_release,
            max_growths=self.growth_budget)
        self._grow_guard(before, (state.tl.capacity,
                                  state.pending_capacity))
        self._state = state
        self.counters["cancelled"] += sum(done)
        return done

    def snapshot(self):
        self._drain_inflight()
        self._sync_counters()
        self._retained = True    # snapshot aliases these buffers
        return (self._state,
                self.ring.snapshot() if self.ring else None)

    def restore(self, payload):
        self._drain_inflight()   # settle results against the old state
        state, ring_snap = payload
        self._state = state
        self._retained = True    # ...and so does a restored payload
        self._acc_dev = None     # accumulated after the snapshot
        if self.ring and ring_snap is not None:
            self.ring.restore(ring_snap)

    def _refresh_dev_metrics(self) -> None:
        """One fused device read of every state-derived counter."""
        s = self._state
        vals: Dict[str, Any] = dict(
            n_pending=jnp.sum(s.pend_te != T_INF, dtype=jnp.int32))
        if self.cfg.backfilling:
            vals.update(
                n_parked_now=jnp.sum(s.park_seq != T_INF,
                                     dtype=jnp.int32),
                n_parked=s.n_parked, n_promoted=s.n_promoted,
                n_moved=s.n_moved)
        if s.tenants is not None:
            from repro.tenancy.telemetry import _PER_TENANT
            vals["tenants"] = {
                f: getattr(s.tenants, f)
                for f in _PER_TENANT + ("occ_ewma",)}
        host = _device_fetch(vals)
        self._dev_metrics = {
            k: ({kk: np.asarray(vv) for kk, vv in v.items()}
                if k == "tenants" else int(v))
            for k, v in host.items()}

    def metrics(self):
        # fast path (satellite: idle polls cost no device sync): with
        # nothing in flight, no deferred accepted count, and a warm
        # cache, this performs zero device fetches
        if self._inflight:
            self._drain_inflight()
        self._sync_counters()
        if self._dev_metrics is None:
            self._refresh_dev_metrics()
        cap, pend = self._capacities()
        out = dict(capacity=cap, pending_capacity=pend)
        out.update(self._dev_metrics)
        if self.ring:
            out.update(ring_capacity=self.ring.capacity,
                       ring_staged=self.ring.count,
                       ring_wrapped=self.ring.wrapped)
        if self.cfg.backfilling:
            out["park_capacity"] = self._state.park_capacity
        return out


class _EnsembleBackend(_BackendBase):
    """E whole-machine replica lanes behind one vmapped state."""

    def __init__(self, cfg, counters):
        super().__init__(cfg, counters)
        # lane axis -> mesh data axis (DESIGN.md §8): every stacked
        # leaf is sharded on its leading (ensemble) dimension, so the
        # vmapped admit scan runs one program with each device owning
        # lanes/n_shards lanes — decisions are placement-invariant.
        self.mesh = resolve_placement(cfg.placement, cfg.lanes)
        states = ens_lib.init_ensemble(
            cfg.lanes, cfg.capacity, cfg.n_pe, cfg.pending_capacity,
            cfg.park_capacity, rspec=cfg.rspec,
            machine_units=cfg.machine_units,
            index_tile=cfg.index_tile)
        self._lane_specs = cfg.lane_tenant_specs
        if self._lane_specs is not None:
            # per-lane tables stack to one [E, ...] pytree and shard
            # on the lane axis with everything else (DESIGN.md §10);
            # None entries become neutral tables, decision-identical
            # to no table (the FCFS-equivalence invariant)
            from repro.tenancy import stack_tables
            states = states._replace(tenants=stack_tables(
                self._lane_specs, cfg.pending_capacity,
                cfg.park_capacity))
        self.states = self._put(states)
        self._bf_ids = self._put(
            ens_lib.backfill_ids(cfg.backfill, cfg.lanes))
        self.rings = [RequestRing(cfg.ring_capacity,
                                  with_tenant=cfg.tenancy,
                                  extra_demand=cfg.extra_demand)
                      for _ in range(cfg.lanes)] \
            if cfg.chunk_size else None

    def _put(self, tree):
        """Lane-shard a stacked pytree (no-op on unsharded sessions,
        and for leaves already carrying the target sharding)."""
        return shard_rules.shard_ensemble(self.mesh, tree)

    @property
    def states(self):
        return self._states_val

    @states.setter
    def states(self, s):
        self._states_val = s
        self._dev_metrics = None         # device metrics went stale

    @property
    def engine(self):
        return self

    def _capacities(self):
        return ens_lib.lane_capacity(self.states)

    def _resolve_pids(self, policy) -> jax.Array:
        E = self.cfg.lanes
        if policy is None:
            policy = self.cfg.policy
        if isinstance(policy, (Policy, int, str)):
            return jnp.full((E,), policy_id_of(policy), jnp.int32)
        if isinstance(policy, jax.Array):
            return policy
        pids = [policy_id_of(p) for p in policy]
        if len(pids) != E:
            raise ValueError(
                f"{len(pids)} policies for {E} lanes")
        return jnp.asarray(pids, jnp.int32)

    def _admit_batch(self, batch: RequestBatch,
                     pids: jax.Array) -> Decision:
        before = self._capacities()
        try:
            states, dec = ens_lib.admit_stream_ensemble_auto(
                self.states, self._put(batch), pids,
                n_pe=self.cfg.n_pe,
                backfills=self._bf_ids,
                auto_release=self.cfg.auto_release,
                use_kernel=self.cfg.use_kernel,
                max_growths=self.growth_budget,
                donate=self._donate_ok())
        except batch_lib.GrowthError as e:
            if e.state is not None:
                self.states = e.state._replace(
                    overflow=jnp.zeros_like(e.state.overflow))
            raise
        after = ens_lib.lane_capacity(states)
        self._grow_guard(before, after)
        if after != before:
            # growth re-materialized the lanes outside the donated
            # dispatch; re-pin the lane sharding deterministically
            states = self._put(states)
        self.states = states
        self._retained = False
        return dec

    def pending(self, lane: int = 0) -> list:
        if not 0 <= lane < self.cfg.lanes:
            raise ValueError(
                f"lane {lane} out of range for {self.cfg.lanes} lanes")
        return batch_lib.parked_entries(
            ens_lib.member(self.states, lane))

    def offer(self, streams, *, policy, routing, flush) -> OfferResult:
        if routing is not None:
            raise ValueError("routing applies to partitioned sessions")
        if not flush and self.rings is None:
            raise ValueError(
                "flush=False staging needs the ring buffers; this "
                "session is one-shot (chunk_size=None)")
        pids = self._resolve_pids(policy)
        if isinstance(streams, tuple) and len(streams) == 2 \
                and isinstance(streams[0], RequestBatch):
            # pre-padded (batch, valid): the grid's one-shot path
            if self.rings is not None:
                raise ValueError(
                    "a pre-padded (RequestBatch, valid) pair bypasses "
                    "the rings; use chunk_size=None (one-shot mode)")
            batch, valid = streams
            self.counters["offered"] += int(valid.sum())
            dec = self._admit_batch(batch, pids)
            self.counters["one_shot_scans"] += 1
            res = OfferResult(decision=dec, batch=batch, valid=valid)
            self._defer_accepted(res.decision, res.valid)
            return res
        streams = [list(s) for s in streams] or \
            [[] for _ in range(self.cfg.lanes)]
        if len(streams) != self.cfg.lanes:
            raise ValueError(
                f"{len(streams)} per-lane streams for "
                f"{self.cfg.lanes} lanes")
        if self.rings is not None:
            for ring, stream in zip(self.rings, streams):
                batch_lib.check_arrival_order(stream, ring.last_t_a)
        if self._lane_specs is not None:
            for e, (spec, stream) in enumerate(
                    zip(self._lane_specs, streams)):
                limit = spec.n_tenants if spec is not None else 1
                for r in stream:
                    if r.tenant >= limit:
                        raise ValueError(
                            f"request tenant {r.tenant} out of range "
                            f"[0, {limit}) for lane {e}'s TenantSpec")
        for stream in streams:
            _check_demands(self.cfg.rspec, stream)
        self.counters["offered"] += sum(map(len, streams))
        if self.rings is None:
            if not any(streams):
                return _empty_result()
            batch, valid = batch_lib.pad_streams(
                streams, self.cfg.n_pe, with_tenant=self.cfg.tenancy,
                extra_demand=self.cfg.extra_demand)
            dec = self._admit_batch(batch, pids)
            self.counters["one_shot_scans"] += 1
            res = OfferResult(decision=dec, batch=batch, valid=valid)
            self._defer_accepted(res.decision, res.valid)
            return res
        if self._donate_ok() and self.growth_budget > 0:
            return self._offer_pipelined(streams, pids, flush)
        return self._offer_eager(streams, pids, flush)

    def _offer_eager(self, streams, pids, flush) -> OfferResult:
        chunk = self.cfg.chunk_size
        decs, batches, valids = [], [], []

        def drain_one(full_only: bool):
            # a lane below a full chunk keeps its requests staged
            # unless this is a flushing drain (flush=False contract)
            ring_snaps = [r.snapshot() for r in self.rings]
            batch, valid = batch_lib.pop_chunk_ensemble(
                self.rings, chunk, self.cfg.n_pe, full_only=full_only)
            try:
                decs.append(self._admit_batch(batch, pids))
            except Exception:
                for r, s in zip(self.rings, ring_snaps):
                    r.restore(s)
                raise
            batches.append(batch)
            valids.append(valid)
            self.counters["chunks"] += 1

        cursors = [0] * self.cfg.lanes
        while any(c < len(s) for c, s in zip(cursors, streams)):
            for e, (ring, stream) in enumerate(
                    zip(self.rings, streams)):
                take = min(ring.free, len(stream) - cursors[e])
                ring.push(stream[cursors[e]:cursors[e] + take])
                cursors[e] += take
            while any(r.count >= chunk for r in self.rings):
                drain_one(full_only=not flush)
        if flush:
            while any(r.count for r in self.rings):
                drain_one(full_only=False)
        if not decs:
            return _empty_result()
        res = OfferResult(decision=_concat_tree(decs, axis=1),
                          batch=_concat_tree(batches, axis=1),
                          valid=np.concatenate(valids, axis=1))
        self._defer_accepted(res.decision, res.valid)
        return res

    def _offer_pipelined(self, streams, pids, flush) -> OfferResult:
        """Lane-stacked pipelined drain (see the stream backend).

        One donated vmapped dispatch per chunk across all lanes —
        sharded lanes run their slices in the same program — while the
        host pops and lane-shards the next chunk.  All overflow
        latches are read once at the end; a latched chunk replays on
        a collectively grown ensemble, bit-identical to the eager
        per-chunk growth path.
        """
        chunk = self.cfg.chunk_size
        pids = self._put(pids)
        decs, batches, valids, ovfs = [], [], [], []
        ltas = [[r.last_popped_t_a for r in self.rings]]
        staged = None

        def stage(full_only: bool):
            batch, valid = batch_lib.pop_chunk_ensemble(
                self.rings, chunk, self.cfg.n_pe, full_only=full_only)
            ltas.append([r.last_popped_t_a for r in self.rings])
            return self._put(batch), valid

        def dispatch(cur) -> None:
            batch, valid = cur
            states, dec = ens_lib.admit_stream_ensemble_donated(
                self.states, batch, pids, self._bf_ids,
                n_pe=self.cfg.n_pe,
                auto_release=self.cfg.auto_release,
                use_kernel=self.cfg.use_kernel)
            self.states = states
            ovfs.append(jnp.any(states.overflow))
            decs.append(dec)
            batches.append(batch)
            valids.append(valid)
            self.counters["chunks"] += 1

        def drain(more, full_only: bool) -> None:
            nonlocal staged
            while staged is not None or more():
                cur = staged if staged is not None \
                    else stage(full_only)
                staged = None
                dispatch(cur)
                if more():
                    staged = stage(full_only)

        cursors = [0] * self.cfg.lanes
        while any(c < len(s) for c, s in zip(cursors, streams)):
            for e, (ring, stream) in enumerate(
                    zip(self.rings, streams)):
                take = min(ring.free, len(stream) - cursors[e])
                ring.push(stream[cursors[e]:cursors[e] + take])
                cursors[e] += take
            drain(lambda: any(r.count >= chunk for r in self.rings),
                  full_only=not flush)
        if flush:
            drain(lambda: any(r.count for r in self.rings),
                  full_only=False)
        if not decs:
            return _empty_result()
        latched = np.asarray(jax.device_get(jnp.stack(ovfs)))
        if latched.any():
            self._replay_overflow(int(latched.argmax()), batches,
                                  pids, decs, valids, ltas)
        res = OfferResult(decision=_concat_tree(decs, axis=1),
                          batch=_concat_tree(batches, axis=1),
                          valid=np.concatenate(valids, axis=1))
        self._defer_accepted(res.decision, res.valid)
        return res

    def _replay_overflow(self, j: int, batches, pids, decs, valids,
                         ltas) -> None:
        """Collective-growth replay of chunks ``j..`` after rollback."""
        before = self._capacities()
        self.states = self._put(
            ens_lib.grow_rollback_ensemble(self.states))
        self._grow_guard(before, self._capacities())
        for k in range(j, len(batches)):
            try:
                decs[k] = self._admit_batch(batches[k], pids)
            except batch_lib.GrowthError:
                self._restage_tail(k, batches, valids, ltas)
                self.counters["chunks"] -= len(batches) - k
                del decs[k:], batches[k:], valids[k:]
                raise

    def _restage_tail(self, k: int, batches, valids, ltas) -> None:
        """Per-lane front-reinsertion of undecided chunks ``k..``."""
        dropped = 0
        for e, ring in enumerate(self.rings):
            rows = []
            names = ring._fields
            for batch, valid in zip(batches[k:], valids[k:]):
                fields = {f: np.asarray(getattr(batch, f)[e])
                          for f in names}
                for i in np.flatnonzero(valid[e]):
                    rows.append({f: int(fields[f][i]) for f in names})
            dropped += _push_front(ring, rows, ltas[k][e])
        if dropped:
            warnings.warn(
                f"rings full while restaging after terminal overflow: "
                f"{dropped} undecided requests dropped",
                RuntimeWarning, stacklevel=2)

    def tick(self, t: int) -> int:
        if not self.cfg.auto_release:
            return self._reap(t)
        before_rel = int(jnp.sum(self.states.n_released))
        before = self._capacities()
        states = ens_lib.release_until_ensemble(
            self.states, t, max_growths=self.growth_budget)
        self._grow_guard(before, ens_lib.lane_capacity(states))
        self.states = self._put(states)
        released = int(jnp.sum(states.n_released)) - before_rel
        self.counters["released"] += released
        return released

    def _reap(self, t: int) -> int:
        """Per-lane overdue reaping (see the stream backend's _reap).

        Each lane reaps with its own spec's grace; lanes without one
        get a ``T_INF`` grace, whose cutoff precedes every arrival.
        """
        if self._lane_specs is None:
            return 0
        graces = [T_INF if s is None or s.grace is None else s.grace
                  for s in self._lane_specs]
        if all(g == T_INF for g in graces):
            return 0
        before_rel = int(jnp.sum(self.states.n_released))
        before = self._capacities()
        states = ens_lib.reap_until_ensemble(
            self.states, t, np.asarray(graces, np.int32),
            max_growths=self.growth_budget)
        self._grow_guard(before, ens_lib.lane_capacity(states))
        self.states = self._put(states)
        reaped = int(jnp.sum(states.n_released)) - before_rel
        self.counters["reaped"] += reaped
        return reaped

    def cancel(self, t_s, t_e, pe_ids, lane: int = 0) -> bool:
        if not 0 <= lane < self.cfg.lanes:
            raise ValueError(
                f"lane {lane} out of range for {self.cfg.lanes} lanes")
        one = ens_lib.member(self.states, lane)
        mask = tl_lib.ids_to_mask32(pe_ids, one.tl.words)
        state, done = batch_lib.cancel_one(
            one, t_s, t_e, mask,
            require_pending=self.cfg.auto_release,
            max_growths=self.growth_budget)
        if state.tl.capacity != one.tl.capacity or \
                state.pending_capacity != one.pending_capacity:
            # growth must stay collective (shared static lane shape)
            self.states = self._put(ens_lib.grow_ensemble(
                self.states, state.tl.capacity,
                state.pending_capacity))
            self.counters["growths"] += 1
            one = ens_lib.member(self.states, lane)
            state, done = batch_lib.cancel_one(
                one, t_s, t_e, mask,
                require_pending=self.cfg.auto_release,
                max_growths=self.growth_budget)
        self.states = self._put(
            ens_lib.set_member(self.states, lane, state))
        self.counters["cancelled"] += int(done)
        return done

    def find_allocation(self, req, policy, t_now=None):
        raise NotImplementedError(
            "ensemble sessions decide per lane; use offer() with "
            "per-lane streams")

    add_allocation = delete_allocation = find_allocation

    def records(self, lane: int = 0):
        times = np.asarray(self.states.tl.times[lane])
        occ = np.asarray(self.states.tl.occ[lane])
        return [(int(t), frozenset(batch_lib.mask32_to_ids(o)))
                for t, o in zip(times, occ) if t < T_INF]

    def snapshot(self):
        self._sync_counters()
        self._retained = True
        return (self.states,
                [r.snapshot() for r in self.rings]
                if self.rings else None)

    def restore(self, payload):
        states, ring_snaps = payload
        self.states = states
        self._retained = True
        self._acc_dev = None
        if self.rings and ring_snaps is not None:
            for r, s in zip(self.rings, ring_snaps):
                r.restore(s)

    def _refresh_dev_metrics(self) -> None:
        """One fused device read of every state-derived counter."""
        s = self.states
        vals: Dict[str, Any] = {}
        if self.cfg.backfilling:
            vals.update(
                n_parked_now=jnp.sum(s.park_seq != T_INF,
                                     dtype=jnp.int32),
                n_parked=jnp.sum(s.n_parked),
                n_promoted=jnp.sum(s.n_promoted),
                n_moved=jnp.sum(s.n_moved))
        if s.tenants is not None:
            from repro.tenancy.telemetry import _PER_TENANT
            vals["tenants"] = {
                f: getattr(s.tenants, f)
                for f in _PER_TENANT + ("occ_ewma",)}
        host = _device_fetch(vals) if vals else {}
        self._dev_metrics = {
            k: ({kk: np.asarray(vv) for kk, vv in v.items()}
                if k == "tenants" else int(v))
            for k, v in host.items()}

    def metrics(self):
        self._sync_counters()
        if self._dev_metrics is None:
            self._refresh_dev_metrics()
        cap, pend = self._capacities()
        out = dict(capacity=cap, pending_capacity=pend,
                   placement_shards=data_shards(self.mesh)
                   if self.mesh is not None else 1)
        out.update(self._dev_metrics)
        if self.rings:
            out.update(ring_capacity=self.cfg.ring_capacity,
                       ring_staged=sum(r.count for r in self.rings),
                       ring_wrapped=any(r.wrapped for r in self.rings))
        if self.cfg.backfilling:
            out["park_capacity"] = int(
                self.states.park_seq.shape[-1])
        return out


class _PartitionBackend(_BackendBase):
    """Cluster partitions (machine slices) with routed bulk admission."""

    def __init__(self, cfg, counters):
        super().__init__(cfg, counters)
        from repro.runtime.fleet import PartitionedCore

        bf = cfg.backfill if isinstance(cfg.backfill, str) \
            else cfg.backfill[0]
        self.engine = PartitionedCore(
            cfg.n_pe, cfg.n_partitions, capacity=cfg.capacity,
            pending_capacity=cfg.pending_capacity,
            use_kernel=cfg.use_kernel, placement=cfg.placement,
            park_capacity=cfg.park_capacity, backfill=bf,
            auto_release=cfg.auto_release,
            index_tile=cfg.index_tile)
        # partitions enforce tenancy at the host router (the lane
        # states keep tenants=None): a HostTenantAccounts gate before
        # routing, and a completion ledger attributing each held
        # reservation to its tenant for release / overdue reaping
        self._accounts = None
        self._grace = None
        if cfg.tenancy:
            from repro.tenancy import HostTenantAccounts
            self._accounts = HostTenantAccounts(cfg.tenants)
            self._grace = cfg.tenants.grace
        self._ledger: list = []   # heap of (t_e, seq, tid, t_s, ids)
        self._lseq = 0

    def _ledger_release(self, t: int) -> None:
        """Mirror the engine's completion releases ending by ``t``."""
        while self._ledger and self._ledger[0][0] <= t:
            _, _, tid, _, _ = heapq.heappop(self._ledger)
            self._accounts.release(tid)

    def offer(self, requests, *, policy, routing, flush) -> OfferResult:
        routing = routing or self.cfg.routing
        if routing not in ROUTINGS:
            raise ValueError(
                f"unknown routing {routing!r}; pick one of {ROUTINGS}")
        if not flush:
            raise ValueError(
                "flush=False staging is a ring-buffer (device "
                "session) feature; partitioned sessions decide every "
                "offer immediately")
        reqs = list(requests)
        self.counters["offered"] += len(reqs)
        if not reqs:
            return _empty_result()
        pol = self.resolve_policy(policy)
        if self._accounts is None:
            allocs = self.engine.admit_stream_allocations(
                reqs, pol, routing)
        else:
            allocs = self._offer_gated(reqs, pol, routing)
        self.counters["accepted"] += \
            sum(a is not None for a in allocs)
        self.counters["one_shot_scans"] += 1
        return OfferResult(decision=None, batch=None, valid=None,
                           _allocations=allocs)

    def _offer_gated(self, reqs, pol, routing):
        """Quota-gated routing: reject over-quota before the probe.

        Same gate order as the device path (DESIGN.md §10): releases
        ending by the arrival settle first (so ``live`` reflects the
        post-release population), then the float32 quota /
        concurrency check, then routing for requests that pass.
        Occupancy EWMA is not tracked at the router (no single
        machine occupancy exists across partitions): ``occ_frac=0``.
        """
        acc = self._accounts
        allocs: List[Optional[Allocation]] = []
        for req in reqs:
            if acc.n_tenants and req.tenant >= acc.n_tenants:
                raise ValueError(
                    f"request tenant {req.tenant} out of range "
                    f"[0, {acc.n_tenants}) for this session's "
                    f"TenantSpec")
            if self.cfg.auto_release:
                self._ledger_release(req.t_a)
            tid = acc.clip_tid(req.tenant)
            if not acc.allowed(tid, req.n_pe, req.t_du):
                acc.record(tid, accepted=False, blocked=True,
                           parked=False, occ_frac=np.float32(0.0))
                allocs.append(None)
                continue
            alloc = self.engine.admit_stream_allocations(
                [req], pol, routing)[0]
            acc.record(tid, accepted=alloc is not None,
                       blocked=False, parked=False,
                       occ_frac=np.float32(0.0),
                       t_e=alloc.t_e if alloc else -1,
                       t_r=req.t_r, t_du=req.t_du, n_pe=req.n_pe)
            if alloc is not None:
                heapq.heappush(
                    self._ledger,
                    (alloc.t_e, self._lseq, tid, alloc.t_s,
                     tuple(alloc.pe_ids)))
                self._lseq += 1
            allocs.append(alloc)
        return allocs

    def tick(self, t: int) -> int:
        # with auto_release=False the client owns completion release
        # (cancel/delete_allocation); otherwise advance every lane's
        # pending buffer in one dispatch
        if not self.cfg.auto_release:
            return self._reap(t)
        before = int(np.asarray(
            self.engine.states.n_released).sum())
        self.engine.release_until(t)
        if self._accounts is not None:
            self._ledger_release(t)
        released = int(np.asarray(
            self.engine.states.n_released).sum()) - before
        self.counters["released"] += released
        return released

    def _reap(self, t: int) -> int:
        """Ledger-driven overdue reaping at the host router."""
        if self._accounts is None or self._grace is None:
            return 0
        reaped = 0
        cutoff = t - self._grace
        while self._ledger and self._ledger[0][0] <= cutoff:
            t_e, _, tid, t_s, ids = heapq.heappop(self._ledger)
            self.engine.delete_allocation(t_s, t_e, list(ids))
            self._accounts.reap(tid)
            reaped += 1
        self.counters["reaped"] += reaped
        return reaped

    def pending(self, lane: int = 0) -> list:
        if not 0 <= lane < self.cfg.n_partitions:
            raise ValueError(
                f"lane {lane} out of range for "
                f"{self.cfg.n_partitions} partitions")
        if not self.cfg.backfilling:
            return []
        return batch_lib.parked_entries(
            ens_lib.member(self.engine.states, lane))

    def cancel(self, t_s, t_e, pe_ids, lane: int = 0) -> bool:
        if lane != 0:
            raise ValueError(
                "partitioned sessions address reservations by global "
                "chip ids, not lanes")
        if not self.cfg.auto_release:
            self.engine.delete_allocation(t_s, t_e, list(pe_ids))
            self._ledger_cancel(t_s, t_e, pe_ids)
            self.counters["cancelled"] += 1
            return True
        # auto-release lanes track completions in the pending buffer:
        # cancel through cancel_one so the slot clears with the
        # interval (a blind delete would double-release at tick)
        eng = self.engine
        part, local = eng._split(pe_ids)
        state = ens_lib.member(eng.states, part)
        mask = tl_lib.ids_to_mask32(local, state.tl.words)
        state, done = batch_lib.cancel_one(
            state, t_s, t_e, mask, require_pending=True,
            max_growths=0)
        eng.states = eng._put(
            ens_lib.set_member(eng.states, part, state))
        if done:
            eng._bump_load(part, -(t_e - t_s) * len(local))
            self._ledger_cancel(t_s, t_e, pe_ids)
        self.counters["cancelled"] += int(done)
        return done

    def _ledger_cancel(self, t_s, t_e, pe_ids) -> None:
        """Drop a cancelled reservation's ledger entry (if tracked)."""
        if self._accounts is None:
            return
        key = (t_e, t_s, tuple(pe_ids))
        for i, ent in enumerate(self._ledger):
            if (ent[0], ent[3], ent[4]) == key:
                self._accounts.release(ent[2])
                self._ledger.pop(i)
                heapq.heapify(self._ledger)
                return

    def snapshot(self):
        tenancy = None
        if self._accounts is not None:
            tenancy = (copy.deepcopy(self._accounts),
                       list(self._ledger), self._lseq)
        return (self.engine.states, list(self.engine.load),
                self.engine._rr, tenancy)

    def restore(self, payload):
        states, load, rr, tenancy = payload
        self.engine.states = states
        self.engine.load = list(load)
        self.engine._rr = rr
        if tenancy is not None:
            accounts, ledger, lseq = tenancy
            self._accounts = copy.deepcopy(accounts)
            self._ledger = list(ledger)
            self._lseq = lseq

    def metrics(self):
        cap, pend = ens_lib.lane_capacity(self.engine.states)
        out = dict(capacity=cap, pending_capacity=pend,
                   chips_per_partition=self.engine.chips_per_part,
                   partition_load=list(self.engine.load),
                   dispatches=self.engine.dispatches,
                   match_rounds=self.engine.last_match_rounds)
        if self.cfg.backfilling:
            s = self.engine.states
            out.update(
                # per-lane queue depth (park_capacity reads axis 0,
                # which is the lane axis on a stacked state)
                park_capacity=int(s.park_seq.shape[-1]),
                n_parked_now=int(np.asarray(
                    s.park_seq != T_INF).sum()),
                n_parked=int(np.asarray(s.n_parked).sum()),
                n_promoted=int(np.asarray(s.n_promoted).sum()),
                n_moved=int(np.asarray(s.n_moved).sum()))
        if self._accounts is not None:
            out["tenants"] = self._accounts.snapshot()
            out["ledger_depth"] = len(self._ledger)
        return out


class _HostBackend(_BackendBase):
    """Host/list engines behind the same verb set (reference path)."""

    def __init__(self, cfg, counters):
        super().__init__(cfg, counters)
        self.engine = _make_engine(cfg.n_pe, cfg.engine,
                                   **(cfg.engine_kwargs or {}))
        self._completions: list = []     # heap of (t_e, seq, t_s, ids)
        self._seq = 0
        self._last_ta = 0                # arrival-order watermark

    def _pes(self, ids):
        return set(ids) if self.cfg.engine == "list" else list(ids)

    def add_allocation(self, t_s, t_e, pes):
        self.engine.add_allocation(t_s, t_e, self._pes(pes))

    def delete_allocation(self, t_s, t_e, pes):
        self.engine.delete_allocation(t_s, t_e, self._pes(pes))

    def _release_due(self, t: int) -> int:
        n = 0
        while self._completions and self._completions[0][0] <= t:
            t_e, _, t_s, ids = heapq.heappop(self._completions)
            self.engine.delete_allocation(t_s, t_e, self._pes(ids))
            n += 1
        self.counters["released"] += n
        return n

    def offer(self, requests, *, policy, routing, flush) -> OfferResult:
        if routing is not None:
            raise ValueError("routing applies to partitioned sessions")
        if not flush:
            raise ValueError(
                "flush=False staging is a ring-buffer (device "
                "session) feature; host/list sessions decide every "
                "offer immediately")
        pol = self.resolve_policy(policy)
        reqs = list(requests)
        _check_demands(None, reqs)
        batch_lib.check_arrival_order(reqs, self._last_ta)
        self.counters["offered"] += len(reqs)
        if not reqs:
            return _empty_result()
        W = tl_lib.n_words(self.cfg.n_pe)
        rows: List[Tuple] = []
        allocs: List[Optional[Allocation]] = []
        for req in reqs:
            if self.cfg.auto_release:
                self._release_due(req.t_a)
            alloc = self.engine.find_allocation(req, pol,
                                                t_now=req.t_a)
            allocs.append(alloc)
            if alloc is None:
                rows.append((False, -1, -1, np.zeros(W, np.uint32),
                             0, 0, 0))
                continue
            self.engine.add_allocation(alloc.t_s, alloc.t_e,
                                       self._pes(alloc.pe_ids))
            if self.cfg.auto_release:
                heapq.heappush(
                    self._completions,
                    (alloc.t_e, self._seq, alloc.t_s,
                     tuple(alloc.pe_ids)))
                self._seq += 1
            r = alloc.rectangle
            rows.append((True, alloc.t_s, alloc.t_e,
                         _mask_np(alloc.pe_ids, W),
                         r.n_free, r.t_begin, r.t_end))
        self._last_ta = reqs[-1].t_a
        self.counters["accepted"] += \
            sum(a is not None for a in allocs)
        dec = Decision(
            accepted=np.asarray([r[0] for r in rows]),
            t_s=np.asarray([r[1] for r in rows], np.int32),
            t_e=np.asarray([r[2] for r in rows], np.int32),
            pe_mask=np.stack([r[3] for r in rows]),
            n_free=np.asarray([r[4] for r in rows], np.int32),
            t_begin=np.asarray([r[5] for r in rows], np.int32),
            t_end=np.asarray([r[6] for r in rows], np.int32),
            parked=np.zeros(len(rows), bool))
        return OfferResult(
            decision=dec, batch=None,
            valid=np.ones(len(reqs), bool), _allocations=allocs)

    def tick(self, t: int) -> int:
        if not self.cfg.auto_release:
            return 0
        return self._release_due(t)

    def cancel(self, t_s, t_e, pe_ids, lane: int = 0) -> bool:
        if lane != 0:
            raise ValueError("lane applies to ensemble sessions")
        key = (t_s, t_e, tuple(pe_ids))
        if self.cfg.auto_release:
            match = [c for c in self._completions
                     if (c[2], c[0], c[3]) == key]
            if not match:
                return False
            self._completions.remove(match[0])
            heapq.heapify(self._completions)
        self.engine.delete_allocation(t_s, t_e, self._pes(pe_ids))
        self.counters["cancelled"] += 1
        return True

    def snapshot(self):
        return (copy.deepcopy(self.engine),
                list(self._completions), self._seq, self._last_ta)

    def restore(self, payload):
        engine, completions, seq, last_ta = payload
        self.engine = copy.deepcopy(engine)
        self._completions = list(completions)
        self._seq = seq
        self._last_ta = last_ta

    def metrics(self):
        return dict(n_pending=len(self._completions))
