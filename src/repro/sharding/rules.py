"""Logical -> mesh sharding rules for parameters and inputs.

``param_specs(params)`` walks the parameter pytree and assigns each
leaf a :class:`PartitionSpec` by its path (Megatron-style TP over the
"model" axis, EP for experts, head-sharding for SSM/mLSTM).  GSPMD
handles non-divisible dimensions by padding (e.g. starcoder2's 36 heads
on a 16-way axis), so the rules never special-case arch dims.

``zero_specs`` derives optimizer-state shardings: each state tensor is
additionally sharded over the data axis on its first free dimension —
ZeRO-1.  GSPMD inserts the reduce-scatter / all-gather pair implied by
the sharding mismatch with the gradients, which is exactly the ZeRO
communication pattern.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (parent-context, leaf-name) -> index of the axis sharded over "model";
# negative indices count from the end so stacked [L, ...] and unstacked
# layer weights share one rule.  None context = any parent.
_MODEL_AXIS_RULES = [
    ("moe", "w_gate", -3),      # [.., E, d, f] -> experts
    ("moe", "w_up", -3),
    ("moe", "w_down", -3),
    ("moe", "router", -1),
    ("mlp", "w_gate", -1),      # [.., d, f] -> ff
    ("mlp", "w_up", -1),
    ("mlp", "w_down", -2),
    ("attn", "wq", -2),         # [.., d, H, hd] -> heads
    ("attn", "wk", -2),
    ("attn", "wv", -2),
    ("attn", "wo", -3),         # [.., H, hd, d]
    ("ssm", "w_in", -1),
    ("ssm", "w_out", -2),
    ("ssm", "conv_w", -1),
    ("ssm", "conv_b", -1),
    ("ssm", "a_log", -1),
    ("ssm", "d_skip", -1),
    ("ssm", "dt_bias", -1),
    ("ssm", "norm", -1),
    ("mlstm", "w_qkv", -2),     # [.., d, H, 3hd] -> heads
    ("mlstm", "w_if", -2),
    ("mlstm", "w_gate", -1),
    ("mlstm", "w_out", -2),
    ("mlstm", "norm", -2),
    ("slstm", "w_x", -2),
    ("slstm", "r_h", -3),
    ("slstm", "bias", -2),
    ("slstm", "norm", -2),
    (None, "tok_embed", 0),     # vocab-sharded embedding
    (None, "lm_head", -1),
    (None, "enc_embed_proj", -1),
    (None, "img_proj", -1),
]


MODEL_AXIS_SIZE = 16   # fixed by the production mesh (16x16 / 2x16x16)
DATA_AXES_SIZE = 16    # secondary (fully-sharded) axis, per pod

# MoE expert tensors additionally shard their ffn/d axis over the data
# axes (2D expert sharding, FSDP-style): a trillion-parameter expert
# bank cannot live 16-way sharded (kimi-k2 would need 136 GiB/chip).
_DATA_AXIS_RULES = {
    ("moe", "w_gate"): -1,   # [.., E, d, f] -> f over data
    ("moe", "w_up"): -1,
    ("moe", "w_down"): -2,   # [.., E, f, d] -> f over data
}


def _spec_for(path, leaf) -> P:
    names = [p.key for p in path if hasattr(p, "key")]
    leaf_name = names[-1] if names else ""
    parents = set(names[:-1])
    ndim = leaf.ndim
    for ctx, name, axis in _MODEL_AXIS_RULES:
        if name != leaf_name:
            continue
        if ctx is not None and ctx not in parents:
            continue
        ax = axis % ndim if ndim else 0
        if ndim == 0:
            return P()
        if leaf.shape[ax] % MODEL_AXIS_SIZE != 0:
            # replicated fallback: GSPMD input shardings must divide
            # (e.g. starcoder2's 36 heads, 8-of-16 KV heads).  Noted in
            # EXPERIMENTS.md; candidates for the perf pass.
            return P(*([None] * ndim))
        spec = [None] * ndim
        spec[ax] = "model"
        for (d_ctx, d_name), d_axis in _DATA_AXIS_RULES.items():
            if d_name == leaf_name and d_ctx in parents:
                dax = d_axis % ndim
                if dax != ax and leaf.shape[dax] % DATA_AXES_SIZE == 0:
                    spec[dax] = ("pod", "data")
        return P(*spec)
    return P(*([None] * ndim))


def param_specs(params) -> Any:
    """PartitionSpec pytree matching ``params``."""
    return jax.tree_util.tree_map_with_path(_spec_for, params)


def zero_specs(specs, params, mesh: Mesh) -> Any:
    """ZeRO-1: shard optimizer state over the data axes too (on the
    first free *divisible* dimension of each tensor)."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dn = 1
    for a in data_axes:
        dn *= mesh.shape[a]

    def add_data(spec: P, leaf) -> P:
        if leaf.ndim == 0 or leaf.size < 1024 or not data_axes:
            return spec
        axes = list(spec) + [None] * (leaf.ndim - len(spec))
        if any(isinstance(a, (tuple, list)) or a in ("pod", "data")
               for a in axes if a is not None):
            return spec    # already data-sharded (2D expert weights)
        for i in range(leaf.ndim):
            if axes[i] is None and leaf.shape[i] % dn == 0:
                axes[i] = data_axes
                return P(*axes)
        return spec
    return jax.tree.map(add_data, specs, params)


def to_named(mesh: Mesh, specs) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree, dropping mesh axes
    that do not exist on this mesh (single-pod vs multi-pod)."""
    names = set(mesh.axis_names)

    def conv(spec: P) -> NamedSharding:
        axes = []
        for s in spec:
            if s is None:
                axes.append(None)
            elif isinstance(s, (tuple, list)):
                kept = tuple(a for a in s if a in names)
                axes.append(kept if kept else None)
            else:
                axes.append(s if s in names else None)
        return NamedSharding(mesh, P(*axes))
    return jax.tree.map(conv, specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(ndim: int, batch_axis: int = 0) -> P:
    axes: list = [None] * ndim
    axes[batch_axis] = ("pod", "data")
    return P(*axes)


# --------------------------------------------------------------------------
# scheduler-ensemble lane sharding (DESIGN.md §8)
# --------------------------------------------------------------------------
#
# The stacked SchedulerState / RequestBatch pytrees of
# :mod:`repro.core.ensemble` carry their ensemble (lane) axis as the
# *leading* axis of every leaf, so one rule covers the whole tree:
# shard axis 0 over the mesh's data axes and replicate the rest.
# ``fit_sharding`` drops the data axes per-leaf whenever the lane
# count does not divide (the service layer builds divisor meshes via
# ``launch.mesh.make_lane_mesh``, so this is a belt-and-braces
# fallback, never a silent correctness change).

LANE_DATA_AXES = ("pod", "data")


def lane_spec(ndim: int) -> P:
    """Leading lane axis over the data mesh axes, rest replicated."""
    return P(*((LANE_DATA_AXES,) + (None,) * (ndim - 1)))


def ensemble_specs(tree) -> Any:
    """PartitionSpec pytree for a stacked (leading-lane-axis) pytree."""
    return jax.tree.map(lambda x: lane_spec(max(x.ndim, 1)), tree)


def ensemble_shardings(mesh: Mesh, tree) -> Any:
    """NamedSharding pytree: lane axis over ``mesh``'s data axes."""
    return jax.tree.map(
        lambda x: fit_sharding(mesh, x.shape, lane_spec(max(x.ndim, 1))),
        tree)


def shard_ensemble(mesh: Optional[Mesh], tree) -> Any:
    """Place a stacked ensemble pytree lane-sharded on ``mesh``.

    One ``device_put`` per leaf (async; a no-op for leaves already
    carrying the target sharding).  ``mesh=None`` returns the tree
    untouched — the unsharded single-device path.
    """
    if mesh is None:
        return tree
    return jax.device_put(tree, ensemble_shardings(mesh, tree))


def probe_spec(ndim: int) -> P:
    """Spec for ``[N, E, ...]`` fleet-probe tensors (DESIGN.md §9).

    The request-batched probe ``find_allocations_ensemble`` yields
    leaves whose *second* axis is the lane axis (requests lead): shard
    axis 1 over the data mesh axes, replicate the request axis and any
    trailing word axes.
    """
    return P(*((None, LANE_DATA_AXES) + (None,) * (ndim - 2)))


def probe_shardings(mesh: Mesh, tree) -> Any:
    """NamedSharding pytree for an ``[N, E, ...]`` probe pytree."""
    return jax.tree.map(
        lambda x: fit_sharding(
            mesh, x.shape,
            probe_spec(x.ndim) if x.ndim >= 2
            else P(*([None] * x.ndim))),
        tree)


def shard_probe(mesh: Optional[Mesh], tree) -> Any:
    """Pin a probe pytree's lane axis (axis 1) onto ``mesh``.

    ``mesh=None`` returns the tree untouched; leaves with fewer than
    two dims (scalars from degenerate probes) stay replicated.
    """
    if mesh is None:
        return tree
    return jax.device_put(tree, probe_shardings(mesh, tree))


def fit_sharding(mesh: Mesh, shape, spec: P) -> NamedSharding:
    """NamedSharding with indivisible / missing axes dropped per-dim."""
    names = set(mesh.axis_names)

    def extent(ax) -> int:
        if isinstance(ax, (tuple, list)):
            n = 1
            for a in ax:
                n *= mesh.shape[a]
            return n
        return mesh.shape[ax]

    axes = []
    for i, ax in enumerate(spec):
        if ax is None:
            axes.append(None)
            continue
        if isinstance(ax, (tuple, list)):
            ax = tuple(a for a in ax if a in names)
            ax = ax if ax else None
        elif ax not in names:
            ax = None
        if ax is not None and shape[i] % extent(ax) != 0:
            ax = None
        axes.append(ax)
    return NamedSharding(mesh, P(*axes))
