"""Subpackage."""
