"""Discrete-event simulator for AR scheduling (paper Section 6).

Mirrors the paper's SimJava setup with a single event loop: a meta-user
submits AR requests in arrival order; the meta-scheduler manages the
availability structure and decides admission with one of the seven
policies; completions release their PEs via ``deleteAllocation``.

The engine is pluggable (list / host / device) — the host numpy engine
is the default for 10^4-job runs; the device engine exercises the
jitted JAX (+ optional Pallas) path end-to-end.
"""
from __future__ import annotations

import heapq
import time as _time
from typing import Iterable, List, Optional

from repro.core.scheduler import make_scheduler
from repro.core.types import ARRequest, Policy
from repro.sim.metrics import SimResult


def simulate(
    jobs: Iterable[ARRequest],
    n_pe: int,
    policy: Policy,
    engine: str = "host",
    engine_kwargs: Optional[dict] = None,
) -> SimResult:
    """Run one experiment: schedule every job, collect the metrics."""
    jobs = sorted(jobs, key=lambda j: j.t_a)
    sched = make_scheduler(n_pe, engine=engine, **(engine_kwargs or {}))
    completions: List = []   # heap of (t_e, seq, t_s, t_e, pe_ids)
    seq = 0
    result = SimResult(policy=policy.value, n_jobs=len(jobs),
                       n_accepted=0, n_pe=n_pe)
    wall = 0.0
    for req in jobs:
        t_now = req.t_a
        # release completed reservations first (paper: deleteAllocation
        # is called immediately when a job finishes)
        while completions and completions[0][0] <= t_now:
            _, _, ts, te, ids = heapq.heappop(completions)
            t0 = _time.perf_counter()
            sched.delete_allocation(ts, te, ids)
            wall += _time.perf_counter() - t0
        t0 = _time.perf_counter()
        alloc = sched.find_allocation(req, policy, t_now=t_now)
        if alloc is not None:
            sched.add_allocation(alloc.t_s, alloc.t_e, _as_pes(alloc, engine))
        wall += _time.perf_counter() - t0
        if alloc is None:
            continue
        result.n_accepted += 1
        wait = alloc.t_s - req.t_r
        result.slowdowns.append((wait + req.t_du) / req.t_du)
        result.busy_area += req.n_pe * req.t_du
        heapq.heappush(
            completions, (alloc.t_e, seq, alloc.t_s, alloc.t_e,
                          _as_pes(alloc, engine)))
        seq += 1
    if jobs:
        result.span = max(jobs[-1].t_a, 1) - jobs[0].t_a + 1
    result.wall_seconds = wall
    return result


def _as_pes(alloc, engine: str):
    return set(alloc.pe_ids) if engine == "list" else list(alloc.pe_ids)


def run_policies(jobs: List[ARRequest], n_pe: int,
                 policies: Iterable[Policy],
                 engine: str = "host") -> List[SimResult]:
    """Evaluate several policies on one shared workload (paper setup)."""
    return [simulate(jobs, n_pe, pol, engine=engine) for pol in policies]
