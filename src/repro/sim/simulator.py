"""Discrete-event simulator for AR scheduling (paper Section 6).

Mirrors the paper's SimJava setup with a single event loop: a meta-user
submits AR requests in arrival order; the meta-scheduler manages the
availability structure and decides admission with one of the seven
policies; completions release their PEs via ``deleteAllocation``.

The engine is pluggable (list / host / device) — the host numpy engine
is the default for 10^4-job runs; the device engine exercises the
jitted JAX (+ optional Pallas) path end-to-end.
"""
from __future__ import annotations

import heapq
import time as _time
from typing import Iterable, List, Optional

import numpy as np

from repro.api import ReservationService, ServiceConfig
from repro.core import batch as batch_lib
from repro.core.scheduler import _make_engine
from repro.core.types import ARRequest, Policy
from repro.sim.metrics import SimResult


def simulate(
    jobs: Iterable[ARRequest],
    n_pe: int,
    policy: Policy,
    engine: str = "host",
    engine_kwargs: Optional[dict] = None,
    record_decisions: bool = False,
) -> SimResult:
    """Run one experiment: schedule every job, collect the metrics."""
    jobs = sorted(jobs, key=lambda j: j.t_a)
    sched = _make_engine(n_pe, engine=engine, **(engine_kwargs or {}))
    completions: List = []   # heap of (t_e, seq, t_s, t_e, pe_ids)
    seq = 0
    result = SimResult(policy=policy.value, n_jobs=len(jobs),
                       n_accepted=0, n_pe=n_pe)
    if record_decisions:
        result.decisions = []
    wall = 0.0
    for req in jobs:
        t_now = req.t_a
        # release completed reservations first (paper: deleteAllocation
        # is called immediately when a job finishes)
        while completions and completions[0][0] <= t_now:
            _, _, ts, te, ids = heapq.heappop(completions)
            t0 = _time.perf_counter()
            sched.delete_allocation(ts, te, ids)
            wall += _time.perf_counter() - t0
        t0 = _time.perf_counter()
        alloc = sched.find_allocation(req, policy, t_now=t_now)
        if alloc is not None:
            sched.add_allocation(alloc.t_s, alloc.t_e, _as_pes(alloc, engine))
        wall += _time.perf_counter() - t0
        if record_decisions:
            result.decisions.append(
                (alloc is not None, alloc.t_s if alloc else -1))
        if alloc is None:
            continue
        result.n_accepted += 1
        wait = alloc.t_s - req.t_r
        result.slowdowns.append((wait + req.t_du) / req.t_du)
        result.busy_area += req.n_pe * req.t_du
        heapq.heappush(
            completions, (alloc.t_e, seq, alloc.t_s, alloc.t_e,
                          _as_pes(alloc, engine)))
        seq += 1
    if jobs:
        result.span = max(jobs[-1].t_a, 1) - jobs[0].t_a + 1
    result.wall_seconds = wall
    return result


def _as_pes(alloc, engine: str):
    return set(alloc.pe_ids) if engine == "list" else list(alloc.pe_ids)


def simulate_batched(
    jobs: Iterable[ARRequest],
    n_pe: int,
    policy: Policy,
    capacity: int = 128,
    pending_capacity: int = 256,
    cross_check: bool = False,
    cross_check_engine: str = "host",
    index_tile: "int | None" = None,
) -> SimResult:
    """On-device fast path: admit the whole stream with one ``lax.scan``.

    Semantically identical to :func:`simulate` with the device engine —
    completions are released before each arrival, then the fused step
    searches and commits — but the entire experiment runs as one
    one-shot :meth:`repro.api.Session.offer` (a single jitted scan,
    :mod:`repro.core.batch`), so there are zero host round-trips
    between requests.  ``capacity``/``pending_capacity`` are starting
    sizes; overflow grows them and re-runs.

    With ``cross_check=True`` the host-loop simulator is run on the
    same workload and the per-job accept/reject decisions, start times
    and metrics are asserted identical (the acceptance gate for the
    batched path).  ``index_tile`` attaches the hierarchical
    availability index (DESIGN.md §12) — decisions stay identical,
    rejection-heavy streams admit faster.
    """
    jobs = sorted(jobs, key=lambda j: j.t_a)
    result = SimResult(policy=policy.value, n_jobs=len(jobs),
                       n_accepted=0, n_pe=n_pe)
    result.decisions = []
    if not jobs:
        return result
    batch = batch_lib.requests_to_batch(jobs)
    session = ReservationService(ServiceConfig(
        n_pe=n_pe, policy=policy, capacity=capacity,
        pending_capacity=pending_capacity, chunk_size=None,
        index_tile=index_tile)).session()
    t0 = _time.perf_counter()
    res = session.offer(batch)
    accepted = np.asarray(res.decision.accepted)       # device sync
    starts = np.asarray(res.decision.t_s)
    result.wall_seconds = _time.perf_counter() - t0
    result.n_accepted = int(accepted.sum())
    result.decisions = [
        (bool(a), int(t)) for a, t in zip(accepted, starts)]
    for i, req in enumerate(jobs):
        if not accepted[i]:
            continue
        wait = int(starts[i]) - req.t_r
        result.slowdowns.append((wait + req.t_du) / req.t_du)
        result.busy_area += req.n_pe * req.t_du
    result.span = max(jobs[-1].t_a, 1) - jobs[0].t_a + 1
    if cross_check:
        ref = simulate(jobs, n_pe, policy, engine=cross_check_engine,
                       record_decisions=True)
        if ref.decisions != result.decisions:
            diff = [i for i, (x, y) in
                    enumerate(zip(ref.decisions, result.decisions))
                    if x != y]
            raise AssertionError(
                f"batched decisions diverge from the {cross_check_engine} "
                f"loop at job indices {diff[:10]} "
                f"({len(diff)}/{len(jobs)} total)")
        assert ref.n_accepted == result.n_accepted
        assert ref.slowdowns == result.slowdowns
        assert ref.busy_area == result.busy_area
    return result


def run_policies(jobs: List[ARRequest], n_pe: int,
                 policies: Iterable[Policy],
                 engine: str = "host") -> List[SimResult]:
    """Evaluate several policies on one shared workload (paper setup)."""
    return [simulate(jobs, n_pe, pol, engine=engine) for pol in policies]
