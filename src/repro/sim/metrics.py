"""Performance metrics of Section 6.1: acceptance rate and slowdown."""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class SimResult:
    """Outcome of one simulation run."""

    policy: str
    n_jobs: int
    n_accepted: int
    slowdowns: List[float] = dataclasses.field(default_factory=list)
    busy_area: float = 0.0          # accepted PE-seconds
    span: float = 0.0               # makespan of the arrival stream
    n_pe: int = 0
    wall_seconds: float = 0.0       # scheduler wall time (data-structure cost)
    # per-job (accepted, t_s) trace; populated on request only
    decisions: Optional[List[Tuple[bool, int]]] = None

    @property
    def acceptance_rate(self) -> float:
        return self.n_accepted / max(self.n_jobs, 1)

    @property
    def avg_slowdown(self) -> float:
        if not self.slowdowns:
            return float("nan")
        return sum(self.slowdowns) / len(self.slowdowns)

    @property
    def utilization(self) -> float:
        denom = self.n_pe * max(self.span, 1.0)
        return self.busy_area / denom

    def summary(self) -> str:
        return (f"{self.policy:8s} accept={self.acceptance_rate:.3f} "
                f"slowdown={self.avg_slowdown:.3f} "
                f"util={self.utilization:.3f} "
                f"sched_wall={self.wall_seconds:.2f}s")


@dataclasses.dataclass
class GridResult:
    """Stacked metrics of one vmapped Section-6 sweep grid.

    Every metric array is indexed ``[policy, load, seed, flexibility]``
    — the cell order of :func:`repro.sim.sweep.simulate_grid`.
    """

    policies: Tuple[str, ...]
    arrival_factors: Tuple[float, ...]
    seeds: Tuple[int, ...]
    flex_factors: Tuple[float, ...]
    acceptance: np.ndarray        # float [P, L, S, F]
    slowdown: np.ndarray          # float [P, L, S, F] (nan: none accepted)
    utilization: np.ndarray       # float [P, L, S, F]
    n_jobs: np.ndarray            # int   [P, L, S, F] valid jobs per cell
    n_accepted: np.ndarray        # int   [P, L, S, F]
    wall_seconds: float = 0.0     # one dispatch for the whole grid
    # per-cell (accepted, t_s) traces, populated on request only:
    # decisions[p][l][s][f] is a list over that cell's (unpadded) jobs
    decisions: Optional[list] = None

    @property
    def n_cells(self) -> int:
        return int(np.prod(self.acceptance.shape))

    @property
    def cells_per_sec(self) -> float:
        return self.n_cells / max(self.wall_seconds, 1e-9)

    def policy_acceptance(self) -> Dict[str, float]:
        """Grid-mean acceptance rate per policy (paper Figs. 2/4/6)."""
        return {p: float(np.nanmean(self.acceptance[i]))
                for i, p in enumerate(self.policies)}

    def policy_slowdown(self) -> Dict[str, float]:
        """Grid-mean slowdown per policy (paper Figs. 3/5/7)."""
        return {p: float(np.nanmean(self.slowdown[i]))
                for i, p in enumerate(self.policies)}

    def summary(self) -> str:
        acc, sd = self.policy_acceptance(), self.policy_slowdown()
        lines = [f"{self.n_cells} cells in {self.wall_seconds:.2f}s "
                 f"({self.cells_per_sec:.1f} cells/s)"]
        for p in self.policies:
            lines.append(f"  {p:8s} accept={acc[p]:.3f} "
                         f"slowdown={sd[p]:.3f}")
        return "\n".join(lines)


def mean_ci95(values: Sequence[float]) -> tuple:
    """(mean, half-width of the normal-approx 95% CI)."""
    n = len(values)
    if n == 0:
        return float("nan"), float("nan")
    mean = sum(values) / n
    if n == 1:
        return mean, float("nan")
    var = sum((v - mean) ** 2 for v in values) / (n - 1)
    return mean, 1.96 * math.sqrt(var / n)
