"""Performance metrics of Section 6.1: acceptance rate and slowdown."""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass
class SimResult:
    """Outcome of one simulation run."""

    policy: str
    n_jobs: int
    n_accepted: int
    slowdowns: List[float] = dataclasses.field(default_factory=list)
    busy_area: float = 0.0          # accepted PE-seconds
    span: float = 0.0               # makespan of the arrival stream
    n_pe: int = 0
    wall_seconds: float = 0.0       # scheduler wall time (data-structure cost)
    # per-job (accepted, t_s) trace; populated on request only
    decisions: Optional[List[Tuple[bool, int]]] = None

    @property
    def acceptance_rate(self) -> float:
        return self.n_accepted / max(self.n_jobs, 1)

    @property
    def avg_slowdown(self) -> float:
        if not self.slowdowns:
            return float("nan")
        return sum(self.slowdowns) / len(self.slowdowns)

    @property
    def utilization(self) -> float:
        denom = self.n_pe * max(self.span, 1.0)
        return self.busy_area / denom

    def summary(self) -> str:
        return (f"{self.policy:8s} accept={self.acceptance_rate:.3f} "
                f"slowdown={self.avg_slowdown:.3f} "
                f"util={self.utilization:.3f} "
                f"sched_wall={self.wall_seconds:.2f}s")


def mean_ci95(values: Sequence[float]) -> tuple:
    """(mean, half-width of the normal-approx 95% CI)."""
    n = len(values)
    if n == 0:
        return float("nan"), float("nan")
    mean = sum(values) / n
    if n == 1:
        return mean, float("nan")
    var = sum((v - mean) ** 2 for v in values) / (n - 1)
    return mean, 1.96 * math.sqrt(var / n)
